//! Quickstart: build a tiny Qwen3-architecture model, generate text with
//! the quantized engine, and see the modeled IMAX cost of the same
//! kernel sequence — the whole stack in ~60 lines of user code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use imax_llm::model::{Engine, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::runtime::BackendRegistry;
use imax_llm::tokenizer::Tokenizer;

fn main() {
    // 1. A tiny Qwen3-style model (GQA + QK-norm + RoPE + SwiGLU),
    //    quantized to Q8_0 — the paper's workhorse format.
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 2025);
    println!(
        "model: {} ({} params, {} on disk as {})",
        cfg.name,
        cfg.n_params(),
        imax_llm::util::human_bytes(weights.nbytes()),
        weights.scheme.name()
    );

    // 2. Tokenize a prompt with the byte-BPE tokenizer.
    let corpus = "a coarse grained linear array streams weights through \
                  a pipeline of processing elements "
        .repeat(6);
    let tok = Tokenizer::train(&corpus, 64);
    let prompt_text = "a coarse grained linear array";
    let prompt = tok.encode_with_bos(prompt_text);
    println!("prompt: {prompt_text:?} -> {} tokens", prompt.len());

    // 3. Generate through the backend registry's instrumented-IMAX
    //    executor: every dot-product kernel the engine dispatches is
    //    also accounted against the IMAX cost model.
    let mut exec = BackendRegistry::build_named("imax").expect("imax backend");
    let mut engine = Engine::new(weights);
    let mut sampler = Sampler::top_k(0.9, 40, 7);
    let result = engine.generate(&prompt, 24, &mut sampler, &mut exec);

    println!("output: {:?}", tok.decode(&result.tokens));
    let rep = exec.report();
    println!(
        "\nmeasured wall time: prefill {:.1} ms, decode {:.1} ms",
        rep.wall_prefill_s * 1e3,
        rep.wall_decode_s * 1e3
    );
    let modeled = rep.modeled.expect("imax backend models phases");
    let p = modeled.prefill;
    let d = modeled.decode;
    println!(
        "modeled on IMAX3 (FPGA, 2 lanes): prefill {:.2} ms, decode {:.2} ms",
        p.total() * 1e3,
        d.total() * 1e3
    );
    println!(
        "decode composition: EXEC {:.0}% LOAD {:.0}% HOST {:.0}% (the paper's \
         LOAD-bound decode, visible even on the tiny model)",
        100.0 * d.exec / d.total(),
        100.0 * d.load / d.total(),
        100.0 * d.host / d.total()
    );
    if let Some(stats) = exec.offload_stats() {
        stats.table("quickstart offload ratios").print();
    }
}
