//! End-to-end serving driver — the required full-system validation
//! (EXPERIMENTS.md §E2E): build (or load) a ~110M-parameter
//! Qwen3-architecture model with real quantized weights, serve a batch of
//! concurrent requests through the coordinator's worker pool, and report
//! latency/throughput plus the modeled IMAX phase economics for the same
//! traffic.
//!
//! ```bash
//! cargo run --release --example serve_e2e            # default: 12 requests
//! SERVE_REQUESTS=32 SERVE_WORKERS=4 cargo run --release --example serve_e2e
//! SERVE_BACKEND=imax cargo run --release --example serve_e2e   # modeled phases
//! ```

use std::time::Instant;

use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::coordinator::{serve_with, Request, ServeOptions};
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::{file as model_file, ModelConfig, ModelWeights, QuantScheme};
use imax_llm::power;
use imax_llm::runtime::ExecSpec;
use imax_llm::tokenizer::Tokenizer;
use imax_llm::util::report::Table;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n_requests = env_usize("SERVE_REQUESTS", 12);
    let n_workers = env_usize("SERVE_WORKERS", 2);
    let n_out = env_usize("SERVE_TOKENS", 24);
    let n_slots = env_usize("SERVE_SLOTS", 4);
    let backend = std::env::var("SERVE_BACKEND").unwrap_or_else(|_| "native".to_string());
    let spec = ExecSpec::parse(&backend).expect("SERVE_BACKEND");

    // ---- build or load the model (the paper loads identical quantized
    //      model files on every platform; we persist ours the same way) ----
    let cfg = ModelConfig::tiny_110m();
    let path = std::env::temp_dir().join("imax_llm_serve_110m_q8.imx3");
    let t0 = Instant::now();
    let weights = if path.exists() {
        println!("loading {} …", path.display());
        model_file::load(&path).expect("load model file")
    } else {
        println!("building {} (Q8_0, random-init) …", cfg.name);
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 2025);
        model_file::save(&w, &path).expect("save model file");
        w
    };
    println!(
        "model ready in {:.1}s: {} params, {} quantized",
        t0.elapsed().as_secs_f64(),
        cfg.n_params(),
        imax_llm::util::human_bytes(weights.nbytes()),
    );

    // ---- request batch: short chat-like prompts (the paper's [8:x]
    //      latency-sensitive Q&A scenario) ----
    let tok = Tokenizer::train(
        &"the accelerator loads quantized weights over dma and multiplies vectors "
            .repeat(12),
        96,
    );
    let prompts = [
        "the accelerator loads",
        "quantized weights over",
        "dma and multiplies",
        "vectors the accelerator",
        "loads quantized weights",
        "over dma and",
    ];
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| Request::new(id, tok.encode_with_bos(prompts[id % prompts.len()]), n_out))
        .collect();
    let total_prompt_toks: usize = requests.iter().map(|r| r.prompt.len()).sum();

    // ---- serve (continuous batching: requests are admitted into free
    //      session slots between decode rounds) ----
    println!(
        "\nserving {n_requests} requests × {n_out} output tokens on {n_workers} workers \
         × {n_slots} sessions [{}] …",
        spec.name()
    );
    let opts = ServeOptions {
        slots_per_worker: n_slots,
        sampler_seed: 42,
        spec,
        ..ServeOptions::default()
    };
    let rep = serve_with(&weights, requests, n_workers, &opts).expect("serve");

    let mut t = Table::new(
        "serve_e2e results (real compute, tiny-110M Q8_0)",
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), format!("{}", rep.completions.len())]);
    t.row(vec![
        "prompt tokens (total)".into(),
        format!("{total_prompt_toks}"),
    ]);
    t.row(vec!["generated tokens".into(), format!("{}", rep.total_tokens)]);
    t.row(vec!["wall time".into(), format!("{:.2} s", rep.wall_s)]);
    t.row(vec![
        "throughput".into(),
        format!("{:.1} tok/s", rep.throughput_tok_s),
    ]);
    t.row(vec![
        "request latency mean".into(),
        format!("{:.3} s", rep.latency_mean_s),
    ]);
    t.row(vec![
        "request latency p50 / p95".into(),
        format!("{:.3} / {:.3} s", rep.latency_p50_s, rep.latency_p95_s),
    ]);
    let prefill: f64 = rep.completions.iter().map(|c| c.prefill_s).sum();
    let decode: f64 = rep.completions.iter().map(|c| c.decode_s).sum();
    t.row(vec![
        "prefill : decode time".into(),
        format!("{:.2} s : {:.2} s", prefill, decode),
    ]);
    t.row(vec!["backend".into(), rep.backend.clone()]);
    if let Some(modeled) = rep.modeled {
        t.row(vec![
            "modeled IMAX prefill : decode".into(),
            format!(
                "{:.2} s : {:.2} s",
                modeled.prefill.total(),
                modeled.decode.total()
            ),
        ]);
    }
    t.print();

    // A couple of sample generations (random weights → gibberish, but
    // real tokens through the real quantized pipeline).
    for c in rep.completions.iter().take(2) {
        println!(
            "  req {} (worker {}): {:?}",
            c.id,
            c.worker,
            tok.decode(&c.tokens)
        );
    }

    // ---- the same traffic on the modeled devices ----
    println!("\nmodeled cost of this traffic at paper scale (per request, [8:{n_out}]):");
    let mut mt = Table::new(
        "modeled per-request cost (Qwen3-0.6B Q8_0)",
        &["device", "latency (s)", "PDP (J)"],
    );
    let w = Workload {
        cfg: ModelConfig::qwen3_0_6b(),
        scheme: QuantScheme::Q8_0,
        n_in: 8,
        n_out,
    };
    for dev in [ImaxDevice::fpga(2), ImaxDevice::asic28(2)] {
        let run = simulate_auto(&w, &dev, TransferMode::Coalesced);
        let e = power::imax_energy(&dev, &LmmConfig::new(dev.lmm_kb), &run);
        mt.row(vec![
            dev.name(),
            format!("{:.2}", run.breakdown.e2e_seconds()),
            format!("{:.1}", e.pdp_j()),
        ]);
    }
    for g in imax_llm::baseline::GpuDevice::all() {
        mt.row(vec![
            g.name.to_string(),
            format!("{:.2}", g.e2e_seconds(&w)),
            format!("{:.1}", g.energy(&w).pdp_j()),
        ]);
    }
    mt.print();
}
