//! Bottleneck explorer: the paper's §V analysis as an interactive-ish
//! report — phase breakdowns, context-length scaling of the LOAD share,
//! LMM sweet-spot, lane scalability, and the host-interconnect what-if
//! its future-work section proposes (PCIe-class host).
//!
//! ```bash
//! cargo run --release --example bottleneck_explorer
//! ```

use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::coordinator::scheduler::lane_sweep;
use imax_llm::imax::{Component, ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::{ModelConfig, QuantScheme};
use imax_llm::power;
use imax_llm::util::report::Table;

fn main() {
    let dev = ImaxDevice::fpga(2);

    // --- §V.B: LOAD share grows with context length ---
    let mut t = Table::new(
        "decode LOAD share vs context length (Qwen3-1.7B Q8_0, FPGA) — §V.B \
         'its proportional share grows with longer context lengths'",
        &["n_in", "n_out", "decode LOAD %", "decode EXEC %", "E2E (s)"],
    );
    for (n_in, n_out) in [(8, 8), (32, 8), (128, 8), (512, 8)] {
        let w = Workload {
            cfg: ModelConfig::qwen3_1_7b(),
            scheme: QuantScheme::Q8_0,
            n_in,
            n_out,
        };
        let run = simulate_auto(&w, &dev, TransferMode::Coalesced);
        let d = run.breakdown.decode;
        let imax_side = d.total() - d.host;
        t.row(vec![
            n_in.to_string(),
            n_out.to_string(),
            format!("{:.1}%", 100.0 * d.load / imax_side),
            format!("{:.1}%", 100.0 * d.exec / imax_side),
            format!("{:.2}", run.breakdown.e2e_seconds()),
        ]);
    }
    t.print();

    // --- macro breakdown for the paper's representative workload ---
    let w = Workload {
        cfg: ModelConfig::qwen3_0_6b(),
        scheme: QuantScheme::Q3KS,
        n_in: 32,
        n_out: 16,
    };
    let run = simulate_auto(&w, &dev, TransferMode::Coalesced);
    let tot = run.breakdown.total();
    let mut m = Table::new(
        "macro breakdown — Qwen3-0.6B Q3_K_S [32:16] on the FPGA \
         (paper §V.B: 16.3 s total, LOAD > EXEC)",
        &["component", "seconds", "share"],
    );
    for c in Component::ALL {
        m.row(vec![
            c.name().into(),
            format!("{:.2}", tot.get(c)),
            format!("{:.1}%", 100.0 * tot.get(c) / tot.total()),
        ]);
    }
    m.row(vec![
        "TOTAL".into(),
        format!("{:.2}", tot.total()),
        "100%".into(),
    ]);
    m.print();

    // --- §V.C what-if: a host with PCIe-class interconnect + 8 cores ---
    let mut hf = Table::new(
        "future-work what-if: stronger host (8 cores, 8 GB/s interconnect)",
        &["config", "E2E (s)", "best lanes", "8-lane E2E (s)"],
    );
    for (label, mk) in [
        ("dual-A72 + FPGA NoC (paper)", {
            fn f() -> ImaxDevice {
                ImaxDevice::fpga(2)
            }
            f as fn() -> ImaxDevice
        }),
        ("8-core host + PCIe-class link", {
            fn f() -> ImaxDevice {
                let mut d = ImaxDevice::fpga(2);
                d.host.cores = 8;
                d.host.memcpy_bw *= 4.0;
                d.host.call_overhead /= 4.0;
                d.dma_bw = 8.0e9;
                d
            }
            f as fn() -> ImaxDevice
        }),
    ] {
        let base = mk();
        let pts = lane_sweep(&w, &base, &[1, 2, 4, 8], TransferMode::Coalesced);
        let best = pts
            .iter()
            .min_by(|a, b| a.e2e_s.partial_cmp(&b.e2e_s).unwrap())
            .unwrap();
        let two = &pts[1];
        hf.row(vec![
            label.into(),
            format!("{:.2}", two.e2e_s),
            best.lanes.to_string(),
            format!("{:.2}", pts[3].e2e_s),
        ]);
    }
    hf.print();
    println!(
        "note: with the stronger host, scaling past 2 lanes finally pays off — \
         the paper's §V.C conclusion."
    );

    // --- LMM sweep on the challenging 8B Q8_0 case (paper §V.A) ---
    let mut l = Table::new(
        "LMM size vs PDP — Qwen3-8B Q8_0 [32:16] (28nm): bigger LMMs cannot \
         rescue a DMA-bound kernel (§V.A)",
        &["LMM (KB)", "PDP (J)", "offload total"],
    );
    let w8 = Workload {
        cfg: ModelConfig::qwen3_8b(),
        scheme: QuantScheme::Q8_0,
        n_in: 32,
        n_out: 16,
    };
    for kb in [16usize, 64, 256, 512] {
        let d = ImaxDevice::asic28(2).with_lmm_kb(kb);
        let run = simulate_auto(&w8, &d, TransferMode::Coalesced);
        let e = power::imax_energy(&d, &LmmConfig::new(kb), &run);
        l.row(vec![
            kb.to_string(),
            format!("{:.0}", e.pdp_j()),
            format!("{:.1}%", 100.0 * run.stats.total_ratio()),
        ]);
    }
    l.print();
}
