//! Energy report: reproduce the paper's headline energy-efficiency story
//! in one run — PDP/EDP across the five platforms for the three scenario
//! classes its introduction motivates (conversational Q&A, summarization,
//! generation), plus the improvement factors vs each GPU.
//!
//! ```bash
//! cargo run --release --example energy_report
//! ```

use imax_llm::baseline::GpuDevice;
use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::harness::workloads;
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::{ModelConfig, QuantScheme};
use imax_llm::power;
use imax_llm::util::report::Table;

fn main() {
    // The paper's three practical scenarios (§IV.A): latency-sensitive
    // Q&A [8:1]/[8:4], summarization [32:4], generation [16:16]/[32:16].
    let scenarios: [(&str, usize, usize); 3] =
        [("conversational Q&A", 8, 4), ("summarization", 32, 4), ("generation", 32, 16)];

    let asic = ImaxDevice::asic28(2);
    for (label, n_in, n_out) in scenarios {
        let mut t = Table::new(
            &format!("{label} [{n_in}:{n_out}] — energy metrics"),
            &["model", "quant", "device", "latency (s)", "PDP (J)", "EDP (J*s)"],
        );
        for cfg in [ModelConfig::qwen3_0_6b(), ModelConfig::qwen3_1_7b()] {
            for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS] {
                let w = Workload {
                    cfg: cfg.clone(),
                    scheme,
                    n_in,
                    n_out,
                };
                let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
                let lat = run.breakdown.e2e_seconds();
                let e = power::imax_energy(&asic, &LmmConfig::new(64), &run);
                t.row(vec![
                    cfg.name.into(),
                    scheme.name().into(),
                    "IMAX3 (28nm)".into(),
                    format!("{lat:.2}"),
                    format!("{:.1}", e.pdp_j()),
                    format!("{:.1}", lat * e.pdp_j()),
                ]);
                for g in GpuDevice::all() {
                    let gl = g.e2e_seconds(&w);
                    let ge = g.energy(&w);
                    t.row(vec![
                        cfg.name.into(),
                        scheme.name().into(),
                        g.name.into(),
                        format!("{gl:.2}"),
                        format!("{:.1}", ge.pdp_j()),
                        format!("{:.1}", gl * ge.pdp_j()),
                    ]);
                }
            }
        }
        t.print();
    }

    // Headline factors across the whole grid (paper: "improving the PDP
    // by up to 44.4× and 13.6× compared with the RTX 4090 and Jetson").
    let mut best_rtx = (0.0f64, String::new());
    let mut best_gtx = (0.0f64, String::new());
    let mut best_jet = (0.0f64, String::new());
    for w in workloads::grid() {
        let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
        let pdp = power::imax_energy(&asic, &LmmConfig::new(64), &run).pdp_j();
        let upd = |slot: &mut (f64, String), dev: &GpuDevice| {
            let r = dev.energy(&w).pdp_j() / pdp;
            if r > slot.0 {
                *slot = (r, w.label());
            }
        };
        upd(&mut best_rtx, &GpuDevice::rtx4090());
        upd(&mut best_gtx, &GpuDevice::gtx1080ti());
        upd(&mut best_jet, &GpuDevice::jetson_orin());
    }
    let mut h = Table::new(
        "headline PDP improvement factors (IMAX 28nm vs GPU, max over 54 workloads)",
        &["vs", "factor", "at workload", "paper claims"],
    );
    h.row(vec!["RTX 4090".into(), format!("{:.1}x", best_rtx.0), best_rtx.1, "44.4x".into()]);
    h.row(vec!["GTX 1080 Ti".into(), format!("{:.1}x", best_gtx.0), best_gtx.1, "54x".into()]);
    h.row(vec!["Jetson AGX Orin".into(), format!("{:.1}x", best_jet.0), best_jet.1, "13.6x".into()]);
    h.print();
}
