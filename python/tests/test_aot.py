"""AOT export path tests: HLO text generation is deterministic, parseable
by XLA's text parser (sanity), and the manifest describes every artifact."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.config import TINY, TINY_LINEAR_SHAPES
from compile.kernels import q8_0_dot
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def lower_q8(n, k):
    shapes = [
        jax.ShapeDtypeStruct((n, k), jnp.int8),
        jax.ShapeDtypeStruct((n, k // 32), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.int8),
        jax.ShapeDtypeStruct((k // 32,), jnp.float32),
    ]
    return aot.to_hlo_text(jax.jit(q8_0_dot).lower(*shapes))


def test_hlo_text_is_deterministic():
    a = lower_q8(64, 256)
    b = lower_q8(64, 256)
    assert a == b


def test_hlo_text_structure():
    text = lower_q8(64, 256)
    assert text.startswith("HloModule"), "HLO text header"
    assert "ENTRY" in text
    # return_tuple=True → tuple-shaped root.
    assert "(f32[64]" in text.replace(" ", "")[: len(text)] or "tuple" in text


def test_kernel_artifacts_cover_all_tiny_shapes():
    names = [a[0] for a in aot.kernel_artifacts()]
    for n, k in TINY_LINEAR_SHAPES:
        assert f"q8_0_dot_{n}x{k}" in names
    assert any(s.startswith("fp16_dot") for s in names)
    assert any(s.startswith("q6_k_dot") for s in names)
    assert any(s.startswith("q3_k_dot") for s in names)


def test_manifest_matches_artifacts_if_built():
    manifest = os.path.join(ARTIFACTS, "manifest.txt")
    if not os.path.exists(manifest):
        import pytest

        pytest.skip("artifacts not built (run `make artifacts`)")
    lines = [l for l in open(manifest).read().splitlines() if l.strip()]
    assert len(lines) >= 10
    for line in lines:
        name, sig, digest = line.split("\t")
        path = os.path.join(ARTIFACTS, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {name}"
        import hashlib

        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest()[:16] == digest, name
        assert sig  # non-empty shape signature


def test_lowered_kernel_numerics_match_eager():
    # The lowered (jitted) function and eager interpret-mode execution
    # must agree exactly.
    rng = np.random.default_rng(3)
    n, k = 32, 256
    wq, wd = ref.quantize_q8_0((rng.standard_normal((n, k)) * 0.5).astype(np.float32))
    aq, ad = ref.quantize_q8_0(rng.standard_normal(k).astype(np.float32))
    jitted = jax.jit(q8_0_dot)
    np.testing.assert_array_equal(
        np.asarray(jitted(wq, wd, aq, ad)), np.asarray(q8_0_dot(wq, wd, aq, ad))
    )


def test_tiny_config_consistency():
    # Shared config invariants the Rust side mirrors.
    assert TINY.q_dim == TINY.n_heads * TINY.head_dim
    assert TINY.kv_dim == TINY.n_kv_heads * TINY.head_dim
    assert TINY.d_model % 256 == 0 and TINY.d_ffn % 256 == 0
    assert (TINY.vocab_size, TINY.d_model) in TINY_LINEAR_SHAPES
