"""L2 model-graph tests: the quantized Pallas-kernel layer forward must
track a dense f32 reference implementation of the same architecture."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.config import TINY
from compile.kernels import ref


def rng():
    return np.random.default_rng(2025)


def make_layer_inputs(r, ctx_prev=7, sigma=0.4):
    cfg = TINY

    def g(*shape, s=sigma):
        return (r.standard_normal(shape) * s).astype(np.float32)

    x = g(cfg.d_model, s=1.0)
    norms = dict(
        attn_norm=np.abs(g(cfg.d_model, s=0.2)) + 0.9,
        ffn_norm=np.abs(g(cfg.d_model, s=0.2)) + 0.9,
        q_norm=np.abs(g(cfg.head_dim, s=0.2)) + 0.9,
        k_norm=np.abs(g(cfg.head_dim, s=0.2)) + 0.9,
    )
    sigma_w = 0.7 / np.sqrt(cfg.d_model)
    dense = dict(
        wq=g(cfg.q_dim, cfg.d_model, s=sigma_w),
        wk=g(cfg.kv_dim, cfg.d_model, s=sigma_w),
        wv=g(cfg.kv_dim, cfg.d_model, s=sigma_w),
        wo=g(cfg.d_model, cfg.q_dim, s=0.7 / np.sqrt(cfg.q_dim)),
        wg=g(cfg.d_ffn, cfg.d_model, s=sigma_w),
        wu=g(cfg.d_ffn, cfg.d_model, s=sigma_w),
        wd=g(cfg.d_model, cfg.d_ffn, s=0.7 / np.sqrt(cfg.d_ffn)),
    )
    quant = {}
    for name, w in dense.items():
        q, d = ref.quantize_q8_0(w)
        quant[f"{name}_q"] = q
        quant[f"{name}_d"] = d
    caches = dict(
        k_cache=g(ctx_prev, cfg.kv_dim, s=1.0),
        v_cache=g(ctx_prev, cfg.kv_dim, s=1.0),
    )
    return x, norms, dense, quant, caches


def dense_layer_reference(x, norms, dense, caches):
    """f32 reference of layer_fwd (same math, dequantized weights)."""
    cfg = TINY
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    pos = caches["k_cache"].shape[0]

    def rms(v, w):
        return v / np.sqrt((v * v).mean() + cfg.rms_eps) * w

    def rope(v, p):
        half = hd // 2
        i = np.arange(half)
        freq = cfg.rope_theta ** (-2.0 * i / hd)
        ang = p * freq
        a, b = v[:half].copy(), v[half:].copy()
        return np.concatenate(
            [a * np.cos(ang) - b * np.sin(ang), a * np.sin(ang) + b * np.cos(ang)]
        )

    # Use the *quantized-dequantized* weights so only activation-quant and
    # kernel arithmetic differ from the Pallas path.
    xn = rms(x, norms["attn_norm"])
    q = dense["wq"] @ xn
    k = dense["wk"] @ xn
    v = dense["wv"] @ xn
    qh = q.reshape(cfg.n_heads, hd)
    kh = k.reshape(cfg.n_kv_heads, hd)
    qh = np.stack([rope(rms(h, norms["q_norm"]), pos) for h in qh])
    kh = np.stack([rope(rms(h, norms["k_norm"]), pos) for h in kh])
    k_all = np.concatenate(
        [caches["k_cache"].reshape(pos, cfg.n_kv_heads, hd), kh[None]], axis=0
    )
    v_all = np.concatenate(
        [caches["v_cache"].reshape(pos, cfg.n_kv_heads, hd),
         v.reshape(1, cfg.n_kv_heads, hd)], axis=0
    )
    outs = []
    for h in range(cfg.n_heads):
        kvh = h // groups
        s = k_all[:, kvh, :] @ qh[h] / np.sqrt(hd)
        p = np.exp(s - s.max())
        p /= p.sum()
        outs.append(p @ v_all[:, kvh, :])
    attn = np.concatenate(outs)
    x1 = x + dense["wo"] @ attn
    xn2 = rms(x1, norms["ffn_norm"])
    gate = dense["wg"] @ xn2
    up = dense["wu"] @ xn2
    act = gate / (1 + np.exp(-gate)) * up
    x2 = x1 + dense["wd"] @ act
    return x2, kh.reshape(-1), v


def test_layer_fwd_tracks_dense_reference():
    r = rng()
    x, norms, dense, quant, caches = make_layer_inputs(r)
    # Replace dense weights with their dequantized Q8_0 versions so the
    # comparison isolates kernel arithmetic (not quantization noise).
    dense_dq = {
        name: ref.dequantize_q8_0(quant[f"{name}_q"], quant[f"{name}_d"])
        for name in dense
    }
    want_x, want_k, want_v = dense_layer_reference(x, norms, dense_dq, caches)

    got_x, got_k, got_v = model.layer_fwd_q8(
        x,
        norms["attn_norm"], norms["ffn_norm"], norms["q_norm"], norms["k_norm"],
        quant["wq_q"], quant["wq_d"],
        quant["wk_q"], quant["wk_d"],
        quant["wv_q"], quant["wv_d"],
        quant["wo_q"], quant["wo_d"],
        quant["wg_q"], quant["wg_d"],
        quant["wu_q"], quant["wu_d"],
        quant["wd_q"], quant["wd_d"],
        caches["k_cache"], caches["v_cache"],
    )
    # Activation quantization adds ~1% noise on top of exact arithmetic.
    scale = np.abs(want_x).mean()
    assert np.abs(np.asarray(got_x) - want_x).max() < 0.08 * scale + 0.05
    np.testing.assert_allclose(np.asarray(got_k), want_k, rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=5e-2, atol=5e-2)


def test_lm_head_matches_manual():
    r = rng()
    cfg = TINY
    x = (r.standard_normal(cfg.d_model)).astype(np.float32)
    fn = np.abs(r.standard_normal(cfg.d_model).astype(np.float32)) * 0.1 + 0.95
    w = (r.standard_normal((cfg.vocab_size, cfg.d_model)) * 0.05).astype(np.float32)
    hq, hd = ref.quantize_q8_0(w)
    got = np.asarray(model.lm_head_q8(x, fn, hq, hd))
    # Manual: rmsnorm, quantize activation with the same scheme, ref dot.
    xn = x / np.sqrt((x * x).mean() + cfg.rms_eps) * fn
    aq, ad = ref.quantize_q8_0(xn)
    want = ref.ref_dot_q8_0(hq, hd, aq, ad)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert got.shape == (cfg.vocab_size,)


def test_rope_matches_rust_convention():
    # Cross-check the jnp rope against the numpy reference used above
    # (both mirror rust ops::rope_inplace).
    v = np.arange(8, dtype=np.float32)
    out = np.asarray(model.rope_jnp(jnp.asarray(v), 3.0, 1e4))
    half = 4
    i = np.arange(half)
    freq = 1e4 ** (-2.0 * i / 8)
    ang = 3.0 * freq
    want = np.concatenate(
        [v[:half] * np.cos(ang) - v[half:] * np.sin(ang),
         v[:half] * np.sin(ang) + v[half:] * np.cos(ang)]
    )
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


def test_quantize_act_matches_ref():
    r = rng()
    x = (r.standard_normal(512) * 1.7).astype(np.float32)
    q_j, d_j = model.quantize_q8_0_act_jnp(jnp.asarray(x))
    q_n, d_n = ref.quantize_q8_0(x)
    np.testing.assert_array_equal(np.asarray(q_j), q_n)
    np.testing.assert_allclose(np.asarray(d_j), d_n, rtol=0, atol=0)


def test_layer_fwd_is_jittable_and_deterministic():
    r = rng()
    x, norms, dense, quant, caches = make_layer_inputs(r)
    args = (
        x,
        norms["attn_norm"], norms["ffn_norm"], norms["q_norm"], norms["k_norm"],
        quant["wq_q"], quant["wq_d"],
        quant["wk_q"], quant["wk_d"],
        quant["wv_q"], quant["wv_d"],
        quant["wo_q"], quant["wo_d"],
        quant["wg_q"], quant["wg_d"],
        quant["wu_q"], quant["wu_d"],
        quant["wd_q"], quant["wd_d"],
        caches["k_cache"], caches["v_cache"],
    )
    jit_fn = jax.jit(model.layer_fwd_q8)
    a = jit_fn(*args)
    b = jit_fn(*args)
    for x1, x2 in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
