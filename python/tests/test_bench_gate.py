"""Unit tests for the CI bench gate (scripts/check_bench_regression.py):
the per-metric ``gate_fails`` helper's band and floor semantics, plus
end-to-end exit codes for a floor-gated metric. Stdlib only — no jax."""

import importlib.util
import json
import os
import subprocess
import sys

SCRIPT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "scripts",
    "check_bench_regression.py",
)


def _load():
    spec = importlib.util.spec_from_file_location("check_bench_regression", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


gate = _load()


# ---------------------------------------------------------------------------
# Band gate (the default +/-tolerance semantics)
# ---------------------------------------------------------------------------

def test_band_lower_passes_inside_tolerance():
    assert not gate.gate_fails("lower", 100.0, 119.0, 0.20)
    assert not gate.gate_fails("lower", 100.0, 50.0, 0.20)


def test_band_lower_fails_beyond_tolerance():
    assert gate.gate_fails("lower", 100.0, 121.0, 0.20)


def test_band_higher_passes_inside_tolerance():
    assert not gate.gate_fails("higher", 100.0, 81.0, 0.20)
    assert not gate.gate_fails("higher", 100.0, 500.0, 0.20)


def test_band_higher_fails_beyond_tolerance():
    assert gate.gate_fails("higher", 100.0, 79.0, 0.20)


# ---------------------------------------------------------------------------
# Floor gate (absolute threshold; baseline value is trajectory-only)
# ---------------------------------------------------------------------------

def test_floor_higher_gates_on_threshold_not_baseline():
    # Baseline records 1.882 but the gate is the 1.7 floor: a drop to
    # 1.75 (a >5% band regression) still passes.
    assert not gate.gate_fails("higher", 1.882, 1.75, 0.20, floor=1.7)
    assert gate.gate_fails("higher", 1.882, 1.69, 0.20, floor=1.7)


def test_floor_higher_ignores_null_baseline_value():
    # A staged floor metric (value null) still gates.
    assert not gate.gate_fails("higher", None, 1.88, 0.20, floor=1.7)
    assert gate.gate_fails("higher", None, 1.2, 0.20, floor=1.7)


def test_floor_lower_is_a_ceiling():
    assert not gate.gate_fails("lower", 10.0, 4.0, 0.20, floor=5.0)
    assert gate.gate_fails("lower", 10.0, 6.0, 0.20, floor=5.0)


# ---------------------------------------------------------------------------
# End-to-end: exit codes through the CLI
# ---------------------------------------------------------------------------

def _run(tmp_path, baseline, current):
    bpath = tmp_path / "baseline.json"
    cpath = tmp_path / "current.json"
    bpath.write_text(json.dumps({"metrics": baseline}))
    cpath.write_text(json.dumps(current))
    return subprocess.run(
        [sys.executable, SCRIPT, "--baseline", str(bpath), "--current", str(cpath)],
        capture_output=True,
        text=True,
    )


FLOOR_BASE = {
    "kv_quant/stream_bytes_ratio": {
        "value": 1.8823529411764706,
        "better": "higher",
        "check": True,
        "floor": 1.7,
    }
}


def test_cli_floor_pass(tmp_path):
    cur = {
        "bench": "kv_quant",
        "metrics": {
            "stream_bytes_ratio": {"value": 1.75, "better": "higher", "check": True}
        },
    }
    res = _run(tmp_path, FLOOR_BASE, cur)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ok (floor 1.7)" in res.stdout


def test_cli_floor_fail(tmp_path):
    cur = {
        "bench": "kv_quant",
        "metrics": {
            "stream_bytes_ratio": {"value": 1.6, "better": "higher", "check": True}
        },
    }
    res = _run(tmp_path, FLOOR_BASE, cur)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "REGRESSION" in res.stdout


def test_cli_floor_metric_missing_from_run_fails(tmp_path):
    cur = {"bench": "kv_quant", "metrics": {}}
    res = _run(tmp_path, FLOOR_BASE, cur)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "MISSING (gated)" in res.stdout
