"""Pallas kernels vs the pure-NumPy oracle (ref.py) — the core L1
correctness signal. Hypothesis sweeps shapes and value distributions."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import QK8_0, QK_K
from compile.kernels import fp16_dot, q3_k_dot, q6_k_dot, q8_0_dot
from compile.kernels import ref
from compile.kernels.common import LMM_BYTES, vmem_tile_bytes
from compile.kernels.fp16_dot import tile_n_for as fp16_tile
from compile.kernels.q3_k_dot import tile_n_for as q3_tile
from compile.kernels.q6_k_dot import tile_n_for as q6_tile
from compile.kernels.q8_0_dot import tile_n_for as q8_tile


def rng_for(seed):
    return np.random.default_rng(seed)


def gaussian(rng, shape, sigma=1.0):
    return (rng.standard_normal(shape) * sigma).astype(np.float32)


# ---------------------------------------------------------------------------
# Quantizer self-consistency (round-trips through the packed layouts)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_q6_codes_pack_roundtrip(seed, nsb):
    rng = rng_for(seed)
    q = rng.integers(0, 64, size=(3, nsb * QK_K), dtype=np.int64)
    ql, qh = ref.encode_q6_codes(q)
    assert ql.shape == (3, nsb * 128) and qh.shape == (3, nsb * 64)
    np.testing.assert_array_equal(ref.decode_q6_codes(ql, qh), q)


@given(st.integers(0, 2**32 - 1), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_q3_codes_pack_roundtrip(seed, nsb):
    rng = rng_for(seed)
    q = rng.integers(-4, 4, size=(2, nsb * QK_K), dtype=np.int64)
    qs, hm = ref.encode_q3_codes(q)
    assert qs.shape == (2, nsb * 64) and hm.shape == (2, nsb * 32)
    np.testing.assert_array_equal(ref.decode_q3_codes(qs, hm), q)


@given(st.integers(0, 2**32 - 1), st.floats(0.05, 4.0))
@settings(max_examples=15, deadline=None)
def test_quantize_rmse_bounds(seed, sigma):
    rng = rng_for(seed)
    x = gaussian(rng, (4, 2 * QK_K), sigma)
    scale = np.sqrt((x**2).mean())

    y8 = ref.dequantize_q8_0(*ref.quantize_q8_0(x))
    assert np.sqrt(((x - y8) ** 2).mean()) / scale < 0.012

    y6 = ref.dequantize_q6_k(*ref.quantize_q6_k(x))
    assert np.sqrt(((x - y6) ** 2).mean()) / scale < 0.05

    y3 = ref.dequantize_q3_k(*ref.quantize_q3_k(x))
    assert np.sqrt(((x - y3) ** 2).mean()) / scale < 0.35


def test_q8_k_bsums_consistent():
    rng = rng_for(7)
    x = gaussian(rng, (2 * QK_K,))
    q, d, bsums = ref.quantize_q8_k(x)
    np.testing.assert_array_equal(
        bsums, q.reshape(-1, 16).astype(np.int16).sum(axis=-1)
    )
    assert d.shape == (2,)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle — hypothesis over shapes and distributions
# ---------------------------------------------------------------------------

K_CHOICES_32 = [32, 64, 256, 768]
K_CHOICES_256 = [256, 512, 768]
N_CHOICES = [1, 8, 33, 128]


@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from(N_CHOICES),
    st.sampled_from(K_CHOICES_32),
    st.floats(0.1, 3.0),
)
@settings(max_examples=25, deadline=None)
def test_q8_0_dot_matches_ref(seed, n, k, sigma):
    rng = rng_for(seed)
    wq, wd = ref.quantize_q8_0(gaussian(rng, (n, k), sigma))
    aq, ad = ref.quantize_q8_0(gaussian(rng, (k,)))
    got = np.asarray(q8_0_dot(wq, wd, aq, ad))
    want = ref.ref_dot_q8_0(wq, wd, aq, ad)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from(N_CHOICES),
    st.sampled_from(K_CHOICES_256),
)
@settings(max_examples=20, deadline=None)
def test_q6_k_dot_matches_ref(seed, n, k):
    rng = rng_for(seed)
    ql, qh, sc, d = ref.quantize_q6_k(gaussian(rng, (n, k)))
    aq, ad, _ = ref.quantize_q8_k(gaussian(rng, (k,)))
    got = np.asarray(q6_k_dot(ql, qh, sc, d, aq, ad))
    want = ref.ref_dot_q6_k(ql, qh, sc, d, aq, ad)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from(N_CHOICES),
    st.sampled_from(K_CHOICES_256),
    st.booleans(),
)
@settings(max_examples=20, deadline=None)
def test_q3_k_dot_matches_ref(seed, n, k, cvt53):
    rng = rng_for(seed)
    qs, hm, sc6, d = ref.quantize_q3_k(gaussian(rng, (n, k)))
    aq, ad, _ = ref.quantize_q8_k(gaussian(rng, (k,)))
    got = np.asarray(q3_k_dot(qs, hm, sc6, d, aq, ad, cvt53=cvt53))
    want = ref.ref_dot_q3_k(qs, hm, sc6, d, aq, ad, cvt53=cvt53)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from(N_CHOICES),
    st.sampled_from([32, 64, 256]),
)
@settings(max_examples=20, deadline=None)
def test_fp16_dot_matches_ref(seed, n, k):
    rng = rng_for(seed)
    w16 = gaussian(rng, (n, k)).astype(np.float16)
    a = gaussian(rng, (k,))
    got = np.asarray(fp16_dot(w16, a))
    want = ref.ref_dot_fp16(w16, a)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Accuracy against the unquantized dot (end-to-end quantization error)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "fmt,tol",
    [("q8_0", 0.02), ("q6_k", 0.05), ("q3_k", 0.30)],
)
def test_quantized_dot_tracks_f32(fmt, tol):
    rng = rng_for(11)
    n, k = 64, 512
    w = gaussian(rng, (n, k), 0.5)
    a = gaussian(rng, (k,))
    want = w @ a
    if fmt == "q8_0":
        aq, ad = ref.quantize_q8_0(a)
        got = np.asarray(q8_0_dot(*ref.quantize_q8_0(w), aq, ad))
    elif fmt == "q6_k":
        aq, ad, _ = ref.quantize_q8_k(a)
        got = np.asarray(q6_k_dot(*ref.quantize_q6_k(w), aq, ad))
    else:
        aq, ad, _ = ref.quantize_q8_k(a)
        got = np.asarray(q3_k_dot(*ref.quantize_q3_k(w), aq, ad))
    scale = np.linalg.norm(w, axis=-1) * np.linalg.norm(a)
    assert np.max(np.abs(got - want) / scale) < tol


# ---------------------------------------------------------------------------
# CVT53 approximation quality (paper: "negligible impact")
# ---------------------------------------------------------------------------

def test_cvt53_negligible():
    rng = rng_for(13)
    n, k = 32, 1024
    qs, hm, sc6, d = ref.quantize_q3_k(gaussian(rng, (n, k)))
    aq, ad, _ = ref.quantize_q8_k(gaussian(rng, (k,)))
    exact = ref.ref_dot_q3_k(qs, hm, sc6, d, aq, ad, cvt53=False)
    approx = ref.ref_dot_q3_k(qs, hm, sc6, d, aq, ad, cvt53=True)
    denom = np.abs(exact).mean() + 1e-6
    assert np.abs(exact - approx).mean() / denom < 0.08


# ---------------------------------------------------------------------------
# LMM budget: every kernel's VMEM tile must fit the 64 KB LMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(128, 256), (256, 256), (768, 256), (2048, 256), (256, 768)])
def test_vmem_tiles_fit_lmm(n, k):
    cases = [
        (fp16_tile(n, k), 2 * k, 4 * k),
        (q8_tile(n, k), k + k // 8, k + k // 8),
        (q6_tile(n, k), k // 2 + k // 4 + k // 16 + k // QK_K * 4, k + k // QK_K * 4),
        (q3_tile(n, k), k // 4 + k // 8 + k // 16 + k // QK_K * 4, k + k // QK_K * 4),
    ]
    for tile, per_row, shared in cases:
        assert n % tile == 0, "tile divides N"
        assert vmem_tile_bytes(tile, per_row, shared) <= LMM_BYTES
