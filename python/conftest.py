"""Pytest anchor: importing this conftest puts `python/` on sys.path so
the suites can `from compile import ...` whether pytest is invoked from
the repository root (`python -m pytest python/tests -q`) or from
`python/` itself."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
