"""Build-time compile path (L1 Pallas kernels + L2 JAX model + AOT export).

Python runs ONCE: `make artifacts` invokes `compile.aot`, which lowers the
jitted model/kernels to HLO text under `artifacts/`. The Rust coordinator
loads those artifacts via PJRT; Python is never on the request path.
"""
