"""Shared tiny-model configuration.

Mirrors `rust/src/model/config.rs::ModelConfig::tiny()` exactly — the AOT
artifacts are lowered at these shapes and the Rust runtime feeds them
tensors of matching geometry. Keep the two definitions in sync (the Rust
integration tests will fail loudly on any drift).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class TinyConfig:
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ffn: int = 768
    vocab_size: int = 2048
    qk_norm: bool = True
    rope_theta: float = 1e4
    rms_eps: float = 1e-6
    max_seq_len: int = 512

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


TINY = TinyConfig()

# (rows, cols) of every linear projection of the tiny model — the shape set
# the per-format AOT kernel artifacts are compiled for.
TINY_LINEAR_SHAPES = sorted(
    {
        (TINY.q_dim, TINY.d_model),        # q_proj  (256, 256)
        (TINY.kv_dim, TINY.d_model),       # k/v_proj (128, 256)
        (TINY.d_model, TINY.q_dim),        # o_proj  (256, 256)
        (TINY.d_ffn, TINY.d_model),        # gate/up (768, 256)
        (TINY.d_model, TINY.d_ffn),        # down    (256, 768)
        (TINY.vocab_size, TINY.d_model),   # lm_head (2048, 256)
    }
)

# Super-block / block sizes (ggml geometry, mirrored from rust/src/quant).
QK8_0 = 32
QK_K = 256
