"""L2 — the Qwen3-architecture compute graph in JAX, calling the L1
Pallas kernels for every linear projection.

Mirrors `rust/src/model/engine.rs` operator-for-operator (RMSNorm → GQA
attention with QK-Norm + RoPE → SwiGLU), at the tiny config the AOT
artifacts are lowered for. Weights enter as the packed quantized arrays
the paper's DMA transfers carry (e.g. Q8_0 = int8 codes + f32 block
scales), so the Pallas kernels' decode/MAC pipelines lower into the same
HLO module that the Rust runtime executes via PJRT.
"""

import jax
import jax.numpy as jnp

from .config import QK8_0, TINY
from .kernels import q8_0_dot


# --------------------------------------------------------------------------
# Host-op mirrors (must match rust/src/model/ops.rs bit-for-bit in f32
# semantics; summation order may differ, tolerances cover it).
# --------------------------------------------------------------------------

def round_away_jnp(x):
    """Round half away from zero (Rust f32::round)."""
    return jnp.trunc(x + jnp.copysign(0.5, x))


def rmsnorm_jnp(x, w, eps):
    ss = jnp.mean(x * x)
    return x * jax.lax.rsqrt(ss + eps) * w


def rope_jnp(v, pos, theta_base):
    """Rotate-half RoPE on one head vector (mirror of ops::rope_inplace)."""
    d = v.shape[-1]
    half = d // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = theta_base ** (-2.0 * i / d)
    ang = pos * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    a, b = v[..., :half], v[..., half:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)


def quantize_q8_0_act_jnp(x):
    """In-graph Q8_0 activation quantization (mirror of
    rust quant::q8_0::quantize_row, f16 scale rounding included)."""
    k = x.shape[-1]
    blocks = x.reshape(k // QK8_0, QK8_0)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    d = amax / 127.0
    inv = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    q = jnp.clip(round_away_jnp(blocks * inv[:, None]), -127, 127).astype(jnp.int8)
    d16 = d.astype(jnp.float16).astype(jnp.float32)
    return q.reshape(k), d16


def _linear_q8(wq, wd, x):
    """Quantize activation + Pallas Q8_0 kernel (one offloaded matvec)."""
    aq, ad = quantize_q8_0_act_jnp(x)
    return q8_0_dot(wq, wd, aq, ad)


# --------------------------------------------------------------------------
# One decoder layer, Q8_0 weights (the shape lowered to layer_fwd_q8.hlo.txt)
# --------------------------------------------------------------------------

def layer_fwd_q8(
    x,
    attn_norm,
    ffn_norm,
    q_norm,
    k_norm,
    wq_q, wq_d,
    wk_q, wk_d,
    wv_q, wv_d,
    wo_q, wo_d,
    wg_q, wg_d,
    wu_q, wu_d,
    wd_q, wd_d,
    k_cache,
    v_cache,
):
    """One tiny-config decoder layer at position `pos = k_cache.shape[0]`.

    Returns (x_out f32[d_model], k_new f32[kv_dim], v_new f32[kv_dim]).
    The caches hold the *prior* positions; attention runs over
    cache ∪ {current}.
    """
    cfg = TINY
    pos = k_cache.shape[0]  # static at lowering time
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads

    xn = rmsnorm_jnp(x, attn_norm, cfg.rms_eps)
    q = _linear_q8(wq_q, wq_d, xn)                     # [q_dim]
    k = _linear_q8(wk_q, wk_d, xn)                     # [kv_dim]
    v = _linear_q8(wv_q, wv_d, xn)                     # [kv_dim]

    # QK-Norm + RoPE per head.
    qh = q.reshape(cfg.n_heads, hd)
    kh = k.reshape(cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        qh = jax.vmap(lambda h: rmsnorm_jnp(h, q_norm, cfg.rms_eps))(qh)
        kh = jax.vmap(lambda h: rmsnorm_jnp(h, k_norm, cfg.rms_eps))(kh)
    qh = jax.vmap(lambda h: rope_jnp(h, float(pos), cfg.rope_theta))(qh)
    kh = jax.vmap(lambda h: rope_jnp(h, float(pos), cfg.rope_theta))(kh)

    # Attention over cache ∪ current (ctx = pos + 1).
    k_all = jnp.concatenate(
        [k_cache.reshape(pos, cfg.n_kv_heads, hd), kh[None, :, :]], axis=0
    )                                                   # [ctx, kvh, hd]
    v_all = jnp.concatenate(
        [v_cache.reshape(pos, cfg.n_kv_heads, hd),
         v.reshape(1, cfg.n_kv_heads, hd)], axis=0
    )
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def head_attn(h):
        kvh = h // groups
        scores = jnp.einsum("d,cd->c", qh[h], k_all[:, kvh, :]) * scale
        probs = jax.nn.softmax(scores)
        return jnp.einsum("c,cd->d", probs, v_all[:, kvh, :])

    attn = jax.vmap(head_attn)(jnp.arange(cfg.n_heads))  # [n_heads, hd]
    attn = attn.reshape(cfg.q_dim)

    x = x + _linear_q8(wo_q, wo_d, attn)

    # SwiGLU FFN.
    xn2 = rmsnorm_jnp(x, ffn_norm, cfg.rms_eps)
    gate = _linear_q8(wg_q, wg_d, xn2)
    up = _linear_q8(wu_q, wu_d, xn2)
    act = jax.nn.silu(gate) * up
    x = x + _linear_q8(wd_q, wd_d, act)

    return x, kh.reshape(cfg.kv_dim), v.reshape(cfg.kv_dim)


def lm_head_q8(x, final_norm, head_q, head_d):
    """Final RMSNorm + quantized LM head → logits f32[vocab]."""
    xn = rmsnorm_jnp(x, final_norm, TINY.rms_eps)
    return q8_0_dot(head_q, head_d, *quantize_q8_0_act_jnp(xn))


# --------------------------------------------------------------------------
# Example-input builders (shapes only; used by aot.py lowering)
# --------------------------------------------------------------------------

def layer_fwd_example_shapes(ctx_prev: int):
    """ShapeDtypeStructs for layer_fwd_q8 at a given prior-context length."""
    cfg = TINY
    f32 = jnp.float32
    i8 = jnp.int8
    sd = jax.ShapeDtypeStruct

    def wpair(rows, cols):
        return [sd((rows, cols), i8), sd((rows, cols // QK8_0), f32)]

    args = [
        sd((cfg.d_model,), f32),       # x
        sd((cfg.d_model,), f32),       # attn_norm
        sd((cfg.d_model,), f32),       # ffn_norm
        sd((cfg.head_dim,), f32),      # q_norm
        sd((cfg.head_dim,), f32),      # k_norm
    ]
    args += wpair(cfg.q_dim, cfg.d_model)     # wq
    args += wpair(cfg.kv_dim, cfg.d_model)    # wk
    args += wpair(cfg.kv_dim, cfg.d_model)    # wv
    args += wpair(cfg.d_model, cfg.q_dim)     # wo
    args += wpair(cfg.d_ffn, cfg.d_model)     # wg
    args += wpair(cfg.d_ffn, cfg.d_model)     # wu
    args += wpair(cfg.d_model, cfg.d_ffn)     # wd
    args += [
        sd((ctx_prev, cfg.kv_dim), f32),  # k_cache
        sd((ctx_prev, cfg.kv_dim), f32),  # v_cache
    ]
    return args


def lm_head_example_shapes():
    cfg = TINY
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    return [
        sd((cfg.d_model,), f32),
        sd((cfg.d_model,), f32),
        sd((cfg.vocab_size, cfg.d_model), jnp.int8),
        sd((cfg.vocab_size, cfg.d_model // QK8_0), f32),
    ]
