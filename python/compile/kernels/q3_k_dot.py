"""Q3_K dot-product kernel (paper Fig 9).

The most intricate format: 2-bit QL planes, a 1-bit QH mask, and 6-bit
packed sub-block scales. IMAX's custom `OP_CVT53` reconfigures this data —
approximating the 6-bit scales to 5 bits and unifying the 2+1-bit weights
into a 3-bit format — so the Q8_0-style back-end can be reused,
"processing 256 elements per burst by running four parallel dataflows for
sixteen iterations" (51 arithmetic units).

Pallas mapping: vectorized bit-plane unpack to signed [-4,3] codes
(CVT53's weight half), optional 5-bit scale truncation (CVT53's scale
half, `cvt53=True` — the paper's deployed configuration), then the shared
int32 MAC back-end and f32 drain scaling.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_divisible, pick_tile_n, row_tiled_specs
from ..config import QK_K


def decode_q3_codes_jnp(qs, hmask):
    """jnp mirror of ref.decode_q3_codes: signed codes in [-4, 3]."""
    lead = qs.shape[:-1]
    nsb = qs.shape[-1] // 64
    qsh = qs.reshape(*lead, nsb, 2, 32).astype(jnp.int32)
    hm = hmask.reshape(*lead, nsb, 32).astype(jnp.int32)
    outs = []
    for half in range(2):
        for j in range(4):
            low = (qsh[..., half, :] >> (2 * j)) & 0x03
            bit = (hm >> (half * 4 + j)) & 0x01
            outs.append(low - 4 * (1 - bit))
    q = jnp.stack(outs, axis=-2)  # [..., nsb, 8, 32]
    return q.reshape(*lead, nsb * QK_K)


def _make_kernel(cvt53: bool):
    def kernel(qs_ref, hm_ref, sc_ref, d_ref, aq_ref, ad_ref, o_ref):
        tile_n = qs_ref.shape[0]
        k = qs_ref.shape[-1] * 4
        # CVT53 front-end, weight half: unify 2+1-bit planes to 3-bit codes.
        q = decode_q3_codes_jnp(qs_ref[...], hm_ref[...])      # [T, K]
        prod = q * aq_ref[...].astype(jnp.int32)[None, :]
        sub = prod.reshape(tile_n, k // 16, 16).sum(axis=-1)
        eff = sc_ref[...].astype(jnp.int32) - 32               # 6-bit code
        if cvt53:
            # CVT53 front-end, scale half: approximate to 5 bits.
            eff = (eff >> 1) << 1
        scaled = sub * eff
        per_sb = scaled.reshape(tile_n, k // QK_K, 16).sum(axis=-1)
        o_ref[...] = (
            per_sb.astype(jnp.float32) * d_ref[...] * ad_ref[...][None, :]
        ).sum(axis=-1)

    return kernel


def tile_n_for(n: int, k: int) -> int:
    per_row = k // 4 + k // 8 + k // 16 + (k // QK_K) * 4
    shared = k + (k // QK_K) * 4
    return pick_tile_n(n, per_row, shared)


@functools.partial(jax.jit, static_argnames=("cvt53",))
def q3_k_dot(qs, hmask, sc6, d, aq, ad, cvt53: bool = True):
    """Q3_K×Q8_K matvec.

    qs u8[N,K/4], hmask u8[N,K/8], sc6 i8[N,K/16] (6-bit codes),
    d f32[N,K/256], aq int8[K], ad f32[K/256] -> f32[N].
    `cvt53` selects the paper's 5-bit scale approximation (its deployed
    configuration; False gives the exact llama.cpp kernel).
    """
    n = qs.shape[0]
    k = qs.shape[1] * 4
    assert_divisible(k, QK_K, "q3_k_dot")
    tile = tile_n_for(n, k)
    in_specs, out_spec = row_tiled_specs(
        pl,
        tile,
        [(k // 4,), (k // 8,), (k // 16,), (k // QK_K,)],
        [(k,), (k // QK_K,)],
    )
    return pl.pallas_call(
        _make_kernel(cvt53),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=INTERPRET,
    )(qs, hmask, sc6, d, aq, ad)
