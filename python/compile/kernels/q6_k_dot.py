"""Q6_K dot-product kernel (paper Fig 8).

IMAX decodes the packed 4-bit QL / 2-bit QH planes and the 8-bit sub-block
scales with the custom `CVT86` instruction (one cycle, 16-bit
intermediates) and feeds the decoded INT8 stream into the same MAC
back-end as Q8_0 (`SML16`), using 64 arithmetic units.

Pallas mapping: the CVT86 front-end is a vectorized bit-unpack
(shift/mask) in VMEM producing int32 codes; the back-end is the shared
int32 MAC + per-sub-block scale chain; the f16 super-scale and the Q8_K
activation scale multiply at the drain stage.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_divisible, pick_tile_n, row_tiled_specs
from ..config import QK_K


def decode_q6_codes_jnp(ql, qh):
    """jnp mirror of ref.decode_q6_codes: (..., K/2),(...,K/4) -> (...,K)
    int32 codes in [0, 63]."""
    lead = ql.shape[:-1]
    nsb = ql.shape[-1] // 128
    qlh = ql.reshape(*lead, nsb, 2, 64).astype(jnp.int32)
    qhh = qh.reshape(*lead, nsb, 2, 32).astype(jnp.int32)
    a, b = qlh[..., :32], qlh[..., 32:]
    j0 = (a & 0x0F) | (((qhh >> 0) & 0x03) << 4)
    j1 = (b & 0x0F) | (((qhh >> 2) & 0x03) << 4)
    j2 = (a >> 4) | (((qhh >> 4) & 0x03) << 4)
    j3 = (b >> 4) | (((qhh >> 6) & 0x03) << 4)
    q = jnp.concatenate([j0, j1, j2, j3], axis=-1)
    return q.reshape(*lead, nsb * QK_K)


def _kernel(ql_ref, qh_ref, sc_ref, d_ref, aq_ref, ad_ref, o_ref):
    tile_n = ql_ref.shape[0]
    k = ql_ref.shape[-1] * 2
    # CVT86 front-end: unpack to INT8-range codes, center by -32.
    q = decode_q6_codes_jnp(ql_ref[...], qh_ref[...]) - 32     # [T, K]
    # Shared INT8 MAC back-end (SML16): int32 accumulation.
    prod = q * aq_ref[...].astype(jnp.int32)[None, :]
    sub = prod.reshape(tile_n, k // 16, 16).sum(axis=-1)       # [T, K/16]
    scaled = sub * sc_ref[...].astype(jnp.int32)               # i8 scales
    per_sb = scaled.reshape(tile_n, k // QK_K, 16).sum(axis=-1)
    # Drain stage: f16 super-scale × Q8_K activation scale.
    o_ref[...] = (per_sb.astype(jnp.float32) * d_ref[...] * ad_ref[...][None, :]).sum(
        axis=-1
    )


def tile_n_for(n: int, k: int) -> int:
    # Per row: K/2 + K/4 packed + K/16 scales + K/256×4 d.
    per_row = k // 2 + k // 4 + k // 16 + (k // QK_K) * 4
    shared = k + (k // QK_K) * 4  # activation qs + scales
    return pick_tile_n(n, per_row, shared)


@jax.jit
def q6_k_dot(ql, qh, sc, d, aq, ad):
    """Q6_K×Q8_K matvec.

    ql u8[N,K/2], qh u8[N,K/4], sc i8[N,K/16], d f32[N,K/256],
    aq int8[K], ad f32[K/256] -> f32[N].
    """
    n = ql.shape[0]
    k = ql.shape[1] * 2
    assert_divisible(k, QK_K, "q6_k_dot")
    tile = tile_n_for(n, k)
    in_specs, out_spec = row_tiled_specs(
        pl,
        tile,
        [(k // 2,), (k // 4,), (k // 16,), (k // QK_K,)],
        [(k,), (k // QK_K,)],
    )
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=INTERPRET,
    )(ql, qh, sc, d, aq, ad)
