"""Pure NumPy correctness oracle for the Pallas kernels.

Implements ggml-layout quantizers/dequantizers and reference dot products
that are *bit-compatible in integer space* with `rust/src/quant/` (same
block layouts, same rounding: f32 scales rounded to f16 with
round-to-nearest-even, element codes rounded half-away-from-zero). The
Pallas kernels in this package are validated against these references by
`python/tests/`, and the Rust integration tests validate the Rust kernels
against the AOT-compiled artifacts — closing the three-way loop.
"""

import numpy as np

from ..config import QK8_0, QK_K


def round_away(x: np.ndarray) -> np.ndarray:
    """Round half away from zero (Rust `f32::round`), unlike np.round's
    banker's rounding."""
    return np.trunc(x + np.copysign(0.5, x))


def f16_round(x: np.ndarray) -> np.ndarray:
    """Round f32 values through IEEE binary16 (round-to-nearest-even)."""
    return np.asarray(x, dtype=np.float32).astype(np.float16).astype(np.float32)


# --------------------------------------------------------------------------
# Q8_0 (32-element blocks, f16 scale)
# --------------------------------------------------------------------------

def quantize_q8_0(x: np.ndarray):
    """Quantize rows to Q8_0. x: [..., K] with K % 32 == 0.

    Returns (q int8[..., K], d f32[..., K/32]) — d already f16-rounded.
    Matches rust quant::q8_0::quantize_row.
    """
    x = np.asarray(x, dtype=np.float32)
    assert x.shape[-1] % QK8_0 == 0
    blocks = x.reshape(*x.shape[:-1], -1, QK8_0)
    amax = np.abs(blocks).max(axis=-1)
    d = amax / 127.0
    inv = np.where(d > 0, 1.0 / np.where(d > 0, d, 1.0), 0.0)
    q = round_away(blocks * inv[..., None]).clip(-127, 127).astype(np.int8)
    return q.reshape(x.shape), f16_round(d)


def dequantize_q8_0(q: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Inverse of quantize_q8_0 (exact, given stored codes + f16 scale)."""
    blocks = q.reshape(*q.shape[:-1], -1, QK8_0).astype(np.float32)
    return (blocks * d[..., None]).reshape(q.shape)


def ref_dot_q8_0(wq, wd, aq, ad) -> np.ndarray:
    """Reference Q8_0×Q8_0 matvec.

    wq int8[N,K], wd f32[N,K/32], aq int8[K], ad f32[K/32] -> f32[N].
    Integer MACs per 32-block (24-bit-safe), then per-block f32 scaling —
    the computation of paper Fig 5.
    """
    n, k = wq.shape
    wb = wq.astype(np.int32).reshape(n, k // QK8_0, QK8_0)
    ab = aq.astype(np.int32).reshape(1, k // QK8_0, QK8_0)
    isum = (wb * ab).sum(axis=-1)  # [N, K/32] int32
    return (isum.astype(np.float32) * wd * ad[None, :]).sum(axis=-1)


# --------------------------------------------------------------------------
# Q8_K activations (256-element super-blocks, f32 scale, cached bsums)
# --------------------------------------------------------------------------

def quantize_q8_k(x: np.ndarray):
    """Quantize an activation row to Q8_K.

    x: [K], K % 256 == 0. Returns (q int8[K], d f32[K/256], bsums i16[K/16]).
    Matches rust quant::q8_k::quantize_row.
    """
    x = np.asarray(x, dtype=np.float32)
    (k,) = x.shape
    assert k % QK_K == 0
    blocks = x.reshape(-1, QK_K)
    amax = np.abs(blocks).max(axis=-1)
    d = (amax / 127.0).astype(np.float32)
    inv = np.where(d > 0, 1.0 / np.where(d > 0, d, 1.0), 0.0)
    q = round_away(blocks * inv[:, None]).clip(-127, 127).astype(np.int8)
    bsums = q.reshape(-1, 16).astype(np.int16).sum(axis=-1, dtype=np.int16)
    return q.reshape(k), d, bsums


# --------------------------------------------------------------------------
# Q6_K (256-element super-blocks: 4-bit QL + 2-bit QH + i8 scales + f16 d)
# --------------------------------------------------------------------------

def decode_q6_codes(ql: np.ndarray, qh: np.ndarray) -> np.ndarray:
    """Decode packed Q6_K bit-planes to codes in [0, 63].

    ql: uint8[..., K/2], qh: uint8[..., K/4] -> int32[..., K].
    ggml layout: two 128-halves, quarters j0..j3 (see rust get_q). This is
    the CVT86 front-end of paper Fig 8.
    """
    lead = ql.shape[:-1]
    nsb = ql.shape[-1] // 128  # superblocks
    qlh = ql.reshape(*lead, nsb, 2, 64).astype(np.int32)
    qhh = qh.reshape(*lead, nsb, 2, 32).astype(np.int32)
    a, b = qlh[..., :32], qlh[..., 32:]
    j0 = (a & 0x0F) | (((qhh >> 0) & 0x03) << 4)
    j1 = (b & 0x0F) | (((qhh >> 2) & 0x03) << 4)
    j2 = (a >> 4) | (((qhh >> 4) & 0x03) << 4)
    j3 = (b >> 4) | (((qhh >> 6) & 0x03) << 4)
    q = np.concatenate([j0, j1, j2, j3], axis=-1)  # [..., nsb, 2, 128]
    return q.reshape(*lead, nsb * QK_K)


def encode_q6_codes(q: np.ndarray):
    """Inverse of decode_q6_codes. q: int[..., K] in [0,63] -> (ql, qh)."""
    lead = q.shape[:-1]
    nsb = q.shape[-1] // QK_K
    qq = q.reshape(*lead, nsb, 2, 4, 32).astype(np.uint8)  # [.., half, j, l]
    j0 = qq[..., 0, :]
    j1 = qq[..., 1, :]
    j2 = qq[..., 2, :]
    j3 = qq[..., 3, :]
    a = (j0 & 0x0F) | ((j2 & 0x0F) << 4)
    b = (j1 & 0x0F) | ((j3 & 0x0F) << 4)
    ql = np.concatenate([a, b], axis=-1).reshape(*lead, nsb * 128)
    qh = (
        ((j0 >> 4) & 3)
        | (((j1 >> 4) & 3) << 2)
        | (((j2 >> 4) & 3) << 4)
        | (((j3 >> 4) & 3) << 6)
    ).reshape(*lead, nsb * 64)
    return ql.astype(np.uint8), qh.astype(np.uint8)


def quantize_q6_k(x: np.ndarray):
    """Quantize rows to Q6_K. x: [..., K], K % 256 == 0.

    Returns (ql u8[...,K/2], qh u8[...,K/4], sc i8[...,K/16], d f32[...,K/256]).
    Matches rust quant::q6_k::quantize_row (same scale search + rounding).
    """
    x = np.asarray(x, dtype=np.float32)
    lead = x.shape[:-1]
    k = x.shape[-1]
    assert k % QK_K == 0
    xs = x.reshape(*lead, -1, 16)                    # sub-blocks of 16
    sub_amax = np.abs(xs).max(axis=-1)               # [..., K/16]
    sb_amax = sub_amax.reshape(*lead, -1, 16).max(axis=-1)  # [..., K/256]
    d = f16_round(sb_amax / 31.0 / 127.0)            # f16-rounded superscale
    d_sub = np.repeat(d, 16, axis=-1)                # per sub-block
    with np.errstate(divide="ignore", invalid="ignore"):
        sc = np.where(
            d_sub > 0, round_away(sub_amax / 31.0 / np.where(d_sub > 0, d_sub, 1.0)), 0.0
        ).clip(-128, 127).astype(np.int8)
    step = d_sub * sc.astype(np.float32)             # [..., K/16]
    step_e = np.repeat(step, 16, axis=-1).reshape(x.shape)
    with np.errstate(divide="ignore", invalid="ignore"):
        q = np.where(
            step_e != 0, round_away(x / np.where(step_e != 0, step_e, 1.0)), 0.0
        )
    q = (q.clip(-32, 31) + 32).astype(np.int32)
    ql, qh = encode_q6_codes(q)
    return ql, qh, sc, d


def dequantize_q6_k(ql, qh, sc, d) -> np.ndarray:
    """Exact dequantization from stored Q6_K arrays."""
    q = decode_q6_codes(ql, qh) - 32                 # [..., K]
    lead = q.shape[:-1]
    k = q.shape[-1]
    scf = np.repeat(sc.astype(np.float32), 16, axis=-1).reshape(*lead, k)
    df = np.repeat(np.asarray(d, np.float32), QK_K, axis=-1).reshape(*lead, k)
    return q.astype(np.float32) * scf * df


def ref_dot_q6_k(ql, qh, sc, d, aq, ad) -> np.ndarray:
    """Reference Q6_K×Q8_K matvec (paper Fig 8 pipeline).

    Weight arrays [N, ...] as from quantize_q6_k; aq int8[K],
    ad f32[K/256] -> f32[N].
    """
    n = ql.shape[0]
    k = aq.shape[0]
    q = decode_q6_codes(ql, qh) - 32                          # [N, K] int32
    prod = q * aq.astype(np.int32)[None, :]                   # int32
    sub = prod.reshape(n, k // 16, 16).sum(axis=-1)           # [N, K/16]
    scaled = sub * sc.astype(np.int32)                        # int32
    per_sb = scaled.reshape(n, k // QK_K, 16).sum(axis=-1)    # [N, K/256]
    return (per_sb.astype(np.float32) * d * ad[None, :]).sum(axis=-1)


# --------------------------------------------------------------------------
# Q3_K (256-element super-blocks: 2-bit QL + 1-bit QH + 6-bit scales + f16 d)
# --------------------------------------------------------------------------

def decode_q3_codes(qs: np.ndarray, hmask: np.ndarray) -> np.ndarray:
    """Decode packed Q3_K bit-planes to signed codes in [-4, 3].

    qs: uint8[..., K/4], hmask: uint8[..., K/8] -> int32[..., K].
    The CVT53 front-end of paper Fig 9 (bit-plane part).
    """
    lead = qs.shape[:-1]
    nsb = qs.shape[-1] // 64
    qsh = qs.reshape(*lead, nsb, 2, 32).astype(np.int32)   # [.., half, l]
    hm = hmask.reshape(*lead, nsb, 32).astype(np.int32)    # [.., l]
    outs = []
    for half in range(2):
        for j in range(4):
            low = (qsh[..., half, :] >> (2 * j)) & 0x03
            bit = (hm >> (half * 4 + j)) & 0x01
            outs.append(low - 4 * (1 - bit))
    q = np.stack(outs, axis=-2)  # [..., nsb, 8, 32]
    return q.reshape(*lead, nsb * QK_K)


def encode_q3_codes(q: np.ndarray):
    """Inverse of decode_q3_codes. q int[..., K] in [-4,3] -> (qs, hmask)."""
    lead = q.shape[:-1]
    nsb = q.shape[-1] // QK_K
    biased = (q + 4).reshape(*lead, nsb, 2, 4, 32).astype(np.uint8)
    low = biased & 0x03
    hi = (biased >> 2) & 0x01
    qs = np.zeros((*lead, nsb, 2, 32), dtype=np.uint8)
    hm = np.zeros((*lead, nsb, 32), dtype=np.uint8)
    for j in range(4):
        qs |= low[..., j, :] << (2 * j)
        for half in range(2):
            hm |= hi[..., half, j, :] << (half * 4 + j)
    return qs.reshape(*lead, nsb * 64), hm.reshape(*lead, nsb * 32)


def quantize_q3_k(x: np.ndarray):
    """Quantize rows to Q3_K. Returns (qs u8[...,K/4], hmask u8[...,K/8],
    sc6 i8[...,K/16] codes in [0,63], d f32[...,K/256]).
    Matches rust quant::q3_k::quantize_row."""
    x = np.asarray(x, dtype=np.float32)
    lead = x.shape[:-1]
    k = x.shape[-1]
    assert k % QK_K == 0
    xs = x.reshape(*lead, -1, 16)
    sub_amax = np.abs(xs).max(axis=-1)
    sb_amax = sub_amax.reshape(*lead, -1, 16).max(axis=-1)
    d = f16_round(sb_amax / 4.0 / 31.0)
    d_sub = np.repeat(d, 16, axis=-1)
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = np.where(
            d_sub > 0, round_away(sub_amax / 4.0 / np.where(d_sub > 0, d_sub, 1.0)), 0.0
        ).clip(-32, 31).astype(np.int32)
    step = d_sub * eff.astype(np.float32)
    step_e = np.repeat(step, 16, axis=-1).reshape(x.shape)
    with np.errstate(divide="ignore", invalid="ignore"):
        q = np.where(
            step_e != 0, round_away(x / np.where(step_e != 0, step_e, 1.0)), 0.0
        )
    q = q.clip(-4, 3).astype(np.int32)
    qs, hmask = encode_q3_codes(q)
    sc6 = (eff + 32).astype(np.int8)  # stored 6-bit code
    return qs, hmask, sc6, d


def dequantize_q3_k(qs, hmask, sc6, d) -> np.ndarray:
    """Exact dequantization from stored Q3_K arrays."""
    q = decode_q3_codes(qs, hmask)
    lead = q.shape[:-1]
    k = q.shape[-1]
    eff = sc6.astype(np.int32) - 32
    scf = np.repeat(eff.astype(np.float32), 16, axis=-1).reshape(*lead, k)
    df = np.repeat(np.asarray(d, np.float32), QK_K, axis=-1).reshape(*lead, k)
    return q.astype(np.float32) * scf * df


def ref_dot_q3_k(qs, hmask, sc6, d, aq, ad, cvt53: bool = False) -> np.ndarray:
    """Reference Q3_K×Q8_K matvec (paper Fig 9 pipeline).

    With cvt53=True, applies the paper's OP_CVT53 5-bit scale approximation
    (drop the LSB of the effective scale)."""
    n = qs.shape[0]
    k = aq.shape[0]
    q = decode_q3_codes(qs, hmask)
    prod = q * aq.astype(np.int32)[None, :]
    sub = prod.reshape(n, k // 16, 16).sum(axis=-1)
    eff = sc6.astype(np.int32) - 32
    if cvt53:
        eff = (eff >> 1) << 1
    scaled = sub * eff
    per_sb = scaled.reshape(n, k // QK_K, 16).sum(axis=-1)
    return (per_sb.astype(np.float32) * d * ad[None, :]).sum(axis=-1)


# --------------------------------------------------------------------------
# FP16
# --------------------------------------------------------------------------

def ref_dot_fp16(w16: np.ndarray, a: np.ndarray) -> np.ndarray:
    """FP16-weight matvec reference: widen to f32 and accumulate (paper
    Fig 6's LUT-convert + FMA). w16: float16[N,K], a: f32[K] -> f32[N]."""
    return (w16.astype(np.float32) * a[None, :].astype(np.float32)).sum(axis=-1)
