"""Shared helpers for the Pallas kernels.

Hardware adaptation note (DESIGN.md §4): the IMAX LMM is a 64 KB
double-buffered local memory per PE; the Pallas analogue is the VMEM tile
selected by each kernel's BlockSpec. `pick_tile_n` chooses the largest row
tile whose operand set stays within the 64 KB budget, mirroring the
paper's LMM-fit criterion, and `vmem_tile_bytes` reports the footprint the
DESIGN.md §Perf estimates use.
"""

import jax

# The paper's chosen LMM size (§III.D / §V.A): 64 KB.
LMM_BYTES = 64 * 1024

# Pallas must run in interpret mode: real TPU lowering emits a Mosaic
# custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
INTERPRET = True


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest d <= cap with n % d == 0 (>= 1)."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def pick_tile_n(n_rows: int, bytes_per_row: int, extra_bytes: int) -> int:
    """Pick the row-tile size: the largest divisor of `n_rows` whose tile
    (rows × bytes_per_row + shared operands) fits the 64 KB LMM budget.

    `extra_bytes` covers the operands shared by every tile (the quantized
    activation row + scales), which the paper's DMA coalescing transfers
    once per kernel.
    """
    budget = max(LMM_BYTES - extra_bytes, bytes_per_row)
    cap = max(budget // max(bytes_per_row, 1), 1)
    return largest_divisor_leq(n_rows, cap)


def vmem_tile_bytes(tile_n: int, bytes_per_row: int, extra_bytes: int) -> int:
    """VMEM footprint of one grid step (documented in DESIGN.md §Perf)."""
    return tile_n * bytes_per_row + extra_bytes


def row_tiled_specs(pl, tile_n: int, per_row_shapes, shared_shapes):
    """Build BlockSpecs for a row-tiled matvec kernel.

    per_row_shapes: list of trailing shapes for operands indexed [N, ...]
    (tiled over rows). shared_shapes: operands broadcast to every tile.
    Returns (in_specs, out_spec).
    """
    in_specs = []
    for trail in per_row_shapes:
        block = (tile_n, *trail)
        ndim_trailing = len(trail)
        in_specs.append(
            pl.BlockSpec(block, lambda i, _nt=ndim_trailing: (i,) + (0,) * _nt)
        )
    for shape in shared_shapes:
        nd = len(shape)
        in_specs.append(pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd))
    out_spec = pl.BlockSpec((tile_n,), lambda i: (i,))
    return in_specs, out_spec


def assert_divisible(k: int, block: int, what: str):
    if k % block != 0:
        raise ValueError(f"{what}: length {k} not a multiple of {block}")


def cost_estimate(n: int, k: int):
    """FLOP/byte estimate attached to kernels for XLA's scheduler."""
    return jax.ShapeDtypeStruct((n,), "float32"), 2 * n * k
