"""Q8_0 dot-product kernel (paper Figs 5 & 7).

The IMAX dataflow: `OP_SML8` performs 2-way SIMD signed 8-bit
multiply-accumulate into sign-extended 24-bit partials, `OP_AD24`
aggregates them along twelve pipelined PEs, and the final stage multiplies
by the f32 scale product — replicated 4× in parallel, two passes per
32-element block, 46 arithmetic units total.

Pallas mapping: int8 operands widened to int32 in VMEM (SML8's
sign-extended products; i32 ⊇ the 24-bit accumulator, and a 32-block's
partial sum is < 2^23 so the hardware width is provably sufficient),
per-block reduction (AD24 chain), then the `d_w · d_a` f32 scale — one
grid step per row tile, operands sized to the 64 KB LMM budget.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_divisible, pick_tile_n, row_tiled_specs
from ..config import QK8_0


def _kernel(wq_ref, wd_ref, aq_ref, ad_ref, o_ref):
    tile_n = wq_ref.shape[0]
    k = wq_ref.shape[1]
    nb = k // QK8_0
    # SML8: widen int8→int32 and multiply (sign-extended products).
    wq = wq_ref[...].astype(jnp.int32)               # [T, K]
    aq = aq_ref[...].astype(jnp.int32)               # [K]
    prod = wq * aq[None, :]
    # AD24: accumulate within each 32-block (fits 24 bits).
    isum = prod.reshape(tile_n, nb, QK8_0).sum(axis=-1)  # [T, nb] i32
    # Final f32 scale stage: d_w * d_a per block, then block reduction.
    scaled = isum.astype(jnp.float32) * wd_ref[...] * ad_ref[...][None, :]
    o_ref[...] = scaled.sum(axis=-1)


def tile_n_for(n: int, k: int) -> int:
    # Per row: K int8 + K/32 f32 scales; shared: activation qs + scales.
    per_row = k + (k // QK8_0) * 4
    shared = k + (k // QK8_0) * 4
    return pick_tile_n(n, per_row, shared)


@jax.jit
def q8_0_dot(wq, wd, aq, ad):
    """Q8_0×Q8_0 matvec.

    wq int8[N,K], wd f32[N,K/32], aq int8[K], ad f32[K/32] -> f32[N].
    """
    n, k = wq.shape
    assert_divisible(k, QK8_0, "q8_0_dot")
    tile = tile_n_for(n, k)
    nb = k // QK8_0
    in_specs, out_spec = row_tiled_specs(
        pl, tile, [(k,), (nb,)], [(k,), (nb,)]
    )
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=INTERPRET,
    )(wq, wd, aq, ad)
