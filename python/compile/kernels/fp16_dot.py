"""FP16 dot-product kernel (paper Fig 6).

IMAX converts incoming FP16 to FP32 through a per-PE LUT, then runs 2-way
SIMD FMA with column multithreading over 22 arithmetic units. The Pallas
mapping: row-tiled matvec, weights widened f16→f32 in VMEM (the LUT
analogue — XLA lowers the convert to a vectorized widen), f32 FMA
reduction. One grid step processes `TILE_N` rows; the weight tile plus the
shared activation row is kept within the 64 KB LMM budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_tile_n, row_tiled_specs


def _kernel(w_ref, a_ref, o_ref):
    # LUT F16→F32 convert (in-line, no dedicated hardware — §III.C).
    w = w_ref[...].astype(jnp.float32)          # [TILE_N, K]
    a = a_ref[...].astype(jnp.float32)          # [K]
    # 2-way SIMD FMA analogue: XLA vectorizes this contraction.
    o_ref[...] = jnp.sum(w * a[None, :], axis=-1)


def tile_n_for(n: int, k: int) -> int:
    # Per row: K f16 weights; shared: K f32 activations.
    return pick_tile_n(n, 2 * k, 4 * k)


@functools.partial(jax.jit, static_argnames=())
def fp16_dot(w16, a):
    """Matvec with FP16 weights: w16 f16[N,K], a f32[K] -> f32[N]."""
    n, k = w16.shape
    tile = tile_n_for(n, k)
    in_specs, out_spec = row_tiled_specs(pl, tile, [(k,)], [(k,)])
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=INTERPRET,
    )(w16, a)
