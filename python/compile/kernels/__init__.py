"""L1 — Pallas kernels for the paper's four quantized dot-product formats.

Each module maps one IMAX dataflow (paper Figs 5–9) onto the Pallas/TPU
programming model: row-tiled matvec grids whose per-step operand set stays
within the 64 KB LMM budget, bit-plane decode front-ends (the CVT
instructions) feeding a shared int32 MAC back-end, and f32 scaling at the
drain stage. All kernels run under `interpret=True` (see common.py).
"""

from .fp16_dot import fp16_dot
from .q3_k_dot import q3_k_dot
from .q6_k_dot import q6_k_dot
from .q8_0_dot import q8_0_dot

__all__ = ["fp16_dot", "q3_k_dot", "q6_k_dot", "q8_0_dot"]
