"""AOT export: lower the jitted L1/L2 functions to HLO *text* artifacts.

HLO text (not `.serialize()` / StableHLO bytes) is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run once at build time (`make artifacts`); the Rust runtime
(`rust/src/runtime/`) loads every artifact listed in
`artifacts/manifest.txt` and executes it on the PJRT CPU client. Python
never runs on the request path.
"""

import argparse
import functools
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import QK8_0, QK_K, TINY, TINY_LINEAR_SHAPES
from .kernels import fp16_dot, q3_k_dot, q6_k_dot, q8_0_dot


def to_hlo_text(lowered) -> str:
    """jax lowered → XlaComputation → HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def kernel_artifacts():
    """(name, jitted fn, example shapes) for the standalone L1 kernels."""
    arts = []
    f32, i8, f16 = jnp.float32, jnp.int8, jnp.float16

    # Q8_0 at every tiny-model linear shape (the PJRT offload backend
    # executes these from the Rust hot path).
    for n, k in TINY_LINEAR_SHAPES:
        arts.append(
            (
                f"q8_0_dot_{n}x{k}",
                q8_0_dot,
                [
                    sd((n, k), i8),
                    sd((n, k // QK8_0), f32),
                    sd((k,), i8),
                    sd((k // QK8_0,), f32),
                ],
            )
        )

    n, k = TINY.d_model, TINY.d_model
    arts.append((f"fp16_dot_{n}x{k}", fp16_dot, [sd((n, k), f16), sd((k,), f32)]))
    arts.append(
        (
            f"q6_k_dot_{n}x{k}",
            q6_k_dot,
            [
                sd((n, k // 2), jnp.uint8),
                sd((n, k // 4), jnp.uint8),
                sd((n, k // 16), i8),
                sd((n, k // QK_K), f32),
                sd((k,), i8),
                sd((k // QK_K,), f32),
            ],
        )
    )
    arts.append(
        (
            f"q3_k_dot_{n}x{k}",
            functools.partial(q3_k_dot, cvt53=True),
            [
                sd((n, k // 4), jnp.uint8),
                sd((n, k // 8), jnp.uint8),
                sd((n, k // 16), i8),
                sd((n, k // QK_K), f32),
                sd((k,), i8),
                sd((k // QK_K,), f32),
            ],
        )
    )
    return arts


def model_artifacts():
    """(name, jitted fn, example shapes) for the L2 model graphs."""
    arts = []
    # Decode-step layer forward at a fixed prior-context (ctx_prev = 7,
    # i.e. attention over 8 positions) — the integration-test shape.
    ctx_prev = 7
    arts.append(
        (
            f"layer_fwd_q8_ctx{ctx_prev}",
            model.layer_fwd_q8,
            model.layer_fwd_example_shapes(ctx_prev),
        )
    )
    arts.append(("lm_head_q8", model.lm_head_q8, model.lm_head_example_shapes()))
    return arts


def shape_sig(shapes) -> str:
    """Manifest shape signature, e.g. 'i8[256,256];f32[256,8]'."""
    names = {
        jnp.int8.dtype: "i8",
        jnp.uint8.dtype: "u8",
        jnp.float16.dtype: "f16",
        jnp.float32.dtype: "f32",
        jnp.int16.dtype: "i16",
    }
    parts = []
    for s in shapes:
        dt = names[jnp.dtype(s.dtype)]
        dims = ",".join(str(d) for d in s.shape)
        parts.append(f"{dt}[{dims}]")
    return ";".join(parts)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact-name filter"
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest_lines = []
    for name, fn, shapes in kernel_artifacts() + model_artifacts():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest_lines.append(f"{name}\t{shape_sig(shapes)}\t{digest}")
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    if not only:
        with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote {args.outdir}/manifest.txt ({len(manifest_lines)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
