"""Render the paper's figures from the bench-generated CSV series.

Usage (after `cargo bench` or the individual `imax-llm figNN` commands
have populated `reports/`):

    python python/plots.py            # writes reports/figNN.png

Produces matplotlib analogues of paper Figs 11-16: grouped bar charts for
the device comparisons (log-scale energy axes, like the paper), the LMM
sweep lines, the stacked phase-breakdown bars, and the lane-scaling curve.
"""

import csv
import os
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

REPORTS = os.path.join(os.path.dirname(__file__), "..", "reports")


def read_csv(name):
    path = os.path.join(REPORTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rows = list(csv.reader(f))
    return rows[0], rows[1:]


def device_bars(csvname, outname, title, ylabel, logy=True):
    parsed = read_csv(csvname)
    if parsed is None:
        print(f"skip {outname}: {csvname} missing (run the bench first)")
        return
    header, rows = parsed
    devices = header[1:]
    labels = [r[0].replace("Qwen3-", "").replace(" ", "\n", 1) for r in rows]
    values = [[float(v) for v in r[1:]] for r in rows]

    fig, ax = plt.subplots(figsize=(max(12, len(rows) * 0.45), 5))
    n = len(devices)
    width = 0.8 / n
    xs = range(len(rows))
    for d in range(n):
        ax.bar(
            [x + d * width for x in xs],
            [values[i][d] for i in range(len(rows))],
            width,
            label=devices[d],
        )
    ax.set_xticks([x + 0.4 for x in xs])
    ax.set_xticklabels(labels, rotation=90, fontsize=5)
    if logy:
        ax.set_yscale("log")
    ax.set_ylabel(ylabel)
    ax.set_title(title)
    ax.legend(fontsize=7)
    fig.tight_layout()
    out = os.path.join(REPORTS, outname)
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def fig14():
    parsed = read_csv("fig14_lmm_pdp.csv")
    if parsed is None:
        print("skip fig14: csv missing")
        return
    header, rows = parsed
    sizes = [int(h.split("KB")[0]) for h in header[1:]]
    fig, ax = plt.subplots(figsize=(7, 5))
    for r in rows:
        ax.plot(sizes, [float(v) for v in r[1:]], marker="o", label=r[0])
    ax.set_xscale("log", base=2)
    ax.set_yscale("log")
    ax.set_xlabel("LMM size (KB)")
    ax.set_ylabel("PDP (J)")
    ax.set_title("Fig 14 — PDP vs LMM size (IMAX 28nm)")
    ax.axvline(64, color="gray", ls=":", lw=1)
    ax.legend(fontsize=6)
    fig.tight_layout()
    out = os.path.join(REPORTS, "fig14.png")
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def fig15():
    parsed = read_csv("fig15_breakdown.csv")
    if parsed is None:
        print("skip fig15: csv missing")
        return
    header, rows = parsed
    comps = header[2:]
    labels = [f"{r[0].replace('Qwen3-', '')}\n{r[1]}" for r in rows]
    fig, ax = plt.subplots(figsize=(12, 5))
    bottoms = [0.0] * len(rows)
    for ci, comp in enumerate(comps):
        vals = [float(r[2 + ci].rstrip("%")) for r in rows]
        ax.bar(labels, vals, bottom=bottoms, label=comp.upper())
        bottoms = [b + v for b, v in zip(bottoms, vals)]
    ax.set_ylabel("share of phase time (%)")
    ax.set_title("Fig 15 — execution-time breakdown (prefill vs decode)")
    ax.tick_params(axis="x", labelsize=6, rotation=90)
    ax.legend(fontsize=7)
    fig.tight_layout()
    out = os.path.join(REPORTS, "fig15.png")
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def fig16():
    parsed = read_csv("fig16_scaling.csv")
    if parsed is None:
        print("skip fig16: csv missing")
        return
    _, rows = parsed
    lanes = [int(r[0]) for r in rows]
    e2e = [float(r[1]) for r in rows]
    tps = [float(r[2]) for r in rows]
    fig, ax1 = plt.subplots(figsize=(6, 4))
    ax1.plot(lanes, e2e, marker="o", color="tab:red", label="E2E (s)")
    ax1.set_xlabel("IMAX lanes")
    ax1.set_ylabel("E2E latency (s)", color="tab:red")
    ax2 = ax1.twinx()
    ax2.plot(lanes, tps, marker="s", color="tab:blue", label="tokens/s")
    ax2.set_ylabel("tokens/s", color="tab:blue")
    ax1.set_title("Fig 16 — lane scalability (dual-core host bottleneck)")
    fig.tight_layout()
    out = os.path.join(REPORTS, "fig16.png")
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def main():
    os.makedirs(REPORTS, exist_ok=True)
    device_bars("fig11_latency.csv", "fig11.png", "Fig 11 — E2E latency by device", "latency (s)")
    device_bars("fig12_pdp.csv", "fig12.png", "Fig 12 — PDP by device (lower is better)", "PDP (J)")
    device_bars("fig13_edp.csv", "fig13.png", "Fig 13 — EDP by device (lower is better)", "EDP (J·s)")
    fig14()
    fig15()
    fig16()
    return 0


if __name__ == "__main__":
    sys.exit(main())
