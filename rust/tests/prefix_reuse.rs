//! Prefix-sharing / host-swap acceptance suite.
//!
//! The contracts under test, per the refcounted-CoW KV refactor:
//!
//! * a **warm prefix hit** generates bit-identically to a cold prefill
//!   while executing strictly fewer prefill tokens (the aliased span's
//!   kernels never run),
//! * with `--prefix-cache` off and `--swap-pages 0` nothing changes
//!   (the refcount refactor is invisible — also pinned by the untouched
//!   batching/stress suites),
//! * serve surfaces nonzero prefix-hit / evict / swap counters, and the
//!   modeled swap bytes are charged through the imax DMA transfer mode
//!   (`ServeReport::kv_swap_bytes` > 0 under an imax backend when the
//!   pool oversubscribes).

use imax_llm::coordinator::{serve_with, Request, ServeOptions};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::{ModelConfig, ModelWeights, Phase, QuantScheme, Sampler, Session};
use imax_llm::runtime::ExecSpec;

fn tiny_weights() -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 23)
}

/// Greedy-decode `n` tokens for `sess` starting from `logits`.
fn decode_greedy(
    engine: &mut Engine,
    sess: &Session,
    mut logits: Vec<f32>,
    n: usize,
) -> Vec<u32> {
    let mut out = Vec::new();
    for step in 0..n {
        let next = Sampler::greedy().sample(&logits);
        out.push(next);
        if step + 1 < n {
            logits = engine
                .forward_session(sess, next, Phase::Decode, true, &mut NativeExec)
                .expect("decode produced logits");
        }
    }
    out
}

#[test]
fn warm_hit_is_bit_identical_with_strictly_fewer_prefill_tokens() {
    let mut engine = Engine::with_paged_slots(tiny_weights(), 2, 4, None);
    engine.enable_prefix_cache();
    let prompt: Vec<u32> = (1..=13).collect(); // 3 full 4-token pages + 1

    // Cold run: everything executes, prompt pages get registered.
    let s0 = engine.open_session(Sampler::greedy()).unwrap();
    let cold = engine.try_prefill_session_shared(&s0, &prompt, 3, &mut NativeExec).unwrap();
    assert_eq!(cold.cached_tokens, 0);
    assert_eq!(cold.executed_tokens, prompt.len());
    let cold_tokens = decode_greedy(&mut engine, &s0, cold.logits.clone(), 6);
    engine.close_session(s0);

    // Warm run: the three full prompt pages alias; only the last token
    // executes.
    let executed_before = engine.n_tokens_processed;
    let s1 = engine.open_session(Sampler::greedy()).unwrap();
    let warm = engine.try_prefill_session_shared(&s1, &prompt, 3, &mut NativeExec).unwrap();
    assert_eq!(warm.cached_tokens, 12, "three full pages served from cache");
    assert_eq!(warm.executed_tokens, 1, "only the uncached tail executes");
    assert_eq!(
        engine.n_tokens_processed - executed_before,
        1,
        "strictly fewer prefill tokens executed on the warm path"
    );
    assert_eq!(
        warm.logits, cold.logits,
        "aliased KV is bit-identical: last-token logits match exactly"
    );
    let warm_tokens = decode_greedy(&mut engine, &s1, warm.logits, 6);
    assert_eq!(warm_tokens, cold_tokens, "generation identical after a warm hit");
    engine.close_session(s1);
}

#[test]
fn prefix_cache_enabled_cold_run_matches_disabled_engine() {
    // The refactor must be invisible until a prefix actually repeats: a
    // single cold request through a prefix-enabled engine matches a
    // plain engine token-for-token.
    let weights = tiny_weights();
    let prompt: Vec<u32> = vec![4, 9, 1, 7, 7, 2, 8, 8, 3];

    let mut plain = Engine::with_paged_slots(weights.clone(), 2, 4, None);
    let sp = plain.open_session(Sampler::greedy()).unwrap();
    let lp = plain.try_prefill_session(&sp, &prompt, 3, &mut NativeExec).unwrap();
    let want = decode_greedy(&mut plain, &sp, lp, 5);

    let mut cached = Engine::with_paged_slots(weights, 2, 4, None);
    cached.enable_prefix_cache();
    let sc = cached.open_session(Sampler::greedy()).unwrap();
    let res = cached.try_prefill_session_shared(&sc, &prompt, 3, &mut NativeExec).unwrap();
    assert_eq!(res.cached_tokens, 0, "nothing cached yet");
    let got = decode_greedy(&mut cached, &sc, res.logits, 5);
    assert_eq!(got, want, "prefix cache must not change a cold run");
}

#[test]
fn partial_prefix_hit_diverging_suffix_still_identical() {
    // Two prompts sharing one full page then diverging: the second
    // request aliases only the shared page and its output must match a
    // fresh engine's.
    let weights = tiny_weights();
    let a: Vec<u32> = vec![5, 6, 7, 8, 1, 2, 3];
    let b: Vec<u32> = vec![5, 6, 7, 8, 9, 9, 4];

    let mut engine = Engine::with_paged_slots(weights.clone(), 2, 4, None);
    engine.enable_prefix_cache();
    let sa = engine.open_session(Sampler::greedy()).unwrap();
    let ra = engine.try_prefill_session_shared(&sa, &a, 32, &mut NativeExec).unwrap();
    decode_greedy(&mut engine, &sa, ra.logits, 3);
    engine.close_session(sa);

    let sb = engine.open_session(Sampler::greedy()).unwrap();
    let rb = engine.try_prefill_session_shared(&sb, &b, 32, &mut NativeExec).unwrap();
    assert_eq!(rb.cached_tokens, 4, "only the shared first page aliases");
    let got = decode_greedy(&mut engine, &sb, rb.logits, 4);

    let mut fresh = Engine::with_paged_slots(weights, 1, 4, None);
    let sf = fresh.open_session(Sampler::greedy()).unwrap();
    let rf = fresh.try_prefill_session(&sf, &b, 32, &mut NativeExec).unwrap();
    let want = decode_greedy(&mut fresh, &sf, rf, 4);
    assert_eq!(got, want, "partial hit must not perturb the diverging suffix");
}

fn templated_requests(n: usize) -> Vec<Request> {
    // Shared two-page template + a short unique suffix per request.
    (0..n)
        .map(|id| {
            let mut prompt: Vec<u32> = (100..108).collect(); // 2 pages of 4
            prompt.extend([3 + id as u32, 7]);
            Request::new(id, prompt, 4)
        })
        .collect()
}

#[test]
fn serve_reports_hits_and_identical_completions() {
    let w = tiny_weights();
    let base = ServeOptions {
        slots_per_worker: 2,
        page_size: 4,
        ..ServeOptions::default()
    };
    let off = serve_with(&w, templated_requests(6), 1, &base).unwrap();
    assert_eq!(off.reuse.prefix_hits, 0, "sharing off: no hits counted");

    let on_opts = ServeOptions {
        prefix_cache: true,
        ..base
    };
    let on = serve_with(&w, templated_requests(6), 1, &on_opts).unwrap();
    assert_eq!(on.completions.len(), 6);
    // Identical completions for the repeated-prefix workload.
    for (a, b) in on.completions.iter().zip(&off.completions) {
        assert_eq!(a.id, b.id);
        assert!(a.error.is_none());
        assert_eq!(a.tokens, b.tokens, "prefix sharing must not change tokens");
    }
    // ≥1 page-aligned prefix hit, with real prefill work skipped.
    assert!(on.reuse.prefix_hits >= 1, "hits: {:?}", on.reuse);
    assert!(
        on.reuse.prefix_hit_tokens >= 8,
        "the shared two-page template is skipped at least once: {:?}",
        on.reuse
    );
}

#[test]
fn oversubscribed_serve_swaps_and_charges_dma_bytes() {
    // Tight pool (4 pages of 4 tokens) + a host arena: serving three
    // 9-token prompts — the third repeating the first — forces cached
    // pages out to the arena and back. Under the imax backend the swap
    // traffic must land in the modeled report.
    let w = tiny_weights();
    let mk_reqs = || {
        let a: Vec<u32> = (20..29).collect();
        let b: Vec<u32> = (40..49).collect();
        vec![
            Request::new(0, a.clone(), 3),
            Request::new(1, b, 3),
            Request::new(2, a, 3),
        ]
    };
    // One slot serializes the three requests, so the A→B→A order forces
    // A's cached pages out under B's reservation and back in on the
    // repeat.
    let opts = ServeOptions {
        slots_per_worker: 1,
        page_size: 4,
        kv_pages: Some(4),
        prefix_cache: true,
        swap_pages: 8,
        spec: ExecSpec::parse("imax").unwrap(),
        ..ServeOptions::default()
    };
    let rep = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
    assert_eq!(rep.completions.len(), 3);
    for c in &rep.completions {
        assert!(c.error.is_none(), "request {} errored: {:?}", c.id, c.error);
    }
    let r = &rep.reuse;
    assert!(r.swap_out_pages >= 1, "pressure evicted to the arena: {r:?}");
    assert!(r.swap_in_pages >= 1, "a repeat prompt swapped back in: {r:?}");
    assert_eq!(r.dropped_pages, 0, "the arena had room for every eviction");
    assert!(r.prefix_hits >= 1, "the repeated prompt hit: {r:?}");
    assert!(r.swap_bytes > 0);
    // The imax cost model charged exactly the swapped bytes through the
    // DMA transfer mode.
    assert_eq!(rep.kv_swap_bytes as usize, r.swap_bytes);
    let m = rep.modeled.expect("imax backend models phases");
    assert!(m.prefill.total() > 0.0 && m.decode.total() > 0.0);

    // Same workload, sharing off: identical tokens (the baseline the
    // acceptance criterion pins), and no swap bytes charged.
    let off = serve_with(
        &w,
        mk_reqs(),
        1,
        &ServeOptions {
            slots_per_worker: 1,
            page_size: 4,
            kv_pages: Some(4),
            spec: ExecSpec::parse("imax").unwrap(),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(off.kv_swap_bytes, 0);
    for (a, b) in rep.completions.iter().zip(&off.completions) {
        assert_eq!(a.tokens, b.tokens, "swap/oversubscription must not change tokens");
    }
}

#[test]
fn swap_roundtrip_preserves_generation_across_eviction() {
    // Engine-level: register a prompt, force its pages to swap out via
    // pool pressure, then readmit the same prompt — the swapped-in pages
    // must reproduce the cold generation exactly.
    let weights = tiny_weights();
    let mut engine = Engine::with_paged_slots(weights, 2, 4, Some(4));
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(8);
    let prompt: Vec<u32> = (60..69).collect();

    let s0 = engine.open_session(Sampler::greedy()).unwrap();
    let cold = engine.try_prefill_session_shared(&s0, &prompt, 32, &mut NativeExec).unwrap();
    let want = decode_greedy(&mut engine, &s0, cold.logits, 4);
    engine.close_session(s0);

    // Pressure: a different 13-token sequence needs all 4 pages, so the
    // two cached pages must swap out.
    let filler: Vec<u32> = (80..93).collect();
    let s1 = engine.open_session(Sampler::greedy()).unwrap();
    engine.try_prefill_session(&s1, &filler, 32, &mut NativeExec).unwrap();
    assert_eq!(engine.cache.swapped_out_pages(), 2, "cached pages went host-side");
    engine.close_session(s1);

    // Warm readmit: pages swap back in bit-exact.
    let s2 = engine.open_session(Sampler::greedy()).unwrap();
    let warm = engine.try_prefill_session_shared(&s2, &prompt, 32, &mut NativeExec).unwrap();
    assert_eq!(warm.cached_tokens, 8, "both swapped pages restored");
    assert_eq!(engine.cache.reuse_stats().swap_in_pages, 2);
    let got = decode_greedy(&mut engine, &s2, warm.logits, 4);
    assert_eq!(got, want, "swap-out/swap-in roundtrip is bit-exact end to end");
}
