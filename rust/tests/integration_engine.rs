//! Engine-level integration: full tokenizer → engine → sampler pipeline
//! on real tiny models across all quant schemes, model file round-trips,
//! and the serving loop.

use imax_llm::coordinator::{serve, Request};
use imax_llm::model::config::{ModelConfig, QuantScheme};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::sampler::Sampler;
use imax_llm::model::weights::ModelWeights;
use imax_llm::model::{file as model_file, Phase};
use imax_llm::tokenizer::Tokenizer;

#[test]
fn text_to_text_pipeline_all_schemes() {
    let cfg = ModelConfig::tiny();
    let corpus = "the linear array of processing elements streams quantized weights ".repeat(8);
    let tok = Tokenizer::train(&corpus, 48);
    let prompt = tok.encode_with_bos("the linear array of");
    assert!(prompt.len() > 2);

    for scheme in [QuantScheme::F16, QuantScheme::Q8_0, QuantScheme::Q3KS] {
        let mut engine = Engine::new(ModelWeights::random(&cfg, scheme, 31));
        let res = engine.generate(&prompt, 12, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(res.tokens.len(), 12, "{}", scheme.name());
        let text = tok.decode(&res.tokens);
        // Random weights produce gibberish but decoding must not fail and
        // tokens must be in-vocab.
        assert!(res.tokens.iter().all(|&t| (t as usize) < cfg.vocab_size));
        let _ = text;
    }
}

#[test]
fn q8_and_f16_agree_on_early_tokens() {
    // Near-lossless quantization should follow the same greedy path for
    // at least the first few tokens.
    let cfg = ModelConfig::tiny();
    let prompt = [1u32, 17, 93, 240, 5];
    let mut ef = Engine::new(ModelWeights::random(&cfg, QuantScheme::F16, 7));
    let mut eq = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 7));
    let rf = ef.generate(&prompt, 4, &mut Sampler::greedy(), &mut NativeExec);
    let rq = eq.generate(&prompt, 4, &mut Sampler::greedy(), &mut NativeExec);
    assert_eq!(rf.tokens[0], rq.tokens[0], "first greedy token must agree");
}

#[test]
fn kv_cache_incremental_matches_recompute() {
    // Decoding t tokens incrementally must equal prefilling them all:
    // the logits after processing [a, b, c] via generate-path equal the
    // logits of a fresh engine prefilled with [a, b, c].
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 55);
    let toks = [3u32, 100, 42];

    let mut incremental = Engine::new(weights.clone());
    let mut logits_inc = None;
    for (i, &t) in toks.iter().enumerate() {
        logits_inc = incremental.forward(
            t,
            if i == 0 { Phase::Prefill } else { Phase::Decode },
            i + 1 == toks.len(),
            &mut NativeExec,
        );
    }

    let mut fresh = Engine::new(weights);
    let mut logits_fresh = None;
    for (i, &t) in toks.iter().enumerate() {
        logits_fresh = fresh.forward(t, Phase::Prefill, i + 1 == toks.len(), &mut NativeExec);
    }
    assert_eq!(
        logits_inc.unwrap(),
        logits_fresh.unwrap(),
        "KV-cached incremental forward must be exact"
    );
}

#[test]
fn model_file_roundtrip_via_disk_and_serve() {
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q3KS, 77);
    let path = std::env::temp_dir().join(format!("imax_it_model_{}.imx3", std::process::id()));
    model_file::save(&weights, &path).unwrap();
    let loaded = model_file::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let requests: Vec<Request> = (0..4)
        .map(|id| Request::new(id, vec![1, 2 + id as u32, 9], 4))
        .collect();
    let rep = serve(&loaded, requests, 2, 5);
    assert_eq!(rep.completions.len(), 4);
    assert_eq!(rep.total_tokens, 16);
    assert!(rep.throughput_tok_s > 0.0);
}

#[test]
fn long_generation_is_stable() {
    // 64 tokens of decode on the tiny model: activations must stay finite
    // (no cache corruption / norm blow-up).
    let cfg = ModelConfig::tiny();
    let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 99));
    let res = engine.generate(
        &[1, 2, 3],
        64,
        &mut Sampler::top_k(1.0, 50, 123),
        &mut NativeExec,
    );
    assert_eq!(res.tokens.len(), 64);
    // Re-forward the last sampled token and inspect logits.
    let logits = engine
        .forward(*res.tokens.last().unwrap(), Phase::Decode, true, &mut NativeExec)
        .unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn independent_requests_are_isolated() {
    // Running request B after request A (with reset) must give the same
    // answer as running B on a fresh engine.
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 13);
    let a = [4u32, 5, 6, 7];
    let b = [9u32, 8];

    let mut shared = Engine::new(weights.clone());
    shared.generate(&a, 5, &mut Sampler::greedy(), &mut NativeExec);
    let rb_shared = shared.generate(&b, 5, &mut Sampler::greedy(), &mut NativeExec);

    let mut fresh = Engine::new(weights);
    let rb_fresh = fresh.generate(&b, 5, &mut Sampler::greedy(), &mut NativeExec);
    assert_eq!(rb_shared.tokens, rb_fresh.tokens);
}
