//! Chunked-prefill equivalence suite: a prompt advanced chunk-by-chunk
//! through a resumable [`PrefillCursor`] (the token-budget scheduler's
//! prefill primitive) must be *bit-identical* to a one-shot prefill of
//! the same prompt — chunk boundaries are an execution schedule, never a
//! numerics change. Covers chunk sizes 1, `page_size − 1`, `page_size`,
//! and whole-prompt, a prefix-cache warm hit that lands mid-chunk, and
//! the token-budget batcher composing both with admission-time prefix
//! adoption.

use std::time::Instant;

use imax_llm::coordinator::{Admitted, ContinuousBatcher, Request};
use imax_llm::model::engine::{Engine, NativeExec, PrefillCursor};
use imax_llm::model::{ModelConfig, ModelWeights, Phase, QuantScheme, Sampler};

const PAGE_SIZE: usize = 4;

fn weights(seed: u64) -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, seed)
}

/// One-shot reference: whole-prompt prefill then `n_out` greedy decode
/// steps; returns (prefill logits, every decode logits, tokens).
fn one_shot(
    w: &ModelWeights,
    prompt: &[u32],
    n_out: usize,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<u32>) {
    let mut e = Engine::with_paged_slots(w.clone(), 1, PAGE_SIZE, None);
    let s = e.open_session(Sampler::greedy()).unwrap();
    let mut logits = e.prefill_session(&s, prompt, prompt.len(), &mut NativeExec);
    let prefill_logits = logits.clone();
    let mut trace = Vec::new();
    let mut toks = Vec::new();
    for step in 0..n_out {
        let next = Sampler::greedy().sample(&logits);
        toks.push(next);
        if step + 1 < n_out {
            logits = e
                .forward_session(&s, next, Phase::Decode, true, &mut NativeExec)
                .unwrap();
            trace.push(logits.clone());
        }
    }
    (prefill_logits, trace, toks)
}

#[test]
fn cursor_chunks_bit_identical_across_chunk_sizes() {
    // Chunk sizes 1, page_size−1, page_size, and whole-prompt, over
    // prompts whose lengths do and don't align with pages and chunks.
    let w = weights(42);
    let prompts: &[&[u32]] = &[
        &[5],
        &[1, 5, 9, 2, 11],
        &[2, 7, 1, 8, 2, 8, 1, 8],
        &[9, 8, 7, 6, 5, 4, 3, 2, 1, 9, 8, 7, 6],
    ];
    for prompt in prompts {
        let (want_prefill, want_trace, want_toks) = one_shot(&w, prompt, 6);
        for chunk in [1usize, PAGE_SIZE - 1, PAGE_SIZE, prompt.len()] {
            let mut e = Engine::with_paged_slots(w.clone(), 1, PAGE_SIZE, None);
            let s = e.open_session(Sampler::greedy()).unwrap();
            let mut cursor = PrefillCursor::new(prompt.to_vec());
            let mut got = None;
            let mut steps = 0usize;
            while !cursor.done() {
                got = e
                    .prefill_partial(&s, &mut cursor, chunk, &mut NativeExec)
                    .unwrap();
                steps += 1;
            }
            assert_eq!(steps, prompt.len().div_ceil(chunk), "chunk count (chunk {chunk})");
            let mut logits = got.expect("cursor completed with logits");
            assert_eq!(
                want_prefill,
                logits,
                "prefill logits (chunk {chunk}, prompt len {})",
                prompt.len()
            );
            let mut toks = Vec::new();
            for step in 0..6 {
                let next = Sampler::greedy().sample(&logits);
                toks.push(next);
                if step + 1 < 6 {
                    logits = e
                        .forward_session(&s, next, Phase::Decode, true, &mut NativeExec)
                        .unwrap();
                    assert_eq!(
                        want_trace[step], logits,
                        "decode logits step {step} (chunk {chunk})"
                    );
                }
            }
            assert_eq!(want_toks, toks, "greedy decode after chunked prefill");
        }
    }
}

#[test]
fn warm_prefix_hit_mid_chunk_bit_identical_with_fewer_executed_tokens() {
    // A cached two-page prefix adopted at admission starts the cursor
    // mid-prompt; the chunk size (5) straddles the adoption boundary, so
    // the first resumed chunk is *not* page- or chunk-aligned. Results
    // must match a cold one-shot run bit for bit while executing
    // strictly fewer prompt tokens.
    let w = weights(7);
    let prompt: Vec<u32> = (1..=12).collect();
    let (want_prefill, _, want_toks) = one_shot(&w, &prompt, 5);

    let mut e = Engine::with_paged_slots(w.clone(), 2, PAGE_SIZE, None);
    e.enable_prefix_cache();
    // Warm the cache: one full shared-prefill pass commits and registers
    // the prompt's pages, which survive the session as cached entries.
    let warmer = e.open_session(Sampler::greedy()).unwrap();
    let cold = e
        .try_prefill_session_shared(&warmer, &prompt, 32, &mut NativeExec)
        .unwrap();
    assert_eq!(cold.cached_tokens, 0, "first pass is cold");
    assert_eq!(want_prefill, cold.logits, "shared prefill matches one-shot");
    e.close_session(warmer);

    // Warm hit: adoption covers the two full pages (8 of 12 tokens), and
    // the cursor resumes from there in chunks of 5 → one chunk of 4.
    let sess = e.open_session(Sampler::greedy()).unwrap();
    let adopted = e.adopt_prefix(&sess, &prompt, &mut NativeExec);
    assert_eq!(adopted.tokens, 2 * PAGE_SIZE, "page-aligned adoption");
    let mut cursor = PrefillCursor::with_adopted(prompt.clone(), adopted.tokens);
    assert_eq!(cursor.remaining(), prompt.len() - 2 * PAGE_SIZE);
    let mut executed = 0usize;
    let mut got = None;
    while !cursor.done() {
        let before = cursor.pos();
        got = e.prefill_partial(&sess, &mut cursor, 5, &mut NativeExec).unwrap();
        executed += cursor.pos() - before;
    }
    let mut logits = got.expect("cursor completed");
    assert_eq!(want_prefill, logits, "warm chunked prefill bit-identical");
    assert_eq!(executed, 4, "strictly fewer tokens executed than the cold 12");
    let mut toks = Vec::new();
    for step in 0..5 {
        let next = Sampler::greedy().sample(&logits);
        toks.push(next);
        if step + 1 < 5 {
            logits = e
                .forward_session(&sess, next, Phase::Decode, true, &mut NativeExec)
                .unwrap();
        }
    }
    assert_eq!(want_toks, toks, "decode after a mid-chunk warm hit");
}

#[test]
fn token_budget_batcher_composes_with_prefix_adoption() {
    // Templated prompts through the token-budget batcher with the prefix
    // cache on: warm admissions adopt the shared two-page template and
    // stream only their tails through in-round chunks. Tokens must match
    // the phase-segregated prefix-cache run, with strictly fewer chunked
    // prefill tokens than the total prompt length.
    let mk_reqs = || {
        (0..4)
            .map(|id| {
                let mut prompt: Vec<u32> = (100..100 + 2 * PAGE_SIZE as u32).collect();
                prompt.extend([7 + id as u32, 3]);
                Request::new(id as usize, prompt, 4)
            })
            .collect::<Vec<Request>>()
    };
    let run = |budget: Option<usize>| {
        let mut engine = Engine::with_paged_slots(weights(11), 4, PAGE_SIZE, None);
        engine.enable_prefix_cache();
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        if let Some(n) = budget {
            b = b.with_token_budget(n).with_prefill_chunk(3);
        }
        let mut exec = NativeExec;
        let mut reqs = mk_reqs().into_iter();
        assert!(matches!(
            b.admit(reqs.next().unwrap(), Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        if budget.is_some() {
            // Stream the cold template in: ceil(10 / 3) = 4 rounds
            // completes request 0's prefill and registers its pages (on
            // the segregated path admission already did both inline).
            for _ in 0..4 {
                assert!(b.decode_round(&mut exec).is_empty());
            }
            assert_eq!(b.reuse_stats().prefix_hits, 0, "cold so far");
        }
        for req in reqs {
            assert!(matches!(
                b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
        }
        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        let reuse = b.reuse_stats();
        (logs, b.round_stats(), reuse)
    };
    let (seg, _, seg_reuse) = run(None);
    let (bud, bud_stats, bud_reuse) = run(Some(6));
    for (a, b) in seg.iter().zip(&bud) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "budget + prefix cache must not change tokens");
    }
    // Same sharing either way: requests 1..4 adopt the template…
    assert_eq!(seg_reuse.prefix_hits, 3);
    assert_eq!(bud_reuse.prefix_hits, 3);
    assert_eq!(bud_reuse.prefix_hit_tokens, 3 * 2 * PAGE_SIZE);
    // …so the budgeted run streams only the cold prompt plus three tails.
    let total_prompt: usize = mk_reqs().iter().map(|r| r.prompt.len()).sum();
    assert_eq!(
        bud_stats.chunked_prefill_tokens,
        total_prompt - 3 * 2 * PAGE_SIZE,
        "adopted spans never stream through chunks"
    );
}
