//! Integration tests for speculative decoding: the draft/verify loop
//! must be bit-identical to vanilla decode under every page geometry,
//! and a rejected draft must leave no trace — neither in the sequence
//! (rollback) nor in the paged pool (no leaked pages, no corrupted
//! refcounts, no damage to shared prefix pages).
//!
//! Engine-level coverage pins the verify/rollback contract directly
//! ([`Engine::try_verify_session`] + [`Engine::truncate_session`]),
//! including a rollback that lands mid-page; scheduler-level coverage
//! sweeps k × page-size through the [`ContinuousBatcher`] with the KV
//! pool sized to the admission commitment exactly, so an over-reserving
//! verify would fail loudly; the property suite drives randomized
//! configurations (page size, draft depth, request count, prefix cache
//! on/off) and checks pool conservation after teardown.

use std::time::Instant;

use imax_llm::coordinator::{Admitted, ContinuousBatcher, FinishReason, Request, SessionLog};
use imax_llm::harness::workloads::templated_prompt;
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::{DrafterSpec, ModelConfig, ModelWeights, Phase, QuantScheme, Sampler};
use imax_llm::util::ceil_div;
use imax_llm::util::proptest_lite::Runner;
use imax_llm::util::rng::Rng;

/// Tiny 16-vocab config (mirrors the scheduler's spec tests): a prompt
/// covering the whole vocabulary guarantees every sampled token has a
/// 1-gram match, so the n-gram drafter always proposes something and
/// the speculative path is exercised deterministically.
fn spec_cfg() -> ModelConfig {
    ModelConfig {
        name: "spec-itest",
        n_layers: 2,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        d_ffn: 128,
        vocab_size: 16,
        qk_norm: true,
        rope_theta: 1e4,
        rms_eps: 1e-6,
        max_seq_len: 128,
    }
}

const PROMPT_LEN: usize = 16;
const N_OUT: usize = 12;

fn full_vocab_prompt() -> Vec<u32> {
    (0..PROMPT_LEN as u32).collect()
}

/// A second vocabulary-covering prompt (5 is coprime with 16, so this is
/// a permutation) — distinct content, same drafting guarantees.
fn permuted_prompt() -> Vec<u32> {
    (0..PROMPT_LEN as u32).map(|i| (5 * i) % 16).collect()
}

/// Serve both requests through a batcher over a paged engine whose pool
/// is exactly the admission commitment; returns the per-request logs.
fn run_batched(weights: &ModelWeights, k: usize, page_size: usize) -> Vec<SessionLog> {
    // Admission commits pages for `prompt + n_out - 1` cached tokens per
    // request; a verify may never reserve beyond that.
    let pool = 2 * ceil_div(PROMPT_LEN + N_OUT - 1, page_size);
    let engine = Engine::with_paged_slots(weights.clone(), 2, page_size, Some(pool));
    let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
    if k > 0 {
        b = b.with_speculation(k, DrafterSpec::default());
    }
    let mut exec = NativeExec;
    for (id, prompt) in [full_vocab_prompt(), permuted_prompt()].into_iter().enumerate() {
        let req = Request::new(id, prompt, N_OUT);
        assert!(
            matches!(b.admit(req, Sampler::greedy(), 0.0, &mut exec), Ok(Admitted::Active)),
            "admission must not defer (k={k}, page={page_size})"
        );
    }
    let mut logs = b.drain(&mut exec);
    assert_eq!(
        b.engine().free_pages(),
        pool,
        "pages leaked after drain (k={k}, page={page_size})"
    );
    assert_eq!(b.committed_pages(), 0, "commitments leaked (k={k}, page={page_size})");
    logs.sort_by_key(|l| l.id);
    logs
}

#[test]
fn greedy_bit_identity_across_k_and_page_sizes() {
    let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 17);
    let mut reference: Option<Vec<Vec<u32>>> = None;
    for page_size in [1usize, 3, 16] {
        let vanilla = run_batched(&weights, 0, page_size);
        let tokens: Vec<Vec<u32>> = vanilla.iter().map(|l| l.tokens.clone()).collect();
        assert!(vanilla.iter().all(|l| l.verify_calls == 0));
        assert!(tokens.iter().all(|t| t.len() == N_OUT));
        // Page geometry is an allocation detail: vanilla output must not
        // depend on it.
        match &reference {
            None => reference = Some(tokens.clone()),
            Some(want) => assert_eq!(&tokens, want, "page={page_size} changed vanilla output"),
        }
        for k in [1usize, 2, 4, 8] {
            let spec = run_batched(&weights, k, page_size);
            for (s, v) in spec.iter().zip(&vanilla) {
                assert_eq!(
                    s.tokens, v.tokens,
                    "speculative output diverged (k={k}, page={page_size}, id={})",
                    s.id
                );
            }
            let verifies: usize = spec.iter().map(|l| l.verify_calls).sum();
            assert!(verifies > 0, "vocab-covering prompts must draft (k={k})");
            for l in &spec {
                assert!(l.draft_accepted <= l.draft_tokens);
            }
        }
    }
}

#[test]
fn mid_page_rejection_rolls_back_and_decode_continues_bit_identical() {
    let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 11);
    let prompt = full_vocab_prompt();
    let n_out = 8usize;

    // Vanilla reference stream.
    let mut reference = Engine::new(weights.clone());
    let r = reference.generate(&prompt, n_out, &mut Sampler::greedy(), &mut NativeExec);
    assert_eq!(r.tokens.len(), n_out);
    let want = r.tokens;
    let wrong = |t: u32| (t + 1) % 16; // never equal to t in a 16-vocab

    for page_size in [1usize, 3, 16] {
        let mut e = Engine::with_paged_slots(weights.clone(), 1, page_size, None);
        let total = e.total_pages();
        let s = e.open_session(Sampler::greedy()).unwrap();
        let logits = e.prefill_session(&s, &prompt, 8, &mut NativeExec);
        let mut sampler = Sampler::greedy();
        let t0 = sampler.sample(&logits);
        assert_eq!(t0, want[0]);
        assert_eq!(e.session_pos(&s), 16);

        // Verify pass with an entirely wrong 3-token draft: the sampler
        // rejects at the first drafted position, so the valid length is
        // base + 1 (the forwarded `t0`) — 17 tokens, which for page
        // sizes 3 and 16 lands mid-page.
        let draft = [wrong(want[1]), wrong(want[2]), wrong(want[3])];
        let rows = e
            .try_verify_session(&s, &[t0, draft[0], draft[1], draft[2]], &mut NativeExec)
            .unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(e.session_pos(&s), 20, "verify cached every position");
        let t1 = sampler.sample(&rows[0]);
        assert_eq!(t1, want[1], "verify row 0 is the vanilla next-token logits");
        assert_ne!(t1, draft[0], "draft constructed to be rejected");
        let free_grown = e.free_pages();
        e.truncate_session(&s, 17);
        assert_eq!(e.session_pos(&s), 17);
        assert_eq!(
            e.free_pages() - free_grown,
            e.pages_needed(20) - e.pages_needed(17),
            "rollback returns exactly the rejected tail's pages (page={page_size})"
        );

        // The rejection's own sampled token was never cached: forward it
        // (the scheduler's pending-forward handoff) and keep decoding —
        // the stream must rejoin the vanilla one exactly.
        let logits = e
            .forward_session(&s, t1, Phase::Decode, true, &mut NativeExec)
            .unwrap();
        let t2 = sampler.sample(&logits);
        assert_eq!(t2, want[2], "post-rollback decode diverged (page={page_size})");

        // Second verify: a fully correct draft — every position accepts
        // and the last row samples the bonus token, no rollback needed.
        let rows = e
            .try_verify_session(&s, &[t2, want[3], want[4]], &mut NativeExec)
            .unwrap();
        let accepted: Vec<u32> = rows.iter().map(|row| sampler.sample(row)).collect();
        assert_eq!(accepted, [want[3], want[4], want[5]], "full acceptance + bonus");
        assert_eq!(e.session_pos(&s), 21);

        // Drain the rest sequentially; the full stream matches vanilla.
        let mut logits = e
            .forward_session(&s, want[5], Phase::Decode, true, &mut NativeExec)
            .unwrap();
        let mut tokens = vec![t0, t1, t2, want[3], want[4], want[5]];
        while tokens.len() < n_out {
            let t = sampler.sample(&logits);
            tokens.push(t);
            if tokens.len() < n_out {
                logits = e
                    .forward_session(&s, t, Phase::Decode, true, &mut NativeExec)
                    .unwrap();
            }
        }
        assert_eq!(tokens, want, "mixed verify/rollback stream (page={page_size})");

        e.close_session(s);
        assert_eq!(e.free_pages(), total, "session teardown recovered the pool");
    }
}

/// Randomized configuration for the no-leak property: page geometry,
/// draft depth, output length, request count, and whether the prefix
/// cache (shared pages under the verify ubatches) is enabled.
#[derive(Clone, Debug)]
struct SpecCase {
    wseed: u64,
    page_size: usize,
    k: usize,
    n_out: usize,
    n_req: usize,
    prefix: bool,
}

fn gen_spec_case(r: &mut Rng) -> SpecCase {
    SpecCase {
        wseed: 31 + r.below(4) as u64,
        page_size: 1 + r.below(4),
        k: 1 + r.below(8),
        // n_out ≥ 3 so the first decode round has draft room (k is
        // capped at n_out − tokens − 1).
        n_out: 3 + r.below(8),
        n_req: 1 + r.below(3),
        prefix: r.below(2) == 1,
    }
}

/// Post-drain engine state + outputs of one batched run, for comparing
/// a speculative run against its vanilla twin.
struct RunOutcome {
    free_pages: usize,
    /// `peek_prefix` of the shared prompt: (cached tokens, resident
    /// pages, swapped pages) — the registered index state.
    peek: (usize, usize, usize),
    tokens: Vec<Vec<u32>>,
    verify_calls: usize,
}

fn check_spec_case(case: &SpecCase) -> Result<(), String> {
    let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, case.wseed);
    let prompt = full_vocab_prompt();
    let pool = case.n_req * ceil_div(PROMPT_LEN + case.n_out - 1, case.page_size);
    let run = |k: usize| -> Result<RunOutcome, String> {
        let mut engine =
            Engine::with_paged_slots(weights.clone(), case.n_req, case.page_size, Some(pool));
        if case.prefix {
            engine.enable_prefix_cache();
        }
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        if k > 0 {
            b = b.with_speculation(k, DrafterSpec::default());
        }
        let mut exec = NativeExec;
        for id in 0..case.n_req {
            let req = Request::new(id, prompt.clone(), case.n_out);
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {}
                other => return Err(format!("admission {other:?} ({case:?})")),
            }
        }
        let mut logs = b.drain(&mut exec);
        if b.committed_pages() != 0 {
            return Err(format!("{} committed pages after drain ({case:?})", b.committed_pages()));
        }
        logs.sort_by_key(|l| l.id);
        let verify_calls = logs.iter().map(|l| l.verify_calls).sum();
        for l in &logs {
            if l.draft_accepted > l.draft_tokens {
                return Err(format!("accepted > drafted ({case:?})"));
            }
        }
        Ok(RunOutcome {
            free_pages: b.engine().free_pages(),
            peek: b.engine().peek_prefix(&prompt),
            tokens: logs.into_iter().map(|l| l.tokens).collect(),
            verify_calls,
        })
    };
    let vanilla = run(0)?;
    let spec = run(case.k)?;
    if spec.tokens != vanilla.tokens {
        return Err(format!("speculative tokens diverge ({case:?})"));
    }
    if spec.verify_calls == 0 {
        return Err(format!("vocab-covering prompt never drafted ({case:?})"));
    }
    // Pool conservation: without the prefix cache the whole pool comes
    // back; with it, only the registered prompt chain may stay resident,
    // and the speculative run must retire to the *same* state as the
    // vanilla run — a rejected draft that leaked a page or dropped a
    // shared page's refcount would break the equality.
    if !case.prefix && spec.free_pages != pool {
        return Err(format!("leak: {}/{pool} pages free ({case:?})", spec.free_pages));
    }
    if spec.free_pages != vanilla.free_pages {
        return Err(format!(
            "free pages {} != vanilla {} ({case:?})",
            spec.free_pages, vanilla.free_pages
        ));
    }
    if spec.peek != vanilla.peek {
        return Err(format!(
            "prefix index {:?} != vanilla {:?} ({case:?})",
            spec.peek, vanilla.peek
        ));
    }
    Ok(())
}

#[test]
fn prop_rejected_drafts_never_leak_pages_or_corrupt_shared_state() {
    Runner::new("spec-decode-no-leak").cases(24).run_noshrink(gen_spec_case, check_spec_case);
}

/// Median of a non-empty gap set (copies and sorts).
fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[s.len() / 2]
}

/// Regression test for the TBT-deflation bug: speculative verifies used
/// to push k+1 `token_marks_s` entries at the same instant, so the gap
/// percentiles filled with ~0 intra-burst gaps and `--speculate 4`
/// *reported* lower time-between-tokens than vanilla decode while the
/// consumer experienced the opposite. Gaps are now measured over
/// delivery events (one per sink call), which cannot deflate.
#[test]
fn speculate_4_tbt_is_measured_over_delivery_events_and_does_not_deflate() {
    // Mirrors benches/speculation.rs exactly (same tensor shapes, same
    // weight seed, templated prompts, k=4): the CI-gated bench baseline
    // proves this workload accepts drafts — its strict bytes-per-token
    // win is only possible with a positive accept count — so the burst
    // assertions below are deterministic, not hopeful.
    let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 29);
    let run = |k: usize| -> Vec<SessionLog> {
        let mut b =
            ContinuousBatcher::new(Engine::with_slots(weights.clone(), 4), 32, Instant::now());
        if k > 0 {
            b = b.with_speculation(k, DrafterSpec::default());
        }
        let mut exec = NativeExec;
        for id in 0..3 {
            let req = Request::new(id, templated_prompt(id, 48, 16), 24);
            assert!(matches!(
                b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
        }
        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        logs
    };
    let vanilla = run(0);
    let spec = run(4);
    for (s, v) in spec.iter().zip(&vanilla) {
        assert_eq!(s.tokens, v.tokens, "speculative stream diverged (id={})", s.id);
    }
    let accepted: usize = spec.iter().map(|l| l.draft_accepted).sum();
    assert!(accepted > 0, "templated workload must accept drafts");

    // Vanilla decode: one delivery event per token, marks coincide.
    for l in &vanilla {
        assert_eq!(l.delivery_marks_s.len(), l.tokens.len());
        assert_eq!(l.token_marks_s, l.delivery_marks_s);
    }
    // Speculative decode: an accepted run is ONE event — strictly fewer
    // events than tokens in aggregate, every token still individually
    // marked, and the tokens of one event share the event's instant.
    let spec_events: usize = spec.iter().map(|l| l.delivery_marks_s.len()).sum();
    let spec_tokens: usize = spec.iter().map(|l| l.tokens.len()).sum();
    assert!(spec_events < spec_tokens, "{spec_events} events for {spec_tokens} tokens");
    for l in &spec {
        assert_eq!(l.token_marks_s.len(), l.tokens.len());
        let mut distinct = l.token_marks_s.clone();
        distinct.dedup();
        assert_eq!(distinct, l.delivery_marks_s, "burst tokens share the delivery instant");
        assert_eq!(l.tbt_gaps_s().len(), l.delivery_marks_s.len() - 1);
    }

    // Over delivery events the speculative median gap must sit in the
    // same regime as vanilla (a verify round does strictly more work
    // than a single-token decode round). The old per-token accounting
    // fails this by orders of magnitude — most gaps were exactly 0 —
    // so a 4x noise margin keeps the comparison stable.
    let gaps = |logs: &[SessionLog]| -> Vec<f64> {
        logs.iter().flat_map(|l| l.tbt_gaps_s()).collect()
    };
    let (gv, gs) = (gaps(&vanilla), gaps(&spec));
    assert!(gv.len() >= 8 && gs.len() >= 8, "{} / {} gaps", gv.len(), gs.len());
    assert!(gs.iter().all(|&g| g > 0.0), "delivery gaps are real time spans");
    assert!(
        median(&gs) >= 0.25 * median(&gv),
        "speculative TBT p50 deflated: {:.3e}s vs vanilla {:.3e}s",
        median(&gs),
        median(&gv)
    );
}

/// Deterministic pin of the delivery-mark semantics on a synthetic log:
/// a 3-token accepted burst at t=2.0 followed by a lone token at t=3.5
/// yields exactly one gap (1.5s), and TTFT counts queue time plus the
/// wait from admission to the first *delivery*.
#[test]
fn tbt_gaps_ignore_intra_burst_instants_by_construction() {
    let log = SessionLog {
        id: 0,
        tokens: vec![1, 2, 3, 4],
        n_prefill: 8,
        queue_s: 0.5,
        prefill_s: 0.0,
        decode_s: 0.0,
        admitted_s: 1.0,
        decode_start_s: 1.0,
        finished_s: 4.0,
        token_marks_s: vec![2.0, 2.0, 2.0, 3.5],
        delivery_marks_s: vec![2.0, 3.5],
        reason: FinishReason::Completed,
        verify_calls: 1,
        draft_tokens: 2,
        draft_accepted: 2,
    };
    assert_eq!(log.tbt_gaps_s(), vec![1.5]);
    assert_eq!(log.ttft_s(), Some(1.5), "0.5s queued + 1.0s to first delivery");
    assert_eq!(log.accepted_tokens_per_verify(), Some(3.0));
}
