//! Batching-equivalence suite: the ubatch prefill path and the
//! continuous-batching scheduler must be *bit-identical* to the legacy
//! one-token-at-a-time pipeline — batching is an execution-schedule
//! optimization, never a numerics change. This is the functional-path
//! analogue of the cost model's prefill/decode duality: same kernels,
//! different amortization.
//!
//! The same contract covers the paged KV cache: page geometry
//! (`page_size = max_seq, n_pages = n_slots` *is* the old contiguous
//! layout) is a memory-layout choice, never a numerics change, so paged
//! runs must produce bit-identical logits to the contiguous reference
//! for single-token decode, ubatch prefill, and interleaved multi-slot
//! decode alike.
//!
//! And it covers the plan/submit backend API: queueing backends that
//! flush a `LaunchQueue` at the engine's submit points (imax, with or
//! without double-buffered prefetch modeling, and heterogeneous
//! placements) must be bit-identical to the eager native path, and the
//! queue itself must never reorder launches within a dependency chain.

use imax_llm::coordinator::{serve, serve_with, Request, ServeOptions};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::graph::{MatvecOp, OpKind, Phase};
use imax_llm::model::{LinearKind, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::quant::GgmlType;
use imax_llm::runtime::queue::{KernelOp, LaunchQueue};
use imax_llm::runtime::{BackendRegistry, ExecSpec};
use imax_llm::util::proptest_lite::Runner;

fn weights(scheme: QuantScheme, seed: u64) -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), scheme, seed)
}

/// Engine whose cache geometry degenerates to the old contiguous layout:
/// one `max_seq`-sized page per slot.
fn contiguous_engine(w: &ModelWeights, n_slots: usize) -> Engine {
    let max_seq = w.cfg.max_seq_len;
    Engine::with_paged_slots(w.clone(), n_slots, max_seq, None)
}

/// Sequential reference: one forward call per prompt token, then greedy
/// decode; returns (prefill logits, decoded tokens).
fn sequential_greedy(w: &ModelWeights, prompt: &[u32], n_out: usize) -> (Vec<f32>, Vec<u32>) {
    let mut e = Engine::new(w.clone());
    let mut logits = None;
    for (i, &t) in prompt.iter().enumerate() {
        logits = e.forward(t, Phase::Prefill, i + 1 == prompt.len(), &mut NativeExec);
    }
    let prefill_logits = logits.expect("prefill logits");
    let mut logits = prefill_logits.clone();
    let mut toks = Vec::new();
    for step in 0..n_out {
        let next = Sampler::greedy().sample(&logits);
        toks.push(next);
        if step + 1 < n_out {
            logits = e.forward(next, Phase::Decode, true, &mut NativeExec).unwrap();
        }
    }
    (prefill_logits, toks)
}

#[test]
fn ubatch_prefill_equals_sequential_across_prompts_and_seeds() {
    // Property-style sweep: several prompts × weight seeds × schemes ×
    // chunk sizes, all token-for-token identical under greedy sampling.
    let prompts: &[&[u32]] = &[
        &[1],
        &[3, 1, 4, 1, 5],
        &[2, 7, 1, 8, 2, 8, 1, 8, 2, 8],
        &[9, 9, 9, 9, 9, 9, 9],
    ];
    for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS] {
        for seed in [42u64, 7, 1234] {
            let w = weights(scheme, seed);
            for prompt in prompts {
                let (want_logits, want_toks) = sequential_greedy(&w, prompt, 5);
                for ubatch in [1usize, 2, 3, 16] {
                    let mut e = Engine::new(w.clone());
                    let sess = e.open_session(Sampler::greedy()).unwrap();
                    let got_logits = e.prefill_session(&sess, prompt, ubatch, &mut NativeExec);
                    assert_eq!(
                        want_logits, got_logits,
                        "prefill logits (scheme {} seed {seed} ubatch {ubatch})",
                        scheme.name()
                    );
                    let mut logits = got_logits;
                    let mut toks = Vec::new();
                    for step in 0..5 {
                        let next = Sampler::greedy().sample(&logits);
                        toks.push(next);
                        if step + 1 < 5 {
                            logits = e
                                .forward_session(&sess, next, Phase::Decode, true, &mut NativeExec)
                                .unwrap();
                        }
                    }
                    assert_eq!(want_toks, toks, "greedy decode after ubatch prefill");
                }
            }
        }
    }
}

#[test]
fn interleaved_sessions_match_isolated_engines() {
    // Two sessions sharing one engine, with *interleaved* prefill chunks
    // and decode steps, must reproduce exactly what each request gets on
    // a private engine — no KV cross-contamination through the shared
    // slot-indexed cache.
    let w = weights(QuantScheme::Q8_0, 42);
    let pa: Vec<u32> = vec![1, 5, 9, 2, 11, 3];
    let pb: Vec<u32> = vec![7, 3, 3, 8];

    let mut e = Engine::with_slots(w.clone(), 2);
    let sa = e.open_session(Sampler::greedy()).unwrap();
    let sb = e.open_session(Sampler::greedy()).unwrap();
    // Interleave prefill chunks: A[0..3], B[0..2], A[3..6], B[2..4].
    e.forward_ubatch(&sa, &pa[0..3], Phase::Prefill, false, &mut NativeExec);
    e.forward_ubatch(&sb, &pb[0..2], Phase::Prefill, false, &mut NativeExec);
    let mut la = e
        .forward_ubatch(&sa, &pa[3..6], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut lb = e
        .forward_ubatch(&sb, &pb[2..4], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut ta = Vec::new();
    let mut tb = Vec::new();
    for _ in 0..6 {
        let na = Sampler::greedy().sample(&la);
        ta.push(na);
        la = e.forward_session(&sa, na, Phase::Decode, true, &mut NativeExec).unwrap();
        let nb = Sampler::greedy().sample(&lb);
        tb.push(nb);
        lb = e.forward_session(&sb, nb, Phase::Decode, true, &mut NativeExec).unwrap();
    }

    for (prompt, got) in [(&pa, &ta), (&pb, &tb)] {
        let (_, want) = sequential_greedy(&w, prompt, 6);
        assert_eq!(&want, got, "interleaved session must match isolated engine");
    }
}

#[test]
fn serve_results_independent_of_worker_and_slot_topology() {
    // Per-request samplers are seeded by request id, and sessions are
    // isolated, so the served tokens must not depend on how many workers
    // or session slots the scheduler spreads the requests over.
    let w = weights(QuantScheme::Q8_0, 11);
    let requests: Vec<Request> = (0..6)
        .map(|id| Request::new(id, vec![1 + id as u32, 2, 3, 4, 5], 7))
        .collect();
    let a = serve(&w, requests.clone(), 1, 42);
    let b = serve(&w, requests.clone(), 3, 42);
    let opts = ServeOptions {
        slots_per_worker: 1, // degenerates to the old FIFO worker pool
        sampler_seed: 42,
        ..ServeOptions::default()
    };
    let c = serve_with(&w, requests, 2, &opts).unwrap();
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "worker count must not change tokens");
    }
    for (x, y) in a.completions.iter().zip(&c.completions) {
        assert_eq!(x.tokens, y.tokens, "slot topology must not change tokens");
    }
}

#[test]
fn queued_replay_bit_identical_to_eager_across_backends() {
    // The plan/submit replay path (registry backends flushing their
    // launch queues at the engine's submit()/sync() points) vs the old
    // eager path (plain NativeExec, submit is a no-op): tokens AND the
    // full logits vector at every step must be bit-identical, for the
    // native and imax backends, with and without double-buffered
    // prefetch modeling, and under a heterogeneous placement.
    let w = weights(QuantScheme::Q8_0, 42);
    let prompt: Vec<u32> = vec![1, 5, 9, 2, 11];
    let n_out = 6;

    // Eager reference: prefill + greedy decode, tracing every logits.
    let mut eager = Engine::new(w.clone());
    let se = eager.open_session(Sampler::greedy()).unwrap();
    let mut trace = vec![eager.prefill_session(&se, &prompt, 3, &mut NativeExec)];
    let mut want_toks = Vec::new();
    for _ in 0..n_out {
        let next = Sampler::greedy().sample(trace.last().unwrap());
        want_toks.push(next);
        let l = eager.forward_session(&se, next, Phase::Decode, true, &mut NativeExec).unwrap();
        trace.push(l);
    }

    for backend in ["native", "imax", "imax:dbuf", "imax:naive", "0-1:imax,2-3:native"] {
        let mut exec = BackendRegistry::build(&ExecSpec::parse(backend).unwrap()).unwrap();
        let mut e = Engine::new(w.clone());
        let s = e.open_session(Sampler::greedy()).unwrap();
        let mut got = vec![e.prefill_session(&s, &prompt, 3, &mut exec)];
        let mut toks = Vec::new();
        for _ in 0..n_out {
            let next = Sampler::greedy().sample(got.last().unwrap());
            toks.push(next);
            let l = e.forward_session(&s, next, Phase::Decode, true, &mut exec).unwrap();
            got.push(l);
        }
        assert_eq!(want_toks, toks, "tokens ({backend})");
        for (step, (a, b)) in trace.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "logits at step {step} ({backend})");
        }
    }
}

#[test]
fn launch_queue_never_reorders_within_a_dependency_chain() {
    // Property: over random record/submit interleavings, the flushed
    // launch stream preserves record order — globally (FIFO) and hence
    // within every per-layer dependency chain — with monotonically
    // non-decreasing submission stamps and no launch lost or duplicated.
    fn lop(layer: usize) -> KernelOp {
        KernelOp::Linear {
            op: MatvecOp {
                kind: OpKind::Linear(LinearKind::QProj),
                layer: Some(layer),
                wty: GgmlType::Q8_0,
                rows: 4,
                cols: 32,
            },
            batch: 1,
        }
    }
    Runner::new("launch_queue_fifo").cases(128).run(
        |rng| {
            let n = 1 + (rng.next_u64() % 48) as usize;
            // 0..=3: record a launch on that layer's chain; 4: submit.
            (0..n).map(|_| (rng.next_u64() % 5) as u8).collect::<Vec<u8>>()
        },
        |actions| {
            let mut q: LaunchQueue<usize> = LaunchQueue::new();
            let mut recorded: Vec<(u64, usize)> = Vec::new(); // (seq, chain)
            let mut flushed = Vec::new();
            let mut idx = 0usize;
            for &a in actions {
                if a == 4 {
                    flushed.extend(q.submit());
                } else {
                    let seq = q.record(lop(a as usize), idx);
                    recorded.push((seq, a as usize));
                    idx += 1;
                }
            }
            flushed.extend(q.submit());
            if flushed.len() != recorded.len() {
                return Err(format!(
                    "lost launches: {} flushed of {} recorded",
                    flushed.len(),
                    recorded.len()
                ));
            }
            for (i, l) in flushed.iter().enumerate() {
                if l.payload != i {
                    return Err(format!("reorder at {i}: payload {}", l.payload));
                }
                if l.seq != recorded[i].0 {
                    return Err(format!("seq mismatch at {i}"));
                }
                if l.op.layer() != Some(recorded[i].1) {
                    return Err(format!("chain mismatch at {i}"));
                }
                if i > 0 && l.submission < flushed[i - 1].submission {
                    return Err(format!("submission stamp went backwards at {i}"));
                }
            }
            // Per-chain subsequence explicitly (the dependency-chain
            // contract, should global FIFO ever be relaxed).
            for chain in 0..4usize {
                let seqs: Vec<u64> = flushed
                    .iter()
                    .filter(|l| l.op.layer() == Some(chain))
                    .map(|l| l.seq)
                    .collect();
                if seqs.windows(2).any(|w| w[1] <= w[0]) {
                    return Err(format!("chain {chain} reordered: {seqs:?}"));
                }
            }
            Ok(())
        },
        |v| {
            let mut shrinks = Vec::new();
            if v.len() > 1 {
                shrinks.push(v[..v.len() - 1].to_vec());
                shrinks.push(v[1..].to_vec());
            }
            shrinks
        },
    );
}

#[test]
fn paged_cache_bit_identical_to_contiguous() {
    // Page sizes that do (16 vs len 1; 1 vs anything) and don't (3 vs
    // len 5/7/10) divide the prompt lengths, so last pages are exercised
    // both full and partial. Prefill runs as ubatch chunks of 4 (its own
    // misalignment with the page size), decode single-token; the full
    // logits vector must match the contiguous reference bit for bit at
    // every step.
    let w = weights(QuantScheme::Q8_0, 42);
    let prompts: &[&[u32]] = &[
        &[1],
        &[3, 1, 4, 1, 5],
        &[2, 7, 1, 8, 2, 8, 1],
        &[9, 8, 7, 6, 5, 4, 3, 2, 1, 9],
    ];
    for &page_size in &[1usize, 3, 16] {
        for prompt in prompts {
            let mut c = contiguous_engine(&w, 1);
            let sc = c.open_session(Sampler::greedy()).unwrap();
            let mut lc = c.prefill_session(&sc, prompt, 4, &mut NativeExec);

            let mut p = Engine::with_paged_slots(w.clone(), 1, page_size, None);
            let sp = p.open_session(Sampler::greedy()).unwrap();
            let mut lp = p.prefill_session(&sp, prompt, 4, &mut NativeExec);
            assert_eq!(
                lc,
                lp,
                "prefill logits (page_size {page_size}, prompt len {})",
                prompt.len()
            );
            for step in 0..6 {
                let nc = Sampler::greedy().sample(&lc);
                let np = Sampler::greedy().sample(&lp);
                assert_eq!(nc, np, "greedy token step {step} (page_size {page_size})");
                lc = c
                    .forward_session(&sc, nc, Phase::Decode, true, &mut NativeExec)
                    .unwrap();
                lp = p
                    .forward_session(&sp, np, Phase::Decode, true, &mut NativeExec)
                    .unwrap();
                assert_eq!(lc, lp, "decode logits step {step} (page_size {page_size})");
            }
        }
    }
}

#[test]
fn paged_interleaved_sessions_match_contiguous_isolated() {
    // Two sessions growing in lockstep on a 3-token-page engine: their
    // pages alternate in the shared pool, so every read goes through a
    // non-trivial block table. Results must match each prompt served
    // alone on a contiguous-geometry engine.
    let w = weights(QuantScheme::Q8_0, 7);
    let pa: Vec<u32> = vec![1, 5, 9, 2, 11, 3, 6];
    let pb: Vec<u32> = vec![7, 3, 3, 8];

    let mut e = Engine::with_paged_slots(w.clone(), 2, 3, None);
    let sa = e.open_session(Sampler::greedy()).unwrap();
    let sb = e.open_session(Sampler::greedy()).unwrap();
    // Interleave prefill chunks: A[0..4], B[0..2], A[4..7], B[2..4].
    e.forward_ubatch(&sa, &pa[0..4], Phase::Prefill, false, &mut NativeExec);
    e.forward_ubatch(&sb, &pb[0..2], Phase::Prefill, false, &mut NativeExec);
    let mut la = e
        .forward_ubatch(&sa, &pa[4..7], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut lb = e
        .forward_ubatch(&sb, &pb[2..4], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut ta = Vec::new();
    let mut tb = Vec::new();
    for _ in 0..6 {
        let na = Sampler::greedy().sample(&la);
        ta.push(na);
        la = e.forward_session(&sa, na, Phase::Decode, true, &mut NativeExec).unwrap();
        let nb = Sampler::greedy().sample(&lb);
        tb.push(nb);
        lb = e.forward_session(&sb, nb, Phase::Decode, true, &mut NativeExec).unwrap();
    }
    // Both slots hold exactly the pages their live tokens need.
    for s in [&sa, &sb] {
        let len = e.session_pos(s);
        assert_eq!(e.cache.slot_pages(s.slot()).len(), e.pages_needed(len));
    }

    for (prompt, got) in [(&pa, &ta), (&pb, &tb)] {
        let mut iso = contiguous_engine(&w, 1);
        let s = iso.open_session(Sampler::greedy()).unwrap();
        let mut l = iso.prefill_session(&s, prompt, prompt.len(), &mut NativeExec);
        let mut want = Vec::new();
        for _ in 0..6 {
            let n = Sampler::greedy().sample(&l);
            want.push(n);
            l = iso.forward_session(&s, n, Phase::Decode, true, &mut NativeExec).unwrap();
        }
        assert_eq!(&want, got, "interleaved paged decode must match isolated");
    }
}
