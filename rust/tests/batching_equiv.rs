//! Batching-equivalence suite: the ubatch prefill path and the
//! continuous-batching scheduler must be *bit-identical* to the legacy
//! one-token-at-a-time pipeline — batching is an execution-schedule
//! optimization, never a numerics change. This is the functional-path
//! analogue of the cost model's prefill/decode duality: same kernels,
//! different amortization.

use imax_llm::coordinator::{serve, serve_with, Request, ServeOptions};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::graph::Phase;
use imax_llm::model::{ModelConfig, ModelWeights, QuantScheme, Sampler};

fn weights(scheme: QuantScheme, seed: u64) -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), scheme, seed)
}

/// Sequential reference: one forward call per prompt token, then greedy
/// decode; returns (prefill logits, decoded tokens).
fn sequential_greedy(w: &ModelWeights, prompt: &[u32], n_out: usize) -> (Vec<f32>, Vec<u32>) {
    let mut e = Engine::new(w.clone());
    let mut logits = None;
    for (i, &t) in prompt.iter().enumerate() {
        logits = e.forward(t, Phase::Prefill, i + 1 == prompt.len(), &mut NativeExec);
    }
    let prefill_logits = logits.expect("prefill logits");
    let mut logits = prefill_logits.clone();
    let mut toks = Vec::new();
    for step in 0..n_out {
        let next = Sampler::greedy().sample(&logits);
        toks.push(next);
        if step + 1 < n_out {
            logits = e.forward(next, Phase::Decode, true, &mut NativeExec).unwrap();
        }
    }
    (prefill_logits, toks)
}

#[test]
fn ubatch_prefill_equals_sequential_across_prompts_and_seeds() {
    // Property-style sweep: several prompts × weight seeds × schemes ×
    // chunk sizes, all token-for-token identical under greedy sampling.
    let prompts: &[&[u32]] = &[
        &[1],
        &[3, 1, 4, 1, 5],
        &[2, 7, 1, 8, 2, 8, 1, 8, 2, 8],
        &[9, 9, 9, 9, 9, 9, 9],
    ];
    for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS] {
        for seed in [42u64, 7, 1234] {
            let w = weights(scheme, seed);
            for prompt in prompts {
                let (want_logits, want_toks) = sequential_greedy(&w, prompt, 5);
                for ubatch in [1usize, 2, 3, 16] {
                    let mut e = Engine::new(w.clone());
                    let sess = e.open_session(Sampler::greedy()).unwrap();
                    let got_logits = e.prefill_session(&sess, prompt, ubatch, &mut NativeExec);
                    assert_eq!(
                        want_logits, got_logits,
                        "prefill logits (scheme {} seed {seed} ubatch {ubatch})",
                        scheme.name()
                    );
                    let mut logits = got_logits;
                    let mut toks = Vec::new();
                    for step in 0..5 {
                        let next = Sampler::greedy().sample(&logits);
                        toks.push(next);
                        if step + 1 < 5 {
                            logits = e
                                .forward_session(&sess, next, Phase::Decode, true, &mut NativeExec)
                                .unwrap();
                        }
                    }
                    assert_eq!(want_toks, toks, "greedy decode after ubatch prefill");
                }
            }
        }
    }
}

#[test]
fn interleaved_sessions_match_isolated_engines() {
    // Two sessions sharing one engine, with *interleaved* prefill chunks
    // and decode steps, must reproduce exactly what each request gets on
    // a private engine — no KV cross-contamination through the shared
    // slot-indexed cache.
    let w = weights(QuantScheme::Q8_0, 42);
    let pa: Vec<u32> = vec![1, 5, 9, 2, 11, 3];
    let pb: Vec<u32> = vec![7, 3, 3, 8];

    let mut e = Engine::with_slots(w.clone(), 2);
    let sa = e.open_session(Sampler::greedy()).unwrap();
    let sb = e.open_session(Sampler::greedy()).unwrap();
    // Interleave prefill chunks: A[0..3], B[0..2], A[3..6], B[2..4].
    e.forward_ubatch(&sa, &pa[0..3], Phase::Prefill, false, &mut NativeExec);
    e.forward_ubatch(&sb, &pb[0..2], Phase::Prefill, false, &mut NativeExec);
    let mut la = e
        .forward_ubatch(&sa, &pa[3..6], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut lb = e
        .forward_ubatch(&sb, &pb[2..4], Phase::Prefill, true, &mut NativeExec)
        .unwrap();
    let mut ta = Vec::new();
    let mut tb = Vec::new();
    for _ in 0..6 {
        let na = Sampler::greedy().sample(&la);
        ta.push(na);
        la = e.forward_session(&sa, na, Phase::Decode, true, &mut NativeExec).unwrap();
        let nb = Sampler::greedy().sample(&lb);
        tb.push(nb);
        lb = e.forward_session(&sb, nb, Phase::Decode, true, &mut NativeExec).unwrap();
    }

    for (prompt, got) in [(&pa, &ta), (&pb, &tb)] {
        let (_, want) = sequential_greedy(&w, prompt, 6);
        assert_eq!(&want, got, "interleaved session must match isolated engine");
    }
}

#[test]
fn serve_results_independent_of_worker_and_slot_topology() {
    // Per-request samplers are seeded by request id, and sessions are
    // isolated, so the served tokens must not depend on how many workers
    // or session slots the scheduler spreads the requests over.
    let w = weights(QuantScheme::Q8_0, 11);
    let requests: Vec<Request> = (0..6)
        .map(|id| Request {
            id,
            prompt: vec![1 + id as u32, 2, 3, 4, 5],
            n_out: 7,
        })
        .collect();
    let a = serve(&w, requests.clone(), 1, 42);
    let b = serve(&w, requests.clone(), 3, 42);
    let opts = ServeOptions {
        slots_per_worker: 1, // degenerates to the old FIFO worker pool
        sampler_seed: 42,
        ..ServeOptions::default()
    };
    let c = serve_with(&w, requests, 2, &opts).unwrap();
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "worker count must not change tokens");
    }
    for (x, y) in a.completions.iter().zip(&c.completions) {
        assert_eq!(x.tokens, y.tokens, "slot topology must not change tokens");
    }
}
