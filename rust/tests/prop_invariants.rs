//! Cross-module property tests (proptest-lite): invariants over random
//! shapes/values that individual unit tests don't cover.

use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::imax::{ImaxDevice, TransferMode};
use imax_llm::model::config::{ModelConfig, QuantScheme};
use imax_llm::quant::{dequantize_row, quantize_row, GgmlType};
use imax_llm::util::proptest_lite::Runner;
use imax_llm::util::rng::Rng;

#[test]
fn prop_quantize_dequantize_idempotent() {
    // dq(q(dq(q(x)))) == dq(q(x)) for every format: quantization is a
    // projection (idempotent after one round).
    Runner::new("quant-idempotent").cases(40).run_noshrink(
        |r: &mut Rng| {
            let fmt = match r.below(4) {
                0 => GgmlType::F16,
                1 => GgmlType::Q8_0,
                2 => GgmlType::Q6K,
                _ => GgmlType::Q3K,
            };
            let blocks = 1 + r.below(4);
            let n = blocks * fmt.block_size().max(32);
            let mut x = vec![0.0f32; n];
            for v in x.iter_mut() {
                *v = r.normal() * r.uniform(0.1, 4.0);
            }
            (fmt, x)
        },
        |(fmt, x)| {
            let once = dequantize_row(*fmt, &quantize_row(*fmt, x), x.len());
            let twice = dequantize_row(*fmt, &quantize_row(*fmt, &once), x.len());
            // The K-quants re-fit sub-block scales on requantization (the
            // dequantized data has different sub-maxima when values
            // saturated), so idempotence holds only up to one quantization
            // step — format-dependent. Q8_0/F16 are near-exact.
            let rms = (once.iter().map(|v| v * v).sum::<f32>() / once.len() as f32).sqrt();
            let step_frac = match fmt {
                GgmlType::F16 => 1e-3,
                GgmlType::Q8_0 => 2e-2,
                GgmlType::Q6K => 8e-2,
                GgmlType::Q3K => 4e-1,
                GgmlType::F32 => 0.0,
            };
            for (i, (a, b)) in once.iter().zip(&twice).enumerate() {
                let tol = step_frac * (a.abs() + rms).max(1e-3);
                if (a - b).abs() > tol {
                    return Err(format!("{}: elem {i}: {a} vs {b}", fmt.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantization_error_scales_linearly() {
    // Quantization is scale-equivariant: q(c·x) ≈ c·q(x).
    Runner::new("quant-scale-equivariant").cases(30).run_noshrink(
        |r: &mut Rng| {
            let mut x = vec![0.0f32; 256];
            for v in x.iter_mut() {
                *v = r.normal();
            }
            let c = r.uniform(0.5, 8.0);
            (x, c)
        },
        |(x, c)| {
            let base = dequantize_row(GgmlType::Q6K, &quantize_row(GgmlType::Q6K, x), x.len());
            let scaled_x: Vec<f32> = x.iter().map(|v| v * c).collect();
            let scaled =
                dequantize_row(GgmlType::Q6K, &quantize_row(GgmlType::Q6K, &scaled_x), x.len());
            let rms = (base.iter().map(|v| v * v).sum::<f32>() / base.len() as f32).sqrt();
            for (i, (b, s)) in base.iter().zip(&scaled).enumerate() {
                let want = b * c;
                // The f16 super-scale and integer sub-scales re-round under
                // scaling: equivariance holds to within one Q6_K step.
                if (s - want).abs() > 0.12 * (want.abs() + c * rms) {
                    return Err(format!("elem {i}: {s} vs {want} (c={c})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_breakdown_components_sum_to_total() {
    // PhaseCost accounting is strictly additive across random workloads.
    Runner::new("breakdown-additive").cases(12).run_noshrink(
        |r: &mut Rng| {
            let model = match r.below(2) {
                0 => ModelConfig::qwen3_0_6b(),
                _ => ModelConfig::qwen3_1_7b(),
            };
            let scheme = if r.below(2) == 0 {
                QuantScheme::Q8_0
            } else {
                QuantScheme::Q3KS
            };
            (model, scheme, 1 + r.below(16), 1 + r.below(8))
        },
        |(cfg, scheme, n_in, n_out)| {
            let w = Workload {
                cfg: cfg.clone(),
                scheme: *scheme,
                n_in: *n_in,
                n_out: *n_out,
            };
            let run = simulate_auto(&w, &ImaxDevice::fpga(2), TransferMode::Coalesced);
            let t = run.breakdown.total();
            let sum = t.exec + t.load + t.drain + t.conf + t.regv + t.range + t.host;
            if (sum - run.breakdown.e2e_seconds()).abs() > 1e-9 * sum.max(1.0) {
                return Err(format!("sum {sum} != e2e {}", run.breakdown.e2e_seconds()));
            }
            let pd = run.breakdown.prefill.total() + run.breakdown.decode.total();
            if (pd - sum).abs() > 1e-9 * sum.max(1.0) {
                return Err(format!("prefill+decode {pd} != total {sum}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_monotone_in_tokens() {
    // More input or output tokens never reduces modeled E2E latency.
    Runner::new("latency-monotone").cases(10).run_noshrink(
        |r: &mut Rng| (1 + r.below(24), 1 + r.below(12)),
        |&(n_in, n_out)| {
            let mk = |ni: usize, no: usize| {
                let w = Workload {
                    cfg: ModelConfig::qwen3_0_6b(),
                    scheme: QuantScheme::Q8_0,
                    n_in: ni,
                    n_out: no,
                };
                simulate_auto(&w, &ImaxDevice::fpga(2), TransferMode::Coalesced)
                    .breakdown
                    .e2e_seconds()
            };
            let base = mk(n_in, n_out);
            if mk(n_in + 4, n_out) < base {
                return Err(format!("longer prompt got faster at [{n_in}:{n_out}]"));
            }
            if mk(n_in, n_out + 2) < base {
                return Err(format!("more outputs got faster at [{n_in}:{n_out}]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_offload_ratio_bounded_and_stable() {
    // Offload ratios are in [0,1] and total is a convex combination of
    // the per-class ratios.
    Runner::new("offload-ratio-bounds").cases(10).run_noshrink(
        |r: &mut Rng| {
            let model = match r.below(3) {
                0 => ModelConfig::qwen3_0_6b(),
                1 => ModelConfig::qwen3_1_7b(),
                _ => ModelConfig::qwen3_8b(),
            };
            let scheme = if r.below(2) == 0 {
                QuantScheme::Q8_0
            } else {
                QuantScheme::Q3KS
            };
            (model, scheme)
        },
        |(cfg, scheme)| {
            let w = Workload {
                cfg: cfg.clone(),
                scheme: *scheme,
                n_in: 8,
                n_out: 4,
            };
            let run = simulate_auto(&w, &ImaxDevice::asic28(2), TransferMode::Coalesced);
            let total = run.stats.total_ratio();
            if !(0.0..=1.0).contains(&total) {
                return Err(format!("total ratio {total}"));
            }
            use imax_llm::imax::KernelClass;
            let mut lo = 1.0f64;
            let mut hi = 0.0f64;
            let mut any = false;
            for c in KernelClass::ALL {
                if let Some(rr) = run.stats.ratio(c) {
                    if !(0.0..=1.0).contains(&rr) {
                        return Err(format!("{} ratio {rr}", c.name()));
                    }
                    lo = lo.min(rr);
                    hi = hi.max(rr);
                    any = true;
                }
            }
            if any && !(lo - 1e-9..=hi + 1e-9).contains(&total) {
                return Err(format!("total {total} outside [{lo}, {hi}]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_deterministic_under_seed() {
    // Full engine determinism across random prompts and schemes.
    Runner::new("engine-deterministic").cases(6).run_noshrink(
        |r: &mut Rng| {
            let scheme = match r.below(3) {
                0 => QuantScheme::F16,
                1 => QuantScheme::Q8_0,
                _ => QuantScheme::Q3KS,
            };
            let len = 1 + r.below(6);
            let prompt: Vec<u32> = (0..len).map(|_| r.below(2048) as u32).collect();
            (scheme, prompt, r.next_u64())
        },
        |(scheme, prompt, seed)| {
            use imax_llm::model::engine::{Engine, NativeExec};
            use imax_llm::model::sampler::Sampler;
            use imax_llm::model::weights::ModelWeights;
            let cfg = ModelConfig::tiny();
            let run = |s: u64| {
                let mut e = Engine::new(ModelWeights::random(&cfg, *scheme, s));
                e.generate(prompt, 4, &mut Sampler::top_k(0.8, 20, 3), &mut NativeExec)
                    .tokens
            };
            if run(*seed) != run(*seed) {
                return Err("nondeterministic generation".to_string());
            }
            Ok(())
        },
    );
}
