//! Scheduler stress suite: randomized request arrivals and lengths
//! through [`ContinuousBatcher`] under a *tight* page budget. The
//! page-budget admission contract under test:
//!
//! * every feasible request eventually completes (deferral never wedges),
//! * no pages leak after drain (free list back to the full pool, zero
//!   committed budget),
//! * a request whose worst case exceeds the whole pool is rejected with
//!   a typed error instead of blocking admission forever,
//! * and the acceptance criterion of the paging work: under the same
//!   memory budget, page-gated admission runs strictly more concurrent
//!   short-prompt sequences than the fixed-stride slot-count limit.
//!
//! The churn tests additionally run the cross-subsystem invariant
//! auditor (`imax_llm::analysis::audit` — the `serve --audit` checks)
//! at **every** round boundary: refcount conservation, free-list
//! consistency, CoW alias validity, budget conservation, and
//! prefix-chain hash integrity must hold mid-churn, not just after
//! drain.

use std::collections::VecDeque;
use std::time::Instant;

use imax_llm::analysis;
use imax_llm::coordinator::{
    AdmitError, Admitted, CancelHandle, ContinuousBatcher, FinishReason, Request, SessionLog,
};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::{DrafterSpec, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::util::rng::Rng;
use imax_llm::util::stats::percentile;

fn tiny_weights(seed: u64) -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, seed)
}

/// The `serve --audit` invariant check, applied between rounds: the
/// page pool and the batcher's budget view must agree at every round
/// boundary, whatever the churn just tore down.
fn assert_audit_clean(b: &ContinuousBatcher, round: usize) {
    let findings = analysis::audit(b.engine(), b);
    assert!(
        findings.is_empty(),
        "invariant audit failed at round {round}: {findings:?}"
    );
}

#[test]
fn randomized_arrivals_complete_under_tight_page_budget() {
    let mut rng = Rng::new(0xBADC0FFE);
    // 3 slots sharing 10 pages of 4 tokens = 40 cached tokens; worst-case
    // requests below need up to 5 pages, so admission constantly defers.
    let engine = Engine::with_paged_slots(tiny_weights(11), 3, 4, Some(10));
    let total_pages = engine.total_pages();
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;

    let n_req = 24usize;
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            let prompt = (0..1 + rng.below(10))
                .map(|i| 1 + ((id * 31 + i * 7) % 100) as u32)
                .collect();
            Request::new(id, prompt, rng.below(9))
        })
        .collect();
    let expected_n_out: Vec<usize> = requests.iter().map(|r| r.n_out).collect();
    let mut queue: VecDeque<Request> = requests.into_iter().collect();

    let mut done = Vec::new();
    let mut rounds = 0usize;
    while !queue.is_empty() || b.n_active() > 0 {
        rounds += 1;
        assert!(
            rounds < 10_000,
            "scheduler wedged: {} done, {} queued, {} active",
            done.len(),
            queue.len(),
            b.n_active()
        );
        // Admit in arrival order until the budget defers.
        while let Some(req) = queue.pop_front() {
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {}
                Ok(Admitted::Finished(log)) => done.push(log),
                Ok(Admitted::Deferred(req)) => {
                    assert!(b.n_active() > 0, "deferred on an idle engine");
                    queue.push_front(req);
                    break;
                }
                Err(e) => panic!("no request here is oversized, got: {e}"),
            }
        }
        // The committed budget never oversubscribes the pool.
        assert!(b.committed_pages() <= total_pages);
        done.extend(b.decode_round(&mut exec));
        assert_audit_clean(&b, rounds);
    }

    let mut ids: Vec<usize> = done.iter().map(|l| l.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n_req).collect::<Vec<_>>(), "each request exactly once");
    for log in &done {
        assert_eq!(log.tokens.len(), expected_n_out[log.id], "request {}", log.id);
    }
    // No leaks after drain.
    assert_eq!(b.engine().free_pages(), total_pages, "all pages back in the pool");
    assert_eq!(b.committed_pages(), 0);
    assert_eq!(b.capacity(), 3, "all slots free");
}

#[test]
fn oversized_request_rejected_instead_of_wedging() {
    // Pool: 5 pages × 4 tokens = 20 cached tokens.
    let engine = Engine::with_paged_slots(tiny_weights(5), 2, 4, Some(5));
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;
    // Worst case 15 + 10 − 1 = 24 tokens → 6 pages > 5-page pool.
    let big = Request::new(0, vec![1; 15], 10);
    match b.admit(big, Sampler::greedy(), 0.0, &mut exec) {
        Err(AdmitError::TooLarge { need_pages, pool_pages, .. }) => {
            assert_eq!(need_pages, 6);
            assert_eq!(pool_pages, 5);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }
    // Admission continues: a feasible request admits and completes.
    let ok = Request::new(1, vec![2, 3, 4], 4);
    assert!(matches!(
        b.admit(ok, Sampler::greedy(), 0.0, &mut exec),
        Ok(Admitted::Active)
    ));
    let logs = b.drain(&mut exec);
    assert_eq!(logs.len(), 1);
    assert_eq!(logs[0].tokens.len(), 4);
    assert_eq!(b.engine().free_pages(), 5, "rejection leaked nothing");
}

#[test]
fn page_budget_admits_more_short_sequences_than_fixed_stride() {
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 9);
    // Memory budget: 2 × max_seq tokens of KV. Fixed-stride slots reserve
    // max_seq per sequence, so that budget caps out at 2 concurrent
    // sequences no matter how short they are.
    let budget_tokens = 2 * cfg.max_seq_len;
    let fixed_stride_limit = budget_tokens / cfg.max_seq_len;
    assert_eq!(fixed_stride_limit, 2);
    // The identical budget as a shared pool of 16-token pages.
    let page_size = 16;
    let engine =
        Engine::with_paged_slots(weights, 8, page_size, Some(budget_tokens / page_size));
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;
    for id in 0..8usize {
        // Worst case 4 + 4 − 1 = 7 tokens → one page each.
        let req = Request::new(id, vec![1 + id as u32, 2, 3, 4], 4);
        assert!(
            matches!(b.admit(req, Sampler::greedy(), 0.0, &mut exec), Ok(Admitted::Active)),
            "request {id} must be admitted concurrently"
        );
    }
    assert!(
        b.n_active() > fixed_stride_limit,
        "paged admission ({} live) must beat the fixed-stride limit ({})",
        b.n_active(),
        fixed_stride_limit
    );
    assert_eq!(b.n_active(), 8, "every short sequence decodes concurrently");
    let logs = b.drain(&mut exec);
    assert_eq!(logs.len(), 8);
    for log in &logs {
        assert_eq!(log.tokens.len(), 4);
    }
}

#[test]
fn token_budget_bounds_decode_delay_under_long_prompt_arrival() {
    // Chunked-prefill fairness. Two short requests are decoding when a
    // long prompt arrives mid-serve. Phase-segregated, its whole prefill
    // runs at admission, stalling every live decode for the full prompt;
    // token-budgeted, it streams in as bounded chunks that ride along
    // the decode rounds. The property under test: no decode round is
    // delayed by more than one chunk's tokens while the long prompt
    // streams in — and the measured worst-case / p99 decode gap (TBT)
    // is strictly lower than the segregated path's, with all generated
    // tokens bit-identical. The long prompt is big enough (192 tokens,
    // O(n²) attention) that the segregated stall dwarfs any plausible
    // OS-scheduling noise in the budgeted rounds, keeping the wall-clock
    // comparison robust on loaded CI runners.
    const LONG: usize = 192;
    const CHUNK: usize = 4;
    let run = |budget: Option<usize>| {
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(21), 4),
            32,
            Instant::now(),
        );
        if let Some(n) = budget {
            b = b.with_token_budget(n).with_prefill_chunk(CHUNK);
        }
        let mut exec = NativeExec;
        for id in 0..2usize {
            let req = Request::new(id, vec![1 + id as u32, 2, 3, 4], 8);
            assert!(matches!(
                b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
        }
        for _ in 0..3 {
            assert!(b.decode_round(&mut exec).is_empty(), "shorts still decoding");
        }
        let long = Request::new(2, (0..LONG).map(|i| 1 + (i % 100) as u32).collect(), 2);
        assert!(matches!(
            b.admit(long, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        let rounds: Vec<_> = b.rounds().to_vec();
        (logs, rounds)
    };
    let (seg_logs, _) = run(None);
    let (bud_logs, bud_rounds) = run(Some(8));

    // Scheduling must never change tokens.
    assert_eq!(seg_logs.len(), 3);
    assert_eq!(bud_logs.len(), 3);
    for (a, b) in seg_logs.iter().zip(&bud_logs) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "token budget must not change tokens");
    }

    // The fairness bound: no round that carried live decodes delayed
    // them by more than one chunk of the streaming prompt (rounds with
    // no decodes may batch several admitted prompts' chunks up to the
    // budget — nothing waits on those).
    for r in bud_rounds.iter().filter(|r| r.decode_tokens > 0) {
        assert!(
            r.prefill_tokens <= CHUNK,
            "round delayed decodes by more than one chunk: {r:?}"
        );
    }
    assert!(
        bud_rounds.iter().any(|r| r.decode_tokens >= 2 && r.prefill_tokens > 0),
        "the long prompt must stream while both shorts decode: {bud_rounds:?}"
    );
    let streamed: usize = bud_rounds.iter().map(|r| r.prefill_tokens).sum();
    assert_eq!(streamed, 8 + LONG, "every prompt token streamed through rounds");

    // Worst-case and p99 decode gap over the short requests, measured
    // from their per-token emission marks: strictly lower under the
    // token budget (segregated inserts the whole 192-token prefill
    // between two of their tokens; budgeted at most one 4-token chunk).
    let gaps = |logs: &[SessionLog]| -> Vec<f64> {
        logs.iter()
            .filter(|l| l.id < 2)
            .flat_map(|l| l.tbt_gaps_s())
            .collect()
    };
    let (seg_gaps, bud_gaps) = (gaps(&seg_logs), gaps(&bud_logs));
    assert!(!seg_gaps.is_empty() && !bud_gaps.is_empty());
    let max = |xs: &[f64]| xs.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        max(&bud_gaps) < max(&seg_gaps),
        "worst-case decode gap must drop: budgeted {} vs segregated {}",
        max(&bud_gaps),
        max(&seg_gaps)
    );
    assert!(
        percentile(&bud_gaps, 99.0) < percentile(&seg_gaps, 99.0),
        "p99 TBT must drop: budgeted {} vs segregated {}",
        percentile(&bud_gaps, 99.0),
        percentile(&seg_gaps, 99.0)
    );
}

#[test]
fn templated_stress_with_prefix_sharing_and_swap_completes_cleanly() {
    let mut rng = Rng::new(0x5EED_CAFE);
    // Tight pool: 3 slots sharing 8 pages of 4 tokens, with prefix
    // sharing on and a 6-page host swap arena — the oversubscribed
    // serving shape. Every request must still complete, the budget must
    // never oversubscribe the pool, and nothing may leak.
    let mut engine = Engine::with_paged_slots(tiny_weights(3), 3, 4, Some(8));
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(6);
    let total_pages = engine.total_pages();
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;

    // Templated workload: three two-page prompt templates with short
    // random unique suffixes (the prefix cache's target shape).
    let n_req = 30usize;
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            let tpl = id % 3;
            let mut prompt: Vec<u32> = (0..8).map(|i| (100 * (tpl + 1) + i) as u32).collect();
            prompt.extend((0..rng.below(4)).map(|i| 1 + ((id * 13 + i * 5) % 50) as u32));
            Request::new(id, prompt, 1 + rng.below(6))
        })
        .collect();
    let expected_n_out: Vec<usize> = requests.iter().map(|r| r.n_out).collect();
    let mut queue: VecDeque<Request> = requests.into_iter().collect();

    let mut done = Vec::new();
    let mut rounds = 0usize;
    while !queue.is_empty() || b.n_active() > 0 {
        rounds += 1;
        assert!(
            rounds < 10_000,
            "scheduler wedged: {} done, {} queued, {} active",
            done.len(),
            queue.len(),
            b.n_active()
        );
        while let Some(req) = queue.pop_front() {
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {}
                Ok(Admitted::Finished(log)) => done.push(log),
                Ok(Admitted::Deferred(req)) => {
                    assert!(b.n_active() > 0, "deferred on an idle engine");
                    queue.push_front(req);
                    break;
                }
                Err(e) => panic!("no request here is oversized, got: {e}"),
            }
        }
        assert!(
            b.committed_pages() <= total_pages,
            "commitment {} oversubscribes the {total_pages}-page pool",
            b.committed_pages()
        );
        done.extend(b.decode_round(&mut exec));
        assert_audit_clean(&b, rounds);
    }

    let mut ids: Vec<usize> = done.iter().map(|l| l.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n_req).collect::<Vec<_>>(), "each request exactly once");
    for log in &done {
        assert_eq!(log.tokens.len(), expected_n_out[log.id], "request {}", log.id);
    }
    // No leaks after drain: every page is free or a resident cached
    // prefix page; commitment and slots fully released.
    assert_eq!(b.committed_pages(), 0);
    assert_eq!(b.capacity(), 3, "all slots free");
    let cache = &b.engine().cache;
    assert_eq!(
        cache.free_page_count() + cache.cached_resident_pages(),
        total_pages,
        "pages are either free or cached — none leaked"
    );
    let s = b.reuse_stats();
    assert!(s.prefix_hits > 0, "templated workload must share prefixes: {s:?}");
    assert!(s.prefix_hit_tokens >= 4 * s.prefix_hits, "every hit spans ≥1 page: {s:?}");
}

/// What each request in the cancellation churn expects of its log.
#[derive(Clone, Copy, PartialEq)]
enum Role {
    /// Runs to completion: `Completed` with exactly `n_out` tokens.
    Plain,
    /// Carries a [`CancelHandle`] fired 1–3 rounds after admission;
    /// `n_out ≥ 4` guarantees the cancel lands mid-decode, so the log
    /// must be `Cancelled` with a non-empty, short token stream.
    Cancel,
    /// Carries a zero-second deadline: expired by the first reap,
    /// before any token decodes.
    Deadline,
}

#[test]
fn randomized_cancels_and_deadlines_leak_nothing_under_tight_pool() {
    let mut rng = Rng::new(0xCA9CE1);
    // The oversubscribed serving shape of the templated stress test —
    // 3 slots on 8 pages of 4 tokens, prefix sharing + host swap on —
    // now with a third of the requests torn down mid-flight. Teardown
    // must free exactly the non-shared pages (pool conservation below),
    // keep registered prefix pages adoptable, and hand the freed budget
    // to the queue so nothing wedges.
    let mut engine = Engine::with_paged_slots(tiny_weights(13), 3, 4, Some(8));
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(6);
    let total_pages = engine.total_pages();
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;

    let n_req = 30usize;
    let mut roles = Vec::with_capacity(n_req);
    let mut handles: Vec<Option<CancelHandle>> = Vec::with_capacity(n_req);
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            let tpl = id % 3;
            let mut prompt: Vec<u32> = (0..8).map(|i| (100 * (tpl + 1) + i) as u32).collect();
            prompt.extend((0..rng.below(4)).map(|i| 1 + ((id * 13 + i * 5) % 50) as u32));
            let (role, req) = if id % 5 == 4 {
                (Role::Deadline, Request::new(id, prompt, 1 + rng.below(6)).with_deadline_s(0.0))
            } else if rng.next_f64() < 0.4 {
                let h = CancelHandle::new();
                let req = Request::new(id, prompt, 4 + rng.below(4)).with_cancel(h.clone());
                handles.push(Some(h));
                roles.push(Role::Cancel);
                return req;
            } else {
                (Role::Plain, Request::new(id, prompt, 1 + rng.below(6)))
            };
            handles.push(None);
            roles.push(role);
            req
        })
        .collect();
    let expected_n_out: Vec<usize> = requests.iter().map(|r| r.n_out).collect();
    assert!(roles.iter().any(|&r| r == Role::Cancel), "seed must produce cancels");
    let mut queue: VecDeque<Request> = requests.into_iter().collect();

    let mut done = Vec::new();
    let mut pending_cancels: Vec<(usize, usize)> = Vec::new(); // (fire_round, id)
    let mut rounds = 0usize;
    while !queue.is_empty() || b.n_active() > 0 {
        rounds += 1;
        assert!(
            rounds < 10_000,
            "scheduler wedged: {} done, {} queued, {} active",
            done.len(),
            queue.len(),
            b.n_active()
        );
        // Fire the cancels that have come due — mid-decode, between
        // rounds, exactly how a serve-loop consumer drops a stream.
        pending_cancels.retain(|&(fire, id)| {
            if fire <= rounds {
                handles[id].as_ref().unwrap().cancel();
                false
            } else {
                true
            }
        });
        while let Some(req) = queue.pop_front() {
            let id = req.id;
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {
                    if handles[id].is_some() {
                        pending_cancels.push((rounds + 1 + rng.below(3), id));
                    }
                }
                Ok(Admitted::Finished(log)) => done.push(log),
                Ok(Admitted::Deferred(req)) => {
                    assert!(b.n_active() > 0, "deferred on an idle engine");
                    queue.push_front(req);
                    break;
                }
                Err(e) => panic!("no request here is oversized, got: {e}"),
            }
        }
        assert!(
            b.committed_pages() <= total_pages,
            "commitment {} oversubscribes the {total_pages}-page pool",
            b.committed_pages()
        );
        done.extend(b.decode_round(&mut exec));
        assert_audit_clean(&b, rounds);
    }

    let mut ids: Vec<usize> = done.iter().map(|l| l.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n_req).collect::<Vec<_>>(), "each request exactly once");
    for log in &done {
        match roles[log.id] {
            Role::Plain => {
                assert_eq!(log.reason, FinishReason::Completed, "request {}", log.id);
                assert_eq!(log.tokens.len(), expected_n_out[log.id], "request {}", log.id);
            }
            Role::Cancel => {
                assert_eq!(log.reason, FinishReason::Cancelled, "request {}", log.id);
                assert!(
                    !log.tokens.is_empty() && log.tokens.len() < expected_n_out[log.id],
                    "mid-decode cancel kept {} of {} tokens (request {})",
                    log.tokens.len(),
                    expected_n_out[log.id],
                    log.id
                );
            }
            Role::Deadline => {
                assert_eq!(log.reason, FinishReason::DeadlineExpired, "request {}", log.id);
                assert!(log.tokens.is_empty(), "request {} expired before decode", log.id);
            }
        }
    }
    // Pool conservation across every teardown path: each page is free
    // or a resident cached prefix page; budget and slots fully released.
    assert_eq!(b.committed_pages(), 0);
    assert_eq!(b.capacity(), 3, "all slots free");
    let cache = &b.engine().cache;
    assert_eq!(
        cache.free_page_count() + cache.cached_resident_pages(),
        total_pages,
        "pages are either free or cached — none leaked"
    );
    let s = b.reuse_stats();
    assert!(s.prefix_hits > 0, "templated workload must share prefixes: {s:?}");

    // Prefix entries that survived the churn stay adoptable: a fresh
    // template request completes, and if its template is still indexed
    // the adoption counter moves.
    let tpl_prompt: Vec<u32> = (0..8).map(|i| (100 + i) as u32).collect();
    let (cached_tokens, resident, swapped) = b.engine().peek_prefix(&tpl_prompt);
    let hits_before = b.reuse_stats().prefix_hits;
    let req = Request::new(n_req, tpl_prompt, 2);
    assert!(matches!(
        b.admit(req, Sampler::greedy(), 0.0, &mut exec),
        Ok(Admitted::Active)
    ));
    let logs = b.drain(&mut exec);
    assert_eq!(logs.len(), 1);
    assert_eq!(logs[0].tokens.len(), 2);
    if cached_tokens > 0 && resident + swapped > 0 {
        assert!(
            b.reuse_stats().prefix_hits > hits_before,
            "surviving prefix entry must still adopt after cancellation churn"
        );
    }
}

#[test]
fn mid_decode_cancel_frees_budget_for_the_next_round() {
    // Pool: 4 pages × 4 tokens. Each request's worst case is
    // 8 + 4 − 1 = 11 tokens → 3 pages, so the second must defer while
    // the first holds its commitment.
    let engine = Engine::with_paged_slots(tiny_weights(7), 2, 4, Some(4));
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;
    let handle = CancelHandle::new();
    let r0 = Request::new(0, (1u32..=8).collect(), 4).with_cancel(handle.clone());
    assert!(matches!(
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec),
        Ok(Admitted::Active)
    ));
    let r1 = Request::new(1, (11u32..=18).collect(), 4);
    let r1 = match b.admit(r1, Sampler::greedy(), 0.0, &mut exec) {
        Ok(Admitted::Deferred(r)) => r,
        other => panic!("expected deferral under a full pool, got {other:?}"),
    };
    // One decode round in, then the consumer walks away.
    assert!(b.decode_round(&mut exec).is_empty(), "4-token request still decoding");
    handle.cancel();
    // The next round reaps the cancelled flight before decoding, so the
    // freed pages are spendable budget the moment it returns.
    let logs = b.decode_round(&mut exec);
    assert_eq!(logs.len(), 1);
    assert_eq!(logs[0].reason, FinishReason::Cancelled);
    assert_eq!(logs[0].tokens.len(), 1, "the delivered token survives the cancel");
    assert!(matches!(
        b.admit(r1, Sampler::greedy(), 0.0, &mut exec),
        Ok(Admitted::Active)
    ));
    let logs = b.drain(&mut exec);
    assert_eq!(logs.len(), 1);
    assert_eq!(logs[0].reason, FinishReason::Completed);
    assert_eq!(logs[0].tokens.len(), 4);
    assert_eq!(b.engine().free_pages(), 4, "nothing leaked");
    assert_eq!(b.committed_pages(), 0);
}

#[test]
fn speculative_churn_audits_clean_every_round() {
    let mut rng = Rng::new(0xD00_DAD5);
    // Speculative decoding over the oversubscribed shape: prefix sharing
    // + host swap + a prompt-lookup drafter proposing 3 tokens per
    // sequence per round, with a slice of the flights cancelled or
    // expiring mid-decode. Draft rollback returns rejected KV entries
    // through the paged pool, so every round boundary must still pass
    // the full invariant audit — this is the `serve --audit` contract
    // under the nastiest combination of features.
    let mut engine = Engine::with_paged_slots(tiny_weights(17), 3, 4, Some(10));
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(6);
    let total_pages = engine.total_pages();
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now())
        .with_speculation(3, DrafterSpec::default());
    let mut exec = NativeExec;

    let n_req = 24usize;
    let mut handles: Vec<Option<CancelHandle>> = Vec::with_capacity(n_req);
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            let tpl = id % 3;
            // Two shared template pages plus a repetitive body: the
            // repetition gives the n-gram drafter real matches, so
            // accepted *and* rejected drafts both occur.
            let mut prompt: Vec<u32> = (0..8).map(|i| (100 * (tpl + 1) + i) as u32).collect();
            prompt.extend((0..8).map(|i| (100 * (tpl + 1) + (i % 4)) as u32));
            let req = if id % 7 == 6 {
                handles.push(None);
                Request::new(id, prompt, 1 + rng.below(5)).with_deadline_s(0.0)
            } else if rng.next_f64() < 0.3 {
                let h = CancelHandle::new();
                handles.push(Some(h.clone()));
                Request::new(id, prompt, 4 + rng.below(4)).with_cancel(h)
            } else {
                handles.push(None);
                Request::new(id, prompt, 1 + rng.below(5))
            };
            req
        })
        .collect();
    let expected_n_out: Vec<usize> = requests.iter().map(|r| r.n_out).collect();
    let mut queue: VecDeque<Request> = requests.into_iter().collect();

    let mut done = Vec::new();
    let mut pending_cancels: Vec<(usize, usize)> = Vec::new(); // (fire_round, id)
    let mut rounds = 0usize;
    while !queue.is_empty() || b.n_active() > 0 {
        rounds += 1;
        assert!(
            rounds < 10_000,
            "scheduler wedged: {} done, {} queued, {} active",
            done.len(),
            queue.len(),
            b.n_active()
        );
        pending_cancels.retain(|&(fire, id)| {
            if fire <= rounds {
                handles[id].as_ref().unwrap().cancel();
                false
            } else {
                true
            }
        });
        while let Some(req) = queue.pop_front() {
            let id = req.id;
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {
                    if handles[id].is_some() {
                        pending_cancels.push((rounds + 1 + rng.below(3), id));
                    }
                }
                Ok(Admitted::Finished(log)) => done.push(log),
                Ok(Admitted::Deferred(req)) => {
                    assert!(b.n_active() > 0, "deferred on an idle engine");
                    queue.push_front(req);
                    break;
                }
                Err(e) => panic!("no request here is oversized, got: {e}"),
            }
        }
        assert!(
            b.committed_pages() <= total_pages,
            "commitment {} oversubscribes the {total_pages}-page pool",
            b.committed_pages()
        );
        done.extend(b.decode_round(&mut exec));
        assert_audit_clean(&b, rounds);
    }

    let mut ids: Vec<usize> = done.iter().map(|l| l.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n_req).collect::<Vec<_>>(), "each request exactly once");
    for log in &done {
        // Cancels race speculation (an accepted run may complete the
        // request before its cancel fires), so assert consistency of
        // outcome rather than a fixed per-id reason.
        match log.reason {
            FinishReason::Completed => {
                assert_eq!(log.tokens.len(), expected_n_out[log.id], "request {}", log.id);
            }
            FinishReason::Cancelled => {
                assert!(
                    log.tokens.len() < expected_n_out[log.id],
                    "cancelled request {} kept a full token stream",
                    log.id
                );
            }
            FinishReason::DeadlineExpired => {
                assert!(log.tokens.is_empty(), "request {} expired before decode", log.id);
            }
        }
    }
    assert!(
        done.iter().any(|l| l.verify_calls > 0),
        "repetitive prompts must draft at least once"
    );
    // Pool conservation after drain, then one final audit over the
    // quiesced pair.
    assert_eq!(b.committed_pages(), 0);
    assert_eq!(b.capacity(), 3, "all slots free");
    let cache = &b.engine().cache;
    assert_eq!(
        cache.free_page_count() + cache.cached_resident_pages(),
        total_pages,
        "pages are either free or cached — none leaked"
    );
    assert_audit_clean(&b, rounds + 1);
}
