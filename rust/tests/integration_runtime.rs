//! Integration tests for the PJRT runtime: the AOT-compiled JAX/Pallas
//! artifacts must agree numerically with the native Rust kernels on the
//! same packed operands — the three-layer composition proof.
//!
//! Requires `make artifacts` (skipped with a message otherwise) and the
//! `pjrt` cargo feature (the whole suite is compiled out without it —
//! the default build carries no xla dependency).
#![cfg(feature = "pjrt")]

use imax_llm::model::config::{ModelConfig, QuantScheme};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::graph::Phase;
use imax_llm::model::sampler::Sampler;
use imax_llm::model::weights::ModelWeights;
use imax_llm::quant::{q3_k, q6_k, q8_0, q8_k};
use imax_llm::runtime::backend::{split_q8_blocks, PjrtExec};
use imax_llm::runtime::pjrt::{lit, PjrtRuntime};
use imax_llm::runtime::ArtifactDir;
use imax_llm::util::f16::F16;
use imax_llm::util::rng::Rng;

fn runtime_or_skip() -> Option<PjrtRuntime> {
    match PjrtRuntime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts/PJRT unavailable): {e:#}");
            None
        }
    }
}

fn gauss(rng: &mut Rng, n: usize, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, sigma);
    v
}

#[test]
fn q8_dot_artifact_matches_rust_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(101);
    for (n, k) in [(256usize, 256usize), (128, 256), (768, 256), (2048, 256), (256, 768)] {
        let name = ArtifactDir::q8_dot_name(n, k);
        let w = gauss(&mut rng, n * k, 0.5);
        let a = gauss(&mut rng, k, 1.0);
        let wq = q8_0::quantize_row(&w);
        let aq = q8_0::quantize_row(&a);

        // Native Rust result.
        let want: Vec<f32> = (0..n)
            .map(|r| q8_0::vec_dot(&wq[r * (k / 32)..(r + 1) * (k / 32)], &aq))
            .collect();

        // PJRT result on the same packed data.
        let (wqs, wds) = split_q8_blocks(&wq);
        let (aqs, ads) = split_q8_blocks(&aq);
        let got = rt
            .execute_vec1_f32(
                &name,
                &[
                    lit::i8(&[n, k], &wqs).unwrap(),
                    lit::f32(&[n, k / 32], &wds).unwrap(),
                    lit::i8(&[k], &aqs).unwrap(),
                    lit::f32(&[k / 32], &ads).unwrap(),
                ],
            )
            .unwrap();

        assert_eq!(got.len(), n, "{name}");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                "{name} row {i}: pjrt {g} vs rust {w}"
            );
        }
    }
}

#[test]
fn fp16_dot_artifact_matches_rust_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(102);
    let (n, k) = (256usize, 256usize);
    let w = gauss(&mut rng, n * k, 0.5);
    let a = gauss(&mut rng, k, 1.0);
    let wh: Vec<F16> = w.iter().map(|&v| F16::from_f32(v)).collect();
    let want: Vec<f32> = (0..n)
        .map(|r| imax_llm::quant::fp16::vec_dot_f16(&wh[r * k..(r + 1) * k], &a))
        .collect();
    let got = rt
        .execute_vec1_f32(
            "fp16_dot_256x256",
            &[lit::f16(&[n, k], &wh).unwrap(), lit::f32(&[k], &a).unwrap()],
        )
        .unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 2e-3 * w.abs().max(1.0), "row {i}: {g} vs {w}");
    }
}

/// Split Q6_K blocks into the kernel's operand arrays.
fn split_q6(blocks: &[q6_k::BlockQ6K]) -> (Vec<u8>, Vec<u8>, Vec<i8>, Vec<f32>) {
    let mut ql = Vec::new();
    let mut qh = Vec::new();
    let mut sc = Vec::new();
    let mut d = Vec::new();
    for b in blocks {
        ql.extend_from_slice(&b.ql);
        qh.extend_from_slice(&b.qh);
        sc.extend_from_slice(&b.scales);
        d.push(b.d.to_f32());
    }
    (ql, qh, sc, d)
}

/// Split Q8_K activation blocks into (qs, d).
fn split_q8k(blocks: &[q8_k::BlockQ8K]) -> (Vec<i8>, Vec<f32>) {
    let mut qs = Vec::new();
    let mut d = Vec::new();
    for b in blocks {
        qs.extend_from_slice(&b.qs);
        d.push(b.d);
    }
    (qs, d)
}

#[test]
fn q6_k_dot_artifact_matches_rust_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(103);
    let (n, k) = (256usize, 256usize);
    let w = gauss(&mut rng, n * k, 0.7);
    let a = gauss(&mut rng, k, 1.0);
    let wq = q6_k::quantize_row(&w);
    let aq = q8_k::quantize_row(&a);
    let want: Vec<f32> = (0..n)
        .map(|r| q6_k::vec_dot(&wq[r..r + 1], &aq))
        .collect();
    let (ql, qh, sc, d) = split_q6(&wq);
    let (aqs, ads) = split_q8k(&aq);
    let got = rt
        .execute_vec1_f32(
            "q6_k_dot_256x256",
            &[
                lit::u8(&[n, k / 2], &ql).unwrap(),
                lit::u8(&[n, k / 4], &qh).unwrap(),
                lit::i8(&[n, k / 16], &sc).unwrap(),
                lit::f32(&[n, k / 256], &d).unwrap(),
                lit::i8(&[k], &aqs).unwrap(),
                lit::f32(&[k / 256], &ads).unwrap(),
            ],
        )
        .unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "row {i}: {g} vs {w}");
    }
}

#[test]
fn q3_k_dot_artifact_matches_rust_cvt53_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(104);
    let (n, k) = (256usize, 256usize);
    let w = gauss(&mut rng, n * k, 0.7);
    let a = gauss(&mut rng, k, 1.0);
    let wq = q3_k::quantize_row(&w);
    let aq = q8_k::quantize_row(&a);
    // The artifact was lowered with cvt53=True (the paper's deployed
    // configuration) — compare against the Rust CVT53 kernel.
    let want: Vec<f32> = (0..n)
        .map(|r| q3_k::vec_dot_cvt53(&wq[r..r + 1], &aq))
        .collect();
    let mut qs = Vec::new();
    let mut hm = Vec::new();
    let mut sc = Vec::new();
    let mut d = Vec::new();
    for b in &wq {
        qs.extend_from_slice(&b.qs);
        hm.extend_from_slice(&b.hmask);
        // The kernel takes the *unpacked* 6-bit scale codes.
        sc.extend_from_slice(&q3_k::unpack_scales(&b.scales));
        d.push(b.d.to_f32());
    }
    let (aqs, ads) = split_q8k(&aq);
    let got = rt
        .execute_vec1_f32(
            "q3_k_dot_256x256",
            &[
                lit::u8(&[n, k / 4], &qs).unwrap(),
                lit::u8(&[n, k / 8], &hm).unwrap(),
                lit::i8(&[n, k / 16], &sc).unwrap(),
                lit::f32(&[n, k / 256], &d).unwrap(),
                lit::i8(&[k], &aqs).unwrap(),
                lit::f32(&[k / 256], &ads).unwrap(),
            ],
        )
        .unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "row {i}: {g} vs {w}");
    }
}

#[test]
fn lm_head_artifact_matches_engine_head() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(105);
    let cfg = ModelConfig::tiny();
    let x = gauss(&mut rng, cfg.d_model, 1.0);
    let final_norm = vec![1.0f32; cfg.d_model];
    let head = gauss(&mut rng, cfg.vocab_size * cfg.d_model, 0.05);
    let head_q = q8_0::quantize_row(&head);

    // Native: rmsnorm + quantize + per-row dot.
    let mut xn = x.clone();
    imax_llm::model::ops::rmsnorm_inplace(&mut xn, &final_norm, cfg.rms_eps);
    let act = q8_0::quantize_row(&xn);
    let bpr = cfg.d_model / 32;
    let want: Vec<f32> = (0..cfg.vocab_size)
        .map(|r| q8_0::vec_dot(&head_q[r * bpr..(r + 1) * bpr], &act))
        .collect();

    let (hq, hd) = split_q8_blocks(&head_q);
    let got = rt
        .execute_vec1_f32(
            "lm_head_q8",
            &[
                lit::f32(&[cfg.d_model], &x).unwrap(),
                lit::f32(&[cfg.d_model], &final_norm).unwrap(),
                lit::i8(&[cfg.vocab_size, cfg.d_model], &hq).unwrap(),
                lit::f32(&[cfg.vocab_size, bpr], &hd).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(got.len(), cfg.vocab_size);
    // The JAX graph quantizes the normed activation in-graph with the
    // same rounding; tolerate only f32 association noise.
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst < 5e-3, "worst abs err {worst}");
    // argmax must agree (greedy decoding equivalence).
    let am = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(am(&got), am(&want));
}

#[test]
fn layer_fwd_artifact_matches_rust_layer() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::tiny();
    let ctx_prev = 7usize;
    let mut rng = Rng::new(106);

    // Build one layer's worth of Q8_0 weights + random state.
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 2024);
    let lw = &weights.layers[0];
    let x = gauss(&mut rng, cfg.d_model, 1.0);
    let k_cache = gauss(&mut rng, ctx_prev * cfg.kv_dim(), 1.0);
    let v_cache = gauss(&mut rng, ctx_prev * cfg.kv_dim(), 1.0);

    // ---- Rust reference: replicate engine layer semantics ----
    use imax_llm::model::ops;
    use imax_llm::tensor::{matvec, QTensor, TensorData};
    let q8 = |t: &QTensor| match &t.data {
        TensorData::Q8_0(b) => b.clone(),
        _ => panic!("expected q8"),
    };
    let pos = ctx_prev;
    let head_dim = cfg.head_dim;
    let groups = cfg.gqa_groups();

    let mut xn = vec![0.0f32; cfg.d_model];
    ops::rmsnorm(&x, &lw.attn_norm, cfg.rms_eps, &mut xn);
    let mut q = matvec(&lw.wq, &xn);
    let mut k = matvec(&lw.wk, &xn);
    let v = matvec(&lw.wv, &xn);
    for h in 0..cfg.n_heads {
        let qh = &mut q[h * head_dim..(h + 1) * head_dim];
        ops::rmsnorm_inplace(qh, &lw.q_norm, cfg.rms_eps);
        ops::rope_inplace(qh, pos, cfg.rope_theta);
    }
    for h in 0..cfg.n_kv_heads {
        let kh = &mut k[h * head_dim..(h + 1) * head_dim];
        ops::rmsnorm_inplace(kh, &lw.k_norm, cfg.rms_eps);
        ops::rope_inplace(kh, pos, cfg.rope_theta);
    }
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut attn = vec![0.0f32; cfg.q_dim()];
    for h in 0..cfg.n_heads {
        let kvh = h / groups;
        let qh = &q[h * head_dim..(h + 1) * head_dim];
        let mut scores = Vec::with_capacity(pos + 1);
        for p in 0..=pos {
            let kvec: &[f32] = if p < pos {
                &k_cache[(p * cfg.kv_dim() + kvh * head_dim)..][..head_dim]
            } else {
                &k[kvh * head_dim..(kvh + 1) * head_dim]
            };
            scores.push(qh.iter().zip(kvec).map(|(a, b)| a * b).sum::<f32>() * scale);
        }
        ops::softmax_inplace(&mut scores);
        let out = &mut attn[h * head_dim..(h + 1) * head_dim];
        for p in 0..=pos {
            let vvec: &[f32] = if p < pos {
                &v_cache[(p * cfg.kv_dim() + kvh * head_dim)..][..head_dim]
            } else {
                &v[kvh * head_dim..(kvh + 1) * head_dim]
            };
            for i in 0..head_dim {
                out[i] += scores[p] * vvec[i];
            }
        }
    }
    let mut x1 = x.clone();
    ops::add_inplace(&mut x1, &matvec(&lw.wo, &attn));
    let mut xn2 = vec![0.0f32; cfg.d_model];
    ops::rmsnorm(&x1, &lw.ffn_norm, cfg.rms_eps, &mut xn2);
    let gate = matvec(&lw.w_gate, &xn2);
    let up = matvec(&lw.w_up, &xn2);
    let mut actv = vec![0.0f32; cfg.d_ffn];
    ops::swiglu(&gate, &up, &mut actv);
    let mut want_x = x1.clone();
    ops::add_inplace(&mut want_x, &matvec(&lw.w_down, &actv));

    // ---- PJRT layer_fwd_q8 on identical packed operands ----
    let wpair = |t: &QTensor| {
        let (qs, ds) = split_q8_blocks(&q8(t));
        (
            lit::i8(&[t.rows, t.cols], &qs).unwrap(),
            lit::f32(&[t.rows, t.cols / 32], &ds).unwrap(),
        )
    };
    let (wq_q, wq_d) = wpair(&lw.wq);
    let (wk_q, wk_d) = wpair(&lw.wk);
    let (wv_q, wv_d) = wpair(&lw.wv);
    let (wo_q, wo_d) = wpair(&lw.wo);
    let (wg_q, wg_d) = wpair(&lw.w_gate);
    let (wu_q, wu_d) = wpair(&lw.w_up);
    let (wd_q, wd_d) = wpair(&lw.w_down);
    let outs = rt
        .execute(
            "layer_fwd_q8_ctx7",
            &[
                lit::f32(&[cfg.d_model], &x).unwrap(),
                lit::f32(&[cfg.d_model], &lw.attn_norm).unwrap(),
                lit::f32(&[cfg.d_model], &lw.ffn_norm).unwrap(),
                lit::f32(&[cfg.head_dim], &lw.q_norm).unwrap(),
                lit::f32(&[cfg.head_dim], &lw.k_norm).unwrap(),
                wq_q, wq_d, wk_q, wk_d, wv_q, wv_d, wo_q, wo_d, wg_q, wg_d, wu_q, wu_d,
                wd_q, wd_d,
                lit::f32(&[ctx_prev, cfg.kv_dim()], &k_cache).unwrap(),
                lit::f32(&[ctx_prev, cfg.kv_dim()], &v_cache).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3, "x_out, k_new, v_new");
    let got_x = outs[0].to_vec::<f32>().unwrap();
    let got_k = outs[1].to_vec::<f32>().unwrap();
    let got_v = outs[2].to_vec::<f32>().unwrap();

    let max_err = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    };
    // Same integer kernels + same f32 host ops; only summation order and
    // activation-quant rounding at f32 boundaries differ.
    assert!(max_err(&got_x, &want_x) < 0.05, "x: {}", max_err(&got_x, &want_x));
    assert!(max_err(&got_k, &k[..cfg.kv_dim()]) < 1e-3);
    assert!(max_err(&got_v, &v[..cfg.kv_dim()]) < 2e-2);
}

#[test]
fn pjrt_backend_generates_same_tokens_as_native() {
    if runtime_or_skip().is_none() {
        return;
    }
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 77);
    let prompt = [1u32, 42, 7, 300];

    let mut native_engine = Engine::new(weights.clone());
    let native = native_engine.generate(&prompt, 6, &mut Sampler::greedy(), &mut NativeExec);

    let mut pjrt_exec = PjrtExec::new().expect("pjrt backend");
    let mut pjrt_engine = Engine::new(weights);
    let via_pjrt = pjrt_engine.generate(&prompt, 6, &mut Sampler::greedy(), &mut pjrt_exec);

    assert!(
        pjrt_exec.pjrt_calls > 0,
        "backend must actually route kernels through PJRT"
    );
    assert_eq!(
        native.tokens, via_pjrt.tokens,
        "greedy decode must agree between native and PJRT kernels \
         (pjrt calls: {}, native fallbacks: {})",
        pjrt_exec.pjrt_calls, pjrt_exec.native_calls
    );
}

#[test]
fn pjrt_single_forward_logits_close() {
    if runtime_or_skip().is_none() {
        return;
    }
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 88);
    let mut e1 = Engine::new(weights.clone());
    let l_native = e1.forward(5, Phase::Prefill, true, &mut NativeExec).unwrap();
    let mut exec = PjrtExec::new().unwrap();
    let mut e2 = Engine::new(weights);
    let l_pjrt = e2.forward(5, Phase::Prefill, true, &mut exec).unwrap();
    let max_err = l_native
        .iter()
        .zip(&l_pjrt)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 0.02, "logit divergence {max_err}");
}
