//! The adaptive per-round token budget must inherit the fixed budget's
//! decode-starvation guarantee: whatever budget the controller walks to,
//! prefill only ever spends what the live decodes left of it, and the
//! walk itself stays inside `[min, max]`.

use std::time::Instant;

use imax_llm::coordinator::{
    AdaptiveBudget, ContinuousBatcher, InstrumentedExec, OffloadPolicy, Request,
};
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::engine::NativeExec;
use imax_llm::model::{Engine, ModelConfig, ModelWeights, QuantScheme, RoundBalance, Sampler};

fn instrumented() -> InstrumentedExec<NativeExec> {
    InstrumentedExec::new(
        NativeExec,
        ImaxDevice::fpga(2),
        OffloadPolicy::new(LmmConfig::new(64)),
        TransferMode::Coalesced,
    )
}

#[test]
fn controller_direction_follows_the_modeled_balance() {
    let a = AdaptiveBudget::new(4, 64);
    let load_bound = RoundBalance { load_s: 0.9, exec_s: 0.1 };
    let exec_bound = RoundBalance { load_s: 0.1, exec_s: 0.9 };
    let balanced = RoundBalance { load_s: 0.5, exec_s: 0.5 };
    let unmodeled = RoundBalance { load_s: 0.0, exec_s: 0.0 };

    // LOAD-bound rounds grow the budget (more tokens amortize each
    // weight transfer) until the ceiling absorbs the walk.
    let mut cur = 8;
    for _ in 0..16 {
        let next = a.next_budget(cur, &load_bound);
        assert!(next >= cur, "LOAD-bound must never shrink: {cur} -> {next}");
        cur = next;
    }
    assert_eq!(cur, a.max, "LOAD-bound walk saturates at the ceiling");

    // EXEC-bound rounds shrink it (the budget is adding latency, not
    // amortization) until the floor catches it.
    let mut cur = 32;
    for _ in 0..16 {
        let next = a.next_budget(cur, &exec_bound);
        assert!(next <= cur, "EXEC-bound must never grow: {cur} -> {next}");
        cur = next;
    }
    assert_eq!(cur, a.min, "EXEC-bound walk saturates at the floor");

    // Inside the dead-band the controller holds still, and a round with
    // no modeled time at all freezes it (functional backend).
    assert_eq!(a.next_budget(16, &balanced), 16);
    assert_eq!(a.next_budget(16, &unmodeled), 16);

    // Out-of-range starting points are clamped, never amplified.
    assert_eq!(a.next_budget(1, &balanced), a.min);
    assert_eq!(a.next_budget(1000, &balanced), a.max);
}

#[test]
fn adaptive_budget_never_starves_decodes() {
    // The fixed-budget starvation test (scheduler.rs
    // `token_budget_decode_pass_never_starves`) replayed under the
    // controller: two live decodes, then a long prompt chunk-streaming
    // in while the budget walks. Every settled round must satisfy
    // `prefill <= budget_that_round - decode`, where "budget that
    // round" comes from the controller's own trace.
    let weights = ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 23);
    let spec = AdaptiveBudget::new(2, 4);
    let mut b = ContinuousBatcher::new(Engine::with_slots(weights, 3), 32, Instant::now())
        .with_token_budget(2)
        .with_adaptive_budget(spec)
        .with_prefill_chunk(2);
    let mut exec = instrumented();
    b.admit(Request::new(0, vec![1], 4), Sampler::greedy(), 0.0, &mut exec).unwrap();
    b.admit(Request::new(1, vec![2], 4), Sampler::greedy(), 0.0, &mut exec).unwrap();
    assert!(b.decode_round(&mut exec).is_empty());
    b.admit(Request::new(2, (1..=9).collect(), 1), Sampler::greedy(), 0.0, &mut exec).unwrap();
    let logs = b.drain(&mut exec);
    assert_eq!(logs.len(), 3, "the long prompt completes despite decode priority");

    let rounds = b.rounds();
    let trace = b.budget_trace();
    assert!(!rounds.is_empty());
    assert_eq!(
        trace.len(),
        rounds.len(),
        "one controller step per settled round keeps the traces aligned"
    );
    for &bud in trace {
        assert!((spec.min..=spec.max).contains(&bud), "budget {bud} escaped [2, 4]");
    }
    // Round 0 ran under the initial budget (2, the seed passed to
    // with_token_budget); round i under trace[i - 1].
    for (i, r) in rounds.iter().enumerate() {
        let budget = if i == 0 { 2 } else { trace[i - 1] };
        assert!(
            r.prefill_tokens <= budget.saturating_sub(r.decode_tokens),
            "round {i} (budget {budget}) starved decodes: {r:?}"
        );
    }
    let both_live: Vec<_> = rounds.iter().filter(|r| r.decode_tokens == 2).collect();
    assert!(!both_live.is_empty(), "rounds carried both live decodes");
}

#[test]
fn adaptive_schedule_is_output_invariant() {
    // The controller reshapes rounds, never tokens: a run under the
    // adaptive budget emits exactly the token streams of a fixed-budget
    // run (same seeded sampler, same requests).
    let run = |adaptive: bool| {
        let weights = ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 23);
        let mut b = ContinuousBatcher::new(Engine::with_slots(weights, 3), 32, Instant::now())
            .with_token_budget(3)
            .with_prefill_chunk(2);
        if adaptive {
            b = b.with_adaptive_budget(AdaptiveBudget::new(1, 8));
        }
        let mut exec = instrumented();
        for id in 0..3usize {
            let req = Request::new(id, (1..=(4 + 3 * id as u32)).collect(), 5);
            b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        }
        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        logs.into_iter().map(|l| l.tokens).collect::<Vec<_>>()
    };
    assert_eq!(run(false), run(true), "budget adaptation must be schedule-only");
}
