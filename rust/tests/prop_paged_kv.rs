//! Property tests for the paged KV cache (`util::proptest_lite`):
//! random admit/advance/reset sequences over small page geometries must
//! preserve the pool invariants the scheduler relies on —
//!
//! * the page ids owned by slots plus the free list are always a
//!   permutation of `0..n_pages` (no page is ever double-allocated or
//!   lost),
//! * every slot owns exactly `pages_needed(slot_len)` pages,
//! * `reset_slot` returns exactly the pages that slot held,
//! * a failed reservation changes nothing (atomicity), and the error is
//!   the right kind for the state (`ContextOverflow` vs `OutOfPages`),
//! * data written through one slot is never clobbered by another slot's
//!   growth (the functional face of "no double allocation").
//!
//! Both suites run under each [`KvScheme`]: f16 pools must read back
//! stored values bit-exactly; q8_0 pools must keep the canonical block
//! bytes equal to the commit-time encoding of every live position (and
//! the f32 mirror equal to their dequantization) through CoW splits,
//! swap roundtrips, and truncation — rollback never leaves a
//! partially-encoded page behind.

use imax_llm::model::{CacheError, KvCache, KvScheme, ModelConfig};
use imax_llm::quant::q8_0;
use imax_llm::util::proptest_lite::Runner;
use imax_llm::util::rng::Rng;

/// Tiny geometry so each case is microseconds: kv_dim = 4, 2 layers.
fn mini_cfg(max_seq: usize) -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.d_model = 16;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 1;
    cfg.head_dim = 4;
    cfg.d_ffn = 16;
    cfg.vocab_size = 32;
    cfg.max_seq_len = max_seq;
    cfg
}

/// Smallest geometry a q8_0 pool accepts: kv_dim = 32 (one block per
/// K/V row), 2 layers.
fn q8_cfg(max_seq: usize) -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.d_model = 64;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 1;
    cfg.head_dim = 32;
    cfg.d_ffn = 64;
    cfg.vocab_size = 32;
    cfg.max_seq_len = max_seq;
    cfg
}

fn cfg_for(scheme: KvScheme, max_seq: usize) -> ModelConfig {
    match scheme {
        KvScheme::F16 => mini_cfg(max_seq),
        KvScheme::Q8_0 => q8_cfg(max_seq),
    }
}

/// What a scheme's pool reads back for a cell committed as `val` (the
/// whole row is uniform, so one cell characterizes it): f16 pools are
/// lossless, q8_0 pools return the quantization roundtrip.
fn expect_cell(scheme: KvScheme, kv_dim: usize, val: f32) -> f32 {
    match scheme {
        KvScheme::F16 => val,
        KvScheme::Q8_0 => {
            q8_0::dequantize_row_bytes(&q8_0::quantize_row_bytes(&vec![val; kv_dim]), kv_dim)[0]
        }
    }
}

const MAX_SEQ: usize = 32;

#[derive(Clone, Debug)]
enum Op {
    /// Reserve + store + advance `n` tokens on `slot`.
    Grow { slot: usize, n: usize },
    /// Close `slot`, returning its pages.
    Reset { slot: usize },
}

#[derive(Clone, Debug)]
struct Case {
    page_size: usize,
    n_pages: usize,
    n_slots: usize,
    ops: Vec<Op>,
}

fn gen_case(r: &mut Rng) -> Case {
    let page_size = 1 + r.below(5);
    let n_slots = 1 + r.below(4);
    let n_pages = 1 + r.below(12);
    let n_ops = r.below(40);
    let ops = (0..n_ops)
        .map(|_| {
            if r.below(4) == 0 {
                Op::Reset { slot: r.below(n_slots) }
            } else {
                Op::Grow { slot: r.below(n_slots), n: 1 + r.below(6) }
            }
        })
        .collect();
    Case { page_size, n_pages, n_slots, ops }
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    if !c.ops.is_empty() {
        let mut half = c.clone();
        half.ops.truncate(c.ops.len() / 2);
        out.push(half);
        let mut minus_one = c.clone();
        minus_one.ops.pop();
        out.push(minus_one);
    }
    out
}

/// The distinct marker value written at `(slot, epoch, pos, layer)` —
/// collision-free for the generator's ranges and exact in f32.
fn marker(slot: usize, epoch: usize, pos: usize, layer: usize) -> f32 {
    (slot * 1_000_000 + epoch * 10_000 + pos * 10 + layer) as f32
}

/// Replay a case, checking every invariant after every operation.
/// Returns `Err(description)` on the first violation.
fn check_case(case: &Case, scheme: KvScheme) -> Result<(), String> {
    let cfg = cfg_for(scheme, MAX_SEQ);
    let kv_dim = cfg.kv_dim();
    let mut c =
        KvCache::paged_with_scheme(&cfg, case.n_slots, case.page_size, case.n_pages, scheme);
    // Mirror state: per-slot length and reset epoch.
    let mut lens = vec![0usize; case.n_slots];
    let mut epochs = vec![0usize; case.n_slots];

    let pool_is_permutation = |c: &KvCache| -> Result<(), String> {
        let mut ids: Vec<u32> = c.free_list().to_vec();
        for slot in 0..case.n_slots {
            ids.extend_from_slice(c.slot_pages(slot));
        }
        ids.sort_unstable();
        let want: Vec<u32> = (0..case.n_pages as u32).collect();
        if ids != want {
            return Err(format!(
                "owned + free pages are not a permutation of the pool: {ids:?}"
            ));
        }
        Ok(())
    };

    for (i, op) in case.ops.iter().enumerate() {
        match *op {
            Op::Grow { slot, n } => {
                let free_before = c.free_page_count();
                let pages_before = c.slot_pages(slot).len();
                match c.try_reserve(slot, n) {
                    Ok(()) => {
                        for pos in lens[slot]..lens[slot] + n {
                            for layer in 0..cfg.n_layers {
                                let m = marker(slot, epochs[slot], pos, layer);
                                c.store(slot, layer, pos, &vec![m; kv_dim], &vec![-m; kv_dim]);
                            }
                        }
                        c.advance(slot, n)
                            .map_err(|e| format!("op {i}: advance after reserve failed: {e}"))?;
                        lens[slot] += n;
                    }
                    Err(err) => {
                        // Atomic: nothing changed.
                        if c.free_page_count() != free_before
                            || c.slot_pages(slot).len() != pages_before
                        {
                            return Err(format!("op {i}: failed reserve mutated state"));
                        }
                        // The error kind matches the mirror state.
                        let over_ctx = lens[slot] + n > MAX_SEQ;
                        match err {
                            CacheError::ContextOverflow { .. } if over_ctx => {}
                            CacheError::OutOfPages { .. } if !over_ctx => {}
                            other => {
                                return Err(format!(
                                    "op {i}: wrong error {other:?} (len {} + {n}, max {MAX_SEQ})",
                                    lens[slot]
                                ))
                            }
                        }
                    }
                }
            }
            Op::Reset { slot } => {
                let held: Vec<u32> = c.slot_pages(slot).to_vec();
                let free_before = c.free_page_count();
                c.reset_slot(slot);
                lens[slot] = 0;
                epochs[slot] += 1;
                if c.free_page_count() != free_before + held.len() {
                    return Err(format!(
                        "op {i}: reset returned {} pages, slot held {}",
                        c.free_page_count() - free_before,
                        held.len()
                    ));
                }
                // Exactly those pages, pushed LIFO (table order reversed).
                let tail = &c.free_list()[free_before..];
                let want: Vec<u32> = held.iter().rev().cloned().collect();
                if tail != want.as_slice() {
                    return Err(format!(
                        "op {i}: reset freed {tail:?}, slot held {held:?}"
                    ));
                }
                if !c.slot_pages(slot).is_empty() || c.slot_len(slot) != 0 {
                    return Err(format!("op {i}: reset left slot {slot} non-empty"));
                }
            }
        }

        // Global invariants after every op.
        pool_is_permutation(&c)?;
        for slot in 0..case.n_slots {
            if c.slot_len(slot) != lens[slot] {
                return Err(format!(
                    "op {i}: slot {slot} len {} != mirror {}",
                    c.slot_len(slot),
                    lens[slot]
                ));
            }
            if c.slot_pages(slot).len() != c.pages_needed(lens[slot]) {
                return Err(format!(
                    "op {i}: slot {slot} owns {} pages for {} tokens (want {})",
                    c.slot_pages(slot).len(),
                    lens[slot],
                    c.pages_needed(lens[slot])
                ));
            }
        }
        if c.used_pages() + c.free_page_count() != c.n_pages() {
            return Err(format!("op {i}: used + free != pool"));
        }
    }

    // Data integrity: every live position still holds the marker written
    // in its slot's current epoch — growth of other slots never clobbered
    // it through a double-allocated page.
    for slot in 0..case.n_slots {
        for pos in 0..lens[slot] {
            for layer in 0..cfg.n_layers {
                let want = marker(slot, epochs[slot], pos, layer);
                let (want_k, want_v) = (
                    expect_cell(scheme, kv_dim, want),
                    expect_cell(scheme, kv_dim, -want),
                );
                let k = c.k_at(slot, layer, pos, 0, cfg.head_dim)[0];
                let v = c.v_at(slot, layer, pos, 0, cfg.head_dim)[0];
                if k != want_k || v != want_v {
                    return Err(format!(
                        "slot {slot} layer {layer} pos {pos}: k/v = {k}/{v}, \
                         want {want_k}/{want_v}"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn prop_pool_conservation_and_no_double_allocation() {
    Runner::new("paged-kv-pool-invariants").run(
        gen_case,
        |c| check_case(c, KvScheme::F16),
        shrink_case,
    );
}

#[test]
fn prop_q8_0_pool_conservation_and_no_double_allocation() {
    Runner::new("paged-kv-pool-invariants-q8").cases(128).run(
        gen_case,
        |c| check_case(c, KvScheme::Q8_0),
        shrink_case,
    );
}

// ---- refcounted sharing / CoW / eviction property suite ----

/// Ops for the refcounted pool: exclusive growth plus the sharing
/// machinery (register, adopt, in-place overwrite → CoW).
#[derive(Clone, Debug)]
enum ShareOp {
    /// Reserve + store + advance `n` content-derived tokens on `slot`.
    Grow { slot: usize, n: usize },
    /// Close `slot`, releasing its references.
    Reset { slot: usize },
    /// Register `slot`'s committed full pages in the prefix index
    /// (skipped when the slot holds overwritten positions).
    Register { slot: usize },
    /// Adopt a previously registered prompt into an empty slot.
    Adopt { slot: usize, pick: usize },
    /// Overwrite one committed position in place (CoW on shared pages).
    Overwrite { slot: usize, pos_seed: usize },
    /// Roll back `slot` to a shorter length (the speculative-verify
    /// rejection path), dropping whole pages past the kept span.
    Truncate { slot: usize, keep_seed: usize },
}

#[derive(Clone, Debug)]
struct ShareCase {
    page_size: usize,
    n_pages: usize,
    n_slots: usize,
    /// Host swap arena capacity (0: evictions drop).
    swap_pages: usize,
    ops: Vec<ShareOp>,
}

fn gen_share_case(r: &mut Rng) -> ShareCase {
    let page_size = 1 + r.below(4);
    let n_slots = 1 + r.below(3);
    let n_pages = 2 + r.below(10);
    let swap_pages = r.below(6);
    let n_ops = r.below(48);
    let ops = (0..n_ops)
        .map(|_| match r.below(10) {
            0 => ShareOp::Reset { slot: r.below(n_slots) },
            1 | 2 => ShareOp::Register { slot: r.below(n_slots) },
            3 | 4 => ShareOp::Adopt { slot: r.below(n_slots), pick: r.below(8) },
            5 => ShareOp::Overwrite { slot: r.below(n_slots), pos_seed: r.below(64) },
            6 => ShareOp::Truncate { slot: r.below(n_slots), keep_seed: r.below(64) },
            _ => ShareOp::Grow { slot: r.below(n_slots), n: 1 + r.below(5) },
        })
        .collect();
    ShareCase { page_size, n_pages, n_slots, swap_pages, ops }
}

fn shrink_share_case(c: &ShareCase) -> Vec<ShareCase> {
    let mut out = Vec::new();
    if !c.ops.is_empty() {
        let mut half = c.clone();
        half.ops.truncate(c.ops.len() / 2);
        out.push(half);
        let mut minus_one = c.clone();
        minus_one.ops.pop();
        out.push(minus_one);
    }
    out
}

/// Content-derived value at `(token, pos)` — what every clean cell of a
/// committed position holds (layer adds a small offset). Exact in f32
/// for the generator's ranges.
fn content_val(token: u32, pos: usize) -> f32 {
    (token as f32) * 1000.0 + (pos as f32) * 10.0
}

fn check_share_case(case: &ShareCase, scheme: KvScheme) -> Result<(), String> {
    let cfg = cfg_for(scheme, MAX_SEQ);
    let kv_dim = cfg.kv_dim();
    let ps = case.page_size;
    let mut c = KvCache::paged_with_scheme(&cfg, case.n_slots, ps, case.n_pages, scheme);
    c.enable_prefix_cache(0xF00D);
    if case.swap_pages > 0 {
        c.set_swap_capacity(case.swap_pages);
    }

    // Mirror: committed token ids, expected cell values (layer 0 basis),
    // and dirty flags per slot; plus the prompts registered so far.
    let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); case.n_slots];
    let mut vals: Vec<Vec<f32>> = vec![Vec::new(); case.n_slots];
    let mut dirty: Vec<Vec<bool>> = vec![Vec::new(); case.n_slots];
    let mut registered: Vec<Vec<u32>> = Vec::new();

    let write_pos = |c: &mut KvCache, slot: usize, pos: usize, val: f32| {
        for layer in 0..cfg.n_layers {
            let v = val + layer as f32;
            c.store(slot, layer, pos, &vec![v; kv_dim], &vec![-v; kv_dim]);
        }
    };

    for (i, op) in case.ops.iter().enumerate() {
        match *op {
            ShareOp::Grow { slot, n } => {
                if c.try_reserve(slot, n).is_ok() {
                    for k in 0..n {
                        let pos = tokens[slot].len();
                        let tok = ((i * 7 + pos * 3 + k) % 13) as u32;
                        let val = content_val(tok, pos);
                        write_pos(&mut c, slot, pos, val);
                        tokens[slot].push(tok);
                        vals[slot].push(val);
                        dirty[slot].push(false);
                        c.advance(slot, 1)
                            .map_err(|e| format!("op {i}: advance after reserve: {e}"))?;
                    }
                }
            }
            ShareOp::Reset { slot } => {
                c.reset_slot(slot);
                tokens[slot].clear();
                vals[slot].clear();
                dirty[slot].clear();
            }
            ShareOp::Register { slot } => {
                let full = tokens[slot].len() / ps;
                // Only register content-clean spans (mirrors the engine:
                // prompts are written once, never patched).
                if full > 0 && !dirty[slot][..full * ps].iter().any(|&d| d) {
                    c.register_prefix(slot, &tokens[slot]);
                    registered.push(tokens[slot][..full * ps].to_vec());
                }
            }
            ShareOp::Adopt { slot, pick } => {
                if tokens[slot].is_empty() && !registered.is_empty() {
                    let prompt = &registered[pick % registered.len()];
                    let adopted = c.adopt_prefix(slot, prompt, prompt.len());
                    if adopted.tokens % ps != 0 || adopted.tokens > prompt.len() {
                        return Err(format!(
                            "op {i}: adopted {} tokens (page size {ps}, prompt {})",
                            adopted.tokens,
                            prompt.len()
                        ));
                    }
                    if adopted.pages.len() * ps != adopted.tokens {
                        return Err(format!("op {i}: pages/tokens mismatch: {adopted:?}"));
                    }
                    for (pos, &tok) in prompt[..adopted.tokens].iter().enumerate() {
                        tokens[slot].push(tok);
                        vals[slot].push(content_val(tok, pos));
                        dirty[slot].push(false);
                    }
                }
            }
            ShareOp::Overwrite { slot, pos_seed } => {
                if !tokens[slot].is_empty() {
                    let pos = pos_seed % tokens[slot].len();
                    // A write to a shared page splits it (CoW), which
                    // needs an obtainable page; skip states where the
                    // pool is fully pinned (the engine never writes into
                    // shared spans, so CoW exhaustion is unreachable in
                    // real flows — the guard keeps the generator inside
                    // satisfiable states).
                    let page = c.slot_pages(slot)[pos / ps];
                    let shared = c.page_ref(page) > 1;
                    if !shared || c.free_page_count() + c.reclaimable_pages() > 0 {
                        // Distinct from every clean value (exact in f32).
                        let val = vals[slot][pos] + 0.5;
                        write_pos(&mut c, slot, pos, val);
                        vals[slot][pos] = val;
                        dirty[slot][pos] = true;
                    }
                }
            }
            ShareOp::Truncate { slot, keep_seed } => {
                if !tokens[slot].is_empty() {
                    let keep = keep_seed % (tokens[slot].len() + 1);
                    // Future growth re-stores into the last kept page when
                    // `keep` is unaligned; the engine only rolls back its
                    // own freshly appended (exclusive) tail, so keep the
                    // generator out of the shared-page-rewrite states that
                    // real flows never reach.
                    let safe = keep % ps == 0
                        || c.page_ref(c.slot_pages(slot)[(keep - 1) / ps]) == 1;
                    if safe {
                        c.truncate(slot, keep);
                        tokens[slot].truncate(keep);
                        vals[slot].truncate(keep);
                        dirty[slot].truncate(keep);
                    }
                }
            }
        }

        // ---- invariants after every op ----
        // Refcounts: block-table references + resident index entries.
        let mut want_refs = vec![0u32; case.n_pages];
        for s in 0..case.n_slots {
            for &p in c.slot_pages(s) {
                want_refs[p as usize] += 1;
            }
        }
        for p in c.cached_page_ids() {
            want_refs[p as usize] += 1;
        }
        for page in 0..case.n_pages as u32 {
            if c.page_ref(page) != want_refs[page as usize] {
                return Err(format!(
                    "op {i}: page {page} refcount {} != table+index references {}",
                    c.page_ref(page),
                    want_refs[page as usize]
                ));
            }
        }
        // Free list: exactly the zero-ref pages, each once.
        let mut free: Vec<u32> = c.free_list().to_vec();
        free.sort_unstable();
        if free.windows(2).any(|w| w[0] == w[1]) {
            return Err(format!("op {i}: duplicate page on the free list: {free:?}"));
        }
        let want_free: Vec<u32> =
            (0..case.n_pages as u32).filter(|&p| want_refs[p as usize] == 0).collect();
        if free != want_free {
            return Err(format!("op {i}: free list {free:?} != zero-ref pages {want_free:?}"));
        }
        // Arena stays inside its capacity.
        if c.swapped_out_pages() > case.swap_pages {
            return Err(format!(
                "op {i}: arena holds {} pages over capacity {}",
                c.swapped_out_pages(),
                case.swap_pages
            ));
        }
        // Slot shapes match the mirror.
        for s in 0..case.n_slots {
            if c.slot_len(s) != tokens[s].len() {
                return Err(format!(
                    "op {i}: slot {s} len {} != mirror {}",
                    c.slot_len(s),
                    tokens[s].len()
                ));
            }
            if c.slot_pages(s).len() != c.pages_needed(tokens[s].len()) {
                return Err(format!(
                    "op {i}: slot {s} owns {} pages for {} tokens",
                    c.slot_pages(s).len(),
                    tokens[s].len()
                ));
            }
        }
        // Arena payloads always match the scheme's per-page shape: f16
        // pools swap the lossless f32 mirror, q8_0 pools swap only the
        // canonical block bytes — never a mixed or partial payload.
        let want_arena = c.arena_expected_payload();
        for (key, f_cells, q_bytes) in c.arena_payloads() {
            if (f_cells, q_bytes) != want_arena {
                return Err(format!(
                    "op {i}: arena entry {key:#x} payload ({f_cells} cells, {q_bytes} \
                     block bytes) != scheme shape {want_arena:?}"
                ));
            }
        }
        // Data integrity: every live cell reads back the mirrored value —
        // CoW never leaks a writer's bytes into another reader, adoption
        // serves exactly the registered content, swap roundtrips are
        // bit-exact. On q8_0 pools the canonical block bytes must equal
        // the commit-time encoding byte-for-byte (truncation, CoW, and
        // swap never re-encode or partially encode a live row) and the
        // f32 mirror must be exactly their dequantization.
        for s in 0..case.n_slots {
            for pos in 0..vals[s].len() {
                for layer in 0..cfg.n_layers {
                    let want = vals[s][pos] + layer as f32;
                    let (want_k, want_v) = (
                        expect_cell(scheme, kv_dim, want),
                        expect_cell(scheme, kv_dim, -want),
                    );
                    let k = c.k_at(s, layer, pos, 0, cfg.head_dim)[0];
                    let v = c.v_at(s, layer, pos, 0, cfg.head_dim)[0];
                    if k != want_k || v != want_v {
                        return Err(format!(
                            "op {i}: slot {s} layer {layer} pos {pos}: k/v {k}/{v}, \
                             want {want_k}/{want_v}"
                        ));
                    }
                    if scheme == KvScheme::Q8_0 {
                        let enc_k = q8_0::quantize_row_bytes(&vec![want; kv_dim]);
                        let enc_v = q8_0::quantize_row_bytes(&vec![-want; kv_dim]);
                        if c.k_block_bytes_at(s, layer, pos) != enc_k.as_slice()
                            || c.v_block_bytes_at(s, layer, pos) != enc_v.as_slice()
                        {
                            return Err(format!(
                                "op {i}: slot {s} layer {layer} pos {pos}: block bytes \
                                 differ from the commit-time q8_0 encoding"
                            ));
                        }
                    }
                }
            }
        }
    }

    // No leaks: dropping every slot and the index recovers the pool.
    for s in 0..case.n_slots {
        c.reset_slot(s);
    }
    c.clear_prefix_cache();
    if c.free_page_count() != c.n_pages() {
        return Err(format!(
            "teardown recovered {}/{} pages",
            c.free_page_count(),
            c.n_pages()
        ));
    }
    Ok(())
}

#[test]
fn prop_refcounted_pool_share_cow_evict_invariants() {
    Runner::new("refcounted-kv-share-invariants").run(
        gen_share_case,
        |c| check_share_case(c, KvScheme::F16),
        shrink_share_case,
    );
}

#[test]
fn prop_q8_0_share_cow_swap_roundtrip_preserves_block_bytes() {
    Runner::new("refcounted-kv-share-invariants-q8").cases(128).run(
        gen_share_case,
        |c| check_share_case(c, KvScheme::Q8_0),
        shrink_share_case,
    );
}

#[test]
fn prop_full_pool_recovers_after_reset_all() {
    // Drive every slot to reservation failure, reset everything, and the
    // whole pool must be reusable — the leak detector for the free list.
    Runner::new("paged-kv-drain-recover").cases(64).run_noshrink(gen_case, |case| {
        let cfg = mini_cfg(MAX_SEQ);
        let mut c = KvCache::paged(&cfg, case.n_slots, case.page_size, case.n_pages);
        let mut lens = vec![0usize; case.n_slots];
        // Greedily grow slots round-robin until nothing fits anywhere.
        let mut progressed = true;
        while progressed {
            progressed = false;
            for slot in 0..case.n_slots {
                if c.try_reserve(slot, 1).is_ok() {
                    c.advance(slot, 1)
                        .map_err(|e| format!("advance after reserve: {e}"))?;
                    lens[slot] += 1;
                    progressed = true;
                }
            }
        }
        // Fragmentation-free: single-token growth stops only at max_seq
        // or an empty free list, so leftover free pages mean every slot
        // hit the context window.
        if c.free_page_count() > 0 && lens.iter().any(|&l| l < MAX_SEQ) {
            return Err(format!(
                "pool has {} free pages but slot lens are {lens:?}",
                c.free_page_count()
            ));
        }
        c.reset();
        if c.free_page_count() != c.n_pages() {
            return Err(format!(
                "reset recovered {}/{} pages",
                c.free_page_count(),
                c.n_pages()
            ));
        }
        // The recovered pool serves a fresh max-size reservation.
        let fit = (case.n_pages * case.page_size).min(MAX_SEQ);
        c.try_reserve(0, fit).map_err(|e| format!("post-reset reserve: {e}"))?;
        Ok(())
    });
}
