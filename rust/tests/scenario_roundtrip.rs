//! Scenario-file round-trip and replay-determinism properties, run
//! against both constructed scenarios and the committed example files
//! under `examples/scenarios/` (the ones CI replays through
//! `serve --scenario`).

use imax_llm::harness::scenario::{ArrivalProcess, Scenario, TenantShape, TenantSpec};
use imax_llm::harness::workloads::Arrival;
use imax_llm::model::ModelConfig;

fn example_path(file: &str) -> String {
    format!("{}/../examples/scenarios/{file}", env!("CARGO_MANIFEST_DIR"))
}

const EXAMPLES: &[&str] = &["mixed_tenants.scn", "diurnal_ramp.scn"];

fn load(file: &str) -> Scenario {
    let path = example_path(file);
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    Scenario::parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e:#}"))
}

/// Bit-exact equality of two arrival traces: ids, prompts, tenants,
/// arrival instants (compared via `to_bits`), cancel marks and
/// deadlines.
fn assert_traces_identical(a: &[Arrival], b: &[Arrival]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.request.id, y.request.id);
        assert_eq!(x.request.prompt, y.request.prompt);
        assert_eq!(x.request.n_out, y.request.n_out);
        assert_eq!(x.request.tenant, y.request.tenant);
        assert_eq!(
            x.at_s.to_bits(),
            y.at_s.to_bits(),
            "arrival {} moved: {} vs {}",
            x.request.id,
            x.at_s,
            y.at_s
        );
        assert_eq!(x.request.deadline_s, y.request.deadline_s);
        match (&x.cancel, &y.cancel) {
            (None, None) => {}
            (Some((_, dx)), Some((_, dy))) => assert_eq!(dx.to_bits(), dy.to_bits()),
            _ => panic!("cancel mark diverged on request {}", x.request.id),
        }
    }
}

#[test]
fn committed_examples_round_trip_to_identical_traces() {
    for file in EXAMPLES {
        let sc = load(file);
        let reparsed = Scenario::parse(&sc.to_text())
            .unwrap_or_else(|e| panic!("{file}: to_text() must re-parse: {e:#}"));
        assert_eq!(sc, reparsed, "{file}: parse(to_text()) must be the same scenario");
        assert_traces_identical(&sc.arrivals(), &reparsed.arrivals());
    }
}

#[test]
fn committed_examples_replay_bit_identically() {
    for file in EXAMPLES {
        let sc = load(file);
        assert!(sc.n > 0, "{file}: empty scenario");
        assert_traces_identical(&sc.arrivals(), &sc.arrivals());
        // A different seed must actually move the process (guards
        // against a seed that is parsed but never used).
        let mut other = sc.clone();
        other.seed ^= 0xdead_beef;
        let a = sc.arrivals();
        let b = other.arrivals();
        assert!(
            a.iter().zip(&b).any(|(x, y)| x.at_s != y.at_s),
            "{file}: reseeding did not move the arrival clock"
        );
    }
}

#[test]
fn committed_examples_fit_the_tiny_model() {
    // CI replays these files through `serve --scenario` on the tiny
    // model; a prompt token at or above its vocabulary would be invalid.
    let vocab = ModelConfig::tiny().vocab_size;
    for file in EXAMPLES {
        let sc = load(file);
        assert!(
            sc.vocab_size <= vocab,
            "{file}: scenario vocab {} exceeds tiny model vocab {vocab}",
            sc.vocab_size
        );
        for a in sc.arrivals() {
            assert!(a.request.prompt.iter().all(|&t| (t as usize) < sc.vocab_size));
        }
    }
}

#[test]
fn constructed_scenarios_round_trip_across_all_processes() {
    // Property sweep over the process grammar with awkward floats
    // (values whose decimal form is not exact in binary): shortest
    // round-trip serialization must reproduce the trace bit-for-bit.
    let processes = [
        ArrivalProcess::Poisson { rate_per_s: 33.3 },
        ArrivalProcess::Bursty {
            base_rate_per_s: 17.7,
            burst_rate_per_s: 211.13,
            mean_dwell_base_s: 0.31,
            mean_dwell_burst_s: 0.07,
        },
        ArrivalProcess::Diurnal {
            low_rate_per_s: 3.14159,
            high_rate_per_s: 271.828,
            period_s: 1.618,
        },
    ];
    for (i, &arrivals) in processes.iter().enumerate() {
        let mut chat = TenantSpec::named("chat");
        chat.cancel_frac = 0.1;
        chat.cancel_after_s = 0.05;
        let mut agent = TenantSpec::named("agent");
        agent.shape = TenantShape::Agent;
        agent.n_in = 24;
        agent.prefix_len = 16;
        agent.weight = 0.125;
        agent.deadline_frac = 0.4;
        agent.deadline_s = 1.75;
        let sc = Scenario {
            name: format!("prop_{i}"),
            seed: 1000 + i as u64,
            n: 40,
            vocab_size: 96,
            time_scale: 1.5,
            arrivals,
            slo_ttft_s: 0.9,
            slo_tbt_s: 0.033,
            tenants: vec![chat, agent],
        };
        sc.validate().expect("constructed scenario is valid");
        let text = sc.to_text();
        let reparsed = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("process {i}: {e:#}\n{text}"));
        assert_eq!(sc, reparsed, "process {i}");
        assert_traces_identical(&sc.arrivals(), &reparsed.arrivals());
    }
}
