//! Mutation-style property suite for the `analysis/` rule catalog.
//!
//! The static verifier and the invariant auditor are only trustworthy if
//! every rule demonstrably *fires*: a checker that silently passes
//! corrupted inputs certifies nothing. Each property here builds a known
//! legal artifact (a launch stream recorded the way the engine records
//! one, or a pool snapshot taken off a live engine/batcher mid-churn),
//! applies one seeded corruption from a class keyed to a rule, and
//! asserts that exact rule reports it. The clean counterparts — a legal
//! stream, an uncorrupted snapshot, a real engine driven through
//! [`AuditExec`], a full-feature serve run — must verify with zero
//! findings.

use std::collections::HashMap;
use std::time::Instant;

use imax_llm::analysis::{
    self, audit_snapshot, verify_placement, verify_schedule, AuditExec, PoolSnapshot,
};
use imax_llm::coordinator::{
    serve_streaming, Admitted, CancelHandle, ContinuousBatcher, Request, ServeOptions,
};
use imax_llm::harness::workloads::{templated_prompt, TEMPLATE_SPAN};
use imax_llm::model::config::LinearKind;
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::{
    KvScheme, MatvecOp, ModelConfig, ModelWeights, OpKind, Phase, QuantScheme, Sampler,
};
use imax_llm::quant::GgmlType;
use imax_llm::runtime::queue::{KernelOp, Launch};
use imax_llm::runtime::{ExecSpec, PlacementRule, PlacementSpec};
use imax_llm::util::proptest_lite::Runner;
use imax_llm::util::rng::Rng;

fn tiny_weights(seed: u64) -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, seed)
}

// ---------------------------------------------------------------------
// Stream construction: record launches exactly the way the engine does
// (see `ubatch_core`), with submit boundaries at every host dependency.
// ---------------------------------------------------------------------

struct StreamBuilder {
    stream: Vec<Launch<()>>,
    seq: u64,
    submission: u64,
}

impl StreamBuilder {
    fn new() -> StreamBuilder {
        StreamBuilder { stream: Vec::new(), seq: 0, submission: 0 }
    }

    fn push(&mut self, op: KernelOp) {
        self.stream.push(Launch {
            op,
            payload: (),
            seq: self.seq,
            submission: self.submission,
        });
        self.seq += 1;
    }

    fn submit(&mut self) {
        self.submission += 1;
    }
}

fn lin(kind: LinearKind, layer: Option<usize>, batch: usize) -> KernelOp {
    KernelOp::Linear {
        op: MatvecOp {
            kind: OpKind::Linear(kind),
            layer,
            wty: GgmlType::Q8_0,
            rows: 8,
            cols: 8,
        },
        batch,
    }
}

fn attn(kind: OpKind, layer: usize) -> KernelOp {
    KernelOp::Attn {
        op: MatvecOp { kind, layer: Some(layer), wty: GgmlType::F16, rows: 8, cols: 8 },
    }
}

const N_LAYERS: usize = 3;

/// Append one legal forward step of `width` tokens: per layer
/// q/k/v | submit | attention + o_proj | submit | gate/up | submit |
/// down | submit, then LM head | submit | EndStep — the exact boundary
/// placement of `Engine::ubatch_core`.
fn push_step(b: &mut StreamBuilder, phase: Phase, pos: usize, width: usize) {
    b.push(KernelOp::BeginStep { phase, pos });
    for layer in 0..N_LAYERS {
        b.push(lin(LinearKind::QProj, Some(layer), width));
        b.push(lin(LinearKind::KProj, Some(layer), width));
        b.push(lin(LinearKind::VProj, Some(layer), width));
        b.submit();
        for _ in 0..width {
            b.push(attn(OpKind::AttnScore, layer));
            b.push(attn(OpKind::AttnMix, layer));
        }
        b.push(lin(LinearKind::OProj, Some(layer), width));
        b.submit();
        b.push(lin(LinearKind::FfnGate, Some(layer), width));
        b.push(lin(LinearKind::FfnUp, Some(layer), width));
        b.submit();
        b.push(lin(LinearKind::FfnDown, Some(layer), width));
        b.submit();
    }
    b.push(lin(LinearKind::LmHead, None, 1));
    b.submit();
    b.push(KernelOp::EndStep { phase, pos: pos + width - 1 });
    b.submit();
}

/// A single-token decode step at position 3. With `width == 1` the
/// layout is fixed: index 0 is BeginStep, layer `L` occupies
/// `1 + 9L ..= 9 + 9L` (q,k,v,score,mix,o,gate,up,down), the LM head and
/// EndStep close the stream.
fn decode_step() -> Vec<Launch<()>> {
    let mut b = StreamBuilder::new();
    push_step(&mut b, Phase::Decode, 3, 1);
    b.stream
}

fn idx_q(layer: usize) -> usize {
    1 + 9 * layer
}

#[test]
fn legal_streams_verify_clean() {
    let mut b = StreamBuilder::new();
    push_step(&mut b, Phase::Prefill, 0, 4);
    push_step(&mut b, Phase::Decode, 4, 1);
    let findings = verify_schedule(&b.stream);
    assert!(findings.is_empty(), "legal two-step stream must be clean: {findings:?}");
}

/// Every `schedule/*` rule fires on its corruption class. Classes:
/// 0 step-markers, 1 op-outside-step, 2 op-order, 3 submit-hazard,
/// 4 batch-legality, 5 seq-order.
#[test]
fn seeded_schedule_corruptions_fire_their_rules() {
    Runner::new("analysis_rules::schedule_corruptions").cases(72).run_noshrink(
        |rng| (rng.below(6), rng.next_u64()),
        |&(class, seed)| {
            let mut rng = Rng::new(seed);
            let mut s = decode_step();
            let layer = rng.below(N_LAYERS);
            let expected = match class {
                0 => {
                    match rng.below(3) {
                        // Unclosed step: drop the EndStep marker.
                        0 => {
                            s.pop();
                        }
                        // Phase flip between the step's markers.
                        1 => {
                            s.last_mut().unwrap().op =
                                KernelOp::EndStep { phase: Phase::Prefill, pos: 3 };
                        }
                        // EndStep position before the step's base.
                        _ => {
                            s.last_mut().unwrap().op =
                                KernelOp::EndStep { phase: Phase::Decode, pos: 2 };
                        }
                    }
                    "schedule/step-markers"
                }
                1 => {
                    // Kernels recorded with no enclosing step.
                    s.remove(0);
                    "schedule/op-outside-step"
                }
                2 => {
                    // Swap the gate and down launches of one layer: the
                    // walk then sees the chain run backwards (down before
                    // gate/up).
                    let (a, b) = (idx_q(layer) + 6, idx_q(layer) + 8);
                    let tmp = s[a].op.clone();
                    s[a].op = s[b].op.clone();
                    s[b].op = tmp;
                    "schedule/op-order"
                }
                3 => {
                    // Merge one layer's attention trio into its q/k/v
                    // submission: the modeled LOAD/EXEC overlap window
                    // would now span the host QK-norm/RoPE/cache-store.
                    let qsub = s[idx_q(layer)].submission;
                    for i in idx_q(layer) + 3..=idx_q(layer) + 5 {
                        s[i].submission = qsub;
                    }
                    "schedule/submit-hazard"
                }
                4 => {
                    if rng.below(2) == 0 {
                        // Empty ubatch on one projection.
                        let i = idx_q(layer) + 6;
                        if let KernelOp::Linear { batch, .. } = &mut s[i].op {
                            *batch = 0;
                        }
                    } else {
                        // Mixed ubatch widths inside the q/k/v batch.
                        let i = idx_q(layer) + 2;
                        if let KernelOp::Linear { batch, .. } = &mut s[i].op {
                            *batch = 3;
                        }
                    }
                    "schedule/batch-legality"
                }
                _ => {
                    // Swap two adjacent sequence numbers: record order lost.
                    let i = rng.below(s.len() - 1);
                    let (x, y) = (s[i].seq, s[i + 1].seq);
                    s[i].seq = y;
                    s[i + 1].seq = x;
                    "schedule/seq-order"
                }
            };
            let findings = verify_schedule(&s);
            if findings.iter().any(|f| f.rule == expected) {
                Ok(())
            } else {
                Err(format!("class {class}: expected {expected}, got {findings:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------
// Invariant auditor: corrupt a snapshot taken off a live engine/batcher
// mid-decode (live flights, shared prefix pages, budget committed).
// ---------------------------------------------------------------------

/// Snapshot of a real engine/batcher pair two rounds into serving three
/// prefix-sharing requests — every auditable structure is populated.
fn live_snapshot() -> PoolSnapshot {
    live_snapshot_kv(KvScheme::F16)
}

fn live_snapshot_kv(scheme: KvScheme) -> PoolSnapshot {
    let mut engine =
        Engine::with_paged_slots_kv(tiny_weights(29), 3, 4, Some(14), scheme);
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(4);
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
    let mut exec = NativeExec;
    for id in 0..3usize {
        // 8 shared prefix tokens (2 full pages) + a 2-token unique tail.
        let mut prompt: Vec<u32> = (0..8u32).map(|i| 5 + i).collect();
        prompt.push(40 + 3 * id as u32);
        prompt.push(41 + 3 * id as u32);
        match b.admit(Request::new(id, prompt, 6), Sampler::greedy(), 0.0, &mut exec) {
            Ok(Admitted::Active) => {}
            Ok(_) => panic!("request {id} must stay active"),
            Err(e) => panic!("request {id} must admit: {e}"),
        }
    }
    // Two decode rounds of six: flights stay live mid-decode.
    b.decode_round(&mut exec);
    b.decode_round(&mut exec);
    analysis::snapshot(b.engine(), &b)
}

/// Every `audit/*` rule fires on its corruption class. Classes:
/// 0 refcount-conservation, 1/2 free-consistency, 3 alias-validity,
/// 4 length-coverage, 5 budget-conservation, 6 chain-integrity,
/// 7 encoding-consistency.
#[test]
fn seeded_audit_corruptions_fire_their_rules() {
    let base = live_snapshot();
    // The corruptions below only mean something if the baseline is clean
    // and every structure they target is populated.
    assert!(audit_snapshot(&base).is_empty(), "live snapshot must audit clean");
    assert!(base.tables.iter().any(|t| !t.is_empty()), "live flights expected");
    assert!(!base.free.is_empty(), "spare pages expected");
    assert!(!base.chains.is_empty(), "committed prefix chains expected");

    let referenced_page = |s: &PoolSnapshot| -> u32 {
        s.tables
            .iter()
            .flat_map(|t| t.iter().copied())
            .next()
            .expect("a live flight holds pages")
    };

    Runner::new("analysis_rules::audit_corruptions").cases(64).run_noshrink(
        |rng| (rng.below(8), rng.next_u64()),
        |&(class, seed)| {
            let mut rng = Rng::new(seed);
            let mut s = base.clone();
            let expected = match class {
                0 => {
                    // Phantom reference: the count no longer matches the
                    // block tables + resident prefix entries.
                    let p = referenced_page(&s);
                    s.refs[p as usize] += 1;
                    "audit/refcount-conservation"
                }
                1 => {
                    // Double free.
                    let p = s.free[rng.below(s.free.len())];
                    s.free.push(p);
                    "audit/free-consistency"
                }
                2 => {
                    // A referenced page on the free list.
                    let p = referenced_page(&s);
                    s.free.push(p);
                    "audit/free-consistency"
                }
                3 => {
                    // Kill a page's refcount under a live block table.
                    let p = referenced_page(&s);
                    s.refs[p as usize] = 0;
                    "audit/alias-validity"
                }
                4 => {
                    // A slot claims more cached tokens than its table backs.
                    let slot = s
                        .tables
                        .iter()
                        .position(|t| !t.is_empty())
                        .expect("a live flight");
                    s.lens[slot] += s.page_size;
                    "audit/length-coverage"
                }
                5 => {
                    // Budget drift between the batcher's cached count and
                    // the recomputed distinct demand.
                    s.committed_pages += 1;
                    "audit/budget-conservation"
                }
                6 => {
                    match rng.below(3) {
                        // Stored key no longer re-hashes from its parent.
                        0 => s.chains[0].key ^= 1,
                        // Span no longer covers exactly one page.
                        1 => s.chains[0].tokens.push(0),
                        // Residency and arena backing disagree.
                        _ => {
                            let flipped = !s.chains[0].in_arena;
                            s.chains[0].in_arena = flipped;
                        }
                    }
                    "audit/chain-integrity"
                }
                _ => {
                    match rng.below(3) {
                        // The k mirror lost cells: pool backing no
                        // longer matches the page geometry.
                        0 => s.pool_backing.0 -= 1,
                        // q8_0 block arrays materialized on an f16 pool.
                        1 => s.pool_backing.2 += 34,
                        // A swapped page stores q8_0 block bytes where
                        // the f16 scheme demands the f32 mirror — it
                        // could never restore.
                        _ => s.arena_payloads.push((0xdead_beef, 0, 544)),
                    }
                    "audit/encoding-consistency"
                }
            };
            let findings = audit_snapshot(&s);
            if findings.iter().any(|f| f.rule == expected) {
                Ok(())
            } else {
                Err(format!("class {class}: expected {expected}, got {findings:?}"))
            }
        },
    );
}

/// A q8_0 pool mid-churn satisfies the whole audit catalog too — the
/// encoding rule certifies the block arrays and (dequantized) mirror
/// are sized for the quantized scheme, not the f16 default.
#[test]
fn q8_0_live_snapshot_audits_clean() {
    let s = live_snapshot_kv(KvScheme::Q8_0);
    assert_eq!(s.kv_scheme, KvScheme::Q8_0);
    assert!(s.pool_backing.2 > 0, "q8_0 pool carries block bytes");
    let findings = audit_snapshot(&s);
    assert!(findings.is_empty(), "q8_0 churn must audit clean: {findings:?}");
}

// ---------------------------------------------------------------------
// Placement coverage rules.
// ---------------------------------------------------------------------

fn rule(first: usize, last: usize) -> PlacementRule {
    PlacementRule { first, last, spec: ExecSpec::Native }
}

#[test]
fn placement_rules_fire_on_gap_overlap_and_lm_head() {
    let gap = PlacementSpec { rules: vec![rule(0, 1), rule(3, 3)] };
    let f = verify_placement(&gap, 4);
    assert!(f.iter().any(|x| x.rule == "placement/gap"), "layer 2 uncovered: {f:?}");

    let overlap = PlacementSpec { rules: vec![rule(0, 2), rule(2, 3)] };
    let f = verify_placement(&overlap, 4);
    assert!(f.iter().any(|x| x.rule == "placement/overlap"), "layer 2 double-routed: {f:?}");

    // The highest range (the LM-head home) serves no live layer of a
    // 4-layer model: logits would run on an idle part.
    let lm = PlacementSpec { rules: vec![rule(0, 3), rule(8, 15)] };
    let f = verify_placement(&lm, 4);
    assert!(f.iter().any(|x| x.rule == "placement/lm-head"), "idle LM-head home: {f:?}");

    let clean = PlacementSpec { rules: vec![rule(0, 1), rule(2, 3)] };
    assert!(verify_placement(&clean, 4).is_empty());
    assert!(verify_placement(&clean, 0).is_empty(), "zero-depth model is trivially clean");
}

// ---------------------------------------------------------------------
// Clean-path certification: the real engine through AuditExec, and a
// full-feature serve run, must produce zero findings.
// ---------------------------------------------------------------------

#[test]
fn audit_exec_certifies_real_engine_schedules() {
    let prompt: Vec<u32> = (0..12u32).map(|i| 3 + i).collect();
    let mut engine = Engine::new(tiny_weights(7));
    let mut exec = AuditExec::new(NativeExec, true);
    let out = engine.generate(&prompt, 4, &mut Sampler::greedy(), &mut exec);
    assert_eq!(out.tokens.len(), 4);
    assert!(
        exec.steps_verified() >= 4,
        "prefill chunks + 3 decode steps, saw {}",
        exec.steps_verified()
    );
    assert!(exec.findings().is_empty(), "real engine must verify clean: {:?}", exec.findings());

    // Disabled wrapper: pure passthrough, bit-identical tokens, nothing
    // recorded or verified.
    let mut engine2 = Engine::new(tiny_weights(7));
    let mut plain = AuditExec::new(NativeExec, false);
    let out2 = engine2.generate(&prompt, 4, &mut Sampler::greedy(), &mut plain);
    assert_eq!(out2.tokens, out.tokens, "auditing must not change execution");
    assert_eq!(plain.steps_verified(), 0);
}

/// The tentpole acceptance run: prefix cache + host swap + speculation +
/// mid-decode cancellation + a deadline expiry, all under `--audit`, and
/// the full rule catalog stays silent — under both KV page encodings.
#[test]
fn full_feature_audited_serve_is_clean() {
    run_full_feature_audited_serve(KvScheme::F16);
}

#[test]
fn full_feature_audited_q8_0_serve_is_clean() {
    run_full_feature_audited_serve(KvScheme::Q8_0);
}

fn run_full_feature_audited_serve(kv_quant: KvScheme) {
    let w = tiny_weights(3);
    let cfg = ModelConfig::tiny();
    // 16 shared prefix tokens = 2 pages of 8, then a templated body the
    // n-gram drafter can bite into.
    let shared: Vec<u32> = (0..16u32).map(|i| 2 + (i % 97)).collect();
    let cancels: HashMap<usize, CancelHandle> =
        [2usize, 5].iter().map(|&id| (id, CancelHandle::new())).collect();
    let requests: Vec<Request> = (0..8usize)
        .map(|id| {
            let mut prompt = shared.clone();
            prompt.extend(templated_prompt(id, 2 * TEMPLATE_SPAN, cfg.vocab_size));
            if let Some(h) = cancels.get(&id) {
                // Long enough that the mid-stream cancel below always
                // lands many rounds before completion.
                Request::new(id, prompt, 64).with_cancel(h.clone())
            } else if id == 7 {
                Request::new(id, prompt, 6).with_deadline_s(0.0)
            } else {
                Request::new(id, prompt, 10)
            }
        })
        .collect();
    let opts = ServeOptions {
        page_size: 8,
        kv_pages: Some(24),
        prefix_cache: true,
        swap_pages: 8,
        speculate: 4,
        kv_quant,
        audit: true,
        ..ServeOptions::default()
    };
    let run = serve_streaming(&w, requests, 2, &opts).expect("options validate");
    let mut seen: HashMap<usize, usize> = HashMap::new();
    for ev in run.events.iter() {
        let n = seen.entry(ev.request_id).or_insert(0);
        *n += 1;
        if *n == 2 {
            if let Some(h) = cancels.get(&ev.request_id) {
                h.cancel();
            }
        }
    }
    let rep = run.join().expect("serve must drain");
    assert_eq!(rep.completions.len(), 8);
    assert!(rep.cancelled >= 2, "both handles fired mid-decode: {rep:?}");
    assert!(
        rep.audit_findings.is_empty(),
        "full-feature churn must audit clean: {:?}",
        rep.audit_findings
    );
}
