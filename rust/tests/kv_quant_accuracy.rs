//! Accuracy harness for `--kv-quant q8_0` (quantized KV pages).
//!
//! The q8_0 pool deliberately trades bit-identity for a 64/34 ≈ 1.88×
//! cut in KV bytes, so correctness splits into two claims this suite
//! pins down:
//!
//! 1. **Bounded drift vs the exact path.** Per-row quantization error
//!    obeys the analytic q8_0 bound (`≤ max|x| × 0.005` per element),
//!    and end-to-end logits of a q8_0-KV engine stay close to the f16
//!    reference under teacher forcing (same token fed to both), with
//!    high greedy-token agreement.
//! 2. **Exactness *within* the q8_0 world.** The drift is introduced
//!    once, at commit time; everything downstream is deterministic on
//!    the canonical block bytes. Warm prefix hits, host-swap
//!    roundtrips, and speculative verify/rollback must all reproduce
//!    the plain q8_0 path token-for-token and byte-for-byte.
//!
//! Property-level churn coverage (CoW/truncate/swap under random op
//! sequences) lives in `prop_paged_kv.rs`; this file is the directed
//! accuracy story the serve `--kv-quant` flag documentation points at.

use std::time::Instant;

use imax_llm::coordinator::{
    serve_with, Admitted, ContinuousBatcher, Request, ServeOptions, SessionLog,
};
use imax_llm::harness::workloads::templated_prompt;
use imax_llm::model::engine::NativeExec;
use imax_llm::model::{
    DrafterSpec, Engine, KvCache, KvScheme, ModelConfig, ModelWeights, Phase, QuantScheme, Sampler,
};
use imax_llm::quant::q8_0;
use imax_llm::util::rng::Rng;

/// 2-layer kv_dim-32 model: the smallest shape a q8_0 pool accepts,
/// with a vocabulary small enough that greedy ties are far apart.
fn cfg() -> ModelConfig {
    ModelConfig {
        name: "kv-acc",
        n_layers: 2,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        d_ffn: 128,
        vocab_size: 16,
        qk_norm: true,
        rope_theta: 1e4,
        rms_eps: 1e-6,
        max_seq_len: 128,
    }
}

fn weights(seed: u64) -> ModelWeights {
    ModelWeights::random(&cfg(), QuantScheme::Q8_0, seed)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += f64::from(x - y) * f64::from(x - y);
        den += f64::from(x) * f64::from(x);
    }
    (num / den.max(1e-12)).sqrt()
}

// ---------------------------------------------------------------------------
// 1. Bounded drift vs the exact f16 path
// ---------------------------------------------------------------------------

#[test]
fn q8_0_row_roundtrip_error_within_analytic_bound() {
    // quantize → dequantize of one 32-wide row: per-element error is at
    // most d/2 (integer rounding) plus 127 × the f16 error of the scale
    // itself, which together stay under max|x| × 0.005 for values in
    // the f16 normal range. 0.005 holds even for a truncating (rather
    // than round-to-nearest) f32→f16 conversion.
    let mut r = Rng::new(0xACC0);
    for _ in 0..200 {
        let mut row: Vec<f32> = (0..q8_0::QK8_0)
            .map(|_| (r.below(4001) as f32 - 2000.0) / 1000.0)
            .collect();
        row[0] = 1.5; // keep amax in the f16 normal range
        let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let back = q8_0::dequantize_row_bytes(&q8_0::quantize_row_bytes(&row), row.len());
        for (&x, &y) in row.iter().zip(&back) {
            assert!(
                (x - y).abs() <= amax * 0.005,
                "roundtrip error {} exceeds the analytic bound {} (x = {x})",
                (x - y).abs(),
                amax * 0.005
            );
        }
    }
}

/// Teacher-forced drift run: prefill the same prompt on an f16-KV and a
/// q8_0-KV engine (identical weights), then decode feeding *the f16
/// path's greedy token* to both, so the KV contents stay comparable
/// step for step. Returns per-step relative-L2 logit drifts and the
/// greedy-agreement count.
fn teacher_forced_drift(steps: usize) -> (Vec<f64>, usize) {
    let mut exec = NativeExec;
    let mut e16 = Engine::with_paged_slots_kv(weights(77), 1, 8, None, KvScheme::F16);
    let mut e8 = Engine::with_paged_slots_kv(weights(77), 1, 8, None, KvScheme::Q8_0);
    let s16 = e16.open_session(Sampler::greedy()).expect("slot");
    let s8 = e8.open_session(Sampler::greedy()).expect("slot");
    let prompt = templated_prompt(3, 32, cfg().vocab_size);
    let l16 = e16.prefill_session(&s16, &prompt, 8, &mut exec);
    let l8 = e8.prefill_session(&s8, &prompt, 8, &mut exec);

    let mut drifts = vec![rel_l2(&l16, &l8)];
    let mut agree = usize::from(argmax(&l16) == argmax(&l8));
    let mut tok = argmax(&l16) as u32;
    for _ in 0..steps {
        let a = e16
            .forward_session(&s16, tok, Phase::Decode, true, &mut exec)
            .expect("logits");
        let b = e8
            .forward_session(&s8, tok, Phase::Decode, true, &mut exec)
            .expect("logits");
        drifts.push(rel_l2(&a, &b));
        agree += usize::from(argmax(&a) == argmax(&b));
        tok = argmax(&a) as u32;
    }
    (drifts, agree)
}

const DRIFT_STEPS: usize = 24;

#[test]
fn logit_drift_vs_exact_path_is_bounded() {
    let (drifts, _) = teacher_forced_drift(DRIFT_STEPS);
    // Per-element KV error is ~0.5%; through attention, two layers, and
    // the LM head it stays percent-level. 0.3 relative L2 is a loose
    // ceiling — a regression that re-quantizes pages per read or leaks
    // wrong bytes lands far above it.
    for (step, d) in drifts.iter().enumerate() {
        assert!(
            *d < 0.3,
            "step {step}: q8_0 logit drift {d:.4} breaches the 0.3 relative-L2 bound"
        );
    }
}

#[test]
fn greedy_agreement_vs_exact_path_is_high() {
    let (_, agree) = teacher_forced_drift(DRIFT_STEPS);
    let total = DRIFT_STEPS + 1; // prefill logits + each decode step
    assert!(
        agree * 10 >= total * 6,
        "greedy agreement {agree}/{total} fell below 60% under teacher forcing"
    );
}

// ---------------------------------------------------------------------------
// 2. Exactness within the q8_0 world
// ---------------------------------------------------------------------------

#[test]
fn swap_roundtrip_is_bit_identical_on_q8_0_pages() {
    // Commit two pages, register them, force both out to the host arena,
    // adopt them back, and every canonical block byte and mirror cell
    // must come back exactly — the swap path moves blocks, never
    // re-encodes.
    let cfg = cfg();
    let kv_dim = cfg.kv_dim();
    let mut c = KvCache::paged_with_scheme(&cfg, 2, 4, 4, KvScheme::Q8_0);
    c.enable_prefix_cache(0xBEEF);
    c.set_swap_capacity(4);

    let tokens: Vec<u32> = (0..8u32).collect();
    c.try_reserve(0, 8).expect("pool starts empty");
    for (pos, &t) in tokens.iter().enumerate() {
        for layer in 0..cfg.n_layers {
            let val = 0.25 + t as f32 + layer as f32 * 0.125;
            c.store(0, layer, pos, &vec![val; kv_dim], &vec![-val; kv_dim]);
        }
    }
    c.advance(0, 8).expect("reserved");
    c.register_prefix(0, &tokens);

    let snap: Vec<(Vec<u8>, Vec<u8>, f32)> = (0..8usize)
        .flat_map(|pos| {
            (0..cfg.n_layers).map(move |layer| (pos, layer)).collect::<Vec<_>>()
        })
        .map(|(pos, layer)| {
            (
                c.k_block_bytes_at(0, layer, pos).to_vec(),
                c.v_block_bytes_at(0, layer, pos).to_vec(),
                c.k_at(0, layer, pos, 0, cfg.head_dim)[0],
            )
        })
        .collect();

    // Free the slot, then fill the whole pool from slot 1: the two
    // cached pages must be evicted to the arena to satisfy the reserve.
    c.reset_slot(0);
    c.try_reserve(1, 16).expect("eviction frees the cached pages");
    c.advance(1, 16).expect("reserved");
    assert_eq!(c.swapped_out_pages(), 2, "both registered pages swap out");
    c.reset_slot(1);

    let adopted = c.adopt_prefix(0, &tokens, tokens.len());
    assert!(adopted.tokens > 0, "swapped-out prefix must still hit");
    for pos in 0..adopted.tokens {
        for layer in 0..cfg.n_layers {
            let (want_k, want_v, want_cell) = &snap[pos * cfg.n_layers + layer];
            assert_eq!(
                c.k_block_bytes_at(0, layer, pos),
                want_k.as_slice(),
                "K blocks differ after swap roundtrip at pos {pos} layer {layer}"
            );
            assert_eq!(
                c.v_block_bytes_at(0, layer, pos),
                want_v.as_slice(),
                "V blocks differ after swap roundtrip at pos {pos} layer {layer}"
            );
            assert_eq!(
                c.k_at(0, layer, pos, 0, cfg.head_dim)[0],
                *want_cell,
                "mirror differs after swap roundtrip at pos {pos} layer {layer}"
            );
        }
    }
}

#[test]
fn warm_hits_and_swap_roundtrips_do_not_change_served_tokens() {
    // Three requests on one serial slot: 0 registers its prompt, 1 (a
    // different prompt) evicts those pages into the swap arena under a
    // 4-page pool, 2 (prompt identical to 0) warm-hits via swap-in.
    // Against an unconstrained q8_0 run of the same requests (warm hit
    // stays device-resident, no swap), every request's token stream
    // must match exactly: aliased and swapped-back pages carry the same
    // canonical block bytes a cold prefill would commit.
    let w = weights(11);
    let prompt_a: Vec<u32> = (0..8).map(|i| 3 + i as u32 % 5).collect();
    let prompt_b: Vec<u32> = (0..8).map(|i| 1 + i as u32 % 7).collect();
    let reqs = || {
        vec![
            Request::new(0, prompt_a.clone(), 3),
            Request::new(1, prompt_b.clone(), 3),
            Request::new(2, prompt_a.clone(), 3),
        ]
    };
    let tight = ServeOptions {
        slots_per_worker: 1,
        page_size: 4,
        kv_pages: Some(4),
        prefix_cache: true,
        swap_pages: 4,
        kv_quant: KvScheme::Q8_0,
        ..ServeOptions::default()
    };
    let ample = ServeOptions {
        slots_per_worker: 1,
        page_size: 4,
        kv_pages: None,
        prefix_cache: true,
        kv_quant: KvScheme::Q8_0,
        ..ServeOptions::default()
    };
    let rt = serve_with(&w, reqs(), 1, &tight).expect("options validate");
    let ra = serve_with(&w, reqs(), 1, &ample).expect("options validate");
    assert!(
        rt.reuse.swap_in_pages >= 1,
        "tight run must exercise the swap-in path: {:?}",
        rt.reuse
    );
    assert!(ra.reuse.prefix_hits >= 1, "ample run must warm-hit: {:?}", ra.reuse);
    let toks = |rep: &imax_llm::coordinator::ServeReport| {
        let mut out: Vec<(usize, Vec<u32>)> =
            rep.completions.iter().map(|c| (c.id, c.tokens.clone())).collect();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    assert_eq!(
        toks(&rt),
        toks(&ra),
        "swap roundtrips / warm hits changed q8_0 token streams"
    );
}

#[test]
fn speculative_verify_and_rollback_match_sequential_q8_0_decode() {
    // Greedy verification is exact, and rollback truncates to whole
    // committed rows — neither may disturb quantized pages. The
    // templated workload (drafter-friendly) decoded with k=4 must
    // reproduce the sequential q8_0 stream token for token.
    let run = |speculate: usize| -> Vec<Vec<u32>> {
        let mut exec = NativeExec;
        let engine = Engine::with_paged_slots_kv(weights(29), 4, 8, None, KvScheme::Q8_0);
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        if speculate > 0 {
            b = b.with_speculation(speculate, DrafterSpec::default());
        }
        for id in 0..3usize {
            let req = Request::new(id, templated_prompt(id, 48, cfg().vocab_size), 24);
            assert!(matches!(
                b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
        }
        let mut logs: Vec<SessionLog> = Vec::new();
        while b.n_active() > 0 {
            logs.extend(b.decode_round(&mut exec));
        }
        logs.sort_by_key(|l| l.id);
        assert!(
            speculate == 0 || logs.iter().map(|l| l.verify_calls).sum::<usize>() > 0,
            "templated workload must trigger drafting"
        );
        logs.into_iter().map(|l| l.tokens).collect()
    };
    assert_eq!(
        run(0),
        run(4),
        "speculative decode must be bit-identical to sequential under q8_0 KV"
    );
}
