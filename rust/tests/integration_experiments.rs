//! Experiment-level integration: the paper's qualitative findings (the
//! "shape" — who wins, by roughly what factor, where crossovers fall)
//! must hold in the simulator, and the calibration anchors must stay
//! within tolerance (DESIGN.md §6).

use imax_llm::baseline::calibration::{self as cal, within_factor};
use imax_llm::baseline::GpuDevice;
use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::coordinator::scheduler::{best_lanes, lane_sweep};
use imax_llm::harness::experiments::eval_workload;
use imax_llm::harness::workloads;
use imax_llm::imax::{ImaxDevice, KernelClass, LmmConfig, TransferMode};
use imax_llm::model::config::{ModelConfig, QuantScheme};
use imax_llm::power;

fn wl(cfg: ModelConfig, scheme: QuantScheme, n_in: usize, n_out: usize) -> Workload {
    Workload {
        cfg,
        scheme,
        n_in,
        n_out,
    }
}

#[test]
fn anchor1_fpga_breakdown_within_tolerance() {
    let w = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16);
    let run = simulate_auto(&w, &ImaxDevice::fpga(2), TransferMode::Coalesced);
    let t = run.breakdown.total();
    assert!(within_factor(run.breakdown.e2e_seconds(), cal::anchor_breakdown::TOTAL_S, 1.25));
    assert!(within_factor(t.exec, cal::anchor_breakdown::EXEC_S, 1.3));
    assert!(within_factor(t.load, cal::anchor_breakdown::LOAD_S, 1.3));
    assert!(within_factor(t.host, cal::anchor_breakdown::HOST_S, 1.3));
    // The paper's headline observation: LOAD exceeds EXEC.
    assert!(t.load > t.exec, "DMA load must dominate kernel execution");
}

#[test]
fn anchor_asic_latency_and_orderings() {
    let asic = ImaxDevice::asic28(2);
    let w = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16);
    let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
    let lat = run.breakdown.e2e_seconds();
    assert!(within_factor(lat, cal::anchor_edp_06b_q3_32_16::IMAX28_LATENCY_S, 1.3));

    // EDP ordering on the compute-bound workload: IMAX < Jetson < RTX.
    let e = power::imax_energy(&asic, &LmmConfig::new(64), &run);
    let edp_imax = lat * e.pdp_j();
    let rtx = GpuDevice::rtx4090();
    let jet = GpuDevice::jetson_orin();
    let edp_rtx = rtx.e2e_seconds(&w) * rtx.energy(&w).pdp_j();
    let edp_jet = jet.e2e_seconds(&w) * jet.energy(&w).pdp_j();
    assert!(
        edp_imax < edp_jet && edp_jet < edp_rtx,
        "EDP order: imax {edp_imax} < jetson {edp_jet} < rtx {edp_rtx}"
    );
}

#[test]
fn pdp_ordering_compute_bound_and_inversion() {
    let asic = ImaxDevice::asic28(2);
    // Compute-bound: IMAX wins PDP against every GPU (paper Fig 12).
    let w = wl(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 16, 4);
    let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
    let pdp_imax = power::imax_energy(&asic, &LmmConfig::new(64), &run).pdp_j();
    for g in GpuDevice::all() {
        assert!(
            pdp_imax < g.energy(&w).pdp_j(),
            "IMAX {pdp_imax} must beat {} {}",
            g.name,
            g.energy(&w).pdp_j()
        );
    }

    // Memory-bound inversion (paper: 8B Q8_0 [32:16] PDP surges above
    // RTX and Jetson).
    let w8 = wl(ModelConfig::qwen3_8b(), QuantScheme::Q8_0, 32, 16);
    let run8 = simulate_auto(&w8, &asic, TransferMode::Coalesced);
    let pdp8 = power::imax_energy(&asic, &LmmConfig::new(64), &run8).pdp_j();
    assert!(pdp8 > GpuDevice::rtx4090().energy(&w8).pdp_j());
    assert!(pdp8 > GpuDevice::jetson_orin().energy(&w8).pdp_j());
}

#[test]
fn pdp_improvement_factor_is_large() {
    // Paper headline: PDP up to 44.4× better than the RTX 4090. Our
    // calibration yields a lower but same-order maximum; require ≥5×
    // somewhere on the grid and report the max.
    let asic = ImaxDevice::asic28(2);
    let mut best = 0.0f64;
    for w in workloads::grid() {
        let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
        let pdp = power::imax_energy(&asic, &LmmConfig::new(64), &run).pdp_j();
        let r = GpuDevice::rtx4090().energy(&w).pdp_j() / pdp;
        if r > best {
            best = r;
        }
    }
    eprintln!("max PDP improvement vs RTX 4090: {best:.1}x (paper: 44.4x)");
    assert!(best >= 5.0, "got only {best:.1}x");
}

#[test]
fn edp_crossover_jetson_wins_memory_bound() {
    // Paper: 1.7B Q8_0 [32:16] — Jetson's low latency wins EDP over IMAX.
    let asic = ImaxDevice::asic28(2);
    let w = wl(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 32, 16);
    let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
    let lat = run.breakdown.e2e_seconds();
    assert!(within_factor(lat, cal::anchor_edp_17b_q8_32_16::IMAX28_LATENCY_S, 1.3));
    let edp_imax = lat * power::imax_energy(&asic, &LmmConfig::new(64), &run).pdp_j();
    let jet = GpuDevice::jetson_orin();
    let edp_jet = jet.e2e_seconds(&w) * jet.energy(&w).pdp_j();
    assert!(edp_jet < edp_imax, "jetson {edp_jet} < imax {edp_imax}");
}

#[test]
fn gpus_always_win_latency() {
    // Paper Fig 11: "the NVIDIA RTX 4090 demonstrated the lowest latency
    // in all scenarios"; IMAX never beats it.
    for w in workloads::grid() {
        let r = eval_workload(&w);
        let rtx = r
            .devices
            .iter()
            .find(|d| d.device.contains("4090"))
            .unwrap()
            .latency_s;
        for d in &r.devices {
            assert!(rtx <= d.latency_s + 1e-9, "{}: {} vs rtx {rtx}", w.label(), d.device);
        }
    }
}

#[test]
fn table2_offload_pattern() {
    let asic = ImaxDevice::asic28(2);
    // 8B Q8_0: Q8_0 kernels 0%, total collapses to the FP16 attention
    // share (paper: 11.51%).
    let w = wl(ModelConfig::qwen3_8b(), QuantScheme::Q8_0, 32, 16);
    let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
    assert_eq!(run.stats.ratio(KernelClass::Q8_0), Some(0.0));
    let total = run.stats.total_ratio();
    assert!(
        within_factor(total, cal::anchor_offload_totals::Q8B_Q8, 1.6),
        "8B Q8_0 total offload {total} vs paper {}",
        cal::anchor_offload_totals::Q8B_Q8
    );

    // 8B Q3_K_S: Q6_K shed, Q3_K retained (paper: Q6_K 0%, Q3_K 89.09%).
    let w3 = wl(ModelConfig::qwen3_8b(), QuantScheme::Q3KS, 32, 16);
    let run3 = simulate_auto(&w3, &asic, TransferMode::Coalesced);
    assert_eq!(run3.stats.ratio(KernelClass::Q6K), Some(0.0));
    assert!(run3.stats.ratio(KernelClass::Q3K).unwrap() > 0.9);
    assert!(within_factor(
        run3.stats.total_ratio(),
        cal::anchor_offload_totals::Q8B_Q3KS,
        1.35
    ));

    // Small models: everything offloads (paper: ≥85% totals).
    for (cfg, scheme, anchor) in [
        (ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, cal::anchor_offload_totals::Q06B_Q3KS),
        (ModelConfig::qwen3_0_6b(), QuantScheme::Q8_0, cal::anchor_offload_totals::Q06B_Q8),
        (ModelConfig::qwen3_1_7b(), QuantScheme::Q3KS, cal::anchor_offload_totals::Q17B_Q3KS),
        (ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, cal::anchor_offload_totals::Q17B_Q8),
    ] {
        let w = wl(cfg, scheme, 32, 16);
        let run = simulate_auto(&w, &asic, TransferMode::Coalesced);
        assert!(
            within_factor(run.stats.total_ratio(), anchor, 1.25),
            "{}: {} vs paper {anchor}",
            w.label(),
            run.stats.total_ratio()
        );
    }
}

#[test]
fn fig14_64kb_is_pdp_optimal_for_most_workloads() {
    // Paper §V.A: "for most workloads, increasing the LMM size beyond
    // 64 KB results in a higher PDP".
    let sizes = [16usize, 32, 64, 128, 256, 512];
    let mut best_is_64_or_less = 0;
    let mut total = 0;
    for cfg in workloads::models() {
        for scheme in workloads::SCHEMES {
            let w = wl(cfg.clone(), scheme, 32, 16);
            let mut best_kb = 0;
            let mut best_pdp = f64::INFINITY;
            for &kb in &sizes {
                let dev = ImaxDevice::asic28(2).with_lmm_kb(kb);
                let run = simulate_auto(&w, &dev, TransferMode::Coalesced);
                let pdp = power::imax_energy(&dev, &LmmConfig::new(kb), &run).pdp_j();
                if pdp < best_pdp {
                    best_pdp = pdp;
                    best_kb = kb;
                }
            }
            total += 1;
            if best_kb <= 64 {
                best_is_64_or_less += 1;
            }
            // Larger LMMs must never be strictly better by a wide margin.
            let dev512 = ImaxDevice::asic28(2).with_lmm_kb(512);
            let run512 = simulate_auto(&w, &dev512, TransferMode::Coalesced);
            let pdp512 = power::imax_energy(&dev512, &LmmConfig::new(512), &run512).pdp_j();
            assert!(pdp512 > best_pdp * 0.99, "{}", w.label());
        }
    }
    assert!(
        best_is_64_or_less >= total - 1,
        "{best_is_64_or_less}/{total} workloads PDP-optimal at ≤64 KB"
    );
}

#[test]
fn fig16_two_lanes_optimal() {
    // Paper Fig 16 / §V.C: saturation at 2 lanes, degradation beyond.
    let w = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16);
    let pts = lane_sweep(&w, &ImaxDevice::fpga(2), &[1, 2, 4, 8], TransferMode::Coalesced);
    assert_eq!(best_lanes(&pts), 2);
    assert!(pts[3].e2e_s > pts[1].e2e_s, "8 lanes worse than 2");
}

#[test]
fn dma_coalescing_gains_match_paper() {
    // Paper §III.D: LOAD ×1.2, DRAIN ×4.8 vs the naive implementation.
    let w = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q8_0, 32, 16);
    let dev = ImaxDevice::fpga(2);
    let coal = simulate_auto(&w, &dev, TransferMode::Coalesced);
    let naive = simulate_auto(&w, &dev, TransferMode::Naive);
    let load_gain = naive.breakdown.total().load / coal.breakdown.total().load;
    let drain_gain = naive.breakdown.total().drain / coal.breakdown.total().drain;
    assert!(
        within_factor(load_gain, cal::anchor_coalescing::LOAD_SPEEDUP, 1.35),
        "LOAD gain {load_gain} vs paper 1.2"
    );
    assert!(
        within_factor(drain_gain, cal::anchor_coalescing::DRAIN_SPEEDUP, 1.6),
        "DRAIN gain {drain_gain} vs paper 4.8"
    );
    assert!(drain_gain > load_gain, "paper: drain benefits more");
}

#[test]
fn decode_load_bound_across_models() {
    // Fig 15: the decode phase is LOAD-bound for every offloaded
    // model/scheme (IMAX-side components only, as the paper plots them).
    let dev = ImaxDevice::fpga(2);
    for cfg in [ModelConfig::qwen3_0_6b(), ModelConfig::qwen3_1_7b()] {
        for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS] {
            let w = wl(cfg.clone(), scheme, 32, 16);
            let run = simulate_auto(&w, &dev, TransferMode::Coalesced);
            let d = run.breakdown.decode;
            assert!(
                d.load > d.exec,
                "{}: decode LOAD {} vs EXEC {}",
                w.label(),
                d.load,
                d.exec
            );
            let p = run.breakdown.prefill;
            assert!(
                p.exec > p.load,
                "{}: prefill EXEC {} vs LOAD {}",
                w.label(),
                p.exec,
                p.load
            );
        }
    }
}
