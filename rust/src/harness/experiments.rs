//! Experiment runners — one per paper table/figure (DESIGN.md §5).
//!
//! Each runner computes the full data series behind the corresponding
//! figure, prints it as an aligned table, and writes a CSV under
//! `reports/` so the series can be re-plotted. The bench targets in
//! `rust/benches/` wrap these runners.

use crate::baseline::gpu::GpuDevice;
use crate::coordinator::hybrid::{simulate, Workload, WorkloadRun};
use crate::coordinator::offload::OffloadPolicy;
use crate::coordinator::scheduler;
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;
use crate::imax::timing::Component;
use crate::model::config::QuantScheme;
use crate::power::{self, EnergyReport};
use crate::util::report::{Csv, Table};

use super::workloads;

/// Where figure CSVs land.
pub const REPORT_DIR: &str = "reports";

/// One row of the Fig 11–13 device comparison.
#[derive(Clone, Debug)]
pub struct DeviceMetrics {
    /// Platform name (the figure's x-axis label).
    pub device: String,
    /// End-to-end workload latency.
    pub latency_s: f64,
    /// Power-delay product (energy, joules).
    pub pdp_j: f64,
    /// Energy-delay product (joule-seconds).
    pub edp_js: f64,
}

/// Full result set for one workload across all five platforms.
#[derive(Clone, Debug)]
pub struct WorkloadResult {
    /// The `[n_in:n_out]` workload the row set describes.
    pub workload: Workload,
    /// One metrics row per compared platform.
    pub devices: Vec<DeviceMetrics>,
    /// The IMAX simulation behind the IMAX rows.
    pub imax_run: WorkloadRun,
}

fn imax_metrics(name: &str, dev: &ImaxDevice, run: &WorkloadRun) -> DeviceMetrics {
    let lmm = LmmConfig::new(dev.lmm_kb);
    let latency = run.breakdown.e2e_seconds();
    let e = power::imax_energy(dev, &lmm, run);
    DeviceMetrics {
        device: name.to_string(),
        latency_s: latency,
        pdp_j: e.pdp_j(),
        edp_js: latency * e.pdp_j(),
    }
}

fn gpu_metrics(dev: &GpuDevice, w: &Workload) -> DeviceMetrics {
    let latency = dev.e2e_seconds(w);
    let e: EnergyReport = dev.energy(w);
    DeviceMetrics {
        device: dev.name.to_string(),
        latency_s: latency,
        pdp_j: e.pdp_j(),
        edp_js: latency * e.pdp_j(),
    }
}

/// Evaluate one workload on all platforms (the unit of Figs 11–13).
pub fn eval_workload(w: &Workload) -> WorkloadResult {
    let fpga = ImaxDevice::fpga(2);
    let asic = ImaxDevice::asic28(2);
    let run_f = crate::coordinator::hybrid::simulate_auto(w, &fpga, TransferMode::Coalesced);
    let run_a = crate::coordinator::hybrid::simulate_auto(w, &asic, TransferMode::Coalesced);

    let mut devices = vec![
        imax_metrics("IMAX3 (FPGA)", &fpga, &run_f),
        imax_metrics("IMAX3 (28nm)", &asic, &run_a),
    ];
    for g in GpuDevice::all() {
        devices.push(gpu_metrics(&g, w));
    }
    WorkloadResult {
        workload: w.clone(),
        devices,
        imax_run: run_a,
    }
}

/// Evaluate the whole 54-workload grid once (shared by Figs 11–13).
pub fn eval_grid() -> Vec<WorkloadResult> {
    workloads::grid().iter().map(eval_workload).collect()
}

fn metric_table(
    title: &str,
    results: &[WorkloadResult],
    metric: impl Fn(&DeviceMetrics) -> f64,
    unit: &str,
) -> (Table, Csv) {
    let dev_names: Vec<String> = results[0]
        .devices
        .iter()
        .map(|d| d.device.clone())
        .collect();
    let mut header: Vec<&str> = vec!["workload"];
    let owned: Vec<String> = dev_names.iter().map(|d| format!("{d} ({unit})")).collect();
    for o in &owned {
        header.push(o);
    }
    let mut table = Table::new(title, &header);
    let mut csv_header = vec!["workload".to_string()];
    csv_header.extend(dev_names.clone());
    let mut csv = Csv::new(&csv_header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for r in results {
        let mut row = vec![r.workload.label()];
        for d in &r.devices {
            row.push(format!("{:.3}", metric(d)));
        }
        csv.row(&row);
        table.row(row);
    }
    (table, csv)
}

/// Fig 11 — E2E latency by device across the 54 workloads.
pub fn fig11(results: &[WorkloadResult]) -> Table {
    let (t, csv) = metric_table("Fig 11 — E2E latency", results, |d| d.latency_s, "s");
    csv.write_to(format!("{REPORT_DIR}/fig11_latency.csv")).ok();
    t
}

/// Fig 12 — PDP (energy) by device.
pub fn fig12(results: &[WorkloadResult]) -> Table {
    let (t, csv) = metric_table("Fig 12 — PDP (lower is better)", results, |d| d.pdp_j, "J");
    csv.write_to(format!("{REPORT_DIR}/fig12_pdp.csv")).ok();
    t
}

/// Fig 13 — EDP by device.
pub fn fig13(results: &[WorkloadResult]) -> Table {
    let (t, csv) = metric_table("Fig 13 — EDP (lower is better)", results, |d| d.edp_js, "J*s");
    csv.write_to(format!("{REPORT_DIR}/fig13_edp.csv")).ok();
    t
}

/// Fig 14 — LMM size sweep → PDP per workload (IMAX 28 nm).
pub fn fig14(lmm_sizes: &[usize]) -> Table {
    let mut header = vec!["workload".to_string()];
    header.extend(lmm_sizes.iter().map(|kb| format!("{kb}KB (J)")));
    let mut t = Table::new(
        "Fig 14 — PDP vs LMM size (IMAX 28nm)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut csv = Csv::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    // The paper sweeps the grid's representative workloads; we use the
    // [32:16] column of every model × scheme.
    for cfg in workloads::models() {
        for scheme in workloads::SCHEMES {
            let w = Workload {
                cfg: cfg.clone(),
                scheme,
                n_in: 32,
                n_out: 16,
            };
            let mut row = vec![w.label()];
            for &kb in lmm_sizes {
                let dev = ImaxDevice::asic28(2).with_lmm_kb(kb);
                let lmm = LmmConfig::new(kb);
                let policy = OffloadPolicy::for_workload(&dev, &w.cfg, w.scheme, lmm);
                let run = simulate(&w, &dev, &policy, TransferMode::Coalesced);
                let e = power::imax_energy(&dev, &lmm, &run);
                row.push(format!("{:.2}", e.pdp_j()));
            }
            csv.row(&row);
            t.row(row);
        }
    }
    csv.write_to(format!("{REPORT_DIR}/fig14_lmm_pdp.csv")).ok();
    t
}

/// Fig 15 — prefill/decode execution-time breakdown on the FPGA.
pub fn fig15() -> Table {
    let mut t = Table::new(
        "Fig 15 — IMAX execution-time breakdown (FPGA, shares of phase total)",
        &[
            "workload", "phase", "EXEC", "LOAD", "DRAIN", "CONF", "REGV", "RANGE", "HOST",
        ],
    );
    let mut csv = Csv::new(&[
        "workload", "phase", "exec", "load", "drain", "conf", "regv", "range", "host",
    ]);
    let dev = ImaxDevice::fpga(2);
    for cfg in workloads::models() {
        for scheme in workloads::SCHEMES {
            let w = Workload {
                cfg: cfg.clone(),
                scheme,
                n_in: 32,
                n_out: 16,
            };
            let run = crate::coordinator::hybrid::simulate_auto(&w, &dev, TransferMode::Coalesced);
            for (phase, cost) in [
                ("prefill", run.breakdown.prefill),
                ("decode", run.breakdown.decode),
            ] {
                let total = cost.total();
                let share = |c: Component| {
                    if total > 0.0 {
                        format!("{:.1}%", 100.0 * cost.get(c) / total)
                    } else {
                        "-".to_string()
                    }
                };
                let row = vec![
                    w.label(),
                    phase.to_string(),
                    share(Component::Exec),
                    share(Component::Load),
                    share(Component::Drain),
                    share(Component::Conf),
                    share(Component::Regv),
                    share(Component::Range),
                    share(Component::Host),
                ];
                csv.row(&row);
                t.row(row);
            }
        }
    }
    csv.write_to(format!("{REPORT_DIR}/fig15_breakdown.csv")).ok();
    t
}

/// Fig 16 — lane scalability (E2E latency and tokens/s vs lane count).
pub fn fig16() -> Table {
    let mut t = Table::new(
        "Fig 16 — lane scalability (FPGA, Qwen3-0.6B Q3_K_S [32:16])",
        &["lanes", "E2E (s)", "tokens/s", "EXEC (s)", "HOST (s)"],
    );
    let mut csv = Csv::new(&["lanes", "e2e_s", "tokens_per_s", "exec_s", "host_s"]);
    let w = Workload {
        cfg: crate::model::config::ModelConfig::qwen3_0_6b(),
        scheme: QuantScheme::Q3KS,
        n_in: 32,
        n_out: 16,
    };
    for p in scheduler::lane_sweep(
        &w,
        &ImaxDevice::fpga(2),
        &[1, 2, 4, 8],
        TransferMode::Coalesced,
    ) {
        let row = vec![
            p.lanes.to_string(),
            format!("{:.2}", p.e2e_s),
            format!("{:.3}", p.tokens_per_s),
            format!("{:.2}", p.exec_s),
            format!("{:.2}", p.host_s),
        ];
        csv.row(&row);
        t.row(row);
    }
    csv.write_to(format!("{REPORT_DIR}/fig16_scaling.csv")).ok();
    t
}

/// Table 1 — device specifications.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — physical specifications",
        &[
            "device", "CPU", "cores", "area (mm2)", "process (nm)", "freq (MHz)", "memory",
            "power (W)",
        ],
    );
    t.row(vec![
        "IMAX3 (Xilinx VPK180)".into(),
        "Arm Cortex-A72".into(),
        "64/lane".into(),
        "-".into(),
        "7".into(),
        "145".into(),
        "8GB+4GB DDR4".into(),
        "180".into(),
    ]);
    t.row(vec![
        "IMAX3 (28 nm)".into(),
        "-".into(),
        "64/lane".into(),
        "14.6".into(),
        "28".into(),
        "840".into(),
        "-".into(),
        "2.16-6.1/kernel".into(),
    ]);
    for g in GpuDevice::all() {
        t.row(vec![
            g.name.into(),
            if g.name.contains("Jetson") {
                "Arm Cortex-A78AE".into()
            } else {
                "Xeon W5-2455X".into()
            },
            g.cores.to_string(),
            format!("{}", g.chip_area_mm2),
            g.process_nm.to_string(),
            g.freq_mhz.to_string(),
            g.memory.into(),
            format!("{}", g.tdp_w),
        ]);
    }
    t
}

/// Table 2 — offload ratios per model/quant/kernel format at 64 KB.
pub fn table2() -> Table {
    use crate::imax::isa::KernelClass;
    let mut t = Table::new(
        "Table 2 — offload ratio of computational kernels (64 KB LMM)",
        &["model", "quant", "FP16", "Q3_K", "Q6_K", "Q8_0", "Total"],
    );
    let mut csv = Csv::new(&["model", "quant", "fp16", "q3_k", "q6_k", "q8_0", "total"]);
    let dev = ImaxDevice::asic28(2);
    for cfg in workloads::models() {
        for scheme in workloads::SCHEMES {
            let w = Workload {
                cfg: cfg.clone(),
                scheme,
                n_in: 32,
                n_out: 16,
            };
            let run = crate::coordinator::hybrid::simulate_auto(&w, &dev, TransferMode::Coalesced);
            let fmt = |c: KernelClass| match run.stats.ratio(c) {
                Some(r) => format!("{:.2}%", 100.0 * r),
                None => "-".to_string(),
            };
            let row = vec![
                cfg.name.to_string(),
                scheme.name().to_string(),
                fmt(KernelClass::Fp16),
                fmt(KernelClass::Q3K),
                fmt(KernelClass::Q6K),
                fmt(KernelClass::Q8_0),
                format!("{:.2}%", 100.0 * run.stats.total_ratio()),
            ];
            csv.row(&row);
            t.row(row);
        }
    }
    csv.write_to(format!("{REPORT_DIR}/table2_offload.csv")).ok();
    t
}

/// §III.D — DMA transfer-coalescing ablation (LOAD ×1.2, DRAIN ×4.8).
pub fn ablate_dma() -> Table {
    let mut t = Table::new(
        "DMA coalescing ablation (naive / coalesced)",
        &["workload", "LOAD gain", "DRAIN gain", "E2E gain"],
    );
    let dev = ImaxDevice::fpga(2);
    for cfg in workloads::models() {
        let w = Workload {
            cfg: cfg.clone(),
            scheme: QuantScheme::Q8_0,
            n_in: 32,
            n_out: 16,
        };
        let coal = crate::coordinator::hybrid::simulate_auto(&w, &dev, TransferMode::Coalesced);
        let naive = crate::coordinator::hybrid::simulate_auto(&w, &dev, TransferMode::Naive);
        let ct = coal.breakdown.total();
        let nt = naive.breakdown.total();
        t.row(vec![
            w.label(),
            format!("{:.2}x", nt.load / ct.load.max(1e-12)),
            format!("{:.2}x", nt.drain / ct.drain.max(1e-12)),
            format!(
                "{:.2}x",
                naive.breakdown.e2e_seconds() / coal.breakdown.e2e_seconds()
            ),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn quick_workload() -> Workload {
        Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q8_0,
            n_in: 8,
            n_out: 4,
        }
    }

    #[test]
    fn eval_workload_covers_five_devices() {
        let r = eval_workload(&quick_workload());
        assert_eq!(r.devices.len(), 5);
        for d in &r.devices {
            assert!(d.latency_s > 0.0, "{}", d.device);
            assert!(d.pdp_j > 0.0);
            assert!(d.edp_js > 0.0);
        }
    }

    #[test]
    fn rtx_latency_wins_imax_pdp_competitive() {
        let r = eval_workload(&Workload {
            cfg: ModelConfig::qwen3_1_7b(),
            scheme: QuantScheme::Q8_0,
            n_in: 16,
            n_out: 4,
        });
        let get = |n: &str| r.devices.iter().find(|d| d.device.contains(n)).unwrap();
        let rtx = get("4090");
        let imax28 = get("28nm");
        assert!(rtx.latency_s < imax28.latency_s, "GPU wins latency");
        assert!(imax28.pdp_j < rtx.pdp_j, "IMAX wins energy");
    }

    #[test]
    fn table1_has_five_rows() {
        let t = table1();
        assert!(t.render().lines().count() >= 8);
    }
}
