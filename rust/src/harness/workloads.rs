//! The paper's workload grid (§IV.A): 3 Qwen3 models × 2 quantized model
//! files × 9 input/output-token combinations = 54 distinct workloads,
//! "from [8:1] to [32:16]".

use crate::coordinator::hybrid::Workload;
use crate::model::config::{ModelConfig, QuantScheme};

/// Input-token counts of the grid.
pub const N_IN: [usize; 3] = [8, 16, 32];
/// Output-token counts of the grid.
pub const N_OUT: [usize; 3] = [1, 4, 16];

/// The evaluated models.
pub fn models() -> Vec<ModelConfig> {
    vec![
        ModelConfig::qwen3_0_6b(),
        ModelConfig::qwen3_1_7b(),
        ModelConfig::qwen3_8b(),
    ]
}

/// The evaluated quantized model files.
pub const SCHEMES: [QuantScheme; 2] = [QuantScheme::Q8_0, QuantScheme::Q3KS];

/// The full 54-workload grid, ordered model-major (the paper's figures
/// group by model, then quantization, then token combo).
pub fn grid() -> Vec<Workload> {
    let mut out = Vec::with_capacity(54);
    for cfg in models() {
        for scheme in SCHEMES {
            for n_in in N_IN {
                for n_out in N_OUT {
                    out.push(Workload {
                        cfg: cfg.clone(),
                        scheme,
                        n_in,
                        n_out,
                    });
                }
            }
        }
    }
    out
}

/// Span length of the repeating body in [`templated_prompt`].
pub const TEMPLATE_SPAN: usize = 8;

/// Build a prompt dominated by a repeating [`TEMPLATE_SPAN`]-token span —
/// the form-letter shape (boilerplate body, tiny unique closer) where
/// prompt-lookup n-gram drafting wins: the trailing gram of the history
/// re-occurs earlier in the prompt, so the drafter proposes the span's
/// continuation and greedy verification accepts long prefixes.
///
/// `id` perturbs the span so distinct requests stay distinct (and keep
/// distinct prefix-cache fingerprints); all tokens stay `< vocab_size`.
pub fn templated_prompt(id: usize, len: usize, vocab_size: usize) -> Vec<u32> {
    assert!(vocab_size > 0, "vocab_size must be positive");
    let span: Vec<u32> = (0..TEMPLATE_SPAN)
        .map(|j| ((id * 31 + j * 7 + 3) % vocab_size) as u32)
        .collect();
    let mut out: Vec<u32> = (0..len).map(|p| span[p % TEMPLATE_SPAN]).collect();
    if let Some(last) = out.last_mut() {
        *last = (id % vocab_size) as u32;
    }
    out
}

/// Look up one grid workload by its paper-style label components.
pub fn find(model: &str, scheme: QuantScheme, n_in: usize, n_out: usize) -> Option<Workload> {
    let cfg = ModelConfig::by_name(model)?;
    Some(Workload {
        cfg,
        scheme,
        n_in,
        n_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_54_workloads() {
        let g = grid();
        assert_eq!(g.len(), 54);
        // Range matches the paper: [8:1] .. [32:16].
        assert_eq!(g[0].n_in, 8);
        assert_eq!(g[0].n_out, 1);
        assert!(g.iter().any(|w| w.n_in == 32 && w.n_out == 16));
    }

    #[test]
    fn all_labels_unique() {
        let g = grid();
        let labels: std::collections::HashSet<String> =
            g.iter().map(|w| w.label()).collect();
        assert_eq!(labels.len(), 54);
    }

    #[test]
    fn templated_prompts_are_repetitive_distinct_and_vocab_bounded() {
        let a = templated_prompt(0, 40, 16);
        let b = templated_prompt(1, 40, 16);
        assert_eq!(a.len(), 40);
        assert!(a.iter().all(|&t| (t as usize) < 16));
        assert_ne!(a, b);
        assert_eq!(a, templated_prompt(0, 40, 16));
        // The body repeats with period TEMPLATE_SPAN (only the closer
        // token is perturbed).
        for p in TEMPLATE_SPAN..a.len() - 1 {
            assert_eq!(a[p], a[p - TEMPLATE_SPAN]);
        }
    }

    #[test]
    fn find_returns_known_workloads() {
        let w = find("1.7b", QuantScheme::Q8_0, 16, 4).unwrap();
        assert_eq!(w.label(), "Qwen3-1.7B Q8_0 [16:4]");
        assert!(find("nope", QuantScheme::Q8_0, 16, 4).is_none());
    }
}
