//! The paper's workload grid (§IV.A): 3 Qwen3 models × 2 quantized model
//! files × 9 input/output-token combinations = 54 distinct workloads,
//! "from [8:1] to [32:16]" — plus an open-loop serving trace generator
//! (exponential interarrivals with a cancellation/deadline mix) for
//! exercising the streaming front-end.

use crate::coordinator::hybrid::Workload;
use crate::coordinator::{CancelHandle, Request};
use crate::model::config::{ModelConfig, QuantScheme};
use crate::util::rng::Rng;

/// Input-token counts of the grid.
pub const N_IN: [usize; 3] = [8, 16, 32];
/// Output-token counts of the grid.
pub const N_OUT: [usize; 3] = [1, 4, 16];

/// The evaluated models.
pub fn models() -> Vec<ModelConfig> {
    vec![
        ModelConfig::qwen3_0_6b(),
        ModelConfig::qwen3_1_7b(),
        ModelConfig::qwen3_8b(),
    ]
}

/// The evaluated quantized model files.
pub const SCHEMES: [QuantScheme; 2] = [QuantScheme::Q8_0, QuantScheme::Q3KS];

/// The full 54-workload grid, ordered model-major (the paper's figures
/// group by model, then quantization, then token combo).
pub fn grid() -> Vec<Workload> {
    let mut out = Vec::with_capacity(54);
    for cfg in models() {
        for scheme in SCHEMES {
            for n_in in N_IN {
                for n_out in N_OUT {
                    out.push(Workload {
                        cfg: cfg.clone(),
                        scheme,
                        n_in,
                        n_out,
                    });
                }
            }
        }
    }
    out
}

/// Span length of the repeating body in [`templated_prompt`].
pub const TEMPLATE_SPAN: usize = 8;

/// Build a prompt dominated by a repeating [`TEMPLATE_SPAN`]-token span —
/// the form-letter shape (boilerplate body, tiny unique closer) where
/// prompt-lookup n-gram drafting wins: the trailing gram of the history
/// re-occurs earlier in the prompt, so the drafter proposes the span's
/// continuation and greedy verification accepts long prefixes.
///
/// `id` perturbs the span so distinct requests stay distinct (and keep
/// distinct prefix-cache fingerprints); all tokens stay `< vocab_size`.
pub fn templated_prompt(id: usize, len: usize, vocab_size: usize) -> Vec<u32> {
    assert!(vocab_size > 0, "vocab_size must be positive");
    let span: Vec<u32> = (0..TEMPLATE_SPAN)
        .map(|j| ((id * 31 + j * 7 + 3) % vocab_size) as u32)
        .collect();
    let mut out: Vec<u32> = (0..len).map(|p| span[p % TEMPLATE_SPAN]).collect();
    if let Some(last) = out.last_mut() {
        *last = (id % vocab_size) as u32;
    }
    out
}

/// One request in an open-loop serving trace.
pub struct Arrival {
    /// The request itself (cancel handle and deadline already wired).
    pub request: Request,
    /// Seconds after trace start at which the request enters the queue.
    pub at_s: f64,
    /// `Some` when this arrival is in the cancelled fraction: the
    /// handle wired into the request and the delay after arrival at
    /// which the load driver should fire it (mid-decode for delays
    /// shorter than the request's service time).
    pub cancel: Option<(CancelHandle, f64)>,
}

/// Shape of an open-loop arrival trace for the streaming serve
/// front-end: Poisson arrivals (seeded exponential interarrivals) of
/// templated prompts, with a fraction of requests carrying a
/// [`CancelHandle`] to fire shortly after arrival and a fraction
/// carrying an enqueue-relative deadline.
#[derive(Clone, Debug)]
pub struct OpenLoopSpec {
    /// Number of arrivals to generate.
    pub n: usize,
    /// Mean arrival rate (requests per second).
    pub rate_per_s: f64,
    /// Prompt length (templated, see [`templated_prompt`]).
    pub n_in: usize,
    /// Decode length.
    pub n_out: usize,
    /// Vocabulary bound for prompt tokens.
    pub vocab_size: usize,
    /// Fraction of requests that self-cancel (0.0 disables).
    pub cancel_frac: f64,
    /// Upper bound of the uniform post-arrival cancel delay (seconds).
    pub cancel_after_s: f64,
    /// Fraction of requests given a deadline (0.0 disables).
    pub deadline_frac: f64,
    /// The enqueue-relative deadline those requests carry (seconds).
    pub deadline_s: f64,
}

/// Generate a seeded open-loop trace: arrival offsets are a running sum
/// of `Exp(rate_per_s)` draws, so the same seed always reproduces the
/// same trace (ids, prompts, arrival times, cancel/deadline marks).
pub fn open_loop_arrivals(spec: &OpenLoopSpec, seed: u64) -> Vec<Arrival> {
    assert!(spec.rate_per_s > 0.0, "rate_per_s must be positive");
    let mut rng = Rng::new(seed);
    let mut at_s = 0.0f64;
    let mut out = Vec::with_capacity(spec.n);
    for id in 0..spec.n {
        // Inverse-CDF exponential draw; next_f64 is in [0, 1) so the
        // argument of ln stays strictly positive.
        at_s += -(1.0 - rng.next_f64()).ln() / spec.rate_per_s;
        let mut request =
            Request::new(id, templated_prompt(id, spec.n_in, spec.vocab_size), spec.n_out);
        let cancel = if rng.next_f64() < spec.cancel_frac {
            let handle = CancelHandle::new();
            request = request.with_cancel(handle.clone());
            Some((handle, rng.next_f64() * spec.cancel_after_s))
        } else {
            None
        };
        if rng.next_f64() < spec.deadline_frac {
            request = request.with_deadline_s(spec.deadline_s);
        }
        out.push(Arrival { request, at_s, cancel });
    }
    out
}

/// Look up one grid workload by its paper-style label components.
pub fn find(model: &str, scheme: QuantScheme, n_in: usize, n_out: usize) -> Option<Workload> {
    let cfg = ModelConfig::by_name(model)?;
    Some(Workload {
        cfg,
        scheme,
        n_in,
        n_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_54_workloads() {
        let g = grid();
        assert_eq!(g.len(), 54);
        // Range matches the paper: [8:1] .. [32:16].
        assert_eq!(g[0].n_in, 8);
        assert_eq!(g[0].n_out, 1);
        assert!(g.iter().any(|w| w.n_in == 32 && w.n_out == 16));
    }

    #[test]
    fn all_labels_unique() {
        let g = grid();
        let labels: std::collections::HashSet<String> =
            g.iter().map(|w| w.label()).collect();
        assert_eq!(labels.len(), 54);
    }

    #[test]
    fn templated_prompts_are_repetitive_distinct_and_vocab_bounded() {
        let a = templated_prompt(0, 40, 16);
        let b = templated_prompt(1, 40, 16);
        assert_eq!(a.len(), 40);
        assert!(a.iter().all(|&t| (t as usize) < 16));
        assert_ne!(a, b);
        assert_eq!(a, templated_prompt(0, 40, 16));
        // The body repeats with period TEMPLATE_SPAN (only the closer
        // token is perturbed).
        for p in TEMPLATE_SPAN..a.len() - 1 {
            assert_eq!(a[p], a[p - TEMPLATE_SPAN]);
        }
    }

    #[test]
    fn open_loop_trace_is_deterministic_and_well_formed() {
        let spec = OpenLoopSpec {
            n: 64,
            rate_per_s: 100.0,
            n_in: 8,
            n_out: 4,
            vocab_size: 16,
            cancel_frac: 0.25,
            cancel_after_s: 0.01,
            deadline_frac: 0.25,
            deadline_s: 0.5,
        };
        let a = open_loop_arrivals(&spec, 7);
        let b = open_loop_arrivals(&spec, 7);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.id, y.request.id);
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.at_s, y.at_s, "same seed, same trace");
            assert_eq!(x.cancel.is_some(), y.cancel.is_some());
        }
        // Arrival offsets strictly increase; prompts stay vocab-bounded.
        for w in a.windows(2) {
            assert!(w[1].at_s > w[0].at_s);
        }
        assert!(a
            .iter()
            .all(|x| x.request.prompt.iter().all(|&t| (t as usize) < 16)));
        // Both fractions land somewhere strictly between none and all.
        let cancels = a.iter().filter(|x| x.cancel.is_some()).count();
        assert!(cancels > 0 && cancels < 64, "{cancels} cancels");
        let deadlines =
            a.iter().filter(|x| x.request.deadline_s.is_some()).count();
        assert!(deadlines > 0 && deadlines < 64, "{deadlines} deadlines");
        // The cancel handle in the arrival is wired into its request.
        let c = a.iter().find(|x| x.cancel.is_some()).unwrap();
        c.cancel.as_ref().unwrap().0.cancel();
        assert!(c.request.is_cancelled(), "handle wired into the request");
        // A different seed moves the arrival process.
        let other = open_loop_arrivals(&spec, 8);
        assert!(a.iter().zip(&other).any(|(x, y)| x.at_s != y.at_s));
    }

    #[test]
    fn find_returns_known_workloads() {
        let w = find("1.7b", QuantScheme::Q8_0, 16, 4).unwrap();
        assert_eq!(w.label(), "Qwen3-1.7B Q8_0 [16:4]");
        assert!(find("nope", QuantScheme::Q8_0, 16, 4).is_none());
    }
}
