//! Experiment harness: the 54-workload grid ([`workloads`]), one
//! runner per paper table/figure ([`experiments`]), and the replayable
//! multi-tenant traffic scenarios ([`scenario`]) behind `serve
//! --scenario`. The `rust/benches/` targets and the CLI subcommands are
//! thin wrappers over these.

#![warn(missing_docs)]

pub mod experiments;
pub mod scenario;
pub mod workloads;

pub use experiments::{eval_grid, eval_workload, WorkloadResult};
pub use scenario::{ArrivalProcess, Scenario, TenantShape, TenantSpec};
