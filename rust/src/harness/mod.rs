//! Experiment harness: the 54-workload grid ([`workloads`]) and one
//! runner per paper table/figure ([`experiments`]). The `rust/benches/`
//! targets and the CLI subcommands are thin wrappers over these.

pub mod experiments;
pub mod workloads;

pub use experiments::{eval_grid, eval_workload, WorkloadResult};
