//! Seeded multi-tenant traffic scenarios for the serving front-end.
//!
//! A [`Scenario`] bundles an arrival process, a set of named tenant
//! classes with distinct request shapes, and the SLO targets the serve
//! report grades against — everything needed to replay one load
//! experiment bit-identically from a committed text file. The format is
//! a hand-rolled line-based `key value` dialect (the crate carries no
//! serde; see `docs/scenarios.md` for the full spec): scenario-level
//! keys first, then one `tenant <name>` section per class. Parsing and
//! serialization round-trip exactly — floats are printed with Rust's
//! shortest-round-trip formatting — so `parse(to_text(parse(f)))`
//! yields the same [`Scenario`] and therefore, through the seeded
//! [`Rng`], the same arrival trace to the bit.
//!
//! Three arrival processes cover the serving regimes the scheduler has
//! to survive: steady [`ArrivalProcess::Poisson`] load,
//! [`ArrivalProcess::Bursty`] Markov-modulated flash crowds (a 2-state
//! MMPP with exponential dwell times), and a smooth
//! [`ArrivalProcess::Diurnal`] ramp (sinusoidal rate sampled by
//! thinning). Three tenant shapes exercise distinct engine paths:
//! [`TenantShape::Chat`] short prompts (decode-bound),
//! [`TenantShape::Rag`] long prompts (prefill-bound), and
//! [`TenantShape::Agent`] prompts sharing a templated per-tenant prefix
//! (exercising the prefix cache — every request of the tenant opens
//! with the same `prefix_len` tokens, then a unique tail).

use crate::coordinator::{CancelHandle, Request};
use crate::harness::workloads::{templated_prompt, Arrival};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;

/// Tenant-index offset for the shared agent prefix: [`TenantShape::Agent`]
/// prompts open with `templated_prompt(AGENT_PREFIX_ID_BASE + tenant_idx,
/// prefix_len, ..)`, so every request of one tenant shares a prefix (and
/// a prefix-cache fingerprint) that no request id can collide with.
pub const AGENT_PREFIX_ID_BASE: usize = 0x5CE0_0000;

/// The arrival-time process of a [`Scenario`] (all rates in requests
/// per second of scenario time, before `time_scale` is applied).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals: exponential interarrivals at a
    /// fixed mean rate.
    Poisson {
        /// Mean arrival rate.
        rate_per_s: f64,
    },
    /// 2-state Markov-modulated Poisson process: the trace alternates
    /// between a base-rate state and a burst-rate state, dwelling in
    /// each for an exponentially distributed time. Models flash crowds
    /// without losing memorylessness (so the simulation is exact).
    Bursty {
        /// Arrival rate in the quiet state.
        base_rate_per_s: f64,
        /// Arrival rate in the burst state.
        burst_rate_per_s: f64,
        /// Mean dwell time in the quiet state (seconds).
        mean_dwell_base_s: f64,
        /// Mean dwell time in the burst state (seconds).
        mean_dwell_burst_s: f64,
    },
    /// Sinusoidal rate ramp between a low and a high rate with the
    /// given period, sampled exactly by thinning a Poisson process at
    /// the high rate. The trace starts at the low point of the cycle.
    Diurnal {
        /// Rate at the trough of the cycle.
        low_rate_per_s: f64,
        /// Rate at the peak of the cycle.
        high_rate_per_s: f64,
        /// Full cycle length (seconds).
        period_s: f64,
    },
}

/// Request shape of a tenant class (what part of the engine it leans on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantShape {
    /// Short unique prompts: decode-dominated interactive chat.
    Chat,
    /// Long unique prompts: prefill-dominated retrieval-augmented load.
    Rag,
    /// A shared templated prefix of `prefix_len` tokens followed by a
    /// unique tail: agent/tool loops that hit the prefix cache.
    Agent,
}

impl TenantShape {
    /// The format keyword for this shape.
    pub fn name(&self) -> &'static str {
        match self {
            TenantShape::Chat => "chat",
            TenantShape::Rag => "rag",
            TenantShape::Agent => "agent",
        }
    }

    /// Parse a format keyword (inverse of [`TenantShape::name`]).
    pub fn by_name(s: &str) -> Option<TenantShape> {
        match s {
            "chat" => Some(TenantShape::Chat),
            "rag" => Some(TenantShape::Rag),
            "agent" => Some(TenantShape::Agent),
            _ => None,
        }
    }
}

/// One tenant class of a [`Scenario`].
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Tenant name (a single whitespace-free token; tags every request
    /// and keys the per-tenant serve report).
    pub name: String,
    /// WFQ weight: admitted tokens are charged at `tokens / weight`, so
    /// a weight-2 tenant earns twice the service of a weight-1 tenant
    /// under contention.
    pub weight: f64,
    /// Relative share of arrivals assigned to this tenant (normalized
    /// over all tenants; it shapes the traffic mix, not the scheduler).
    pub share: f64,
    /// Request shape (see [`TenantShape`]).
    pub shape: TenantShape,
    /// Prompt length in tokens.
    pub n_in: usize,
    /// Decode length in tokens.
    pub n_out: usize,
    /// Shared-prefix length for [`TenantShape::Agent`] (must be
    /// positive and strictly below `n_in`; ignored otherwise).
    pub prefix_len: usize,
    /// Fraction of this tenant's requests that self-cancel mid-flight.
    pub cancel_frac: f64,
    /// Upper bound of the uniform post-arrival cancel delay (seconds).
    pub cancel_after_s: f64,
    /// Fraction of this tenant's requests carrying a deadline.
    pub deadline_frac: f64,
    /// The enqueue-relative deadline those requests carry (seconds).
    pub deadline_s: f64,
}

impl TenantSpec {
    /// A tenant with the format's default field values (chat shape,
    /// weight/share 1, 16-in/8-out, no cancels or deadlines).
    pub fn named(name: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight: 1.0,
            share: 1.0,
            shape: TenantShape::Chat,
            n_in: 16,
            n_out: 8,
            prefix_len: 0,
            cancel_frac: 0.0,
            cancel_after_s: 0.0,
            deadline_frac: 0.0,
            deadline_s: 0.0,
        }
    }

    /// Build this tenant's prompt for global request `id`.
    ///
    /// `tenant_idx` selects the shared agent prefix; `id` keeps every
    /// request's full prompt (and prefix-cache fingerprint) distinct.
    pub fn prompt(&self, tenant_idx: usize, id: usize, vocab_size: usize) -> Vec<u32> {
        match self.shape {
            TenantShape::Agent => {
                let mut p = templated_prompt(
                    AGENT_PREFIX_ID_BASE + tenant_idx,
                    self.prefix_len,
                    vocab_size,
                );
                p.extend(templated_prompt(id, self.n_in - self.prefix_len, vocab_size));
                p
            }
            TenantShape::Chat | TenantShape::Rag => templated_prompt(id, self.n_in, vocab_size),
        }
    }
}

/// A complete replayable traffic scenario (see the module docs and
/// `docs/scenarios.md` for the file format).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Scenario name (a single whitespace-free token).
    pub name: String,
    /// PRNG seed: same seed, same scenario, same trace — to the bit.
    pub seed: u64,
    /// Number of arrivals to generate.
    pub n: usize,
    /// Vocabulary bound for prompt tokens.
    pub vocab_size: usize,
    /// Replay speed multiplier: generated arrival times and cancel
    /// delays are divided by this, so `2.0` replays the same scenario
    /// clock twice as fast in wall time (SLO targets are not scaled).
    pub time_scale: f64,
    /// The arrival-time process.
    pub arrivals: ArrivalProcess,
    /// TTFT target graded by the serve report (0 disables).
    pub slo_ttft_s: f64,
    /// p99 time-between-tokens target graded by the serve report (0
    /// disables).
    pub slo_tbt_s: f64,
    /// The tenant classes (at least one).
    pub tenants: Vec<TenantSpec>,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario {
            name: "scenario".to_string(),
            seed: 0,
            n: 0,
            vocab_size: 512,
            time_scale: 1.0,
            arrivals: ArrivalProcess::Poisson { rate_per_s: 100.0 },
            slo_ttft_s: 0.0,
            slo_tbt_s: 0.0,
            tenants: Vec::new(),
        }
    }
}

fn token_ok(s: &str) -> bool {
    !s.is_empty() && !s.contains(char::is_whitespace) && !s.contains('#')
}

/// Strictly positive and not NaN (`NaN > 0.0` is false).
fn is_pos(v: f64) -> bool {
    v > 0.0
}

/// Non-negative and not NaN.
fn non_neg(v: f64) -> bool {
    v >= 0.0
}

/// Exponential draw with the given rate (strictly positive argument to
/// `ln` because `next_f64` is in `[0, 1)`).
fn exp_draw(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

/// The arrival-clock simulator: one instance walks a single seeded
/// trace forward, one call per arrival.
struct ArrivalClock {
    proc: ArrivalProcess,
    t: f64,
    in_burst: bool,
    /// Scenario time of the next MMPP state switch; negative until the
    /// first dwell is drawn (lazily, so `new` needs no RNG).
    next_switch: f64,
}

impl ArrivalClock {
    fn new(proc: ArrivalProcess) -> ArrivalClock {
        ArrivalClock {
            proc,
            t: 0.0,
            in_burst: false,
            next_switch: -1.0,
        }
    }

    /// Advance to (and return) the next arrival time.
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        match self.proc {
            ArrivalProcess::Poisson { rate_per_s } => {
                self.t += exp_draw(rng, rate_per_s);
            }
            ArrivalProcess::Bursty {
                base_rate_per_s,
                burst_rate_per_s,
                mean_dwell_base_s,
                mean_dwell_burst_s,
            } => {
                if self.next_switch < 0.0 {
                    self.next_switch = self.t + exp_draw(rng, 1.0 / mean_dwell_base_s);
                }
                loop {
                    let rate = if self.in_burst {
                        burst_rate_per_s
                    } else {
                        base_rate_per_s
                    };
                    let dt = exp_draw(rng, rate);
                    if self.t + dt <= self.next_switch {
                        self.t += dt;
                        break;
                    }
                    // The candidate arrival lands past the state switch:
                    // jump to the switch and redraw. Exact because the
                    // exponential is memoryless.
                    self.t = self.next_switch;
                    self.in_burst = !self.in_burst;
                    let dwell = if self.in_burst {
                        mean_dwell_burst_s
                    } else {
                        mean_dwell_base_s
                    };
                    self.next_switch = self.t + exp_draw(rng, 1.0 / dwell);
                }
            }
            ArrivalProcess::Diurnal {
                low_rate_per_s,
                high_rate_per_s,
                period_s,
            } => {
                // Thinning: candidates at the peak rate, accepted with
                // probability rate(t)/high. Exact for any rate(t) <=
                // high; the cosine ramp starts at its trough.
                loop {
                    self.t += exp_draw(rng, high_rate_per_s);
                    let phase = 0.5 - 0.5 * (2.0 * std::f64::consts::PI * self.t / period_s).cos();
                    let rate = low_rate_per_s + (high_rate_per_s - low_rate_per_s) * phase;
                    if rng.next_f64() * high_rate_per_s < rate {
                        break;
                    }
                }
            }
        }
        self.t
    }
}

fn pick_share(rng: &mut Rng, shares: &[f64], total: f64) -> usize {
    let mut t = rng.next_f64() * total;
    for (i, &s) in shares.iter().enumerate() {
        t -= s;
        if t < 0.0 {
            return i;
        }
    }
    shares.len() - 1
}

impl Scenario {
    /// Validate every field (called by [`Scenario::parse`]; call it
    /// directly on hand-built scenarios).
    pub fn validate(&self) -> Result<()> {
        if !token_ok(&self.name) {
            bail!("scenario name must be a single non-empty token: {:?}", self.name);
        }
        if self.n == 0 {
            bail!("scenario must generate at least one arrival (n >= 1)");
        }
        if self.vocab_size == 0 {
            bail!("vocab_size must be positive");
        }
        if !is_pos(self.time_scale) {
            bail!("time_scale must be positive, got {}", self.time_scale);
        }
        if !non_neg(self.slo_ttft_s) || !non_neg(self.slo_tbt_s) {
            bail!("SLO targets must be non-negative");
        }
        match self.arrivals {
            ArrivalProcess::Poisson { rate_per_s } => {
                if !is_pos(rate_per_s) {
                    bail!("poisson rate must be positive, got {rate_per_s}");
                }
            }
            ArrivalProcess::Bursty {
                base_rate_per_s,
                burst_rate_per_s,
                mean_dwell_base_s,
                mean_dwell_burst_s,
            } => {
                if !is_pos(base_rate_per_s) || !is_pos(burst_rate_per_s) {
                    bail!("bursty rates must be positive");
                }
                if !is_pos(mean_dwell_base_s) || !is_pos(mean_dwell_burst_s) {
                    bail!("bursty dwell times must be positive");
                }
            }
            ArrivalProcess::Diurnal {
                low_rate_per_s,
                high_rate_per_s,
                period_s,
            } => {
                if !non_neg(low_rate_per_s) || !is_pos(high_rate_per_s) {
                    bail!("diurnal rates must be non-negative with a positive peak");
                }
                if high_rate_per_s < low_rate_per_s {
                    bail!("diurnal peak rate must be >= trough rate");
                }
                if !is_pos(period_s) {
                    bail!("diurnal period must be positive");
                }
            }
        }
        if self.tenants.is_empty() {
            bail!("scenario needs at least one tenant section");
        }
        let mut seen = std::collections::HashSet::new();
        let mut total_share = 0.0;
        for t in &self.tenants {
            if !token_ok(&t.name) {
                bail!("tenant name must be a single non-empty token: {:?}", t.name);
            }
            if !seen.insert(t.name.as_str()) {
                bail!("duplicate tenant name {:?}", t.name);
            }
            if !is_pos(t.weight) {
                bail!("tenant {:?}: weight must be positive", t.name);
            }
            if !non_neg(t.share) {
                bail!("tenant {:?}: share must be non-negative", t.name);
            }
            total_share += t.share;
            if t.n_in == 0 || t.n_out == 0 {
                bail!("tenant {:?}: n_in and n_out must be positive", t.name);
            }
            for (key, v) in [("cancel_frac", t.cancel_frac), ("deadline_frac", t.deadline_frac)] {
                if !(0.0..=1.0).contains(&v) {
                    bail!("tenant {:?}: {key} must be in [0, 1], got {v}", t.name);
                }
            }
            if !non_neg(t.cancel_after_s) || !non_neg(t.deadline_s) {
                bail!("tenant {:?}: delays must be non-negative", t.name);
            }
            if t.shape == TenantShape::Agent && (t.prefix_len == 0 || t.prefix_len >= t.n_in) {
                bail!(
                    "tenant {:?}: agent shape needs 0 < prefix_len < n_in (got prefix_len {} \
                     with n_in {})",
                    t.name,
                    t.prefix_len,
                    t.n_in
                );
            }
        }
        if !is_pos(total_share) {
            bail!("tenant shares must sum to a positive value");
        }
        Ok(())
    }

    /// Parse the scenario text format. Scenario-level keys come before
    /// the first `tenant <name>` line; `#` starts a comment anywhere.
    pub fn parse(text: &str) -> Result<Scenario> {
        let mut sc = Scenario::default();
        let mut cur: Option<usize> = None;
        for (i, raw) in text.lines().enumerate() {
            let ln = i + 1;
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let tok: Vec<&str> = line.split_whitespace().collect();
            let key = tok[0];
            let want = |n: usize| -> Result<()> {
                if tok.len() != n + 1 {
                    bail!("line {ln}: `{key}` takes {n} value(s), got {}", tok.len() - 1);
                }
                Ok(())
            };
            let f64_at = |j: usize| -> Result<f64> {
                tok[j]
                    .parse::<f64>()
                    .with_context(|| format!("line {ln}: bad number {:?} for `{key}`", tok[j]))
            };
            let usize_at = |j: usize| -> Result<usize> {
                tok[j]
                    .parse::<usize>()
                    .with_context(|| format!("line {ln}: bad integer {:?} for `{key}`", tok[j]))
            };
            match key {
                "scenario" | "seed" | "n" | "vocab_size" | "time_scale" | "arrivals"
                | "slo_ttft_s" | "slo_tbt_s"
                    if cur.is_some() =>
                {
                    bail!("line {ln}: scenario-level key `{key}` inside a tenant section");
                }
                "scenario" => {
                    want(1)?;
                    sc.name = tok[1].to_string();
                }
                "seed" => {
                    want(1)?;
                    sc.seed = tok[1]
                        .parse::<u64>()
                        .with_context(|| format!("line {ln}: bad seed {:?}", tok[1]))?;
                }
                "n" => {
                    want(1)?;
                    sc.n = usize_at(1)?;
                }
                "vocab_size" => {
                    want(1)?;
                    sc.vocab_size = usize_at(1)?;
                }
                "time_scale" => {
                    want(1)?;
                    sc.time_scale = f64_at(1)?;
                }
                "slo_ttft_s" => {
                    want(1)?;
                    sc.slo_ttft_s = f64_at(1)?;
                }
                "slo_tbt_s" => {
                    want(1)?;
                    sc.slo_tbt_s = f64_at(1)?;
                }
                "arrivals" => {
                    if tok.len() < 2 {
                        bail!("line {ln}: `arrivals` needs a process kind");
                    }
                    sc.arrivals = match tok[1] {
                        "poisson" => {
                            want(2)?;
                            ArrivalProcess::Poisson { rate_per_s: f64_at(2)? }
                        }
                        "bursty" => {
                            want(5)?;
                            ArrivalProcess::Bursty {
                                base_rate_per_s: f64_at(2)?,
                                burst_rate_per_s: f64_at(3)?,
                                mean_dwell_base_s: f64_at(4)?,
                                mean_dwell_burst_s: f64_at(5)?,
                            }
                        }
                        "diurnal" => {
                            want(4)?;
                            ArrivalProcess::Diurnal {
                                low_rate_per_s: f64_at(2)?,
                                high_rate_per_s: f64_at(3)?,
                                period_s: f64_at(4)?,
                            }
                        }
                        other => bail!(
                            "line {ln}: unknown arrival process {other:?} \
                             (expected poisson, bursty or diurnal)"
                        ),
                    };
                }
                "tenant" => {
                    want(1)?;
                    sc.tenants.push(TenantSpec::named(tok[1]));
                    cur = Some(sc.tenants.len() - 1);
                }
                "weight" | "share" | "shape" | "n_in" | "n_out" | "prefix_len" | "cancel_frac"
                | "cancel_after_s" | "deadline_frac" | "deadline_s" => {
                    want(1)?;
                    let Some(ti) = cur else {
                        bail!("line {ln}: tenant key `{key}` before any `tenant <name>` line");
                    };
                    let shape = if key == "shape" {
                        Some(TenantShape::by_name(tok[1]).with_context(|| {
                            format!(
                                "line {ln}: unknown shape {:?} (expected chat, rag or agent)",
                                tok[1]
                            )
                        })?)
                    } else {
                        None
                    };
                    let t = &mut sc.tenants[ti];
                    match key {
                        "weight" => t.weight = f64_at(1)?,
                        "share" => t.share = f64_at(1)?,
                        "shape" => t.shape = shape.expect("parsed above"),
                        "n_in" => t.n_in = usize_at(1)?,
                        "n_out" => t.n_out = usize_at(1)?,
                        "prefix_len" => t.prefix_len = usize_at(1)?,
                        "cancel_frac" => t.cancel_frac = f64_at(1)?,
                        "cancel_after_s" => t.cancel_after_s = f64_at(1)?,
                        "deadline_frac" => t.deadline_frac = f64_at(1)?,
                        "deadline_s" => t.deadline_s = f64_at(1)?,
                        _ => unreachable!("guarded by the outer match arm"),
                    }
                }
                other => bail!("line {ln}: unknown key {other:?}"),
            }
        }
        sc.validate()?;
        Ok(sc)
    }

    /// Serialize to the text format. Floats print with Rust's shortest
    /// round-trip formatting, so `parse(to_text())` reproduces this
    /// scenario (and its arrival trace) exactly.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "scenario {}", self.name);
        let _ = writeln!(s, "seed {}", self.seed);
        let _ = writeln!(s, "n {}", self.n);
        let _ = writeln!(s, "vocab_size {}", self.vocab_size);
        let _ = writeln!(s, "time_scale {:?}", self.time_scale);
        match self.arrivals {
            ArrivalProcess::Poisson { rate_per_s } => {
                let _ = writeln!(s, "arrivals poisson {rate_per_s:?}");
            }
            ArrivalProcess::Bursty {
                base_rate_per_s,
                burst_rate_per_s,
                mean_dwell_base_s,
                mean_dwell_burst_s,
            } => {
                let _ = writeln!(
                    s,
                    "arrivals bursty {base_rate_per_s:?} {burst_rate_per_s:?} \
                     {mean_dwell_base_s:?} {mean_dwell_burst_s:?}"
                );
            }
            ArrivalProcess::Diurnal {
                low_rate_per_s,
                high_rate_per_s,
                period_s,
            } => {
                let _ = writeln!(
                    s,
                    "arrivals diurnal {low_rate_per_s:?} {high_rate_per_s:?} {period_s:?}"
                );
            }
        }
        let _ = writeln!(s, "slo_ttft_s {:?}", self.slo_ttft_s);
        let _ = writeln!(s, "slo_tbt_s {:?}", self.slo_tbt_s);
        for t in &self.tenants {
            let _ = writeln!(s);
            let _ = writeln!(s, "tenant {}", t.name);
            let _ = writeln!(s, "weight {:?}", t.weight);
            let _ = writeln!(s, "share {:?}", t.share);
            let _ = writeln!(s, "shape {}", t.shape.name());
            let _ = writeln!(s, "n_in {}", t.n_in);
            let _ = writeln!(s, "n_out {}", t.n_out);
            let _ = writeln!(s, "prefix_len {}", t.prefix_len);
            let _ = writeln!(s, "cancel_frac {:?}", t.cancel_frac);
            let _ = writeln!(s, "cancel_after_s {:?}", t.cancel_after_s);
            let _ = writeln!(s, "deadline_frac {:?}", t.deadline_frac);
            let _ = writeln!(s, "deadline_s {:?}", t.deadline_s);
        }
        s
    }

    /// Generate the scenario's seeded arrival trace: requests tagged
    /// with their tenant, arrival times walked by the configured
    /// process and divided by `time_scale`, per-tenant cancel/deadline
    /// marks. Same scenario, same trace — to the bit.
    pub fn arrivals(&self) -> Vec<Arrival> {
        let mut rng = Rng::new(self.seed);
        let mut clock = ArrivalClock::new(self.arrivals);
        let shares: Vec<f64> = self.tenants.iter().map(|t| t.share).collect();
        let total: f64 = shares.iter().sum();
        let mut out = Vec::with_capacity(self.n);
        for id in 0..self.n {
            let at = clock.next_arrival(&mut rng);
            let ti = pick_share(&mut rng, &shares, total);
            let t = &self.tenants[ti];
            let mut request = Request::new(id, t.prompt(ti, id, self.vocab_size), t.n_out)
                .with_tenant(t.name.clone());
            // Draw both marks unconditionally so a tenant's cancel mix
            // never perturbs another tenant's trace positions.
            let cancel = if rng.next_f64() < t.cancel_frac {
                let handle = CancelHandle::new();
                request = request.with_cancel(handle.clone());
                Some((handle, rng.next_f64() * t.cancel_after_s / self.time_scale))
            } else {
                let _ = rng.next_f64();
                None
            };
            if rng.next_f64() < t.deadline_frac {
                request = request.with_deadline_s(t.deadline_s);
            }
            out.push(Arrival {
                request,
                at_s: at / self.time_scale,
                cancel,
            });
        }
        out
    }

    /// The `(name, weight)` pairs for the scheduler's WFQ ledger.
    pub fn tenant_weights(&self) -> Vec<(String, f64)> {
        self.tenants
            .iter()
            .map(|t| (t.name.clone(), t.weight))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = "\
# A three-tenant mixed scenario.
scenario mixed
seed 42
n 96
vocab_size 128
time_scale 4.0
arrivals bursty 60.0 240.0 0.5 0.125
slo_ttft_s 0.5
slo_tbt_s 0.05

tenant chat
weight 2.0
share 0.5
shape chat
n_in 12
n_out 8
cancel_frac 0.1
cancel_after_s 0.05

tenant rag
share 0.25
shape rag
n_in 48
n_out 4
deadline_frac 0.5
deadline_s 2.0

tenant agents
weight 0.5
share 0.25
shape agent
n_in 32
n_out 6
prefix_len 24
";

    #[test]
    fn parse_reads_every_field() {
        let sc = Scenario::parse(EXAMPLE).unwrap();
        assert_eq!(sc.name, "mixed");
        assert_eq!(sc.seed, 42);
        assert_eq!(sc.n, 96);
        assert_eq!(sc.vocab_size, 128);
        assert_eq!(sc.time_scale, 4.0);
        assert_eq!(
            sc.arrivals,
            ArrivalProcess::Bursty {
                base_rate_per_s: 60.0,
                burst_rate_per_s: 240.0,
                mean_dwell_base_s: 0.5,
                mean_dwell_burst_s: 0.125,
            }
        );
        assert_eq!(sc.slo_ttft_s, 0.5);
        assert_eq!(sc.slo_tbt_s, 0.05);
        assert_eq!(sc.tenants.len(), 3);
        assert_eq!(sc.tenants[0].weight, 2.0);
        // Unset keys keep their defaults.
        assert_eq!(sc.tenants[1].weight, 1.0);
        assert_eq!(sc.tenants[1].cancel_frac, 0.0);
        assert_eq!(sc.tenants[2].shape, TenantShape::Agent);
        assert_eq!(sc.tenants[2].prefix_len, 24);
    }

    #[test]
    fn round_trip_is_exact() {
        let sc = Scenario::parse(EXAMPLE).unwrap();
        let sc2 = Scenario::parse(&sc.to_text()).unwrap();
        assert_eq!(sc, sc2, "parse(to_text()) reproduces the scenario");
        // And serializing again is a fixed point.
        assert_eq!(sc.to_text(), sc2.to_text());
    }

    #[test]
    fn arrival_trace_is_bit_identical_across_replays() {
        let sc = Scenario::parse(EXAMPLE).unwrap();
        let a = sc.arrivals();
        let b = Scenario::parse(&sc.to_text()).unwrap().arrivals();
        assert_eq!(a.len(), 96);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.id, y.request.id);
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.request.tenant, y.request.tenant);
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits(), "bit-identical times");
            assert_eq!(x.cancel.is_some(), y.cancel.is_some());
            match (&x.cancel, &y.cancel) {
                (Some((_, dx)), Some((_, dy))) => assert_eq!(dx.to_bits(), dy.to_bits()),
                (None, None) => {}
                _ => unreachable!(),
            }
            assert_eq!(x.request.deadline_s, y.request.deadline_s);
        }
        // A different seed moves the trace.
        let mut other = sc.clone();
        other.seed = 43;
        let c = other.arrivals();
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_s != y.at_s));
    }

    #[test]
    fn trace_is_well_formed() {
        let sc = Scenario::parse(EXAMPLE).unwrap();
        let a = sc.arrivals();
        for w in a.windows(2) {
            assert!(w[1].at_s > w[0].at_s, "arrival times strictly increase");
        }
        // Every tenant lands somewhere in the mix.
        for t in &sc.tenants {
            let n = a
                .iter()
                .filter(|x| x.request.tenant.as_deref() == Some(t.name.as_str()))
                .count();
            assert!(n > 0, "tenant {} never drawn", t.name);
            assert!(n < a.len(), "tenant {} drew everything", t.name);
        }
        // Prompt lengths match the owning tenant's shape.
        for x in &a {
            let t = sc
                .tenants
                .iter()
                .find(|t| Some(t.name.as_str()) == x.request.tenant.as_deref())
                .unwrap();
            assert_eq!(x.request.prompt.len(), t.n_in);
            assert!(x.request.prompt.iter().all(|&tok| (tok as usize) < 128));
        }
    }

    #[test]
    fn agent_requests_share_a_prefix_with_unique_tails() {
        let sc = Scenario::parse(EXAMPLE).unwrap();
        let a = sc.arrivals();
        let agents: Vec<_> = a
            .iter()
            .filter(|x| x.request.tenant.as_deref() == Some("agents"))
            .collect();
        assert!(agents.len() >= 2, "need two agent arrivals to compare");
        let plen = sc.tenants[2].prefix_len;
        for pair in agents.windows(2) {
            assert_eq!(
                pair[0].request.prompt[..plen],
                pair[1].request.prompt[..plen],
                "shared templated prefix"
            );
            assert_ne!(
                pair[0].request.prompt[plen..],
                pair[1].request.prompt[plen..],
                "unique tails keep full prompts distinct"
            );
        }
    }

    #[test]
    fn time_scale_divides_the_arrival_clock() {
        let mut sc = Scenario::parse(EXAMPLE).unwrap();
        sc.time_scale = 1.0;
        let slow = sc.arrivals();
        sc.time_scale = 4.0;
        let fast = sc.arrivals();
        for (s, f) in slow.iter().zip(&fast) {
            assert_eq!(s.at_s / 4.0, f.at_s, "same scenario clock, scaled replay");
        }
    }

    #[test]
    fn diurnal_and_poisson_processes_generate() {
        for arrivals in [
            ArrivalProcess::Poisson { rate_per_s: 200.0 },
            ArrivalProcess::Diurnal {
                low_rate_per_s: 20.0,
                high_rate_per_s: 200.0,
                period_s: 1.0,
            },
        ] {
            let sc = Scenario {
                n: 64,
                arrivals,
                tenants: vec![TenantSpec::named("only")],
                ..Scenario::default()
            };
            sc.validate().unwrap();
            let a = sc.arrivals();
            assert_eq!(a.len(), 64);
            for w in a.windows(2) {
                assert!(w[1].at_s > w[0].at_s);
            }
        }
    }

    #[test]
    fn diurnal_ramp_thins_the_trough() {
        // The cosine ramp troughs at phase 0 and peaks at phase 1/2, so
        // the trough-centered half-window (phase within a quarter period
        // of 0) must hold far fewer arrivals than the peak-centered one
        // — the thinning actually shapes the trace.
        let sc = Scenario {
            n: 400,
            seed: 9,
            arrivals: ArrivalProcess::Diurnal {
                low_rate_per_s: 5.0,
                high_rate_per_s: 400.0,
                period_s: 2.0,
            },
            tenants: vec![TenantSpec::named("only")],
            ..Scenario::default()
        };
        let a = sc.arrivals();
        let trough = a
            .iter()
            .filter(|x| {
                let phase = (x.at_s % 2.0) / 2.0;
                !(0.25..0.75).contains(&phase)
            })
            .count();
        let peak = a.len() - trough;
        assert!(
            peak > trough * 2,
            "peak half-cycle should dominate: {trough} trough vs {peak} peak"
        );
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for (text, needle) in [
            ("n 4\ntenant a\nseed 3\n", "inside a tenant section"),
            ("n 4\nweight 2\ntenant a\n", "before any `tenant"),
            ("n 4\nbogus 1\ntenant a\n", "unknown key"),
            ("n 4\narrivals sawtooth 1\ntenant a\n", "unknown arrival process"),
            ("n 4\narrivals poisson nope\ntenant a\n", "bad number"),
            ("n 0\ntenant a\n", "at least one arrival"),
            ("n 4\n", "at least one tenant"),
            ("n 4\ntenant a\ntenant a\n", "duplicate tenant"),
            ("n 4\ntenant a\nshape agent\n", "prefix_len"),
            ("n 4\ntenant a\ncancel_frac 1.5\n", "must be in [0, 1]"),
            ("n 4\ntenant a\nweight 0\n", "weight must be positive"),
        ] {
            let err = Scenario::parse(text).unwrap_err();
            assert!(
                format!("{err:#}").contains(needle),
                "{text:?} should fail with {needle:?}, got: {err:#}"
            );
        }
    }
}
