//! Host-side (non-offloaded) operators.
//!
//! Per the paper's task partitioning (Fig 4) these stay on the CPU: RMS
//! normalization, rotary position encodings, softmax, and the SwiGLU
//! activation — "complex, sequential control flow" operations whose
//! parameter counts and FLOP shares are negligible next to the dot-product
//! kernels.

/// RMSNorm: `y = x / rms(x) * w`, rms = sqrt(mean(x²) + eps).
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ss: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ss + eps).sqrt();
    for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * inv * wi;
    }
}

/// In-place RMSNorm over a slice with its own buffer reuse.
pub fn rmsnorm_inplace(x: &mut [f32], w: &[f32], eps: f32) {
    let ss: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ss + eps).sqrt();
    for (xi, &wi) in x.iter_mut().zip(w) {
        *xi *= inv * wi;
    }
}

/// Rotary position embedding applied in-place to one head vector
/// (interleaved-pair convention, matching `python/compile/model.py`).
pub fn rope_inplace(v: &mut [f32], pos: usize, theta_base: f32) {
    let d = v.len();
    debug_assert!(d % 2 == 0);
    let half = d / 2;
    for i in 0..half {
        // Pair (v[i], v[i+half]) — "rotate-half" convention used by Qwen.
        let freq = theta_base.powf(-2.0 * i as f32 / d as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (v[i], v[i + half]);
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// Numerically-stable softmax in place.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// SiLU (swish): `x * sigmoid(x)` — the gate activation of SwiGLU.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU combine: `out[i] = silu(gate[i]) * up[i]`.
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32]) {
    assert_eq!(gate.len(), up.len());
    assert_eq!(gate.len(), out.len());
    for ((o, &g), &u) in out.iter_mut().zip(gate).zip(up) {
        *o = silu(g) * u;
    }
}

/// Vector add in place (`acc += x`), the residual connections.
pub fn add_inplace(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rmsnorm_unit_output_norm() {
        let mut rng = Rng::new(20);
        let mut x = vec![0.0f32; 64];
        rng.fill_normal(&mut x, 3.0);
        let w = vec![1.0f32; 64];
        let mut y = vec![0.0f32; 64];
        rmsnorm(&x, &w, 1e-6, &mut y);
        let rms = (y.iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
    }

    #[test]
    fn rmsnorm_inplace_matches() {
        let x = vec![1.0f32, -2.0, 3.0, 0.5];
        let w = vec![0.5f32, 1.0, 2.0, 1.5];
        let mut a = vec![0.0f32; 4];
        rmsnorm(&x, &w, 1e-6, &mut a);
        let mut b = x.clone();
        rmsnorm_inplace(&mut b, &w, 1e-6);
        assert_eq!(a, b);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut rng = Rng::new(21);
        let mut v = vec![0.0f32; 64];
        rng.fill_normal(&mut v, 1.0);
        let orig = v.clone();
        let norm0: f32 = v.iter().map(|x| x * x).sum();
        rope_inplace(&mut v, 0, 1e4);
        assert_eq!(v, orig, "pos 0 is identity");
        rope_inplace(&mut v, 17, 1e4);
        let norm1: f32 = v.iter().map(|x| x * x).sum();
        assert!((norm0 - norm1).abs() / norm0 < 1e-5, "rotation preserves norm");
    }

    #[test]
    fn rope_relative_property() {
        // <rope(q,m), rope(k,n)> depends only on m-n (per frequency pair):
        // check a shifted pair yields the same dot product.
        let q0 = vec![0.3f32, -1.2, 0.7, 2.0];
        let k0 = vec![1.0f32, 0.5, -0.25, 0.8];
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let mut q1 = q0.clone();
        let mut k1 = k0.clone();
        rope_inplace(&mut q1, 5, 1e4);
        rope_inplace(&mut k1, 3, 1e4);
        let mut q2 = q0.clone();
        let mut k2 = k0.clone();
        rope_inplace(&mut q2, 9, 1e4);
        rope_inplace(&mut k2, 7, 1e4);
        assert!((dot(&q1, &k1) - dot(&q2, &k2)).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x.iter().all(|&v| v.is_finite() && v > 0.0));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.731_058_6).abs() < 1e-5);
        assert!(silu(-20.0).abs() < 1e-6);
    }

    #[test]
    fn swiglu_elementwise() {
        let gate = [0.0f32, 1.0];
        let up = [5.0f32, 2.0];
        let mut out = [0.0f32; 2];
        swiglu(&gate, &up, &mut out);
        assert_eq!(out[0], 0.0);
        assert!((out[1] - 2.0 * silu(1.0)).abs() < 1e-6);
    }
}
