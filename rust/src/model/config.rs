//! Model configurations: the Qwen3 family the paper evaluates (§III.A,
//! Table 1 workloads) plus tiny runnable presets for the functional path.
//!
//! The paper-scale configs (0.6B / 1.7B / 8B) drive the *timing/energy*
//! path — their tensor shapes determine DMA bytes, LMM fit and kernel
//! cycles. The tiny configs are architecturally identical (GQA + QK-norm +
//! RoPE + RMSNorm + SwiGLU, untied head) but small enough to run real
//! quantized inference in tests, examples and the serving driver.

use crate::quant::GgmlType;

/// Transformer architecture hyperparameters (Qwen3-style decoder).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub vocab_size: usize,
    /// Qwen3 applies RMSNorm to each q/k head (QK-Norm).
    pub qk_norm: bool,
    pub rope_theta: f32,
    pub rms_eps: f32,
    /// Maximum context the KV cache is sized for.
    pub max_seq_len: usize,
}

impl ModelConfig {
    /// Qwen3-0.6B (28 layers, d=1024, 16/8 heads, head_dim 128, ffn 3072).
    pub fn qwen3_0_6b() -> ModelConfig {
        ModelConfig {
            name: "Qwen3-0.6B",
            n_layers: 28,
            d_model: 1024,
            n_heads: 16,
            n_kv_heads: 8,
            head_dim: 128,
            d_ffn: 3072,
            vocab_size: 151_936,
            qk_norm: true,
            rope_theta: 1e6,
            rms_eps: 1e-6,
            max_seq_len: 4096,
        }
    }

    /// Qwen3-1.7B (28 layers, d=2048, 16/8 heads, ffn 6144).
    pub fn qwen3_1_7b() -> ModelConfig {
        ModelConfig {
            name: "Qwen3-1.7B",
            n_layers: 28,
            d_model: 2048,
            n_heads: 16,
            n_kv_heads: 8,
            head_dim: 128,
            d_ffn: 6144,
            vocab_size: 151_936,
            qk_norm: true,
            rope_theta: 1e6,
            rms_eps: 1e-6,
            max_seq_len: 4096,
        }
    }

    /// Qwen3-8B (36 layers, d=4096, 32/8 heads, ffn 12288).
    pub fn qwen3_8b() -> ModelConfig {
        ModelConfig {
            name: "Qwen3-8B",
            n_layers: 36,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ffn: 12288,
            vocab_size: 151_936,
            qk_norm: true,
            rope_theta: 1e6,
            rms_eps: 1e-6,
            max_seq_len: 4096,
        }
    }

    /// Tiny runnable preset (~5M params) used by unit/integration tests and
    /// the quickstart; shapes are multiples of 256 so every quant format
    /// applies. Matches `python/compile/model.py::TINY` — the AOT artifacts
    /// are lowered at these shapes.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny",
            n_layers: 4,
            d_model: 256,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 64,
            d_ffn: 768,
            vocab_size: 2048,
            qk_norm: true,
            rope_theta: 1e4,
            rms_eps: 1e-6,
            max_seq_len: 512,
        }
    }

    /// ~110M-parameter runnable preset for the end-to-end serving example
    /// (examples/serve_e2e.rs): big enough to be a "real small workload",
    /// small enough to decode interactively on CPU.
    pub fn tiny_110m() -> ModelConfig {
        ModelConfig {
            name: "tiny-110M",
            n_layers: 12,
            d_model: 768,
            n_heads: 12,
            n_kv_heads: 4,
            head_dim: 64,
            d_ffn: 2048,
            vocab_size: 4096,
            qk_norm: true,
            rope_theta: 1e4,
            rms_eps: 1e-6,
            max_seq_len: 1024,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "Qwen3-0.6B" | "0.6b" => Some(Self::qwen3_0_6b()),
            "Qwen3-1.7B" | "1.7b" => Some(Self::qwen3_1_7b()),
            "Qwen3-8B" | "8b" => Some(Self::qwen3_8b()),
            "tiny" => Some(Self::tiny()),
            "tiny-110M" | "110m" => Some(Self::tiny_110m()),
            _ => None,
        }
    }

    /// Dimension of the concatenated Q heads (= rows of q_proj).
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Dimension of the concatenated KV heads (= rows of k/v_proj).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// GQA group size (query heads per KV head).
    pub fn gqa_groups(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Total parameter count (weights only, untied embeddings).
    pub fn n_params(&self) -> usize {
        let per_layer = self.d_model * self.q_dim()      // q_proj
            + self.d_model * self.kv_dim() * 2           // k,v_proj
            + self.q_dim() * self.d_model                 // o_proj
            + self.d_model * self.d_ffn * 2               // gate, up
            + self.d_ffn * self.d_model                   // down
            + self.d_model * 2                            // 2 rmsnorms
            + if self.qk_norm { self.head_dim * 2 } else { 0 };
        self.n_layers * per_layer
            + self.vocab_size * self.d_model * 2          // embed + lm_head
            + self.d_model                                // final norm
    }
}

/// Which quantized model file the paper runs: Q8_0 or Q3_K_S (plus the
/// FP16 baseline for the tiny presets).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum QuantScheme {
    F16,
    Q8_0,
    Q3KS,
}

impl QuantScheme {
    pub fn name(self) -> &'static str {
        match self {
            QuantScheme::F16 => "F16",
            QuantScheme::Q8_0 => "Q8_0",
            QuantScheme::Q3KS => "Q3_K_S",
        }
    }

    pub fn by_name(name: &str) -> Option<QuantScheme> {
        match name.to_ascii_uppercase().as_str() {
            "F16" | "FP16" => Some(QuantScheme::F16),
            "Q8_0" | "Q8" => Some(QuantScheme::Q8_0),
            "Q3_K_S" | "Q3KS" | "Q3_K" => Some(QuantScheme::Q3KS),
            _ => None,
        }
    }
}

/// The linear-projection tensors of one decoder layer (+ the LM head).
/// These are exactly the dot-product kernels the paper offloads (Fig 4,
/// pink boxes).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum LinearKind {
    QProj,
    KProj,
    VProj,
    OProj,
    FfnGate,
    FfnUp,
    FfnDown,
    LmHead,
}

impl LinearKind {
    pub const ALL: [LinearKind; 8] = [
        LinearKind::QProj,
        LinearKind::KProj,
        LinearKind::VProj,
        LinearKind::OProj,
        LinearKind::FfnGate,
        LinearKind::FfnUp,
        LinearKind::FfnDown,
        LinearKind::LmHead,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LinearKind::QProj => "attn_q",
            LinearKind::KProj => "attn_k",
            LinearKind::VProj => "attn_v",
            LinearKind::OProj => "attn_output",
            LinearKind::FfnGate => "ffn_gate",
            LinearKind::FfnUp => "ffn_up",
            LinearKind::FfnDown => "ffn_down",
            LinearKind::LmHead => "output",
        }
    }

    /// (rows, cols) of this projection under `cfg`.
    pub fn shape(self, cfg: &ModelConfig) -> (usize, usize) {
        match self {
            LinearKind::QProj => (cfg.q_dim(), cfg.d_model),
            LinearKind::KProj | LinearKind::VProj => (cfg.kv_dim(), cfg.d_model),
            LinearKind::OProj => (cfg.d_model, cfg.q_dim()),
            LinearKind::FfnGate | LinearKind::FfnUp => (cfg.d_ffn, cfg.d_model),
            LinearKind::FfnDown => (cfg.d_model, cfg.d_ffn),
            LinearKind::LmHead => (cfg.vocab_size, cfg.d_model),
        }
    }

    /// Weight format under a quant scheme. Mirrors llama.cpp's K-quant
    /// mix: in Q3_K_S files the bulk of linears are Q3_K while `attn_v`,
    /// `ffn_down` and the LM head are kept at Q6_K ("Q6_K ... is also
    /// utilized for specific layers within the Q3_K_S models,
    /// complementing the Q3_K kernel" — paper §III.B).
    pub fn weight_type(self, scheme: QuantScheme) -> GgmlType {
        match scheme {
            QuantScheme::F16 => GgmlType::F16,
            QuantScheme::Q8_0 => GgmlType::Q8_0,
            QuantScheme::Q3KS => match self {
                LinearKind::VProj | LinearKind::FfnDown | LinearKind::LmHead => GgmlType::Q6K,
                _ => GgmlType::Q3K,
            },
        }
    }
}

/// Serialized size of all weights under a scheme (the "model file size"
/// quantity behind the paper's 4.5×-smaller-than-FP16 claim).
pub fn model_bytes(cfg: &ModelConfig, scheme: QuantScheme) -> usize {
    let mut total = 0usize;
    for kind in LinearKind::ALL {
        let (rows, cols) = kind.shape(cfg);
        let count = if kind == LinearKind::LmHead {
            1
        } else {
            cfg.n_layers
        };
        total += count * rows * kind.weight_type(scheme).row_bytes(cols);
    }
    // Embedding table (stored like the LM head's format) + norm weights
    // (always FP16 per §III.B: "we preserve the weights of the
    // normalization layers in high-precision FP16").
    total += cfg.vocab_size * LinearKind::LmHead.weight_type(scheme).row_bytes(cfg.d_model);
    let mut norm_elems = cfg.n_layers * 2 * cfg.d_model + cfg.d_model;
    if cfg.qk_norm {
        norm_elems += cfg.n_layers * 2 * cfg.head_dim;
    }
    total += GgmlType::F16.row_bytes(norm_elems);
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_names() {
        // Within ~20% of the nominal sizes (vocab-heavy small models).
        let p06 = ModelConfig::qwen3_0_6b().n_params() as f64 / 1e9;
        let p17 = ModelConfig::qwen3_1_7b().n_params() as f64 / 1e9;
        let p8 = ModelConfig::qwen3_8b().n_params() as f64 / 1e9;
        assert!((0.5..0.9).contains(&p06), "0.6B -> {p06}");
        assert!((1.4..2.2).contains(&p17), "1.7B -> {p17}");
        assert!((7.0..9.5).contains(&p8), "8B -> {p8}");
        let tiny = ModelConfig::tiny_110m().n_params() as f64 / 1e6;
        assert!((80.0..140.0).contains(&tiny), "110M -> {tiny}M");
    }

    #[test]
    fn shapes_are_block_aligned() {
        // Every linear's cols must be 256-aligned so K-quants apply.
        for cfg in [
            ModelConfig::qwen3_0_6b(),
            ModelConfig::qwen3_1_7b(),
            ModelConfig::qwen3_8b(),
            ModelConfig::tiny(),
            ModelConfig::tiny_110m(),
        ] {
            for kind in LinearKind::ALL {
                let (_, cols) = kind.shape(&cfg);
                assert_eq!(cols % 256, 0, "{} {}", cfg.name, kind.name());
            }
        }
    }

    #[test]
    fn q3ks_mixes_q3_and_q6() {
        let tys: Vec<GgmlType> = LinearKind::ALL
            .iter()
            .map(|k| k.weight_type(QuantScheme::Q3KS))
            .collect();
        assert!(tys.contains(&GgmlType::Q3K));
        assert!(tys.contains(&GgmlType::Q6K));
    }

    #[test]
    fn q3ks_file_much_smaller_than_f16() {
        let cfg = ModelConfig::qwen3_1_7b();
        let f16 = model_bytes(&cfg, QuantScheme::F16) as f64;
        let q3 = model_bytes(&cfg, QuantScheme::Q3KS) as f64;
        let q8 = model_bytes(&cfg, QuantScheme::Q8_0) as f64;
        // Paper: the Q3_K *kernel format* is ≈4.65× smaller than FP16;
        // the scheme-level file ratio is lower because attn_v/ffn_down and
        // the vocab-heavy embed/head tensors are Q6_K.
        assert!(f16 / q3 > 3.0, "ratio {}", f16 / q3);
        assert!(f16 / q8 > 1.8 && f16 / q8 < 2.0);
    }

    #[test]
    fn gqa_divides() {
        for cfg in [
            ModelConfig::qwen3_0_6b(),
            ModelConfig::qwen3_8b(),
            ModelConfig::tiny(),
        ] {
            assert_eq!(cfg.n_heads % cfg.n_kv_heads, 0);
            assert_eq!(cfg.gqa_groups(), cfg.n_heads / cfg.n_kv_heads);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(
            ModelConfig::by_name("1.7b").unwrap().name,
            "Qwen3-1.7B"
        );
        assert_eq!(QuantScheme::by_name("q3_k_s"), Some(QuantScheme::Q3KS));
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
