//! Token samplers. The paper's host CPU performs "the final Softmax
//! operation" and token selection; we provide greedy and
//! temperature/top-k sampling (llama.cpp defaults) with a seeded RNG for
//! the paper's fixed-seed reproducibility requirement.

use crate::util::rng::Rng;

/// Sampling strategy.
#[derive(Clone, Debug)]
pub enum Sampler {
    /// Argmax (deterministic).
    Greedy,
    /// Softmax sampling at `temperature` over the `top_k` best logits.
    TopK {
        temperature: f32,
        top_k: usize,
        rng: Rng,
    },
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler::Greedy
    }

    pub fn top_k(temperature: f32, top_k: usize, seed: u64) -> Sampler {
        assert!(temperature > 0.0);
        assert!(top_k >= 1);
        Sampler::TopK {
            temperature,
            top_k,
            rng: Rng::new(seed),
        }
    }

    /// Pick the next token from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        assert!(!logits.is_empty());
        match self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::TopK {
                temperature,
                top_k,
                rng,
            } => {
                let k = (*top_k).min(logits.len());
                // Partial selection of the k best (indices).
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
                // Softmax over the survivors at the given temperature.
                let max = idx
                    .iter()
                    .map(|&i| logits[i])
                    .fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f32> = idx
                    .iter()
                    .map(|&i| ((logits[i] - max) / *temperature).exp())
                    .collect();
                idx[rng.sample_weighted(&weights)] as u32
            }
        }
    }
}

fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 5.0, -2.0, 4.9]), 1);
    }

    #[test]
    fn greedy_first_max_on_tie() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[3.0, 3.0, 1.0]), 0);
    }

    #[test]
    fn topk_stays_within_top_k() {
        let mut s = Sampler::top_k(1.0, 2, 42);
        let logits = [10.0f32, -50.0, 9.5, -50.0, -50.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 2, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut s = Sampler::top_k(0.01, 5, 7);
        let logits = [1.0f32, 2.0, 3.0, 2.5, 0.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 2);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let logits: Vec<f32> = (0..100).map(|i| ((i * 37) % 19) as f32 * 0.3).collect();
        let run = |seed| {
            let mut s = Sampler::top_k(0.8, 10, seed);
            (0..20).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
