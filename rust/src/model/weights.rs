//! Model weights: quantized tensors for every layer, built either from a
//! deterministic random initialization (the functional test path — we have
//! no Qwen3 checkpoint license-free in this offline image) or loaded from
//! the crate's own binary model file ([`crate::model::file`]).
//!
//! Random-init weights exercise *exactly* the same kernels, formats,
//! shapes and byte counts as real checkpoints; only the text quality
//! differs, which none of the paper's metrics depend on (DESIGN.md §2).

use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
use crate::tensor::QTensor;
use crate::util::rng::Rng;

/// Weights of one decoder layer.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    /// QK-Norm weights (per-head RMSNorm), present when `cfg.qk_norm`.
    pub q_norm: Vec<f32>,
    pub k_norm: Vec<f32>,
    pub wq: QTensor,
    pub wk: QTensor,
    pub wv: QTensor,
    pub wo: QTensor,
    pub w_gate: QTensor,
    pub w_up: QTensor,
    pub w_down: QTensor,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub scheme: QuantScheme,
    pub embed: QTensor,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: QTensor,
}

impl ModelWeights {
    /// Build deterministic random-initialized weights (seeded).
    ///
    /// Initialization follows standard transformer practice
    /// (N(0, 0.02-ish) scaled by fan-in) so activations stay in a sane
    /// range through all layers and the quantizers see realistic
    /// distributions.
    pub fn random(cfg: &ModelConfig, scheme: QuantScheme, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let sigma_d = 0.7 / (cfg.d_model as f32).sqrt();

        let quant_linear = |name: String, kind: LinearKind, rng: &mut Rng| -> QTensor {
            let (rows, cols) = kind.shape(cfg);
            let sigma = 0.7 / (cols as f32).sqrt();
            let mut w = vec![0.0f32; rows * cols];
            rng.fill_normal(&mut w, sigma);
            QTensor::quantize(&name, kind.weight_type(scheme), rows, cols, &w)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            layers.push(LayerWeights {
                attn_norm: vec![1.0; cfg.d_model],
                ffn_norm: vec![1.0; cfg.d_model],
                q_norm: vec![1.0; if cfg.qk_norm { cfg.head_dim } else { 0 }],
                k_norm: vec![1.0; if cfg.qk_norm { cfg.head_dim } else { 0 }],
                wq: quant_linear(format!("blk.{l}.attn_q"), LinearKind::QProj, &mut rng),
                wk: quant_linear(format!("blk.{l}.attn_k"), LinearKind::KProj, &mut rng),
                wv: quant_linear(format!("blk.{l}.attn_v"), LinearKind::VProj, &mut rng),
                wo: quant_linear(format!("blk.{l}.attn_output"), LinearKind::OProj, &mut rng),
                w_gate: quant_linear(format!("blk.{l}.ffn_gate"), LinearKind::FfnGate, &mut rng),
                w_up: quant_linear(format!("blk.{l}.ffn_up"), LinearKind::FfnUp, &mut rng),
                w_down: quant_linear(format!("blk.{l}.ffn_down"), LinearKind::FfnDown, &mut rng),
            });
        }

        // Embedding table stored in the LM-head's format (llama.cpp keeps
        // token_embd quantized too); rows are dequantized on lookup.
        let emb_ty = LinearKind::LmHead.weight_type(scheme);
        let mut emb = vec![0.0f32; cfg.vocab_size * cfg.d_model];
        rng.fill_normal(&mut emb, sigma_d);
        let embed = QTensor::quantize("token_embd", emb_ty, cfg.vocab_size, cfg.d_model, &emb);

        let mut head = vec![0.0f32; cfg.vocab_size * cfg.d_model];
        rng.fill_normal(&mut head, sigma_d);
        let lm_head = QTensor::quantize(
            "output",
            emb_ty,
            cfg.vocab_size,
            cfg.d_model,
            &head,
        );

        ModelWeights {
            cfg: cfg.clone(),
            scheme,
            embed,
            layers,
            final_norm: vec![1.0; cfg.d_model],
            lm_head,
        }
    }

    /// Pick the weight tensor for a linear kind in a layer.
    pub fn linear(&self, layer: usize, kind: LinearKind) -> &QTensor {
        match kind {
            LinearKind::QProj => &self.layers[layer].wq,
            LinearKind::KProj => &self.layers[layer].wk,
            LinearKind::VProj => &self.layers[layer].wv,
            LinearKind::OProj => &self.layers[layer].wo,
            LinearKind::FfnGate => &self.layers[layer].w_gate,
            LinearKind::FfnUp => &self.layers[layer].w_up,
            LinearKind::FfnDown => &self.layers[layer].w_down,
            LinearKind::LmHead => &self.lm_head,
        }
    }

    /// Total serialized weight bytes (matches `config::model_bytes` up to
    /// the f32-vs-f16 norm storage detail).
    pub fn nbytes(&self) -> usize {
        let mut total = self.embed.nbytes() + self.lm_head.nbytes();
        for l in &self.layers {
            total += l.wq.nbytes()
                + l.wk.nbytes()
                + l.wv.nbytes()
                + l.wo.nbytes()
                + l.w_gate.nbytes()
                + l.w_up.nbytes()
                + l.w_down.nbytes();
            total += 4 * (l.attn_norm.len() + l.ffn_norm.len() + l.q_norm.len() + l.k_norm.len());
        }
        total + 4 * self.final_norm.len()
    }

    /// Dequantized embedding row for a token id.
    pub fn embed_token(&self, tok: u32) -> Vec<f32> {
        assert!((tok as usize) < self.cfg.vocab_size, "token {tok} out of vocab");
        self.embed.dequantize_row(tok as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GgmlType as T;

    #[test]
    fn tiny_builds_with_expected_types() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q3KS, 1);
        assert_eq!(w.layers.len(), cfg.n_layers);
        assert_eq!(w.layers[0].wq.ty, T::Q3K);
        assert_eq!(w.layers[0].wv.ty, T::Q6K);
        assert_eq!(w.layers[0].w_down.ty, T::Q6K);
        assert_eq!(w.lm_head.ty, T::Q6K);
        assert_eq!(w.layers[0].wq.rows, cfg.q_dim());
        assert_eq!(w.layers[0].wk.rows, cfg.kv_dim());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ModelConfig::tiny();
        let a = ModelWeights::random(&cfg, QuantScheme::Q8_0, 7);
        let b = ModelWeights::random(&cfg, QuantScheme::Q8_0, 7);
        assert_eq!(a.embed_token(5), b.embed_token(5));
        assert_eq!(a.layers[2].wq.dequantize_row(3), b.layers[2].wq.dequantize_row(3));
    }

    #[test]
    fn embedding_rows_are_sane() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 2);
        let e = w.embed_token(100);
        assert_eq!(e.len(), cfg.d_model);
        let norm = (e.iter().map(|v| v * v).sum::<f32>() / e.len() as f32).sqrt();
        assert!(norm > 0.005 && norm < 0.5, "rms {norm}");
    }

    #[test]
    fn nbytes_close_to_config_estimate() {
        let cfg = ModelConfig::tiny();
        for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS, QuantScheme::F16] {
            let w = ModelWeights::random(&cfg, scheme, 3);
            let est = crate::model::config::model_bytes(&cfg, scheme);
            let got = w.nbytes();
            let ratio = got as f64 / est as f64;
            assert!((0.95..1.1).contains(&ratio), "{}: {ratio}", scheme.name());
        }
    }
}
