//! Binary model file format (a minimal GGUF analogue).
//!
//! The paper's experiments load "the exact same quantized model files" on
//! every platform; our serving example does the same — build a model once
//! (`imax-llm build-model`), then every run loads identical bytes. The
//! format stores the config, the quant scheme, and each tensor's raw ggml
//! block bytes.
//!
//! Layout (little-endian):
//! ```text
//! magic  u32 = 0x494D5833  ("IMX3")
//! version u32 = 1
//! config: name_len u32, name bytes, 10 × u32 fields
//! scheme: u8 (0=F16, 1=Q8_0, 2=Q3_K_S)
//! n_tensors u32
//! per tensor: name_len u32, name, ty u8, rows u64, cols u64,
//!             nbytes u64, raw block bytes
//! ```

use std::fs;
use std::io::{self, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::{ModelConfig, QuantScheme};
use crate::model::weights::{LayerWeights, ModelWeights};
use crate::quant::{GgmlType, QK_K};
use crate::tensor::{QTensor, TensorData};
use crate::util::f16::F16;

const MAGIC: u32 = 0x494D_5833;
const VERSION: u32 = 1;

fn ty_code(ty: GgmlType) -> u8 {
    match ty {
        GgmlType::F32 => 0,
        GgmlType::F16 => 1,
        GgmlType::Q8_0 => 2,
        GgmlType::Q6K => 3,
        GgmlType::Q3K => 4,
    }
}

fn ty_from_code(c: u8) -> Result<GgmlType> {
    Ok(match c {
        0 => GgmlType::F32,
        1 => GgmlType::F16,
        2 => GgmlType::Q8_0,
        3 => GgmlType::Q6K,
        4 => GgmlType::Q3K,
        _ => bail!("unknown tensor type code {c}"),
    })
}

fn scheme_code(s: QuantScheme) -> u8 {
    match s {
        QuantScheme::F16 => 0,
        QuantScheme::Q8_0 => 1,
        QuantScheme::Q3KS => 2,
    }
}

fn scheme_from_code(c: u8) -> Result<QuantScheme> {
    Ok(match c {
        0 => QuantScheme::F16,
        1 => QuantScheme::Q8_0,
        2 => QuantScheme::Q3KS,
        _ => bail!("unknown scheme code {c}"),
    })
}

/// Serialize a tensor's data to raw ggml block bytes.
fn tensor_bytes(t: &QTensor) -> Vec<u8> {
    match &t.data {
        TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        TensorData::F16(v) => v.iter().flat_map(|h| h.0.to_le_bytes()).collect(),
        TensorData::Q8_0(b) => crate::quant::q8_0::to_bytes(b),
        TensorData::Q6K(b) => crate::quant::q6_k::to_bytes(b),
        TensorData::Q3K(b) => crate::quant::q3_k::to_bytes(b),
    }
}

/// Rebuild a tensor from raw block bytes.
fn tensor_from_bytes(
    name: &str,
    ty: GgmlType,
    rows: usize,
    cols: usize,
    bytes: &[u8],
) -> Result<QTensor> {
    let expect = rows * ty.row_bytes(cols);
    if bytes.len() != expect {
        bail!("tensor {name}: expected {expect} bytes, got {}", bytes.len());
    }
    let data = match ty {
        GgmlType::F32 => TensorData::F32(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        GgmlType::F16 => TensorData::F16(
            bytes
                .chunks_exact(2)
                .map(|c| F16(u16::from_le_bytes([c[0], c[1]])))
                .collect(),
        ),
        GgmlType::Q8_0 => TensorData::Q8_0(crate::quant::q8_0::from_bytes(bytes)),
        GgmlType::Q6K => TensorData::Q6K(crate::quant::q6_k::from_bytes(bytes)),
        GgmlType::Q3K => TensorData::Q3K(crate::quant::q3_k::from_bytes(bytes)),
    };
    Ok(QTensor {
        name: name.to_string(),
        ty,
        rows,
        cols,
        data,
    })
}

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u8(&mut self, v: u8) -> io::Result<()> {
        self.w.write_all(&[v])
    }
    fn u32(&mut self, v: u32) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f32(&mut self, v: f32) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn str(&mut self, s: &str) -> io::Result<()> {
        self.u32(s.len() as u32)?;
        self.w.write_all(s.as_bytes())
    }
    fn tensor(&mut self, t: &QTensor) -> io::Result<()> {
        self.str(&t.name)?;
        self.u8(ty_code(t.ty))?;
        self.u64(t.rows as u64)?;
        self.u64(t.cols as u64)?;
        let bytes = tensor_bytes(t);
        self.u64(bytes.len() as u64)?;
        self.w.write_all(&bytes)
    }
    fn f32_vec(&mut self, name: &str, v: &[f32]) -> io::Result<()> {
        self.str(name)?;
        self.u8(ty_code(GgmlType::F32))?;
        self.u64(1)?;
        self.u64(v.len() as u64)?;
        self.u64(4 * v.len() as u64)?;
        for &x in v {
            self.f32(x)?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f32(&mut self) -> Result<f32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("string length {n} unreasonable (corrupt file?)");
        }
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        Ok(String::from_utf8(b)?)
    }
    fn tensor(&mut self) -> Result<QTensor> {
        let name = self.str()?;
        let ty = ty_from_code(self.u8()?)?;
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let nbytes = self.u64()? as usize;
        if nbytes > 8usize << 30 {
            bail!("tensor {name}: {nbytes} bytes unreasonable");
        }
        let mut bytes = vec![0u8; nbytes];
        self.r.read_exact(&mut bytes)?;
        tensor_from_bytes(&name, ty, rows, cols, &bytes)
    }
    fn f32_vec(&mut self, expect_name: &str) -> Result<Vec<f32>> {
        let t = self.tensor()?;
        if t.name != expect_name {
            bail!("expected tensor '{expect_name}', found '{}'", t.name);
        }
        match t.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor '{expect_name}' is not F32"),
        }
    }
}

/// Save model weights to `path`.
pub fn save(weights: &ModelWeights, path: impl AsRef<Path>) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        fs::create_dir_all(dir)?;
    }
    let f = fs::File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = Writer {
        w: io::BufWriter::new(f),
    };
    let cfg = &weights.cfg;
    w.u32(MAGIC)?;
    w.u32(VERSION)?;
    w.str(cfg.name)?;
    for v in [
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        cfg.d_ffn,
        cfg.vocab_size,
        cfg.qk_norm as usize,
        cfg.max_seq_len,
    ] {
        w.u32(v as u32)?;
    }
    w.f32(cfg.rope_theta)?;
    w.f32(cfg.rms_eps)?;
    w.u8(scheme_code(weights.scheme))?;
    let n_tensors = 1 /*embed*/ + 1 /*head*/ + 1 /*final norm*/
        + weights.layers.len() * 11;
    w.u32(n_tensors as u32)?;
    w.tensor(&weights.embed)?;
    for (l, lw) in weights.layers.iter().enumerate() {
        w.f32_vec(&format!("blk.{l}.attn_norm"), &lw.attn_norm)?;
        w.f32_vec(&format!("blk.{l}.ffn_norm"), &lw.ffn_norm)?;
        w.f32_vec(&format!("blk.{l}.q_norm"), &lw.q_norm)?;
        w.f32_vec(&format!("blk.{l}.k_norm"), &lw.k_norm)?;
        w.tensor(&lw.wq)?;
        w.tensor(&lw.wk)?;
        w.tensor(&lw.wv)?;
        w.tensor(&lw.wo)?;
        w.tensor(&lw.w_gate)?;
        w.tensor(&lw.w_up)?;
        w.tensor(&lw.w_down)?;
    }
    w.f32_vec("final_norm", &weights.final_norm)?;
    w.tensor(&weights.lm_head)?;
    Ok(())
}

/// Load model weights from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<ModelWeights> {
    let f = fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut r = Reader {
        r: io::BufReader::new(f),
    };
    if r.u32()? != MAGIC {
        bail!("bad magic (not an imax-llm model file)");
    }
    let ver = r.u32()?;
    if ver != VERSION {
        bail!("unsupported version {ver}");
    }
    let name = r.str()?;
    let mut fields = [0u32; 9];
    for f in fields.iter_mut() {
        *f = r.u32()?;
    }
    let rope_theta = r.f32()?;
    let rms_eps = r.f32()?;
    // Leak the name into 'static (model files are loaded once per process).
    let static_name: &'static str = Box::leak(name.into_boxed_str());
    let cfg = ModelConfig {
        name: static_name,
        n_layers: fields[0] as usize,
        d_model: fields[1] as usize,
        n_heads: fields[2] as usize,
        n_kv_heads: fields[3] as usize,
        head_dim: fields[4] as usize,
        d_ffn: fields[5] as usize,
        vocab_size: fields[6] as usize,
        qk_norm: fields[7] != 0,
        max_seq_len: fields[8] as usize,
        rope_theta,
        rms_eps,
    };
    let scheme = scheme_from_code(r.u8()?)?;
    let n_tensors = r.u32()? as usize;
    let expect = 3 + cfg.n_layers * 11;
    if n_tensors != expect {
        bail!("expected {expect} tensors, file has {n_tensors}");
    }
    let embed = r.tensor()?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let attn_norm = r.f32_vec(&format!("blk.{l}.attn_norm"))?;
        let ffn_norm = r.f32_vec(&format!("blk.{l}.ffn_norm"))?;
        let q_norm = r.f32_vec(&format!("blk.{l}.q_norm"))?;
        let k_norm = r.f32_vec(&format!("blk.{l}.k_norm"))?;
        layers.push(LayerWeights {
            attn_norm,
            ffn_norm,
            q_norm,
            k_norm,
            wq: r.tensor()?,
            wk: r.tensor()?,
            wv: r.tensor()?,
            wo: r.tensor()?,
            w_gate: r.tensor()?,
            w_up: r.tensor()?,
            w_down: r.tensor()?,
        });
    }
    let final_norm = r.f32_vec("final_norm")?;
    let lm_head = r.tensor()?;
    Ok(ModelWeights {
        cfg,
        scheme,
        embed,
        layers,
        final_norm,
        lm_head,
    })
}

// QK_K referenced to keep the import local to block-size sanity checks.
const _: () = assert!(QK_K == 256);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::engine::{Engine, NativeExec};
    use crate::model::graph::Phase;
    use crate::model::weights::ModelWeights;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("imax_llm_test_{tag}_{}.bin", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip_identical_logits() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q3KS, 99);
        let path = tmpfile("roundtrip");
        save(&w, &path).unwrap();
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.scheme, QuantScheme::Q3KS);
        assert_eq!(loaded.cfg.d_model, cfg.d_model);

        let mut e1 = Engine::new(w);
        let mut e2 = Engine::new(loaded);
        let l1 = e1.forward(7, Phase::Prefill, true, &mut NativeExec).unwrap();
        let l2 = e2.forward(7, Phase::Prefill, true, &mut NativeExec).unwrap();
        assert_eq!(l1, l2, "bit-identical logits after save/load");
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPE-not-a-model-file").unwrap();
        let err = load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn truncated_file_rejected() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 1);
        let path = tmpfile("trunc");
        save(&w, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
