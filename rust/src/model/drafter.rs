//! Self-contained draft proposers for speculative decoding.
//!
//! The paper's decode phase streams every weight for one token of useful
//! work; speculative decoding amortizes that stream by verifying k
//! drafted tokens in a single ubatch (see
//! [`crate::model::engine::Engine::try_verify_session`]). The drafter
//! side must therefore be *cheap* — no second model, no extra weight
//! traffic. [`NgramDrafter`] is the classic prompt-lookup scheme: match
//! the trailing n-gram of the sequence history (prompt + generated
//! tokens) against an earlier occurrence and propose the continuation
//! that followed it. Templated / retrieval-heavy prompts repeat long
//! spans, so the continuation is often exactly what the model will emit
//! — and a wrong draft costs only the rolled-back verify positions,
//! never correctness (verification accepts the longest prefix the
//! session's own sampler agrees with, bit-identical to vanilla decode).
//!
//! When the prefix cache is on, the cache's committed token spans form a
//! shared corpus ([`crate::model::kv_cache::KvCache::prefix_token_spans`])
//! searched after the sequence's own history — a sequence can draft from
//! spans another request taught the server.

use anyhow::{bail, Result};

/// Default trailing n-gram length (`--drafter ngram` without `:N`).
pub const DEFAULT_NGRAM: usize = 3;

/// Drafter selection, parseable from the `--drafter` CLI flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrafterSpec {
    /// Prompt-lookup n-gram drafting with trailing grams of up to
    /// `max_n` tokens.
    Ngram { max_n: usize },
}

impl Default for DrafterSpec {
    fn default() -> DrafterSpec {
        DrafterSpec::Ngram { max_n: DEFAULT_NGRAM }
    }
}

impl DrafterSpec {
    /// Parse a `--drafter` selector: `ngram` or `ngram:<N>` (N in
    /// 1..=16).
    pub fn parse(s: &str) -> Result<DrafterSpec> {
        let s = s.trim().to_ascii_lowercase();
        if s == "ngram" {
            return Ok(DrafterSpec::default());
        }
        if let Some(n) = s.strip_prefix("ngram:") {
            let max_n: usize = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad n-gram length '{n}' (use ngram:<N>)"))?;
            if !(1..=16).contains(&max_n) {
                bail!("n-gram length {max_n} out of range (1..=16)");
            }
            return Ok(DrafterSpec::Ngram { max_n });
        }
        bail!("unknown drafter '{s}' (available: ngram[:N])");
    }

    /// Canonical selector string; [`DrafterSpec::parse`] round-trips it.
    pub fn name(&self) -> String {
        match self {
            DrafterSpec::Ngram { max_n } => format!("ngram:{max_n}"),
        }
    }

    pub fn build(&self) -> NgramDrafter {
        match *self {
            DrafterSpec::Ngram { max_n } => NgramDrafter::new(max_n),
        }
    }
}

/// Prompt-lookup n-gram drafter: propose the continuation of the most
/// recent earlier occurrence of the history's trailing n-gram, trying
/// the longest gram first. Deterministic and stateless — the same
/// history always drafts the same tokens.
#[derive(Clone, Copy, Debug)]
pub struct NgramDrafter {
    pub max_n: usize,
}

impl NgramDrafter {
    pub fn new(max_n: usize) -> NgramDrafter {
        assert!(max_n >= 1, "n-gram length must be at least 1");
        NgramDrafter { max_n }
    }

    /// Propose up to `k` continuation tokens for a sequence whose full
    /// history (prompt followed by generated tokens) is `history`.
    /// `corpus` is an optional set of extra token spans to fall back to
    /// (the prefix cache's committed pages); pass `&[]` without it.
    /// Returns an empty draft when no gram matches — the caller falls
    /// back to vanilla decode for that round.
    pub fn draft(&self, history: &[u32], corpus: &[Vec<u32>], k: usize) -> Vec<u32> {
        if k == 0 || history.is_empty() {
            return Vec::new();
        }
        // Longest gram first, within the sequence's own history: the
        // match must end before the suffix so a continuation exists.
        let cap = self.max_n.min(history.len().saturating_sub(1));
        for n in (1..=cap).rev() {
            let suffix = &history[history.len() - n..];
            // Most recent occurrence wins (recency beats frequency for
            // templated text).
            for i in (0..history.len() - n).rev() {
                if &history[i..i + n] == suffix {
                    let end = (i + n + k).min(history.len());
                    return history[i + n..end].to_vec();
                }
            }
        }
        // Corpus fallback: spans someone else's prompt committed.
        let cap = self.max_n.min(history.len());
        for n in (1..=cap).rev() {
            let suffix = &history[history.len() - n..];
            for span in corpus {
                if span.len() <= n {
                    continue;
                }
                for i in (0..span.len() - n).rev() {
                    if &span[i..i + n] == suffix {
                        let end = (i + n + k).min(span.len());
                        return span[i + n..end].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_roundtrips() {
        assert_eq!(DrafterSpec::parse("ngram").unwrap(), DrafterSpec::Ngram { max_n: 3 });
        let s = DrafterSpec::parse("ngram:5").unwrap();
        assert_eq!(s, DrafterSpec::Ngram { max_n: 5 });
        assert_eq!(s.name(), "ngram:5");
        assert_eq!(DrafterSpec::parse(&s.name()).unwrap(), s);
        assert!(DrafterSpec::parse("ngram:0").is_err());
        assert!(DrafterSpec::parse("ngram:17").is_err());
        assert!(DrafterSpec::parse("ngram:x").is_err());
        assert!(DrafterSpec::parse("model").is_err());
        assert!(DrafterSpec::parse("").is_err());
    }

    #[test]
    fn drafts_continuation_of_repeated_span() {
        let d = NgramDrafter::new(3);
        // "7 8 9" occurred earlier, followed by "10 11 12".
        let history = [7u32, 8, 9, 10, 11, 12, 1, 2, 7, 8, 9];
        assert_eq!(d.draft(&history, &[], 3), vec![10, 11, 12]);
        assert_eq!(d.draft(&history, &[], 2), vec![10, 11], "k caps the draft");
        // Continuation stops at the end of the matched span's history.
        let short = [5u32, 6, 5];
        assert_eq!(d.draft(&short, &[], 4), vec![6]);
    }

    #[test]
    fn most_recent_match_wins() {
        let d = NgramDrafter::new(2);
        // "1 2" appears twice with different continuations: the later
        // occurrence (→ 9) is proposed.
        let history = [1u32, 2, 3, 4, 1, 2, 9, 0, 1, 2];
        assert_eq!(d.draft(&history, &[], 1), vec![9]);
    }

    #[test]
    fn longest_gram_preferred() {
        let d = NgramDrafter::new(3);
        // Trailing "2 3": a 2-gram match (→ 7) exists, but the 3-gram
        // "1 2 3" (→ 8) is more specific and wins.
        let history = [4u32, 2, 3, 7, 1, 2, 3, 8, 0, 1, 2, 3];
        assert_eq!(d.draft(&history, &[], 1), vec![8]);
    }

    #[test]
    fn pure_repetition_extends() {
        let d = NgramDrafter::new(3);
        let history = [5u32, 5, 5, 5];
        // Overlapping self-match: repetition keeps proposing the token.
        assert_eq!(d.draft(&history, &[], 2), vec![5]);
    }

    #[test]
    fn corpus_fallback_after_history_miss() {
        let d = NgramDrafter::new(2);
        let history = [1u32, 2];
        // No earlier occurrence in history; a corpus span continues it.
        let corpus = vec![vec![9u32, 1, 2, 30, 31, 32]];
        assert_eq!(d.draft(&history, &corpus, 2), vec![30, 31]);
        // History matches take priority over the corpus.
        let history2 = [1u32, 2, 40, 1, 2];
        assert_eq!(d.draft(&history2, &corpus, 1), vec![40]);
    }

    #[test]
    fn no_match_drafts_nothing() {
        let d = NgramDrafter::new(3);
        assert!(d.draft(&[1, 2, 3, 4], &[], 4).is_empty());
        assert!(d.draft(&[], &[], 4).is_empty());
        assert!(d.draft(&[1, 1, 2], &[], 0).is_empty());
    }
}
