//! Kernel-call graph enumeration.
//!
//! One token step of llama.cpp-style inference is a fixed sequence of
//! dot-product kernels (the pink boxes of paper Fig 4). This module
//! enumerates that sequence *symbolically* — shapes, formats, byte sizes —
//! so the same code path drives both the functional engine (which executes
//! each op) and the IMAX timing model (which costs each op at paper scale
//! without materializing weights). Keeping one enumeration is what makes
//! the Table 2 offload ratios and the Fig 15 breakdowns consistent with
//! the real engine.

use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
use crate::model::kv_cache::KvScheme;
use crate::quant::GgmlType;
use crate::tensor::ActQuant;

/// LLM inference phase (the paper's central workload duality).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Phase {
    /// Parallel prompt processing.
    Prefill,
    /// Sequential token generation.
    Decode,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// Direction of a host↔device KV page transfer (prefix-cache swap
/// eviction / restore). The instrumented backend charges these through
/// the DMA [`crate::imax::dma::TransferMode`] cost model so oversubscribed
/// serving keeps the paper's transfer bottleneck visible.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum KvSwapDir {
    /// Host arena → device pool (swap-in on a prefix hit).
    In,
    /// Device pool → host arena (eviction under page pressure).
    Out,
}

impl KvSwapDir {
    pub fn name(self) -> &'static str {
        match self {
            KvSwapDir::In => "swap-in",
            KvSwapDir::Out => "swap-out",
        }
    }
}

/// What a dot-product kernel instance computes.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum OpKind {
    /// A weight-matrix projection (weights streamed from model memory).
    Linear(LinearKind),
    /// Attention scores q·Kᵀ over the KV cache (FP16 kernel on IMAX).
    AttnScore,
    /// Attention mix probs·V over the KV cache (FP16 kernel on IMAX).
    AttnMix,
}

impl OpKind {
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Linear(k) => k.name(),
            OpKind::AttnScore => "attn_score",
            OpKind::AttnMix => "attn_mix",
        }
    }
}

/// One dot-product kernel instance: `rows` dot products of length `cols`
/// in weight format `wty`.
#[derive(Clone, Debug)]
pub struct MatvecOp {
    pub kind: OpKind,
    /// Layer index, or `None` for the LM head.
    pub layer: Option<usize>,
    pub wty: GgmlType,
    pub rows: usize,
    pub cols: usize,
}

impl MatvecOp {
    /// Multiply–accumulate count.
    pub fn macs(&self) -> u64 {
        self.rows as u64 * self.cols as u64
    }

    /// Number of individual dot-product invocations (the unit the paper's
    /// Table 2 offload ratios are expressed in).
    pub fn dots(&self) -> u64 {
        self.rows as u64
    }

    /// Bytes of the weight-side operand (per-token DMA traffic if
    /// offloaded: model weights for linears, KV cache for attention).
    pub fn weight_bytes(&self) -> usize {
        self.rows * self.wty.row_bytes(self.cols)
    }

    /// Bytes of the quantized activation operand.
    pub fn act_bytes(&self) -> usize {
        match self.wty {
            GgmlType::F32 | GgmlType::F16 => 4 * self.cols,
            GgmlType::Q8_0 => GgmlType::Q8_0.row_bytes(self.cols),
            GgmlType::Q6K | GgmlType::Q3K => {
                // Q8_K activations: 4 + 256 + 32 bytes per 256 elements.
                crate::util::ceil_div(self.cols, 256) * crate::quant::q8_k::BLOCK_BYTES
            }
        }
    }

    /// Bytes of the f32 result vector drained back to the host.
    pub fn out_bytes(&self) -> usize {
        4 * self.rows
    }

    /// Number of distinct input arrays the host must coalesce for DMA
    /// (§III.D: "the Q8_0 kernel requires four distinct input arrays").
    pub fn dma_operand_arrays(&self) -> usize {
        match self.wty {
            // weights + activations (both f32/f16 contiguous).
            GgmlType::F32 | GgmlType::F16 => 2,
            // w qs + w scales + act qs + act scales.
            GgmlType::Q8_0 => 4,
            // + packed high bits / sub-block scales.
            GgmlType::Q6K | GgmlType::Q3K => 6,
        }
    }
}

/// Enumerate the dot-product kernels for one token at context position
/// `pos` (0-based; attention sees `pos + 1` cached entries including the
/// current token). `logits` selects whether the LM head runs (llama.cpp
/// computes logits for the last prefill token and every decode token).
/// The KV cache is priced f16 (the reference [`KvScheme::F16`] pool);
/// see [`ops_for_token_kv`] for encoding-aware attention pricing.
pub fn ops_for_token(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    pos: usize,
    logits: bool,
) -> Vec<MatvecOp> {
    ops_for_token_kv(cfg, scheme, KvScheme::F16, pos, logits)
}

/// [`ops_for_token`] parameterized over the KV pool's page encoding:
/// the attention score/mix ops carry `kv.elem_type()` as their weight
/// format, so their streamed-byte and LOAD-cost accounting charge the
/// compressed size under [`KvScheme::Q8_0`] (the same `wty` the engine
/// records through `MatvecExec::attn`).
pub fn ops_for_token_kv(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    kv: KvScheme,
    pos: usize,
    logits: bool,
) -> Vec<MatvecOp> {
    let ctx = pos + 1;
    let mut ops = Vec::with_capacity(cfg.n_layers * 9 + 1);
    for layer in 0..cfg.n_layers {
        let l = Some(layer);
        for kind in [
            LinearKind::QProj,
            LinearKind::KProj,
            LinearKind::VProj,
        ] {
            let (rows, cols) = kind.shape(cfg);
            ops.push(MatvecOp {
                kind: OpKind::Linear(kind),
                layer: l,
                wty: kind.weight_type(scheme),
                rows,
                cols,
            });
        }
        // Attention over the KV cache: n_heads score-dots of length
        // head_dim per cached position, then the value mix. The weight
        // side is the cache itself, so its format follows the pool's
        // page encoding (f16 reference, or q8_0 blocks at 8.5
        // bits/element).
        ops.push(MatvecOp {
            kind: OpKind::AttnScore,
            layer: l,
            wty: kv.elem_type(),
            rows: cfg.n_heads * ctx,
            cols: cfg.head_dim,
        });
        ops.push(MatvecOp {
            kind: OpKind::AttnMix,
            layer: l,
            wty: kv.elem_type(),
            rows: cfg.n_heads * cfg.head_dim,
            cols: ctx,
        });
        for kind in [
            LinearKind::OProj,
            LinearKind::FfnGate,
            LinearKind::FfnUp,
            LinearKind::FfnDown,
        ] {
            let (rows, cols) = kind.shape(cfg);
            ops.push(MatvecOp {
                kind: OpKind::Linear(kind),
                layer: l,
                wty: kind.weight_type(scheme),
                rows,
                cols,
            });
        }
    }
    if logits {
        let (rows, cols) = LinearKind::LmHead.shape(cfg);
        ops.push(MatvecOp {
            kind: OpKind::Linear(LinearKind::LmHead),
            layer: None,
            wty: LinearKind::LmHead.weight_type(scheme),
            rows,
            cols,
        });
    }
    ops
}

/// Enumerate all token steps of a `[n_in : n_out]` workload (the paper's
/// token-I/O notation): prefill positions `0..n_in`, then decode positions
/// `n_in..n_in+n_out`.
pub fn ops_for_workload(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    n_in: usize,
    n_out: usize,
) -> Vec<(Phase, Vec<MatvecOp>)> {
    ops_for_workload_kv(cfg, scheme, KvScheme::F16, n_in, n_out)
}

/// [`ops_for_workload`] with encoding-aware attention pricing (see
/// [`ops_for_token_kv`]).
pub fn ops_for_workload_kv(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    kv: KvScheme,
    n_in: usize,
    n_out: usize,
) -> Vec<(Phase, Vec<MatvecOp>)> {
    let mut steps = Vec::with_capacity(n_in + n_out);
    for pos in 0..n_in {
        let logits = pos + 1 == n_in; // last prefill token produces logits
        steps.push((Phase::Prefill, ops_for_token_kv(cfg, scheme, kv, pos, logits)));
    }
    for pos in n_in..n_in + n_out {
        steps.push((Phase::Decode, ops_for_token_kv(cfg, scheme, kv, pos, true)));
    }
    steps
}

/// Quantize an activation for `wty`'s kernel — shared helper so the
/// functional engine and the byte accounting agree on formats.
pub fn quantize_activation(wty: GgmlType, x: &[f32]) -> ActQuant {
    ActQuant::for_weight(wty, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_count_per_token() {
        let cfg = ModelConfig::tiny();
        let ops = ops_for_token(&cfg, QuantScheme::Q8_0, 0, true);
        // 9 ops per layer + lm head.
        assert_eq!(ops.len(), cfg.n_layers * 9 + 1);
        let no_logits = ops_for_token(&cfg, QuantScheme::Q8_0, 0, false);
        assert_eq!(no_logits.len(), cfg.n_layers * 9);
    }

    #[test]
    fn attention_grows_with_context() {
        let cfg = ModelConfig::tiny();
        let at = |pos: usize| -> u64 {
            ops_for_token(&cfg, QuantScheme::Q8_0, pos, false)
                .iter()
                .filter(|o| matches!(o.kind, OpKind::AttnScore | OpKind::AttnMix))
                .map(|o| o.macs())
                .sum()
        };
        assert!(at(10) > at(1));
        // Attention MACs scale linearly in ctx.
        assert_eq!(at(19), 2 * at(9));
    }

    #[test]
    fn linear_macs_independent_of_position() {
        let cfg = ModelConfig::qwen3_0_6b();
        let lin = |pos: usize| -> u64 {
            ops_for_token(&cfg, QuantScheme::Q8_0, pos, true)
                .iter()
                .filter(|o| matches!(o.kind, OpKind::Linear(_)))
                .map(|o| o.macs())
                .sum()
        };
        assert_eq!(lin(0), lin(100));
        // ~0.75G MACs per token for 0.6B (linear part).
        let g = lin(0) as f64 / 1e9;
        assert!((0.5..1.0).contains(&g), "linear GMACs {g}");
    }

    #[test]
    fn workload_phases() {
        let cfg = ModelConfig::tiny();
        let steps = ops_for_workload(&cfg, QuantScheme::Q3KS, 8, 4);
        assert_eq!(steps.len(), 12);
        assert_eq!(
            steps.iter().filter(|(p, _)| *p == Phase::Prefill).count(),
            8
        );
        // Only the last prefill step has the LM head.
        let lm_heads_in_prefill: usize = steps[..8]
            .iter()
            .map(|(_, ops)| {
                ops.iter()
                    .filter(|o| o.kind == OpKind::Linear(LinearKind::LmHead))
                    .count()
            })
            .sum();
        assert_eq!(lm_heads_in_prefill, 1);
        // Every decode step has it.
        for (p, ops) in &steps[8..] {
            assert_eq!(*p, Phase::Decode);
            assert!(ops
                .iter()
                .any(|o| o.kind == OpKind::Linear(LinearKind::LmHead)));
        }
    }

    #[test]
    fn q3ks_scheme_contains_both_kquants() {
        let cfg = ModelConfig::tiny();
        let ops = ops_for_token(&cfg, QuantScheme::Q3KS, 0, true);
        assert!(ops.iter().any(|o| o.wty == GgmlType::Q3K));
        assert!(ops.iter().any(|o| o.wty == GgmlType::Q6K));
        assert!(ops.iter().any(|o| o.wty == GgmlType::F16)); // attention
    }

    #[test]
    fn byte_accounting_q8_example() {
        // A 1.7B Q8_0 ffn_gate: 6144 × 2048 → weight bytes = rows × 2048/32×34.
        let cfg = ModelConfig::qwen3_1_7b();
        let ops = ops_for_token(&cfg, QuantScheme::Q8_0, 0, false);
        let gate = ops
            .iter()
            .find(|o| o.kind == OpKind::Linear(LinearKind::FfnGate))
            .unwrap();
        assert_eq!(gate.weight_bytes(), 6144 * (2048 / 32) * 34);
        assert_eq!(gate.act_bytes(), (2048 / 32) * 34);
        assert_eq!(gate.out_bytes(), 4 * 6144);
        assert_eq!(gate.dma_operand_arrays(), 4);
    }

    #[test]
    fn total_macs_scale_with_model() {
        let macs = |cfg: &ModelConfig| -> u64 {
            ops_for_token(cfg, QuantScheme::Q8_0, 31, true)
                .iter()
                .map(|o| o.macs())
                .sum()
        };
        let m06 = macs(&ModelConfig::qwen3_0_6b());
        let m17 = macs(&ModelConfig::qwen3_1_7b());
        let m8 = macs(&ModelConfig::qwen3_8b());
        assert!(m17 > 2 * m06);
        assert!(m8 > 3 * m17);
    }
}
