//! Paged multi-sequence KV cache with refcounted copy-on-write pages,
//! prefix sharing, and host-swap eviction.
//!
//! The paper's host CPU owns "KV cache management" (§III.A), and the
//! decode phase's LOAD-bound behaviour (§V.B) comes from streaming this
//! cache to the accelerator every step. Serving interleaves many
//! sequences on one engine (continuous batching), so the cache is
//! organised vLLM-style as a **shared pool of fixed-size pages** instead
//! of one fixed-stride slab per slot:
//!
//! * A *page* holds `page_size` consecutive token positions of K and V
//!   for **every layer**: the K (or V) backing store is laid out as
//!   `[n_pages][n_layers][page_size][kv_dim]`, row-major. One logical
//!   page allocation therefore covers all layers of a position range,
//!   which keeps the per-slot block table small and layer-independent.
//! * Each session slot owns a *block table* — the ordered list of page
//!   ids backing logical positions `0..slot_len(slot)`. Position `pos`
//!   of `slot` lives at offset `pos % page_size` inside page
//!   `table[pos / page_size]`.
//! * Pages are **refcounted**: a page's count is the number of block-table
//!   entries referencing it, plus one if the prefix index holds it (see
//!   below). Pages at count zero sit on a LIFO *free list*.
//!   [`KvCache::try_reserve`] pops pages lazily as a slot's sequence
//!   crosses page boundaries and [`KvCache::reset_slot`] releases exactly
//!   that slot's references — a page returns to the free list only when
//!   its last reference drops.
//! * A shared page is immutable through any one table: [`KvCache::store`]
//!   to a page with more than one reference triggers **copy-on-write**,
//!   so writers can never clobber bytes another reader (or the prefix
//!   index) still sees.
//!
//! Two subsystems build on the refcounts (both opt-in; with neither
//! enabled every page has exactly one reference and behaviour is
//! bit-identical to exclusive ownership):
//!
//! * **Prefix cache** ([`KvCache::enable_prefix_cache`]) — a
//!   content-addressed index over *full* pages of committed prompt
//!   tokens. Keys are chain hashes of `(model fingerprint, parent key,
//!   the page's token ids)`, verified against the stored token span, so
//!   a lookup for a new prompt walks page-aligned spans and
//!   [`KvCache::adopt_prefix`] aliases every consecutively cached page
//!   into the new slot's block table — the engine then skips prefill for
//!   the aliased span. Registered pages carry the index's reference, so
//!   they survive the owning sequence finishing ("recently-finished"
//!   reuse) until evicted.
//! * **Host-swap arena** ([`KvCache::set_swap_capacity`]) — when the pool
//!   runs dry, the coldest *unpinned* cached pages (held only by the
//!   index, LRU by last touch) are evicted to a host-side arena instead
//!   of being dropped, and swapped back in on a later prefix hit. Swap
//!   traffic is surfaced through [`KvCache::take_pending_swap_bytes`] so
//!   the engine can charge it through the DMA transfer cost model — the
//!   paper's transfer bottleneck stays visible in reports.
//!
//! The practical consequence, and the reason serving wants paging: slot
//! count no longer reserves `max_seq` tokens of memory per sequence.
//! A pool of `n_pages` serves any mix of sequences whose *live* tokens
//! fit, so many short sequences can decode concurrently inside a memory
//! budget that fixed-stride slots would exhaust after a couple of slots
//! (the admission logic lives in
//! [`crate::coordinator::scheduler::ContinuousBatcher`]).
//!
//! `page_size = max_seq, n_pages = n_slots` degenerates to exactly the
//! old contiguous layout — the equivalence suite in
//! `rust/tests/batching_equiv.rs` pins paged execution bit-identical to
//! that reference, and `rust/tests/prefix_reuse.rs` pins warm prefix hits
//! output-identical to cold prefill.
//!
//! Cache exhaustion is a typed [`CacheError`] (carrying slot, current
//! length and the failed requirement) so schedulers can defer admission
//! instead of unwinding.
//!
//! **Page encoding** is chosen at pool construction ([`KvScheme`]):
//!
//! * [`KvScheme::F16`] (default) — the functional engine keeps K/V in
//!   f32 and the *byte accounting* used by the timing path models the
//!   llama.cpp default of an FP16 cache (see `MatvecOp::weight_bytes`
//!   with `GgmlType::F16`). Bit-exact reference behaviour.
//! * [`KvScheme::Q8_0`] — [`KvCache::store`] quantizes each token's K/V
//!   row into q8_0 blocks (the canonical stored bytes) and keeps an f32
//!   *dequantized mirror* that [`KvCache::k_at`]/[`KvCache::v_at`] read,
//!   so attention consumes exactly what a q8_0 decode kernel would. All
//!   byte accounting, swap traffic, and the modeled attention stream
//!   charge the compressed size (8.5 bits/element vs 16 — a 1.88× cut
//!   for 32-aligned `kv_dim`). Numerics deliberately drift from f16 by
//!   the quantization error; `rust/tests/kv_quant_accuracy.rs` bounds
//!   that drift.
//!
//! Accounting is page-granular and **dedup-aware**:
//! [`KvCache::resident_bytes`] counts each physical page once however
//! many block tables alias it, while
//! [`KvCache::logical_resident_bytes`] counts per-slot references
//! (what exclusive ownership would cost), so the difference is the bytes
//! prefix sharing keeps off the device.

use std::collections::HashMap;
use std::fmt;

use crate::model::config::{ModelConfig, QuantScheme};
use crate::quant::{q8_0, GgmlType};
use crate::util::ceil_div;

/// Default page size in tokens. Small enough that short sequences waste
/// little slack in their last page, large enough that the block table
/// indirection stays cold next to the attention arithmetic.
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Encoding of the cached K/V pages, chosen at pool construction.
///
/// `F16` is the bit-exact reference (the llama.cpp default the paper's
/// FP16 attention kernels stream); `Q8_0` stores each token's K and V
/// rows as q8_0 blocks — 8.5 bits/element instead of 16 — so resident
/// bytes, swap traffic, and the modeled per-round attention stream all
/// shrink by ~1.88× at the cost of bounded quantization drift.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvScheme {
    /// FP16 byte accounting, f32 functional storage (exact reference).
    F16,
    /// q8_0-blocked pages: quantize on commit, dequantize on read.
    Q8_0,
}

impl KvScheme {
    /// Parse a CLI name (`f16` | `q8_0`).
    pub fn by_name(name: &str) -> Option<KvScheme> {
        match name.to_ascii_lowercase().as_str() {
            "f16" | "fp16" => Some(KvScheme::F16),
            "q8_0" | "q8" => Some(KvScheme::Q8_0),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvScheme::F16 => "f16",
            KvScheme::Q8_0 => "q8_0",
        }
    }

    /// The element format whose sizing this scheme charges — and, for
    /// `Q8_0`, whose block codec the store path actually runs. Feeds the
    /// attention ops' `MatvecOp::wty` so the cost model prices the
    /// compressed stream end-to-end.
    pub fn elem_type(self) -> GgmlType {
        match self {
            KvScheme::F16 => GgmlType::F16,
            KvScheme::Q8_0 => GgmlType::Q8_0,
        }
    }

    /// Encoded bytes of one `kv_dim`-element K (or V) row.
    pub fn row_bytes(self, kv_dim: usize) -> usize {
        self.elem_type().row_bytes(kv_dim)
    }
}

/// Typed KV-cache exhaustion/contract error. Every variant carries the
/// slot, its current length, and what was asked for, so callers (and
/// panics built from `Display`) can report precisely what ran out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CacheError {
    /// The slot's sequence would exceed the model's context window.
    ContextOverflow {
        slot: usize,
        len: usize,
        need: usize,
        max_seq: usize,
    },
    /// The shared page pool has too few free pages for the reservation
    /// (`free_pages` includes cached pages that could have been
    /// reclaimed/evicted — the reservation is short even after eviction).
    OutOfPages {
        slot: usize,
        len: usize,
        need_pages: usize,
        free_pages: usize,
        n_pages: usize,
    },
    /// `advance` ran past the positions covered by reserved pages
    /// (missing `try_reserve` call — a scheduling bug, not exhaustion).
    Unreserved {
        slot: usize,
        len: usize,
        need: usize,
        reserved: usize,
    },
}

impl fmt::Display for CacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            CacheError::ContextOverflow { slot, len, need, max_seq } => write!(
                f,
                "KV context overflow: slot {slot} at len {len} needs {need} more \
                 tokens but max_seq is {max_seq}"
            ),
            CacheError::OutOfPages { slot, len, need_pages, free_pages, n_pages } => write!(
                f,
                "KV page pool exhausted: slot {slot} at len {len} needs {need_pages} \
                 more pages but only {free_pages} of {n_pages} are free"
            ),
            CacheError::Unreserved { slot, len, need, reserved } => write!(
                f,
                "KV advance past reservation: slot {slot} at len {len} advances by \
                 {need} but pages only cover {reserved} tokens (call try_reserve first)"
            ),
        }
    }
}

impl std::error::Error for CacheError {}

/// Counters for the sharing/eviction machinery, merged across workers
/// into the serve report. All byte quantities use the pool's encoded
/// page size (the same scheme-aware basis as
/// [`KvCache::resident_bytes`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KvReuseStats {
    /// Admissions that aliased at least one cached prefix page.
    pub prefix_hits: usize,
    /// Prompt tokens served from aliased pages (prefill skipped).
    pub prefix_hit_tokens: usize,
    /// Copy-on-write page splits.
    pub cow_pages: usize,
    /// Cached pages evicted without swap (arena full or disabled).
    pub dropped_pages: usize,
    /// Cached pages evicted to the host swap arena.
    pub swap_out_pages: usize,
    /// Pages swapped back in from the arena on a prefix hit.
    pub swap_in_pages: usize,
    /// Modeled bytes moved host↔device by swap traffic (both
    /// directions), in the pool's page encoding — f16 page bytes for
    /// [`KvScheme::F16`], q8_0 block bytes for [`KvScheme::Q8_0`].
    pub swap_bytes: usize,
}

impl KvReuseStats {
    /// Cached pages evicted from the device pool (dropped + swapped out).
    pub fn evicted_pages(&self) -> usize {
        self.dropped_pages + self.swap_out_pages
    }

    /// Accumulate another worker's counters.
    pub fn merge(&mut self, other: &KvReuseStats) {
        self.prefix_hits += other.prefix_hits;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.cow_pages += other.cow_pages;
        self.dropped_pages += other.dropped_pages;
        self.swap_out_pages += other.swap_out_pages;
        self.swap_in_pages += other.swap_in_pages;
        self.swap_bytes += other.swap_bytes;
    }
}

/// Result of [`KvCache::adopt_prefix`]: the page-aligned cached span
/// aliased into the slot.
#[derive(Clone, Debug, Default)]
pub struct AdoptedPrefix {
    /// Prompt tokens covered by aliased pages (a multiple of
    /// `page_size`); prefill may start at this offset.
    pub tokens: usize,
    /// The aliased page ids, in block-table order (the scheduler tracks
    /// these for its dedup-aware admission accounting).
    pub pages: Vec<u32>,
}

/// One prefix-index entry exported for invariant auditing
/// ([`KvCache::prefix_chain_records`]): the chain key the entry is
/// stored under, its parent key, the committed token span, and where the
/// page's bytes currently live.
#[derive(Clone, Debug)]
pub struct PrefixChainRecord {
    /// Index key the entry is stored under (must equal
    /// [`chain_key`]`(fingerprint, prev, &tokens)`).
    pub key: u64,
    /// Parent chain key (the model fingerprint for chain roots).
    pub prev: u64,
    /// The committed token span — exactly one full page of prompt tokens.
    pub tokens: Vec<u32>,
    /// Device page backing the entry when resident (`None` = swapped).
    pub resident_page: Option<u32>,
    /// Whether the host swap arena holds a copy of the entry's bytes.
    pub in_arena: bool,
}

/// Where a cached page's bytes currently live.
#[derive(Clone, Debug, PartialEq, Eq)]
enum PageLoc {
    /// In the device pool, holding one index reference.
    Resident(u32),
    /// Evicted to the host swap arena (no device page).
    Swapped,
}

/// One content-addressed index entry: a full page of committed prompt
/// tokens. `prev` chains entries so a prefix hit is exact by
/// construction (the parent span was verified before this one).
#[derive(Clone, Debug)]
struct PrefixEntry {
    prev: u64,
    tokens: Vec<u32>,
    loc: PageLoc,
    last_touch: u64,
}

/// Host-side copy of one evicted page (all layers, K and V). The
/// payload is the pool's *canonical* storage: f32 mirror cells under
/// [`KvScheme::F16`] (lossless restore of the exact reference), q8_0
/// block bytes under [`KvScheme::Q8_0`] (the f32 mirror is rebuilt by
/// dequantization on swap-in — bit-exact, because the mirror was the
/// dequantization of those same blocks before eviction).
#[derive(Clone, Debug)]
struct SwapPage {
    /// f32 cells (F16 pools; empty under Q8_0).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Encoded q8_0 block bytes (Q8_0 pools; empty under F16).
    k_q: Vec<u8>,
    v_q: Vec<u8>,
}

/// The prefix-sharing state: content-addressed index + host swap arena.
#[derive(Clone, Debug)]
struct PrefixState {
    /// Model fingerprint mixed into every chain key, so caches never
    /// alias across incompatible configurations.
    fingerprint: u64,
    index: HashMap<u64, PrefixEntry>,
    arena: HashMap<u64, SwapPage>,
    /// Maximum pages the host arena may hold (0 = drop on eviction).
    swap_capacity: usize,
    /// Logical last-touch clock for LRU eviction.
    clock: u64,
}

impl PrefixState {
    fn touch(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a accumulation of `bytes` into `h` — the one hash the prefix
/// cache's chain keys and the model fingerprint both build on.
fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

/// FNV-1a over the chain parent key and a token span, seeded with the
/// model fingerprint. Collisions are tolerated (entries verify the full
/// token span and parent key on lookup); the hash only buckets. Public
/// so the `analysis` auditor can recompute keys independently and prove
/// chain-hash integrity against [`KvCache::prefix_chain_records`].
pub fn chain_key(fingerprint: u64, prev: u64, tokens: &[u32]) -> u64 {
    let mut h = FNV_OFFSET;
    fnv1a(&mut h, &fingerprint.to_le_bytes());
    fnv1a(&mut h, &prev.to_le_bytes());
    for &t in tokens {
        fnv1a(&mut h, &t.to_le_bytes());
    }
    h
}

/// Fingerprint of a model configuration + quantization scheme. Seeds
/// every chain key (via [`KvCache::enable_prefix_cache`]) so cached
/// pages can never alias across incompatible engines.
pub fn model_fingerprint(cfg: &ModelConfig, scheme: QuantScheme) -> u64 {
    let mut h = FNV_OFFSET;
    fnv1a(&mut h, cfg.name.as_bytes());
    for d in [
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        cfg.d_ffn,
        cfg.vocab_size,
        cfg.max_seq_len,
    ] {
        fnv1a(&mut h, &(d as u64).to_le_bytes());
    }
    let scheme_tag: u8 = match scheme {
        QuantScheme::F16 => 1,
        QuantScheme::Q8_0 => 2,
        QuantScheme::Q3KS => 3,
    };
    fnv1a(&mut h, &[scheme_tag]);
    h
}

/// Paged KV cache for all layers and session slots (see module docs for
/// the layout and the sharing model).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub kv_dim: usize,
    /// Per-slot context capacity (model context window).
    pub max_seq: usize,
    /// Number of independent sequences the cache can hold.
    pub n_slots: usize,
    /// Tokens per page.
    page_size: usize,
    /// Total pages in the shared pool.
    n_pages: usize,
    /// Current number of cached positions per slot (shared across layers).
    lens: Vec<usize>,
    /// Per-slot block table: page ids backing positions `0..lens[slot]`
    /// (the last page may be partially filled).
    tables: Vec<Vec<u32>>,
    /// LIFO free list of pages with zero references.
    free: Vec<u32>,
    /// Per-page reference counts: block-table entries + one for a
    /// resident prefix-index entry. Zero ⇔ on the free list.
    refs: Vec<u32>,
    /// Lifetime high-water mark of owned pages (exact peak residency,
    /// updated at allocation so it can't miss pages freed mid-round).
    peak_used: usize,
    /// `[n_pages][n_layers][page_size][kv_dim]`, row-major. Under
    /// [`KvScheme::F16`] this is the functional storage; under
    /// [`KvScheme::Q8_0`] it is the *dequantized mirror* of `k_q`/`v_q`
    /// (what attention reads — exactly the q8_0 roundtrip of what was
    /// stored).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Canonical q8_0 block bytes,
    /// `[n_pages][n_layers][page_size][row_bytes(kv_dim)]`, row-major
    /// (empty under [`KvScheme::F16`]).
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    /// Page encoding chosen at construction.
    scheme: KvScheme,
    n_layers: usize,
    /// Prefix index + swap arena (None: plain exclusive paging).
    prefix: Option<PrefixState>,
    /// Sharing/eviction counters (live even without the index, for CoW).
    stats: KvReuseStats,
    /// Swap bytes accumulated since the engine last drained them into the
    /// executor's DMA accounting.
    pending_swap_in_bytes: usize,
    pending_swap_out_bytes: usize,
}

impl KvCache {
    /// Single-sequence cache (the legacy one-request-at-a-time engine).
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::with_slots(cfg, 1)
    }

    /// Cache holding `n_slots` independent sequences, fully backed: the
    /// pool holds enough pages for every slot to reach `max_seq`, so
    /// reservations can only fail on context overflow (exactly the old
    /// fixed-stride capacity semantics).
    pub fn with_slots(cfg: &ModelConfig, n_slots: usize) -> KvCache {
        let pages = KvCache::full_backing_pages(cfg, n_slots, DEFAULT_PAGE_SIZE);
        KvCache::paged(cfg, n_slots, DEFAULT_PAGE_SIZE, pages)
    }

    /// Pages needed to fully back `n_slots` sequences of `max_seq` tokens.
    pub fn full_backing_pages(cfg: &ModelConfig, n_slots: usize, page_size: usize) -> usize {
        assert!(page_size >= 1, "page_size must be at least 1");
        n_slots * ceil_div(cfg.max_seq_len, page_size)
    }

    /// Cache with an explicit page geometry: `n_slots` sequences sharing
    /// a pool of `n_pages` pages of `page_size` tokens each. The pool may
    /// deliberately be smaller than `n_slots × max_seq` worth of pages —
    /// that is the point of paging; admission control keeps concurrent
    /// sequences inside the budget.
    pub fn paged(cfg: &ModelConfig, n_slots: usize, page_size: usize, n_pages: usize) -> KvCache {
        KvCache::paged_with_scheme(cfg, n_slots, page_size, n_pages, KvScheme::F16)
    }

    /// [`KvCache::paged`] with an explicit page encoding. `Q8_0` requires
    /// `kv_dim` to be a multiple of the q8_0 block size (32) — true of
    /// every shipping configuration — so each K/V row packs into whole
    /// blocks with no padding ambiguity.
    pub fn paged_with_scheme(
        cfg: &ModelConfig,
        n_slots: usize,
        page_size: usize,
        n_pages: usize,
        scheme: KvScheme,
    ) -> KvCache {
        assert!(n_slots >= 1, "need at least one session slot");
        assert!(page_size >= 1, "page_size must be at least 1");
        assert!(n_pages >= 1, "need at least one page");
        let kv_dim = cfg.kv_dim();
        if scheme == KvScheme::Q8_0 {
            assert!(
                kv_dim % q8_0::QK8_0 == 0,
                "q8_0 KV pages need kv_dim divisible by {} (got {kv_dim})",
                q8_0::QK8_0,
            );
        }
        let cells = n_pages * cfg.n_layers * page_size * kv_dim;
        let q_bytes = match scheme {
            KvScheme::F16 => 0,
            KvScheme::Q8_0 => n_pages * cfg.n_layers * page_size * scheme.row_bytes(kv_dim),
        };
        KvCache {
            kv_dim,
            max_seq: cfg.max_seq_len,
            n_slots,
            page_size,
            n_pages,
            lens: vec![0; n_slots],
            tables: vec![Vec::new(); n_slots],
            // LIFO: page 0 is handed out first.
            free: (0..n_pages as u32).rev().collect(),
            refs: vec![0; n_pages],
            peak_used: 0,
            k: vec![0.0; cells],
            v: vec![0.0; cells],
            k_q: vec![0; q_bytes],
            v_q: vec![0; q_bytes],
            scheme,
            n_layers: cfg.n_layers,
            prefix: None,
            stats: KvReuseStats::default(),
            pending_swap_in_bytes: 0,
            pending_swap_out_bytes: 0,
        }
    }

    /// The page encoding chosen at construction.
    pub fn kv_scheme(&self) -> KvScheme {
        self.scheme
    }

    /// Length of slot 0 — the single-sequence engine's implicit slot.
    pub fn len(&self) -> usize {
        self.lens[0]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }

    /// Current number of cached positions in `slot`.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the shared pool.
    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Model layers each page stores a `page_size`-token span of.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Pages currently on the free list.
    pub fn free_page_count(&self) -> usize {
        self.free.len()
    }

    /// Pages currently referenced (by block tables or the prefix index).
    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// The ordered page ids backing `slot`'s sequence.
    pub fn slot_pages(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// The free list (LIFO; the next page handed out is the *last*
    /// element). Exposed for diagnostics and the property suite.
    pub fn free_list(&self) -> &[u32] {
        &self.free
    }

    /// Reference count of `page` (block-table entries + a resident index
    /// entry). Zero means the page is on the free list.
    pub fn page_ref(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// Pages required to hold `n_tokens` tokens.
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        ceil_div(n_tokens, self.page_size)
    }

    /// Clear every slot (fresh engine) and release their page
    /// references. Cached prefix pages survive (use
    /// [`KvCache::clear_prefix_cache`] for a full flush).
    pub fn reset(&mut self) {
        for slot in 0..self.n_slots {
            self.reset_slot(slot);
        }
    }

    /// Clear one slot (session closed / slot reassigned), releasing
    /// exactly the page references it held. A page returns to the free
    /// list only when its last reference drops — pages shared with other
    /// slots or pinned by the prefix index live on. Returns how many
    /// pages this release actually freed to the pool (the non-shared
    /// ones), so teardown paths — including mid-decode cancellation —
    /// can account the budget they handed back.
    ///
    /// Safe in *any* slot state: a partially prefilled sequence (an
    /// abandoned cursor), one with a speculative verify pending, or one
    /// mid-decode all hold nothing but per-slot page references, and
    /// this drops exactly those.
    pub fn reset_slot(&mut self, slot: usize) -> usize {
        self.lens[slot] = 0;
        let free_before = self.free.len();
        // Most-recently-allocated pages go back on top of the LIFO stack.
        while let Some(page) = self.tables[slot].pop() {
            self.release_ref(page);
        }
        self.free.len() - free_before
    }

    /// Drop one reference to `page`, freeing it when the count reaches
    /// zero.
    fn release_ref(&mut self, page: u32) {
        let r = &mut self.refs[page as usize];
        debug_assert!(*r > 0, "releasing an unreferenced page {page}");
        *r -= 1;
        if *r == 0 {
            self.free.push(page);
        }
    }

    /// Take one page off the free list (refcount 1 for the caller),
    /// evicting cold cached pages if the list is empty. `protect` names
    /// chain keys that must not be evicted (an in-progress adoption's
    /// remaining chain). `None` when nothing can be obtained.
    fn obtain_page(&mut self, protect: &[u64]) -> Option<u32> {
        if self.free.is_empty() && !self.evict_coldest_unpinned(protect) {
            return None;
        }
        let page = self.free.pop()?;
        debug_assert_eq!(self.refs[page as usize], 0);
        self.refs[page as usize] = 1;
        self.peak_used = self.peak_used.max(self.used_pages());
        Some(page)
    }

    // ---- prefix cache & swap arena ----

    /// Turn on the content-addressed prefix index. `fingerprint`
    /// identifies the model/quantization configuration; it seeds every
    /// chain key so lookups can never alias across configurations.
    pub fn enable_prefix_cache(&mut self, fingerprint: u64) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixState {
                fingerprint,
                index: HashMap::new(),
                arena: HashMap::new(),
                swap_capacity: 0,
                clock: 0,
            });
        }
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Size the host swap arena (pages). Evictions beyond the capacity
    /// drop the page instead of swapping. Requires the prefix cache —
    /// only indexed pages are ever evicted.
    pub fn set_swap_capacity(&mut self, pages: usize) {
        let p = self
            .prefix
            .as_mut()
            .expect("swap arena requires the prefix cache (enable_prefix_cache first)");
        p.swap_capacity = pages;
    }

    /// Sharing/eviction counters so far (prefix-hit counters are filled
    /// by the scheduler, which knows admissions; see
    /// [`crate::coordinator::scheduler::ContinuousBatcher::reuse_stats`]).
    pub fn reuse_stats(&self) -> &KvReuseStats {
        &self.stats
    }

    /// Cached (index-resident) pages currently occupying device pages.
    pub fn cached_resident_pages(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| {
            p.index.values().filter(|e| matches!(e.loc, PageLoc::Resident(_))).count()
        })
    }

    /// Pages currently held by the host swap arena.
    pub fn swapped_out_pages(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.arena.len())
    }

    /// The device page ids the prefix index currently holds resident
    /// (diagnostics and the property suite's refcount accounting).
    pub fn cached_page_ids(&self) -> Vec<u32> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| {
            p.index
                .values()
                .filter_map(|e| match e.loc {
                    PageLoc::Resident(page) => Some(page),
                    PageLoc::Swapped => None,
                })
                .collect()
        })
    }

    /// The token spans committed to the prefix index (one full page of
    /// prompt tokens each, resident or swapped), hottest first — the
    /// speculative drafter's shared lookup corpus when the prefix cache
    /// is on. Ordered by last touch (then by span) so drafting stays
    /// deterministic despite the hash-map index.
    pub fn prefix_token_spans(&self) -> Vec<Vec<u32>> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| {
            let mut entries: Vec<(u64, &Vec<u32>)> =
                p.index.values().map(|e| (e.last_touch, &e.tokens)).collect();
            entries.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(b.1)));
            entries.into_iter().map(|(_, t)| t.clone()).collect()
        })
    }

    /// The fingerprint seeding this cache's chain keys (`None` when the
    /// prefix index is disabled). Chain roots use the fingerprint itself
    /// as their parent key.
    pub fn prefix_fingerprint(&self) -> Option<u64> {
        self.prefix.as_ref().map(|p| p.fingerprint)
    }

    /// Export the prefix index for invariant auditing: one record per
    /// entry with the key it is stored under, its parent key, the
    /// committed token span, and where its bytes live. Sorted by key so
    /// audits are deterministic despite the hash-map index.
    pub fn prefix_chain_records(&self) -> Vec<PrefixChainRecord> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| {
            let mut records: Vec<PrefixChainRecord> = p
                .index
                .iter()
                .map(|(&key, e)| PrefixChainRecord {
                    key,
                    prev: e.prev,
                    tokens: e.tokens.clone(),
                    resident_page: match e.loc {
                        PageLoc::Resident(page) => Some(page),
                        PageLoc::Swapped => None,
                    },
                    in_arena: p.arena.contains_key(&key),
                })
                .collect();
            records.sort_by_key(|r| r.key);
            records
        })
    }

    /// Swap traffic (encoded bytes in, bytes out — sized by the pool's
    /// [`KvScheme`]) accumulated since the last call — the engine drains
    /// this into the executor's DMA accounting so modeled reports keep
    /// the transfer bottleneck visible.
    pub fn take_pending_swap_bytes(&mut self) -> (usize, usize) {
        let out = (self.pending_swap_in_bytes, self.pending_swap_out_bytes);
        self.pending_swap_in_bytes = 0;
        self.pending_swap_out_bytes = 0;
        out
    }

    /// Drop the whole prefix index and swap arena, releasing the index's
    /// page references (the full-flush companion of [`KvCache::reset`]).
    pub fn clear_prefix_cache(&mut self) {
        let Some(p) = self.prefix.as_mut() else { return };
        let resident: Vec<u32> = p
            .index
            .values()
            .filter_map(|e| match e.loc {
                PageLoc::Resident(page) => Some(page),
                PageLoc::Swapped => None,
            })
            .collect();
        p.index.clear();
        p.arena.clear();
        for page in resident {
            self.release_ref(page);
        }
    }

    /// Cached pages that could be evicted right now (resident, held only
    /// by the index — not aliased by any live block table).
    pub fn reclaimable_pages(&self) -> usize {
        let Some(p) = self.prefix.as_ref() else { return 0 };
        p.index
            .values()
            .filter(|e| match e.loc {
                PageLoc::Resident(page) => self.refs[page as usize] == 1,
                PageLoc::Swapped => false,
            })
            .count()
    }

    /// Evict the coldest unpinned cached page (LRU by last touch; ties
    /// break on the chain key for determinism) to the swap arena — or
    /// drop it when the arena is full/disabled — returning whether a
    /// page was freed.
    fn evict_coldest_unpinned(&mut self, protect: &[u64]) -> bool {
        let Some(p) = self.prefix.as_ref() else { return false };
        let victim = p
            .index
            .iter()
            .filter_map(|(&key, e)| match e.loc {
                PageLoc::Resident(page)
                    if self.refs[page as usize] == 1 && !protect.contains(&key) =>
                {
                    Some((e.last_touch, key, page))
                }
                _ => None,
            })
            .min();
        let Some((_, key, page)) = victim else { return false };
        let page_bytes = self.page_bytes();
        let will_swap =
            self.prefix.as_ref().is_some_and(|p| p.arena.len() < p.swap_capacity);
        if will_swap {
            let sp = self.export_page(page);
            let p = self.prefix.as_mut().expect("checked above");
            p.arena.insert(key, sp);
            p.index.get_mut(&key).expect("victim exists").loc = PageLoc::Swapped;
            self.stats.swap_out_pages += 1;
            self.stats.swap_bytes += page_bytes;
            self.pending_swap_out_bytes += page_bytes;
        } else {
            let p = self.prefix.as_mut().expect("checked above");
            p.index.remove(&key);
            self.stats.dropped_pages += 1;
        }
        self.release_ref(page);
        true
    }

    /// Snapshot one page's canonical payload for the swap arena (see
    /// [`SwapPage`] for the per-scheme contents).
    fn export_page(&self, page: u32) -> SwapPage {
        let cells = self.page_cells();
        let base = page as usize * cells;
        match self.scheme {
            KvScheme::F16 => SwapPage {
                k: self.k[base..base + cells].to_vec(),
                v: self.v[base..base + cells].to_vec(),
                k_q: Vec::new(),
                v_q: Vec::new(),
            },
            KvScheme::Q8_0 => {
                let pq = self.page_q_bytes();
                let qbase = page as usize * pq;
                SwapPage {
                    k: Vec::new(),
                    v: Vec::new(),
                    k_q: self.k_q[qbase..qbase + pq].to_vec(),
                    v_q: self.v_q[qbase..qbase + pq].to_vec(),
                }
            }
        }
    }

    /// Restore one arena payload into device `page`, rebuilding the f32
    /// mirror from the block bytes under [`KvScheme::Q8_0`] (bit-exact:
    /// the mirror is *defined* as the dequantization of the blocks).
    fn import_page(&mut self, page: u32, sp: &SwapPage) {
        let cells = self.page_cells();
        let base = page as usize * cells;
        match self.scheme {
            KvScheme::F16 => {
                self.k[base..base + cells].copy_from_slice(&sp.k);
                self.v[base..base + cells].copy_from_slice(&sp.v);
            }
            KvScheme::Q8_0 => {
                let pq = self.page_q_bytes();
                let qbase = page as usize * pq;
                self.k_q[qbase..qbase + pq].copy_from_slice(&sp.k_q);
                self.v_q[qbase..qbase + pq].copy_from_slice(&sp.v_q);
                let rb = self.scheme.row_bytes(self.kv_dim);
                let rows = self.n_layers * self.page_size;
                for r in 0..rows {
                    let qoff = qbase + r * rb;
                    let off = base + r * self.kv_dim;
                    let kd = q8_0::dequantize_row_bytes(&self.k_q[qoff..qoff + rb], self.kv_dim);
                    self.k[off..off + self.kv_dim].copy_from_slice(&kd);
                    let vd = q8_0::dequantize_row_bytes(&self.v_q[qoff..qoff + rb], self.kv_dim);
                    self.v[off..off + self.kv_dim].copy_from_slice(&vd);
                }
            }
        }
    }

    /// f32 cells of one page's K (or V) backing store, all layers.
    #[inline]
    fn page_cells(&self) -> usize {
        self.n_layers * self.page_size * self.kv_dim
    }

    /// Encoded q8_0 bytes of one page's K (or V) blocks (Q8_0 pools).
    #[inline]
    fn page_q_bytes(&self) -> usize {
        self.n_layers * self.page_size * self.scheme.row_bytes(self.kv_dim)
    }

    /// Verified index lookup: the entry at `key` whose token span and
    /// parent chain match exactly (hash collisions read as misses).
    fn verified_entry<'a>(
        index: &'a HashMap<u64, PrefixEntry>,
        key: u64,
        prev: u64,
        span: &[u32],
    ) -> Option<&'a PrefixEntry> {
        index.get(&key).filter(|e| e.prev == prev && e.tokens == span)
    }

    /// The page-aligned cached span of `prompt` (capped at `max_tokens`)
    /// without mutating anything: `(cached_tokens, resident_pages,
    /// swapped_pages)`. Used by schedulers to cost admissions.
    pub fn peek_prefix(&self, prompt: &[u32], max_tokens: usize) -> (usize, usize, usize) {
        let Some(p) = self.prefix.as_ref() else { return (0, 0, 0) };
        let ps = self.page_size;
        let mut prev = p.fingerprint;
        let (mut tokens, mut resident, mut swapped) = (0usize, 0usize, 0usize);
        let limit = max_tokens.min(prompt.len()).min(self.max_seq);
        while tokens + ps <= limit {
            let span = &prompt[tokens..tokens + ps];
            let key = chain_key(p.fingerprint, prev, span);
            let Some(e) = Self::verified_entry(&p.index, key, prev, span) else { break };
            match e.loc {
                PageLoc::Resident(_) => resident += 1,
                PageLoc::Swapped => swapped += 1,
            }
            tokens += ps;
            prev = key;
        }
        (tokens, resident, swapped)
    }

    /// Alias every consecutively cached full page of `prompt` (capped at
    /// `max_tokens`) into `slot`'s block table, swapping pages back in
    /// from the host arena as needed. The slot must be empty. Stops at
    /// the first uncached page, or when a swapped page cannot obtain a
    /// device page. Swap-in bytes accumulate for
    /// [`KvCache::take_pending_swap_bytes`].
    pub fn adopt_prefix(
        &mut self,
        slot: usize,
        prompt: &[u32],
        max_tokens: usize,
    ) -> AdoptedPrefix {
        assert!(
            self.lens[slot] == 0 && self.tables[slot].is_empty(),
            "adopt_prefix requires an empty slot (slot {slot} has {} tokens)",
            self.lens[slot]
        );
        if self.prefix.is_none() {
            return AdoptedPrefix::default();
        }
        let ps = self.page_size;
        let limit = max_tokens.min(prompt.len()).min(self.max_seq);
        // Pre-compute the chain keys of the cached span so eviction never
        // cannibalizes pages this adoption is about to use.
        let chain = {
            let p = self.prefix.as_ref().expect("checked above");
            let mut chain = Vec::new();
            let mut prev = p.fingerprint;
            let mut tokens = 0usize;
            while tokens + ps <= limit {
                let span = &prompt[tokens..tokens + ps];
                let key = chain_key(p.fingerprint, prev, span);
                if Self::verified_entry(&p.index, key, prev, span).is_none() {
                    break;
                }
                chain.push(key);
                tokens += ps;
                prev = key;
            }
            chain
        };
        let mut out = AdoptedPrefix::default();
        for (i, &key) in chain.iter().enumerate() {
            let loc = {
                let p = self.prefix.as_ref().expect("enabled");
                p.index.get(&key).expect("chain verified").loc.clone()
            };
            let page = match loc {
                PageLoc::Resident(page) => {
                    self.refs[page as usize] += 1;
                    page
                }
                PageLoc::Swapped => {
                    // Bring the page home; the remaining chain is
                    // protected from eviction.
                    let Some(page) = self.obtain_page(&chain[i..]) else { break };
                    let page_bytes = self.page_bytes();
                    let sp = {
                        let p = self.prefix.as_mut().expect("enabled");
                        p.arena.remove(&key).expect("swapped entry has arena bytes")
                    };
                    self.import_page(page, &sp);
                    let p = self.prefix.as_mut().expect("enabled");
                    p.index.get_mut(&key).expect("chain verified").loc = PageLoc::Resident(page);
                    // One ref for the index (obtain_page granted one to
                    // the caller) plus one for the adopting slot.
                    self.refs[page as usize] += 1;
                    self.stats.swap_in_pages += 1;
                    self.stats.swap_bytes += page_bytes;
                    self.pending_swap_in_bytes += page_bytes;
                    page
                }
            };
            let p = self.prefix.as_mut().expect("enabled");
            let touch = p.touch();
            p.index.get_mut(&key).expect("chain verified").last_touch = touch;
            self.tables[slot].push(page);
            self.lens[slot] += ps;
            out.pages.push(page);
            out.tokens += ps;
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        out
    }

    /// Register every committed full page of `slot`'s prompt `tokens`
    /// into the prefix index (pinning each with an index reference).
    /// Pages already indexed just refresh their LRU stamp; a swapped
    /// entry whose content this slot re-computed is resurrected as
    /// resident. Call after prefill commits the prompt.
    pub fn register_prefix(&mut self, slot: usize, tokens: &[u32]) {
        if self.prefix.is_none() {
            return;
        }
        let ps = self.page_size;
        let full = (tokens.len().min(self.lens[slot])) / ps;
        let fingerprint = self.prefix.as_ref().expect("enabled").fingerprint;
        let mut prev = fingerprint;
        for i in 0..full {
            let span = &tokens[i * ps..(i + 1) * ps];
            let key = chain_key(fingerprint, prev, span);
            let page = self.tables[slot][i];
            let p = self.prefix.as_mut().expect("enabled");
            let touch = p.touch();
            let existing = p.index.get(&key).map(|e| e.prev == prev && e.tokens == span);
            match existing {
                Some(true) => {
                    let was_swapped = {
                        let e = p.index.get_mut(&key).expect("present");
                        e.last_touch = touch;
                        let swapped = e.loc == PageLoc::Swapped;
                        if swapped {
                            // The slot holds a fresh resident copy of
                            // bytes we evicted earlier: resurrect the
                            // entry onto this page.
                            e.loc = PageLoc::Resident(page);
                        }
                        swapped
                    };
                    if was_swapped {
                        p.arena.remove(&key);
                        self.refs[page as usize] += 1;
                    }
                }
                Some(false) => {
                    // Hash collision with a different chain: replace.
                    let old = p.index.remove(&key).expect("present");
                    p.arena.remove(&key);
                    p.index.insert(
                        key,
                        PrefixEntry {
                            prev,
                            tokens: span.to_vec(),
                            loc: PageLoc::Resident(page),
                            last_touch: touch,
                        },
                    );
                    self.refs[page as usize] += 1;
                    if let PageLoc::Resident(op) = old.loc {
                        self.release_ref(op);
                    }
                }
                None => {
                    p.index.insert(
                        key,
                        PrefixEntry {
                            prev,
                            tokens: span.to_vec(),
                            loc: PageLoc::Resident(page),
                            last_touch: touch,
                        },
                    );
                    self.refs[page as usize] += 1;
                }
            }
            prev = key;
        }
    }

    /// Append one already-owned full page to `slot`'s block table,
    /// sharing it (refcount +1). The slot's length must be page-aligned.
    /// This is the aliasing primitive under [`KvCache::adopt_prefix`],
    /// exposed for the property suite.
    pub fn alias_page(&mut self, slot: usize, page: u32) {
        assert!(self.refs[page as usize] > 0, "aliasing unowned page {page}");
        assert_eq!(
            self.lens[slot] % self.page_size,
            0,
            "alias requires a page-aligned slot length"
        );
        assert!(
            self.lens[slot] + self.page_size <= self.max_seq,
            "alias would exceed the context window"
        );
        self.refs[page as usize] += 1;
        self.tables[slot].push(page);
        self.lens[slot] += self.page_size;
    }

    /// Ensure pages cover positions `slot_len(slot)..slot_len(slot)+n`,
    /// allocating from the free list — and evicting cold cached pages
    /// when it runs dry — as needed. Call before `store`-ing a ubatch.
    /// Fails atomically: on `Err` no pages were taken and nothing was
    /// evicted.
    pub fn try_reserve(&mut self, slot: usize, n: usize) -> Result<(), CacheError> {
        let len = self.lens[slot];
        if len + n > self.max_seq {
            return Err(CacheError::ContextOverflow {
                slot,
                len,
                need: n,
                max_seq: self.max_seq,
            });
        }
        let want = self.pages_needed(len + n);
        let have = self.tables[slot].len();
        let need_pages = want.saturating_sub(have);
        let obtainable = self.free.len() + self.reclaimable_pages();
        if need_pages > obtainable {
            return Err(CacheError::OutOfPages {
                slot,
                len,
                need_pages,
                free_pages: obtainable,
                n_pages: self.n_pages,
            });
        }
        for _ in 0..need_pages {
            let page = self.obtain_page(&[]).expect("obtainable count checked above");
            self.tables[slot].push(page);
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Flat index of `(slot, layer, pos)` through the block table.
    #[inline]
    fn base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.n_slots && layer < self.n_layers);
        let page = self.tables[slot][pos / self.page_size] as usize;
        ((page * self.n_layers + layer) * self.page_size + pos % self.page_size) * self.kv_dim
    }

    /// Replace `slot`'s shared page at table index `idx` with a private
    /// copy (copy-on-write): the new page clones every layer's cells, the
    /// old page keeps its other references untouched.
    fn cow_page(&mut self, slot: usize, idx: usize) {
        let old = self.tables[slot][idx];
        let new = self
            .obtain_page(&[])
            .unwrap_or_else(|| panic!("copy-on-write needs a free page (slot {slot})"));
        let cells = self.page_cells();
        let (ob, nb) = (old as usize * cells, new as usize * cells);
        self.k.copy_within(ob..ob + cells, nb);
        self.v.copy_within(ob..ob + cells, nb);
        if self.scheme == KvScheme::Q8_0 {
            let pq = self.page_q_bytes();
            let (oq, nq) = (old as usize * pq, new as usize * pq);
            self.k_q.copy_within(oq..oq + pq, nq);
            self.v_q.copy_within(oq..oq + pq, nq);
        }
        self.tables[slot][idx] = new;
        self.release_ref(old);
        self.stats.cow_pages += 1;
    }

    /// Write one position's K and V for `layer` of `slot`. A ubatch
    /// first calls `try_reserve(slot, n)`, then stores `pos` values
    /// `slot_len(slot)..slot_len(slot)+n` for every layer, then calls
    /// `advance(slot, n)` once. Storing into a page other readers still
    /// reference triggers copy-on-write — the other readers' bytes are
    /// never mutated.
    ///
    /// Under [`KvScheme::Q8_0`] the row is quantized on commit: the
    /// q8_0 block bytes become the canonical storage and the f32 mirror
    /// gets their exact dequantization, so every committed row is always
    /// a *complete* encoding (a store writes the whole row's blocks and
    /// mirror together — no partially-encoded state exists for rollback
    /// or CoW to observe).
    pub fn store(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(
            pos < self.max_seq,
            "KV store past the context window: slot {slot} pos {pos}, max_seq {}",
            self.max_seq,
        );
        let reserved = self.tables[slot].len() * self.page_size;
        assert!(
            pos < reserved,
            "KV store outside reserved pages: slot {slot} pos {pos} but pages cover \
             only {reserved} tokens (len {}; call try_reserve first)",
            self.lens[slot],
        );
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let idx = pos / self.page_size;
        if self.refs[self.tables[slot][idx] as usize] > 1 {
            self.cow_page(slot, idx);
        }
        let base = self.base(slot, layer, pos);
        match self.scheme {
            KvScheme::F16 => {
                self.k[base..base + self.kv_dim].copy_from_slice(k);
                self.v[base..base + self.kv_dim].copy_from_slice(v);
            }
            KvScheme::Q8_0 => {
                let rb = self.scheme.row_bytes(self.kv_dim);
                let qoff = (base / self.kv_dim) * rb;
                let kq = q8_0::quantize_row_bytes(k);
                let vq = q8_0::quantize_row_bytes(v);
                let kd = q8_0::dequantize_row_bytes(&kq, self.kv_dim);
                let vd = q8_0::dequantize_row_bytes(&vq, self.kv_dim);
                self.k_q[qoff..qoff + rb].copy_from_slice(&kq);
                self.v_q[qoff..qoff + rb].copy_from_slice(&vq);
                self.k[base..base + self.kv_dim].copy_from_slice(&kd);
                self.v[base..base + self.kv_dim].copy_from_slice(&vd);
            }
        }
    }

    /// The stored q8_0 block bytes of one position's K row (Q8_0 pools
    /// only) — exposed so the property/accuracy suites can prove the f32
    /// mirror is exactly the dequantization of the canonical blocks, and
    /// that swap/CoW round trips preserve the blocks byte-for-byte.
    pub fn k_block_bytes_at(&self, slot: usize, layer: usize, pos: usize) -> &[u8] {
        assert_eq!(self.scheme, KvScheme::Q8_0, "block bytes exist only on q8_0 pools");
        let rb = self.scheme.row_bytes(self.kv_dim);
        let qoff = (self.base(slot, layer, pos) / self.kv_dim) * rb;
        &self.k_q[qoff..qoff + rb]
    }

    /// V-row companion of [`KvCache::k_block_bytes_at`].
    pub fn v_block_bytes_at(&self, slot: usize, layer: usize, pos: usize) -> &[u8] {
        assert_eq!(self.scheme, KvScheme::Q8_0, "block bytes exist only on q8_0 pools");
        let rb = self.scheme.row_bytes(self.kv_dim);
        let qoff = (self.base(slot, layer, pos) / self.kv_dim) * rb;
        &self.v_q[qoff..qoff + rb]
    }

    /// Advance `slot`'s position counter after all layers of a ubatch of
    /// `n` tokens have been stored. The positions must already be covered
    /// by a `try_reserve`.
    pub fn advance(&mut self, slot: usize, n: usize) -> Result<(), CacheError> {
        let len = self.lens[slot];
        if len + n > self.max_seq {
            return Err(CacheError::ContextOverflow {
                slot,
                len,
                need: n,
                max_seq: self.max_seq,
            });
        }
        let reserved = self.tables[slot].len() * self.page_size;
        if len + n > reserved {
            return Err(CacheError::Unreserved {
                slot,
                len,
                need: n,
                reserved,
            });
        }
        self.lens[slot] = len + n;
        Ok(())
    }

    /// Roll `slot` back to `new_len` positions — the speculative-decode
    /// rejection path. Shrinks the logical length and releases this
    /// slot's references to pages wholly beyond the retained span; the
    /// retained partial page keeps its bytes (positions past `new_len`
    /// are dead until a later reserve/store overwrites them, and
    /// attention never reads past `slot_len`). Refcount/CoW-safe: only
    /// this slot's references drop, so pages shared with other block
    /// tables or pinned by the prefix index live on.
    pub fn truncate(&mut self, slot: usize, new_len: usize) {
        assert!(
            new_len <= self.lens[slot],
            "truncate can only shrink: slot {slot} at len {} asked for {new_len}",
            self.lens[slot]
        );
        self.lens[slot] = new_len;
        let keep = self.pages_needed(new_len);
        while self.tables[slot].len() > keep {
            let page = self.tables[slot].pop().expect("table longer than keep");
            self.release_ref(page);
        }
    }

    /// K vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn k_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        debug_assert!(pos < self.max_seq);
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.k[base..base + head_dim]
    }

    /// V vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn v_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.v[base..base + head_dim]
    }

    /// Bytes one decode step must stream if the cache lives host-side
    /// and attention is offloaded (scheme-encoded cache entries, both K
    /// and V). Paging makes the transfer page-granular: whole pages
    /// covering `ctx` positions move, so `2 formats × pages(ctx) ×
    /// page_size × row_bytes(kv_dim)` per layer — f16 rows are
    /// `2 × kv_dim` bytes, q8_0 rows `kv_dim / 32 × 34` (a 1.88× cut).
    pub fn stream_bytes_per_layer(&self, ctx: usize) -> usize {
        2 * self.pages_needed(ctx) * self.page_size * self.scheme.row_bytes(self.kv_dim)
    }

    /// Encoded bytes of one whole page, all layers, both K and V — the
    /// unit the swap-traffic accounting charges per eviction/swap-in,
    /// sized by the pool's [`KvScheme`].
    pub fn page_bytes(&self) -> usize {
        2 * self.n_layers * self.page_size * self.scheme.row_bytes(self.kv_dim)
    }

    /// Total resident size of the cache (scheme-encoded, all layers,
    /// both K and V) at the current allocation — the quantity that grows
    /// with live context in the paper's long-context discussion. Paging
    /// makes residency page-granular (slack inside a sequence's last
    /// page is resident even though not yet written), and refcounting
    /// makes it **dedup-aware**: a page aliased by several block tables
    /// counts once.
    pub fn resident_bytes(&self) -> usize {
        self.bytes_for_pages(self.used_pages())
    }

    /// What the current block tables would cost under exclusive
    /// ownership: per-slot page references counted with multiplicity.
    /// `logical − resident` (clamped at the index-only pages) is the
    /// memory prefix sharing saves.
    pub fn logical_resident_bytes(&self) -> usize {
        let refs: usize = self.tables.iter().map(Vec::len).sum();
        self.bytes_for_pages(refs)
    }

    /// Lifetime peak of [`KvCache::resident_bytes`] — tracked at
    /// allocation time, so it is exact even when pages are freed between
    /// observations (what the serve report surfaces per worker).
    pub fn peak_resident_bytes(&self) -> usize {
        self.bytes_for_pages(self.peak_used)
    }

    fn bytes_for_pages(&self, pages: usize) -> usize {
        pages * self.page_bytes()
    }

    // ---- encoding-consistency audit surface ----

    /// Host-side backing lengths of the device pool, for the auditor's
    /// encoding-consistency rule: `(k_mirror_cells, v_mirror_cells,
    /// k_block_bytes, v_block_bytes)`. Invariant: mirrors always hold
    /// `n_pages × n_layers × page_size × kv_dim` f32 cells; block arrays
    /// hold `n_pages × page_q_bytes` under [`KvScheme::Q8_0`] and are
    /// empty under [`KvScheme::F16`].
    pub fn pool_backing_lens(&self) -> (usize, usize, usize, usize) {
        (self.k.len(), self.v.len(), self.k_q.len(), self.v_q.len())
    }

    /// Expected per-scheme payload of one arena-held page:
    /// `(mirror_f32_cells, block_bytes)` counting K and V together. F16
    /// pools swap the f32 mirror (lossless restore of the exact
    /// reference); Q8_0 pools swap only the canonical block bytes.
    pub fn arena_expected_payload(&self) -> (usize, usize) {
        match self.scheme {
            KvScheme::F16 => (2 * self.page_cells(), 0),
            KvScheme::Q8_0 => (0, 2 * self.page_q_bytes()),
        }
    }

    /// Stored payload of every arena entry, sorted by chain key:
    /// `(key, mirror_f32_cells, block_bytes)` — each must match
    /// [`KvCache::arena_expected_payload`] or the page cannot restore
    /// under the pool's scheme.
    pub fn arena_payloads(&self) -> Vec<(u64, usize, usize)> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| {
            let mut out: Vec<(u64, usize, usize)> = p
                .arena
                .iter()
                .map(|(&key, sp)| {
                    (key, sp.k.len() + sp.v.len(), sp.k_q.len() + sp.v_q.len())
                })
                .collect();
            out.sort_by_key(|r| r.0);
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    /// Reserve + per-layer store + advance for one position.
    fn put(c: &mut KvCache, slot: usize, pos: usize, n_layers: usize, fill: f32) {
        c.try_reserve(slot, 1).unwrap();
        let kv_dim = c.kv_dim;
        for layer in 0..n_layers {
            c.store(slot, layer, pos, &vec![fill; kv_dim], &vec![-fill; kv_dim]);
        }
        c.advance(slot, 1).unwrap();
    }

    /// Fill `n` page-aligned tokens of `slot` with distinct values and
    /// commit them.
    fn fill_tokens(c: &mut KvCache, slot: usize, tokens: &[u32]) {
        let kv_dim = c.kv_dim;
        let n_layers = {
            let cfg = ModelConfig::tiny();
            cfg.n_layers
        };
        c.try_reserve(slot, tokens.len()).unwrap();
        for (pos, &t) in tokens.iter().enumerate() {
            for layer in 0..n_layers {
                let fill = (t as f32) * 100.0 + layer as f32;
                c.store(slot, layer, pos, &vec![fill; kv_dim], &vec![-fill; kv_dim]);
            }
        }
        c.advance(slot, tokens.len()).unwrap();
    }

    #[test]
    fn store_and_read_roundtrip() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let kv_dim = cfg.kv_dim();
        for pos in 0..3 {
            c.try_reserve(0, 1).unwrap();
            for layer in 0..cfg.n_layers {
                let k: Vec<f32> =
                    (0..kv_dim).map(|i| (pos * 100 + layer * 10 + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.store(0, layer, pos, &k, &v);
            }
            c.advance(0, 1).unwrap();
        }
        assert_eq!(c.len(), 3);
        let hd = cfg.head_dim;
        let k = c.k_at(0, 1, 2, 1, hd);
        assert_eq!(k[0], (2 * 100 + 10 + hd) as f32);
        let v = c.v_at(0, 1, 2, 1, hd);
        assert_eq!(v[0], -((2 * 100 + 10 + hd) as f32));
    }

    #[test]
    fn reset_empties_and_returns_pages() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let total = c.n_pages();
        put(&mut c, 0, 0, cfg.n_layers, 0.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_pages(), 1);
        c.reset();
        assert!(c.is_empty());
        assert_eq!(c.free_page_count(), total, "all pages back on the free list");
    }

    #[test]
    fn slots_are_independent() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 3);
        // Write distinct data at the same (layer, pos) of two slots.
        for (slot, fill) in [(0usize, 1.0f32), (2, 7.0)] {
            put(&mut c, slot, 0, cfg.n_layers, fill);
        }
        assert_eq!(c.slot_len(0), 1);
        assert_eq!(c.slot_len(1), 0);
        assert_eq!(c.slot_len(2), 1);
        assert_eq!(c.k_at(0, 0, 0, 0, cfg.head_dim)[0], 1.0);
        assert_eq!(c.k_at(2, 0, 0, 0, cfg.head_dim)[0], 7.0);
        assert_eq!(c.v_at(2, 1, 0, 1, cfg.head_dim)[0], -7.0);
        c.reset_slot(2);
        assert_eq!(c.slot_len(2), 0);
        assert_eq!(c.slot_len(0), 1, "resetting one slot leaves others");
        assert_eq!(c.used_pages(), 1, "slot 2's page returned");
    }

    #[test]
    fn ubatch_advance_by_n() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 2);
        let kv_dim = c.kv_dim;
        c.try_reserve(1, 5).unwrap();
        for layer in 0..cfg.n_layers {
            for pos in 0..5 {
                c.store(1, layer, pos, &vec![pos as f32; kv_dim], &vec![0.0; kv_dim]);
            }
        }
        c.advance(1, 5).unwrap();
        assert_eq!(c.slot_len(1), 5);
        assert_eq!(c.k_at(1, 0, 3, 0, cfg.head_dim)[0], 3.0);
    }

    #[test]
    fn pages_allocate_lazily_across_boundaries() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        c.try_reserve(0, 3).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1, "3 tokens fit one 4-token page");
        c.advance(0, 3).unwrap();
        c.try_reserve(0, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1, "4th token still fits");
        c.advance(0, 1).unwrap();
        c.try_reserve(0, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 2, "5th token crosses the boundary");
        c.advance(0, 1).unwrap();
        assert_eq!(c.used_pages(), 2);
        assert_eq!(c.pages_needed(5), 2);
    }

    #[test]
    fn contiguous_geometry_is_one_page_per_slot() {
        // page_size = max_seq, n_pages = n_slots: the old fixed-stride
        // layout exactly.
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, cfg.max_seq_len, 2);
        c.try_reserve(0, cfg.max_seq_len).unwrap();
        c.try_reserve(1, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1);
        assert_eq!(c.slot_pages(1).len(), 1);
        assert_eq!(c.free_page_count(), 0);
    }

    #[test]
    fn byte_accounting_is_page_granular() {
        let cfg = ModelConfig::qwen3_1_7b();
        // Small pool: accounting depends on geometry, not pool size.
        let mut c = KvCache::paged(&cfg, 1, 16, 4);
        // 1.7B: kv_dim = 8*128 = 1024; ctx 48 = 3 pages of 16, so per
        // layer: 2 formats * 48 * 1024 * 2 bytes.
        assert_eq!(c.stream_bytes_per_layer(48), 2 * 48 * 1024 * 2);
        // ctx 40 rounds up to 48 positions' worth of pages.
        assert_eq!(c.stream_bytes_per_layer(40), 2 * 48 * 1024 * 2);
        assert_eq!(c.resident_bytes(), 0);
        c.try_reserve(0, 17).unwrap();
        c.advance(0, 17).unwrap();
        // 17 tokens = 2 pages resident, both K and V, f16, all layers.
        assert_eq!(c.resident_bytes(), 2 * 2 * cfg.n_layers * 16 * 1024 * 2);
    }

    #[test]
    fn peak_residency_watermark() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        assert_eq!(c.peak_resident_bytes(), 0);
        c.try_reserve(0, 9).unwrap(); // 3 pages
        c.advance(0, 9).unwrap();
        c.try_reserve(1, 2).unwrap(); // 1 page → peak 4
        c.advance(1, 2).unwrap();
        let peak = c.peak_resident_bytes();
        assert_eq!(peak, 2 * 4 * cfg.n_layers * 4 * cfg.kv_dim() * 2);
        c.reset_slot(0);
        assert!(c.resident_bytes() < peak);
        assert_eq!(c.peak_resident_bytes(), peak, "watermark survives frees");
    }

    #[test]
    fn context_overflow_is_typed() {
        let mut cfg = ModelConfig::tiny();
        cfg.max_seq_len = 2;
        let mut c = KvCache::new(&cfg);
        c.try_reserve(0, 2).unwrap();
        c.advance(0, 2).unwrap();
        let err = c.try_reserve(0, 1).unwrap_err();
        assert_eq!(
            err,
            CacheError::ContextOverflow { slot: 0, len: 2, need: 1, max_seq: 2 }
        );
        let msg = err.to_string();
        assert!(msg.contains("slot 0") && msg.contains("len 2"), "{msg}");
    }

    #[test]
    fn out_of_pages_is_typed_and_atomic() {
        let cfg = ModelConfig::tiny();
        // 3 pages of 4 tokens shared by 2 slots.
        let mut c = KvCache::paged(&cfg, 2, 4, 3);
        c.try_reserve(0, 8).unwrap();
        c.advance(0, 8).unwrap();
        let free_before = c.free_page_count();
        let err = c.try_reserve(1, 8).unwrap_err();
        assert_eq!(
            err,
            CacheError::OutOfPages { slot: 1, len: 0, need_pages: 2, free_pages: 1, n_pages: 3 }
        );
        assert_eq!(c.free_page_count(), free_before, "failed reserve takes nothing");
        assert!(c.slot_pages(1).is_empty());
        // Freeing slot 0 makes the same reservation succeed.
        c.reset_slot(0);
        c.try_reserve(1, 8).unwrap();
    }

    #[test]
    fn advance_without_reserve_is_typed() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 1, 4, 2);
        let err = c.advance(0, 3).unwrap_err();
        assert_eq!(err, CacheError::Unreserved { slot: 0, len: 0, need: 3, reserved: 0 });
    }

    #[test]
    fn pool_conservation_under_churn() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 3, 2, 9);
        c.try_reserve(0, 5).unwrap();
        c.advance(0, 5).unwrap();
        c.try_reserve(1, 2).unwrap();
        c.advance(1, 2).unwrap();
        c.try_reserve(2, 3).unwrap();
        c.advance(2, 3).unwrap();
        let owned: usize = (0..3).map(|s| c.slot_pages(s).len()).sum();
        assert_eq!(owned + c.free_page_count(), c.n_pages());
        c.reset_slot(1);
        let owned: usize = (0..3).map(|s| c.slot_pages(s).len()).sum();
        assert_eq!(owned + c.free_page_count(), c.n_pages());
    }

    // ---- refcounts, CoW, prefix index, swap ----

    #[test]
    fn alias_shares_and_reset_releases_last_ref() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 4);
        fill_tokens(&mut c, 0, &[1, 2, 3, 4]);
        let page = c.slot_pages(0)[0];
        assert_eq!(c.page_ref(page), 1);
        c.alias_page(1, page);
        assert_eq!(c.page_ref(page), 2);
        assert_eq!(c.slot_len(1), 4);
        assert_eq!(c.used_pages(), 1, "sharing allocates nothing");
        // The reader sees the writer's bytes through its own table.
        assert_eq!(
            c.k_at(1, 0, 0, 0, cfg.head_dim)[0],
            c.k_at(0, 0, 0, 0, cfg.head_dim)[0]
        );
        c.reset_slot(0);
        assert_eq!(c.page_ref(page), 1, "slot 1 still holds the page");
        assert_eq!(c.free_page_count(), 3);
        c.reset_slot(1);
        assert_eq!(c.page_ref(page), 0);
        assert_eq!(c.free_page_count(), 4, "last release frees");
    }

    #[test]
    fn store_on_shared_page_copies_on_write() {
        let cfg = ModelConfig::tiny();
        let kv_dim = cfg.kv_dim();
        let mut c = KvCache::paged(&cfg, 2, 4, 4);
        fill_tokens(&mut c, 0, &[1, 2, 3, 4]);
        let shared = c.slot_pages(0)[0];
        c.alias_page(1, shared);
        let before = c.k_at(0, 0, 2, 0, cfg.head_dim)[0];
        // Slot 1 overwrites position 2: must split, not clobber slot 0.
        c.store(1, 0, 2, &vec![999.0; kv_dim], &vec![-999.0; kv_dim]);
        assert_ne!(c.slot_pages(1)[0], shared, "writer got a private copy");
        assert_eq!(c.page_ref(shared), 1, "reader keeps the original");
        assert_eq!(c.k_at(0, 0, 2, 0, cfg.head_dim)[0], before, "reader bytes intact");
        assert_eq!(c.k_at(1, 0, 2, 0, cfg.head_dim)[0], 999.0);
        // Untouched cells of the copy match the original (whole-page copy).
        assert_eq!(
            c.k_at(1, 1, 0, 0, cfg.head_dim)[0],
            c.k_at(0, 1, 0, 0, cfg.head_dim)[0]
        );
        assert_eq!(c.reuse_stats().cow_pages, 1);
    }

    #[test]
    fn prefix_register_adopt_roundtrip() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        c.enable_prefix_cache(42);
        let prompt = [10u32, 11, 12, 13, 14, 15, 16, 17, 99, 98];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        // Two full pages registered (last two tokens are a partial page).
        assert_eq!(c.cached_resident_pages(), 2);
        let p0 = c.slot_pages(0)[0];
        assert_eq!(c.page_ref(p0), 2, "slot + index");

        // A second slot with the same prompt prefix adopts both pages.
        let adopted = c.adopt_prefix(1, &prompt, prompt.len() - 1);
        assert_eq!(adopted.tokens, 8);
        assert_eq!(adopted.pages, c.slot_pages(0)[..2].to_vec());
        assert_eq!(c.slot_len(1), 8);
        assert_eq!(c.page_ref(p0), 3);
        // Bytes visible through the adopting slot match the original.
        assert_eq!(
            c.k_at(1, 2, 5, 0, cfg.head_dim)[0],
            c.k_at(0, 2, 5, 0, cfg.head_dim)[0]
        );

        // A diverging prompt only matches the first page.
        c.reset_slot(1);
        let diverged = [10u32, 11, 12, 13, 77, 77, 77, 77, 78, 79, 80, 81];
        let adopted = c.adopt_prefix(1, &diverged, diverged.len() - 1);
        assert_eq!(adopted.tokens, 4, "chain stops at the first mismatch");

        // Finished-but-cached: the creator resets, pages survive.
        c.reset_slot(1);
        c.reset_slot(0);
        assert_eq!(c.cached_resident_pages(), 2, "index keeps the pages");
        assert_eq!(c.page_ref(p0), 1);
        let adopted = c.adopt_prefix(0, &prompt, prompt.len() - 1);
        assert_eq!(adopted.tokens, 8, "recently-finished prefix still hits");
    }

    #[test]
    fn peek_matches_adopt_and_respects_caps() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        c.enable_prefix_cache(7);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        let (tokens, resident, swapped) = c.peek_prefix(&prompt, prompt.len() - 1);
        assert_eq!((tokens, resident, swapped), (4, 1, 0), "cap excludes the last page");
        let (tokens, ..) = c.peek_prefix(&prompt, prompt.len());
        assert_eq!(tokens, 8);
        let adopted = c.adopt_prefix(1, &prompt, prompt.len() - 1);
        assert_eq!(adopted.tokens, 4, "adopt honors the same cap");
    }

    #[test]
    fn eviction_reclaims_cached_pages_for_reservations() {
        let cfg = ModelConfig::tiny();
        // 3 pages of 4: one sequence of 8 registers 2 cached pages.
        let mut c = KvCache::paged(&cfg, 2, 4, 3);
        c.enable_prefix_cache(1);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        c.reset_slot(0);
        assert_eq!(c.free_page_count(), 1);
        assert_eq!(c.reclaimable_pages(), 2);
        // A 12-token reservation needs all 3 pages: the two cached pages
        // are evicted (dropped — no swap arena).
        c.try_reserve(1, 12).unwrap();
        assert_eq!(c.slot_pages(1).len(), 3);
        assert_eq!(c.cached_resident_pages(), 0);
        assert_eq!(c.reuse_stats().dropped_pages, 2);
        // Over-asking is still a typed error even counting reclaimables.
        c.reset_slot(1);
        fill_tokens(&mut c, 0, &prompt);
        let err = c.try_reserve(1, 12).unwrap_err();
        assert!(matches!(err, CacheError::OutOfPages { free_pages: 1, .. }), "{err:?}");
    }

    #[test]
    fn swap_out_and_swap_in_roundtrip_is_bit_exact() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 3);
        c.enable_prefix_cache(9);
        c.set_swap_capacity(4);
        let prompt = [21u32, 22, 23, 24, 25, 26, 27, 28];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        // Snapshot the cached bytes before eviction.
        let want_k = c.k_at(0, 1, 5, 0, cfg.head_dim)[0];
        let want_v = c.v_at(0, 1, 5, 0, cfg.head_dim)[0];
        c.reset_slot(0);
        // Force both cached pages out via a full reservation…
        c.try_reserve(1, 12).unwrap();
        assert_eq!(c.swapped_out_pages(), 2, "evictions went to the arena");
        assert_eq!(c.reuse_stats().swap_out_pages, 2);
        assert_eq!(c.reuse_stats().dropped_pages, 0);
        let (in_b, out_b) = c.take_pending_swap_bytes();
        assert_eq!(in_b, 0);
        assert_eq!(out_b, 2 * c.page_bytes());
        // …then release and adopt: pages swap back in, bit-exact.
        c.reset_slot(1);
        let adopted = c.adopt_prefix(0, &prompt, prompt.len());
        assert_eq!(adopted.tokens, 8);
        assert_eq!(c.reuse_stats().swap_in_pages, 2);
        assert_eq!(c.swapped_out_pages(), 0);
        assert_eq!(c.k_at(0, 1, 5, 0, cfg.head_dim)[0], want_k);
        assert_eq!(c.v_at(0, 1, 5, 0, cfg.head_dim)[0], want_v);
        let (in_b, out_b) = c.take_pending_swap_bytes();
        assert_eq!(in_b, 2 * c.page_bytes());
        assert_eq!(out_b, 0);
    }

    #[test]
    fn clear_prefix_cache_releases_everything() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 1, 4, 4);
        c.enable_prefix_cache(3);
        c.set_swap_capacity(2);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        c.reset_slot(0);
        assert!(c.free_page_count() < c.n_pages());
        c.clear_prefix_cache();
        assert_eq!(c.free_page_count(), c.n_pages(), "full flush frees the pool");
        assert_eq!(c.cached_resident_pages(), 0);
        assert_eq!(c.swapped_out_pages(), 0);
    }

    #[test]
    fn truncate_releases_whole_pages_and_keeps_partials() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        fill_tokens(&mut c, 0, &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(c.slot_pages(0).len(), 3);
        // Mid-page rollback: 10 -> 6 drops the third page, keeps the
        // second (position 5 lives there) with its bytes intact.
        let want = c.k_at(0, 1, 5, 0, cfg.head_dim)[0];
        c.truncate(0, 6);
        assert_eq!(c.slot_len(0), 6);
        assert_eq!(c.slot_pages(0).len(), 2);
        assert_eq!(c.free_page_count(), 6);
        assert_eq!(c.k_at(0, 1, 5, 0, cfg.head_dim)[0], want);
        // Page-boundary rollback: 6 -> 4 drops the now-dead second page.
        c.truncate(0, 4);
        assert_eq!(c.slot_pages(0).len(), 1);
        // No-op rollback keeps everything.
        c.truncate(0, 4);
        assert_eq!(c.slot_len(0), 4);
        assert_eq!(c.slot_pages(0).len(), 1);
        // To zero: the slot is as after reset_slot.
        c.truncate(0, 0);
        assert!(c.slot_pages(0).is_empty());
        assert_eq!(c.free_page_count(), 8);
        // The pool can re-serve the returned pages.
        c.try_reserve(1, 8).unwrap();
        c.advance(1, 8).unwrap();
    }

    #[test]
    fn truncate_respects_shared_and_indexed_pages() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        c.enable_prefix_cache(11);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        // Slot 1 adopts both pages, then grows a private tail page.
        let adopted = c.adopt_prefix(1, &prompt, prompt.len());
        assert_eq!(adopted.tokens, 8);
        c.try_reserve(1, 3).unwrap();
        c.advance(1, 3).unwrap();
        let shared = c.slot_pages(1)[1];
        assert_eq!(c.page_ref(shared), 3, "slot 0 + slot 1 + index");
        // Rolling slot 1 back into the shared page drops only its own
        // references: the private tail page frees, the shared page loses
        // one ref but stays resident for the other holders.
        c.truncate(1, 6);
        assert_eq!(c.slot_pages(1).len(), 2);
        assert_eq!(c.page_ref(shared), 3, "partial page retained, ref kept");
        c.truncate(1, 4);
        assert_eq!(c.page_ref(shared), 2, "slot 1's ref dropped");
        assert_eq!(c.cached_resident_pages(), 2, "index pins survive");
        // Slot 0's view of the shared page is untouched.
        assert_eq!(
            c.k_at(0, 0, 5, 0, cfg.head_dim)[0],
            (prompt[5] as f32) * 100.0
        );
    }

    #[test]
    #[should_panic(expected = "truncate can only shrink")]
    fn truncate_rejects_growth() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 1, 4, 2);
        fill_tokens(&mut c, 0, &[1, 2]);
        c.truncate(0, 3);
    }

    #[test]
    fn resident_bytes_are_dedup_aware() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 3, 4, 8);
        c.enable_prefix_cache(5);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        c.adopt_prefix(1, &prompt, prompt.len());
        c.adopt_prefix(2, &prompt, prompt.len());
        // Three block tables reference the same two pages: physical
        // residency counts them once, logical counts per reference.
        assert_eq!(c.used_pages(), 2);
        assert_eq!(c.resident_bytes(), 2 * c.page_bytes());
        assert_eq!(c.logical_resident_bytes(), 6 * c.page_bytes());
    }

    #[test]
    fn fingerprint_separates_incompatible_caches() {
        let cfg = ModelConfig::tiny();
        let prompt = [1u32, 2, 3, 4];
        let key_a = chain_key(1, 1, &prompt);
        let key_b = chain_key(2, 2, &prompt);
        assert_ne!(key_a, key_b, "fingerprint must split chain keys");
        let mut c = KvCache::paged(&cfg, 2, 4, 4);
        c.enable_prefix_cache(1);
        fill_tokens(&mut c, 0, &prompt);
        c.register_prefix(0, &prompt);
        assert_eq!(c.peek_prefix(&prompt, 4).0, 4);
    }
}
