//! Per-layer KV cache.
//!
//! The paper's host CPU owns "KV cache management" (§III.A), and the
//! decode phase's LOAD-bound behaviour (§V.B) comes from streaming this
//! cache to the accelerator every step. The functional engine keeps K/V in
//! f32; the *byte accounting* used by the timing path models the llama.cpp
//! default of an FP16 cache (see `MatvecOp::weight_bytes` with
//! `GgmlType::F16`).

use crate::model::config::ModelConfig;

/// KV cache for all layers: `[n_layers][max_seq][kv_dim]`, row-major.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub kv_dim: usize,
    pub max_seq: usize,
    /// Current number of cached positions (shared across layers).
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    n_layers: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        let kv_dim = cfg.kv_dim();
        KvCache {
            kv_dim,
            max_seq: cfg.max_seq_len,
            len: 0,
            k: vec![0.0; cfg.n_layers * cfg.max_seq_len * kv_dim],
            v: vec![0.0; cfg.n_layers * cfg.max_seq_len * kv_dim],
            n_layers: cfg.n_layers,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Clear all cached positions (new request on the same engine).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Append one position's K and V for layer `layer`. Positions must be
    /// appended for every layer before `advance()` is called.
    pub fn store(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        assert!(self.len < self.max_seq, "KV cache full ({})", self.max_seq);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let base = (layer * self.max_seq + self.len) * self.kv_dim;
        self.k[base..base + self.kv_dim].copy_from_slice(k);
        self.v[base..base + self.kv_dim].copy_from_slice(v);
    }

    /// Advance the shared position counter after all layers stored.
    pub fn advance(&mut self) {
        self.len += 1;
    }

    /// K vector of head `kv_head` at position `pos` in `layer`.
    #[inline]
    pub fn k_at(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        debug_assert!(pos < self.len || pos < self.max_seq);
        let base = (layer * self.max_seq + pos) * self.kv_dim + kv_head * head_dim;
        &self.k[base..base + head_dim]
    }

    /// V vector of head `kv_head` at position `pos` in `layer`.
    #[inline]
    pub fn v_at(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        let base = (layer * self.max_seq + pos) * self.kv_dim + kv_head * head_dim;
        &self.v[base..base + head_dim]
    }

    /// Bytes one decode step must stream if the cache lives host-side and
    /// attention is offloaded (FP16 cache entries, both K and V):
    /// `2 formats × ctx × kv_dim × 2 bytes` per layer.
    pub fn stream_bytes_per_layer(&self, ctx: usize) -> usize {
        2 * ctx * self.kv_dim * 2
    }

    /// Total resident size of the cache at the current length (f16
    /// accounting, all layers) — the quantity that grows linearly with
    /// context in the paper's long-context discussion.
    pub fn resident_bytes_f16(&self) -> usize {
        2 * self.n_layers * self.len * self.kv_dim * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn store_and_read_roundtrip() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let kv_dim = cfg.kv_dim();
        for pos in 0..3 {
            for layer in 0..cfg.n_layers {
                let k: Vec<f32> = (0..kv_dim).map(|i| (pos * 100 + layer * 10 + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.store(layer, &k, &v);
            }
            c.advance();
        }
        assert_eq!(c.len(), 3);
        let hd = cfg.head_dim;
        let k = c.k_at(1, 2, 1, hd);
        assert_eq!(k[0], (2 * 100 + 10 + hd) as f32);
        let v = c.v_at(1, 2, 1, hd);
        assert_eq!(v[0], -((2 * 100 + 10 + hd) as f32));
    }

    #[test]
    fn reset_empties() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        for layer in 0..cfg.n_layers {
            c.store(layer, &vec![0.0; c.kv_dim], &vec![0.0; c.kv_dim]);
        }
        c.advance();
        assert_eq!(c.len(), 1);
        c.reset();
        assert!(c.is_empty());
    }

    #[test]
    fn byte_accounting() {
        let cfg = ModelConfig::qwen3_1_7b();
        let c = KvCache::new(&cfg);
        // 1.7B: kv_dim = 8*128 = 1024; per layer per ctx entry: 2*2*1024 B.
        assert_eq!(c.stream_bytes_per_layer(48), 2 * 48 * 1024 * 2);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_detected() {
        let mut cfg = ModelConfig::tiny();
        cfg.max_seq_len = 2;
        let mut c = KvCache::new(&cfg);
        for _ in 0..3 {
            c.store(0, &vec![0.0; c.kv_dim], &vec![0.0; c.kv_dim]);
            c.advance();
        }
    }
}
