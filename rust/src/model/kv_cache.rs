//! Multi-sequence, slot-indexed KV cache.
//!
//! The paper's host CPU owns "KV cache management" (§III.A), and the
//! decode phase's LOAD-bound behaviour (§V.B) comes from streaming this
//! cache to the accelerator every step. Serving interleaves many
//! sequences on one engine (continuous batching), so the cache is
//! organised as `n_slots` independent sequences over one allocation:
//! each [`crate::model::engine::Session`] owns one slot, and every slot
//! tracks its own length. The functional engine keeps K/V in f32; the
//! *byte accounting* used by the timing path models the llama.cpp
//! default of an FP16 cache (see `MatvecOp::weight_bytes` with
//! `GgmlType::F16`).

use crate::model::config::ModelConfig;

/// KV cache for all layers and session slots:
/// `[n_layers][n_slots][max_seq][kv_dim]`, row-major.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub kv_dim: usize,
    /// Per-slot context capacity.
    pub max_seq: usize,
    /// Number of independent sequences the cache can hold.
    pub n_slots: usize,
    /// Current number of cached positions per slot (shared across layers).
    lens: Vec<usize>,
    k: Vec<f32>,
    v: Vec<f32>,
    n_layers: usize,
}

impl KvCache {
    /// Single-sequence cache (the legacy one-request-at-a-time engine).
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::with_slots(cfg, 1)
    }

    /// Cache holding `n_slots` independent sequences.
    pub fn with_slots(cfg: &ModelConfig, n_slots: usize) -> KvCache {
        assert!(n_slots >= 1, "need at least one session slot");
        let kv_dim = cfg.kv_dim();
        let cells = cfg.n_layers * n_slots * cfg.max_seq_len * kv_dim;
        KvCache {
            kv_dim,
            max_seq: cfg.max_seq_len,
            n_slots,
            lens: vec![0; n_slots],
            k: vec![0.0; cells],
            v: vec![0.0; cells],
            n_layers: cfg.n_layers,
        }
    }

    /// Length of slot 0 — the single-sequence engine's implicit slot.
    pub fn len(&self) -> usize {
        self.lens[0]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }

    /// Current number of cached positions in `slot`.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Clear every slot (fresh engine).
    pub fn reset(&mut self) {
        self.lens.fill(0);
    }

    /// Clear one slot (session closed / slot reassigned).
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
    }

    #[inline]
    fn base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.n_slots && layer < self.n_layers);
        ((layer * self.n_slots + slot) * self.max_seq + pos) * self.kv_dim
    }

    /// Write one position's K and V for `layer` of `slot`. A ubatch
    /// stores `pos` values `slot_len(slot)..slot_len(slot)+n` for every
    /// layer, then calls `advance(slot, n)` once.
    pub fn store(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "KV cache full ({})", self.max_seq);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let base = self.base(slot, layer, pos);
        self.k[base..base + self.kv_dim].copy_from_slice(k);
        self.v[base..base + self.kv_dim].copy_from_slice(v);
    }

    /// Advance `slot`'s position counter after all layers of a ubatch of
    /// `n` tokens have been stored.
    pub fn advance(&mut self, slot: usize, n: usize) {
        assert!(
            self.lens[slot] + n <= self.max_seq,
            "KV cache full ({})",
            self.max_seq
        );
        self.lens[slot] += n;
    }

    /// K vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn k_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        debug_assert!(pos < self.max_seq);
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.k[base..base + head_dim]
    }

    /// V vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn v_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.v[base..base + head_dim]
    }

    /// Bytes one decode step must stream if the cache lives host-side and
    /// attention is offloaded (FP16 cache entries, both K and V):
    /// `2 formats × ctx × kv_dim × 2 bytes` per layer.
    pub fn stream_bytes_per_layer(&self, ctx: usize) -> usize {
        2 * ctx * self.kv_dim * 2
    }

    /// Total resident size of the cache at the current lengths (f16
    /// accounting, all layers, all live sequences) — the quantity that
    /// grows linearly with context in the paper's long-context discussion.
    pub fn resident_bytes_f16(&self) -> usize {
        let live: usize = self.lens.iter().sum();
        2 * self.n_layers * live * self.kv_dim * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn store_and_read_roundtrip() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let kv_dim = cfg.kv_dim();
        for pos in 0..3 {
            for layer in 0..cfg.n_layers {
                let k: Vec<f32> =
                    (0..kv_dim).map(|i| (pos * 100 + layer * 10 + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.store(0, layer, pos, &k, &v);
            }
            c.advance(0, 1);
        }
        assert_eq!(c.len(), 3);
        let hd = cfg.head_dim;
        let k = c.k_at(0, 1, 2, 1, hd);
        assert_eq!(k[0], (2 * 100 + 10 + hd) as f32);
        let v = c.v_at(0, 1, 2, 1, hd);
        assert_eq!(v[0], -((2 * 100 + 10 + hd) as f32));
    }

    #[test]
    fn reset_empties() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        for layer in 0..cfg.n_layers {
            c.store(0, layer, 0, &vec![0.0; c.kv_dim], &vec![0.0; c.kv_dim]);
        }
        c.advance(0, 1);
        assert_eq!(c.len(), 1);
        c.reset();
        assert!(c.is_empty());
    }

    #[test]
    fn slots_are_independent() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 3);
        let kv_dim = c.kv_dim;
        // Write distinct data at the same (layer, pos) of two slots.
        for (slot, fill) in [(0usize, 1.0f32), (2, 7.0)] {
            for layer in 0..cfg.n_layers {
                c.store(slot, layer, 0, &vec![fill; kv_dim], &vec![-fill; kv_dim]);
            }
            c.advance(slot, 1);
        }
        assert_eq!(c.slot_len(0), 1);
        assert_eq!(c.slot_len(1), 0);
        assert_eq!(c.slot_len(2), 1);
        assert_eq!(c.k_at(0, 0, 0, 0, cfg.head_dim)[0], 1.0);
        assert_eq!(c.k_at(2, 0, 0, 0, cfg.head_dim)[0], 7.0);
        assert_eq!(c.v_at(2, 1, 0, 1, cfg.head_dim)[0], -7.0);
        c.reset_slot(2);
        assert_eq!(c.slot_len(2), 0);
        assert_eq!(c.slot_len(0), 1, "resetting one slot leaves others");
    }

    #[test]
    fn ubatch_advance_by_n() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 2);
        let kv_dim = c.kv_dim;
        for layer in 0..cfg.n_layers {
            for pos in 0..5 {
                c.store(1, layer, pos, &vec![pos as f32; kv_dim], &vec![0.0; kv_dim]);
            }
        }
        c.advance(1, 5);
        assert_eq!(c.slot_len(1), 5);
        assert_eq!(c.k_at(1, 0, 3, 0, cfg.head_dim)[0], 3.0);
    }

    #[test]
    fn byte_accounting() {
        let cfg = ModelConfig::qwen3_1_7b();
        let c = KvCache::new(&cfg);
        // 1.7B: kv_dim = 8*128 = 1024; per layer per ctx entry: 2*2*1024 B.
        assert_eq!(c.stream_bytes_per_layer(48), 2 * 48 * 1024 * 2);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_detected() {
        let mut cfg = ModelConfig::tiny();
        cfg.max_seq_len = 2;
        let mut c = KvCache::new(&cfg);
        for pos in 0..3 {
            c.store(0, 0, pos, &vec![0.0; c.kv_dim], &vec![0.0; c.kv_dim]);
            c.advance(0, 1);
        }
    }
}
