//! Paged multi-sequence KV cache.
//!
//! The paper's host CPU owns "KV cache management" (§III.A), and the
//! decode phase's LOAD-bound behaviour (§V.B) comes from streaming this
//! cache to the accelerator every step. Serving interleaves many
//! sequences on one engine (continuous batching), so the cache is
//! organised vLLM-style as a **shared pool of fixed-size pages** instead
//! of one fixed-stride slab per slot:
//!
//! * A *page* holds `page_size` consecutive token positions of K and V
//!   for **every layer**: the K (or V) backing store is laid out as
//!   `[n_pages][n_layers][page_size][kv_dim]`, row-major. One logical
//!   page allocation therefore covers all layers of a position range,
//!   which keeps the per-slot block table small and layer-independent.
//! * Each session slot owns a *block table* — the ordered list of page
//!   ids backing logical positions `0..slot_len(slot)`. Position `pos`
//!   of `slot` lives at offset `pos % page_size` inside page
//!   `table[pos / page_size]`.
//! * Unowned pages sit on a LIFO *free list*. [`KvCache::try_reserve`]
//!   pops pages lazily as a slot's sequence crosses page boundaries and
//!   [`KvCache::reset_slot`] pushes exactly that slot's pages back.
//!
//! The practical consequence, and the reason serving wants paging: slot
//! count no longer reserves `max_seq` tokens of memory per sequence.
//! A pool of `n_pages` serves any mix of sequences whose *live* tokens
//! fit, so many short sequences can decode concurrently inside a memory
//! budget that fixed-stride slots would exhaust after a couple of slots
//! (the admission logic lives in
//! [`crate::coordinator::scheduler::ContinuousBatcher`]).
//!
//! `page_size = max_seq, n_pages = n_slots` degenerates to exactly the
//! old contiguous layout — the equivalence suite in
//! `rust/tests/batching_equiv.rs` pins paged execution bit-identical to
//! that reference.
//!
//! Cache exhaustion is a typed [`CacheError`] (carrying slot, current
//! length and the failed requirement) so schedulers can defer admission
//! instead of unwinding. The functional engine keeps K/V in f32; the
//! *byte accounting* used by the timing path models the llama.cpp
//! default of an FP16 cache (see `MatvecOp::weight_bytes` with
//! `GgmlType::F16`) at page granularity.

use std::fmt;

use crate::model::config::ModelConfig;
use crate::util::ceil_div;

/// Default page size in tokens. Small enough that short sequences waste
/// little slack in their last page, large enough that the block table
/// indirection stays cold next to the attention arithmetic.
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Typed KV-cache exhaustion/contract error. Every variant carries the
/// slot, its current length, and what was asked for, so callers (and
/// panics built from `Display`) can report precisely what ran out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CacheError {
    /// The slot's sequence would exceed the model's context window.
    ContextOverflow {
        slot: usize,
        len: usize,
        need: usize,
        max_seq: usize,
    },
    /// The shared page pool has too few free pages for the reservation.
    OutOfPages {
        slot: usize,
        len: usize,
        need_pages: usize,
        free_pages: usize,
        n_pages: usize,
    },
    /// `advance` ran past the positions covered by reserved pages
    /// (missing `try_reserve` call — a scheduling bug, not exhaustion).
    Unreserved {
        slot: usize,
        len: usize,
        need: usize,
        reserved: usize,
    },
}

impl fmt::Display for CacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            CacheError::ContextOverflow { slot, len, need, max_seq } => write!(
                f,
                "KV context overflow: slot {slot} at len {len} needs {need} more \
                 tokens but max_seq is {max_seq}"
            ),
            CacheError::OutOfPages { slot, len, need_pages, free_pages, n_pages } => write!(
                f,
                "KV page pool exhausted: slot {slot} at len {len} needs {need_pages} \
                 more pages but only {free_pages} of {n_pages} are free"
            ),
            CacheError::Unreserved { slot, len, need, reserved } => write!(
                f,
                "KV advance past reservation: slot {slot} at len {len} advances by \
                 {need} but pages only cover {reserved} tokens (call try_reserve first)"
            ),
        }
    }
}

impl std::error::Error for CacheError {}

/// Paged KV cache for all layers and session slots (see module docs for
/// the layout).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub kv_dim: usize,
    /// Per-slot context capacity (model context window).
    pub max_seq: usize,
    /// Number of independent sequences the cache can hold.
    pub n_slots: usize,
    /// Tokens per page.
    page_size: usize,
    /// Total pages in the shared pool.
    n_pages: usize,
    /// Current number of cached positions per slot (shared across layers).
    lens: Vec<usize>,
    /// Per-slot block table: page ids backing positions `0..lens[slot]`
    /// (the last page may be partially filled).
    tables: Vec<Vec<u32>>,
    /// LIFO free list of unowned page ids.
    free: Vec<u32>,
    /// Lifetime high-water mark of owned pages (exact peak residency,
    /// updated at allocation so it can't miss pages freed mid-round).
    peak_used: usize,
    /// `[n_pages][n_layers][page_size][kv_dim]`, row-major.
    k: Vec<f32>,
    v: Vec<f32>,
    n_layers: usize,
}

impl KvCache {
    /// Single-sequence cache (the legacy one-request-at-a-time engine).
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::with_slots(cfg, 1)
    }

    /// Cache holding `n_slots` independent sequences, fully backed: the
    /// pool holds enough pages for every slot to reach `max_seq`, so
    /// reservations can only fail on context overflow (exactly the old
    /// fixed-stride capacity semantics).
    pub fn with_slots(cfg: &ModelConfig, n_slots: usize) -> KvCache {
        let pages = KvCache::full_backing_pages(cfg, n_slots, DEFAULT_PAGE_SIZE);
        KvCache::paged(cfg, n_slots, DEFAULT_PAGE_SIZE, pages)
    }

    /// Pages needed to fully back `n_slots` sequences of `max_seq` tokens.
    pub fn full_backing_pages(cfg: &ModelConfig, n_slots: usize, page_size: usize) -> usize {
        assert!(page_size >= 1, "page_size must be at least 1");
        n_slots * ceil_div(cfg.max_seq_len, page_size)
    }

    /// Cache with an explicit page geometry: `n_slots` sequences sharing
    /// a pool of `n_pages` pages of `page_size` tokens each. The pool may
    /// deliberately be smaller than `n_slots × max_seq` worth of pages —
    /// that is the point of paging; admission control keeps concurrent
    /// sequences inside the budget.
    pub fn paged(cfg: &ModelConfig, n_slots: usize, page_size: usize, n_pages: usize) -> KvCache {
        assert!(n_slots >= 1, "need at least one session slot");
        assert!(page_size >= 1, "page_size must be at least 1");
        assert!(n_pages >= 1, "need at least one page");
        let kv_dim = cfg.kv_dim();
        let cells = n_pages * cfg.n_layers * page_size * kv_dim;
        KvCache {
            kv_dim,
            max_seq: cfg.max_seq_len,
            n_slots,
            page_size,
            n_pages,
            lens: vec![0; n_slots],
            tables: vec![Vec::new(); n_slots],
            // LIFO: page 0 is handed out first.
            free: (0..n_pages as u32).rev().collect(),
            peak_used: 0,
            k: vec![0.0; cells],
            v: vec![0.0; cells],
            n_layers: cfg.n_layers,
        }
    }

    /// Length of slot 0 — the single-sequence engine's implicit slot.
    pub fn len(&self) -> usize {
        self.lens[0]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }

    /// Current number of cached positions in `slot`.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the shared pool.
    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Pages currently on the free list.
    pub fn free_page_count(&self) -> usize {
        self.free.len()
    }

    /// Pages currently owned by slots.
    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// The ordered page ids backing `slot`'s sequence.
    pub fn slot_pages(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    /// The free list (LIFO; the next page handed out is the *last*
    /// element). Exposed for diagnostics and the property suite.
    pub fn free_list(&self) -> &[u32] {
        &self.free
    }

    /// Pages required to hold `n_tokens` tokens.
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        ceil_div(n_tokens, self.page_size)
    }

    /// Clear every slot (fresh engine) and return all pages to the pool.
    pub fn reset(&mut self) {
        for slot in 0..self.n_slots {
            self.reset_slot(slot);
        }
    }

    /// Clear one slot (session closed / slot reassigned), returning
    /// exactly the pages it held to the free list.
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
        // Most-recently-allocated pages go back on top of the LIFO stack.
        while let Some(page) = self.tables[slot].pop() {
            self.free.push(page);
        }
    }

    /// Ensure pages cover positions `slot_len(slot)..slot_len(slot)+n`,
    /// allocating from the free list as needed. Call before `store`-ing a
    /// ubatch. Fails atomically: on `Err` no pages were taken.
    pub fn try_reserve(&mut self, slot: usize, n: usize) -> Result<(), CacheError> {
        let len = self.lens[slot];
        if len + n > self.max_seq {
            return Err(CacheError::ContextOverflow {
                slot,
                len,
                need: n,
                max_seq: self.max_seq,
            });
        }
        let want = self.pages_needed(len + n);
        let have = self.tables[slot].len();
        let need_pages = want.saturating_sub(have);
        if need_pages > self.free.len() {
            return Err(CacheError::OutOfPages {
                slot,
                len,
                need_pages,
                free_pages: self.free.len(),
                n_pages: self.n_pages,
            });
        }
        for _ in 0..need_pages {
            let page = self.free.pop().expect("free count checked above");
            self.tables[slot].push(page);
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Flat index of `(slot, layer, pos)` through the block table.
    #[inline]
    fn base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.n_slots && layer < self.n_layers);
        let page = self.tables[slot][pos / self.page_size] as usize;
        ((page * self.n_layers + layer) * self.page_size + pos % self.page_size) * self.kv_dim
    }

    /// Write one position's K and V for `layer` of `slot`. A ubatch
    /// first calls `try_reserve(slot, n)`, then stores `pos` values
    /// `slot_len(slot)..slot_len(slot)+n` for every layer, then calls
    /// `advance(slot, n)` once.
    pub fn store(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(
            pos < self.max_seq,
            "KV store past the context window: slot {slot} pos {pos}, max_seq {}",
            self.max_seq,
        );
        let reserved = self.tables[slot].len() * self.page_size;
        assert!(
            pos < reserved,
            "KV store outside reserved pages: slot {slot} pos {pos} but pages cover \
             only {reserved} tokens (len {}; call try_reserve first)",
            self.lens[slot],
        );
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let base = self.base(slot, layer, pos);
        self.k[base..base + self.kv_dim].copy_from_slice(k);
        self.v[base..base + self.kv_dim].copy_from_slice(v);
    }

    /// Advance `slot`'s position counter after all layers of a ubatch of
    /// `n` tokens have been stored. The positions must already be covered
    /// by a `try_reserve`.
    pub fn advance(&mut self, slot: usize, n: usize) -> Result<(), CacheError> {
        let len = self.lens[slot];
        if len + n > self.max_seq {
            return Err(CacheError::ContextOverflow {
                slot,
                len,
                need: n,
                max_seq: self.max_seq,
            });
        }
        let reserved = self.tables[slot].len() * self.page_size;
        if len + n > reserved {
            return Err(CacheError::Unreserved {
                slot,
                len,
                need: n,
                reserved,
            });
        }
        self.lens[slot] = len + n;
        Ok(())
    }

    /// K vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn k_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        debug_assert!(pos < self.max_seq);
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.k[base..base + head_dim]
    }

    /// V vector of head `kv_head` at position `pos` in `layer` of `slot`.
    #[inline]
    pub fn v_at(
        &self,
        slot: usize,
        layer: usize,
        pos: usize,
        kv_head: usize,
        head_dim: usize,
    ) -> &[f32] {
        let base = self.base(slot, layer, pos) + kv_head * head_dim;
        &self.v[base..base + head_dim]
    }

    /// Bytes one decode step must stream if the cache lives host-side and
    /// attention is offloaded (FP16 cache entries, both K and V). Paging
    /// makes the transfer page-granular: whole pages covering `ctx`
    /// positions move, so `2 formats × pages(ctx) × page_size × kv_dim ×
    /// 2 bytes` per layer.
    pub fn stream_bytes_per_layer(&self, ctx: usize) -> usize {
        2 * self.pages_needed(ctx) * self.page_size * self.kv_dim * 2
    }

    /// Total resident size of the cache (f16 accounting, all layers, both
    /// K and V) at the current allocation — the quantity that grows with
    /// live context in the paper's long-context discussion. Paging makes
    /// residency page-granular: slack inside a sequence's last page is
    /// resident even though not yet written.
    pub fn resident_bytes_f16(&self) -> usize {
        self.bytes_f16_for_pages(self.used_pages())
    }

    /// Lifetime peak of [`KvCache::resident_bytes_f16`] — tracked at
    /// allocation time, so it is exact even when pages are freed between
    /// observations (what the serve report surfaces per worker).
    pub fn peak_resident_bytes_f16(&self) -> usize {
        self.bytes_f16_for_pages(self.peak_used)
    }

    fn bytes_f16_for_pages(&self, pages: usize) -> usize {
        2 * pages * self.n_layers * self.page_size * self.kv_dim * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    /// Reserve + per-layer store + advance for one position.
    fn put(c: &mut KvCache, slot: usize, pos: usize, n_layers: usize, fill: f32) {
        c.try_reserve(slot, 1).unwrap();
        let kv_dim = c.kv_dim;
        for layer in 0..n_layers {
            c.store(slot, layer, pos, &vec![fill; kv_dim], &vec![-fill; kv_dim]);
        }
        c.advance(slot, 1).unwrap();
    }

    #[test]
    fn store_and_read_roundtrip() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let kv_dim = cfg.kv_dim();
        for pos in 0..3 {
            c.try_reserve(0, 1).unwrap();
            for layer in 0..cfg.n_layers {
                let k: Vec<f32> =
                    (0..kv_dim).map(|i| (pos * 100 + layer * 10 + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.store(0, layer, pos, &k, &v);
            }
            c.advance(0, 1).unwrap();
        }
        assert_eq!(c.len(), 3);
        let hd = cfg.head_dim;
        let k = c.k_at(0, 1, 2, 1, hd);
        assert_eq!(k[0], (2 * 100 + 10 + hd) as f32);
        let v = c.v_at(0, 1, 2, 1, hd);
        assert_eq!(v[0], -((2 * 100 + 10 + hd) as f32));
    }

    #[test]
    fn reset_empties_and_returns_pages() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::new(&cfg);
        let total = c.n_pages();
        put(&mut c, 0, 0, cfg.n_layers, 0.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_pages(), 1);
        c.reset();
        assert!(c.is_empty());
        assert_eq!(c.free_page_count(), total, "all pages back on the free list");
    }

    #[test]
    fn slots_are_independent() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 3);
        // Write distinct data at the same (layer, pos) of two slots.
        for (slot, fill) in [(0usize, 1.0f32), (2, 7.0)] {
            put(&mut c, slot, 0, cfg.n_layers, fill);
        }
        assert_eq!(c.slot_len(0), 1);
        assert_eq!(c.slot_len(1), 0);
        assert_eq!(c.slot_len(2), 1);
        assert_eq!(c.k_at(0, 0, 0, 0, cfg.head_dim)[0], 1.0);
        assert_eq!(c.k_at(2, 0, 0, 0, cfg.head_dim)[0], 7.0);
        assert_eq!(c.v_at(2, 1, 0, 1, cfg.head_dim)[0], -7.0);
        c.reset_slot(2);
        assert_eq!(c.slot_len(2), 0);
        assert_eq!(c.slot_len(0), 1, "resetting one slot leaves others");
        assert_eq!(c.used_pages(), 1, "slot 2's page returned");
    }

    #[test]
    fn ubatch_advance_by_n() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::with_slots(&cfg, 2);
        let kv_dim = c.kv_dim;
        c.try_reserve(1, 5).unwrap();
        for layer in 0..cfg.n_layers {
            for pos in 0..5 {
                c.store(1, layer, pos, &vec![pos as f32; kv_dim], &vec![0.0; kv_dim]);
            }
        }
        c.advance(1, 5).unwrap();
        assert_eq!(c.slot_len(1), 5);
        assert_eq!(c.k_at(1, 0, 3, 0, cfg.head_dim)[0], 3.0);
    }

    #[test]
    fn pages_allocate_lazily_across_boundaries() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        c.try_reserve(0, 3).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1, "3 tokens fit one 4-token page");
        c.advance(0, 3).unwrap();
        c.try_reserve(0, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1, "4th token still fits");
        c.advance(0, 1).unwrap();
        c.try_reserve(0, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 2, "5th token crosses the boundary");
        c.advance(0, 1).unwrap();
        assert_eq!(c.used_pages(), 2);
        assert_eq!(c.pages_needed(5), 2);
    }

    #[test]
    fn contiguous_geometry_is_one_page_per_slot() {
        // page_size = max_seq, n_pages = n_slots: the old fixed-stride
        // layout exactly.
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, cfg.max_seq_len, 2);
        c.try_reserve(0, cfg.max_seq_len).unwrap();
        c.try_reserve(1, 1).unwrap();
        assert_eq!(c.slot_pages(0).len(), 1);
        assert_eq!(c.slot_pages(1).len(), 1);
        assert_eq!(c.free_page_count(), 0);
    }

    #[test]
    fn byte_accounting_is_page_granular() {
        let cfg = ModelConfig::qwen3_1_7b();
        // Small pool: accounting depends on geometry, not pool size.
        let mut c = KvCache::paged(&cfg, 1, 16, 4);
        // 1.7B: kv_dim = 8*128 = 1024; ctx 48 = 3 pages of 16, so per
        // layer: 2 formats * 48 * 1024 * 2 bytes.
        assert_eq!(c.stream_bytes_per_layer(48), 2 * 48 * 1024 * 2);
        // ctx 40 rounds up to 48 positions' worth of pages.
        assert_eq!(c.stream_bytes_per_layer(40), 2 * 48 * 1024 * 2);
        assert_eq!(c.resident_bytes_f16(), 0);
        c.try_reserve(0, 17).unwrap();
        c.advance(0, 17).unwrap();
        // 17 tokens = 2 pages resident, both K and V, f16, all layers.
        assert_eq!(c.resident_bytes_f16(), 2 * 2 * cfg.n_layers * 16 * 1024 * 2);
    }

    #[test]
    fn peak_residency_watermark() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 2, 4, 8);
        assert_eq!(c.peak_resident_bytes_f16(), 0);
        c.try_reserve(0, 9).unwrap(); // 3 pages
        c.advance(0, 9).unwrap();
        c.try_reserve(1, 2).unwrap(); // 1 page → peak 4
        c.advance(1, 2).unwrap();
        let peak = c.peak_resident_bytes_f16();
        assert_eq!(peak, 2 * 4 * cfg.n_layers * 4 * cfg.kv_dim() * 2);
        c.reset_slot(0);
        assert!(c.resident_bytes_f16() < peak);
        assert_eq!(c.peak_resident_bytes_f16(), peak, "watermark survives frees");
    }

    #[test]
    fn context_overflow_is_typed() {
        let mut cfg = ModelConfig::tiny();
        cfg.max_seq_len = 2;
        let mut c = KvCache::new(&cfg);
        c.try_reserve(0, 2).unwrap();
        c.advance(0, 2).unwrap();
        let err = c.try_reserve(0, 1).unwrap_err();
        assert_eq!(
            err,
            CacheError::ContextOverflow { slot: 0, len: 2, need: 1, max_seq: 2 }
        );
        let msg = err.to_string();
        assert!(msg.contains("slot 0") && msg.contains("len 2"), "{msg}");
    }

    #[test]
    fn out_of_pages_is_typed_and_atomic() {
        let cfg = ModelConfig::tiny();
        // 3 pages of 4 tokens shared by 2 slots.
        let mut c = KvCache::paged(&cfg, 2, 4, 3);
        c.try_reserve(0, 8).unwrap();
        c.advance(0, 8).unwrap();
        let free_before = c.free_page_count();
        let err = c.try_reserve(1, 8).unwrap_err();
        assert_eq!(
            err,
            CacheError::OutOfPages { slot: 1, len: 0, need_pages: 2, free_pages: 1, n_pages: 3 }
        );
        assert_eq!(c.free_page_count(), free_before, "failed reserve takes nothing");
        assert!(c.slot_pages(1).is_empty());
        // Freeing slot 0 makes the same reservation succeed.
        c.reset_slot(0);
        c.try_reserve(1, 8).unwrap();
    }

    #[test]
    fn advance_without_reserve_is_typed() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 1, 4, 2);
        let err = c.advance(0, 3).unwrap_err();
        assert_eq!(err, CacheError::Unreserved { slot: 0, len: 0, need: 3, reserved: 0 });
    }

    #[test]
    fn pool_conservation_under_churn() {
        let cfg = ModelConfig::tiny();
        let mut c = KvCache::paged(&cfg, 3, 2, 9);
        c.try_reserve(0, 5).unwrap();
        c.advance(0, 5).unwrap();
        c.try_reserve(1, 2).unwrap();
        c.advance(1, 2).unwrap();
        c.try_reserve(2, 3).unwrap();
        c.advance(2, 3).unwrap();
        let owned: usize = (0..3).map(|s| c.slot_pages(s).len()).sum();
        assert_eq!(owned + c.free_page_count(), c.n_pages());
        c.reset_slot(1);
        let owned: usize = (0..3).map(|s| c.slot_pages(s).len()).sum();
        assert_eq!(owned + c.free_page_count(), c.n_pages());
    }
}
