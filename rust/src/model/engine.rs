//! The inference engine: llama.cpp-equivalent forward pass and generation
//! loop for the Qwen3 architecture.
//!
//! Every linear projection is dispatched through a [`MatvecExec`] hook so
//! the hybrid coordinator can (a) account each kernel for the IMAX timing
//! model, (b) reroute the computation to the PJRT runtime, or (c) run the
//! native Rust kernels — without the engine knowing which. This mirrors the
//! paper's structure where llama.cpp's graph executor calls into a backend
//! that may offload to IMAX.

use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
use crate::model::graph::{MatvecOp, OpKind, Phase};
use crate::model::kv_cache::KvCache;
use crate::model::ops;
use crate::model::sampler::Sampler;
use crate::model::weights::ModelWeights;
use crate::quant::GgmlType;
use crate::tensor::{matvec_into, ActQuant, QTensor};

/// Execution hook for dot-product kernels.
pub trait MatvecExec {
    /// Execute `out = W · act` for a linear projection. `op` carries the
    /// symbolic shape/format metadata used for timing and offload
    /// decisions.
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]);

    /// Observe an attention kernel (score or mix) computed by the engine;
    /// used by the coordinator for timing/energy accounting. Default: no-op.
    fn attn(&mut self, _op: &MatvecOp) {}

    /// Token-step boundary notification. Default: no-op.
    fn begin_step(&mut self, _phase: Phase, _pos: usize) {}
    fn end_step(&mut self, _phase: Phase, _pos: usize) {}
}

/// Pure-Rust execution (no instrumentation).
pub struct NativeExec;

impl MatvecExec for NativeExec {
    #[inline]
    fn linear(&mut self, _op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        matvec_into(w, act, out);
    }
}

/// Scratch buffers for one token step (allocated once, reused).
struct Scratch {
    xn: Vec<f32>,      // normed input
    q: Vec<f32>,       // q_dim
    k: Vec<f32>,       // kv_dim
    v: Vec<f32>,       // kv_dim
    attn_out: Vec<f32>, // q_dim (concatenated head outputs)
    proj: Vec<f32>,    // d_model (o_proj / ffn_down output)
    gate: Vec<f32>,    // d_ffn
    up: Vec<f32>,      // d_ffn
    act: Vec<f32>,     // d_ffn (swiglu result)
    scores: Vec<f32>,  // max_seq attention scores
    logits: Vec<f32>,  // vocab
}

/// The inference engine: weights + KV cache + scratch.
pub struct Engine {
    pub weights: ModelWeights,
    pub cache: KvCache,
    scratch: Scratch,
    /// Ops counted since construction (functional-path statistics).
    pub n_tokens_processed: usize,
}

/// Result of a generation call.
#[derive(Clone, Debug)]
pub struct GenerateResult {
    /// Sampled output tokens (length `n_out`).
    pub tokens: Vec<u32>,
    /// Positions processed in prefill.
    pub n_prefill: usize,
}

impl Engine {
    pub fn new(weights: ModelWeights) -> Engine {
        let cfg = &weights.cfg;
        let scratch = Scratch {
            xn: vec![0.0; cfg.d_model.max(cfg.q_dim())],
            q: vec![0.0; cfg.q_dim()],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn_out: vec![0.0; cfg.q_dim()],
            proj: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.d_ffn],
            up: vec![0.0; cfg.d_ffn],
            act: vec![0.0; cfg.d_ffn],
            scores: vec![0.0; cfg.max_seq_len],
            logits: vec![0.0; cfg.vocab_size],
        };
        let cache = KvCache::new(cfg);
        Engine {
            weights,
            cache,
            scratch,
            n_tokens_processed: 0,
        }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.weights.cfg
    }

    pub fn scheme(&self) -> QuantScheme {
        self.weights.scheme
    }

    /// Reset the KV cache for a fresh request.
    pub fn reset(&mut self) {
        self.cache.reset();
    }

    fn linear_op(&self, kind: LinearKind, layer: Option<usize>) -> MatvecOp {
        let (rows, cols) = kind.shape(self.cfg());
        MatvecOp {
            kind: OpKind::Linear(kind),
            layer,
            wty: kind.weight_type(self.scheme()),
            rows,
            cols,
        }
    }

    /// Process one token at position `pos` (= current cache length).
    /// Returns logits if `want_logits`.
    pub fn forward(
        &mut self,
        token: u32,
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn MatvecExec,
    ) -> Option<Vec<f32>> {
        let cfg = self.weights.cfg.clone();
        let pos = self.cache.len();
        assert!(pos < cfg.max_seq_len, "context overflow");
        exec.begin_step(phase, pos);

        let mut x = self.weights.embed_token(token);
        let head_dim = cfg.head_dim;
        let groups = cfg.gqa_groups();
        let scale = 1.0 / (head_dim as f32).sqrt();

        for layer in 0..cfg.n_layers {
            // ---- attention block ----
            let lw = &self.weights.layers[layer];
            let s = &mut self.scratch;
            ops::rmsnorm(&x, &lw.attn_norm, cfg.rms_eps, &mut s.xn[..cfg.d_model]);

            // q/k/v projections share one quantized activation.
            let qkv_ty = lw.wq.ty;
            let act = ActQuant::for_weight(qkv_ty, &s.xn[..cfg.d_model]);
            let op_q = self.linear_op(LinearKind::QProj, Some(layer));
            let op_k = self.linear_op(LinearKind::KProj, Some(layer));
            let op_v = self.linear_op(LinearKind::VProj, Some(layer));
            // (wk/wv may differ in type from wq under Q3_K_S: requantize
            // if needed.)
            let lw = &self.weights.layers[layer];
            let s = &mut self.scratch;
            exec.linear(&op_q, &lw.wq, &act, &mut s.q);
            if lw.wk.ty == qkv_ty {
                exec.linear(&op_k, &lw.wk, &act, &mut s.k);
            } else {
                let act_k = ActQuant::for_weight(lw.wk.ty, &s.xn[..cfg.d_model]);
                exec.linear(&op_k, &lw.wk, &act_k, &mut s.k);
            }
            if lw.wv.ty == qkv_ty {
                exec.linear(&op_v, &lw.wv, &act, &mut s.v);
            } else {
                let act_v = ActQuant::for_weight(lw.wv.ty, &s.xn[..cfg.d_model]);
                exec.linear(&op_v, &lw.wv, &act_v, &mut s.v);
            }

            // QK-Norm (Qwen3) + RoPE, per head.
            for h in 0..cfg.n_heads {
                let qh = &mut s.q[h * head_dim..(h + 1) * head_dim];
                if cfg.qk_norm {
                    ops::rmsnorm_inplace(qh, &lw.q_norm, cfg.rms_eps);
                }
                ops::rope_inplace(qh, pos, cfg.rope_theta);
            }
            for h in 0..cfg.n_kv_heads {
                let kh = &mut s.k[h * head_dim..(h + 1) * head_dim];
                if cfg.qk_norm {
                    ops::rmsnorm_inplace(kh, &lw.k_norm, cfg.rms_eps);
                }
                ops::rope_inplace(kh, pos, cfg.rope_theta);
            }

            self.cache.store(layer, &s.k, &s.v);
            let ctx = pos + 1;

            // Attention (host-computed; instrumented as the FP16 kernels
            // the paper offloads).
            exec.attn(&MatvecOp {
                kind: OpKind::AttnScore,
                layer: Some(layer),
                wty: GgmlType::F16,
                rows: cfg.n_heads * ctx,
                cols: head_dim,
            });
            for h in 0..cfg.n_heads {
                let kvh = h / groups;
                let qh = &s.q[h * head_dim..(h + 1) * head_dim];
                for p in 0..ctx {
                    let kvec = self.cache.k_at(layer, p, kvh, head_dim);
                    let mut dot = 0.0f32;
                    for i in 0..head_dim {
                        dot += qh[i] * kvec[i];
                    }
                    s.scores[p] = dot * scale;
                }
                ops::softmax_inplace(&mut s.scores[..ctx]);
                let out = &mut s.attn_out[h * head_dim..(h + 1) * head_dim];
                out.fill(0.0);
                for p in 0..ctx {
                    let w = s.scores[p];
                    let vvec = self.cache.v_at(layer, p, kvh, head_dim);
                    for i in 0..head_dim {
                        out[i] += w * vvec[i];
                    }
                }
            }
            exec.attn(&MatvecOp {
                kind: OpKind::AttnMix,
                layer: Some(layer),
                wty: GgmlType::F16,
                rows: cfg.n_heads * head_dim,
                cols: ctx,
            });

            // Output projection + residual.
            let op_o = self.linear_op(LinearKind::OProj, Some(layer));
            let lw = &self.weights.layers[layer];
            let s = &mut self.scratch;
            let act_o = ActQuant::for_weight(lw.wo.ty, &s.attn_out[..cfg.q_dim()]);
            exec.linear(&op_o, &lw.wo, &act_o, &mut s.proj);
            ops::add_inplace(&mut x, &s.proj);

            // ---- feed-forward block (SwiGLU) ----
            let lw = &self.weights.layers[layer];
            let s = &mut self.scratch;
            ops::rmsnorm(&x, &lw.ffn_norm, cfg.rms_eps, &mut s.xn[..cfg.d_model]);
            let act_f = ActQuant::for_weight(lw.w_gate.ty, &s.xn[..cfg.d_model]);
            let op_g = self.linear_op(LinearKind::FfnGate, Some(layer));
            let op_u = self.linear_op(LinearKind::FfnUp, Some(layer));
            let op_d = self.linear_op(LinearKind::FfnDown, Some(layer));
            let lw = &self.weights.layers[layer];
            let s = &mut self.scratch;
            exec.linear(&op_g, &lw.w_gate, &act_f, &mut s.gate);
            exec.linear(&op_u, &lw.w_up, &act_f, &mut s.up);
            ops::swiglu(&s.gate, &s.up, &mut s.act);
            let act_d = if lw.w_down.ty == lw.w_gate.ty {
                ActQuant::for_weight(lw.w_down.ty, &s.act)
            } else {
                ActQuant::for_weight(lw.w_down.ty, &s.act)
            };
            exec.linear(&op_d, &lw.w_down, &act_d, &mut s.proj);
            ops::add_inplace(&mut x, &s.proj);
        }

        self.cache.advance();
        self.n_tokens_processed += 1;

        let out = if want_logits {
            let s = &mut self.scratch;
            ops::rmsnorm_inplace(&mut x, &self.weights.final_norm, cfg.rms_eps);
            let op_h = MatvecOp {
                kind: OpKind::Linear(LinearKind::LmHead),
                layer: None,
                wty: self.weights.lm_head.ty,
                rows: cfg.vocab_size,
                cols: cfg.d_model,
            };
            let act_h = ActQuant::for_weight(self.weights.lm_head.ty, &x);
            exec.linear(&op_h, &self.weights.lm_head, &act_h, &mut s.logits);
            Some(s.logits.clone())
        } else {
            None
        };
        exec.end_step(phase, pos);
        out
    }

    /// Run a full `[prompt : n_out]` request: prefill every prompt token,
    /// then decode `n_out` tokens with `sampler`. The engine's KV cache is
    /// reset first.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n_out: usize,
        sampler: &mut Sampler,
        exec: &mut dyn MatvecExec,
    ) -> GenerateResult {
        assert!(!prompt.is_empty(), "empty prompt");
        self.reset();
        let mut logits = None;
        for (i, &tok) in prompt.iter().enumerate() {
            let last = i + 1 == prompt.len();
            logits = self.forward(tok, Phase::Prefill, last, exec);
        }
        let mut tokens = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            let l = logits.as_ref().expect("prefill produced logits");
            let next = sampler.sample(l);
            tokens.push(next);
            if tokens.len() == n_out {
                break;
            }
            logits = self.forward(next, Phase::Decode, true, exec);
        }
        GenerateResult {
            tokens,
            n_prefill: prompt.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_engine(scheme: QuantScheme) -> Engine {
        let cfg = ModelConfig::tiny();
        Engine::new(ModelWeights::random(&cfg, scheme, 42))
    }

    #[test]
    fn forward_produces_finite_logits() {
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let logits = e
            .forward(3, Phase::Prefill, true, &mut NativeExec)
            .unwrap();
        assert_eq!(logits.len(), e.cfg().vocab_size);
        assert!(logits.iter().all(|v| v.is_finite()));
        let spread = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - logits.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(spread > 0.0, "logits must not be constant");
    }

    #[test]
    fn deterministic_generation() {
        let mut a = tiny_engine(QuantScheme::Q8_0);
        let mut b = tiny_engine(QuantScheme::Q8_0);
        let prompt = [1u32, 5, 9, 2];
        let ra = a.generate(&prompt, 8, &mut Sampler::greedy(), &mut NativeExec);
        let rb = b.generate(&prompt, 8, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(ra.tokens, rb.tokens);
        assert_eq!(ra.tokens.len(), 8);
    }

    #[test]
    fn cache_length_tracks_tokens() {
        let mut e = tiny_engine(QuantScheme::Q3KS);
        let prompt = [1u32, 2, 3];
        e.generate(&prompt, 4, &mut Sampler::greedy(), &mut NativeExec);
        // 3 prefill + 3 decode forwards (4th sampled w/o forward).
        assert_eq!(e.cache.len(), 6);
        e.reset();
        assert_eq!(e.cache.len(), 0);
    }

    #[test]
    fn different_prompts_different_logits() {
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let la = e.forward(3, Phase::Prefill, true, &mut NativeExec).unwrap();
        e.reset();
        let lb = e.forward(7, Phase::Prefill, true, &mut NativeExec).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn schemes_agree_roughly_on_argmax_distribution() {
        // Q8_0 is a near-lossless quantization: its logits must correlate
        // strongly with the FP16 engine's on the same weights seed.
        let mut ef = tiny_engine(QuantScheme::F16);
        let mut eq = tiny_engine(QuantScheme::Q8_0);
        let lf = ef.forward(11, Phase::Prefill, true, &mut NativeExec).unwrap();
        let lq = eq.forward(11, Phase::Prefill, true, &mut NativeExec).unwrap();
        // Pearson correlation.
        let n = lf.len() as f64;
        let (mf, mq) = (
            lf.iter().map(|&v| v as f64).sum::<f64>() / n,
            lq.iter().map(|&v| v as f64).sum::<f64>() / n,
        );
        let mut num = 0.0;
        let mut df = 0.0;
        let mut dq = 0.0;
        for (&a, &b) in lf.iter().zip(&lq) {
            let (x, y) = (a as f64 - mf, b as f64 - mq);
            num += x * y;
            df += x * x;
            dq += y * y;
        }
        let corr = num / (df.sqrt() * dq.sqrt());
        assert!(corr > 0.98, "corr {corr}");
    }

    #[test]
    fn exec_hook_sees_all_linear_ops() {
        struct Counter {
            linears: usize,
            attns: usize,
            native: NativeExec,
        }
        impl MatvecExec for Counter {
            fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
                self.linears += 1;
                self.native.linear(op, w, act, out);
            }
            fn attn(&mut self, _op: &MatvecOp) {
                self.attns += 1;
            }
        }
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let mut c = Counter {
            linears: 0,
            attns: 0,
            native: NativeExec,
        };
        e.forward(1, Phase::Prefill, true, &mut c);
        let n_layers = e.cfg().n_layers;
        assert_eq!(c.linears, n_layers * 7 + 1);
        assert_eq!(c.attns, n_layers * 2);
    }
}
