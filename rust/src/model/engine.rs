//! The inference engine: llama.cpp-equivalent forward pass and generation
//! loop for the Qwen3 architecture.
//!
//! Every linear projection is dispatched through a [`MatvecExec`] hook so
//! the hybrid coordinator can (a) account each kernel for the IMAX timing
//! model, (b) reroute the computation to the PJRT runtime, or (c) run the
//! native Rust kernels — without the engine knowing which. This mirrors the
//! paper's structure where llama.cpp's graph executor calls into a backend
//! that may offload to IMAX.
//!
//! Dispatch follows a **plan/submit** model: the engine drives a
//! [`KernelExec`], recording kernel launches through the `MatvecExec`
//! methods and marking every host dependency boundary (the points where
//! host code consumes kernel results) with [`KernelExec::submit`], plus
//! one [`KernelExec::sync`] per forward step. Eager backends ignore the
//! marks (the default `submit` is a no-op — bit-identical to the old
//! always-eager API); queueing backends flush their
//! [`crate::runtime::queue::LaunchQueue`] at them, seeing each submission
//! batch of consecutive kernels at once — the hook for modeling
//! double-buffered LMM prefetch and other cross-kernel overlap.
//!
//! The engine is multi-sequence: a [`Session`] owns one slot of the
//! paged [`KvCache`], and [`Engine::forward_ubatch`] processes a
//! prefill chunk of several tokens in one call (llama.cpp's ubatch),
//! which is what lets backends amortize weight transfer and
//! configuration across the chunk — the root of the paper's
//! prefill-compute-bound vs decode-LOAD-bound duality (§V.B). The
//! legacy single-sequence [`Engine::forward`] / [`Engine::generate`] API
//! is a thin wrapper over slot 0.
//!
//! Cache growth is fallible: each forward reserves KV pages for its
//! chunk up front, and the `try_*` variants surface the typed
//! [`CacheError`] (context overflow / page-pool exhaustion) so the
//! continuous-batching scheduler can defer work instead of unwinding.
//! The infallible wrappers panic with the same typed message.
//!
//! With the prefix cache enabled ([`Engine::enable_prefix_cache`]),
//! prefill is **prefix-aware**: [`Engine::try_prefill_session_shared`]
//! aliases the cached page-aligned prefix of the prompt into the
//! session's block table and executes only the uncached tail — the
//! aliased K/V bytes are bit-identical to what a cold prefill would
//! compute, so generation matches token-for-token while skipping the
//! aliased span's kernels entirely. Host↔device page swap traffic
//! (eviction under pressure, swap-in on a hit) is charged to the
//! executor through [`MatvecExec::kv_transfer`].

use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
use crate::model::graph::{KvSwapDir, MatvecOp, OpKind, Phase};
use crate::model::kv_cache::{AdoptedPrefix, CacheError, KvCache, KvScheme};
use crate::model::ops;
use crate::model::sampler::Sampler;
use crate::model::weights::ModelWeights;
use crate::tensor::{matvec_into, ActQuant, QTensor};

/// Default prefill chunk size (llama.cpp's `n_ubatch` spirit; bounds the
/// per-chunk scratch memory while amortizing per-kernel overheads).
pub const DEFAULT_UBATCH: usize = 32;

/// Execution hook for dot-product kernels.
pub trait MatvecExec {
    /// Execute `out = W · act` for a linear projection. `op` carries the
    /// symbolic shape/format metadata used for timing and offload
    /// decisions.
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]);

    /// Execute the same projection for every token of a ubatch:
    /// `outs[i*rows..][..rows] = W · acts[i]`. Backends may override to
    /// amortize the weight transfer / configuration across the chunk
    /// (batched prefill); the default dispatches token-by-token, which
    /// keeps results bit-identical to the sequential path.
    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        for (act, out) in acts.iter().zip(outs.chunks_mut(op.rows)) {
            self.linear(op, w, act, out);
        }
    }

    /// Observe an attention kernel (score or mix) computed by the engine;
    /// used by the coordinator for timing/energy accounting. Default: no-op.
    fn attn(&mut self, _op: &MatvecOp) {}

    /// Step boundary notification: one per forward call (a ubatch counts
    /// as one step spanning `pos..pos+n`). Default: no-op.
    fn begin_step(&mut self, _phase: Phase, _pos: usize) {}
    fn end_step(&mut self, _phase: Phase, _pos: usize) {}

    /// Observe a host↔device KV page swap (prefix-cache eviction or
    /// restore) of `bytes` cache bytes in the pool's page encoding
    /// (f16 or q8_0 blocks). Instrumented backends charge
    /// this through the DMA transfer-mode cost model; the default ignores
    /// it (functional backends move no real bytes — the cache is
    /// host-resident).
    fn kv_transfer(&mut self, _phase: Phase, _dir: KvSwapDir, _bytes: usize) {}
}

/// Modeled LOAD/EXEC split of the last settled scheduler round, fed
/// back through [`KernelExec::last_round_balance`]. This is the signal
/// the adaptive token budget tracks: a LOAD-dominated round re-streams
/// every weight once regardless of how many tokens share it, so a high
/// load fraction means a bigger round amortizes the same transfer over
/// more useful work; an EXEC-dominated round gains nothing from growing
/// and only stretches time-between-tokens.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundBalance {
    /// Modeled host→accelerator streaming seconds the round added.
    pub load_s: f64,
    /// Modeled kernel-execution seconds the round added.
    pub exec_s: f64,
}

impl RoundBalance {
    /// LOAD share of the round's LOAD+EXEC time; `None` when the round
    /// recorded neither (e.g. every kernel ran host-side).
    pub fn load_fraction(&self) -> Option<f64> {
        let total = self.load_s + self.exec_s;
        if total > 0.0 {
            Some(self.load_s / total)
        } else {
            None
        }
    }
}

/// The plan/submit execution API the engine drives: [`MatvecExec`] kernel
/// recording plus explicit flush points.
///
/// The engine calls [`KernelExec::submit`] at every host dependency
/// boundary — after the q/k/v trio, after attention + o_proj, after
/// gate/up, after the down projection — and [`KernelExec::sync`] once at
/// the end of each forward step. Kernels recorded between two submits
/// have no host dependency separating them, so a backend may plan them
/// as one launch batch (prefetch the next kernel's operands while the
/// current one executes). The defaults are no-ops: an eager backend that
/// executes at record time is already correct, bit-identical to the
/// pre-queue API.
pub trait KernelExec: MatvecExec {
    /// Flush kernels recorded since the last submit to the backend's
    /// launch stream. Default: no-op (eager backends).
    fn submit(&mut self) {}

    /// Submit and wait for the launch stream to drain (results are
    /// host-visible after this returns). Default: `submit`.
    fn sync(&mut self) {
        self.submit();
    }

    /// Round boundary notification from an iteration scheduler: one
    /// token-budgeted round (live decode tokens + resumable prefill
    /// chunks) just settled. Instrumented backends snapshot per-round
    /// cost deltas here so the modeled transfer bottleneck stays visible
    /// round by round; the default is a no-op.
    fn round_boundary(&mut self) {}

    /// Modeled LOAD/EXEC balance of the round the most recent
    /// [`KernelExec::round_boundary`] settled, if this backend models
    /// costs. Functional backends return `None` (the default), which
    /// freezes any adaptive budget at its starting value.
    fn last_round_balance(&self) -> Option<RoundBalance> {
        None
    }
}

/// Pure-Rust execution (no instrumentation).
pub struct NativeExec;

impl MatvecExec for NativeExec {
    #[inline]
    fn linear(&mut self, _op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        matvec_into(w, act, out);
    }
}

impl KernelExec for NativeExec {}

/// One in-flight sequence: a claimed KV-cache slot plus the sampler state
/// that decodes it. Obtained from [`Engine::open_session`]; the position
/// is tracked by the cache slot itself (`Engine::session_pos`).
#[derive(Debug)]
pub struct Session {
    slot: usize,
    pub sampler: Sampler,
}

impl Session {
    /// The KV-cache slot this session owns.
    pub fn slot(&self) -> usize {
        self.slot
    }
}

/// Scratch buffers sized for `cap` ubatch tokens (allocated once, grown
/// on demand, reused across steps).
struct Scratch {
    cap: usize,
    xn: Vec<f32>,       // cap × d_model (normed input)
    q: Vec<f32>,        // cap × q_dim
    k: Vec<f32>,        // cap × kv_dim
    v: Vec<f32>,        // cap × kv_dim
    attn_out: Vec<f32>, // cap × q_dim (concatenated head outputs)
    proj: Vec<f32>,     // cap × d_model (o_proj / ffn_down output)
    gate: Vec<f32>,     // cap × d_ffn
    up: Vec<f32>,       // cap × d_ffn
    act: Vec<f32>,      // cap × d_ffn (swiglu result)
    scores: Vec<f32>,   // max_seq attention scores (one token at a time)
    logits: Vec<f32>,   // vocab (last ubatch token only)
}

impl Scratch {
    fn new(cfg: &ModelConfig) -> Scratch {
        let mut s = Scratch {
            cap: 0,
            xn: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            attn_out: Vec::new(),
            proj: Vec::new(),
            gate: Vec::new(),
            up: Vec::new(),
            act: Vec::new(),
            scores: vec![0.0; cfg.max_seq_len],
            logits: vec![0.0; cfg.vocab_size],
        };
        s.ensure(cfg, 1);
        s
    }

    fn ensure(&mut self, cfg: &ModelConfig, n: usize) {
        if n <= self.cap {
            return;
        }
        self.xn.resize(n * cfg.d_model, 0.0);
        self.q.resize(n * cfg.q_dim(), 0.0);
        self.k.resize(n * cfg.kv_dim(), 0.0);
        self.v.resize(n * cfg.kv_dim(), 0.0);
        self.attn_out.resize(n * cfg.q_dim(), 0.0);
        self.proj.resize(n * cfg.d_model, 0.0);
        self.gate.resize(n * cfg.d_ffn, 0.0);
        self.up.resize(n * cfg.d_ffn, 0.0);
        self.act.resize(n * cfg.d_ffn, 0.0);
        self.cap = n;
    }
}

/// Which logits a ubatch forward returns. `All` is the speculative-decode
/// verify path: one LM-head row per chunk position, each bit-identical to
/// what sequential decode would compute at that position (the ubatch
/// residual streams already are — pinned by the equivalence suites — and
/// the per-position final-norm + LM-head arithmetic is unchanged).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LogitsMode {
    None,
    Last,
    All,
}

fn linear_op_for(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    kind: LinearKind,
    layer: Option<usize>,
) -> MatvecOp {
    let (rows, cols) = kind.shape(cfg);
    MatvecOp {
        kind: OpKind::Linear(kind),
        layer,
        wty: kind.weight_type(scheme),
        rows,
        cols,
    }
}

/// The inference engine: weights + multi-slot KV cache + scratch.
pub struct Engine {
    pub weights: ModelWeights,
    pub cache: KvCache,
    scratch: Scratch,
    /// Slots not currently owned by a session (LIFO for cache warmth).
    free_slots: Vec<usize>,
    /// Tokens processed since construction (functional-path statistics).
    pub n_tokens_processed: usize,
}

/// Result of a generation call.
#[derive(Clone, Debug)]
pub struct GenerateResult {
    /// Sampled output tokens (length `n_out`).
    pub tokens: Vec<u32>,
    /// Positions processed in prefill.
    pub n_prefill: usize,
}

/// Result of a prefix-aware prefill ([`Engine::try_prefill_session_shared`]).
#[derive(Clone, Debug)]
pub struct SharedPrefill {
    /// Logits of the prompt's last token.
    pub logits: Vec<f32>,
    /// Prompt tokens served by aliased cached pages (no forward pass).
    pub cached_tokens: usize,
    /// Prompt tokens actually executed (`prompt.len() − cached_tokens`).
    pub executed_tokens: usize,
}

/// Resumable prefill state for one session: the prompt plus how far the
/// cache has advanced through it. A cursor lets a long prompt prefill
/// chunk-by-chunk *across* scheduler rounds ([`Engine::prefill_partial`])
/// instead of monopolizing the engine until it completes — the
/// token-budget scheduler interleaves cursor chunks with live decode
/// tokens. Chunk boundaries are an execution schedule, never a numerics
/// change: any sequence of cursor advances is bit-identical to a
/// one-shot prefill of the same prompt.
#[derive(Clone, Debug)]
pub struct PrefillCursor {
    prompt: Vec<u32>,
    /// Prompt tokens already in the cache (adopted prefix + executed
    /// chunks).
    pos: usize,
}

impl PrefillCursor {
    /// Cursor over a whole prompt (nothing cached yet).
    pub fn new(prompt: Vec<u32>) -> PrefillCursor {
        PrefillCursor::with_adopted(prompt, 0)
    }

    /// Cursor whose first `adopted_tokens` prompt tokens are already
    /// cached (a prefix-cache adoption — see [`Engine::adopt_prefix`]);
    /// execution starts at that offset.
    pub fn with_adopted(prompt: Vec<u32>, adopted_tokens: usize) -> PrefillCursor {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(
            adopted_tokens < prompt.len(),
            "at least one prompt token must execute"
        );
        PrefillCursor { prompt, pos: adopted_tokens }
    }

    /// Prompt tokens already in the cache.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Prompt tokens still to execute.
    pub fn remaining(&self) -> usize {
        self.prompt.len() - self.pos
    }

    /// Whether the whole prompt is cached.
    pub fn done(&self) -> bool {
        self.pos == self.prompt.len()
    }

    /// The full prompt the cursor walks.
    pub fn prompt(&self) -> &[u32] {
        &self.prompt
    }
}

impl Engine {
    /// Single-sequence engine (legacy API; slot 0 is the implicit
    /// sequence).
    pub fn new(weights: ModelWeights) -> Engine {
        Engine::with_slots(weights, 1)
    }

    /// Engine holding up to `n_slots` concurrent sequences (continuous
    /// batching), with a fully backed page pool (every slot can reach
    /// `max_seq`).
    pub fn with_slots(weights: ModelWeights, n_slots: usize) -> Engine {
        let cfg = &weights.cfg;
        let cache = KvCache::with_slots(cfg, n_slots);
        Engine::with_cache(weights, cache)
    }

    /// Engine with an explicit KV page geometry: `n_slots` sequences over
    /// a shared pool of `page_size`-token pages. `n_pages = None` fully
    /// backs the slots; `Some(n)` sets a deliberate page budget (serve
    /// admission then gates on free pages instead of slot count alone).
    pub fn with_paged_slots(
        weights: ModelWeights,
        n_slots: usize,
        page_size: usize,
        n_pages: Option<usize>,
    ) -> Engine {
        Engine::with_paged_slots_kv(weights, n_slots, page_size, n_pages, KvScheme::F16)
    }

    /// [`Engine::with_paged_slots`] with an explicit KV page encoding.
    /// `KvScheme::F16` is the bit-exact reference; `KvScheme::Q8_0`
    /// quantizes pages on commit and dequantizes on attention read,
    /// trading bounded logit drift (see `rust/tests/kv_quant_accuracy.rs`)
    /// for ~1.88× less KV residency and attention-stream traffic.
    pub fn with_paged_slots_kv(
        weights: ModelWeights,
        n_slots: usize,
        page_size: usize,
        n_pages: Option<usize>,
        kv_scheme: KvScheme,
    ) -> Engine {
        let cfg = &weights.cfg;
        let pages =
            n_pages.unwrap_or_else(|| KvCache::full_backing_pages(cfg, n_slots, page_size));
        let cache = KvCache::paged_with_scheme(cfg, n_slots, page_size, pages, kv_scheme);
        Engine::with_cache(weights, cache)
    }

    fn with_cache(weights: ModelWeights, cache: KvCache) -> Engine {
        let scratch = Scratch::new(&weights.cfg);
        let n_slots = cache.n_slots;
        Engine {
            weights,
            cache,
            scratch,
            free_slots: (0..n_slots).rev().collect(),
            n_tokens_processed: 0,
        }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.weights.cfg
    }

    pub fn scheme(&self) -> QuantScheme {
        self.weights.scheme
    }

    pub fn n_slots(&self) -> usize {
        self.cache.n_slots
    }

    /// Sessions that can still be opened.
    pub fn free_sessions(&self) -> usize {
        self.free_slots.len()
    }

    /// Free pages in the shared KV pool.
    pub fn free_pages(&self) -> usize {
        self.cache.free_page_count()
    }

    /// Total pages in the shared KV pool.
    pub fn total_pages(&self) -> usize {
        self.cache.n_pages()
    }

    /// Tokens per KV page.
    pub fn page_size(&self) -> usize {
        self.cache.page_size()
    }

    /// Pages required to hold `n_tokens` cached tokens.
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        self.cache.pages_needed(n_tokens)
    }

    /// Fingerprint of the model configuration + quantization scheme,
    /// seeding the prefix cache's chain keys so cached pages can never
    /// alias across incompatible engines.
    pub fn kv_fingerprint(&self) -> u64 {
        crate::model::kv_cache::model_fingerprint(&self.weights.cfg, self.weights.scheme)
    }

    /// Turn on prompt-prefix sharing: committed prompt pages are indexed
    /// by content and aliased into later sessions with the same
    /// page-aligned prefix ([`Engine::adopt_prefix`] /
    /// [`Engine::register_prefix`]).
    pub fn enable_prefix_cache(&mut self) {
        let fp = self.kv_fingerprint();
        self.cache.enable_prefix_cache(fp);
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.cache.prefix_cache_enabled()
    }

    /// Size the host swap arena backing prefix-cache eviction (pages).
    /// Requires [`Engine::enable_prefix_cache`] first.
    pub fn set_kv_swap_capacity(&mut self, pages: usize) {
        self.cache.set_swap_capacity(pages);
    }

    /// The cached page-aligned span of `prompt` without mutating the
    /// cache: `(cached_tokens, resident_pages, swapped_pages)`, capped so
    /// at least one prompt token always executes (the last token's
    /// logits must be computed fresh).
    pub fn peek_prefix(&self, prompt: &[u32]) -> (usize, usize, usize) {
        if prompt.len() <= 1 {
            return (0, 0, 0);
        }
        self.cache.peek_prefix(prompt, prompt.len() - 1)
    }

    /// Alias the cached prefix of `prompt` into `session`'s slot (must be
    /// fresh), swapping evicted pages back in from the host arena as
    /// needed; swap traffic is charged to `exec` through
    /// [`MatvecExec::kv_transfer`]. Prefill may then start at
    /// `AdoptedPrefix::tokens`. At least one prompt token is always left
    /// to execute.
    pub fn adopt_prefix(
        &mut self,
        session: &Session,
        prompt: &[u32],
        exec: &mut dyn KernelExec,
    ) -> AdoptedPrefix {
        if prompt.len() <= 1 || !self.cache.prefix_cache_enabled() {
            return AdoptedPrefix::default();
        }
        let adopted = self.cache.adopt_prefix(session.slot, prompt, prompt.len() - 1);
        self.charge_pending_swaps(Phase::Prefill, exec);
        adopted
    }

    /// Register `session`'s committed prompt pages in the prefix index
    /// (call after a successful prefill); later sessions with the same
    /// page-aligned prefix alias them instead of re-computing.
    pub fn register_prefix(&mut self, session: &Session, prompt: &[u32]) {
        self.cache.register_prefix(session.slot, prompt);
    }

    /// Drain swap bytes the cache accumulated (evictions during
    /// reservations, swap-ins during adoption) into the executor's DMA
    /// accounting.
    fn charge_pending_swaps(&mut self, phase: Phase, exec: &mut dyn KernelExec) {
        let (in_bytes, out_bytes) = self.cache.take_pending_swap_bytes();
        if in_bytes > 0 {
            exec.kv_transfer(phase, KvSwapDir::In, in_bytes);
        }
        if out_bytes > 0 {
            exec.kv_transfer(phase, KvSwapDir::Out, out_bytes);
        }
    }

    /// Claim a free KV-cache slot for a new sequence. `None` when every
    /// slot is owned by a live session.
    pub fn open_session(&mut self, sampler: Sampler) -> Option<Session> {
        let slot = self.free_slots.pop()?;
        self.cache.reset_slot(slot);
        Some(Session { slot, sampler })
    }

    /// Release a session's slot back to the free pool, returning how
    /// many KV pages dropped their last reference and went back to the
    /// page pool. Valid whatever state the session is in — mid-
    /// [`PrefillCursor`], with a speculative verify pending, or
    /// mid-decode — which is what makes request cancellation safe: the
    /// slot holds only page references, and exactly the non-shared ones
    /// free here. Pages registered in the prefix index keep their index
    /// reference and stay adoptable by later sessions.
    pub fn close_session(&mut self, session: Session) -> usize {
        let freed = self.cache.reset_slot(session.slot);
        self.free_slots.push(session.slot);
        freed
    }

    /// Context length of the session's sequence so far.
    pub fn session_pos(&self, session: &Session) -> usize {
        self.cache.slot_len(session.slot)
    }

    /// Reset the KV cache for a fresh request (legacy single-sequence
    /// API; clears every slot).
    pub fn reset(&mut self) {
        self.cache.reset();
    }

    /// Process one token for `session` at its current position.
    /// Panics on cache exhaustion; see [`Engine::try_forward_session`].
    pub fn forward_session(
        &mut self,
        session: &Session,
        token: u32,
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Option<Vec<f32>> {
        self.try_forward_session(session, token, phase, want_logits, exec)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible single-token step for `session`: `Err` carries the typed
    /// [`CacheError`] (slot, length, requirement) on cache exhaustion,
    /// leaving the sequence unchanged.
    pub fn try_forward_session(
        &mut self,
        session: &Session,
        token: u32,
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Result<Option<Vec<f32>>, CacheError> {
        self.try_ubatch_on_slot(session.slot, &[token], phase, want_logits, exec)
    }

    /// Process a chunk of `tokens` for `session` in one call (prefill
    /// ubatch). Returns the logits of the chunk's last token if
    /// `want_logits`. Panics on cache exhaustion; see
    /// [`Engine::try_forward_ubatch`].
    pub fn forward_ubatch(
        &mut self,
        session: &Session,
        tokens: &[u32],
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Option<Vec<f32>> {
        self.try_forward_ubatch(session, tokens, phase, want_logits, exec)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible ubatch step for `session` (typed error on cache
    /// exhaustion, before any token of the chunk is processed).
    pub fn try_forward_ubatch(
        &mut self,
        session: &Session,
        tokens: &[u32],
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Result<Option<Vec<f32>>, CacheError> {
        self.try_ubatch_on_slot(session.slot, tokens, phase, want_logits, exec)
    }

    /// Prefill a whole prompt for `session` in chunks of at most
    /// `ubatch` tokens; returns the last token's logits. Panics on cache
    /// exhaustion; see [`Engine::try_prefill_session`].
    pub fn prefill_session(
        &mut self,
        session: &Session,
        prompt: &[u32],
        ubatch: usize,
        exec: &mut dyn KernelExec,
    ) -> Vec<f32> {
        self.try_prefill_session(session, prompt, ubatch, exec)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible chunked prefill. On `Err`, chunks before the failing one
    /// remain cached (the caller decides whether to reset the session).
    pub fn try_prefill_session(
        &mut self,
        session: &Session,
        prompt: &[u32],
        ubatch: usize,
        exec: &mut dyn KernelExec,
    ) -> Result<Vec<f32>, CacheError> {
        self.try_prefill_on_slot(session.slot, prompt, ubatch, exec)
    }

    /// Prefix-aware prefill for a fresh session: alias the cached
    /// page-aligned prefix of `prompt` ([`Engine::adopt_prefix`]), run
    /// prefill only for the uncached tail, then register the committed
    /// prompt pages for future sharing. With the prefix cache disabled
    /// this is exactly [`Engine::try_prefill_session`]. The aliased pages
    /// hold bit-identical K/V to a cold prefill, so generation after a
    /// warm hit matches a cold run token-for-token while executing
    /// strictly fewer prefill tokens.
    pub fn try_prefill_session_shared(
        &mut self,
        session: &Session,
        prompt: &[u32],
        ubatch: usize,
        exec: &mut dyn KernelExec,
    ) -> Result<SharedPrefill, CacheError> {
        let adopted = self.adopt_prefix(session, prompt, exec);
        debug_assert!(adopted.tokens < prompt.len(), "at least one token executes");
        let logits =
            self.try_prefill_on_slot(session.slot, &prompt[adopted.tokens..], ubatch, exec)?;
        self.register_prefix(session, prompt);
        Ok(SharedPrefill {
            logits,
            cached_tokens: adopted.tokens,
            executed_tokens: prompt.len() - adopted.tokens,
        })
    }

    /// Advance `cursor` by at most `max_tokens` prompt tokens on
    /// `session`, as one ubatch call. Returns `Ok(Some(logits))` — the
    /// prompt's last-token logits — when the cursor completes, `Ok(None)`
    /// while prompt tokens remain. On `Err` nothing was executed and the
    /// cursor is unchanged (the chunk's pages are reserved up front).
    ///
    /// This is the resumable core of the token-budget scheduler: a long
    /// prompt advances one bounded chunk per round, interleaved with live
    /// decode tokens, and the result is bit-identical to a one-shot
    /// prefill of the same prompt (chunk boundaries are an execution
    /// schedule, not a numerics change — pinned by
    /// `rust/tests/chunked_prefill.rs`).
    pub fn prefill_partial(
        &mut self,
        session: &Session,
        cursor: &mut PrefillCursor,
        max_tokens: usize,
        exec: &mut dyn KernelExec,
    ) -> Result<Option<Vec<f32>>, CacheError> {
        assert!(max_tokens >= 1, "max_tokens must be at least 1");
        assert!(!cursor.done(), "cursor already complete");
        let end = (cursor.pos + max_tokens).min(cursor.prompt.len());
        let last = end == cursor.prompt.len();
        let logits = self.try_ubatch_on_slot(
            session.slot,
            &cursor.prompt[cursor.pos..end],
            Phase::Prefill,
            last,
            exec,
        )?;
        cursor.pos = end;
        Ok(if last {
            Some(logits.expect("final prefill chunk produced logits"))
        } else {
            None
        })
    }

    /// Speculative-decode verify step: process `tokens` — the sampled
    /// next token followed by drafted continuations — as **one** decode
    /// ubatch on `session`, returning the logits after *every* position.
    /// `out[i]` is bit-identical to what sequential decode would produce
    /// after forwarding `tokens[..=i]`, so a verifier can accept the
    /// longest prefix of the draft that matches its own sampling and
    /// roll the cache back past the first mismatch with
    /// [`Engine::truncate_session`]. The whole chunk streams each weight
    /// once (the ubatch amortization that moves decode toward the
    /// prefill regime). On `Err` nothing was executed and the sequence
    /// is unchanged.
    pub fn try_verify_session(
        &mut self,
        session: &Session,
        tokens: &[u32],
        exec: &mut dyn KernelExec,
    ) -> Result<Vec<Vec<f32>>, CacheError> {
        Ok(self
            .ubatch_core(session.slot, tokens, Phase::Decode, LogitsMode::All, exec)?
            .expect("verify always produces logits"))
    }

    /// Roll `session` back to `new_len` cached positions — the rejection
    /// path after a speculative verify. Pages wholly past the retained
    /// span return to the pool; shared/indexed pages only lose this
    /// slot's reference (see [`KvCache::truncate`]).
    pub fn truncate_session(&mut self, session: &Session, new_len: usize) {
        self.cache.truncate(session.slot, new_len);
    }

    /// Chunked-prefill core shared by the session API and the legacy
    /// `generate` path.
    fn try_prefill_on_slot(
        &mut self,
        slot: usize,
        prompt: &[u32],
        ubatch: usize,
        exec: &mut dyn KernelExec,
    ) -> Result<Vec<f32>, CacheError> {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(ubatch >= 1, "ubatch must be at least 1");
        let mut logits = None;
        let mut start = 0;
        while start < prompt.len() {
            let end = (start + ubatch).min(prompt.len());
            let last = end == prompt.len();
            logits =
                self.try_ubatch_on_slot(slot, &prompt[start..end], Phase::Prefill, last, exec)?;
            start = end;
        }
        Ok(logits.expect("prefill produced logits"))
    }

    /// Process one token at position `pos` (= current cache length) on
    /// the implicit slot 0 (legacy single-sequence API). Returns logits
    /// if `want_logits`.
    pub fn forward(
        &mut self,
        token: u32,
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Option<Vec<f32>> {
        self.try_ubatch_on_slot(0, &[token], phase, want_logits, exec)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// The forward pass: `tokens` as one ubatch appended to `slot`'s
    /// sequence. Token `i` of the chunk sits at position `len + i` and
    /// attends causally to everything before it, so the arithmetic is
    /// bit-identical to feeding the chunk one token at a time. KV pages
    /// for the whole chunk are reserved up front: on `Err` nothing was
    /// executed and the sequence is unchanged.
    fn try_ubatch_on_slot(
        &mut self,
        slot: usize,
        tokens: &[u32],
        phase: Phase,
        want_logits: bool,
        exec: &mut dyn KernelExec,
    ) -> Result<Option<Vec<f32>>, CacheError> {
        let mode = if want_logits { LogitsMode::Last } else { LogitsMode::None };
        Ok(self
            .ubatch_core(slot, tokens, phase, mode, exec)?
            .map(|mut rows| rows.pop().expect("last-token logits")))
    }

    /// The transformer stack for one ubatch, parameterized over which
    /// logits to produce (see [`LogitsMode`]).
    fn ubatch_core(
        &mut self,
        slot: usize,
        tokens: &[u32],
        phase: Phase,
        mode: LogitsMode,
        exec: &mut dyn KernelExec,
    ) -> Result<Option<Vec<Vec<f32>>>, CacheError> {
        let cfg = self.weights.cfg.clone();
        let scheme = self.weights.scheme;
        let n = tokens.len();
        assert!(n >= 1, "empty ubatch");
        let base = self.cache.slot_len(slot);
        self.cache.try_reserve(slot, n)?;
        self.scratch.ensure(&cfg, n);
        exec.begin_step(phase, base);
        // The reservation may have evicted cold cached pages to the host
        // arena: charge that swap traffic to this step's phase.
        self.charge_pending_swaps(phase, exec);

        let d = cfg.d_model;
        let qd = cfg.q_dim();
        let kvd = cfg.kv_dim();
        let df = cfg.d_ffn;
        let head_dim = cfg.head_dim;
        let groups = cfg.gqa_groups();
        let scale = 1.0 / (head_dim as f32).sqrt();
        // The attention kernels' weight side is the KV cache itself, so
        // their recorded format follows the pool's page encoding — the
        // cost model then charges the compressed stream under q8_0.
        let kv_elem = self.cache.kv_scheme().elem_type();

        // Residual streams, one per ubatch token.
        let mut xs: Vec<Vec<f32>> =
            tokens.iter().map(|&t| self.weights.embed_token(t)).collect();

        for layer in 0..cfg.n_layers {
            // ---- attention block ----
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                for (i, x) in xs.iter().enumerate() {
                    ops::rmsnorm(x, &lw.attn_norm, cfg.rms_eps, &mut s.xn[i * d..(i + 1) * d]);
                }
            }

            // q/k/v projections share one quantized activation per token.
            let qkv_ty = self.weights.layers[layer].wq.ty;
            let acts: Vec<ActQuant> = (0..n)
                .map(|i| ActQuant::for_weight(qkv_ty, &self.scratch.xn[i * d..(i + 1) * d]))
                .collect();
            let op_q = linear_op_for(&cfg, scheme, LinearKind::QProj, Some(layer));
            let op_k = linear_op_for(&cfg, scheme, LinearKind::KProj, Some(layer));
            let op_v = linear_op_for(&cfg, scheme, LinearKind::VProj, Some(layer));
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                exec.linear_ubatch(&op_q, &lw.wq, &acts, &mut s.q[..n * qd]);
            }
            // (wk/wv may differ in type from wq under Q3_K_S: requantize
            // if needed.)
            let wk_ty = self.weights.layers[layer].wk.ty;
            let acts_k: Vec<ActQuant> = if wk_ty == qkv_ty {
                Vec::new()
            } else {
                (0..n)
                    .map(|i| ActQuant::for_weight(wk_ty, &self.scratch.xn[i * d..(i + 1) * d]))
                    .collect()
            };
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                let a = if acts_k.is_empty() { &acts } else { &acts_k };
                exec.linear_ubatch(&op_k, &lw.wk, a, &mut s.k[..n * kvd]);
            }
            let wv_ty = self.weights.layers[layer].wv.ty;
            let acts_v: Vec<ActQuant> = if wv_ty == qkv_ty {
                Vec::new()
            } else {
                (0..n)
                    .map(|i| ActQuant::for_weight(wv_ty, &self.scratch.xn[i * d..(i + 1) * d]))
                    .collect()
            };
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                let a = if acts_v.is_empty() { &acts } else { &acts_v };
                exec.linear_ubatch(&op_v, &lw.wv, a, &mut s.v[..n * kvd]);
            }
            // Host consumes q/k/v next (QK-norm, RoPE, cache store): the
            // q/k/v trio is one submission batch.
            exec.submit();

            // QK-Norm (Qwen3) + RoPE per head, then store K/V per token.
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                for i in 0..n {
                    let pos = base + i;
                    for h in 0..cfg.n_heads {
                        let off = i * qd + h * head_dim;
                        let qh = &mut s.q[off..off + head_dim];
                        if cfg.qk_norm {
                            ops::rmsnorm_inplace(qh, &lw.q_norm, cfg.rms_eps);
                        }
                        ops::rope_inplace(qh, pos, cfg.rope_theta);
                    }
                    for h in 0..cfg.n_kv_heads {
                        let off = i * kvd + h * head_dim;
                        let kh = &mut s.k[off..off + head_dim];
                        if cfg.qk_norm {
                            ops::rmsnorm_inplace(kh, &lw.k_norm, cfg.rms_eps);
                        }
                        ops::rope_inplace(kh, pos, cfg.rope_theta);
                    }
                    self.cache.store(
                        slot,
                        layer,
                        pos,
                        &s.k[i * kvd..(i + 1) * kvd],
                        &s.v[i * kvd..(i + 1) * kvd],
                    );
                }
            }

            // Attention, one chunk token at a time (host-computed;
            // instrumented as the FP16 kernels the paper offloads).
            // Token i attends causally to `base + i + 1` positions.
            for i in 0..n {
                let ctx = base + i + 1;
                exec.attn(&MatvecOp {
                    kind: OpKind::AttnScore,
                    layer: Some(layer),
                    wty: kv_elem,
                    rows: cfg.n_heads * ctx,
                    cols: head_dim,
                });
                {
                    let s = &mut self.scratch;
                    for h in 0..cfg.n_heads {
                        let kvh = h / groups;
                        let qh = &s.q[i * qd + h * head_dim..i * qd + (h + 1) * head_dim];
                        for p in 0..ctx {
                            let kvec = self.cache.k_at(slot, layer, p, kvh, head_dim);
                            let mut dot = 0.0f32;
                            for j in 0..head_dim {
                                dot += qh[j] * kvec[j];
                            }
                            s.scores[p] = dot * scale;
                        }
                        ops::softmax_inplace(&mut s.scores[..ctx]);
                        let out =
                            &mut s.attn_out[i * qd + h * head_dim..i * qd + (h + 1) * head_dim];
                        out.fill(0.0);
                        for p in 0..ctx {
                            let w = s.scores[p];
                            let vvec = self.cache.v_at(slot, layer, p, kvh, head_dim);
                            for j in 0..head_dim {
                                out[j] += w * vvec[j];
                            }
                        }
                    }
                }
                exec.attn(&MatvecOp {
                    kind: OpKind::AttnMix,
                    layer: Some(layer),
                    wty: kv_elem,
                    rows: cfg.n_heads * head_dim,
                    cols: ctx,
                });
            }

            // Output projection + residual.
            let op_o = linear_op_for(&cfg, scheme, LinearKind::OProj, Some(layer));
            let wo_ty = self.weights.layers[layer].wo.ty;
            let acts_o: Vec<ActQuant> = (0..n)
                .map(|i| {
                    ActQuant::for_weight(wo_ty, &self.scratch.attn_out[i * qd..(i + 1) * qd])
                })
                .collect();
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                exec.linear_ubatch(&op_o, &lw.wo, &acts_o, &mut s.proj[..n * d]);
                // Residual add consumes the projection: flush the
                // attention + o_proj batch.
                exec.submit();
                for (i, x) in xs.iter_mut().enumerate() {
                    ops::add_inplace(x, &s.proj[i * d..(i + 1) * d]);
                }
            }

            // ---- feed-forward block (SwiGLU) ----
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                for (i, x) in xs.iter().enumerate() {
                    ops::rmsnorm(x, &lw.ffn_norm, cfg.rms_eps, &mut s.xn[i * d..(i + 1) * d]);
                }
            }
            let gate_ty = self.weights.layers[layer].w_gate.ty;
            let acts_f: Vec<ActQuant> = (0..n)
                .map(|i| ActQuant::for_weight(gate_ty, &self.scratch.xn[i * d..(i + 1) * d]))
                .collect();
            let op_g = linear_op_for(&cfg, scheme, LinearKind::FfnGate, Some(layer));
            let op_u = linear_op_for(&cfg, scheme, LinearKind::FfnUp, Some(layer));
            let op_d = linear_op_for(&cfg, scheme, LinearKind::FfnDown, Some(layer));
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                exec.linear_ubatch(&op_g, &lw.w_gate, &acts_f, &mut s.gate[..n * df]);
                exec.linear_ubatch(&op_u, &lw.w_up, &acts_f, &mut s.up[..n * df]);
                // SwiGLU consumes gate and up: the pair is one batch.
                exec.submit();
                for i in 0..n {
                    ops::swiglu(
                        &s.gate[i * df..(i + 1) * df],
                        &s.up[i * df..(i + 1) * df],
                        &mut s.act[i * df..(i + 1) * df],
                    );
                }
            }
            let down_ty = self.weights.layers[layer].w_down.ty;
            let acts_d: Vec<ActQuant> = (0..n)
                .map(|i| ActQuant::for_weight(down_ty, &self.scratch.act[i * df..(i + 1) * df]))
                .collect();
            {
                let lw = &self.weights.layers[layer];
                let s = &mut self.scratch;
                exec.linear_ubatch(&op_d, &lw.w_down, &acts_d, &mut s.proj[..n * d]);
                exec.submit();
                for (i, x) in xs.iter_mut().enumerate() {
                    ops::add_inplace(x, &s.proj[i * d..(i + 1) * d]);
                }
            }
        }

        self.cache
            .advance(slot, n)
            .expect("chunk pages reserved before execution");
        self.n_tokens_processed += n;

        let op_h = MatvecOp {
            kind: OpKind::Linear(LinearKind::LmHead),
            layer: None,
            wty: self.weights.lm_head.ty,
            rows: cfg.vocab_size,
            cols: cfg.d_model,
        };
        let out = match mode {
            LogitsMode::None => {
                exec.sync();
                None
            }
            LogitsMode::Last => {
                let mut x = xs.pop().expect("nonempty ubatch");
                ops::rmsnorm_inplace(&mut x, &self.weights.final_norm, cfg.rms_eps);
                let act_h = ActQuant::for_weight(self.weights.lm_head.ty, &x);
                let s = &mut self.scratch;
                exec.linear(&op_h, &self.weights.lm_head, &act_h, &mut s.logits);
                // The sampler reads the logits: drain the launch stream.
                exec.sync();
                Some(vec![s.logits.clone()])
            }
            LogitsMode::All => {
                // Speculative verify: one LM-head row per chunk position,
                // dispatched as a single ubatch so backends amortize the
                // LM-head weight stream across the draft like any other
                // projection. Per-position arithmetic (final norm, act
                // quantization, matvec) is exactly the `Last` path's, so
                // each row is bit-identical to sequential decode at that
                // position.
                for x in xs.iter_mut() {
                    ops::rmsnorm_inplace(x, &self.weights.final_norm, cfg.rms_eps);
                }
                let acts_h: Vec<ActQuant> = xs
                    .iter()
                    .map(|x| ActQuant::for_weight(self.weights.lm_head.ty, x))
                    .collect();
                let mut flat = vec![0.0f32; n * cfg.vocab_size];
                exec.linear_ubatch(&op_h, &self.weights.lm_head, &acts_h, &mut flat);
                exec.sync();
                Some(flat.chunks(cfg.vocab_size).map(<[f32]>::to_vec).collect())
            }
        };
        exec.end_step(phase, base + n - 1);
        Ok(out)
    }

    /// Run a full `[prompt : n_out]` request on the implicit slot 0:
    /// prefill the prompt as ubatch chunks, then decode exactly `n_out`
    /// tokens with `sampler`. The engine's KV cache is reset first.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n_out: usize,
        sampler: &mut Sampler,
        exec: &mut dyn KernelExec,
    ) -> GenerateResult {
        assert!(!prompt.is_empty(), "empty prompt");
        self.reset();
        let mut logits = self
            .try_prefill_on_slot(0, prompt, DEFAULT_UBATCH, exec)
            .unwrap_or_else(|e| panic!("{e}"));
        let mut tokens = Vec::with_capacity(n_out);
        for step in 0..n_out {
            let next = sampler.sample(&logits);
            tokens.push(next);
            if step + 1 < n_out {
                logits = self
                    .try_ubatch_on_slot(0, &[next], Phase::Decode, true, exec)
                    .unwrap_or_else(|e| panic!("{e}"))
                    .expect("decode produced logits");
            }
        }
        GenerateResult {
            tokens,
            n_prefill: prompt.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_engine(scheme: QuantScheme) -> Engine {
        let cfg = ModelConfig::tiny();
        Engine::new(ModelWeights::random(&cfg, scheme, 42))
    }

    #[test]
    fn forward_produces_finite_logits() {
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let logits = e
            .forward(3, Phase::Prefill, true, &mut NativeExec)
            .unwrap();
        assert_eq!(logits.len(), e.cfg().vocab_size);
        assert!(logits.iter().all(|v| v.is_finite()));
        let spread = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - logits.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(spread > 0.0, "logits must not be constant");
    }

    #[test]
    fn deterministic_generation() {
        let mut a = tiny_engine(QuantScheme::Q8_0);
        let mut b = tiny_engine(QuantScheme::Q8_0);
        let prompt = [1u32, 5, 9, 2];
        let ra = a.generate(&prompt, 8, &mut Sampler::greedy(), &mut NativeExec);
        let rb = b.generate(&prompt, 8, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(ra.tokens, rb.tokens);
        assert_eq!(ra.tokens.len(), 8);
    }

    #[test]
    fn cache_length_tracks_tokens() {
        let mut e = tiny_engine(QuantScheme::Q3KS);
        let prompt = [1u32, 2, 3];
        e.generate(&prompt, 4, &mut Sampler::greedy(), &mut NativeExec);
        // 3 prefill + 3 decode forwards (4th sampled w/o forward).
        assert_eq!(e.cache.len(), 6);
        e.reset();
        assert_eq!(e.cache.len(), 0);
    }

    #[test]
    fn different_prompts_different_logits() {
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let la = e.forward(3, Phase::Prefill, true, &mut NativeExec).unwrap();
        e.reset();
        let lb = e.forward(7, Phase::Prefill, true, &mut NativeExec).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn ubatch_prefill_bit_identical_to_sequential() {
        for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS, QuantScheme::F16] {
            let prompt = [1u32, 5, 9, 2, 11, 3, 7];
            // Sequential: one token per forward call.
            let mut seq = tiny_engine(scheme);
            let mut l_seq = None;
            for (i, &t) in prompt.iter().enumerate() {
                l_seq = seq.forward(t, Phase::Prefill, i + 1 == prompt.len(), &mut NativeExec);
            }
            // Ubatch: chunks of 3 through a session.
            let mut ub = tiny_engine(scheme);
            let sess = ub.open_session(Sampler::greedy()).unwrap();
            let l_ub = ub.prefill_session(&sess, &prompt, 3, &mut NativeExec);
            assert_eq!(
                l_seq.unwrap(),
                l_ub,
                "ubatch prefill must be bit-identical ({})",
                scheme.name()
            );
            assert_eq!(ub.session_pos(&sess), prompt.len());
        }
    }

    #[test]
    fn prefill_partial_resumes_bit_identically() {
        let w = ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 42);
        let prompt = [1u32, 5, 9, 2, 11, 3, 7];
        let mut one = Engine::new(w.clone());
        let s1 = one.open_session(Sampler::greedy()).unwrap();
        let want = one.prefill_session(&s1, &prompt, prompt.len(), &mut NativeExec);

        let mut chunked = Engine::new(w);
        let s2 = chunked.open_session(Sampler::greedy()).unwrap();
        let mut cursor = PrefillCursor::new(prompt.to_vec());
        assert_eq!(cursor.remaining(), prompt.len());
        let mut got = None;
        for max in [2usize, 1, 3, 16] {
            assert!(got.is_none(), "logits only arrive on the final chunk");
            got = chunked
                .prefill_partial(&s2, &mut cursor, max, &mut NativeExec)
                .unwrap();
            if cursor.done() {
                break;
            }
        }
        assert_eq!(want, got.expect("cursor completed"), "resumed prefill bit-identical");
        assert_eq!(chunked.session_pos(&s2), prompt.len());
        assert_eq!(cursor.remaining(), 0);
    }

    #[test]
    fn sessions_do_not_cross_contaminate() {
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 42);
        let pa = [1u32, 5, 9, 2];
        let pb = [7u32, 3, 3, 8];

        // Two sessions interleaved on one engine.
        let mut e = Engine::with_slots(weights.clone(), 2);
        let sa = e.open_session(Sampler::greedy()).unwrap();
        let sb = e.open_session(Sampler::greedy()).unwrap();
        let mut la = e.prefill_session(&sa, &pa, 2, &mut NativeExec);
        let mut lb = e.prefill_session(&sb, &pb, 2, &mut NativeExec);
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        for _ in 0..4 {
            let na = Sampler::greedy().sample(&la);
            ta.push(na);
            la = e
                .forward_session(&sa, na, Phase::Decode, true, &mut NativeExec)
                .unwrap();
            let nb = Sampler::greedy().sample(&lb);
            tb.push(nb);
            lb = e
                .forward_session(&sb, nb, Phase::Decode, true, &mut NativeExec)
                .unwrap();
        }

        // Reference: each prompt alone on a fresh engine.
        for (prompt, got) in [(pa, &ta), (pb, &tb)] {
            let mut fresh = Engine::new(weights.clone());
            let want = fresh.generate(&prompt, 4, &mut Sampler::greedy(), &mut NativeExec);
            assert_eq!(&want.tokens, got, "interleaved decode must match isolated");
        }
    }

    #[test]
    fn session_slots_recycle() {
        let cfg = ModelConfig::tiny();
        let mut e = Engine::with_slots(ModelWeights::random(&cfg, QuantScheme::Q8_0, 1), 2);
        assert_eq!(e.free_sessions(), 2);
        let s1 = e.open_session(Sampler::greedy()).unwrap();
        let _s2 = e.open_session(Sampler::greedy()).unwrap();
        assert!(e.open_session(Sampler::greedy()).is_none(), "slots exhausted");
        e.prefill_session(&s1, &[1, 2, 3], 32, &mut NativeExec);
        assert_eq!(e.session_pos(&s1), 3);
        let slot = s1.slot();
        e.close_session(s1);
        let s3 = e.open_session(Sampler::greedy()).unwrap();
        assert_eq!(s3.slot(), slot, "slot recycled");
        assert_eq!(e.session_pos(&s3), 0, "recycled slot starts empty");
    }

    #[test]
    fn typed_error_surfaces_through_try_paths() {
        let mut cfg = ModelConfig::tiny();
        cfg.max_seq_len = 4;
        let mut e = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 42));
        let sess = e.open_session(Sampler::greedy()).unwrap();
        e.try_prefill_session(&sess, &[1, 2, 3, 4], 32, &mut NativeExec)
            .unwrap();
        let err = e
            .try_forward_session(&sess, 5, Phase::Decode, true, &mut NativeExec)
            .unwrap_err();
        match err {
            CacheError::ContextOverflow { slot, len, need, max_seq } => {
                assert_eq!((slot, len, need, max_seq), (sess.slot(), 4, 1, 4));
            }
            other => panic!("unexpected error {other:?}"),
        }
        // The failed step left the sequence unchanged.
        assert_eq!(e.session_pos(&sess), 4);
    }

    #[test]
    fn paged_engine_generates_identically_to_default() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 42);
        let prompt = [1u32, 5, 9, 2];
        let mut a = Engine::new(w.clone());
        let ra = a.generate(&prompt, 6, &mut Sampler::greedy(), &mut NativeExec);
        let mut b = Engine::with_paged_slots(w, 1, 3, None);
        let rb = b.generate(&prompt, 6, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(ra.tokens, rb.tokens, "page size must not change results");
    }

    #[test]
    fn out_of_pages_defers_until_a_session_closes() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 7);
        // 2 slots but only 2 pages of 4 tokens: the second session
        // starves once the first holds both pages.
        let mut e = Engine::with_paged_slots(w, 2, 4, Some(2));
        let sa = e.open_session(Sampler::greedy()).unwrap();
        let sb = e.open_session(Sampler::greedy()).unwrap();
        e.try_prefill_session(&sa, &[1, 2, 3, 4, 5], 32, &mut NativeExec)
            .unwrap();
        let err = e
            .try_prefill_session(&sb, &[9, 8, 7, 6, 5], 32, &mut NativeExec)
            .unwrap_err();
        assert!(matches!(err, CacheError::OutOfPages { .. }), "{err:?}");
        // Closing the first session frees its pages; the second proceeds.
        e.close_session(sa);
        assert_eq!(e.free_pages(), 2);
        e.try_prefill_session(&sb, &[9, 8, 7, 6, 5], 32, &mut NativeExec)
            .unwrap();
        assert_eq!(e.session_pos(&sb), 5);
    }

    #[test]
    fn schemes_agree_roughly_on_argmax_distribution() {
        // Q8_0 is a near-lossless quantization: its logits must correlate
        // strongly with the FP16 engine's on the same weights seed.
        let mut ef = tiny_engine(QuantScheme::F16);
        let mut eq = tiny_engine(QuantScheme::Q8_0);
        let lf = ef.forward(11, Phase::Prefill, true, &mut NativeExec).unwrap();
        let lq = eq.forward(11, Phase::Prefill, true, &mut NativeExec).unwrap();
        // Pearson correlation.
        let n = lf.len() as f64;
        let (mf, mq) = (
            lf.iter().map(|&v| v as f64).sum::<f64>() / n,
            lq.iter().map(|&v| v as f64).sum::<f64>() / n,
        );
        let mut num = 0.0;
        let mut df = 0.0;
        let mut dq = 0.0;
        for (&a, &b) in lf.iter().zip(&lq) {
            let (x, y) = (a as f64 - mf, b as f64 - mq);
            num += x * y;
            df += x * x;
            dq += y * y;
        }
        let corr = num / (df.sqrt() * dq.sqrt());
        assert!(corr > 0.98, "corr {corr}");
    }

    #[test]
    fn verify_logits_bit_identical_to_sequential_decode() {
        for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS, QuantScheme::F16] {
            let w = ModelWeights::random(&ModelConfig::tiny(), scheme, 42);
            let prompt = [1u32, 5, 9, 2];
            let chunk = [4u32, 8, 15, 16, 23];

            // Sequential reference: forward the chunk one token at a time.
            let mut seq = Engine::new(w.clone());
            let s1 = seq.open_session(Sampler::greedy()).unwrap();
            seq.prefill_session(&s1, &prompt, 32, &mut NativeExec);
            let mut want = Vec::new();
            for &t in &chunk {
                want.push(
                    seq.forward_session(&s1, t, Phase::Decode, true, &mut NativeExec)
                        .unwrap(),
                );
            }

            // Verify path: the same chunk as one ubatch.
            let mut ver = Engine::new(w);
            let s2 = ver.open_session(Sampler::greedy()).unwrap();
            ver.prefill_session(&s2, &prompt, 32, &mut NativeExec);
            let got = ver.try_verify_session(&s2, &chunk, &mut NativeExec).unwrap();
            assert_eq!(got.len(), chunk.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g, w, "position {i} logits diverge ({})", scheme.name());
            }
            assert_eq!(ver.session_pos(&s2), prompt.len() + chunk.len());

            // Rollback past a rejection point, then re-decode: the
            // replacement token's logits match a clean sequential run.
            ver.truncate_session(&s2, prompt.len() + 2);
            let after = ver
                .forward_session(&s2, 99, Phase::Decode, true, &mut NativeExec)
                .unwrap();
            let mut clean = Engine::new(seq.weights.clone());
            let s3 = clean.open_session(Sampler::greedy()).unwrap();
            clean.prefill_session(&s3, &prompt, 32, &mut NativeExec);
            for &t in &chunk[..2] {
                clean.forward_session(&s3, t, Phase::Decode, true, &mut NativeExec);
            }
            let want_after = clean
                .forward_session(&s3, 99, Phase::Decode, true, &mut NativeExec)
                .unwrap();
            assert_eq!(after, want_after, "post-rollback decode diverges ({})", scheme.name());
        }
    }

    #[test]
    fn exec_hook_sees_all_linear_ops() {
        struct Counter {
            linears: usize,
            ubatches: usize,
            attns: usize,
            submits: usize,
            native: NativeExec,
        }
        impl KernelExec for Counter {
            fn submit(&mut self) {
                self.submits += 1;
            }
        }
        impl MatvecExec for Counter {
            fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
                self.linears += 1;
                self.native.linear(op, w, act, out);
            }
            fn linear_ubatch(
                &mut self,
                op: &MatvecOp,
                w: &QTensor,
                acts: &[ActQuant],
                outs: &mut [f32],
            ) {
                self.ubatches += 1;
                for (act, out) in acts.iter().zip(outs.chunks_mut(op.rows)) {
                    self.linear(op, w, act, out);
                }
            }
            fn attn(&mut self, _op: &MatvecOp) {
                self.attns += 1;
            }
        }
        let mut e = tiny_engine(QuantScheme::Q8_0);
        let mut c = Counter {
            linears: 0,
            ubatches: 0,
            attns: 0,
            submits: 0,
            native: NativeExec,
        };
        e.forward(1, Phase::Prefill, true, &mut c);
        let n_layers = e.cfg().n_layers;
        assert_eq!(c.linears, n_layers * 7 + 1);
        assert_eq!(c.ubatches, n_layers * 7, "7 batched dispatches per layer");
        assert_eq!(c.attns, n_layers * 2);
        // Per layer: qkv, attention+o_proj, gate/up, down — plus the
        // end-of-step sync (the default sync forwards to submit).
        assert_eq!(c.submits, n_layers * 4 + 1, "dependency-boundary submits");
    }
}
