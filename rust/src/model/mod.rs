//! The LLM inference engine — our from-scratch substitute for llama.cpp
//! (see DESIGN.md §2), architecture-faithful to Qwen3: GQA attention with
//! QK-Norm and RoPE, RMSNorm, SwiGLU FFN, untied quantized LM head.
//!
//! * [`config`] — hyperparameters: the paper's Qwen3 0.6B/1.7B/8B plus
//!   tiny runnable presets; quant schemes (Q8_0, Q3_K_S, F16).
//! * [`graph`] — symbolic enumeration of every dot-product kernel per
//!   token (shared by the functional engine and the IMAX timing model).
//! * [`weights`] / [`file`] — quantized tensors; build random-init or
//!   save/load the crate's binary model format.
//! * [`kv_cache`] — paged multi-sequence KV cache (shared page pool,
//!   per-slot block tables, typed exhaustion errors) with the byte
//!   accounting behind the paper's LOAD-bound decode finding.
//! * [`engine`] — the forward pass (per-token and prefill-ubatch) and
//!   generation loop over per-sequence [`engine::Session`]s, with the
//!   [`engine::MatvecExec`] hook the hybrid coordinator intercepts.
//! * [`ops`] — host-side operators (RMSNorm, RoPE, softmax, SwiGLU).
//! * [`sampler`] — greedy / top-k temperature sampling.

pub mod config;
pub mod drafter;
pub mod engine;
pub mod file;
pub mod graph;
pub mod kv_cache;
pub mod ops;
pub mod sampler;
pub mod weights;

pub use config::{LinearKind, ModelConfig, QuantScheme};
pub use drafter::{DrafterSpec, NgramDrafter, DEFAULT_NGRAM};
pub use engine::{
    Engine, GenerateResult, KernelExec, MatvecExec, NativeExec, PrefillCursor, RoundBalance,
    Session, SharedPrefill, DEFAULT_UBATCH,
};
pub use kv_cache::{AdoptedPrefix, CacheError, KvCache, KvReuseStats, KvScheme, DEFAULT_PAGE_SIZE};
pub use graph::{KvSwapDir, MatvecOp, OpKind, Phase};
pub use sampler::Sampler;
pub use weights::ModelWeights;
