//! Programmed-I/O configuration costs (paper §V.B's CONF / REGV / RANGE).
//!
//! Before a kernel runs, the host writes (a) the mapping commands that
//! configure the PE dataflow (CONF), (b) initial values for the internal
//! PE registers (REGV — proportional to the number of units the dataflow
//! occupies, which is why the 64-unit Q6_K kernel dominates REGV in the
//! paper's Q3_K_S prefill breakdowns), and (c) the LMM address windows
//! (RANGE). All via slow PIO writes over the PS–PL path.

use crate::imax::device::ImaxDevice;
use crate::imax::isa::KernelClass;

/// PIO word counts for one kernel configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PioWords {
    pub conf: usize,
    pub regv: usize,
    pub range: usize,
}

/// Words written when a kernel *class* is (re)mapped onto the lanes.
/// CONF is per dataflow stage and replica; REGV per occupied unit; RANGE
/// per LMM window (operand arrays + result).
pub fn words_for(class: KernelClass, n_operand_arrays: usize) -> PioWords {
    PioWords {
        // 4 parallel dataflow replicas × stages × 2 words per stage.
        conf: 4 * class.dataflow().len() * 2,
        // 2 words per occupied arithmetic unit (init + mode).
        regv: 2 * class.units(),
        // one (base, limit) pair per operand array + result window.
        range: 2 * (n_operand_arrays + 1),
    }
}

/// Seconds for a PIO word sequence.
pub fn seconds(dev: &ImaxDevice, words: usize) -> f64 {
    words as f64 * dev.pio_word
}

/// Configuration cost policy: reconfiguration (CONF + REGV) is paid when
/// the kernel class changes on the lanes; RANGE is paid per kernel
/// instance (every instance addresses new buffers).
#[derive(Clone, Copy, Debug, Default)]
pub struct ConfTracker {
    current: Option<KernelClass>,
}

impl ConfTracker {
    pub fn new() -> ConfTracker {
        ConfTracker::default()
    }

    /// Returns (conf_s, regv_s, range_s) for launching one instance of
    /// `class`, updating the resident-mapping state.
    pub fn launch(
        &mut self,
        dev: &ImaxDevice,
        class: KernelClass,
        n_operand_arrays: usize,
    ) -> (f64, f64, f64) {
        let w = words_for(class, n_operand_arrays);
        let range_s = seconds(dev, w.range);
        if self.current == Some(class) {
            // Mapping already resident: only fresh register state for the
            // new instance's accumulators (a fraction of full init).
            let regv_s = seconds(dev, w.regv / 4);
            (0.0, regv_s, range_s)
        } else {
            self.current = Some(class);
            (seconds(dev, w.conf), seconds(dev, w.regv), range_s)
        }
    }

    pub fn reset(&mut self) {
        self.current = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::device::ImaxDevice;

    #[test]
    fn regv_scales_with_units() {
        let q6 = words_for(KernelClass::Q6K, 6);
        let fp = words_for(KernelClass::Fp16, 2);
        assert_eq!(q6.regv, 128); // 2 × 64 units
        assert_eq!(fp.regv, 44); // 2 × 22 units
        assert!(q6.regv > fp.regv);
    }

    #[test]
    fn range_scales_with_operands() {
        let a = words_for(KernelClass::Q8_0, 4);
        let b = words_for(KernelClass::Q8_0, 2);
        assert_eq!(a.range, 10);
        assert_eq!(b.range, 6);
    }

    #[test]
    fn reconfiguration_only_on_class_switch() {
        let dev = ImaxDevice::fpga(2);
        let mut t = ConfTracker::new();
        let (c1, r1, _) = t.launch(&dev, KernelClass::Q3K, 6);
        assert!(c1 > 0.0 && r1 > 0.0);
        // Same class again: no CONF, reduced REGV.
        let (c2, r2, _) = t.launch(&dev, KernelClass::Q3K, 6);
        assert_eq!(c2, 0.0);
        assert!(r2 < r1);
        // Switch class: full cost again.
        let (c3, _, _) = t.launch(&dev, KernelClass::Q6K, 6);
        assert!(c3 > 0.0);
    }

    #[test]
    fn asic_pio_faster_than_fpga() {
        let f = ImaxDevice::fpga(2);
        let a = ImaxDevice::asic28(2);
        assert!(seconds(&a, 100) < seconds(&f, 100));
    }
}
