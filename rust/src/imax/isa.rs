//! The IMAX custom instruction set and per-kernel dataflow mappings
//! (paper §III.C, Figs 5–9).
//!
//! Each PE packs three ALUs (integer / logic / shift), two address
//! generators and an FPU-capable datapath; the compiler maps dot-product
//! dataflows onto chains of PEs using the custom instructions below. The
//! unit counts and per-burst geometry are taken directly from the paper's
//! text and drive the cycle model in [`crate::imax::sim`].

use crate::quant::GgmlType;

/// IMAX custom instructions referenced by the paper.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Instr {
    /// 2-way SIMD signed 8-bit multiply–accumulate → 24-bit partials
    /// (Q8_0 back-end, Fig 7).
    OpSml8,
    /// 2-way 24-bit integer addition along the pipeline (Fig 5).
    OpAd24,
    /// 16-bit multiply used after K-quant decode (Fig 8).
    OpSml16,
    /// Decode 4-bit QL + 2-bit QH + 8-bit scales → 16-bit intermediates in
    /// one cycle (Q6_K front-end, Fig 8).
    OpCvt86,
    /// Approximate 6-bit scales → 5-bit, pack 2+1-bit weights → 3-bit
    /// (Q3_K front-end, Fig 9).
    OpCvt53,
    /// 2-way SIMD f32 fused multiply–add (FP16 kernel, Fig 6).
    OpFmaSimd,
    /// In-PE LUT conversion FP16 → FP32 (Fig 6).
    OpLutCvt,
    /// LMM load / store issued by the address generators.
    OpLd,
    OpSt,
}

/// One of the paper's four kernel dataflows.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum KernelClass {
    Fp16,
    Q8_0,
    Q6K,
    Q3K,
}

impl KernelClass {
    pub const ALL: [KernelClass; 4] =
        [KernelClass::Fp16, KernelClass::Q8_0, KernelClass::Q6K, KernelClass::Q3K];

    /// Which kernel executes a weight format.
    pub fn for_type(ty: GgmlType) -> KernelClass {
        match ty {
            // F32 host tensors offload through the FP16 datapath too
            // (widened loads), and F16 natively.
            GgmlType::F32 | GgmlType::F16 => KernelClass::Fp16,
            GgmlType::Q8_0 => KernelClass::Q8_0,
            GgmlType::Q6K => KernelClass::Q6K,
            GgmlType::Q3K => KernelClass::Q3K,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelClass::Fp16 => "FP16",
            KernelClass::Q8_0 => "Q8_0",
            KernelClass::Q6K => "Q6_K",
            KernelClass::Q3K => "Q3_K",
        }
    }

    /// Arithmetic units occupied by the mapped dataflow (paper §III.C:
    /// FP16 22, Q8_0 46, Q3_K 51, Q6_K 64).
    pub fn units(self) -> usize {
        match self {
            KernelClass::Fp16 => 22,
            KernelClass::Q8_0 => 46,
            KernelClass::Q3K => 51,
            KernelClass::Q6K => 64,
        }
    }

    /// Elements processed per burst by one lane's mapped dataflow:
    /// FP16 "16-element multiplication in a single operational burst";
    /// Q8_0 "two such parallel executions complete ... a full 32-element
    /// vector segment"; Q3_K/Q6_K "processing 256 elements per burst by
    /// running four parallel dataflows for sixteen iterations".
    pub fn elems_per_burst(self) -> usize {
        match self {
            KernelClass::Fp16 => 16,
            KernelClass::Q8_0 => 32,
            KernelClass::Q6K | KernelClass::Q3K => 256,
        }
    }

    /// Pipeline iterations one burst occupies (steady-state, per lane).
    /// FP16/Q8_0 retire a burst per iteration; the K-quants run their
    /// 4-wide dataflow for 16 iterations per 256-element burst.
    pub fn cycles_per_burst(self) -> usize {
        match self {
            KernelClass::Fp16 => 1,
            KernelClass::Q8_0 => 1,
            KernelClass::Q6K | KernelClass::Q3K => 16,
        }
    }

    /// Steady-state throughput in elements (MACs) per cycle per lane.
    pub fn elems_per_cycle(self) -> f64 {
        self.elems_per_burst() as f64 / self.cycles_per_burst() as f64
    }

    /// Pipeline fill depth in cycles (dataflow stages through the linear
    /// PE array; ≈ PEs traversed: 12-stage pipelines for the quantized
    /// kernels per Fig 5, shorter for FP16).
    pub fn pipeline_depth(self) -> usize {
        match self {
            KernelClass::Fp16 => 8,
            KernelClass::Q8_0 => 12,
            KernelClass::Q6K => 14,
            KernelClass::Q3K => 14,
        }
    }

    /// The instruction sequence of one dataflow stage (documentation /
    /// Fig 5–9 reproduction; also used by the ISA microbench).
    pub fn dataflow(self) -> &'static [Instr] {
        match self {
            KernelClass::Fp16 => &[
                Instr::OpLd,
                Instr::OpLutCvt,
                Instr::OpFmaSimd,
                Instr::OpFmaSimd,
                Instr::OpSt,
            ],
            KernelClass::Q8_0 => &[
                Instr::OpLd,
                Instr::OpSml8,
                Instr::OpAd24,
                Instr::OpAd24,
                Instr::OpSt,
            ],
            KernelClass::Q6K => &[
                Instr::OpLd,
                Instr::OpCvt86,
                Instr::OpSml16,
                Instr::OpAd24,
                Instr::OpSt,
            ],
            KernelClass::Q3K => &[
                Instr::OpLd,
                Instr::OpCvt53,
                Instr::OpSml8,
                Instr::OpAd24,
                Instr::OpSt,
            ],
        }
    }

    /// ASIC power per active lane in watts (paper Table 1 note: FP16
    /// 2.16 W, Q8_0 4.41 W, Q3_K 4.88 W, Q6_K 6.1 W at 64 KB LMM).
    pub fn asic_power_w(self) -> f64 {
        match self {
            KernelClass::Fp16 => 2.16,
            KernelClass::Q8_0 => 4.41,
            KernelClass::Q3K => 4.88,
            KernelClass::Q6K => 6.1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_counts_match_paper() {
        assert_eq!(KernelClass::Fp16.units(), 22);
        assert_eq!(KernelClass::Q8_0.units(), 46);
        assert_eq!(KernelClass::Q3K.units(), 51);
        assert_eq!(KernelClass::Q6K.units(), 64);
    }

    #[test]
    fn burst_geometry_matches_paper() {
        assert_eq!(KernelClass::Fp16.elems_per_burst(), 16);
        assert_eq!(KernelClass::Q8_0.elems_per_burst(), 32);
        assert_eq!(KernelClass::Q3K.elems_per_burst(), 256);
        assert_eq!(KernelClass::Q3K.cycles_per_burst(), 16);
        assert!((KernelClass::Q3K.elems_per_cycle() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn format_to_kernel_mapping() {
        assert_eq!(KernelClass::for_type(GgmlType::F16), KernelClass::Fp16);
        assert_eq!(KernelClass::for_type(GgmlType::Q8_0), KernelClass::Q8_0);
        assert_eq!(KernelClass::for_type(GgmlType::Q6K), KernelClass::Q6K);
        assert_eq!(KernelClass::for_type(GgmlType::Q3K), KernelClass::Q3K);
    }

    #[test]
    fn dataflows_start_with_load_end_with_store() {
        for k in KernelClass::ALL {
            let df = k.dataflow();
            assert_eq!(df.first(), Some(&Instr::OpLd), "{}", k.name());
            assert_eq!(df.last(), Some(&Instr::OpSt), "{}", k.name());
        }
    }

    #[test]
    fn asic_power_ordering() {
        // More units → more power; Q6_K (64 units) is the hungriest.
        assert!(KernelClass::Q6K.asic_power_w() > KernelClass::Q3K.asic_power_w());
        assert!(KernelClass::Q3K.asic_power_w() > KernelClass::Q8_0.asic_power_w());
        assert!(KernelClass::Q8_0.asic_power_w() > KernelClass::Fp16.asic_power_w());
    }

    #[test]
    fn kquant_frontends_use_cvt() {
        assert!(KernelClass::Q6K.dataflow().contains(&Instr::OpCvt86));
        assert!(KernelClass::Q3K.dataflow().contains(&Instr::OpCvt53));
        assert!(!KernelClass::Q8_0.dataflow().contains(&Instr::OpCvt86));
    }
}
