//! DMA engine cost model with transfer coalescing (paper §III.D).
//!
//! A naive implementation issues one DMA transaction per input tensor
//! (e.g. the Q8_0 kernel's four arrays: weight codes, weight scales,
//! activation codes, activation scales), paying the setup latency each
//! time. The paper's optimization aggregates all operands into one
//! contiguous host-side block so a single burst loads the LMMs; its
//! preliminary evaluation measured LOAD ×1.2 and DRAIN ×4.8 vs naive,
//! which the `dma_coalescing` bench reproduces from this model.

use crate::imax::device::ImaxDevice;

/// Coalescing strategy.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TransferMode {
    /// One transaction per operand array.
    Naive,
    /// Operands staged contiguously; single burst per direction.
    Coalesced,
}

/// One host→LMM or LMM→host transfer request.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub bytes: usize,
    /// Distinct operand arrays in this logical transfer.
    pub n_arrays: usize,
}

/// Seconds for an input (LOAD) transfer.
///
/// Naive: `n_arrays` transactions, each paying setup + its share of the
/// bytes at sub-burst efficiency (short transfers do not reach the NoC's
/// streaming bandwidth; the paper's small scale/scalar arrays are the
/// worst case).
pub fn load_seconds(dev: &ImaxDevice, t: Transfer, mode: TransferMode) -> f64 {
    let stream = load_stream_seconds(dev, t, mode);
    match mode {
        TransferMode::Coalesced => dev.dma_setup + stream,
        // Setup per array; the fragmented-burst bandwidth derate lives in
        // the streaming term.
        TransferMode::Naive => t.n_arrays as f64 * dev.dma_setup + stream,
    }
}

/// The streaming (bandwidth-bound) portion of a LOAD transfer — the part
/// a double-buffered LMM prefetch can run concurrently with the previous
/// kernel's EXEC. Per-transaction setup stays exposed (transaction issue
/// is host-serialized), which is why the hideable fraction depends on the
/// [`TransferMode`]: naive mode both derates bandwidth and leaves more
/// setup outside the overlap window.
pub fn load_stream_seconds(dev: &ImaxDevice, t: Transfer, mode: TransferMode) -> f64 {
    match mode {
        TransferMode::Coalesced => t.bytes as f64 / dev.dma_bw,
        TransferMode::Naive => {
            let frag_derate = 1.0 + 0.04 * (t.n_arrays.saturating_sub(1)) as f64;
            t.bytes as f64 * frag_derate / dev.dma_bw
        }
    }
}

/// Seconds for a result (DRAIN) transfer. Results are small (one f32 per
/// output row), so transaction setup dominates — which is why the paper
/// measured the larger 4.8× coalescing win on DRAIN.
pub fn drain_seconds(dev: &ImaxDevice, t: Transfer, mode: TransferMode) -> f64 {
    // The write path runs at roughly half the streaming bandwidth of the
    // read path on the PS-PL NoC (non-posted writes + result gather).
    let wr_bw = dev.dma_bw / 2.0;
    match mode {
        TransferMode::Coalesced => 2.0 * dev.dma_setup + t.bytes as f64 / wr_bw,
        TransferMode::Naive => {
            // Naive drain scatters results as they retire: each dataflow
            // replica writes its f32 partials in short beats instead of
            // an aggregated burst, collapsing AXI write efficiency
            // (~4× fewer bytes per beat), plus per-replica transaction
            // setups. This is the asymmetry behind the paper's 4.8×
            // DRAIN coalescing gain vs only 1.2× on LOAD.
            let fragments = (4 * t.n_arrays).max(1);
            fragments as f64 * dev.dma_setup + t.bytes as f64 * 4.2 / wr_bw
        }
    }
}

/// Host-side staging cost (s): the memcpy that builds the contiguous DMA
/// block (charged to the HOST component, §III.D "aggregates them into a
/// single, contiguous block in the host-side DMA buffer").
pub fn stage_seconds(dev: &ImaxDevice, bytes: usize) -> f64 {
    bytes as f64 / dev.host.memcpy_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::device::ImaxDevice;

    fn dev() -> ImaxDevice {
        ImaxDevice::fpga(2)
    }

    #[test]
    fn coalesced_load_faster() {
        let t = Transfer {
            bytes: 64 * 1024,
            n_arrays: 4,
        };
        let d = dev();
        assert!(load_seconds(&d, t, TransferMode::Coalesced) < load_seconds(&d, t, TransferMode::Naive));
    }

    #[test]
    fn coalescing_gain_larger_on_drain() {
        // Paper: LOAD ×1.2, DRAIN ×4.8 — drains are tiny, setup-dominated.
        let d = dev();
        let load_t = Transfer {
            bytes: 256 * 1024,
            n_arrays: 4,
        };
        let drain_t = Transfer {
            bytes: 4 * 1024,
            n_arrays: 4,
        };
        let load_gain = load_seconds(&d, load_t, TransferMode::Naive)
            / load_seconds(&d, load_t, TransferMode::Coalesced);
        let drain_gain = drain_seconds(&d, drain_t, TransferMode::Naive)
            / drain_seconds(&d, drain_t, TransferMode::Coalesced);
        assert!(load_gain > 1.05 && load_gain < 2.0, "load gain {load_gain}");
        assert!(drain_gain > 3.0, "drain gain {drain_gain}");
        assert!(drain_gain > load_gain);
    }

    #[test]
    fn bandwidth_term_dominates_large_transfers() {
        let d = dev();
        let big = Transfer {
            bytes: 100 * 1024 * 1024,
            n_arrays: 4,
        };
        let t = load_seconds(&d, big, TransferMode::Coalesced);
        let bw_time = big.bytes as f64 / d.dma_bw;
        assert!((t - bw_time) / bw_time < 0.01);
    }

    #[test]
    fn stream_portion_is_load_minus_setup() {
        let d = dev();
        let t = Transfer {
            bytes: 128 * 1024,
            n_arrays: 4,
        };
        for mode in [TransferMode::Coalesced, TransferMode::Naive] {
            let stream = load_stream_seconds(&d, t, mode);
            let load = load_seconds(&d, t, mode);
            assert!(stream > 0.0 && stream < load, "{mode:?}: {stream} vs {load}");
            let setups = match mode {
                TransferMode::Coalesced => d.dma_setup,
                TransferMode::Naive => t.n_arrays as f64 * d.dma_setup,
            };
            assert!((load - stream - setups).abs() < 1e-15);
        }
    }

    #[test]
    fn staging_scales_with_bytes() {
        let d = dev();
        assert!(stage_seconds(&d, 2_000_000) > stage_seconds(&d, 1_000_000));
        // ~2.8 GB/s A72 large-copy bandwidth → 1 GB ≈ 0.36 s.
        let s = stage_seconds(&d, 1_000_000_000);
        assert!(s > 0.2 && s < 0.8, "{s}");
    }
}
