//! The IMAX3 CGLA simulator — our substitute for the paper's FPGA
//! prototype and 28 nm ASIC projection (DESIGN.md §2).
//!
//! IMAX3 is a Coarse-Grained *Linear* Array: per lane, 64 CISC PEs
//! interleaved with 64 KB double-buffered Local Memory Modules in a 1-D
//! pipeline; eight lanes behind a DMA engine and a PIO configuration path,
//! hosted by a dual-core Cortex-A72 (paper Figs 1–3). The simulator is a
//! *structural cost model*: it prices each offloaded dot-product kernel by
//! the machine's published dataflow geometry (units, elements/burst,
//! pipeline depth — [`isa`]), LMM tiling ([`lmm`]), DMA coalescing
//! ([`dma`]), PIO configuration ([`pio`]), and the host's staging work
//! ([`sim`]), with the FPGA/ASIC parameter sets in [`device`] calibrated
//! against the paper's own measurements (DESIGN.md §6).

pub mod device;
pub mod dma;
pub mod isa;
pub mod lmm;
pub mod pio;
pub mod sim;
pub mod timing;

pub use device::{ImaxDevice, ImaxImpl};
pub use dma::TransferMode;
pub use isa::{Instr, KernelClass};
pub use lmm::LmmConfig;
pub use timing::{Component, PhaseCost, RunBreakdown};
