//! IMAX device parameterizations: the measured FPGA prototype and the
//! projected 28 nm ASIC (paper §IV.A).
//!
//! All free parameters are calibrated once against the paper's published
//! anchor measurements (DESIGN.md §6) and then held fixed across every
//! experiment; `baseline::calibration` asserts the anchors stay within
//! tolerance.

/// FPGA vs projected ASIC implementation.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum ImaxImpl {
    /// AMD Versal VPK180 prototype @ 145 MHz.
    Fpga,
    /// TSMC 28 nm projection @ 840 MHz (Synopsys DC synthesis).
    Asic28,
}

/// Host CPU model (dual-core Arm Cortex-A72 on the Versal PS).
#[derive(Clone, Copy, Debug)]
pub struct HostParams {
    /// Cores available for engine control flow (A72 has 2).
    pub cores: usize,
    /// Large-copy bandwidth for DMA-buffer coalescing (bytes/s). The
    /// dominant host cost: every offloaded operand set is staged into the
    /// contiguous DMA buffer (§III.D).
    pub memcpy_bw: f64,
    /// Host-side elementwise op throughput (elements/s) for RMSNorm,
    /// RoPE, softmax, activation quantization, sampling scans.
    pub elemop_rate: f64,
    /// Fixed per-offload-call software overhead (s): graph dispatch,
    /// buffer bookkeeping, completion check.
    pub call_overhead: f64,
    /// Idle power (W) added to active-lane power in the ASIC energy model.
    pub idle_power_w: f64,
    /// Power while the host computes kernels itself (NEON pegged, W).
    pub active_power_w: f64,
    /// Power during light host work: dispatch, staging, sampling (W).
    pub light_power_w: f64,
    /// Power of the DMA/DDR path while transfers are in flight (W).
    pub xfer_power_w: f64,
}

/// Full IMAX system parameters.
#[derive(Clone, Debug)]
pub struct ImaxDevice {
    pub imp: ImaxImpl,
    /// Core clock (Hz): 145 MHz FPGA, 840 MHz ASIC.
    pub clock_hz: f64,
    /// Compute lanes used (paper's main evaluation: 2 of 8).
    pub lanes: usize,
    /// PEs per lane (64).
    pub pes_per_lane: usize,
    /// LMM size per PE in KiB (16–512 configurable; 64 deployed).
    pub lmm_kb: usize,
    /// Effective DMA bandwidth host↔LMM (bytes/s).
    pub dma_bw: f64,
    /// Per-DMA-transaction setup latency (s) — the cost coalescing
    /// amortizes (§III.D).
    pub dma_setup: f64,
    /// Per-PIO-word cost (s) for CONF/REGV/RANGE writes.
    pub pio_word: f64,
    /// Host-side DMA staging buffer capacity (Table 1: 4 GB DDR4 on the
    /// VPK180). Offloaded weights must be resident here; §V.C: "the
    /// prototype's limited DMA buffer size restricted our experiments" —
    /// the constraint behind Table 2's 8B Q8_0 non-offload.
    pub dma_buffer_bytes: usize,
    /// Pipeline utilization multiplier on the ISA steady-state rate
    /// (column-wise multithreading keeps multiple logical ops in flight —
    /// §III.C). Calibrated on the anchor EXEC time.
    pub exec_eff: f64,
    pub host: HostParams,
    /// FPGA board power (Table 1: 180 W) for FPGA-side energy numbers.
    pub board_power_w: f64,
}

impl ImaxDevice {
    /// The measured FPGA prototype (2-lane main configuration).
    ///
    /// dma_bw / memcpy_bw / pio_word are calibrated against the paper's
    /// 0.6B Q3_K_S [32:16] breakdown (16.3 s = EXEC 4.47 + HOST 5.43 +
    /// LOAD 5.31 + DRAIN 0.31 + other 0.78).
    pub fn fpga(lanes: usize) -> ImaxDevice {
        ImaxDevice {
            imp: ImaxImpl::Fpga,
            clock_hz: 145e6,
            lanes,
            pes_per_lane: 64,
            lmm_kb: 64,
            dma_bw: 1.15e9,
            dma_setup: 6.0e-6,
            pio_word: 1.8e-6,
            dma_buffer_bytes: 4_000_000_000, // Table 1: "4 GB DDR4 for DMA buffer"
            exec_eff: 1.36,
            host: HostParams {
                cores: 2,
                memcpy_bw: 2.8e9,
                elemop_rate: 2.0e8,
                call_overhead: 1.4e-3,
                idle_power_w: 1.0,
                active_power_w: 4.5,
                light_power_w: 1.8,
                xfer_power_w: 2.0,
            },
            board_power_w: 180.0,
        }
    }

    /// The 28 nm ASIC projection: core clock ×5.79 (840/145); PIO scales
    /// with the core; the memory path (DMA + host staging) improves by
    /// the integration factor calibrated on the paper's 5.63 s / 16.3 s
    /// representative-workload ratio (≈2.3×, an integrated SoC fabric
    /// rather than the FPGA NoC).
    pub fn asic28(lanes: usize) -> ImaxDevice {
        let f = ImaxDevice::fpga(lanes);
        let clock_ratio = 840e6 / 145e6;
        let mem_ratio = 2.34;
        ImaxDevice {
            imp: ImaxImpl::Asic28,
            clock_hz: 840e6,
            dma_bw: f.dma_bw * mem_ratio,
            dma_setup: f.dma_setup / clock_ratio,
            pio_word: f.pio_word / clock_ratio,
            host: HostParams {
                memcpy_bw: f.host.memcpy_bw * mem_ratio,
                elemop_rate: f.host.elemop_rate * mem_ratio,
                call_overhead: f.host.call_overhead / mem_ratio,
                ..f.host
            },
            board_power_w: f64::NAN, // not meaningful for the ASIC
            ..f
        }
    }

    pub fn name(&self) -> String {
        match self.imp {
            ImaxImpl::Fpga => format!("IMAX3 (FPGA, {} lanes)", self.lanes),
            ImaxImpl::Asic28 => format!("IMAX3 (28 nm, {} lanes)", self.lanes),
        }
    }

    /// Total LMM capacity (bytes) across the active lanes.
    pub fn lmm_total_bytes(&self) -> usize {
        self.lmm_kb * 1024 * self.pes_per_lane * self.lanes
    }

    /// LMM bytes per PE.
    pub fn lmm_pe_bytes(&self) -> usize {
        self.lmm_kb * 1024
    }

    /// With a given LMM size (Fig 14 sweep).
    pub fn with_lmm_kb(mut self, kb: usize) -> ImaxDevice {
        assert!((16..=512).contains(&kb), "LMM configurable 16..512 KB");
        self.lmm_kb = kb;
        self
    }

    /// With a different lane count (Fig 16 sweep).
    pub fn with_lanes(mut self, lanes: usize) -> ImaxDevice {
        assert!((1..=8).contains(&lanes), "IMAX3 has 8 lanes");
        self.lanes = lanes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_defaults_match_paper_table1() {
        let d = ImaxDevice::fpga(2);
        assert_eq!(d.clock_hz, 145e6);
        assert_eq!(d.pes_per_lane, 64);
        assert_eq!(d.lmm_kb, 64);
        assert_eq!(d.host.cores, 2);
        assert_eq!(d.board_power_w, 180.0);
    }

    #[test]
    fn asic_scales_clock_6x() {
        let a = ImaxDevice::asic28(2);
        assert_eq!(a.clock_hz, 840e6);
        let ratio = a.clock_hz / ImaxDevice::fpga(2).clock_hz;
        assert!((ratio - 5.79).abs() < 0.01, "paper: ≈6× speedup");
        // Memory path improves less than the core clock.
        assert!(a.dma_bw / ImaxDevice::fpga(2).dma_bw < ratio);
    }

    #[test]
    fn lmm_capacity_math() {
        let d = ImaxDevice::fpga(2);
        assert_eq!(d.lmm_total_bytes(), 64 * 1024 * 64 * 2);
        let big = d.clone().with_lmm_kb(512);
        assert_eq!(big.lmm_total_bytes(), 512 * 1024 * 64 * 2);
    }

    #[test]
    #[should_panic(expected = "8 lanes")]
    fn lane_bounds_enforced() {
        ImaxDevice::fpga(2).with_lanes(9);
    }

    #[test]
    #[should_panic(expected = "LMM configurable")]
    fn lmm_bounds_enforced() {
        ImaxDevice::fpga(2).with_lmm_kb(8);
    }
}
