//! Phase-component accounting (paper §V.B).
//!
//! The paper breaks IMAX execution into six components, measured
//! *additively* (its example breakdown sums exactly to the E2E total:
//! 16.3 s = 4.47 + 5.43 + 5.31 + 0.31 + 0.78, host included), so the
//! simulator accounts wall time the same way. The double-buffered LMM's
//! overlap benefit is modeled inside the DMA burst model (higher effective
//! bandwidth), not as EXEC/LOAD concurrency — matching how the paper
//! reports numbers ("data transfer remains the dominant bottleneck, even
//! with this hardware optimization").

use std::ops::{Add, AddAssign};

use crate::model::graph::Phase;

/// Execution-time components of one offloaded kernel (plus HOST, which the
/// paper reports at the system level).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    /// Kernel execution on the IMAX cores.
    Exec,
    /// DMA input transfer host → LMM.
    Load,
    /// DMA result transfer LMM → host.
    Drain,
    /// PIO mapping-command configuration.
    Conf,
    /// PIO PE register initialization.
    Regv,
    /// PIO LMM address-space configuration.
    Range,
    /// Host CPU processing (data preparation, norms, sampling, control).
    Host,
}

impl Component {
    pub const ALL: [Component; 7] = [
        Component::Exec,
        Component::Load,
        Component::Drain,
        Component::Conf,
        Component::Regv,
        Component::Range,
        Component::Host,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Component::Exec => "EXEC",
            Component::Load => "LOAD",
            Component::Drain => "DRAIN",
            Component::Conf => "CONF",
            Component::Regv => "REGV",
            Component::Range => "RANGE",
            Component::Host => "HOST",
        }
    }
}

/// Seconds per component; additive.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseCost {
    pub exec: f64,
    pub load: f64,
    pub drain: f64,
    pub conf: f64,
    pub regv: f64,
    pub range: f64,
    pub host: f64,
}

impl PhaseCost {
    pub const ZERO: PhaseCost = PhaseCost {
        exec: 0.0,
        load: 0.0,
        drain: 0.0,
        conf: 0.0,
        regv: 0.0,
        range: 0.0,
        host: 0.0,
    };

    pub fn get(&self, c: Component) -> f64 {
        match c {
            Component::Exec => self.exec,
            Component::Load => self.load,
            Component::Drain => self.drain,
            Component::Conf => self.conf,
            Component::Regv => self.regv,
            Component::Range => self.range,
            Component::Host => self.host,
        }
    }

    pub fn set(&mut self, c: Component, v: f64) {
        match c {
            Component::Exec => self.exec = v,
            Component::Load => self.load = v,
            Component::Drain => self.drain = v,
            Component::Conf => self.conf = v,
            Component::Regv => self.regv = v,
            Component::Range => self.range = v,
            Component::Host => self.host = v,
        }
    }

    /// Total wall time (additive accounting, see module docs).
    pub fn total(&self) -> f64 {
        self.exec + self.load + self.drain + self.conf + self.regv + self.range + self.host
    }

    /// Time attributable to the IMAX-side components only (no HOST).
    pub fn imax_total(&self) -> f64 {
        self.total() - self.host
    }

    pub fn scaled(&self, f: f64) -> PhaseCost {
        PhaseCost {
            exec: self.exec * f,
            load: self.load * f,
            drain: self.drain * f,
            conf: self.conf * f,
            regv: self.regv * f,
            range: self.range * f,
            host: self.host * f,
        }
    }

    /// Fraction of the total in each component (for Fig 15-style plots).
    pub fn shares(&self) -> Vec<(Component, f64)> {
        let t = self.total();
        Component::ALL
            .iter()
            .map(|&c| (c, if t > 0.0 { self.get(c) / t } else { 0.0 }))
            .collect()
    }
}

impl Add for PhaseCost {
    type Output = PhaseCost;
    fn add(self, o: PhaseCost) -> PhaseCost {
        PhaseCost {
            exec: self.exec + o.exec,
            load: self.load + o.load,
            drain: self.drain + o.drain,
            conf: self.conf + o.conf,
            regv: self.regv + o.regv,
            range: self.range + o.range,
            host: self.host + o.host,
        }
    }
}

impl AddAssign for PhaseCost {
    fn add_assign(&mut self, o: PhaseCost) {
        *self = *self + o;
    }
}

/// Prefill + decode accumulation for one workload run (Fig 15's two bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct RunBreakdown {
    pub prefill: PhaseCost,
    pub decode: PhaseCost,
}

impl RunBreakdown {
    pub fn add(&mut self, phase: Phase, cost: PhaseCost) {
        match phase {
            Phase::Prefill => self.prefill += cost,
            Phase::Decode => self.decode += cost,
        }
    }

    pub fn total(&self) -> PhaseCost {
        self.prefill + self.decode
    }

    pub fn e2e_seconds(&self) -> f64 {
        self.total().total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_are_additive() {
        let a = PhaseCost {
            exec: 1.0,
            load: 2.0,
            drain: 0.5,
            conf: 0.1,
            regv: 0.2,
            range: 0.05,
            host: 3.0,
        };
        assert!((a.total() - 6.85).abs() < 1e-12);
        assert!((a.imax_total() - 3.85).abs() < 1e-12);
        let b = a + a;
        assert!((b.total() - 13.7).abs() < 1e-12);
    }

    #[test]
    fn shares_sum_to_one() {
        let a = PhaseCost {
            exec: 1.0,
            load: 3.0,
            drain: 0.25,
            conf: 0.25,
            regv: 0.25,
            range: 0.25,
            host: 5.0,
        };
        let s: f64 = a.shares().iter().map(|(_, v)| v).sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_anchor_sums() {
        // The §V.B example breakdown must be representable exactly.
        let anchor = PhaseCost {
            exec: 4.47,
            host: 5.43,
            load: 5.31,
            drain: 0.31,
            conf: 0.78, // paper lumps CONF/REGV/RANGE into "other config"
            regv: 0.0,
            range: 0.0,
        };
        assert!((anchor.total() - 16.3).abs() < 1e-9);
    }

    #[test]
    fn run_breakdown_accumulates_by_phase() {
        let mut rb = RunBreakdown::default();
        let c = PhaseCost {
            exec: 1.0,
            ..PhaseCost::ZERO
        };
        rb.add(Phase::Prefill, c);
        rb.add(Phase::Decode, c);
        rb.add(Phase::Decode, c);
        assert_eq!(rb.prefill.exec, 1.0);
        assert_eq!(rb.decode.exec, 2.0);
        assert_eq!(rb.e2e_seconds(), 3.0);
    }
}
