//! Local Memory Module (LMM) model.
//!
//! Each PE pairs with a hardware-managed, double-buffered LMM
//! (configurable 16–512 KB; 64 KB deployed — paper §III.D/§V.A). The LMM
//! size governs (a) whether a kernel's per-burst operand tile fits
//! on-chip — the offload criterion — and (b) static power, which grows
//! linearly with capacity and drives the Fig 14 PDP trade-off.

use crate::imax::isa::KernelClass;
use crate::model::graph::MatvecOp;

/// LMM configuration for one PE.
#[derive(Clone, Copy, Debug)]
pub struct LmmConfig {
    pub size_kb: usize,
    pub double_buffered: bool,
}

impl LmmConfig {
    pub fn new(size_kb: usize) -> LmmConfig {
        assert!((16..=512).contains(&size_kb));
        LmmConfig {
            size_kb,
            double_buffered: true,
        }
    }

    pub fn bytes(&self) -> usize {
        self.size_kb * 1024
    }

    /// Capacity usable by one operand tile: double buffering splits the
    /// LMM so compute and DMA overlap (§II.D), halving the per-tile view.
    pub fn tile_bytes(&self) -> usize {
        if self.double_buffered {
            self.bytes() / 2
        } else {
            self.bytes()
        }
    }

    /// Static power contribution per lane (W), linear in capacity
    /// (paper §V.A: "a larger LMM also linearly increases static power").
    /// Calibrated so the 64 KB deployment reproduces the Table 1 ASIC
    /// kernel powers (which *include* 64 KB LMMs).
    pub fn static_power_per_lane_w(&self) -> f64 {
        // ~6.1 mW per PE per 64 KB step × 64 PEs ≈ 0.39 W/lane at 64 KB.
        const W_PER_KB_PER_PE: f64 = 6.1e-3 / 64.0;
        W_PER_KB_PER_PE * self.size_kb as f64 * 64.0
    }

    /// Extra power vs the deployed 64 KB baseline (Fig 14's sweep knob).
    pub fn power_delta_vs_64kb_w(&self) -> f64 {
        self.static_power_per_lane_w() - LmmConfig::new(64).static_power_per_lane_w()
    }
}

/// The operand tile one kernel instance must stage per burst-group:
/// quantized activation row (+ scales) shared across rows, plus the
/// weight rows in flight. This is the §III.D coalesced block.
pub fn operand_tile_bytes(op: &MatvecOp, rows_in_flight: usize) -> usize {
    op.act_bytes() + rows_in_flight * op.wty.row_bytes(op.cols) + 4 * rows_in_flight
}

/// Whether a kernel instance can stream through a given LMM: the shared
/// activation plus at least one weight row in flight must fit the per-PE
/// tile (the paper's "sufficient to accommodate the tensor sizes involved
/// in the dot-product operations" criterion). The four parallel dataflows
/// (Figs 5/9) split a row's burst, not distinct rows.
pub fn fits(op: &MatvecOp, lmm: &LmmConfig) -> bool {
    let _ = KernelClass::for_type(op.wty);
    operand_tile_bytes(op, 1) <= lmm.tile_bytes()
}

/// Maximum weight rows resident per PE tile alongside the activation
/// (drives DMA burst sizing in the mapper).
pub fn rows_per_tile(op: &MatvecOp, lmm: &LmmConfig) -> usize {
    let avail = lmm.tile_bytes().saturating_sub(op.act_bytes());
    (avail / (op.wty.row_bytes(op.cols) + 4)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
    use crate::model::graph::{OpKind, MatvecOp};
    use crate::quant::GgmlType;

    fn op_for(kind: LinearKind, cfg: &ModelConfig, scheme: QuantScheme) -> MatvecOp {
        let (rows, cols) = kind.shape(cfg);
        MatvecOp {
            kind: OpKind::Linear(kind),
            layer: Some(0),
            wty: kind.weight_type(scheme),
            rows,
            cols,
        }
    }

    #[test]
    fn static_power_linear_in_size() {
        let p64 = LmmConfig::new(64).static_power_per_lane_w();
        let p128 = LmmConfig::new(128).static_power_per_lane_w();
        let p256 = LmmConfig::new(256).static_power_per_lane_w();
        assert!((p128 - 2.0 * p64).abs() < 1e-9);
        assert!((p256 - 4.0 * p64).abs() < 1e-9);
        assert_eq!(LmmConfig::new(64).power_delta_vs_64kb_w(), 0.0);
    }

    #[test]
    fn qwen_dot_tiles_fit_64kb() {
        // Paper §III.D: 64 KB "is sufficient to accommodate the tensor
        // sizes involved in the dot-product operations of the Qwen3
        // models" — per-burst tiles, not whole matrices.
        let lmm = LmmConfig::new(64);
        for cfg in [
            ModelConfig::qwen3_0_6b(),
            ModelConfig::qwen3_1_7b(),
            ModelConfig::qwen3_8b(),
        ] {
            for kind in LinearKind::ALL {
                for scheme in [QuantScheme::Q8_0, QuantScheme::Q3KS] {
                    let op = op_for(kind, &cfg, scheme);
                    assert!(fits(&op, &lmm), "{} {} {}", cfg.name, kind.name(), scheme.name());
                }
            }
        }
    }

    #[test]
    fn tiny_lmm_rejects_wide_rows() {
        // A 16 KB LMM (tile = 8 KB) cannot hold even one row of a Q8_0
        // K=12288 projection (row ≈ 13 KB) plus its activation.
        let op = MatvecOp {
            kind: OpKind::Linear(LinearKind::FfnDown),
            layer: Some(0),
            wty: GgmlType::Q8_0,
            rows: 4096,
            cols: 12288,
        };
        assert!(!fits(&op, &LmmConfig::new(16)));
        assert!(fits(&op, &LmmConfig::new(512)));
    }

    #[test]
    fn rows_per_tile_monotone_in_lmm() {
        let op = MatvecOp {
            kind: OpKind::Linear(LinearKind::FfnGate),
            layer: Some(0),
            wty: GgmlType::Q3K,
            rows: 3072,
            cols: 1024,
        };
        let small = rows_per_tile(&op, &LmmConfig::new(32));
        let large = rows_per_tile(&op, &LmmConfig::new(256));
        assert!(large > small);
        assert!(small >= 1);
    }

    #[test]
    fn double_buffer_halves_tile() {
        let mut l = LmmConfig::new(64);
        assert_eq!(l.tile_bytes(), 32 * 1024);
        l.double_buffered = false;
        assert_eq!(l.tile_bytes(), 64 * 1024);
    }
}
