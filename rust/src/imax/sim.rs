//! The kernel-level cycle/cost model: maps one dot-product kernel instance
//! onto the lanes and prices each execution component.
//!
//! The model follows the machine's structure (paper §II.D, §III.C):
//!
//! * EXEC — steady-state elements/cycle per lane from the dataflow
//!   geometry (Fig 5–9), times active lanes, plus pipeline fill per tile.
//! * LOAD/DRAIN — DMA bursts sized by the LMM tile capacity, with
//!   coalescing per §III.D.
//! * CONF/REGV/RANGE — PIO words per (re)configuration, [`crate::imax::pio`].
//! * HOST — staging memcpy into the DMA buffer, activation quantization,
//!   and per-call dispatch overhead; multiplied by the host-contention
//!   factor when more lanes than host cores are active (§V.C).

use crate::imax::device::ImaxDevice;
use crate::imax::dma::{self, Transfer, TransferMode};
use crate::imax::isa::KernelClass;
use crate::imax::lmm::{self, LmmConfig};
use crate::imax::pio::ConfTracker;
use crate::imax::timing::PhaseCost;
use crate::model::graph::MatvecOp;

/// Host MAC throughput (dual Cortex-A72, NEON kernels) for *non-offloaded*
/// kernels, per weight format: llama.cpp-class performance on the Versal
/// PS. K-quants pay their bit-unpacking front-end in software; FP16 pays
/// the widening loads.
pub fn host_mac_rate_fpga(class: KernelClass) -> f64 {
    match class {
        KernelClass::Q8_0 => 3.0e9,
        KernelClass::Fp16 => 1.8e9,
        KernelClass::Q6K => 1.2e9,
        KernelClass::Q3K => 1.0e9,
    }
}

/// Host-contention multiplier: managing more lanes than host cores
/// serializes control flow and data staging (paper Fig 16's saturation
/// and degradation beyond 2 lanes on the dual-core A72).
pub fn host_contention(dev: &ImaxDevice) -> f64 {
    let lanes = dev.lanes as f64;
    let cores = dev.host.cores as f64;
    if lanes <= cores {
        1.0
    } else {
        1.0 + 0.45 * (lanes - cores)
    }
}

/// One offloaded kernel's modeled cost plus the overlap metadata a
/// plan/submit scheduler needs.
#[derive(Clone, Copy, Debug)]
pub struct KernelCost {
    pub cost: PhaseCost,
    /// Streaming (setup-free) portion of `cost.load` — the amount a
    /// double-buffered LMM prefetch can hide under the *previous* queued
    /// kernel's EXEC, capped by the [`TransferMode`]'s effective DMA
    /// bandwidth. Always ≤ `cost.load`.
    pub load_stream: f64,
}

/// Cost of one offloaded kernel instance processing `batch` activation
/// vectors against the same weights (batch > 1 in prefill, where
/// llama.cpp streams the prompt as one ubatch and the weight transfer is
/// amortized — the root of the paper's prefill-compute-bound vs
/// decode-LOAD-bound duality).
pub fn offloaded_cost(
    dev: &ImaxDevice,
    lmm: &LmmConfig,
    tracker: &mut ConfTracker,
    op: &MatvecOp,
    batch: usize,
    mode: TransferMode,
) -> PhaseCost {
    offloaded_cost_parts(dev, lmm, tracker, op, batch, mode).cost
}

/// [`offloaded_cost`] plus the prefetch-overlappable LOAD portion (see
/// [`KernelCost`]); the instrumented plan/submit backend consumes this.
pub fn offloaded_cost_parts(
    dev: &ImaxDevice,
    lmm: &LmmConfig,
    tracker: &mut ConfTracker,
    op: &MatvecOp,
    batch: usize,
    mode: TransferMode,
) -> KernelCost {
    debug_assert!(batch >= 1);
    let class = KernelClass::for_type(op.wty);
    let contention = host_contention(dev);

    // ---- tiling ----
    let rows_per_tile = lmm::rows_per_tile(op, lmm) * dev.pes_per_lane * dev.lanes;
    let n_tiles = crate::util::ceil_div(op.rows, rows_per_tile.max(1)).max(1);

    // ---- EXEC ----
    let macs = op.macs() as f64 * batch as f64;
    let rate = class.elems_per_cycle() * dev.lanes as f64 * dev.clock_hz * dev.exec_eff;
    let fill = (n_tiles * class.pipeline_depth()) as f64 / dev.clock_hz;
    let exec = macs / rate + fill;

    // ---- LOAD / DRAIN ----
    let weight_bytes = op.weight_bytes();
    let act_bytes = op.act_bytes() * batch;
    let in_bytes = weight_bytes + act_bytes;
    let load_t = Transfer {
        bytes: in_bytes,
        n_arrays: op.dma_operand_arrays(),
    };
    // One logical transfer per tile (the coalesced §III.D block); setup
    // amortization is what coalescing buys.
    let mut load = dma::load_seconds(dev, load_t, mode);
    if n_tiles > 1 {
        let extra = (n_tiles - 1) as f64
            * match mode {
                TransferMode::Coalesced => dev.dma_setup,
                TransferMode::Naive => dev.dma_setup * op.dma_operand_arrays() as f64,
            };
        load += extra;
    }
    let drain_t = Transfer {
        bytes: op.out_bytes() * batch,
        n_arrays: 1,
    };
    let drain = dma::drain_seconds(dev, drain_t, mode);

    // ---- PIO ----
    let (conf, regv, range) = tracker.launch(dev, class, op.dma_operand_arrays());
    let range = range * n_tiles as f64;

    // ---- HOST ----
    // Weights are resident in the 4 GB DMA staging buffer (placed once at
    // model load — the offload policy guarantees residency), so per-call
    // host work is: staging the *activation* block contiguously with the
    // resident weight region (§III.D coalescing), quantizing the
    // activation row, and the per-call dispatch overhead (ggml graph
    // scheduling on the slow A72 — the dominant term, calibrated on the
    // paper's 5.43 s HOST anchor).
    let stage = dma::stage_seconds(dev, act_bytes + op.out_bytes() * batch);
    let act_quant = (op.cols * batch) as f64 / dev.host.elemop_rate;
    // Attention kernels dispatch as sub-ops of the fused attention graph
    // node: llama.cpp issues one graph node per layer, so their per-call
    // dispatch cost is a fraction of a full linear's.
    let call = match op.kind {
        crate::model::graph::OpKind::Linear(_) => dev.host.call_overhead,
        _ => dev.host.call_overhead * 0.25,
    };
    let host = (stage + act_quant + call) * contention;

    KernelCost {
        cost: PhaseCost {
            exec,
            load: load * contention.sqrt(), // DMA issue partially serialized
            drain,
            conf,
            regv,
            range,
            host,
        },
        // Same contention scaling as `load` so the stream portion stays a
        // lower bound on the final LOAD term.
        load_stream: dma::load_stream_seconds(dev, load_t, mode) * contention.sqrt(),
    }
}

/// Cost of executing the same kernel on the host CPU instead (the
/// offload policy's alternative, and the fallback the paper's 8B Q8_0
/// configuration takes).
pub fn host_cost(dev: &ImaxDevice, op: &MatvecOp, batch: usize) -> PhaseCost {
    let class = KernelClass::for_type(op.wty);
    let macs = op.macs() as f64 * batch as f64;
    let mem_bytes = op.weight_bytes() as f64; // weights stream from DRAM once
    // The host CPU is the same dual-core A72 in both the FPGA prototype
    // and the ASIC projection (the paper projects the *accelerator* to
    // 28 nm, not the PS) — host kernel execution does not speed up.
    let fpga_bw = ImaxDevice::fpga(2).host.memcpy_bw;
    let mac_rate = host_mac_rate_fpga(class);
    // Roofline: compute or memory bound, whichever is slower.
    let t = (macs / mac_rate).max(mem_bytes / fpga_bw);
    PhaseCost {
        host: t + (op.cols * batch) as f64 / dev.host.elemop_rate,
        ..PhaseCost::ZERO
    }
}

/// Modeled cost of moving evicted KV pages across the host↔accelerator
/// DMA path (prefix-cache swap traffic). Swap-ins ride the LOAD path,
/// swap-outs the DRAIN path, both under the active [`TransferMode`] — so
/// naive mode's fragmentation penalty applies to oversubscription exactly
/// as it does to kernel operands, and the paper's transfer bottleneck
/// stays visible when serving swaps. K and V move as two operand arrays;
/// staging the block through the host-side DMA buffer is charged to HOST.
pub fn kv_swap_cost(
    dev: &ImaxDevice,
    bytes: usize,
    dir: crate::model::graph::KvSwapDir,
    mode: TransferMode,
) -> PhaseCost {
    let t = Transfer { bytes, n_arrays: 2 };
    let mut c = PhaseCost {
        host: dma::stage_seconds(dev, bytes),
        ..PhaseCost::ZERO
    };
    match dir {
        crate::model::graph::KvSwapDir::In => c.load = dma::load_seconds(dev, t, mode),
        crate::model::graph::KvSwapDir::Out => c.drain = dma::drain_seconds(dev, t, mode),
    }
    c
}

/// Host-side per-token work that is never offloaded (paper Fig 4's blue
/// boxes): RMSNorms, RoPE, softmaxes, residuals, sampling scan.
pub fn host_token_overhead(
    dev: &ImaxDevice,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    ctx: usize,
    vocab_for_sampling: Option<usize>,
) -> PhaseCost {
    let norm_elems = (2 * n_layers + 1) * d_model;
    let rope_elems = n_layers * n_heads * 64; // head_dim-scale work
    let softmax_elems = n_layers * n_heads * ctx;
    let sample_elems = vocab_for_sampling.unwrap_or(0);
    let elems = (norm_elems + rope_elems + softmax_elems + sample_elems) as f64;
    PhaseCost {
        host: elems / dev.host.elemop_rate * host_contention(dev),
        ..PhaseCost::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
    use crate::model::graph::{MatvecOp, OpKind};
    use crate::quant::GgmlType;

    fn gate_op(cfg: &ModelConfig, scheme: QuantScheme) -> MatvecOp {
        let (rows, cols) = LinearKind::FfnGate.shape(cfg);
        MatvecOp {
            kind: OpKind::Linear(LinearKind::FfnGate),
            layer: Some(0),
            wty: LinearKind::FfnGate.weight_type(scheme),
            rows,
            cols,
        }
    }

    #[test]
    fn batching_amortizes_weight_load() {
        let dev = ImaxDevice::fpga(2);
        let lmm = LmmConfig::new(64);
        let cfg = ModelConfig::qwen3_1_7b();
        let op = gate_op(&cfg, QuantScheme::Q8_0);
        let mut t1 = ConfTracker::new();
        let mut t2 = ConfTracker::new();
        let c1 = offloaded_cost(&dev, &lmm, &mut t1, &op, 1, TransferMode::Coalesced);
        let c32 = offloaded_cost(&dev, &lmm, &mut t2, &op, 32, TransferMode::Coalesced);
        // 32-token batch: EXEC ×32, LOAD ≪ ×32 (weights amortized).
        assert!(c32.exec > 30.0 * c1.exec);
        assert!(c32.load < 2.0 * c1.load, "load {} vs {}", c32.load, c1.load);
        // Decode (batch=1) is LOAD-bound; prefill is compute-bound.
        assert!(c1.load > c1.exec, "decode LOAD-bound");
        assert!(c32.exec > c32.load, "prefill compute-bound");
    }

    #[test]
    fn overlappable_load_is_bounded_by_total_load() {
        let lmm = LmmConfig::new(64);
        let op = gate_op(&ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0);
        for dev in [ImaxDevice::fpga(2), ImaxDevice::fpga(8), ImaxDevice::asic28(2)] {
            for mode in [TransferMode::Coalesced, TransferMode::Naive] {
                for batch in [1usize, 32] {
                    let k = offloaded_cost_parts(
                        &dev,
                        &lmm,
                        &mut ConfTracker::new(),
                        &op,
                        batch,
                        mode,
                    );
                    assert!(k.load_stream > 0.0);
                    assert!(
                        k.load_stream <= k.cost.load,
                        "stream {} exceeds LOAD {} ({mode:?}, batch {batch})",
                        k.load_stream,
                        k.cost.load
                    );
                }
            }
        }
    }

    #[test]
    fn asic_speeds_up_exec_more_than_load() {
        let f = ImaxDevice::fpga(2);
        let a = ImaxDevice::asic28(2);
        let lmm = LmmConfig::new(64);
        let op = gate_op(&ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS);
        let cf = offloaded_cost(&f, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Coalesced);
        let ca = offloaded_cost(&a, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Coalesced);
        let exec_speedup = cf.exec / ca.exec;
        let load_speedup = cf.load / ca.load;
        assert!(exec_speedup > 5.0, "core ≈5.8× faster");
        assert!(load_speedup < exec_speedup, "memory path scales less");
    }

    #[test]
    fn more_lanes_speed_exec_but_raise_host_contention() {
        let lmm = LmmConfig::new(64);
        let op = gate_op(&ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0);
        let d2 = ImaxDevice::fpga(2);
        let d8 = ImaxDevice::fpga(8);
        let c2 = offloaded_cost(&d2, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Coalesced);
        let c8 = offloaded_cost(&d8, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Coalesced);
        assert!(c8.exec < c2.exec);
        assert!(c8.host > c2.host, "dual-core host penalized beyond 2 lanes");
    }

    #[test]
    fn host_cost_memory_bound_for_large_models() {
        let dev = ImaxDevice::fpga(2);
        let op = MatvecOp {
            kind: OpKind::Linear(LinearKind::FfnDown),
            layer: Some(0),
            wty: GgmlType::Q8_0,
            rows: 4096,
            cols: 12288,
        };
        let c = host_cost(&dev, &op, 1);
        let bw_bound = op.weight_bytes() as f64 / dev.host.memcpy_bw;
        assert!(c.host >= bw_bound);
    }

    #[test]
    fn naive_mode_slower_than_coalesced() {
        let dev = ImaxDevice::fpga(2);
        let lmm = LmmConfig::new(64);
        let op = gate_op(&ModelConfig::qwen3_0_6b(), QuantScheme::Q8_0);
        let c = offloaded_cost(&dev, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Coalesced);
        let n = offloaded_cost(&dev, &lmm, &mut ConfTracker::new(), &op, 1, TransferMode::Naive);
        assert!(n.load > c.load);
        assert!(n.drain > c.drain);
    }

    #[test]
    fn host_token_overhead_grows_with_context() {
        let dev = ImaxDevice::fpga(2);
        let a = host_token_overhead(&dev, 1024, 28, 16, 8, Some(151936));
        let b = host_token_overhead(&dev, 1024, 28, 16, 4096, Some(151936));
        assert!(b.host > a.host);
    }

    #[test]
    fn kv_swap_cost_rides_the_dma_transfer_mode() {
        use crate::model::graph::KvSwapDir;
        let dev = ImaxDevice::fpga(2);
        let bytes = 256 * 1024;
        let cin = kv_swap_cost(&dev, bytes, KvSwapDir::In, TransferMode::Coalesced);
        let cout = kv_swap_cost(&dev, bytes, KvSwapDir::Out, TransferMode::Coalesced);
        // Direction maps to the matching DMA component, nothing else.
        assert!(cin.load > 0.0 && cin.drain == 0.0 && cin.exec == 0.0);
        assert!(cout.drain > 0.0 && cout.load == 0.0 && cout.exec == 0.0);
        assert!(cin.host > 0.0, "staging memcpy charged to HOST");
        // The transfer mode's coalescing penalty carries over to swaps.
        let nin = kv_swap_cost(&dev, bytes, KvSwapDir::In, TransferMode::Naive);
        let nout = kv_swap_cost(&dev, bytes, KvSwapDir::Out, TransferMode::Naive);
        assert!(nin.load > cin.load, "naive swap-in pays fragmentation");
        assert!(nout.drain > cout.drain, "naive swap-out pays fragmentation");
        // More bytes, more seconds.
        let big = kv_swap_cost(&dev, 2 * bytes, KvSwapDir::In, TransferMode::Coalesced);
        assert!(big.load > cin.load);
    }
}
