//! imax-llm CLI — the L3 coordinator binary.
//!
//! Subcommands map 1:1 to the paper's artifacts:
//!
//! ```text
//! imax-llm table1|table2|fig11|fig12|fig13|fig14|fig15|fig16|ablate-dma
//! imax-llm anchors              # calibration vs the paper's numbers
//! imax-llm kernels              # Fig 5-9 kernel mapping summary
//! imax-llm run    [--model tiny|110m] [--scheme Q8_0] [--prompt txt] [--n 32]
//! imax-llm serve  [--requests 16] [--workers 2] [--kv-pages 64] [--page-size 16]
//! imax-llm verify-plan [--backend SPEC]   # static schedule/invariant gate
//! imax-llm build-model --out path [--model tiny|110m] [--scheme Q8_0]
//! ```
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use imax_llm::analysis;
use imax_llm::baseline::calibration as cal;
use imax_llm::baseline::GpuDevice;
use imax_llm::coordinator::hybrid::{simulate_auto, Workload};
use imax_llm::coordinator::{
    serve_streaming, serve_trace, serve_with, AdaptiveBudget, CancelHandle, Request,
    SchedPolicy, ServeError, ServeOptions,
};
use imax_llm::harness::experiments as exp;
use imax_llm::harness::scenario::Scenario;
use imax_llm::harness::workloads::{templated_prompt, TEMPLATE_SPAN};
use imax_llm::imax::{ImaxDevice, KernelClass, LmmConfig, TransferMode};
use imax_llm::model::{
    DrafterSpec, Engine, KvScheme, ModelConfig, ModelWeights, QuantScheme, Sampler,
    DEFAULT_PAGE_SIZE, DEFAULT_UBATCH,
};
use imax_llm::power;
use imax_llm::runtime::{BackendRegistry, ExecSpec};
use imax_llm::tokenizer::Tokenizer;
use imax_llm::util::report::Table;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn model_flag(flags: &HashMap<String, String>) -> Result<ModelConfig> {
    let name = flags.get("model").map(|s| s.as_str()).unwrap_or("tiny");
    ModelConfig::by_name(name).with_context(|| format!("unknown model '{name}'"))
}

fn scheme_flag(flags: &HashMap<String, String>) -> Result<QuantScheme> {
    let name = flags.get("scheme").map(|s| s.as_str()).unwrap_or("Q8_0");
    QuantScheme::by_name(name).with_context(|| format!("unknown scheme '{name}'"))
}

fn cmd_kernels() {
    let mut t = Table::new(
        "IMAX kernel mappings (paper §III.C, Figs 5-9)",
        &["kernel", "units", "elems/burst", "cycles/burst", "pipeline", "dataflow"],
    );
    for k in KernelClass::ALL {
        let df: Vec<String> = k.dataflow().iter().map(|i| format!("{i:?}")).collect();
        t.row(vec![
            k.name().to_string(),
            k.units().to_string(),
            k.elems_per_burst().to_string(),
            k.cycles_per_burst().to_string(),
            k.pipeline_depth().to_string(),
            df.join("->"),
        ]);
    }
    t.print();
}

fn cmd_anchors() {
    // Calibration summary: simulated value vs paper anchor, side by side.
    let mut t = Table::new(
        "Calibration vs paper anchors (shape, not absolutes — DESIGN.md §6)",
        &["anchor", "paper", "simulated", "ratio"],
    );
    let fpga = ImaxDevice::fpga(2);
    let asic = ImaxDevice::asic28(2);

    // Anchor 1: 0.6B Q3_K_S [32:16] FPGA breakdown.
    let w = Workload {
        cfg: ModelConfig::qwen3_0_6b(),
        scheme: QuantScheme::Q3KS,
        n_in: 32,
        n_out: 16,
    };
    let run = simulate_auto(&w, &fpga, TransferMode::Coalesced);
    let tot = run.breakdown.total();
    let mut anchor_row = |name: &str, paper: f64, sim: f64| {
        t.row(vec![
            name.to_string(),
            format!("{paper:.2}"),
            format!("{sim:.2}"),
            format!("{:.2}x", sim / paper),
        ]);
    };
    anchor_row("0.6B Q3KS[32:16] FPGA total (s)", cal::anchor_breakdown::TOTAL_S, run.breakdown.e2e_seconds());
    anchor_row("  EXEC (s)", cal::anchor_breakdown::EXEC_S, tot.exec);
    anchor_row("  LOAD (s)", cal::anchor_breakdown::LOAD_S, tot.load);
    anchor_row("  HOST (s)", cal::anchor_breakdown::HOST_S, tot.host);
    anchor_row("  DRAIN (s)", cal::anchor_breakdown::DRAIN_S, tot.drain);
    anchor_row(
        "  CONFIG (s)",
        cal::anchor_breakdown::CONFIG_S,
        tot.conf + tot.regv + tot.range,
    );

    // Anchor: same workload, 28 nm latency + EDP.
    let run_a = simulate_auto(&w, &asic, TransferMode::Coalesced);
    let lat_a = run_a.breakdown.e2e_seconds();
    let e_a = power::imax_energy(&asic, &LmmConfig::new(64), &run_a);
    anchor_row(
        "0.6B Q3KS[32:16] 28nm latency (s)",
        cal::anchor_edp_06b_q3_32_16::IMAX28_LATENCY_S,
        lat_a,
    );
    anchor_row(
        "0.6B Q3KS[32:16] 28nm EDP (J*s)",
        cal::anchor_edp_06b_q3_32_16::IMAX28,
        lat_a * e_a.pdp_j(),
    );
    anchor_row(
        "0.6B Q3KS[32:16] RTX EDP (J*s)",
        cal::anchor_edp_06b_q3_32_16::RTX4090,
        GpuDevice::rtx4090().e2e_seconds(&w) * GpuDevice::rtx4090().energy(&w).pdp_j(),
    );
    anchor_row(
        "0.6B Q3KS[32:16] Jetson EDP (J*s)",
        cal::anchor_edp_06b_q3_32_16::JETSON,
        GpuDevice::jetson_orin().e2e_seconds(&w) * GpuDevice::jetson_orin().energy(&w).pdp_j(),
    );

    // Anchor 2: 1.7B Q8_0 [16:4] PDP, four platforms.
    let w2 = Workload {
        cfg: ModelConfig::qwen3_1_7b(),
        scheme: QuantScheme::Q8_0,
        n_in: 16,
        n_out: 4,
    };
    let run2 = simulate_auto(&w2, &asic, TransferMode::Coalesced);
    let e2 = power::imax_energy(&asic, &LmmConfig::new(64), &run2);
    anchor_row("1.7B Q8[16:4] PDP imax28 (J)", cal::anchor_pdp_17b_q8_16_4::IMAX28, e2.pdp_j());
    anchor_row(
        "1.7B Q8[16:4] PDP RTX4090 (J)",
        cal::anchor_pdp_17b_q8_16_4::RTX4090,
        GpuDevice::rtx4090().energy(&w2).pdp_j(),
    );
    anchor_row(
        "1.7B Q8[16:4] PDP GTX1080Ti (J)",
        cal::anchor_pdp_17b_q8_16_4::GTX1080TI,
        GpuDevice::gtx1080ti().energy(&w2).pdp_j(),
    );
    anchor_row(
        "1.7B Q8[16:4] PDP Jetson (J)",
        cal::anchor_pdp_17b_q8_16_4::JETSON,
        GpuDevice::jetson_orin().energy(&w2).pdp_j(),
    );

    // Anchor 3: 8B Q8_0 [32:16] PDP inversion.
    let w3 = Workload {
        cfg: ModelConfig::qwen3_8b(),
        scheme: QuantScheme::Q8_0,
        n_in: 32,
        n_out: 16,
    };
    let run3 = simulate_auto(&w3, &asic, TransferMode::Coalesced);
    let e3 = power::imax_energy(&asic, &LmmConfig::new(64), &run3);
    anchor_row("8B Q8[32:16] PDP imax28 (J)", cal::anchor_pdp_8b_q8_32_16::IMAX28, e3.pdp_j());
    anchor_row(
        "8B Q8[32:16] PDP RTX4090 (J)",
        cal::anchor_pdp_8b_q8_32_16::RTX4090,
        GpuDevice::rtx4090().energy(&w3).pdp_j(),
    );
    anchor_row(
        "8B Q8[32:16] PDP Jetson (J)",
        cal::anchor_pdp_8b_q8_32_16::JETSON,
        GpuDevice::jetson_orin().energy(&w3).pdp_j(),
    );

    // Anchor 5: 1.7B Q8_0 [32:16] EDP (Jetson wins).
    let w5 = Workload {
        cfg: ModelConfig::qwen3_1_7b(),
        scheme: QuantScheme::Q8_0,
        n_in: 32,
        n_out: 16,
    };
    let run5 = simulate_auto(&w5, &asic, TransferMode::Coalesced);
    let e5 = power::imax_energy(&asic, &LmmConfig::new(64), &run5);
    let lat5 = run5.breakdown.e2e_seconds();
    anchor_row("1.7B Q8[32:16] 28nm latency (s)", cal::anchor_edp_17b_q8_32_16::IMAX28_LATENCY_S, lat5);
    anchor_row("1.7B Q8[32:16] 28nm EDP (J*s)", cal::anchor_edp_17b_q8_32_16::IMAX28, lat5 * e5.pdp_j());
    let jet = GpuDevice::jetson_orin();
    anchor_row(
        "1.7B Q8[32:16] Jetson latency (s)",
        cal::anchor_edp_17b_q8_32_16::JETSON_LATENCY_S,
        jet.e2e_seconds(&w5),
    );
    anchor_row(
        "1.7B Q8[32:16] Jetson EDP (J*s)",
        cal::anchor_edp_17b_q8_32_16::JETSON,
        jet.e2e_seconds(&w5) * jet.energy(&w5).pdp_j(),
    );
    t.print();
}

fn backend_flag(flags: &HashMap<String, String>, default: &str) -> Result<ExecSpec> {
    let name = flags.get("backend").map(|s| s.as_str()).unwrap_or(default);
    ExecSpec::parse(name)
}

fn kv_quant_flag(flags: &HashMap<String, String>) -> Result<KvScheme> {
    let name = flags.get("kv-quant").map(|s| s.as_str()).unwrap_or("f16");
    KvScheme::by_name(name)
        .with_context(|| format!("unknown KV page encoding '{name}' (use f16|q8_0)"))
}

/// Parse `--tenant-weights name:w,name:w` into the WFQ ledger's pairs.
fn parse_tenant_weights(s: &str) -> Result<Vec<(String, f64)>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let (name, w) = p
                .split_once(':')
                .with_context(|| format!("tenant weight must be name:weight, got '{p}'"))?;
            let w: f64 = w
                .trim()
                .parse()
                .with_context(|| format!("bad tenant weight in '{p}'"))?;
            Ok((name.trim().to_string(), w))
        })
        .collect()
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = model_flag(flags)?;
    let scheme = scheme_flag(flags)?;
    let spec = backend_flag(flags, "imax")?;
    if let ExecSpec::Placement(p) = &spec {
        p.validate_layers(cfg.n_layers)?;
    }
    let n_out: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let prompt_text = flags
        .get("prompt")
        .cloned()
        .unwrap_or_else(|| "the coarse-grained linear array accelerates".to_string());

    eprintln!(
        "building {} ({}) with random-init weights, backend {}…",
        cfg.name,
        scheme.name(),
        spec.name()
    );
    let weights = ModelWeights::random(&cfg, scheme, 2025);
    let tok = Tokenizer::train(&prompt_text.repeat(8), 64);
    let prompt = tok.encode_with_bos(&prompt_text);
    let mut engine = Engine::new(weights);

    let mut exec = BackendRegistry::build(&spec)?;
    let t0 = std::time::Instant::now();
    let res = engine.generate(&prompt, n_out, &mut Sampler::top_k(0.9, 40, 7), &mut exec);
    let wall = t0.elapsed().as_secs_f64();

    println!("backend       : {}", spec.name());
    println!("prompt tokens : {}", prompt.len());
    println!("output tokens : {}", res.tokens.len());
    println!("output text   : {:?}", tok.decode(&res.tokens));
    println!(
        "wall time     : {wall:.3}s ({:.1} tok/s)",
        (prompt.len() + res.tokens.len()) as f64 / wall
    );
    let rep = exec.report();
    if let Some(modeled) = rep.modeled {
        println!(
            "modeled IMAX  : prefill {:.4}s decode {:.4}s",
            modeled.prefill.total(),
            modeled.decode.total()
        );
    }
    if let Some(stats) = exec.offload_stats() {
        stats.table(&format!("{} {}", cfg.name, scheme.name())).print();
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = model_flag(flags)?;
    let scheme = scheme_flag(flags)?;
    let spec = backend_flag(flags, "native")?;
    let n_req: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let slots: usize = flags.get("slots").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let ubatch: usize = flags
        .get("ubatch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(DEFAULT_UBATCH);
    let page_size: usize = flags
        .get("page-size")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(DEFAULT_PAGE_SIZE);
    let kv_pages: Option<usize> = flags.get("kv-pages").map(|s| s.parse()).transpose()?;
    let prefix_cache = flags.get("prefix-cache").map(|v| v == "true").unwrap_or(false);
    let swap_pages: usize = flags.get("swap-pages").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let sched = match flags.get("sched") {
        Some(s) => SchedPolicy::by_name(s)
            .with_context(|| format!("unknown admission policy '{s}' (use fifo|sjf|wfq)"))?,
        None => SchedPolicy::Fifo,
    };
    let token_budget: Option<usize> =
        flags.get("token-budget").map(|s| s.parse()).transpose()?;
    let prefill_chunk: Option<usize> =
        flags.get("prefill-chunk").map(|s| s.parse()).transpose()?;
    let adaptive_budget: Option<AdaptiveBudget> =
        flags.get("adaptive-budget").map(|s| AdaptiveBudget::parse(s)).transpose()?;
    let adaptive_chunk = flags.get("adaptive-chunk").map(|v| v == "true").unwrap_or(false);
    let mut tenant_weights: Vec<(String, f64)> = flags
        .get("tenant-weights")
        .map(|s| parse_tenant_weights(s))
        .transpose()?
        .unwrap_or_default();
    let mut slo_ttft_s: Option<f64> =
        flags.get("slo-ttft-s").map(|s| s.parse()).transpose()?;
    let mut slo_tbt_s: Option<f64> = flags.get("slo-tbt-s").map(|s| s.parse()).transpose()?;
    let scenario: Option<Scenario> = flags
        .get("scenario")
        .map(|path| -> Result<Scenario> {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading scenario file '{path}'"))?;
            Scenario::parse(&text).with_context(|| format!("parsing scenario file '{path}'"))
        })
        .transpose()?;
    if let Some(sc) = &scenario {
        if sc.vocab_size > cfg.vocab_size {
            bail!(
                "scenario vocab_size {} exceeds the model's vocabulary ({})",
                sc.vocab_size,
                cfg.vocab_size
            );
        }
        if flags.contains_key("cancel-after") {
            bail!("--cancel-after drives its own trace; a scenario carries its own cancel mix");
        }
        // The scenario file is the default for traffic-facing knobs;
        // explicit flags still win.
        if tenant_weights.is_empty() {
            tenant_weights = sc.tenant_weights();
        }
        if slo_ttft_s.is_none() && sc.slo_ttft_s > 0.0 {
            slo_ttft_s = Some(sc.slo_ttft_s);
        }
        if slo_tbt_s.is_none() && sc.slo_tbt_s > 0.0 {
            slo_tbt_s = Some(sc.slo_tbt_s);
        }
    }
    let admit_window: usize = flags
        .get("admit-window")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(imax_llm::coordinator::ADMIT_SCAN_WINDOW);
    let speculate: usize = flags.get("speculate").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let drafter: Option<DrafterSpec> =
        flags.get("drafter").map(|s| DrafterSpec::parse(s)).transpose()?;
    let deadline_s: Option<f64> = flags.get("deadline-s").map(|s| s.parse()).transpose()?;
    let cancel_after: Option<usize> =
        flags.get("cancel-after").map(|s| s.parse()).transpose()?;
    let audit = flags.get("audit").map(|v| v == "true").unwrap_or(false);
    let kv_quant = kv_quant_flag(flags)?;
    match kv_pages {
        Some(pages) => eprintln!(
            "building {} ({}), backend {}, {workers} workers × {slots} sessions, \
             KV pool {pages} pages × {page_size} tokens…",
            cfg.name,
            scheme.name(),
            spec.name()
        ),
        None => eprintln!(
            "building {} ({}), backend {}, {workers} workers × {slots} sessions \
             (fully backed KV, {page_size}-token pages)…",
            cfg.name,
            scheme.name(),
            spec.name()
        ),
    }
    let weights = ModelWeights::random(&cfg, scheme, 2025);
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            // With the prefix cache on, serve a templated workload: a
            // shared system-prompt prefix of two full pages plus a short
            // unique user suffix — the shape prefix sharing targets.
            let mut prompt: Vec<u32> = if prefix_cache {
                (0..2 * page_size).map(|i| 2 + (i % 97) as u32).collect()
            } else {
                Vec::new()
            };
            if speculate > 0 {
                // Speculating: serve templated prompts (repetitive
                // spans), the shape where prompt-lookup drafting wins.
                prompt.extend(templated_prompt(id, 6 * TEMPLATE_SPAN, cfg.vocab_size));
            } else {
                prompt.extend((0..8).map(|i| 2 + ((id * 37 + i * 11) % 200) as u32));
            }
            let mut req = Request::new(id, prompt, 16);
            if let Some(d) = deadline_s {
                req = req.with_deadline_s(d);
            }
            req
        })
        .collect();
    let opts = ServeOptions {
        slots_per_worker: slots,
        ubatch,
        sampler_seed: 42,
        spec,
        page_size,
        kv_pages,
        prefix_cache,
        swap_pages,
        sched,
        token_budget,
        prefill_chunk,
        adaptive_budget,
        adaptive_chunk,
        tenant_weights,
        slo_ttft_s,
        slo_tbt_s,
        admit_window,
        speculate,
        drafter,
        kv_quant,
        audit,
    };
    let rep = if let Some(sc) = &scenario {
        // --scenario FILE: replay the seeded multi-tenant trace through
        // the timed open-loop front-end; a load-driver thread fires each
        // scenario cancel at its trace offset (arrival + delay).
        let mut cancels: Vec<(CancelHandle, f64)> = Vec::new();
        let mut trace: Vec<(Request, f64)> = Vec::new();
        for a in sc.arrivals() {
            if let Some((h, delay)) = a.cancel {
                cancels.push((h, a.at_s + delay));
            }
            trace.push((a.request, a.at_s));
        }
        eprintln!(
            "scenario '{}': {} arrivals over {:.2}s of wall time (time_scale {}), \
             {} tenants, {} self-cancelling",
            sc.name,
            trace.len(),
            trace.last().map(|(_, t)| *t).unwrap_or(0.0),
            sc.time_scale,
            sc.tenants.len(),
            cancels.len(),
        );
        cancels.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap_or(std::cmp::Ordering::Equal));
        let canceller = if cancels.is_empty() {
            None
        } else {
            let t0 = std::time::Instant::now();
            Some(std::thread::spawn(move || {
                for (h, fire_s) in cancels {
                    let target = std::time::Duration::from_secs_f64(fire_s.max(0.0));
                    loop {
                        let elapsed = t0.elapsed();
                        if elapsed >= target {
                            break;
                        }
                        std::thread::sleep(
                            (target - elapsed).min(std::time::Duration::from_millis(5)),
                        );
                    }
                    h.cancel();
                }
            }))
        };
        let rep = serve_trace(&weights, trace, workers, &opts)?;
        if let Some(c) = canceller {
            c.join().ok();
        }
        rep
    } else {
        match cancel_after {
            // --cancel-after N: stream tokens and fire each request's
            // cancel handle once N of its tokens have been delivered —
            // exercising mid-decode teardown through the public front-end.
            Some(n) => {
                let mut requests = requests;
                let handles: Vec<CancelHandle> = requests
                    .iter_mut()
                    .map(|r| {
                        let h = CancelHandle::new();
                        r.cancel = Some(h.clone());
                        h
                    })
                    .collect();
                let stream = serve_streaming(&weights, requests, workers, &opts)?;
                let (events, handle) = stream.into_parts();
                let mut delivered = vec![0usize; handles.len()];
                let mut streamed = 0usize;
                for ev in events.iter() {
                    streamed += 1;
                    if let Some(count) = delivered.get_mut(ev.request_id) {
                        *count += 1;
                        if *count >= n {
                            handles[ev.request_id].cancel();
                        }
                    }
                }
                eprintln!("streamed {streamed} token events (cancel after {n} per request)");
                handle.join().expect("serve thread panicked")?
            }
            None => serve_with(&weights, requests, workers, &opts)?,
        }
    };
    println!(
        "served {} requests / {} tokens in {:.2}s — {:.1} tok/s, p50 {:.3}s p95 {:.3}s [{}]",
        rep.completions.len(),
        rep.total_tokens,
        rep.wall_s,
        rep.throughput_tok_s,
        rep.latency_p50_s,
        rep.latency_p95_s,
        rep.backend,
    );
    println!(
        "TTFT p50 {:.4}s p99 {:.4}s; TBT p50 {:.5}s p99 {:.5}s",
        rep.ttft_p50_s, rep.ttft_p99_s, rep.tbt_p50_s, rep.tbt_p99_s,
    );
    if let Some(att) = rep.slo_attainment {
        let target = |v: Option<f64>| v.map_or("-".to_string(), |s| format!("{s}s"));
        println!(
            "SLO attainment {:.1}% (TTFT target {}, per-request TBT p99 target {})",
            100.0 * att,
            target(rep.slo_ttft_s),
            target(rep.slo_tbt_s),
        );
    }
    if !rep.tenants.is_empty() {
        let mut t = Table::new(
            "per-tenant serving report",
            &[
                "tenant", "reqs", "served", "cancel", "expire", "reject", "tokens",
                "ttft p50 (s)", "ttft p99 (s)", "tbt p99 (s)", "slo",
            ],
        );
        for tr in &rep.tenants {
            t.row(vec![
                tr.tenant.clone(),
                tr.requests.to_string(),
                tr.served.to_string(),
                tr.cancelled.to_string(),
                tr.deadline_expired.to_string(),
                tr.rejected.to_string(),
                tr.total_tokens.to_string(),
                format!("{:.4}", tr.ttft_p50_s),
                format!("{:.4}", tr.ttft_p99_s),
                format!("{:.5}", tr.tbt_p99_s),
                tr.slo_attainment
                    .map_or("-".to_string(), |a| format!("{:.0}%", 100.0 * a)),
            ]);
        }
        t.print();
    }
    if token_budget.is_some() || adaptive_budget.is_some() {
        let r = &rep.rounds;
        println!(
            "token-budget rounds: {} total ({} mixed), {} decode tokens, {} chunked \
             prefill tokens ({:.1} per prefill round, max {} in one round)",
            r.rounds,
            r.mixed_rounds,
            r.decode_tokens,
            r.chunked_prefill_tokens,
            r.prefill_tokens_per_round(),
            r.max_prefill_tokens_round,
        );
        if r.adaptive_rounds > 0 {
            println!(
                "adaptive budget: {} controller steps; per-round budget walked \
                 [{}, {}] on the modeled LOAD/EXEC balance",
                r.adaptive_rounds, r.budget_lo, r.budget_hi,
            );
        }
    }
    println!(
        "peak resident KV ({} pages, page-granular, summed per worker): {}",
        rep.kv_scheme,
        imax_llm::util::human_bytes(rep.kv_peak_bytes)
    );
    if prefix_cache {
        let r = &rep.reuse;
        println!(
            "prefix cache: {} hits / {} prefill tokens skipped; CoW {}; evicted {} \
             pages ({} swapped out, {} dropped), {} swapped in; swap traffic {}",
            r.prefix_hits,
            r.prefix_hit_tokens,
            r.cow_pages,
            r.evicted_pages(),
            r.swap_out_pages,
            r.dropped_pages,
            r.swap_in_pages,
            imax_llm::util::human_bytes(r.swap_bytes),
        );
    }
    if speculate > 0 {
        println!(
            "speculation (k={speculate}): {} verify passes, {}/{} drafted tokens accepted \
             ({:.0}% accept rate), {:.2} accepted tokens per verify pass",
            rep.verify_calls,
            rep.draft_accepted,
            rep.draft_tokens,
            100.0 * rep.draft_accept_rate.unwrap_or(0.0),
            rep.accepted_tokens_per_verify.unwrap_or(0.0),
        );
    }
    if let Some(bpt) = rep.streamed_bytes_per_token {
        println!(
            "modeled accelerator stream: {} total, {:.0} bytes per accepted token",
            imax_llm::util::human_bytes(rep.streamed_bytes as usize),
            bpt,
        );
    }
    if rep.kv_swap_bytes > 0 {
        println!(
            "modeled KV swap traffic charged through the DMA transfer mode: {}",
            imax_llm::util::human_bytes(rep.kv_swap_bytes as usize)
        );
    }
    let mut rejected = 0usize;
    for c in rep.completions.iter().filter(|c| c.error.is_some()) {
        match c.error.as_ref().unwrap() {
            ServeError::Cancelled | ServeError::DeadlineExpired => {}
            e => {
                rejected += 1;
                eprintln!("request {} rejected: {e}", c.id);
            }
        }
    }
    if rejected > 0 {
        println!("rejected {rejected} of {} requests (KV budget)", rep.completions.len());
    }
    if rep.cancelled > 0 || rep.deadline_expired > 0 {
        println!(
            "cancelled {} / deadline-expired {} of {} requests (pages released mid-decode)",
            rep.cancelled,
            rep.deadline_expired,
            rep.completions.len()
        );
    }
    if let Some(modeled) = rep.modeled {
        println!(
            "modeled IMAX per-phase: prefill {:.4}s decode {:.4}s (offload ratio {:.0}%)",
            modeled.prefill.total(),
            modeled.decode.total(),
            100.0 * rep.offload_ratio.unwrap_or(0.0)
        );
    }
    // Heterogeneous placements: one summed sub-report per backend.
    for part in &rep.per_backend {
        match part.modeled {
            Some(m) => println!(
                "  [{}] modeled prefill {:.4}s decode {:.4}s (offload ratio {:.0}%)",
                part.backend,
                m.prefill.total(),
                m.decode.total(),
                100.0 * part.offload_ratio.unwrap_or(0.0)
            ),
            None => println!("  [{}] functional only (no modeled costs)", part.backend),
        }
    }
    if audit {
        if rep.audit_findings.is_empty() {
            println!(
                "audit: clean — schedule verifier on every step, invariant auditor \
                 between rounds, 0 findings"
            );
        } else {
            println!("audit: {} findings", rep.audit_findings.len());
            for f in &rep.audit_findings {
                println!("  {f}");
            }
        }
    }
    Ok(())
}

/// `verify-plan`: replay a full-feature serve shape under the analysis
/// stack — static placement verification, the plan-time schedule
/// verifier on every forward step, and the cross-subsystem invariant
/// auditor between rounds — and fail (exit nonzero) on any finding.
fn cmd_verify_plan(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = model_flag(flags)?;
    let scheme = scheme_flag(flags)?;
    let spec = backend_flag(flags, "native")?;
    let mut findings = Vec::new();
    if let ExecSpec::Placement(p) = &spec {
        findings.extend(analysis::verify_placement(p, cfg.n_layers));
    }
    if findings.is_empty() {
        let n_req: usize =
            flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(6);
        let workers: usize =
            flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(1);
        let page_size: usize =
            flags.get("page-size").map(|s| s.parse()).transpose()?.unwrap_or(8);
        let kv_pages: usize =
            flags.get("kv-pages").map(|s| s.parse()).transpose()?.unwrap_or(20);
        let swap_pages: usize =
            flags.get("swap-pages").map(|s| s.parse()).transpose()?.unwrap_or(8);
        let speculate: usize =
            flags.get("speculate").map(|s| s.parse()).transpose()?.unwrap_or(4);
        let kv_quant = kv_quant_flag(flags)?;
        eprintln!(
            "verify-plan: replaying {n_req} requests on {} ({}), backend {} — \
             prefix cache + {swap_pages}-page swap arena over a {kv_pages}-page \
             {} pool, speculation k={speculate}…",
            cfg.name,
            scheme.name(),
            spec.name(),
            kv_quant.name()
        );
        let weights = ModelWeights::random(&cfg, scheme, 2025);
        let requests: Vec<Request> = (0..n_req)
            .map(|id| {
                // Two shared prefix pages plus a templated suffix: the
                // shape that exercises prefix aliasing, CoW, eviction,
                // swap, and prompt-lookup drafting all at once.
                let mut prompt: Vec<u32> =
                    (0..2 * page_size).map(|i| 2 + (i % 97) as u32).collect();
                prompt.extend(templated_prompt(id, 4 * TEMPLATE_SPAN, cfg.vocab_size));
                Request::new(id, prompt, 12)
            })
            .collect();
        let opts = ServeOptions {
            spec,
            page_size,
            kv_pages: Some(kv_pages),
            prefix_cache: true,
            swap_pages,
            speculate,
            kv_quant,
            audit: true,
            ..ServeOptions::default()
        };
        let rep = serve_with(&weights, requests, workers, &opts)?;
        eprintln!(
            "verify-plan: served {} requests / {} tokens; every step's launch \
             stream verified, pool audited between rounds",
            rep.completions.len(),
            rep.total_tokens
        );
        findings.extend(rep.audit_findings);
    }
    if findings.is_empty() {
        println!("verify-plan: clean (0 findings)");
        Ok(())
    } else {
        println!("verify-plan: {} findings", findings.len());
        for f in &findings {
            println!("  {f}");
        }
        bail!("verify-plan found {} schedule/invariant violations", findings.len());
    }
}

fn cmd_build_model(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = model_flag(flags)?;
    let scheme = scheme_flag(flags)?;
    let out = flags.get("out").context("--out required")?;
    let weights = ModelWeights::random(&cfg, scheme, 2025);
    imax_llm::model::file::save(&weights, out)?;
    println!(
        "wrote {} ({} params, {})",
        out,
        cfg.n_params(),
        imax_llm::util::human_bytes(weights.nbytes())
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);

    match cmd {
        "table1" => exp::table1().print(),
        "table2" => exp::table2().print(),
        "fig11" | "fig12" | "fig13" => {
            eprintln!("evaluating the 54-workload grid…");
            let grid = exp::eval_grid();
            match cmd {
                "fig11" => exp::fig11(&grid).print(),
                "fig12" => exp::fig12(&grid).print(),
                _ => exp::fig13(&grid).print(),
            }
        }
        "fig14" => exp::fig14(&[16, 32, 64, 128, 256, 512]).print(),
        "fig15" => exp::fig15().print(),
        "fig16" => exp::fig16().print(),
        "ablate-dma" => exp::ablate_dma().print(),
        "anchors" => cmd_anchors(),
        "kernels" => cmd_kernels(),
        "run" => cmd_run(&flags)?,
        "serve" => cmd_serve(&flags)?,
        "verify-plan" => cmd_verify_plan(&flags)?,
        "build-model" => cmd_build_model(&flags)?,
        "all" => {
            let grid = exp::eval_grid();
            exp::table1().print();
            exp::fig11(&grid).print();
            exp::fig12(&grid).print();
            exp::fig13(&grid).print();
            exp::fig14(&[16, 32, 64, 128, 256, 512]).print();
            exp::fig15().print();
            exp::fig16().print();
            exp::table2().print();
            exp::ablate_dma().print();
            cmd_anchors();
        }
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
        }
        other => bail!("unknown command '{other}' (try `imax-llm help`)"),
    }
    Ok(())
}

const HELP: &str = "\
imax-llm — IMAX CGLA LLM-acceleration reproduction (IEEE Access 2025)

experiments:
  table1            device specifications
  table2            offload ratios per model/quant/kernel
  fig11|fig12|fig13 E2E latency / PDP / EDP across the 54-workload grid
  fig14             LMM-size sweep (PDP)
  fig15             prefill/decode execution-time breakdown
  fig16             lane scalability
  ablate-dma        DMA transfer-coalescing ablation
  anchors           calibration vs the paper's published numbers
  all               everything above

functional engine (real tiny models, real tokens):
  run         [--model tiny|110m] [--scheme F16|Q8_0|Q3_K_S] [--prompt txt] [--n N]
              [--backend SPEC]   (default imax)
  serve       [--requests N] [--workers N] [--slots N] [--ubatch N]
              [--page-size N] [--kv-pages N]
              [--prefix-cache] [--swap-pages N] [--sched fifo|sjf|wfq]
              [--token-budget N] [--prefill-chunk N] [--admit-window N]
              [--adaptive-budget MIN:MAX] [--adaptive-chunk]
              [--scenario FILE] [--tenant-weights name:w,...]
              [--slo-ttft-s F] [--slo-tbt-s F]
              [--speculate K] [--drafter ngram[:N]] [--kv-quant f16|q8_0]
              [--deadline-s F] [--cancel-after N] [--audit]
              [--model tiny|110m] [--scheme S]
              [--backend SPEC]   (default native)
              continuous batching: sessions are admitted into free slots
              between decode rounds; an imax backend adds modeled per-phase
              IMAX accounting to the serve report. The KV cache is paged:
              --kv-pages caps each worker's pool (admission defers until
              pages free up; impossible requests are rejected), --page-size
              sets tokens per page (default 16); omit --kv-pages to fully
              back every slot. --prefix-cache shares committed prompt-prefix
              pages across requests (refcounted copy-on-write pages; warm
              admissions skip the aliased span's prefill and the report
              prints hit counters); --swap-pages N backs eviction with a
              host swap arena of N pages per worker (swap traffic is charged
              through the imax DMA transfer mode; requires --prefix-cache);
              --sched picks admission order: fifo (default), sjf
              (shortest job first by prefix-aware worst-case pages), or
              wfq (weighted fair queueing: every admission window is
              ordered by least weighted service, where admitted work
              charges its tenant's account at tokens/weight;
              --tenant-weights name:w,... sets the weights, default 1).
              --scenario FILE replays a seeded multi-tenant traffic
              scenario (format: docs/scenarios.md; examples under
              examples/scenarios/): requests arrive open-loop at their
              generated offsets via a feeder thread — bursty/diurnal
              arrival processes, per-tenant request shapes (the agent
              shape shares a templated prefix with the prefix cache),
              cancel/deadline mixes, and scenario-level tenant weights
              and SLO targets (explicit flags win). Same file, same
              trace — to the bit. The report adds a per-tenant
              breakdown table. --slo-ttft-s F / --slo-tbt-s F grade
              every served request against a TTFT / per-request p99
              TBT target and report SLO attainment overall and per
              tenant. --adaptive-budget MIN:MAX replaces the fixed
              --token-budget with a closed-loop controller: after every
              settled round it reads the modeled LOAD/EXEC balance the
              imax backend emits and walks the next round's budget by
              quarter-steps inside [MIN, MAX] (load-bound rounds grow
              the budget to amortize weight streaming; exec-bound
              rounds shrink it to protect TBT). On a functional backend
              the budget freezes at MAX. --adaptive-chunk additionally
              splits each round's leftover budget evenly across the
              flights still prefilling (capped by --prefill-chunk)
              instead of feeding them strictly in admission order —
              both are schedule changes only, tokens stay bit-identical.
              --token-budget N switches each worker to token-budget
              iteration scheduling: every round carries all live decode
              tokens first, then resumable prefill chunks of at most
              --prefill-chunk tokens (default: the ubatch size) up to the
              budget, so a long prompt interleaves with live decodes
              instead of stalling them (the report prints TTFT/TBT
              percentiles and the per-round mix). --admit-window N bounds
              how many queued requests admission scans past a deferred
              head per round (default 8; 0 = unbounded).
              --speculate K turns on speculative decoding: a host-side
              prompt-lookup drafter proposes up to K tokens per live
              sequence each decode round and the engine verifies the
              whole draft in one batched ubatch, so one weight stream
              covers every accepted token. Greedy and top-k output is
              bit-identical to vanilla decode (accept the longest prefix
              matching what vanilla sampling would have produced);
              rejected draft KV entries are rolled back through the paged
              pool. Drafted tokens count against --token-budget like any
              other tokens. --drafter ngram:N sets the longest gram the
              drafter matches on (default ngram:3; with --prefix-cache it
              also mines the cache's committed token spans). The report
              prints verify passes, the draft accept rate, accepted
              tokens per verify pass, and — on an imax backend — the
              modeled streamed bytes per accepted token that speculation
              drives down. Serving is streaming-capable: tokens are
              delivered the instant the scheduler emits them, and TTFT /
              TBT percentiles are stamped at delivery (a speculative
              verify's accepted run is one delivery event, so --speculate
              no longer deflates TBT). --deadline-s F gives every request
              an enqueue-relative deadline: expired requests complete
              with a typed deadline error, releasing their pages
              mid-decode. --cancel-after N streams via the front-end and
              fires each request's cancel handle after N delivered
              tokens — cancelled requests free their non-shared KV pages
              between rounds and the freed budget is re-spent the same
              round; both print cancelled/expired counts in the report.
              --kv-quant picks the KV page encoding: f16 (default) is
              the bit-exact reference; q8_0 quantizes each committed
              token's K/V rows into q8_0 blocks and dequantizes on
              attention read — ~1.88x less KV residency, swap traffic,
              and modeled attention-stream bytes, at the cost of a
              small bounded logit drift (sampled tokens can differ from
              the f16 reference; rust/tests/kv_quant_accuracy.rs bounds
              the drift and checks greedy-token agreement). Needs
              kv_dim divisible by 32. Prefix-cache keys hash token ids,
              not page bytes, so warm hits behave identically under
              either encoding.
              --audit runs the static analyzers during the serve: every
              forward step's recorded launch stream goes through the
              plan-time schedule verifier (dependency-chain order, submit
              boundaries vs the dbuf LOAD/EXEC overlap, step markers,
              batch legality) and the cross-subsystem invariant auditor
              runs between decode rounds (page refcounts, CoW aliases,
              budget conservation, prefix-chain hashes); findings print
              with the report and execution stays bit-identical
  verify-plan [--backend SPEC] [--model tiny|110m] [--scheme S]
              [--requests N] [--workers N] [--page-size N] [--kv-pages N]
              [--swap-pages N] [--speculate K] [--kv-quant f16|q8_0]
              static plan verification as a gate: verifies placement
              coverage (every layer routed exactly once, LM head homed on
              a live range), then replays a full-feature serve shape —
              prefix cache, swap arena, speculation — under --audit and
              exits nonzero if any schedule or invariant finding fires
              (rule catalog: rust/src/analysis/mod.rs)
  build-model --out model.imx3 [--model tiny|110m] [--scheme S]
  kernels     Fig 5-9 kernel-mapping summary

backend SPEC grammar (run/serve --backend):
  native | pjrt
  imax[:asic[N]|:fpga[N]][:lmm<KB>][:naive|coalesced][:dbuf]
      lanes N in 1..=8 (default fpga2); lmm<KB> sets the per-PE LMM
      capacity in 16..=512 KB (default 64); naive|coalesced selects the
      DMA transfer mode (default coalesced); dbuf models the
      double-buffered LMM prefetch (overlaps each queued kernel's LOAD
      with the previous kernel's EXEC)
  <first>[-<last>]:<spec>,...   heterogeneous placement: inclusive layer
      ranges mapped to per-range backends, e.g.
      --backend \"0-5:imax:fpga2,6-11:native\"; every model layer must be
      covered, the LM head runs with the highest range, and the serve
      report keeps one summed sub-report per backend
";
