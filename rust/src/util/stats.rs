//! Summary statistics used by the bench harness and the experiment reports
//! (the paper reports means of 10 runs and notes stddev < 3% of mean).

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Summary {
        let mut s = Summary::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Coefficient of variation = stddev / mean (the paper's <3% criterion).
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-300 {
            0.0
        } else {
            self.stddev() / self.mean.abs()
        }
    }
}

/// Percentile over a copy of the data (p in [0,100], linear interpolation).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Max relative error between two vectors: max |a-b| / (|b| + eps).
pub fn max_rel_err(a: &[f32], b: &[f32], eps: f32) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs() / (y.abs() + eps))
        .fold(0.0f32, f32::max)
}

/// Max absolute error between two vectors.
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Root-mean-square error.
pub fn rmse(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    (s / a.len() as f64).sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic set is sqrt(32/7).
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.1, 3.0];
        assert!((max_abs_err(&a, &b) - 0.1).abs() < 1e-6);
        assert!(max_rel_err(&a, &a, 1e-8) == 0.0);
        assert!(rmse(&a, &b) > 0.0);
    }

    #[test]
    fn cv_of_constant_is_zero() {
        let s = Summary::from_slice(&[3.0, 3.0, 3.0]);
        assert_eq!(s.cv(), 0.0);
    }
}
