//! Deterministic PRNG (xoshiro256** core) for tests, synthetic weights and
//! workload generation.
//!
//! The paper fixes a seed for all experiments ("A fixed seed was used for
//! all experiments to ensure reproducibility"); we do the same. Not
//! cryptographic.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` (Lemire reduction; n > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (used for synthetic weights so the
    /// quantizers see realistic bell-shaped distributions).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-12 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill a slice with N(0, sigma) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1234);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
