//! A miniature property-testing harness (the vendored crate set has no
//! `proptest`/`quickcheck`).
//!
//! [`Runner`] drives a property over many random cases from a deterministic
//! seed and, on failure, performs greedy shrinking of the failing case via a
//! user-supplied shrink function before panicking with the minimal
//! reproduction.

use crate::util::rng::Rng;

/// Number of cases run per property by default.
pub const DEFAULT_CASES: usize = 256;

/// A property-test runner. Deterministic given the seed.
pub struct Runner {
    rng: Rng,
    cases: usize,
    name: &'static str,
}

impl Runner {
    pub fn new(name: &'static str) -> Runner {
        // Derive the seed from the property name so distinct properties
        // explore distinct streams but remain reproducible run-to-run.
        let seed = name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x100000001b3)
            });
        Runner {
            rng: Rng::new(seed),
            cases: DEFAULT_CASES,
            name,
        }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run `prop` over `cases` random inputs produced by `gen`.
    /// `prop` returns `Err(msg)` to signal failure. On failure, `shrink`
    /// proposes smaller candidates (tried in order, first still-failing one
    /// is recursed into, up to a depth limit).
    pub fn run<T, G, P, S>(mut self, mut gen: G, mut prop: P, mut shrink: S)
    where
        T: Clone + std::fmt::Debug,
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> Result<(), String>,
        S: FnMut(&T) -> Vec<T>,
    {
        for case in 0..self.cases {
            let input = gen(&mut self.rng);
            if let Err(msg) = prop(&input) {
                // Greedy shrink.
                let mut best = input;
                let mut best_msg = msg;
                let mut budget = 1000usize;
                'outer: loop {
                    if budget == 0 {
                        break;
                    }
                    for cand in shrink(&best) {
                        budget -= 1;
                        if let Err(m) = prop(&cand) {
                            best = cand;
                            best_msg = m;
                            continue 'outer;
                        }
                        if budget == 0 {
                            break 'outer;
                        }
                    }
                    break;
                }
                panic!(
                    "property '{}' failed at case {}/{}:\n  input (shrunk): {:?}\n  reason: {}",
                    self.name, case, self.cases, best, best_msg
                );
            }
        }
    }

    /// Convenience for properties with no useful shrinker.
    pub fn run_noshrink<T, G, P>(self, gen: G, prop: P)
    where
        T: Clone + std::fmt::Debug,
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> Result<(), String>,
    {
        self.run(gen, prop, |_| Vec::new());
    }
}

/// Shrinker for a `Vec<f32>`: halve it, zero elements, truncate.
pub fn shrink_f32_vec(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if let Some(i) = v.iter().position(|&x| x != 0.0) {
        let mut z = v.clone();
        z[i] = 0.0;
        out.push(z);
    }
    out
}

/// Shrinker for a usize: binary-search toward 0 / 1.
pub fn shrink_usize(n: &usize) -> Vec<usize> {
    let n = *n;
    let mut out = Vec::new();
    if n > 0 {
        out.push(n / 2);
        out.push(n - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        Runner::new("always-true").cases(50).run_noshrink(
            |r| r.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        // `count` is moved into the closure; re-check via a second runner.
        Runner::new("count-check")
            .cases(1)
            .run_noshrink(|_| 0usize, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        Runner::new("always-false").cases(5).run_noshrink(
            |r| r.below(10),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    #[should_panic(expected = "input (shrunk): 10")]
    fn shrinking_finds_minimal_counterexample() {
        // Property: n < 10. Minimal failing value is 10.
        Runner::new("lt-ten").cases(200).run(
            |r| 10 + r.below(1000),
            |&n| {
                if n < 10 {
                    Ok(())
                } else {
                    Err(format!("{n} >= 10"))
                }
            },
            shrink_usize,
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |tag: &'static str| {
            let mut v = Vec::new();
            Runner::new(tag).cases(10).run_noshrink(
                |r| r.below(1_000_000),
                |&x| {
                    v.push(x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect("same-tag"), collect("same-tag"));
    }
}
