//! IEEE-754 binary16 (half precision) conversion.
//!
//! ggml stores block scale factors (`d`, `dmin`) and FP16 weight tensors as
//! binary16. The `half` crate is not in the vendored set, so we implement
//! the two conversions directly. Round-to-nearest-even on encode, exact on
//! decode (every f16 is representable in f32).

/// A raw IEEE-754 binary16 value (bit pattern).
///
/// Stored as the transparent `u16` bit pattern so quantized blocks can be
/// memcpy'd / serialized without conversion.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    /// Largest finite f16 = 65504.0.
    pub const MAX: F16 = F16(0x7BFF);

    /// Encode an `f32` to the nearest `f16` (round-to-nearest-even),
    /// overflowing to ±inf like hardware F32→F16 converters.
    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    /// Decode to `f32` (exact).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    /// Decode via the process-wide lookup table — the software analogue
    /// of the paper's in-PE LUT conversion (Fig 6). ~4× faster than the
    /// bit-manipulation path on the matvec hot loop.
    #[inline]
    pub fn to_f32_lut(self) -> f32 {
        lut()[self.0 as usize]
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// f32 → f16 bit pattern, round-to-nearest-even, IEEE semantics
/// (subnormal f16 outputs supported, overflow → inf, NaN preserved).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf or NaN. Keep a quiet-NaN payload bit if NaN.
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }

    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        // Overflows f16 range -> inf.
        return sign | 0x7C00;
    }
    if e >= -14 {
        // Normal f16. 13 mantissa bits are dropped; round to nearest even.
        let mant16 = (mant >> 13) as u16;
        let out = sign | (((e + 15) as u16) << 10) | mant16;
        let rem = mant & 0x1FFF;
        let halfway = 0x1000;
        if rem > halfway || (rem == halfway && (mant16 & 1) == 1) {
            // Carry may ripple into the exponent; that is correct
            // (e.g. 0x3BFF + 1 = 0x3C00 encodes rounding up to 1.0).
            return out + 1;
        }
        return out;
    }
    if e >= -25 {
        // Subnormal f16: shift the (implicit-1 restored) mantissa right.
        let mant = mant | 0x0080_0000;
        let shift = (-14 - e) as u32 + 13;
        let mant16 = (mant >> shift) as u16;
        let rem = mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let out = sign | mant16;
        if rem > halfway || (rem == halfway && (mant16 & 1) == 1) {
            return out + 1;
        }
        return out;
    }
    // Underflows to signed zero.
    sign
}

/// Full 64K-entry decode table (256 KiB), built once on first use.
fn lut() -> &'static [f32; 65536] {
    static LUT: std::sync::OnceLock<Box<[f32; 65536]>> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = vec![0.0f32; 65536].into_boxed_slice();
        for (h, slot) in t.iter_mut().enumerate() {
            *slot = f16_bits_to_f32(h as u16);
        }
        t.try_into().unwrap()
    })
}

/// f16 bit pattern → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: value = mant × 2^-24. Normalize into f32: with p
            // the index of the leading set bit, value = 1.m' × 2^(p-24).
            let mut e = -14i32; // becomes p - 24 after the shifts below
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -2.5, 65504.0, 6.1035156e-5] {
            assert_eq!(F16::from_f32(v).to_f32(), v, "roundtrip {v}");
        }
    }

    #[test]
    fn special_values() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::INFINITY).is_infinite());
        assert!(F16::from_f32(1e9).is_infinite(), "overflow to inf");
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(1e-10).to_f32(), 0.0, "underflow to zero");
    }

    #[test]
    fn subnormal_roundtrip() {
        // Smallest positive subnormal f16 = 2^-24.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(F16::from_f32(tiny).0, 1);
        assert_eq!(F16(1).to_f32(), tiny);
        // A mid-range subnormal.
        let v = 3.0 * 2.0f32.powi(-24);
        assert_eq!(F16::from_f32(v).to_f32(), v);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // nearest-even resolves down to 1.0.
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(halfway).to_f32(), 1.0);
        // Slightly above halfway rounds up.
        let above = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20);
        assert_eq!(F16::from_f32(above).to_f32(), 1.0 + 2.0f32.powi(-10));
    }

    #[test]
    fn exhaustive_decode_encode_consistency() {
        // Every finite f16 must survive decode->encode exactly.
        for h in 0u16..=0xFFFF {
            let f = F16(h);
            if f.is_nan() {
                continue;
            }
            let back = F16::from_f32(f.to_f32());
            assert_eq!(back.0, h, "bits 0x{h:04x}");
        }
    }

    #[test]
    fn relative_error_bound() {
        // |x - f16(x)| / |x| <= 2^-11 for x in normal range.
        let mut x = 6.2e-5f32;
        while x < 6.0e4 {
            let err = (F16::from_f32(x).to_f32() - x).abs() / x;
            assert!(err <= 2.0f32.powi(-11), "x={x} err={err}");
            x *= 1.37;
        }
    }
}
