//! Report emitters: aligned text tables (what the benches print), CSV
//! series (what a plotting script would consume to redraw the paper's
//! figures), and JSON-lines records (machine-readable experiment logs).
//!
//! No serde in the vendored set, so the JSON writer is a small escaping
//! emitter sufficient for flat records.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// An aligned, markdown-ish text table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let _ = write!(s, " {:<w$} |", cells[i], w = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// CSV writer for figure series (one file per paper figure).
pub struct Csv {
    buf: String,
    ncol: usize,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        let mut buf = String::new();
        let _ = writeln!(buf, "{}", header.join(","));
        Csv {
            buf,
            ncol: header.len(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.ncol, "csv row width mismatch");
        let escaped: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        let _ = writeln!(self.buf, "{}", escaped.join(","));
        self
    }

    pub fn contents(&self) -> &str {
        &self.buf
    }

    pub fn write_to(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, &self.buf)
    }
}

/// Escape a string for JSON.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// A flat JSON object builder (string/number/bool fields), emitted as one
/// JSON-lines record per experiment data point.
#[derive(Default)]
pub struct JsonRecord {
    fields: Vec<(String, String)>,
}

impl JsonRecord {
    pub fn new() -> JsonRecord {
        JsonRecord::default()
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.fields.push((k.to_string(), format!("\"{}\"", json_escape(v))));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        let repr = if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        };
        self.fields.push((k.to_string(), repr));
        self
    }

    pub fn int(mut self, k: &str, v: i64) -> Self {
        self.fields.push((k.to_string(), format!("{v}")));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.fields.push((k.to_string(), format!("{v}")));
        self
    }

    pub fn render(&self) -> String {
        let inner: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", json_escape(k), v))
            .collect();
        format!("{{{}}}", inner.join(","))
    }
}

/// Append a JSON-lines record to a log file, creating directories as needed.
pub fn append_jsonl(path: impl AsRef<Path>, rec: &JsonRecord) -> io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        fs::create_dir_all(dir)?;
    }
    use io::Write as _;
    let mut f = fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", rec.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| longer | 2.5   |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas_and_quotes() {
        let mut c = Csv::new(&["k", "v"]);
        c.row(&["a,b".to_string(), "say \"hi\"".to_string()]);
        let s = c.contents();
        assert!(s.contains("\"a,b\",\"say \"\"hi\"\"\""));
    }

    #[test]
    fn json_record_escaping() {
        let r = JsonRecord::new()
            .str("k", "line\n\"q\"")
            .num("x", 1.5)
            .int("n", -3)
            .bool("ok", true);
        let s = r.render();
        assert_eq!(
            s,
            "{\"k\":\"line\\n\\\"q\\\"\",\"x\":1.5,\"n\":-3,\"ok\":true}"
        );
    }

    #[test]
    fn json_nonfinite_becomes_null() {
        let s = JsonRecord::new().num("x", f64::NAN).render();
        assert_eq!(s, "{\"x\":null}");
    }
}
