//! Minimal wall-clock bench harness (no `criterion` in the vendored set).
//!
//! Each `rust/benches/*.rs` target is `harness = false` and drives
//! [`BenchSet`]: warmup, fixed-duration measurement, mean/stddev/min report.
//! For the experiment benches (Figs 11–16) the *measured* quantity is the
//! harness runtime; the figures themselves are printed from the simulator's
//! modeled seconds/joules, like the paper's tables.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Re-export for bench bodies.
pub use std::hint::black_box as bb;

/// One micro-benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub iters: u64,
    /// Optional throughput divisor (elements per iter) for elem/s output.
    pub elems_per_iter: Option<f64>,
}

impl Measurement {
    pub fn report_line(&self) -> String {
        let thr = match self.elems_per_iter {
            Some(e) if self.mean_s > 0.0 => {
                format!("  {:>10.3} Melem/s", e / self.mean_s / 1e6)
            }
            _ => String::new(),
        };
        format!(
            "{:<44} {:>12.3} us/iter (+/- {:>8.3})  min {:>12.3} us  n={}{}",
            self.name,
            self.mean_s * 1e6,
            self.stddev_s * 1e6,
            self.min_s * 1e6,
            self.iters,
            thr
        )
    }
}

/// Bench group: runs closures for a target duration each, prints a report.
pub struct BenchSet {
    title: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<Measurement>,
    quick: bool,
}

impl BenchSet {
    pub fn new(title: &str) -> BenchSet {
        // IMAX_BENCH_QUICK=1 shortens runs (used by `make test` smoke).
        let quick = std::env::var("IMAX_BENCH_QUICK").map_or(false, |v| v == "1");
        BenchSet {
            title: title.to_string(),
            warmup: if quick {
                Duration::from_millis(10)
            } else {
                Duration::from_millis(150)
            },
            measure: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(700)
            },
            results: Vec::new(),
            quick,
        }
    }

    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Time `f` repeatedly; `f` should return something to black-box.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &mut Self {
        self.bench_with_elems(name, None, &mut f)
    }

    /// Like [`bench`], reporting throughput as `elems / s`.
    pub fn bench_elems<T>(
        &mut self,
        name: &str,
        elems: f64,
        mut f: impl FnMut() -> T,
    ) -> &mut Self {
        self.bench_with_elems(name, Some(elems), &mut f)
    }

    fn bench_with_elems<T>(
        &mut self,
        name: &str,
        elems: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &mut Self {
        // Warmup + estimate cost of one call.
        let warm_start = Instant::now();
        let mut one = Duration::from_nanos(1);
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls == 0 {
            let t = Instant::now();
            black_box(f());
            one = t.elapsed().max(Duration::from_nanos(1));
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        // Choose a batch size targeting ~1ms per sample.
        let batch = ((Duration::from_millis(1).as_nanos() / one.as_nanos()).max(1)) as u64;

        let mut samples = Summary::new();
        let mut iters = 0u64;
        let meas_start = Instant::now();
        while meas_start.elapsed() < self.measure || samples.count() < 3 {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let per_iter = t.elapsed().as_secs_f64() / batch as f64;
            samples.add(per_iter);
            iters += batch;
            if samples.count() > 10_000 {
                break;
            }
        }
        self.results.push(Measurement {
            name: name.to_string(),
            mean_s: samples.mean(),
            stddev_s: samples.stddev(),
            min_s: samples.min(),
            iters,
            elems_per_iter: elems,
        });
        self
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn report(&self) {
        println!("\n=== bench: {} ===", self.title);
        for m in &self.results {
            println!("{}", m.report_line());
        }
    }
}

/// One named scalar a bench exports to the CI perf trajectory.
#[derive(Clone, Debug)]
pub struct JsonMetric {
    pub name: String,
    pub value: f64,
    /// `"lower"` or `"higher"` — which direction is an improvement.
    pub better: &'static str,
    /// Whether the regression checker should gate on this metric
    /// (deterministic counters / modeled costs: yes; wall-clock: no —
    /// those seed the trajectory informationally).
    pub check: bool,
}

/// Machine-readable bench summary for the CI `bench-smoke` job: metrics
/// collect during the run and, when the `BENCH_JSON` env var names a
/// path, serialize there as
/// `{"bench": .., "metrics": {name: {value, better, check}}}` —
/// `scripts/check_bench_regression.py` merges these files into
/// `BENCH_PR.json` and gates on the committed `BENCH_baseline.json`.
/// (Hand-rolled serialization: the offline vendor set has no serde.)
pub struct JsonMetrics {
    bench: String,
    metrics: Vec<JsonMetric>,
}

impl JsonMetrics {
    pub fn new(bench: &str) -> JsonMetrics {
        JsonMetrics {
            bench: bench.to_string(),
            metrics: Vec::new(),
        }
    }

    /// Record one metric. Non-finite values are clamped to 0 so the
    /// output stays valid JSON.
    pub fn push(&mut self, name: &str, value: f64, better: &'static str, check: bool) {
        assert!(better == "lower" || better == "higher", "better: lower|higher");
        self.metrics.push(JsonMetric {
            name: name.to_string(),
            value: if value.is_finite() { value } else { 0.0 },
            better,
            check,
        });
    }

    pub fn metrics(&self) -> &[JsonMetric] {
        &self.metrics
    }

    fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", self.bench));
        out.push_str("  \"metrics\": {\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let comma = if i + 1 < self.metrics.len() { "," } else { "" };
            out.push_str(&format!(
                "    \"{}\": {{\"value\": {:e}, \"better\": \"{}\", \"check\": {}}}{}\n",
                m.name, m.value, m.better, m.check, comma
            ));
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write the summary to the path named by `BENCH_JSON`, if set.
    /// Returns whether a file was written.
    pub fn write_if_requested(&self) -> std::io::Result<bool> {
        match std::env::var("BENCH_JSON") {
            Ok(path) if !path.is_empty() => {
                std::fs::write(&path, self.to_json())?;
                eprintln!("wrote bench summary to {path}");
                Ok(true)
            }
            _ => Ok(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_times() {
        std::env::set_var("IMAX_BENCH_QUICK", "1");
        let mut set = BenchSet::new("unit");
        set.bench("noop-sum", || (0..100u64).sum::<u64>());
        let m = &set.results()[0];
        assert!(m.mean_s > 0.0);
        assert!(m.min_s > 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn json_metrics_serialize_valid_shape() {
        let mut m = JsonMetrics::new("unit");
        m.push("a", 1.5, "lower", true);
        m.push("b", f64::NAN, "higher", false);
        let s = m.to_json();
        assert!(s.contains("\"bench\": \"unit\""), "{s}");
        assert!(
            s.contains("\"a\": {\"value\": 1.5e0, \"better\": \"lower\", \"check\": true},"),
            "{s}"
        );
        assert!(
            s.contains("\"b\": {\"value\": 0e0, \"better\": \"higher\", \"check\": false}"),
            "{s}"
        );
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("IMAX_BENCH_QUICK", "1");
        let mut set = BenchSet::new("unit");
        set.bench_elems("sum1k", 1000.0, || (0..1000u64).sum::<u64>());
        let line = set.results()[0].report_line();
        assert!(line.contains("Melem/s"), "{line}");
    }
}
