//! Small self-contained utilities shared across the crate.
//!
//! The build is offline (no crates.io access beyond the vendored set), so
//! this module re-implements the handful of primitives we would otherwise
//! pull in: IEEE-754 half-precision conversion ([`f16`]), a fast
//! deterministic PRNG ([`rng`]), summary statistics ([`stats`]), tabular /
//! CSV / JSON-lines report writers ([`report`]), a tiny property-testing
//! harness ([`proptest_lite`]), and a wall-clock bench timer ([`bench`]).

pub mod bench;
pub mod f16;
pub mod proptest_lite;
pub mod report;
pub mod rng;
pub mod stats;

/// Ceiling division for unsigned sizes: `ceil_div(a, b) == ceil(a / b)`.
///
/// Used everywhere block counts are derived from element counts (quant
/// blocks per row, DMA bursts per transfer, LMM tiles per kernel).
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub const fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Human-readable byte size (KiB/MiB/GiB), used by reports.
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_remainder() {
        assert_eq!(ceil_div(32, 32), 1);
        assert_eq!(ceil_div(33, 32), 2);
        assert_eq!(ceil_div(0, 32), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(64 * 1024), "64.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024 * 1024).starts_with("3.00 GiB"));
    }
}
