//! Byte-level BPE tokenizer substrate.
//!
//! The paper's host CPU is responsible for "prompt tokenization" (§III.A);
//! llama.cpp ships the Qwen3 BPE tokenizer inside the GGUF. We have no
//! GGUF, so we implement a self-contained byte-level BPE: the base
//! vocabulary is the 256 bytes plus special tokens, and merges are learned
//! from a seed corpus at model-build time. Functionally equivalent for the
//! system evaluation — tokenization cost sits in the host phase either way.

use std::collections::HashMap;

/// Special token ids.
pub const TOK_BOS: u32 = 0;
pub const TOK_EOS: u32 = 1;
/// First byte token; byte b is token `TOK_BYTE0 + b`.
pub const TOK_BYTE0: u32 = 2;
/// First merge token id.
pub const TOK_MERGE0: u32 = TOK_BYTE0 + 256;

/// A trained byte-level BPE tokenizer.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// merges[i] = (left, right) produced token `TOK_MERGE0 + i`.
    merges: Vec<(u32, u32)>,
    /// Lookup (left, right) -> merged id.
    merge_map: HashMap<(u32, u32), u32>,
    /// Decoded byte string per token id.
    decoded: Vec<Vec<u8>>,
}

impl Tokenizer {
    /// Train a tokenizer with up to `n_merges` merges from a seed corpus.
    pub fn train(corpus: &str, n_merges: usize) -> Tokenizer {
        let mut decoded: Vec<Vec<u8>> = Vec::with_capacity(TOK_MERGE0 as usize + n_merges);
        decoded.push(b"<bos>".to_vec());
        decoded.push(b"<eos>".to_vec());
        for b in 0u16..256 {
            decoded.push(vec![b as u8]);
        }

        let mut seq: Vec<u32> = corpus.bytes().map(|b| TOK_BYTE0 + b as u32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut merge_map = HashMap::new();

        for _ in 0..n_merges {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Deterministic argmax: highest count, ties by smallest pair.
            let best = counts
                .iter()
                .filter(|(_, &c)| c >= 2)
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)));
            let (&pair, _) = match best {
                Some(kv) => kv,
                None => break, // nothing left to merge
            };
            let new_id = TOK_MERGE0 + merges.len() as u32;
            merges.push(pair);
            merge_map.insert(pair, new_id);
            let mut bytes = decoded[pair.0 as usize].clone();
            bytes.extend_from_slice(&decoded[pair.1 as usize]);
            decoded.push(bytes);

            // Apply the merge to the training sequence.
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }

        Tokenizer {
            merges,
            merge_map,
            decoded,
        }
    }

    /// Trivial tokenizer with no merges (pure byte fallback).
    pub fn byte_level() -> Tokenizer {
        Tokenizer::train("", 0)
    }

    /// Vocabulary size (specials + bytes + merges).
    pub fn vocab_size(&self) -> usize {
        self.decoded.len()
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut seq: Vec<u32> = text.bytes().map(|b| TOK_BYTE0 + b as u32).collect();
        // Apply merges in training order (standard BPE inference).
        for (i, &pair) in self.merges.iter().enumerate() {
            let new_id = TOK_MERGE0 + i as u32;
            if seq.len() < 2 {
                break;
            }
            let mut out = Vec::with_capacity(seq.len());
            let mut j = 0;
            while j < seq.len() {
                if j + 1 < seq.len() && (seq[j], seq[j + 1]) == pair {
                    out.push(new_id);
                    j += 2;
                } else {
                    out.push(seq[j]);
                    j += 1;
                }
            }
            seq = out;
        }
        seq
    }

    /// Encode with BOS prepended (llama.cpp-style prompt encoding).
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut v = vec![TOK_BOS];
        v.extend(self.encode(text));
        v
    }

    /// Decode token ids back to text (lossy UTF-8).
    pub fn decode(&self, toks: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in toks {
            if t == TOK_BOS || t == TOK_EOS {
                continue;
            }
            if let Some(d) = self.decoded.get(t as usize) {
                bytes.extend_from_slice(d);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Id of the merged pair, if trained.
    pub fn merged(&self, left: u32, right: u32) -> Option<u32> {
        self.merge_map.get(&(left, right)).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_roundtrip() {
        let t = Tokenizer::byte_level();
        let s = "hello, CGLA! 日本語";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.vocab_size(), 258);
    }

    #[test]
    fn trained_roundtrip_and_compression() {
        let corpus = "the quick brown fox jumps over the lazy dog. the fox. the dog. "
            .repeat(20);
        let t = Tokenizer::train(&corpus, 64);
        assert!(t.vocab_size() > 258, "some merges learned");
        let s = "the quick fox and the lazy dog";
        let enc = t.encode(s);
        assert_eq!(t.decode(&enc), s);
        // BPE must compress text drawn from the training distribution.
        assert!(
            enc.len() < s.len(),
            "compressed {} < raw {}",
            enc.len(),
            s.len()
        );
    }

    #[test]
    fn roundtrip_outside_training_distribution() {
        let t = Tokenizer::train(&"abcabcabc".repeat(50), 16);
        let s = "zzz completely different 123 \u{1F600}";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prepended() {
        let t = Tokenizer::byte_level();
        let e = t.encode_with_bos("x");
        assert_eq!(e[0], TOK_BOS);
        assert_eq!(e.len(), 2);
    }

    #[test]
    fn merge_lookup_consistent() {
        let corpus = "aaaa aaaa aaaa".repeat(10);
        let t = Tokenizer::train(&corpus, 4);
        if let Some(&pair) = t.merges.first() {
            assert_eq!(t.merged(pair.0, pair.1), Some(TOK_MERGE0));
        }
    }

    #[test]
    fn empty_input() {
        let t = Tokenizer::train("some corpus text here", 8);
        assert!(t.encode("").is_empty());
        assert_eq!(t.decode(&[]), "");
    }
}
