//! The paper's power/energy model (§IV.A) and the PDP/EDP metrics.
//!
//! PDP = Latency × Power (total energy, J); EDP = Latency² × Power (J·s).
//! The model is phase-aware, exactly as the paper describes: "This model
//! distinguishes between host-primary processing and phases where the
//! IMAX cores are active", with per-kernel active power from synthesis
//! (Table 1 note: FP16 2.16 W, Q8_0 4.41 W, Q3_K 4.88 W, Q6_K 6.1 W for
//! the 64 KB-LMM configuration) and nominal TDP for commercial platforms.

use crate::coordinator::hybrid::WorkloadRun;
use crate::imax::device::{ImaxDevice, ImaxImpl};
use crate::imax::isa::KernelClass;
use crate::imax::lmm::LmmConfig;

/// Energy/latency/PDP/EDP of one run on one platform.
#[derive(Clone, Copy, Debug)]
pub struct EnergyReport {
    pub latency_s: f64,
    pub energy_j: f64,
    pub mean_power_w: f64,
    pub edp_js: f64,
}

impl EnergyReport {
    pub fn from_phases(phases: &[(f64, f64)]) -> EnergyReport {
        let latency_s: f64 = phases.iter().map(|(t, _)| t).sum();
        let energy_j: f64 = phases.iter().map(|(t, p)| t * p).sum();
        EnergyReport {
            latency_s,
            energy_j,
            mean_power_w: if latency_s > 0.0 {
                energy_j / latency_s
            } else {
                0.0
            },
            edp_js: latency_s * energy_j,
        }
    }

    /// PDP as the paper defines it (= total energy).
    pub fn pdp_j(&self) -> f64 {
        self.energy_j
    }
}

/// Per-kernel active power for an IMAX configuration (W).
///
/// The synthesized Table 1 powers are for the deployed 2-lane, 64 KB-LMM
/// evaluation configuration; scaling to other lane counts / LMM sizes is
/// linear in lanes (paper: "multiplying the power estimated from
/// synthesis by the number of active lanes") and linear in LMM capacity
/// beyond the 64 KB baseline (§V.A).
pub fn kernel_power_w(dev: &ImaxDevice, lmm: &LmmConfig, class: KernelClass) -> f64 {
    let base_2lane = class.asic_power_w(); // Table 1, 2-lane deployment
    let per_lane = base_2lane / 2.0;
    let lmm_delta = lmm.power_delta_vs_64kb_w(); // per lane
    per_lane * dev.lanes as f64 + lmm_delta * dev.lanes as f64 + dev.host.idle_power_w
}

/// Energy for an IMAX workload run, phase-weighted over the per-kernel
/// active times and the host-primary time.
pub fn imax_energy(dev: &ImaxDevice, lmm: &LmmConfig, run: &WorkloadRun) -> EnergyReport {
    match dev.imp {
        ImaxImpl::Asic28 => {
            let at = run.active_time;
            let phases = [
                // Kernel-active phases at the synthesized Table 1 powers.
                (at.fp16, kernel_power_w(dev, lmm, KernelClass::Fp16)),
                (at.q8_0, kernel_power_w(dev, lmm, KernelClass::Q8_0)),
                (at.q6_k, kernel_power_w(dev, lmm, KernelClass::Q6K)),
                (at.q3_k, kernel_power_w(dev, lmm, KernelClass::Q3K)),
                // DMA/PIO transfer phases: memory path + idle cores.
                (at.xfer, dev.host.xfer_power_w),
                // Light host phases (dispatch/staging/sampling).
                (at.host_primary, dev.host.light_power_w),
                // Heavy host phases (host-executed kernels, NEON pegged).
                (at.host_compute, dev.host.active_power_w),
            ];
            EnergyReport::from_phases(&phases)
        }
        ImaxImpl::Fpga => {
            // FPGA prototype: the board draws its Table 1 nominal power
            // regardless of phase (the paper reports FPGA latency but
            // projects energy from the ASIC synthesis).
            let t = run.breakdown.e2e_seconds();
            EnergyReport::from_phases(&[(t, dev.board_power_w)])
        }
    }
}

/// Energy for a platform modeled by nominal TDP over a single phase
/// (the commercial GPU comparison path; see `baseline::gpu`).
pub fn tdp_energy(latency_s: f64, tdp_w: f64) -> EnergyReport {
    EnergyReport::from_phases(&[(latency_s, tdp_w)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hybrid::{simulate, Workload};
    use crate::coordinator::offload::OffloadPolicy;
    use crate::imax::dma::TransferMode;
    use crate::model::config::{ModelConfig, QuantScheme};

    #[test]
    fn pdp_edp_definitions() {
        let r = EnergyReport::from_phases(&[(2.0, 10.0)]);
        assert_eq!(r.pdp_j(), 20.0);
        assert_eq!(r.edp_js, 40.0);
        assert_eq!(r.mean_power_w, 10.0);
    }

    #[test]
    fn kernel_power_matches_table1_at_deployment() {
        let dev = ImaxDevice::asic28(2);
        let lmm = LmmConfig::new(64);
        for class in KernelClass::ALL {
            let p = kernel_power_w(&dev, &lmm, class);
            // Table 1 power + host idle.
            assert!(
                (p - class.asic_power_w() - dev.host.idle_power_w).abs() < 1e-9,
                "{}: {p}",
                class.name()
            );
        }
    }

    #[test]
    fn larger_lmm_draws_more_power() {
        let dev = ImaxDevice::asic28(2).with_lmm_kb(256);
        let p64 = kernel_power_w(&ImaxDevice::asic28(2), &LmmConfig::new(64), KernelClass::Q3K);
        let p256 = kernel_power_w(&dev, &LmmConfig::new(256), KernelClass::Q3K);
        assert!(p256 > p64);
    }

    #[test]
    fn phase_weighted_energy_below_peak() {
        let w = Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q3KS,
            n_in: 32,
            n_out: 16,
        };
        let dev = ImaxDevice::asic28(2);
        let lmm = LmmConfig::new(64);
        let policy = OffloadPolicy::for_workload(&dev, &w.cfg, w.scheme, lmm);
        let run = simulate(&w, &dev, &policy, TransferMode::Coalesced);
        let e = imax_energy(&dev, &lmm, &run);
        // Mean power must sit between host idle and the hungriest kernel.
        assert!(e.mean_power_w > dev.host.idle_power_w);
        assert!(e.mean_power_w < kernel_power_w(&dev, &lmm, KernelClass::Q6K) + 1.0);
        assert!(e.energy_j > 0.0 && e.edp_js > e.energy_j * 0.1);
    }
}
