//! # imax-llm
//!
//! Reproduction of *"Efficient Kernel Mapping and Comprehensive System
//! Evaluation of LLM Acceleration on a CGLA"* (Ando et al., IEEE Access 2025,
//! DOI 10.1109/ACCESS.2025.3636266).
//!
//! The crate provides, from scratch:
//!
//! * [`quant`] — ggml-style block quantization formats (FP16, Q8_0, Q6_K,
//!   Q3_K) with quantize / dequantize / integer dot-product kernels — the
//!   llama.cpp substrate the paper offloads.
//! * [`model`] — a Qwen3-architecture inference engine (GQA + RoPE + RMSNorm
//!   + SwiGLU, KV cache, prefill/decode) that both *runs* tiny real models
//!   and *enumerates* the kernel-call graph of the paper-scale models for
//!   the timing path.
//! * [`imax`] — a cycle-level simulator of the IMAX3 CGLA: linear PE array,
//!   custom ISA (SML8/AD24/SML16/CVT86/CVT53/…), double-buffered LMMs, a DMA
//!   engine with transfer coalescing, and PIO configuration costs.
//! * [`coordinator`] — the paper's hybrid host/accelerator execution model:
//!   offload policy (LMM fit), multi-lane scheduling under a host-throughput
//!   ceiling, per-phase instrumentation (EXEC/LOAD/DRAIN/CONF/REGV/RANGE),
//!   and a batched serving loop.
//! * [`runtime`] — the plan/submit backend layer: kernel launch queues,
//!   the backend registry (`--backend` specs, including heterogeneous
//!   per-layer-range placements), and PJRT execution of AOT-compiled
//!   JAX/Pallas artifacts (HLO text) via the `xla` crate; Python never
//!   runs at request time.
//! * [`power`] / [`baseline`] — the paper's power model (PDP/EDP) and
//!   roofline GPU comparators (RTX 4090, GTX 1080 Ti, Jetson AGX Orin).
//! * [`harness`] — the 54-workload grid and one runner per paper table and
//!   figure (Table 1–2, Fig 11–16, DMA-coalescing ablation).
//! * [`analysis`] — static analysis over all of the above: a plan-time
//!   schedule verifier for recorded launch streams, a cross-subsystem
//!   invariant auditor for the page pool/batcher pair, and the
//!   [`analysis::AuditExec`] wrapper behind `serve --audit` and the
//!   `verify-plan` subcommand.
//!
//! See `DESIGN.md` for the substitution table (FPGA/ASIC/GPUs → simulator +
//! calibrated analytic models) and `EXPERIMENTS.md` for paper-vs-measured.

pub mod analysis;
pub mod baseline;
pub mod coordinator;
pub mod harness;
pub mod imax;
pub mod model;
pub mod power;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
