//! Block quantization formats — the llama.cpp/ggml substrate the paper's
//! kernels operate on (§III.B of the paper).
//!
//! The paper implements four dot-product kernels on IMAX:
//!
//! | kernel | format | bits/weight | block | paper dataflow |
//! |--------|--------|-------------|-------|----------------|
//! | FP16   | [`fp16`] | 16 | — | Fig 6: LUT F16→F32 + SIMD FMA |
//! | Q8_0   | [`q8_0`] | 8.5 | 32 | Figs 5/7: SML8 + AD24 + f32 scale |
//! | Q6_K   | [`q6_k`] | 6.56 | 256 | Fig 8: CVT86 decode + SML16 MAC |
//! | Q3_K   | [`q3_k`] | 3.44 | 256 | Fig 9: CVT53 decode + INT8 MAC |
//!
//! Block layouts follow ggml (`block_q8_0`, `block_q6_K`, `block_q3_K`) so
//! tensor byte sizes — which drive the paper's DMA/LMM analysis — are
//! exact. Activations are quantized per ggml convention: [`q8_0`] rows for
//! Q8_0 weights, [`q8_k`] super-block rows for the K-quants. All integer
//! dot products accumulate in i32 (the paper's hardware uses 24-bit
//! accumulators; i32 is a superset, and per-block sums fit in 24 bits:
//! 32 × 127 × 127 < 2^23).

pub mod fp16;
pub mod q3_k;
pub mod q6_k;
pub mod q8_0;
pub mod q8_k;

use crate::util::ceil_div;

/// Super-block size shared by the K-quants (ggml `QK_K`).
pub const QK_K: usize = 256;

/// Tensor element formats used across the system.
///
/// `GgmlType` mirrors the subset of ggml types the paper maps onto IMAX,
/// plus `F32` for host-side activations.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum GgmlType {
    F32,
    F16,
    Q8_0,
    Q6K,
    Q3K,
}

impl GgmlType {
    /// Elements per quantization block.
    pub const fn block_size(self) -> usize {
        match self {
            GgmlType::F32 | GgmlType::F16 => 1,
            GgmlType::Q8_0 => q8_0::QK8_0,
            GgmlType::Q6K | GgmlType::Q3K => QK_K,
        }
    }

    /// Bytes per quantization block.
    pub const fn block_bytes(self) -> usize {
        match self {
            GgmlType::F32 => 4,
            GgmlType::F16 => 2,
            GgmlType::Q8_0 => q8_0::BLOCK_BYTES,
            GgmlType::Q6K => q6_k::BLOCK_BYTES,
            GgmlType::Q3K => q3_k::BLOCK_BYTES,
        }
    }

    /// Bytes needed to store `n` elements (n must be block-aligned for the
    /// quantized types; callers pad rows to block multiples).
    pub const fn row_bytes(self, n: usize) -> usize {
        ceil_div(n, self.block_size()) * self.block_bytes()
    }

    /// Effective bits per weight (the paper quotes Q3_K_S as a 4.5×
    /// footprint reduction vs FP16; 16 / 3.44 ≈ 4.65 ✓).
    pub fn bits_per_weight(self) -> f64 {
        self.block_bytes() as f64 * 8.0 / self.block_size() as f64
    }

    pub fn name(self) -> &'static str {
        match self {
            GgmlType::F32 => "F32",
            GgmlType::F16 => "FP16",
            GgmlType::Q8_0 => "Q8_0",
            GgmlType::Q6K => "Q6_K",
            GgmlType::Q3K => "Q3_K",
        }
    }
}

/// Quantize an f32 row into `ty` format, returning raw block bytes.
/// `n` must be a multiple of `ty.block_size()`.
pub fn quantize_row(ty: GgmlType, x: &[f32]) -> Vec<u8> {
    match ty {
        GgmlType::F32 => x.iter().flat_map(|v| v.to_le_bytes()).collect(),
        GgmlType::F16 => fp16::quantize_row_f16_bytes(x),
        GgmlType::Q8_0 => q8_0::quantize_row_bytes(x),
        GgmlType::Q6K => q6_k::quantize_row_bytes(x),
        GgmlType::Q3K => q3_k::quantize_row_bytes(x),
    }
}

/// Dequantize raw block bytes back to f32 (`n` elements).
pub fn dequantize_row(ty: GgmlType, bytes: &[u8], n: usize) -> Vec<f32> {
    match ty {
        GgmlType::F32 => bytes
            .chunks_exact(4)
            .take(n)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        GgmlType::F16 => fp16::dequantize_row_f16_bytes(bytes, n),
        GgmlType::Q8_0 => q8_0::dequantize_row_bytes(bytes, n),
        GgmlType::Q6K => q6_k::dequantize_row_bytes(bytes, n),
        GgmlType::Q3K => q3_k::dequantize_row_bytes(bytes, n),
    }
}

/// Worst-case relative RMS quantization error per format, used by tests
/// and by the accuracy notes in EXPERIMENTS.md. Values are loose upper
/// bounds for N(0,1) data validated by the property tests.
pub fn expected_rmse_bound(ty: GgmlType) -> f32 {
    match ty {
        GgmlType::F32 => 0.0,
        GgmlType::F16 => 1e-3,
        GgmlType::Q8_0 => 0.012,
        GgmlType::Q6K => 0.05,
        GgmlType::Q3K => 0.35,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::rmse;

    #[test]
    fn block_geometry_matches_ggml() {
        assert_eq!(GgmlType::Q8_0.block_size(), 32);
        assert_eq!(GgmlType::Q8_0.block_bytes(), 34); // 2 (f16 d) + 32 (i8)
        assert_eq!(GgmlType::Q6K.block_size(), 256);
        assert_eq!(GgmlType::Q6K.block_bytes(), 210); // 128+64+16+2
        assert_eq!(GgmlType::Q3K.block_size(), 256);
        assert_eq!(GgmlType::Q3K.block_bytes(), 110); // 32+64+12+2
    }

    #[test]
    fn bits_per_weight() {
        assert!((GgmlType::Q8_0.bits_per_weight() - 8.5).abs() < 1e-9);
        assert!((GgmlType::Q6K.bits_per_weight() - 6.5625).abs() < 1e-9);
        assert!((GgmlType::Q3K.bits_per_weight() - 3.4375).abs() < 1e-9);
        // Paper §III.B: Q3_K ≈ 4.5× smaller than FP16.
        let ratio = 16.0 / GgmlType::Q3K.bits_per_weight();
        assert!(ratio > 4.4 && ratio < 4.8, "ratio {ratio}");
    }

    #[test]
    fn row_bytes_examples() {
        // A Qwen3-0.6B gate projection row (d_ffn=3072) in each format.
        assert_eq!(GgmlType::F16.row_bytes(3072), 6144);
        assert_eq!(GgmlType::Q8_0.row_bytes(3072), 3072 / 32 * 34);
        assert_eq!(GgmlType::Q6K.row_bytes(3072), 3072 / 256 * 210);
        assert_eq!(GgmlType::Q3K.row_bytes(3072), 3072 / 256 * 110);
    }

    #[test]
    fn roundtrip_rmse_within_bound_all_formats() {
        let mut rng = Rng::new(2025);
        for ty in [GgmlType::F16, GgmlType::Q8_0, GgmlType::Q6K, GgmlType::Q3K] {
            let n = 4 * ty.block_size().max(32);
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x, 1.0);
            let q = quantize_row(ty, &x);
            assert_eq!(q.len(), ty.row_bytes(n));
            let y = dequantize_row(ty, &q, n);
            let scale = x.iter().map(|v| v * v).sum::<f32>().sqrt() / (n as f32).sqrt();
            let e = rmse(&x, &y) / scale;
            assert!(
                e <= expected_rmse_bound(ty),
                "{}: rmse {} > bound {}",
                ty.name(),
                e,
                expected_rmse_bound(ty)
            );
        }
    }

    #[test]
    fn f32_row_roundtrip_exact() {
        let x = [1.5f32, -2.25, 0.0, 1e-20];
        let b = quantize_row(GgmlType::F32, &x);
        assert_eq!(dequantize_row(GgmlType::F32, &b, 4), x);
    }
}
