//! FP16 tensor format and the FP16 dot-product kernel.
//!
//! The paper keeps normalization-layer weights (and uses FP16 as the
//! baseline kernel) in half precision; its Fig 6 dataflow converts incoming
//! FP16 data to FP32 in-line through a per-PE lookup table, then runs
//! 2-way SIMD FMA with column-wise multithreading (22 arithmetic units,
//! 16 elements per burst). Functionally that is: widen to f32, FMA, which
//! is what [`vec_dot_f16`] does.

use crate::util::f16::F16;

/// Quantize a row to raw little-endian f16 bytes.
pub fn quantize_row_f16_bytes(x: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(x.len() * 2);
    for &v in x {
        out.extend_from_slice(&F16::from_f32(v).0.to_le_bytes());
    }
    out
}

/// Dequantize raw little-endian f16 bytes to f32.
pub fn dequantize_row_f16_bytes(bytes: &[u8], n: usize) -> Vec<f32> {
    assert!(bytes.len() >= 2 * n);
    bytes
        .chunks_exact(2)
        .take(n)
        .map(|c| F16(u16::from_le_bytes([c[0], c[1]])).to_f32())
        .collect()
}

/// Encode an f32 slice as an F16 vector.
pub fn encode_row(x: &[f32]) -> Vec<F16> {
    x.iter().map(|&v| F16::from_f32(v)).collect()
}

/// FP16 dot product against f32 activations: widen each weight to f32
/// (the paper's LUT conversion) and FMA. Activations stay f32 on the host
/// path, matching llama.cpp's `ggml_vec_dot_f16` usage for norm weights.
#[inline]
pub fn vec_dot_f16(w: &[F16], a: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    // LUT conversion (the paper's in-PE table, Fig 6) + 4 independent
    // accumulators modelling the 2-way SIMD FMA with column
    // multithreading; also lets LLVM vectorize the gather-multiply.
    let mut acc = [0.0f32; 4];
    let chunks = w.len() / 4;
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += w[i].to_f32_lut() * a[i];
        acc[1] += w[i + 1].to_f32_lut() * a[i + 1];
        acc[2] += w[i + 2].to_f32_lut() * a[i + 2];
        acc[3] += w[i + 3].to_f32_lut() * a[i + 3];
    }
    let mut tail = 0.0f32;
    for i in 4 * chunks..w.len() {
        tail += w[i].to_f32_lut() * a[i];
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// FP16×FP16 dot (both operands half precision), used when activations are
/// also stored compressed (KV-cache reads in some configurations).
#[inline]
pub fn vec_dot_f16_f16(w: &[F16], a: &[F16]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    w.iter()
        .zip(a.iter())
        .map(|(x, y)| x.to_f32() * y.to_f32())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bytes_roundtrip() {
        let x = [1.0f32, -0.5, 3.14159, 65504.0];
        let b = quantize_row_f16_bytes(&x);
        assert_eq!(b.len(), 8);
        let y = dequantize_row_f16_bytes(&b, 4);
        for (xi, yi) in x.iter().zip(&y) {
            assert!((xi - yi).abs() / xi.abs().max(1.0) < 1e-3);
        }
    }

    #[test]
    fn dot_matches_f32_within_half_precision() {
        let mut rng = Rng::new(13);
        let n = 1000;
        let mut w = vec![0.0f32; n];
        let mut a = vec![0.0f32; n];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut a, 1.0);
        let wh = encode_row(&w);
        let got = vec_dot_f16(&wh, &a);
        let want: f32 = w.iter().zip(&a).map(|(x, y)| x * y).sum();
        let scale: f32 = (n as f32).sqrt();
        assert!((got - want).abs() < 2e-3 * scale, "{got} vs {want}");
    }

    #[test]
    fn odd_length_handled() {
        let w = encode_row(&[1.0, 2.0, 3.0]);
        let a = [1.0f32, 1.0, 1.0];
        assert_eq!(vec_dot_f16(&w, &a), 6.0);
    }

    #[test]
    fn f16_f16_dot() {
        let w = encode_row(&[0.5, -2.0]);
        let a = encode_row(&[4.0, 1.0]);
        assert_eq!(vec_dot_f16_f16(&w, &a), 0.0);
    }
}
