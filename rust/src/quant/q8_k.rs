//! Q8_K: 8-bit activation quantization over 256-element super-blocks
//! (ggml `block_q8_K`).
//!
//! Used as the activation-side operand for the K-quant weight kernels
//! (Q6_K, Q3_K): `x[i] = d * q[i]` with one f32 scale per 256 elements,
//! plus cached 16-element sub-block sums (`bsums`) that the integer kernels
//! use to fold constant offsets (e.g. the `-32` in Q6_K) without a second
//! pass. This mirrors llama.cpp, where `quantize_row_q8_K` runs on the CPU
//! before the dot kernel is dispatched — in the paper's system this is part
//! of the host-side work preceding a DMA transfer to IMAX.

use crate::quant::QK_K;

/// Bytes per block when serialized: f32 d + 256 i8 + 16 i16 bsums.
pub const BLOCK_BYTES: usize = 4 + QK_K + 2 * (QK_K / 16);

/// One Q8_K super-block.
#[derive(Clone, Debug)]
pub struct BlockQ8K {
    pub d: f32,
    pub qs: [i8; QK_K],
    /// Sums of each 16-element group of `qs` (i16 is sufficient:
    /// 16 × 127 = 2032).
    pub bsums: [i16; QK_K / 16],
}

impl Default for BlockQ8K {
    fn default() -> Self {
        BlockQ8K {
            d: 0.0,
            qs: [0; QK_K],
            bsums: [0; QK_K / 16],
        }
    }
}

/// Quantize 256 values into one super-block.
pub fn quantize_block(x: &[f32; QK_K]) -> BlockQ8K {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let d = amax / 127.0;
    let id = if d > 0.0 { 1.0 / d } else { 0.0 };
    let mut b = BlockQ8K {
        d,
        ..Default::default()
    };
    for (i, &v) in x.iter().enumerate() {
        b.qs[i] = (v * id).round().clamp(-127.0, 127.0) as i8;
    }
    for (g, chunk) in b.qs.chunks_exact(16).enumerate() {
        b.bsums[g] = chunk.iter().map(|&q| q as i16).sum();
    }
    b
}

/// Quantize a row (length multiple of 256).
pub fn quantize_row(x: &[f32]) -> Vec<BlockQ8K> {
    assert_eq!(x.len() % QK_K, 0, "Q8_K row must be 256-aligned");
    x.chunks_exact(QK_K)
        .map(|c| quantize_block(c.try_into().unwrap()))
        .collect()
}

/// Dequantize back to f32.
pub fn dequantize_row(blocks: &[BlockQ8K], n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    'outer: for b in blocks {
        for &q in &b.qs {
            if out.len() == n {
                break 'outer;
            }
            out.push(b.d * q as f32);
        }
    }
    assert_eq!(out.len(), n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bsums_are_consistent() {
        let mut rng = Rng::new(4);
        let mut x = [0.0f32; QK_K];
        for v in x.iter_mut() {
            *v = rng.normal();
        }
        let b = quantize_block(&x);
        for g in 0..QK_K / 16 {
            let s: i16 = b.qs[g * 16..(g + 1) * 16].iter().map(|&q| q as i16).sum();
            assert_eq!(s, b.bsums[g]);
        }
    }

    #[test]
    fn zero_block() {
        let b = quantize_block(&[0.0; QK_K]);
        assert_eq!(b.d, 0.0);
        assert!(b.qs.iter().all(|&q| q == 0));
        assert!(b.bsums.iter().all(|&s| s == 0));
    }

    #[test]
    fn roundtrip_error_half_step() {
        let mut rng = Rng::new(5);
        let mut x = vec![0.0f32; 2 * QK_K];
        rng.fill_normal(&mut x, 2.0);
        let blocks = quantize_row(&x);
        let y = dequantize_row(&blocks, x.len());
        for (i, (xi, yi)) in x.iter().zip(&y).enumerate() {
            let d = blocks[i / QK_K].d;
            assert!((xi - yi).abs() <= 0.5 * d + 1e-7, "elem {i}");
        }
    }

    #[test]
    #[should_panic(expected = "256-aligned")]
    fn unaligned_row_rejected() {
        quantize_row(&vec![0.0f32; 100]);
    }
}
