//! Q8_0: 8-bit block quantization (ggml `block_q8_0`).
//!
//! 32 elements per block; one f16 scale `d` plus 32 signed int8 values:
//! `x[i] = d * q[i]`. 34 bytes / 32 elements = 8.5 bits per weight.
//!
//! This is the paper's workhorse format ("This kernel constitutes the
//! majority of the operations performed in the Q8_0 models") and the
//! architectural foundation of all its quantized dataflows (Fig 5): the
//! IMAX `OP_SML8` instruction multiplies int8 pairs into 24-bit partial
//! sums, `OP_AD24` aggregates along the PE pipeline, and a single f32
//! multiply applies `d_w * d_a` at the drain stage. The Rust kernel mirrors
//! that exactly: i32 MAC over the block, then one f32 scale per block.

use crate::util::f16::F16;

/// Elements per Q8_0 block (ggml `QK8_0`).
pub const QK8_0: usize = 32;
/// Bytes per block: f16 scale + 32 int8.
pub const BLOCK_BYTES: usize = 2 + QK8_0;

/// One Q8_0 block.
#[derive(Clone, Copy, Debug)]
pub struct BlockQ8_0 {
    pub d: F16,
    pub qs: [i8; QK8_0],
}

impl Default for BlockQ8_0 {
    fn default() -> Self {
        BlockQ8_0 {
            d: F16::ZERO,
            qs: [0; QK8_0],
        }
    }
}

/// Quantize 32 values into one block: `d = max|x| / 127`, `q = round(x/d)`.
pub fn quantize_block(x: &[f32; QK8_0]) -> BlockQ8_0 {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let d = amax / 127.0;
    let id = if d > 0.0 { 1.0 / d } else { 0.0 };
    let mut qs = [0i8; QK8_0];
    for (q, &v) in qs.iter_mut().zip(x.iter()) {
        *q = (v * id).round().clamp(-127.0, 127.0) as i8;
    }
    BlockQ8_0 {
        d: F16::from_f32(d),
        qs,
    }
}

/// Quantize a row (length multiple of 32).
pub fn quantize_row(x: &[f32]) -> Vec<BlockQ8_0> {
    assert_eq!(x.len() % QK8_0, 0, "Q8_0 row must be 32-aligned");
    x.chunks_exact(QK8_0)
        .map(|c| quantize_block(c.try_into().unwrap()))
        .collect()
}

/// Dequantize blocks to f32.
pub fn dequantize_row(blocks: &[BlockQ8_0], n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    'outer: for b in blocks {
        let d = b.d.to_f32();
        for &q in &b.qs {
            if out.len() == n {
                break 'outer;
            }
            out.push(d * q as f32);
        }
    }
    assert_eq!(out.len(), n);
    out
}

/// Integer dot product of a Q8_0 weight row with a Q8_0 activation row —
/// ggml `ggml_vec_dot_q8_0_q8_0`, the computation the paper's Fig 5
/// dataflow implements.
///
/// Per block: `sum_i32(qw[i] * qa[i]) * dw * da`, accumulated in f32.
#[inline]
pub fn vec_dot(w: &[BlockQ8_0], a: &[BlockQ8_0]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        // 32 × 127 × 127 < 2^23: fits the hardware's 24-bit accumulator.
        // Four independent lanes (the paper's 4 replicated dataflows,
        // Fig 5) let LLVM vectorize the int8 MAC chain.
        let mut lanes = [0i32; 4];
        for k in 0..QK8_0 / 4 {
            let i = 4 * k;
            lanes[0] += bw.qs[i] as i32 * ba.qs[i] as i32;
            lanes[1] += bw.qs[i + 1] as i32 * ba.qs[i + 1] as i32;
            lanes[2] += bw.qs[i + 2] as i32 * ba.qs[i + 2] as i32;
            lanes[3] += bw.qs[i + 3] as i32 * ba.qs[i + 3] as i32;
        }
        let isum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        acc += isum as f32 * bw.d.to_f32_lut() * ba.d.to_f32_lut();
    }
    acc
}

/// Serialize blocks to the ggml byte layout (d little-endian f16, then qs).
pub fn to_bytes(blocks: &[BlockQ8_0]) -> Vec<u8> {
    let mut out = Vec::with_capacity(blocks.len() * BLOCK_BYTES);
    for b in blocks {
        out.extend_from_slice(&b.d.0.to_le_bytes());
        out.extend(b.qs.iter().map(|&q| q as u8));
    }
    out
}

/// Parse blocks from the ggml byte layout.
pub fn from_bytes(bytes: &[u8]) -> Vec<BlockQ8_0> {
    assert_eq!(bytes.len() % BLOCK_BYTES, 0);
    bytes
        .chunks_exact(BLOCK_BYTES)
        .map(|c| {
            let d = F16(u16::from_le_bytes([c[0], c[1]]));
            let mut qs = [0i8; QK8_0];
            for (q, &b) in qs.iter_mut().zip(&c[2..]) {
                *q = b as i8;
            }
            BlockQ8_0 { d, qs }
        })
        .collect()
}

pub fn quantize_row_bytes(x: &[f32]) -> Vec<u8> {
    to_bytes(&quantize_row(x))
}

pub fn dequantize_row_bytes(bytes: &[u8], n: usize) -> Vec<f32> {
    dequantize_row(&from_bytes(bytes), n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{shrink_f32_vec, Runner};
    use crate::util::rng::Rng;

    fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn quantize_zero_block() {
        let b = quantize_block(&[0.0; QK8_0]);
        assert_eq!(b.d.to_f32(), 0.0);
        assert!(b.qs.iter().all(|&q| q == 0));
    }

    #[test]
    fn max_value_maps_to_127() {
        let mut x = [0.0f32; QK8_0];
        x[5] = 2.0;
        x[9] = -1.0;
        let b = quantize_block(&x);
        assert_eq!(b.qs[5], 127);
        assert_eq!(b.qs[9], -64); // -1.0 / (2/127) = -63.5 → round half away = -64
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let mut x = [0.0f32; QK8_0];
        for v in x.iter_mut() {
            *v = rng.uniform(-3.0, 3.0);
        }
        let b = quantize_block(&x);
        let y = dequantize_row(&[b], QK8_0);
        // Error ≤ d/2 per element plus the f16 rounding of d itself.
        let d = b.d.to_f32();
        for (xi, yi) in x.iter().zip(&y) {
            assert!((xi - yi).abs() <= d * 0.5 + d * 2.0f32.powi(-10), "{xi} vs {yi}");
        }
    }

    #[test]
    fn bytes_roundtrip_exact() {
        let mut rng = Rng::new(2);
        let mut x = vec![0.0f32; 96];
        rng.fill_normal(&mut x, 1.0);
        let blocks = quantize_row(&x);
        let bytes = to_bytes(&blocks);
        assert_eq!(bytes.len(), 3 * BLOCK_BYTES);
        let parsed = from_bytes(&bytes);
        for (a, b) in blocks.iter().zip(&parsed) {
            assert_eq!(a.d.0, b.d.0);
            assert_eq!(a.qs, b.qs);
        }
    }

    #[test]
    fn vec_dot_matches_dequantized_dot() {
        let mut rng = Rng::new(3);
        let n = 128;
        let mut w = vec![0.0f32; n];
        let mut a = vec![0.0f32; n];
        rng.fill_normal(&mut w, 0.5);
        rng.fill_normal(&mut a, 1.0);
        let wq = quantize_row(&w);
        let aq = quantize_row(&a);
        let got = vec_dot(&wq, &aq);
        let want = dot_f32(&dequantize_row(&wq, n), &dequantize_row(&aq, n));
        assert!(
            (got - want).abs() <= 1e-3 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn prop_dot_close_to_f32_reference() {
        Runner::new("q8_0-dot-vs-f32").cases(64).run(
            |r| {
                let nblocks = 1 + r.below(8);
                let mut v = vec![0.0f32; 2 * nblocks * QK8_0];
                for x in v.iter_mut() {
                    *x = r.normal();
                }
                v
            },
            |v| {
                let n = v.len() / 2;
                if n % QK8_0 != 0 || n == 0 {
                    return Ok(()); // shrinker may produce unaligned; skip
                }
                let (w, a) = v.split_at(n);
                let got = vec_dot(&quantize_row(w), &quantize_row(a));
                let want = dot_f32(w, a);
                // Q8_0 quantization noise: relative tolerance on the product
                // of norms (standard error model for quantized dots).
                let scale: f32 = w.iter().map(|x| x * x).sum::<f32>().sqrt()
                    * a.iter().map(|x| x * x).sum::<f32>().sqrt();
                let tol = 0.02 * scale.max(1.0);
                if (got - want).abs() <= tol {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}, tol {tol}"))
                }
            },
            shrink_f32_vec,
        );
    }

    #[test]
    fn isum_fits_24_bits() {
        // Adversarial block: all ±127 — the paper's 24-bit AD24 accumulator
        // must hold the per-block partial sum.
        let w = BlockQ8_0 {
            d: F16::ONE,
            qs: [127; QK8_0],
        };
        let isum: i32 = w.qs.iter().map(|&q| q as i32 * q as i32).sum();
        assert!(isum < (1 << 23), "isum {isum} must fit signed 24-bit");
    }
}
