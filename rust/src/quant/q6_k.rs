//! Q6_K: 6.5625-bit super-block quantization (ggml `block_q6_K`).
//!
//! 256 elements per super-block, 16 sub-blocks of 16 with signed int8
//! scales and one f16 super-scale:
//!
//! ```text
//! ql[128]   low 4 bits of each 6-bit q
//! qh[64]    high 2 bits of each q
//! scales[16] int8 sub-block scales
//! d         f16 super scale
//! x[i] = d * scales[i/16] * (q[i] - 32),   q in [0, 63]
//! ```
//!
//! 210 bytes / 256 = 6.5625 bpw. The paper's Fig 8 dataflow decodes the
//! packed QL/QH pairs with the custom `CVT86` instruction into 16-bit
//! intermediates and feeds the shared INT8 MAC back-end (`SML16`); our
//! [`vec_dot`] performs the same decode-then-MAC with i32 accumulation and
//! applies `d * d_a` at the end, using the activation `bsums` to fold the
//! constant `-32` offset exactly like llama.cpp's scalar kernel.

use crate::quant::q8_k::BlockQ8K;
use crate::quant::QK_K;
use crate::util::f16::F16;

/// Bytes per super-block: ql(128) + qh(64) + scales(16) + d(2).
pub const BLOCK_BYTES: usize = QK_K / 2 + QK_K / 4 + QK_K / 16 + 2;

/// One Q6_K super-block (ggml memory layout).
#[derive(Clone, Debug)]
pub struct BlockQ6K {
    pub ql: [u8; QK_K / 2],
    pub qh: [u8; QK_K / 4],
    pub scales: [i8; QK_K / 16],
    pub d: F16,
}

impl Default for BlockQ6K {
    fn default() -> Self {
        BlockQ6K {
            ql: [0; QK_K / 2],
            qh: [0; QK_K / 4],
            scales: [0; QK_K / 16],
            d: F16::ZERO,
        }
    }
}

/// Extract the 6-bit code q[i] ∈ [0,63] for element `i` (ggml layout).
///
/// Elements are organized in two 128-halves; within a half, position
/// `l ∈ [0,32)` and quarter `j ∈ {0,1,2,3}`:
/// `q = (ql-bits) | (qh-bits << 4)` — see `dequantize_row_q6_K` in ggml.
#[inline]
pub fn get_q(b: &BlockQ6K, i: usize) -> u8 {
    debug_assert!(i < QK_K);
    let half = i / 128; // 0 or 1
    let r = i % 128;
    let j = r / 32; // quarter within the half
    let l = r % 32;
    let ql_base = half * 64;
    let qh_base = half * 32;
    let low = match j {
        0 => b.ql[ql_base + l] & 0x0F,
        1 => b.ql[ql_base + 32 + l] & 0x0F,
        2 => b.ql[ql_base + l] >> 4,
        _ => b.ql[ql_base + 32 + l] >> 4,
    };
    let high = (b.qh[qh_base + l] >> (2 * j)) & 0x03;
    low | (high << 4)
}

/// Store the 6-bit code for element `i` (inverse of [`get_q`]).
#[inline]
fn set_q(b: &mut BlockQ6K, i: usize, q: u8) {
    debug_assert!(q < 64);
    let half = i / 128;
    let r = i % 128;
    let j = r / 32;
    let l = r % 32;
    let ql_base = half * 64;
    let qh_base = half * 32;
    let low = q & 0x0F;
    let high = (q >> 4) & 0x03;
    match j {
        0 => b.ql[ql_base + l] = (b.ql[ql_base + l] & 0xF0) | low,
        1 => b.ql[ql_base + 32 + l] = (b.ql[ql_base + 32 + l] & 0xF0) | low,
        2 => b.ql[ql_base + l] = (b.ql[ql_base + l] & 0x0F) | (low << 4),
        _ => b.ql[ql_base + 32 + l] = (b.ql[ql_base + 32 + l] & 0x0F) | (low << 4),
    }
    let shift = 2 * j;
    b.qh[qh_base + l] = (b.qh[qh_base + l] & !(0x03 << shift)) | (high << shift);
}

/// Quantize 256 values into one super-block.
///
/// Per sub-block `s`: `a_s = max|x|/31`; super-scale `d = max_s a_s / 127`;
/// `scales[s] = round(a_s/d)`; `q = clamp(round(x / (d*scales[s])) + 32, 0, 63)`.
pub fn quantize_block(x: &[f32; QK_K]) -> BlockQ6K {
    let mut b = BlockQ6K::default();
    let mut sub_amax = [0.0f32; 16];
    for (s, chunk) in x.chunks_exact(16).enumerate() {
        sub_amax[s] = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    }
    let max_a = sub_amax.iter().fold(0.0f32, |m, &v| m.max(v));
    if max_a == 0.0 {
        return b;
    }
    // Effective per-sub scale a_s/31 maps values onto q-32 ∈ [-32, 31].
    let d = max_a / 31.0 / 127.0;
    b.d = F16::from_f32(d);
    let d = b.d.to_f32(); // use the f16-rounded value for encoding
    for s in 0..16 {
        let sc = if d > 0.0 {
            (sub_amax[s] / 31.0 / d).round().clamp(-128.0, 127.0) as i8
        } else {
            0
        };
        b.scales[s] = sc;
        let step = d * sc as f32;
        for l in 0..16 {
            let i = s * 16 + l;
            let q = if step != 0.0 {
                (x[i] / step).round().clamp(-32.0, 31.0) as i32 + 32
            } else {
                32
            };
            set_q(&mut b, i, q as u8);
        }
    }
    b
}

pub fn quantize_row(x: &[f32]) -> Vec<BlockQ6K> {
    assert_eq!(x.len() % QK_K, 0, "Q6_K row must be 256-aligned");
    x.chunks_exact(QK_K)
        .map(|c| quantize_block(c.try_into().unwrap()))
        .collect()
}

/// Dequantize super-blocks to f32.
pub fn dequantize_row(blocks: &[BlockQ6K], n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    'outer: for b in blocks {
        let d = b.d.to_f32();
        for i in 0..QK_K {
            if out.len() == n {
                break 'outer;
            }
            let q = get_q(b, i) as i32 - 32;
            out.push(d * b.scales[i / 16] as f32 * q as f32);
        }
    }
    assert_eq!(out.len(), n);
    out
}

/// Q6_K × Q8_K integer dot product (ggml `ggml_vec_dot_q6_K_q8_K`).
///
/// Accumulates `scales[s] * Σ_l q6[l]*q8[l]` per sub-block in i32, folds
/// the `-32` offset via the activation `bsums`, then applies `d * d_a`.
/// This is exactly the decode→INT8-MAC→scale pipeline of paper Fig 8.
pub fn vec_dot(w: &[BlockQ6K], a: &[BlockQ8K]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        // Block-wise decode (no per-element index math): walk the two
        // 128-halves and the four bit-plane quarters directly, exactly as
        // the CVT86 hardware streams them (Fig 8). Each (half, j, l<16 /
        // l>=16) span maps to one sub-block scale.
        let mut isum = 0i64;
        let mut mins = 0i32;
        for s in 0..16 {
            mins += bw.scales[s] as i32 * ba.bsums[s] as i32;
        }
        for half in 0..2 {
            let ql = &bw.ql[half * 64..half * 64 + 64];
            let qh = &bw.qh[half * 32..half * 32 + 32];
            let qa = &ba.qs[half * 128..half * 128 + 128];
            let sc = &bw.scales[half * 8..half * 8 + 8];
            let mut subs = [0i32; 8]; // per (j, l-half) sub-block sums
            for l in 0..32 {
                let lo_a = ql[l] as i32;
                let lo_b = ql[32 + l] as i32;
                let h = qh[l] as i32;
                let q0 = (lo_a & 0x0F) | ((h & 0x03) << 4);
                let q1 = (lo_b & 0x0F) | (((h >> 2) & 0x03) << 4);
                let q2 = (lo_a >> 4) | (((h >> 4) & 0x03) << 4);
                let q3 = (lo_b >> 4) | (((h >> 6) & 0x03) << 4);
                let g = l >> 4; // 0 or 1: which 16-sub-block within j
                subs[g] += q0 * qa[l] as i32;
                subs[2 + g] += q1 * qa[32 + l] as i32;
                subs[4 + g] += q2 * qa[64 + l] as i32;
                subs[6 + g] += q3 * qa[96 + l] as i32;
            }
            for (j, &sub) in subs.iter().enumerate() {
                isum += (sc[j] as i32 * sub) as i64;
            }
        }
        // x = d*sc*(q-32) ⇒ dot = d*d_a*(Σ sc·q·qa − 32·Σ sc·bsum).
        acc += bw.d.to_f32() * ba.d * (isum - 32 * mins as i64) as f32;
    }
    acc
}

/// Serialize to ggml byte layout: ql, qh, scales, d.
pub fn to_bytes(blocks: &[BlockQ6K]) -> Vec<u8> {
    let mut out = Vec::with_capacity(blocks.len() * BLOCK_BYTES);
    for b in blocks {
        out.extend_from_slice(&b.ql);
        out.extend_from_slice(&b.qh);
        out.extend(b.scales.iter().map(|&s| s as u8));
        out.extend_from_slice(&b.d.0.to_le_bytes());
    }
    out
}

/// Parse from ggml byte layout.
pub fn from_bytes(bytes: &[u8]) -> Vec<BlockQ6K> {
    assert_eq!(bytes.len() % BLOCK_BYTES, 0);
    bytes
        .chunks_exact(BLOCK_BYTES)
        .map(|c| {
            let mut b = BlockQ6K::default();
            b.ql.copy_from_slice(&c[0..128]);
            b.qh.copy_from_slice(&c[128..192]);
            for (s, &v) in b.scales.iter_mut().zip(&c[192..208]) {
                *s = v as i8;
            }
            b.d = F16(u16::from_le_bytes([c[208], c[209]]));
            b
        })
        .collect()
}

pub fn quantize_row_bytes(x: &[f32]) -> Vec<u8> {
    to_bytes(&quantize_row(x))
}

pub fn dequantize_row_bytes(bytes: &[u8], n: usize) -> Vec<f32> {
    dequantize_row(&from_bytes(bytes), n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::q8_k;
    use crate::util::proptest_lite::Runner;
    use crate::util::rng::Rng;

    #[test]
    fn q_codes_roundtrip_all_positions() {
        let mut b = BlockQ6K::default();
        // Write a distinct 6-bit pattern to every position and read back.
        for i in 0..QK_K {
            set_q(&mut b, i, ((i * 37) % 64) as u8);
        }
        for i in 0..QK_K {
            assert_eq!(get_q(&b, i), ((i * 37) % 64) as u8, "pos {i}");
        }
    }

    #[test]
    fn quantize_dequantize_rmse() {
        let mut rng = Rng::new(6);
        let mut x = [0.0f32; QK_K];
        for v in x.iter_mut() {
            *v = rng.normal();
        }
        let b = quantize_block(&x);
        let y = dequantize_row(&[b], QK_K);
        let err = crate::util::stats::rmse(&x, &y);
        assert!(err < 0.05, "rmse {err}");
    }

    #[test]
    fn bytes_roundtrip_exact() {
        let mut rng = Rng::new(7);
        let mut x = vec![0.0f32; 2 * QK_K];
        rng.fill_normal(&mut x, 1.5);
        let blocks = quantize_row(&x);
        let bytes = to_bytes(&blocks);
        assert_eq!(bytes.len(), 2 * BLOCK_BYTES);
        let parsed = from_bytes(&bytes);
        for (p, q) in blocks.iter().zip(&parsed) {
            assert_eq!(p.ql, q.ql);
            assert_eq!(p.qh, q.qh);
            assert_eq!(p.scales, q.scales);
            assert_eq!(p.d.0, q.d.0);
        }
    }

    #[test]
    fn vec_dot_matches_dequantized_reference() {
        let mut rng = Rng::new(8);
        let n = 2 * QK_K;
        let mut w = vec![0.0f32; n];
        let mut a = vec![0.0f32; n];
        rng.fill_normal(&mut w, 0.7);
        rng.fill_normal(&mut a, 1.0);
        let wq = quantize_row(&w);
        let aq = q8_k::quantize_row(&a);
        let got = vec_dot(&wq, &aq);
        // Reference: dot of the two dequantized rows (exact in f64).
        let wd = dequantize_row(&wq, n);
        let ad = q8_k::dequantize_row(&aq, n);
        let want: f64 = wd
            .iter()
            .zip(&ad)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        assert!(
            ((got as f64) - want).abs() < 1e-2 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn prop_vec_dot_tracks_f32_dot() {
        Runner::new("q6k-dot-vs-f32").cases(48).run_noshrink(
            |r| {
                let nb = 1 + r.below(3);
                let mut v = vec![0.0f32; 2 * nb * QK_K];
                for x in v.iter_mut() {
                    *x = r.normal() * 0.8;
                }
                v
            },
            |v| {
                let n = v.len() / 2;
                let (w, a) = v.split_at(n);
                let got = vec_dot(&quantize_row(w), &q8_k::quantize_row(a));
                let want: f32 = w.iter().zip(a).map(|(x, y)| x * y).sum();
                let scale: f32 = w.iter().map(|x| x * x).sum::<f32>().sqrt()
                    * a.iter().map(|x| x * x).sum::<f32>().sqrt();
                let tol = 0.05 * scale.max(1.0);
                if (got - want).abs() <= tol {
                    Ok(())
                } else {
                    Err(format!("got {got} want {want} tol {tol}"))
                }
            },
        );
    }

    #[test]
    fn extreme_values_clamp_not_wrap() {
        let mut x = [0.0f32; QK_K];
        x[0] = 100.0;
        x[1] = -100.0;
        let b = quantize_block(&x);
        let y = dequantize_row(&[b], QK_K);
        assert!(y[0] > 0.0 && y[1] < 0.0);
        assert!((y[0] - 100.0).abs() / 100.0 < 0.05);
    }
}
