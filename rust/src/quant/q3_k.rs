//! Q3_K: 3.4375-bit super-block quantization (ggml `block_q3_K`).
//!
//! 256 elements per super-block, 16 sub-blocks of 16 with 6-bit scales:
//!
//! ```text
//! hmask[32]   1 high bit per element (8 bit-planes over 32 bytes)
//! qs[64]      2 low bits per element
//! scales[12]  16 × 6-bit sub-block scales, ggml packed layout
//! d           f16 super scale
//! x[i] = d * (scales6[i/16] - 32) * q[i],   q in [-4, 3]
//! ```
//!
//! 110 bytes / 256 = 3.4375 bpw — the paper's "4.5× reduction vs FP16"
//! format. Paper Fig 9 decodes the packed 2-bit QL + 1-bit QH with the
//! custom `OP_CVT53` instruction, which *approximates the 6-bit scales to
//! 5 bits* to fit the SIMD datapath; [`vec_dot_cvt53`] models that exact
//! approximation (the paper: "we empirically confirmed that this
//! approximation ... has a negligible impact"), while [`vec_dot`] is the
//! exact llama.cpp-equivalent kernel. Both are exercised by tests and the
//! kernel microbenches.

use crate::quant::q8_k::BlockQ8K;
use crate::quant::QK_K;
use crate::util::f16::F16;

/// Bytes per super-block: hmask(32) + qs(64) + scales(12) + d(2).
pub const BLOCK_BYTES: usize = QK_K / 8 + QK_K / 4 + 12 + 2;

/// One Q3_K super-block (ggml memory layout).
#[derive(Clone, Debug)]
pub struct BlockQ3K {
    pub hmask: [u8; QK_K / 8],
    pub qs: [u8; QK_K / 4],
    pub scales: [u8; 12],
    pub d: F16,
}

impl Default for BlockQ3K {
    fn default() -> Self {
        BlockQ3K {
            hmask: [0; QK_K / 8],
            qs: [0; QK_K / 4],
            scales: [0; 12],
            d: F16::ZERO,
        }
    }
}

/// Unpack the 16 6-bit scales (values in [0, 63]; effective scale is
/// `value - 32`). ggml packing: low nibbles in bytes 0–7, high 2-bit
/// fields in bytes 8–11.
pub fn unpack_scales(scales: &[u8; 12]) -> [i8; 16] {
    let mut sc = [0i8; 16];
    for k in 0..4 {
        sc[k] = ((scales[k] & 0x0F) | ((scales[8 + k] & 0x03) << 4)) as i8;
        sc[4 + k] = ((scales[4 + k] & 0x0F) | (((scales[8 + k] >> 2) & 0x03) << 4)) as i8;
        sc[8 + k] = ((scales[k] >> 4) | (((scales[8 + k] >> 4) & 0x03) << 4)) as i8;
        sc[12 + k] = ((scales[4 + k] >> 4) | (((scales[8 + k] >> 6) & 0x03) << 4)) as i8;
    }
    sc
}

/// Pack 16 6-bit scale codes (each in [0, 63]) into the 12-byte layout.
pub fn pack_scales(sc: &[i8; 16]) -> [u8; 12] {
    let mut out = [0u8; 12];
    for k in 0..4 {
        let (a, b, c, d) = (
            sc[k] as u8 & 0x3F,
            sc[4 + k] as u8 & 0x3F,
            sc[8 + k] as u8 & 0x3F,
            sc[12 + k] as u8 & 0x3F,
        );
        out[k] = (a & 0x0F) | ((c & 0x0F) << 4);
        out[4 + k] = (b & 0x0F) | ((d & 0x0F) << 4);
        out[8 + k] = ((a >> 4) & 0x03)
            | (((b >> 4) & 0x03) << 2)
            | (((c >> 4) & 0x03) << 4)
            | (((d >> 4) & 0x03) << 6);
    }
    out
}

/// Decode element `i` to its signed 3-bit value q ∈ [-4, 3] (ggml layout:
/// low 2 bits from `qs`, the "no high bit ⇒ −4" offset from `hmask`).
#[inline]
pub fn get_q(b: &BlockQ3K, i: usize) -> i32 {
    debug_assert!(i < QK_K);
    let half = i / 128;
    let j = (i % 128) / 32; // 2-bit plane within the half
    let l = i % 32;
    let low = ((b.qs[half * 32 + l] >> (2 * j)) & 0x03) as i32;
    let mbit = 1u8 << (half * 4 + j);
    if b.hmask[l] & mbit != 0 {
        low
    } else {
        low - 4
    }
}

/// Encode signed q ∈ [-4, 3] at element `i` (inverse of [`get_q`]).
#[inline]
fn set_q(b: &mut BlockQ3K, i: usize, q: i32) {
    debug_assert!((-4..=3).contains(&q));
    let biased = (q + 4) as u8; // [0, 7]
    let half = i / 128;
    let j = (i % 128) / 32;
    let l = i % 32;
    let shift = 2 * j;
    let qi = half * 32 + l;
    b.qs[qi] = (b.qs[qi] & !(0x03 << shift)) | ((biased & 0x03) << shift);
    let mbit = 1u8 << (half * 4 + j);
    if biased & 0x04 != 0 {
        b.hmask[l] |= mbit;
    } else {
        b.hmask[l] &= !mbit;
    }
}

/// Quantize 256 values into one super-block.
pub fn quantize_block(x: &[f32; QK_K]) -> BlockQ3K {
    let mut b = BlockQ3K::default();
    let mut sub_amax = [0.0f32; 16];
    for (s, chunk) in x.chunks_exact(16).enumerate() {
        sub_amax[s] = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    }
    let max_a = sub_amax.iter().fold(0.0f32, |m, &v| m.max(v));
    if max_a == 0.0 {
        // All-zero block: scales code 32 (effective 0), q = 0 everywhere.
        b.scales = pack_scales(&[32i8; 16]);
        return b;
    }
    // q spans [-4, 3]; effective scale (code−32) spans [-32, 31].
    let d = max_a / 4.0 / 31.0;
    b.d = F16::from_f32(d);
    let d = b.d.to_f32();
    let mut codes = [32i8; 16];
    for s in 0..16 {
        let eff = if d > 0.0 {
            (sub_amax[s] / 4.0 / d).round().clamp(-32.0, 31.0) as i32
        } else {
            0
        };
        codes[s] = (eff + 32) as i8;
        let step = d * eff as f32;
        for l in 0..16 {
            let i = s * 16 + l;
            let q = if step != 0.0 {
                (x[i] / step).round().clamp(-4.0, 3.0) as i32
            } else {
                0
            };
            set_q(&mut b, i, q);
        }
    }
    b.scales = pack_scales(&codes);
    b
}

pub fn quantize_row(x: &[f32]) -> Vec<BlockQ3K> {
    assert_eq!(x.len() % QK_K, 0, "Q3_K row must be 256-aligned");
    x.chunks_exact(QK_K)
        .map(|c| quantize_block(c.try_into().unwrap()))
        .collect()
}

/// Dequantize super-blocks to f32.
pub fn dequantize_row(blocks: &[BlockQ3K], n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    'outer: for b in blocks {
        let d = b.d.to_f32();
        let sc = unpack_scales(&b.scales);
        for i in 0..QK_K {
            if out.len() == n {
                break 'outer;
            }
            let dl = d * (sc[i / 16] as i32 - 32) as f32;
            out.push(dl * get_q(b, i) as f32);
        }
    }
    assert_eq!(out.len(), n);
    out
}

/// Block-wise Q3_K dot core: per-sub-block integer sums, decoded by
/// bit-plane spans like the CVT53 hardware (no per-element index math).
#[inline]
fn dot_block_subs(bw: &BlockQ3K, ba: &BlockQ8K) -> [i32; 16] {
    let mut subs = [0i32; 16];
    for half in 0..2 {
        let qs = &bw.qs[half * 32..half * 32 + 32];
        let qa = &ba.qs[half * 128..half * 128 + 128];
        let base = half * 8;
        for l in 0..32 {
            let q = qs[l] as i32;
            let hm = bw.hmask[l] as i32 >> (half * 4);
            let g = l >> 4;
            let q0 = (q & 3) - 4 * (1 - (hm & 1));
            let q1 = ((q >> 2) & 3) - 4 * (1 - ((hm >> 1) & 1));
            let q2 = ((q >> 4) & 3) - 4 * (1 - ((hm >> 2) & 1));
            let q3 = (q >> 6) - 4 * (1 - ((hm >> 3) & 1));
            subs[base + g] += q0 * qa[l] as i32;
            subs[base + 2 + g] += q1 * qa[32 + l] as i32;
            subs[base + 4 + g] += q2 * qa[64 + l] as i32;
            subs[base + 6 + g] += q3 * qa[96 + l] as i32;
        }
    }
    subs
}

/// Q3_K × Q8_K integer dot product — exact (llama.cpp-equivalent) kernel.
pub fn vec_dot(w: &[BlockQ3K], a: &[BlockQ8K]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        let sc = unpack_scales(&bw.scales);
        let subs = dot_block_subs(bw, ba);
        let mut isum = 0i64;
        for s in 0..16 {
            isum += ((sc[s] as i32 - 32) * subs[s]) as i64;
        }
        acc += bw.d.to_f32() * ba.d * isum as f32;
    }
    acc
}

/// Truncate a 6-bit scale code to the 5-bit approximation performed by the
/// paper's `OP_CVT53` instruction (drop the LSB of the *effective* scale,
/// keeping sign and range: eff ∈ [-32,31] → even values).
#[inline]
pub fn cvt53_scale(code6: i8) -> i32 {
    let eff = code6 as i32 - 32;
    (eff >> 1) << 1
}

/// Q3_K × Q8_K dot with the paper's CVT53 5-bit scale approximation
/// (paper Fig 9: "approximate conversion of the 6-bit scales to 5-bit and
/// packs the 2-bit and 1-bit segments into a unified 3-bit format").
pub fn vec_dot_cvt53(w: &[BlockQ3K], a: &[BlockQ8K]) -> f32 {
    debug_assert_eq!(w.len(), a.len());
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        let sc = unpack_scales(&bw.scales);
        let subs = dot_block_subs(bw, ba);
        let mut isum = 0i64;
        for s in 0..16 {
            isum += (cvt53_scale(sc[s]) * subs[s]) as i64;
        }
        acc += bw.d.to_f32() * ba.d * isum as f32;
    }
    acc
}

/// Serialize to ggml byte layout: hmask, qs, scales, d.
pub fn to_bytes(blocks: &[BlockQ3K]) -> Vec<u8> {
    let mut out = Vec::with_capacity(blocks.len() * BLOCK_BYTES);
    for b in blocks {
        out.extend_from_slice(&b.hmask);
        out.extend_from_slice(&b.qs);
        out.extend_from_slice(&b.scales);
        out.extend_from_slice(&b.d.0.to_le_bytes());
    }
    out
}

/// Parse from ggml byte layout.
pub fn from_bytes(bytes: &[u8]) -> Vec<BlockQ3K> {
    assert_eq!(bytes.len() % BLOCK_BYTES, 0);
    bytes
        .chunks_exact(BLOCK_BYTES)
        .map(|c| {
            let mut b = BlockQ3K::default();
            b.hmask.copy_from_slice(&c[0..32]);
            b.qs.copy_from_slice(&c[32..96]);
            b.scales.copy_from_slice(&c[96..108]);
            b.d = F16(u16::from_le_bytes([c[108], c[109]]));
            b
        })
        .collect()
}

pub fn quantize_row_bytes(x: &[f32]) -> Vec<u8> {
    to_bytes(&quantize_row(x))
}

pub fn dequantize_row_bytes(bytes: &[u8], n: usize) -> Vec<f32> {
    dequantize_row(&from_bytes(bytes), n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::q8_k;
    use crate::util::proptest_lite::Runner;
    use crate::util::rng::Rng;

    #[test]
    fn scale_pack_unpack_roundtrip_all_codes() {
        // Every 6-bit code in every slot.
        for base in 0..64i8 {
            let mut sc = [0i8; 16];
            for (s, v) in sc.iter_mut().enumerate() {
                *v = ((base as usize + s * 7) % 64) as i8;
            }
            let packed = pack_scales(&sc);
            assert_eq!(unpack_scales(&packed), sc);
        }
    }

    #[test]
    fn q_codes_roundtrip_all_positions() {
        let mut b = BlockQ3K::default();
        for i in 0..QK_K {
            set_q(&mut b, i, (i as i32 % 8) - 4);
        }
        for i in 0..QK_K {
            assert_eq!(get_q(&b, i), (i as i32 % 8) - 4, "pos {i}");
        }
    }

    #[test]
    fn quantize_dequantize_rmse() {
        let mut rng = Rng::new(9);
        let mut x = [0.0f32; QK_K];
        for v in x.iter_mut() {
            *v = rng.normal();
        }
        let b = quantize_block(&x);
        let y = dequantize_row(&[b], QK_K);
        let err = crate::util::stats::rmse(&x, &y);
        // 3-bit quantization: coarse, but bounded.
        assert!(err < 0.35, "rmse {err}");
    }

    #[test]
    fn zero_block_roundtrip() {
        let b = quantize_block(&[0.0; QK_K]);
        let y = dequantize_row(&[b], QK_K);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bytes_roundtrip_exact() {
        let mut rng = Rng::new(10);
        let mut x = vec![0.0f32; 3 * QK_K];
        rng.fill_normal(&mut x, 1.0);
        let blocks = quantize_row(&x);
        let parsed = from_bytes(&to_bytes(&blocks));
        for (p, q) in blocks.iter().zip(&parsed) {
            assert_eq!(p.hmask, q.hmask);
            assert_eq!(p.qs, q.qs);
            assert_eq!(p.scales, q.scales);
            assert_eq!(p.d.0, q.d.0);
        }
    }

    #[test]
    fn vec_dot_matches_dequantized_reference() {
        let mut rng = Rng::new(11);
        let n = 2 * QK_K;
        let mut w = vec![0.0f32; n];
        let mut a = vec![0.0f32; n];
        rng.fill_normal(&mut w, 0.6);
        rng.fill_normal(&mut a, 1.0);
        let wq = quantize_row(&w);
        let aq = q8_k::quantize_row(&a);
        let got = vec_dot(&wq, &aq);
        let wd = dequantize_row(&wq, n);
        let ad = q8_k::dequantize_row(&aq, n);
        let want: f64 = wd
            .iter()
            .zip(&ad)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        assert!(
            ((got as f64) - want).abs() < 1e-2 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn cvt53_approximation_is_negligible() {
        // The paper: the 5-bit scale approximation "has a negligible impact
        // on the final computational accuracy". Quantify: relative deviation
        // between exact and CVT53 dot stays within a few percent of the
        // norm product.
        let mut rng = Rng::new(12);
        let n = 4 * QK_K;
        let mut w = vec![0.0f32; n];
        let mut a = vec![0.0f32; n];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut a, 1.0);
        let wq = quantize_row(&w);
        let aq = q8_k::quantize_row(&a);
        let exact = vec_dot(&wq, &aq);
        let approx = vec_dot_cvt53(&wq, &aq);
        let scale: f32 = w.iter().map(|x| x * x).sum::<f32>().sqrt()
            * a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(
            (exact - approx).abs() / scale < 0.05,
            "exact {exact} approx {approx}"
        );
    }

    #[test]
    fn cvt53_scale_properties() {
        for code in 0..64i8 {
            let eff = code as i32 - 32;
            let approx = cvt53_scale(code);
            assert!((approx - eff).abs() <= 1, "code {code}");
            assert_eq!(approx % 2, 0, "5-bit scale is even");
            assert!((-32..=31).contains(&approx));
        }
    }

    #[test]
    fn prop_roundtrip_per_subblock_bound() {
        // |x - dq(q(x))| <= 0.5 * |d*eff| + f16 slack, per element.
        Runner::new("q3k-elementwise-bound").cases(32).run_noshrink(
            |r| {
                let mut x = vec![0.0f32; QK_K];
                for v in x.iter_mut() {
                    *v = r.normal() * r.uniform(0.1, 3.0);
                }
                x
            },
            |x| {
                let arr: &[f32; QK_K] = x.as_slice().try_into().unwrap();
                let b = quantize_block(arr);
                let y = dequantize_row(&[b.clone()], QK_K);
                let sc = unpack_scales(&b.scales);
                let d = b.d.to_f32();
                for i in 0..QK_K {
                    let step = (d * (sc[i / 16] as i32 - 32) as f32).abs();
                    // Values that saturate q = ±4/3 can exceed half-step;
                    // allow 4.5 steps of slack at saturation.
                    let tol = 0.55 * step + 4.0 * step * 0.0 + 1e-6
                        + if x[i].abs() >= 3.0 * step { 4.5 * step } else { 0.0 };
                    if (x[i] - y[i]).abs() > tol {
                        return Err(format!(
                            "elem {i}: x={} y={} step={step}",
                            x[i], y[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
