//! Plan-time schedule verification.
//!
//! [`verify_schedule`] statically checks a recorded launch stream — the
//! exact data a queueing backend drains from
//! [`crate::runtime::queue::LaunchQueue`] — against the engine's
//! dependency contract. The key rule is `schedule/submit-hazard`: the
//! dbuf LOAD/EXEC overlap model (PR 3) prefetches kernel *k*'s operands
//! under kernel *k−1*'s EXEC **within one submission batch**, so a
//! submit boundary missing between two host-dependent kernels would let
//! the model overlap across a true RAW dependency. The engine's
//! dependency chain per layer partitions kernels into host-dependency
//! groups — q/k/v (host QK-norm/RoPE/cache-store follows), attention +
//! o_proj (device-chained, one group), gate/up (host SwiGLU follows),
//! down (host residual add follows), LM head — and a legal submission
//! batch stays inside one group of one layer.

use crate::analysis::Finding;
use crate::model::config::LinearKind;
use crate::model::graph::{OpKind, Phase};
use crate::runtime::backend::PlacementSpec;
use crate::runtime::queue::{KernelOp, Launch};

/// Host-dependency group of a kernel inside one layer's chain. Kernels
/// in different groups are separated by host work (a RAW dependency the
/// backend cannot see), so they must never share a submission batch.
/// The group index doubles as the dependency-chain stage: within a
/// layer, groups must appear in ascending order.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Group {
    /// q/k/v projections (host applies QK-norm, RoPE, cache store next).
    Qkv,
    /// Attention score/mix + o_proj: device-chained, no host boundary.
    Attn,
    /// FFN gate and up (host applies SwiGLU next).
    GateUp,
    /// FFN down (host applies the residual add next).
    Down,
    /// LM head (layer `None`; host samples from the logits next).
    LmHead,
}

impl Group {
    fn name(self) -> &'static str {
        match self {
            Group::Qkv => "qkv",
            Group::Attn => "attn",
            Group::GateUp => "gate/up",
            Group::Down => "down",
            Group::LmHead => "lm_head",
        }
    }

    fn of(op: &KernelOp) -> Option<Group> {
        let kind = match op {
            KernelOp::Linear { op, .. } | KernelOp::Attn { op } => op.kind,
            _ => return None,
        };
        Some(match kind {
            OpKind::Linear(LinearKind::QProj | LinearKind::KProj | LinearKind::VProj) => Group::Qkv,
            OpKind::AttnScore | OpKind::AttnMix | OpKind::Linear(LinearKind::OProj) => Group::Attn,
            OpKind::Linear(LinearKind::FfnGate | LinearKind::FfnUp) => Group::GateUp,
            OpKind::Linear(LinearKind::FfnDown) => Group::Down,
            OpKind::Linear(LinearKind::LmHead) => Group::LmHead,
        })
    }
}

fn describe(op: &KernelOp) -> String {
    match op {
        KernelOp::Linear { op, batch } => {
            format!("{}[layer {:?}, batch {batch}]", op.kind.name(), op.layer)
        }
        KernelOp::Attn { op } => format!("{}[layer {:?}]", op.kind.name(), op.layer),
        KernelOp::BeginStep { phase, pos } => format!("BeginStep[{}, pos {pos}]", phase.name()),
        KernelOp::EndStep { phase, pos } => format!("EndStep[{}, pos {pos}]", phase.name()),
    }
}

/// Statically verify a recorded launch stream (one or more complete
/// forward steps in record order). Returns every violation found; an
/// empty vector certifies the stream against the full schedule rule set
/// (`schedule/*` in the [module catalog](crate::analysis)).
pub fn verify_schedule<P>(stream: &[Launch<P>]) -> Vec<Finding> {
    let mut findings = Vec::new();

    // --- schedule/step-markers + schedule/op-outside-step ---
    let mut open: Option<(Phase, usize)> = None;
    for l in stream {
        match &l.op {
            KernelOp::BeginStep { phase, pos } => {
                if let Some((p, q)) = open {
                    findings.push(Finding::error(
                        "schedule/step-markers",
                        format!(
                            "seq {}: BeginStep[{}, pos {pos}] nests inside the \
                             unclosed step [{}, pos {q}]",
                            l.seq,
                            phase.name(),
                            p.name()
                        ),
                    ));
                }
                open = Some((*phase, *pos));
            }
            KernelOp::EndStep { phase, pos } => match open.take() {
                None => findings.push(Finding::error(
                    "schedule/step-markers",
                    format!("seq {}: EndStep[{}, pos {pos}] without a BeginStep", l.seq, phase.name()),
                )),
                Some((p, q)) => {
                    // A ubatch step spans `pos..pos+n`: BeginStep carries
                    // the base position, EndStep the last. End < begin or
                    // a phase flip mid-step is inconsistent.
                    if p != *phase || *pos < q {
                        findings.push(Finding::error(
                            "schedule/step-markers",
                            format!(
                                "seq {}: EndStep[{}, pos {pos}] closes BeginStep[{}, pos {q}]",
                                l.seq,
                                phase.name(),
                                p.name()
                            ),
                        ));
                    }
                }
            },
            op if op.is_kernel() && open.is_none() => {
                findings.push(Finding::error(
                    "schedule/op-outside-step",
                    format!("seq {}: {} recorded outside any step", l.seq, describe(op)),
                ));
            }
            _ => {}
        }
    }
    if let Some((p, q)) = open {
        findings.push(Finding::error(
            "schedule/step-markers",
            format!("stream ends inside the unclosed step [{}, pos {q}]", p.name()),
        ));
    }

    // --- schedule/op-order: per-step layer monotonicity + per-layer
    // group chain + LM head last ---
    let mut cur: Option<(Option<usize>, Group)> = None; // (layer, group) of the previous kernel
    for l in stream {
        if matches!(l.op, KernelOp::BeginStep { .. }) {
            cur = None;
            continue;
        }
        let Some(group) = Group::of(&l.op) else { continue };
        let layer = l.op.layer();
        if let Some((prev_layer, prev_group)) = cur {
            let ok = match (prev_layer, layer) {
                // Same layer: the chain may only advance (or stay —
                // attention records one score+mix pair per ubatch token).
                (Some(a), Some(b)) if a == b => group >= prev_group,
                // New layer: strictly ascending, restarting at qkv.
                (Some(a), Some(b)) => b > a && group == Group::Qkv,
                // LM head (layer None) terminates the chain.
                (Some(_), None) => group == Group::LmHead,
                // Nothing may follow the LM head within a step.
                (None, _) => false,
            };
            if !ok {
                findings.push(Finding::error(
                    "schedule/op-order",
                    format!(
                        "seq {}: {} breaks the dependency chain after {}[layer {:?}]",
                        l.seq,
                        describe(&l.op),
                        prev_group.name(),
                        prev_layer
                    ),
                ));
            }
        } else if layer.is_some() && group != Group::Qkv {
            findings.push(Finding::error(
                "schedule/op-order",
                format!("seq {}: step enters layer {:?} at {} (expected qkv)", l.seq, layer, group.name()),
            ));
        }
        cur = Some((layer, group));
    }

    // --- schedule/submit-hazard + schedule/batch-legality: walk
    // submission batches ---
    let mut i = 0usize;
    while i < stream.len() {
        let sub = stream[i].submission;
        let mut j = i;
        while j < stream.len() && stream[j].submission == sub {
            j += 1;
        }
        let batch = &stream[i..j];
        let mut ident: Option<(Option<usize>, Group)> = None;
        let mut width: Option<usize> = None;
        for l in batch {
            if let KernelOp::Linear { batch: b, .. } = &l.op {
                if *b == 0 {
                    findings.push(Finding::error(
                        "schedule/batch-legality",
                        format!("seq {}: {} records an empty ubatch", l.seq, describe(&l.op)),
                    ));
                } else if *width.get_or_insert(*b) != *b {
                    findings.push(Finding::error(
                        "schedule/batch-legality",
                        format!(
                            "submission {sub}: mixed ubatch widths {} and {b} in one batch",
                            width.unwrap_or(0)
                        ),
                    ));
                }
            }
            let Some(group) = Group::of(&l.op) else { continue };
            let id = (l.op.layer(), group);
            if let Some(prev) = ident {
                if prev != id {
                    findings.push(Finding::error(
                        "schedule/submit-hazard",
                        format!(
                            "submission {sub}: {} shares a batch with {}[layer {:?}] — the \
                             LOAD/EXEC overlap window would span a host (RAW) dependency; \
                             a submit boundary is missing between them",
                            describe(&l.op),
                            prev.1.name(),
                            prev.0
                        ),
                    ));
                    // Report each illegal batch once.
                    break;
                }
            }
            ident = Some(id);
        }
        i = j;
    }

    // --- schedule/seq-order ---
    for w in stream.windows(2) {
        if w[1].seq <= w[0].seq {
            findings.push(Finding::error(
                "schedule/seq-order",
                format!("seq {} follows seq {} (record order lost)", w[1].seq, w[0].seq),
            ));
        }
        if w[1].submission < w[0].submission {
            findings.push(Finding::error(
                "schedule/seq-order",
                format!(
                    "submission {} follows submission {} (flush order lost)",
                    w[1].submission, w[0].submission
                ),
            ));
        }
    }

    findings
}

/// Verify a placement against a model depth: every layer `0..n_layers`
/// routed exactly once (`placement/gap`, `placement/overlap`) and the
/// LM-head home — the part owning the highest range, where
/// `PlacementExec` routes `layer: None` kernels — owning the model's
/// final layer (`placement/lm-head`).
pub fn verify_placement(spec: &PlacementSpec, n_layers: usize) -> Vec<Finding> {
    let mut findings = Vec::new();
    if n_layers == 0 {
        return findings;
    }
    let mut owners = vec![0usize; n_layers];
    for r in &spec.rules {
        for l in r.first..=r.last.min(n_layers - 1) {
            owners[l] += 1;
        }
    }
    for (l, &n) in owners.iter().enumerate() {
        if n == 0 {
            findings.push(Finding::error(
                "placement/gap",
                format!("layer {l} is not covered by any placement rule"),
            ));
        } else if n > 1 {
            findings.push(Finding::error(
                "placement/overlap",
                format!("layer {l} is covered by {n} placement rules"),
            ));
        }
    }
    if n_layers > 0 {
        match spec.rules.iter().max_by_key(|r| r.last) {
            None => findings.push(Finding::error(
                "placement/lm-head",
                "empty placement: the LM head has no home part".to_string(),
            )),
            Some(home) if !(home.first <= n_layers - 1 && n_layers - 1 <= home.last) => {
                findings.push(Finding::error(
                    "placement/lm-head",
                    format!(
                        "the LM-head home range {}-{} does not own the final layer {} — \
                         logits would run on a part serving no live layer",
                        home.first,
                        home.last,
                        n_layers - 1
                    ),
                ));
            }
            Some(_) => {}
        }
    }
    findings
}
