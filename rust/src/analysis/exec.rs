//! A backend wrapper that records and verifies every submission.
//!
//! [`AuditExec`] sits between the engine and any
//! [`KernelExec`] backend: every kernel/marker call is forwarded
//! unchanged (bit-identical execution) and mirrored into a private
//! [`LaunchQueue`], drained at the same submit points the inner backend
//! sees. At each `EndStep` the completed step's launch stream runs
//! through [`verify_schedule`](crate::analysis::verify_schedule), so an
//! engine change that misplaces a submit boundary or reorders a
//! dependency chain surfaces as a typed finding on the very step that
//! produced it — this is what `serve --audit` and the `verify-plan`
//! subcommand run under.

use crate::analysis::{verify_schedule, Finding};
use crate::model::engine::{KernelExec, MatvecExec, RoundBalance};
use crate::model::graph::{KvSwapDir, MatvecOp, Phase};
use crate::runtime::queue::{KernelOp, Launch, LaunchQueue};
use crate::tensor::{ActQuant, QTensor};

/// Records every launch the engine plans and statically verifies each
/// completed step. `enabled: false` is a pure passthrough (no recording,
/// no verification), so one serve code path serves both modes.
pub struct AuditExec<E> {
    inner: E,
    enabled: bool,
    queue: LaunchQueue<()>,
    /// The current step's drained launch stream (markers included).
    step: Vec<Launch<()>>,
    findings: Vec<Finding>,
    steps_verified: u64,
}

impl<E: KernelExec> AuditExec<E> {
    pub fn new(inner: E, enabled: bool) -> AuditExec<E> {
        AuditExec {
            inner,
            enabled,
            queue: LaunchQueue::new(),
            step: Vec::new(),
            findings: Vec::new(),
            steps_verified: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The wrapped backend (reporting still comes from the inner exec).
    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Findings accumulated so far (empty on a clean run).
    pub fn findings(&self) -> &[Finding] {
        &self.findings
    }

    pub fn take_findings(&mut self) -> Vec<Finding> {
        std::mem::take(&mut self.findings)
    }

    /// Completed steps that went through schedule verification.
    pub fn steps_verified(&self) -> u64 {
        self.steps_verified
    }

    fn record(&mut self, op: KernelOp) {
        if self.enabled {
            self.queue.record(op, ());
        }
    }

    fn drain(&mut self) {
        if self.enabled {
            self.step.extend(self.queue.submit());
        }
    }
}

impl<E: KernelExec> MatvecExec for AuditExec<E> {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.record(KernelOp::Linear { op: op.clone(), batch: 1 });
        self.inner.linear(op, w, act, out);
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        self.record(KernelOp::Linear { op: op.clone(), batch: acts.len() });
        self.inner.linear_ubatch(op, w, acts, outs);
    }

    fn attn(&mut self, op: &MatvecOp) {
        self.record(KernelOp::Attn { op: op.clone() });
        self.inner.attn(op);
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        self.record(KernelOp::BeginStep { phase, pos });
        self.inner.begin_step(phase, pos);
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        self.record(KernelOp::EndStep { phase, pos });
        self.inner.end_step(phase, pos);
        // A step boundary is an implicit flush (the instrumented backend
        // settles its batch here too): drain, verify the completed step,
        // and reset so memory stays bounded by one step's launches.
        if self.enabled {
            self.drain();
            self.findings.extend(verify_schedule(&self.step));
            self.steps_verified += 1;
            self.step.clear();
        }
    }

    fn kv_transfer(&mut self, phase: Phase, dir: KvSwapDir, bytes: usize) {
        self.inner.kv_transfer(phase, dir, bytes);
    }
}

impl<E: KernelExec> KernelExec for AuditExec<E> {
    fn submit(&mut self) {
        self.drain();
        self.inner.submit();
    }

    fn sync(&mut self) {
        self.drain();
        self.inner.sync();
    }

    fn round_boundary(&mut self) {
        self.inner.round_boundary();
    }

    fn last_round_balance(&self) -> Option<RoundBalance> {
        self.inner.last_round_balance()
    }
}
