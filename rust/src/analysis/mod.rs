//! Static analysis: plan-time schedule verification and cross-subsystem
//! invariant auditing.
//!
//! PRs 3–7 stacked double-buffered overlap modeling, prefix sharing,
//! speculative rollback, and mid-decode cancellation on the same two
//! state machines — the plan/submit [`crate::runtime::queue::LaunchQueue`]
//! and the refcounted CoW page pool. The invariants that keep them
//! correct were enforced only by scattered `assert!`s and per-feature
//! tests. This subsystem makes them *checkable as data*: a recorded
//! kernel stream or a live engine/batcher pair goes in, a list of typed
//! [`Finding`]s comes out, and a clean run proves the whole invariant
//! set at once.
//!
//! Three entry points:
//!
//! - [`verify_schedule`] statically checks a recorded launch stream
//!   (structure, dependency order, submit placement, batch legality) —
//!   see [`schedule`].
//! - [`audit`] proves the pool/batcher cross-subsystem invariants on a
//!   live engine (refcounts, free list, aliasing, budgets, chain
//!   hashes) by snapshotting state into a [`PoolSnapshot`] and running
//!   the pure [`audit_snapshot`] over it.
//! - [`AuditExec`] wraps any [`crate::model::engine::KernelExec`] and
//!   runs [`verify_schedule`] over every completed step transparently
//!   (`serve --audit`, the `verify-plan` CLI subcommand).
//!
//! # Rule catalog
//!
//! Every finding carries one of these stable rule IDs. Schedule rules
//! (from [`verify_schedule`] / [`verify_placement`]):
//!
//! - `schedule/step-markers` — `BeginStep`/`EndStep` markers are
//!   balanced, non-nested, and paired with identical `Phase`/`pos`.
//! - `schedule/op-outside-step` — every kernel launch falls between a
//!   `BeginStep` and its `EndStep`.
//! - `schedule/op-order` — within a step, per-layer kernels follow the
//!   dependency chain qkv → attention → o_proj → gate/up → down, layers
//!   run in ascending order, and the LM head runs last.
//! - `schedule/submit-hazard` — a submission batch (the window the dbuf
//!   LOAD/EXEC overlap model may prefetch across) never spans a true RAW
//!   dependency: one layer, one host-dependency group per batch.
//! - `schedule/batch-legality` — every linear records a positive ubatch
//!   width and one submission batch keeps a uniform width.
//! - `schedule/seq-order` — launch `seq` numbers are strictly
//!   increasing and `submission` indices non-decreasing (FIFO replay
//!   order is intact).
//! - `placement/gap` — every model layer is covered by a placement rule.
//! - `placement/overlap` — no two placement rules claim the same layer.
//! - `placement/lm-head` — the LM-head home (the part owning the
//!   highest range) owns at least one live layer, and that range
//!   includes the model's final layer.
//!
//! Audit rules (from [`audit`] / [`audit_snapshot`]):
//!
//! - `audit/refcount-conservation` — every page's refcount equals its
//!   block-table entries plus one for a resident prefix-index entry.
//! - `audit/free-consistency` — the free list holds no duplicates and a
//!   page is on it exactly when its refcount is zero.
//! - `audit/alias-validity` — every block-table entry and resident
//!   prefix entry points at a valid, referenced (non-free) page.
//! - `audit/length-coverage` — each slot's block table holds exactly
//!   the pages its token length needs, and lengths fit the context
//!   window.
//! - `audit/budget-conservation` — the batcher's cached committed-page
//!   count equals the live set's recomputed exact distinct demand.
//! - `audit/chain-integrity` — every prefix-index entry's stored key
//!   re-hashes from its parent and token span, spans are exactly one
//!   page, and an entry is swapped exactly when the host arena holds
//!   its bytes.
//! - `audit/encoding-consistency` — the pool's host-side backing and
//!   every swap-arena page's payload are sized exactly by the pool's
//!   [`crate::model::kv_cache::KvScheme`], re-derived from the page
//!   geometry alone: f16 pools carry f32 storage and swap the lossless
//!   mirror; q8_0 pools carry canonical block bytes (plus the
//!   dequantized mirror) and swap only blocks. Prefix-chain keys hash
//!   token ids, never page bytes, so `audit/chain-integrity` stays
//!   scheme-independent and warm hits behave identically under either
//!   encoding.
//!
//! Mutation property tests in `rust/tests/analysis_rules.rs` prove each
//! rule fires on a seeded corruption; the serve/stress suites prove
//! clean runs stay finding-free.

pub mod audit;
pub mod exec;
pub mod schedule;

pub use audit::{audit, audit_snapshot, snapshot, PoolSnapshot};
pub use exec::AuditExec;
pub use schedule::{verify_placement, verify_schedule};

use std::fmt;

/// How bad a finding is.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Severity {
    /// An invariant is broken: state is corrupt or a schedule is
    /// illegal. A clean system never produces one.
    Error,
    /// Suspicious but not provably wrong (reserved; current rules all
    /// report errors).
    Warning,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One verified-invariant violation: a stable rule ID (see the module
/// docs for the catalog), a severity, and a human-readable detail
/// naming the exact page/launch/slot involved.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub detail: String,
}

impl Finding {
    pub fn error(rule: &'static str, detail: String) -> Finding {
        Finding { rule, severity: Severity::Error, detail }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.severity.name(), self.rule, self.detail)
    }
}
