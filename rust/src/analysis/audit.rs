//! Cross-subsystem invariant auditing.
//!
//! The refcounted CoW page pool ([`crate::model::kv_cache::KvCache`])
//! and the continuous batcher's page-budget admission
//! ([`crate::coordinator::scheduler::ContinuousBatcher`]) maintain the
//! same resources from two sides; prefix sharing, host swap, speculative
//! rollback, and mid-decode cancellation all mutate them concurrently
//! within a round. [`audit`] proves, from accessors alone, that the two
//! sides still agree.
//!
//! The audit is **snapshot-based**: [`snapshot`] copies the auditable
//! state into a plain-data [`PoolSnapshot`], and the pure
//! [`audit_snapshot`] runs every `audit/*` rule over it. That split is
//! what makes the rules testable — the live API preserves the
//! invariants by construction, so the mutation property suite corrupts
//! snapshot fields directly and proves each rule fires.

use crate::analysis::Finding;
use crate::coordinator::scheduler::ContinuousBatcher;
use crate::model::engine::Engine;
use crate::model::kv_cache::{chain_key, KvScheme, PrefixChainRecord};
use crate::util::ceil_div;

/// Plain-data copy of every quantity the `audit/*` rules relate: pool
/// geometry, per-page refcounts, the free list, per-slot lengths and
/// block tables, the prefix index, and the batcher's budget view.
#[derive(Clone, Debug)]
pub struct PoolSnapshot {
    /// Tokens per page.
    pub page_size: usize,
    /// Total pages in the shared pool.
    pub n_pages: usize,
    /// Per-slot context capacity.
    pub max_seq: usize,
    /// Per-page reference counts (`refs[page]`).
    pub refs: Vec<u32>,
    /// The LIFO free list, in stack order.
    pub free: Vec<u32>,
    /// Cached positions per slot.
    pub lens: Vec<usize>,
    /// Per-slot block tables (page ids backing `0..lens[slot]`).
    pub tables: Vec<Vec<u32>>,
    /// Device pages pinned by resident prefix-index entries.
    pub resident_prefix_pages: Vec<u32>,
    /// The full prefix index (key, parent, span, location per entry).
    pub chains: Vec<PrefixChainRecord>,
    /// Chain-key fingerprint (`None`: prefix cache disabled).
    pub fingerprint: Option<u64>,
    /// Pages the host swap arena currently holds.
    pub swapped_pages: usize,
    /// The batcher's cached committed-page count.
    pub committed_pages: usize,
    /// The same quantity recomputed from scratch off the live set.
    pub recomputed_committed_pages: usize,
    /// Page encoding chosen at pool construction.
    pub kv_scheme: KvScheme,
    /// Model layers each page spans (encoding-rule geometry).
    pub n_layers: usize,
    /// Elements per K (or V) row (encoding-rule geometry).
    pub kv_dim: usize,
    /// Actual host-side backing lengths of the device pool:
    /// `(k_mirror_cells, v_mirror_cells, k_block_bytes, v_block_bytes)`.
    pub pool_backing: (usize, usize, usize, usize),
    /// Stored payload of every swap-arena entry, sorted by chain key:
    /// `(key, mirror_f32_cells, block_bytes)` counting K and V together.
    pub arena_payloads: Vec<(u64, usize, usize)>,
}

/// Copy the auditable state of a live engine/batcher pair. Cheap
/// relative to a decode round (no KV bytes are copied, only metadata).
pub fn snapshot(engine: &Engine, batcher: &ContinuousBatcher) -> PoolSnapshot {
    let cache = &engine.cache;
    PoolSnapshot {
        page_size: cache.page_size(),
        n_pages: cache.n_pages(),
        max_seq: cache.max_seq,
        refs: (0..cache.n_pages() as u32).map(|p| cache.page_ref(p)).collect(),
        free: cache.free_list().to_vec(),
        lens: (0..cache.n_slots).map(|s| cache.slot_len(s)).collect(),
        tables: (0..cache.n_slots).map(|s| cache.slot_pages(s).to_vec()).collect(),
        resident_prefix_pages: cache.cached_page_ids(),
        chains: cache.prefix_chain_records(),
        fingerprint: cache.prefix_fingerprint(),
        swapped_pages: cache.swapped_out_pages(),
        committed_pages: batcher.committed_pages(),
        recomputed_committed_pages: batcher.recomputed_committed_pages(),
        kv_scheme: cache.kv_scheme(),
        n_layers: cache.n_layers(),
        kv_dim: cache.kv_dim,
        pool_backing: cache.pool_backing_lens(),
        arena_payloads: cache.arena_payloads(),
    }
}

/// Audit a live engine/batcher pair: snapshot + [`audit_snapshot`]. An
/// empty result proves the full `audit/*` rule set at this instant.
pub fn audit(engine: &Engine, batcher: &ContinuousBatcher) -> Vec<Finding> {
    audit_snapshot(&snapshot(engine, batcher))
}

/// Run every `audit/*` rule over a snapshot (see the
/// [module catalog](crate::analysis) for the rule list). Pure: all
/// verdicts derive from the snapshot alone.
pub fn audit_snapshot(s: &PoolSnapshot) -> Vec<Finding> {
    let mut findings = Vec::new();
    let in_pool = |page: u32| (page as usize) < s.n_pages;

    // --- audit/refcount-conservation: refs[p] == block-table entries
    // referencing p + 1 if a resident prefix entry pins p ---
    let mut expected = vec![0u32; s.n_pages];
    for table in &s.tables {
        for &p in table {
            if in_pool(p) {
                expected[p as usize] += 1;
            }
        }
    }
    for &p in &s.resident_prefix_pages {
        if in_pool(p) {
            expected[p as usize] += 1;
        }
    }
    for (p, (&have, &want)) in s.refs.iter().zip(&expected).enumerate() {
        if have != want {
            findings.push(Finding::error(
                "audit/refcount-conservation",
                format!(
                    "page {p}: refcount {have} but {want} live references \
                     (block tables + resident prefix entries)"
                ),
            ));
        }
    }

    // --- audit/free-consistency: no duplicates; on the free list ⇔
    // refcount zero ---
    let mut on_free = vec![false; s.n_pages];
    for &p in &s.free {
        if !in_pool(p) {
            findings.push(Finding::error(
                "audit/free-consistency",
                format!("free list holds page {p} outside the pool of {} pages", s.n_pages),
            ));
            continue;
        }
        if on_free[p as usize] {
            findings.push(Finding::error(
                "audit/free-consistency",
                format!("page {p} appears twice on the free list"),
            ));
        }
        on_free[p as usize] = true;
    }
    for (p, (&free, &r)) in on_free.iter().zip(&s.refs).enumerate() {
        if free && r != 0 {
            findings.push(Finding::error(
                "audit/free-consistency",
                format!("page {p} is on the free list with refcount {r}"),
            ));
        } else if !free && r == 0 {
            findings.push(Finding::error(
                "audit/free-consistency",
                format!("page {p} has refcount 0 but is not on the free list (leaked)"),
            ));
        }
    }

    // --- audit/alias-validity: every alias names a valid, live page ---
    let live = |p: u32| in_pool(p) && s.refs[p as usize] > 0;
    for (slot, table) in s.tables.iter().enumerate() {
        for &p in table {
            if !live(p) {
                findings.push(Finding::error(
                    "audit/alias-validity",
                    format!("slot {slot}'s block table references dead page {p}"),
                ));
            }
        }
    }
    for &p in &s.resident_prefix_pages {
        if !live(p) {
            findings.push(Finding::error(
                "audit/alias-validity",
                format!("a resident prefix entry references dead page {p}"),
            ));
        }
    }

    // --- audit/length-coverage: table size matches the token length,
    // lengths fit the context window ---
    for (slot, (&len, table)) in s.lens.iter().zip(&s.tables).enumerate() {
        let need = ceil_div(len, s.page_size);
        if table.len() != need {
            findings.push(Finding::error(
                "audit/length-coverage",
                format!(
                    "slot {slot}: {len} cached tokens need {need} pages but the \
                     block table holds {}",
                    table.len()
                ),
            ));
        }
        if len > s.max_seq {
            findings.push(Finding::error(
                "audit/length-coverage",
                format!("slot {slot}: {len} cached tokens exceed the context window {}", s.max_seq),
            ));
        }
    }

    // --- audit/budget-conservation: the cached commitment equals the
    // recomputed exact distinct demand ---
    if s.committed_pages != s.recomputed_committed_pages {
        findings.push(Finding::error(
            "audit/budget-conservation",
            format!(
                "batcher commits {} pages but the live set's recomputed distinct \
                 demand is {}",
                s.committed_pages, s.recomputed_committed_pages
            ),
        ));
    }

    // --- audit/chain-integrity: stored keys re-hash from parent + span;
    // spans are one full page; swapped ⇔ arena-backed ---
    match s.fingerprint {
        None => {
            if !s.chains.is_empty() {
                findings.push(Finding::error(
                    "audit/chain-integrity",
                    format!("{} prefix entries exist without a fingerprint", s.chains.len()),
                ));
            }
        }
        Some(fp) => {
            let mut swapped = 0usize;
            for c in &s.chains {
                let rehash = chain_key(fp, c.prev, &c.tokens);
                if rehash != c.key {
                    findings.push(Finding::error(
                        "audit/chain-integrity",
                        format!(
                            "prefix entry {:#018x} does not re-hash from its parent and \
                             token span (expected {rehash:#018x}) — the chain is corrupt",
                            c.key
                        ),
                    ));
                }
                if c.tokens.len() != s.page_size {
                    findings.push(Finding::error(
                        "audit/chain-integrity",
                        format!(
                            "prefix entry {:#018x} spans {} tokens (entries commit exactly \
                             one {}-token page)",
                            c.key,
                            c.tokens.len(),
                            s.page_size
                        ),
                    ));
                }
                match c.resident_page {
                    Some(_) if c.in_arena => findings.push(Finding::error(
                        "audit/chain-integrity",
                        format!("prefix entry {:#018x} is resident yet holds arena bytes", c.key),
                    )),
                    None if !c.in_arena => findings.push(Finding::error(
                        "audit/chain-integrity",
                        format!("prefix entry {:#018x} is swapped but the arena has no bytes", c.key),
                    )),
                    _ => {}
                }
                if c.resident_page.is_none() {
                    swapped += 1;
                }
            }
            if swapped != s.swapped_pages {
                findings.push(Finding::error(
                    "audit/chain-integrity",
                    format!(
                        "{} swapped index entries but the arena holds {} pages \
                         (orphaned or missing arena bytes)",
                        swapped, s.swapped_pages
                    ),
                ));
            }
        }
    }

    // --- audit/encoding-consistency: pool backing and every swapped
    // page's payload are sized exactly by the pool scheme, re-derived
    // from geometry (n_pages, n_layers, page_size, kv_dim) alone ---
    let page_cells = s.n_layers * s.page_size * s.kv_dim;
    let page_q_bytes = s.n_layers * s.page_size * s.kv_scheme.row_bytes(s.kv_dim);
    let (want_pool_q, want_arena) = match s.kv_scheme {
        // F16 pools keep the functional f32 storage and no block
        // arrays; arena pages carry the f32 payload (lossless restore).
        KvScheme::F16 => (0usize, (2 * page_cells, 0usize)),
        // Q8_0 pools keep canonical block bytes plus the dequantized
        // mirror; arena pages carry only the block bytes (the mirror is
        // rebuilt by dequantization on swap-in).
        KvScheme::Q8_0 => (s.n_pages * page_q_bytes, (0usize, 2 * page_q_bytes)),
    };
    let want_pool =
        (s.n_pages * page_cells, s.n_pages * page_cells, want_pool_q, want_pool_q);
    if s.pool_backing != want_pool {
        findings.push(Finding::error(
            "audit/encoding-consistency",
            format!(
                "{} pool backing is {:?} but the page geometry demands {:?} \
                 (k_cells, v_cells, k_block_bytes, v_block_bytes)",
                s.kv_scheme.name(),
                s.pool_backing,
                want_pool
            ),
        ));
    }
    for &(key, f_cells, q_bytes) in &s.arena_payloads {
        if (f_cells, q_bytes) != want_arena {
            findings.push(Finding::error(
                "audit/encoding-consistency",
                format!(
                    "swapped page {key:#018x} holds ({f_cells} f32 cells, {q_bytes} \
                     block bytes) but a {} page must hold ({}, {}) — it cannot \
                     restore under the pool scheme",
                    s.kv_scheme.name(),
                    want_arena.0,
                    want_arena.1
                ),
            ));
        }
    }

    findings
}
