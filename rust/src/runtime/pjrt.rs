//! The `xla`-crate PJRT wrapper: compile HLO-text artifacts once, execute
//! many times from the hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` — serialized
//! protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1). All
//! artifacts are lowered with `return_tuple=True`, so results are always
//! unwrapped from a tuple.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifacts::ArtifactDir;
use crate::util::f16::F16;

/// Literal constructors for the packed operand formats.
pub mod lit {
    use super::*;

    pub fn f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, &bytes)
            .map_err(|e| anyhow!("f32 literal: {e:?}"))
    }

    pub fn i8(dims: &[usize], data: &[i8]) -> Result<xla::Literal> {
        let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, &bytes)
            .map_err(|e| anyhow!("i8 literal: {e:?}"))
    }

    pub fn u8(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
            .map_err(|e| anyhow!("u8 literal: {e:?}"))
    }

    pub fn f16(dims: &[usize], data: &[F16]) -> Result<xla::Literal> {
        let bytes: Vec<u8> = data.iter().flat_map(|h| h.0.to_le_bytes()).collect();
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F16, dims, &bytes)
            .map_err(|e| anyhow!("f16 literal: {e:?}"))
    }
}

/// A PJRT CPU client with a cache of compiled artifact executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub artifacts: ArtifactDir,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create the CPU client and locate the artifact directory.
    pub fn new() -> Result<PjrtRuntime> {
        let artifacts = ArtifactDir::locate()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            artifacts,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse HLO text {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Names of currently compiled executables.
    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a loaded artifact; returns the elements of the result
    /// tuple (artifacts are lowered with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("just loaded");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("execute {name}: empty result"))?;
        let literal = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        literal
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Execute an artifact returning a single f32 vector (the dot-kernel
    /// artifacts).
    pub fn execute_vec1_f32(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let mut out = self.execute(name, inputs)?;
        let first = out
            .drain(..)
            .next()
            .ok_or_else(|| anyhow!("{name}: empty tuple"))?;
        first
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{name} result to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests here only cover literal construction; the full
    //! compile/execute loop needs artifacts and lives in
    //! `rust/tests/integration_runtime.rs`.
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = lit::f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_i8() {
        let l = lit::i8(&[4], &[-1, 2, -3, 127]).unwrap();
        assert_eq!(l.to_vec::<i8>().unwrap(), vec![-1, 2, -3, 127]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit::f32(&[3], &[1.0]).is_err());
    }
}
