//! Execution backends behind the plan/submit API: the
//! [`backend::BackendRegistry`] that constructs a
//! [`crate::model::KernelExec`] from a declarative [`backend::ExecSpec`]
//! (`native` / `imax[:opts]` / `pjrt` / a per-layer-range placement),
//! the [`queue::LaunchQueue`] that queueing backends flush at the
//! engine's submit points, plus the PJRT runtime that loads and executes
//! the AOT-compiled JAX/Pallas artifacts from the Rust request path
//! (Python never runs at inference time).
//!
//! * [`backend`] — the registry, the `ExecSpec` selector grammar
//!   (including heterogeneous `0-11:imax:fpga2,12-23:native`
//!   placements), the per-run [`backend::BackendReport`] accounting with
//!   per-backend sub-reports, and (feature `pjrt`) the
//!   [`backend::PjrtExec`] that reroutes Q8_0 linear projections of the
//!   tiny model through the compiled Pallas kernels.
//! * [`queue`] — [`queue::KernelOp`] launch descriptors and the FIFO
//!   [`queue::LaunchQueue`] with explicit submission batches (the window
//!   cross-kernel optimizations such as double-buffered LMM prefetch are
//!   modeled over).
//! * [`artifacts`] — locate `artifacts/`, parse `manifest.txt`, validate
//!   shape signatures against the tiny-model config.
//! * [`pjrt`] (feature `pjrt`) — the `xla`-crate wrapper: HLO text →
//!   `HloModuleProto` → compile on the PJRT CPU client → execute with
//!   packed quantized operands.
//!
//! The `pjrt` feature gates everything that needs the `xla` crate so the
//! default build carries no native XLA dependency; see `Cargo.toml`.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod queue;

pub use artifacts::ArtifactDir;
pub use backend::{
    BackendExec, BackendRegistry, BackendReport, ExecSpec, ImaxSpec, PlacementExec, PlacementRule,
    PlacementSpec,
};
#[cfg(feature = "pjrt")]
pub use backend::PjrtExec;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;
pub use queue::{KernelOp, Launch, LaunchQueue};
