//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the Rust request path (Python never runs at inference time).
//!
//! * [`artifacts`] — locate `artifacts/`, parse `manifest.txt`, validate
//!   shape signatures against the tiny-model config.
//! * [`pjrt`] — the `xla`-crate wrapper: HLO text → `HloModuleProto` →
//!   compile on the PJRT CPU client → execute with packed quantized
//!   operands.
//! * [`backend`] — a [`crate::model::MatvecExec`] implementation that
//!   reroutes Q8_0 linear projections of the tiny model through the
//!   compiled Pallas kernels, proving the three layers compose.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::ArtifactDir;
pub use backend::PjrtExec;
pub use pjrt::PjrtRuntime;
