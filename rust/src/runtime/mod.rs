//! Execution backends: the [`backend::BackendRegistry`] that constructs a
//! [`crate::model::MatvecExec`] from a declarative [`backend::ExecSpec`]
//! (`native` / `imax` / `pjrt`), plus the PJRT runtime that loads and
//! executes the AOT-compiled JAX/Pallas artifacts from the Rust request
//! path (Python never runs at inference time).
//!
//! * [`backend`] — the registry, the `ExecSpec` selector grammar, the
//!   per-run [`backend::BackendReport`] accounting, and (feature `pjrt`)
//!   the [`backend::PjrtExec`] that reroutes Q8_0 linear projections of
//!   the tiny model through the compiled Pallas kernels.
//! * [`artifacts`] — locate `artifacts/`, parse `manifest.txt`, validate
//!   shape signatures against the tiny-model config.
//! * [`pjrt`] (feature `pjrt`) — the `xla`-crate wrapper: HLO text →
//!   `HloModuleProto` → compile on the PJRT CPU client → execute with
//!   packed quantized operands.
//!
//! The `pjrt` feature gates everything that needs the `xla` crate so the
//! default build carries no native XLA dependency; see `Cargo.toml`.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifacts::ArtifactDir;
pub use backend::{BackendExec, BackendRegistry, BackendReport, ExecSpec, ImaxSpec};
#[cfg(feature = "pjrt")]
pub use backend::PjrtExec;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;
