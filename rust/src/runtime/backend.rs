//! The backend registry for the plan/submit execution API: one place
//! that turns a declarative [`ExecSpec`] into the right
//! [`crate::model::engine::KernelExec`] implementation — native Rust
//! kernels, the instrumented-IMAX cost model, an AOT-Pallas PJRT runner
//! (feature `pjrt`), or a heterogeneous per-layer-range *placement* of
//! any of those.
//!
//! **Plan/submit.** The engine records kernel launches and marks host
//! dependency boundaries with `submit()`/`sync()`
//! ([`crate::model::engine::KernelExec`]); backends built here either
//! execute eagerly (submit is a no-op — `native`, `pjrt`) or queue
//! launch descriptors in a [`crate::runtime::queue::LaunchQueue`] and
//! settle them at the flush (`imax`, whose cost model can then overlap
//! each queued kernel's DMA LOAD with the previous kernel's EXEC — the
//! double-buffered LMM, `imax:…:dbuf`).
//!
//! **Selector grammar** (the `--backend` flag):
//!
//! ```text
//! native | pjrt
//! imax[:asic[N]|:fpga[N]][:lmm<KB>][:naive|coalesced][:dbuf]
//! <first>[-<last>]:<spec>,<first>[-<last>]:<spec>,…   (placement)
//! ```
//!
//! A placement maps inclusive layer ranges to per-range executors
//! (`0-11:imax:fpga2,12-23:native`): the registry builds one executor
//! per range and routes each kernel by its layer, so prefill/decode can
//! shard across heterogeneous devices in one run. The LM head runs on
//! the executor owning the highest range. [`BackendReport::merged`]
//! joins the distinct backend names (`imax:fpga2+native`) and keeps
//! per-backend sub-reports, so heterogeneous runs stay correctly
//! labeled all the way up to the serve report.

use anyhow::{bail, Result};

use crate::coordinator::offload::OffloadPolicy;
use crate::coordinator::phases::InstrumentedExec;
use crate::imax::device::{ImaxDevice, ImaxImpl};
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;
use crate::imax::timing::RunBreakdown;
use crate::model::engine::{KernelExec, MatvecExec, NativeExec, RoundBalance};
use crate::model::graph::{KvSwapDir, MatvecOp, Phase};
use crate::tensor::{ActQuant, QTensor};

/// IMAX instrumentation parameters (which modeled device shadows the
/// functional run).
#[derive(Clone, Debug, PartialEq)]
pub struct ImaxSpec {
    /// 28 nm ASIC projection instead of the FPGA prototype.
    pub asic: bool,
    pub lanes: usize,
    /// LMM capacity per PE in KB (`:lmm<KB>`, 16..=512).
    pub lmm_kb: usize,
    /// DMA coalescing mode (`:naive` / `:coalesced`).
    pub mode: TransferMode,
    /// Model the double-buffered LMM prefetch (`:dbuf`): overlap queued
    /// kernels' streaming LOAD with the previous kernel's EXEC.
    pub overlap: bool,
}

impl Default for ImaxSpec {
    fn default() -> ImaxSpec {
        // The paper's chosen configuration: FPGA prototype, 2 lanes,
        // 64 KB LMM, coalesced DMA, no prefetch-overlap modeling.
        ImaxSpec {
            asic: false,
            lanes: 2,
            lmm_kb: 64,
            mode: TransferMode::Coalesced,
            overlap: false,
        }
    }
}

impl ImaxSpec {
    pub fn device(&self) -> ImaxDevice {
        if self.asic {
            ImaxDevice::asic28(self.lanes)
        } else {
            ImaxDevice::fpga(self.lanes)
        }
    }
}

/// One placement rule: an inclusive layer range mapped to a
/// (non-placement) backend spec.
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementRule {
    /// First layer (inclusive).
    pub first: usize,
    /// Last layer (inclusive).
    pub last: usize,
    pub spec: ExecSpec,
}

/// Heterogeneous multi-backend placement: disjoint layer ranges, each
/// executed by its own backend (`0-11:imax:fpga2,12-23:native`). Rules
/// are kept sorted by first layer; ranges may extend beyond a smaller
/// model's layer count, but every layer of the model that runs must be
/// covered ([`PlacementSpec::validate_layers`]).
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementSpec {
    pub rules: Vec<PlacementRule>,
}

impl PlacementSpec {
    /// Parse `<first>[-<last>]:<spec>` rules separated by commas.
    pub fn parse(s: &str) -> Result<PlacementSpec> {
        let mut rules = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            let Some((range, spec_str)) = part.split_once(':') else {
                bail!("placement rule '{part}' must be '<first>[-<last>]:<backend>'");
            };
            let (first, last) = match range.split_once('-') {
                Some((a, b)) => (
                    a.parse().map_err(|_| anyhow::anyhow!("bad layer '{a}' in rule '{part}'"))?,
                    b.parse().map_err(|_| anyhow::anyhow!("bad layer '{b}' in rule '{part}'"))?,
                ),
                None => {
                    let n: usize = range
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad layer '{range}' in rule '{part}'"))?;
                    (n, n)
                }
            };
            if last < first {
                bail!("empty layer range {first}-{last} in rule '{part}'");
            }
            let spec = ExecSpec::parse(spec_str)?;
            if matches!(spec, ExecSpec::Placement(_)) {
                bail!("nested placement in rule '{part}'");
            }
            rules.push(PlacementRule { first, last, spec });
        }
        rules.sort_by_key(|r| r.first);
        for w in rules.windows(2) {
            if w[1].first <= w[0].last {
                bail!(
                    "overlapping layer ranges {}-{} and {}-{}",
                    w[0].first,
                    w[0].last,
                    w[1].first,
                    w[1].last
                );
            }
        }
        Ok(PlacementSpec { rules })
    }

    /// Check that layers `0..n_layers` are all covered (no gaps below the
    /// model's layer count; ranges reaching beyond it are fine).
    pub fn validate_layers(&self, n_layers: usize) -> Result<()> {
        let mut next = 0usize;
        for r in &self.rules {
            if next >= n_layers {
                break;
            }
            if r.first > next {
                bail!("placement leaves layer {next} uncovered (model has {n_layers} layers)");
            }
            next = r.last + 1;
        }
        if next < n_layers {
            bail!("placement covers layers 0..{next} but the model has {n_layers} layers");
        }
        Ok(())
    }

    pub fn name(&self) -> String {
        self.rules
            .iter()
            .map(|r| {
                if r.first == r.last {
                    format!("{}:{}", r.first, r.spec.name())
                } else {
                    format!("{}-{}:{}", r.first, r.last, r.spec.name())
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Declarative backend selection, parseable from a CLI flag.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecSpec {
    /// Pure-Rust kernels, no instrumentation.
    Native,
    /// Native kernels shadowed by the IMAX cost model (per-phase
    /// EXEC/LOAD/HOST/... accounting and offload stats).
    Imax(ImaxSpec),
    /// AOT-compiled Pallas kernels through PJRT (requires the `pjrt`
    /// cargo feature and `make artifacts`).
    Pjrt,
    /// Heterogeneous per-layer-range placement of the above.
    Placement(PlacementSpec),
}

impl ExecSpec {
    /// Parse a `--backend` selector (see the module docs for the full
    /// grammar): `native`, `pjrt`, `imax` with optional `:`-separated
    /// options — device variant (`asic[N]`/`fpga[N]`), LMM size
    /// (`lmm<KB>`), DMA mode (`naive`/`coalesced`), prefetch overlap
    /// (`dbuf`) — or a comma-separated layer-range placement
    /// (`0-11:imax:fpga2,12-23:native`).
    pub fn parse(s: &str) -> Result<ExecSpec> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() {
            bail!("empty backend spec");
        }
        // A leading digit can only start a layer-range placement rule.
        if s.as_bytes()[0].is_ascii_digit() {
            return Ok(ExecSpec::Placement(PlacementSpec::parse(&s)?));
        }
        match s.as_str() {
            "native" => return Ok(ExecSpec::Native),
            "pjrt" => return Ok(ExecSpec::Pjrt),
            "imax" => return Ok(ExecSpec::Imax(ImaxSpec::default())),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("imax:") {
            let mut spec = ImaxSpec::default();
            let mut saw_variant = false;
            let mut saw_lmm = false;
            let mut saw_mode = false;
            let mut saw_dbuf = false;
            for seg in rest.split(':') {
                if seg.is_empty() {
                    bail!("empty option segment in '{s}'");
                }
                let variant = seg
                    .strip_prefix("asic")
                    .map(|l| (true, l))
                    .or_else(|| seg.strip_prefix("fpga").map(|l| (false, l)));
                if let Some((asic, lanes_str)) = variant {
                    if saw_variant {
                        bail!("duplicate device variant in '{s}'");
                    }
                    saw_variant = true;
                    spec.asic = asic;
                    spec.lanes = if lanes_str.is_empty() {
                        2
                    } else {
                        lanes_str
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad lane count '{lanes_str}'"))?
                    };
                    if !(1..=8).contains(&spec.lanes) {
                        bail!(
                            "lane count {} out of range (the IMAX carrier has 1..=8 lanes)",
                            spec.lanes
                        );
                    }
                } else if let Some(kb) = seg.strip_prefix("lmm") {
                    if saw_lmm {
                        bail!("duplicate LMM size in '{s}'");
                    }
                    saw_lmm = true;
                    spec.lmm_kb = kb.parse().map_err(|_| {
                        anyhow::anyhow!("bad LMM size '{kb}' (use lmm<KB>, e.g. lmm128)")
                    })?;
                    if !(16..=512).contains(&spec.lmm_kb) {
                        bail!(
                            "LMM size {} KB out of range (the LMM is configurable 16..=512 KB)",
                            spec.lmm_kb
                        );
                    }
                } else if seg == "naive" || seg == "coalesced" {
                    if saw_mode {
                        bail!("duplicate DMA mode in '{s}'");
                    }
                    saw_mode = true;
                    spec.mode = if seg == "naive" {
                        TransferMode::Naive
                    } else {
                        TransferMode::Coalesced
                    };
                } else if seg == "dbuf" {
                    if saw_dbuf {
                        bail!("duplicate dbuf option in '{s}'");
                    }
                    saw_dbuf = true;
                    spec.overlap = true;
                } else {
                    bail!(
                        "unknown imax option '{seg}' \
                         (use asic[N]|fpga[N], lmm<KB>, naive|coalesced, dbuf)"
                    );
                }
            }
            return Ok(ExecSpec::Imax(spec));
        }
        bail!(
            "unknown backend '{s}' (available: {}; imax takes :asic[N]|:fpga[N], :lmm<KB>, \
             :naive|:coalesced, :dbuf options, and layer-range placements look like \
             '0-5:imax,6-11:native' — see `imax-llm help`)",
            BackendRegistry::available().join("|")
        );
    }

    /// Canonical selector string; [`ExecSpec::parse`] round-trips it
    /// (non-default imax options are emitted, defaults elided).
    pub fn name(&self) -> String {
        match self {
            ExecSpec::Native => "native".to_string(),
            ExecSpec::Pjrt => "pjrt".to_string(),
            ExecSpec::Imax(i) => {
                let mut n = format!("imax:{}{}", if i.asic { "asic" } else { "fpga" }, i.lanes);
                if i.lmm_kb != 64 {
                    n.push_str(&format!(":lmm{}", i.lmm_kb));
                }
                if i.mode == TransferMode::Naive {
                    n.push_str(":naive");
                }
                if i.overlap {
                    n.push_str(":dbuf");
                }
                n
            }
            ExecSpec::Placement(p) => p.name(),
        }
    }
}

/// Per-backend accounting pulled out after a run; serving aggregates one
/// of these per worker into the `ServeReport`.
#[derive(Clone, Debug, Default)]
pub struct BackendReport {
    pub backend: String,
    /// Modeled IMAX per-phase costs (imax backend only).
    pub modeled: Option<RunBreakdown>,
    /// Offloaded / total dot-product invocations (imax backend only).
    pub offload_ratio: Option<f64>,
    pub offloaded_macs: u64,
    pub total_macs: u64,
    /// KV page swap traffic charged through the DMA cost model (imax
    /// backend; bytes in the pool's page encoding — f16 or q8_0 blocks —
    /// both directions). Nonzero only when the serving layer
    /// oversubscribes the page pool with `--swap-pages`.
    pub kv_swap_bytes: u64,
    /// Modeled weight/activation bytes streamed to the accelerator
    /// (imax backend only; 0 for functional backends). The numerator of
    /// the bytes-streamed-per-accepted-token metric speculative
    /// decoding drives down.
    pub streamed_bytes: u64,
    /// Measured engine wall time per phase (imax backend only; the
    /// serving loop measures its own phases for the others). Under a
    /// placement every part observes the *whole* shared step, so a
    /// per-part wall covers the full model (including other parts'
    /// layers), and summed walls count each step once per instrumented
    /// part — treat these as step-coverage times, not per-backend
    /// attribution.
    pub wall_prefill_s: f64,
    pub wall_decode_s: f64,
    /// Per-backend sub-reports when the merge spanned distinct backends
    /// (heterogeneous placements / mixed fleets); empty for a
    /// single-backend report.
    pub parts: Vec<BackendReport>,
}

impl BackendReport {
    /// Merge reports into one. Distinct backend names are joined
    /// (`imax:fpga2+native`) rather than mislabeled after the last
    /// report, and when more than one distinct backend contributed the
    /// merged report keeps one summed sub-report per backend in
    /// [`BackendReport::parts`].
    pub fn merged(reports: &[BackendReport]) -> BackendReport {
        // Flatten: a report that is itself a merge (placement) stands in
        // for its parts.
        let mut leaves: Vec<&BackendReport> = Vec::new();
        for r in reports {
            if r.parts.is_empty() {
                leaves.push(r);
            } else {
                leaves.extend(r.parts.iter());
            }
        }
        let mut names: Vec<String> = Vec::new();
        for l in &leaves {
            if !names.contains(&l.backend) {
                names.push(l.backend.clone());
            }
        }
        let mut out = Self::sum(leaves.iter().copied(), names.join("+"));
        if names.len() > 1 {
            out.parts = names
                .iter()
                .map(|n| Self::sum(leaves.iter().filter(|l| &l.backend == n).copied(), n.clone()))
                .collect();
        }
        out
    }

    /// Sum additive fields over reports under one label (no grouping).
    fn sum<'a>(reports: impl Iterator<Item = &'a BackendReport>, backend: String) -> BackendReport {
        let mut out = BackendReport {
            backend,
            ..BackendReport::default()
        };
        let mut modeled = RunBreakdown::default();
        let mut any_modeled = false;
        for r in reports {
            if let Some(m) = r.modeled {
                modeled.prefill += m.prefill;
                modeled.decode += m.decode;
                any_modeled = true;
            }
            out.offloaded_macs += r.offloaded_macs;
            out.total_macs += r.total_macs;
            out.kv_swap_bytes += r.kv_swap_bytes;
            out.streamed_bytes += r.streamed_bytes;
            out.wall_prefill_s += r.wall_prefill_s;
            out.wall_decode_s += r.wall_decode_s;
        }
        if any_modeled {
            out.modeled = Some(modeled);
            if out.total_macs > 0 {
                out.offload_ratio = Some(out.offloaded_macs as f64 / out.total_macs as f64);
            }
        }
        out
    }
}

/// One range of a [`PlacementExec`]: the layers it owns and the executor
/// serving them.
pub struct PlacementPart {
    pub first: usize,
    pub last: usize,
    pub exec: BackendExec,
}

/// Heterogeneous executor resolved from a [`PlacementSpec`]: kernels
/// route by `op.layer` to the part owning that layer; the LM head
/// (`layer: None`) runs on the part owning the highest range. Step
/// boundaries and submits fan out to every part, so each keeps coherent
/// per-phase accounting for its share of the model.
pub struct PlacementExec {
    parts: Vec<PlacementPart>,
    /// Index of the part owning the highest layer range (LM head home).
    head: usize,
}

impl PlacementExec {
    fn new(parts: Vec<PlacementPart>) -> PlacementExec {
        assert!(!parts.is_empty(), "placement needs at least one rule");
        let head = parts
            .iter()
            .enumerate()
            .max_by_key(|(_, p)| p.last)
            .map(|(i, _)| i)
            .expect("nonempty parts");
        PlacementExec { parts, head }
    }

    pub fn parts(&self) -> &[PlacementPart] {
        &self.parts
    }

    fn part_for(&mut self, layer: Option<usize>) -> &mut BackendExec {
        let idx = match layer {
            None => self.head,
            Some(l) => self
                .parts
                .iter()
                .position(|p| p.first <= l && l <= p.last)
                .unwrap_or_else(|| {
                    panic!(
                        "layer {l} not covered by the placement \
                         (validate the spec against the model's n_layers)"
                    )
                }),
        };
        &mut self.parts[idx].exec
    }
}

impl MatvecExec for PlacementExec {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.part_for(op.layer).linear(op, w, act, out);
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        self.part_for(op.layer).linear_ubatch(op, w, acts, outs);
    }

    fn attn(&mut self, op: &MatvecOp) {
        self.part_for(op.layer).attn(op);
    }

    fn kv_transfer(&mut self, phase: Phase, dir: KvSwapDir, bytes: usize) {
        // One physical transfer — charge it once, to the part owning the
        // highest range (the LM-head home), not to every part.
        self.parts[self.head].exec.kv_transfer(phase, dir, bytes);
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        for p in &mut self.parts {
            p.exec.begin_step(phase, pos);
        }
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        for p in &mut self.parts {
            p.exec.end_step(phase, pos);
        }
    }
}

impl KernelExec for PlacementExec {
    fn submit(&mut self) {
        for p in &mut self.parts {
            p.exec.submit();
        }
    }

    fn sync(&mut self) {
        for p in &mut self.parts {
            p.exec.sync();
        }
    }

    fn round_boundary(&mut self) {
        for p in &mut self.parts {
            p.exec.round_boundary();
        }
    }

    fn last_round_balance(&self) -> Option<RoundBalance> {
        // Sum over parts: each instrumented range contributed its own
        // share of the round's modeled LOAD/EXEC time. `None` only when
        // no part models costs at all.
        let mut any = false;
        let mut sum = RoundBalance::default();
        for p in &self.parts {
            if let Some(b) = p.exec.last_round_balance() {
                any = true;
                sum.load_s += b.load_s;
                sum.exec_s += b.exec_s;
            }
        }
        any.then_some(sum)
    }
}

/// A constructed backend executor. Closed enum rather than a trait
/// object so `MatvecExec`'s provided methods (ubatch dispatch) forward
/// without dynamic upcasting.
pub enum BackendExec {
    Native(NativeExec),
    Imax(Box<InstrumentedExec<NativeExec>>),
    Placement(PlacementExec),
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtExec),
}

impl BackendExec {
    pub fn name(&self) -> &'static str {
        match self {
            BackendExec::Native(_) => "native",
            BackendExec::Imax(_) => "imax",
            BackendExec::Placement(_) => "placement",
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(_) => "pjrt",
        }
    }

    /// Offload statistics table source, when the backend tracks one
    /// (under a placement: the first part that does).
    pub fn offload_stats(&self) -> Option<&crate::coordinator::offload::OffloadStats> {
        match self {
            BackendExec::Imax(i) => Some(&i.stats),
            BackendExec::Placement(p) => p.parts.iter().find_map(|part| part.exec.offload_stats()),
            _ => None,
        }
    }

    pub fn report(&self) -> BackendReport {
        match self {
            BackendExec::Native(_) => BackendReport {
                backend: "native".to_string(),
                ..BackendReport::default()
            },
            BackendExec::Imax(i) => {
                // Reconstruct the canonical selector from the executor's
                // actual configuration so heterogeneous merges stay
                // labeled with the concrete device (`imax:fpga2`), not a
                // generic family name.
                let spec = ImaxSpec {
                    asic: i.dev.imp == ImaxImpl::Asic28,
                    lanes: i.dev.lanes,
                    lmm_kb: i.policy.lmm.size_kb,
                    mode: i.mode,
                    overlap: i.overlap,
                };
                BackendReport {
                    backend: ExecSpec::Imax(spec).name(),
                    modeled: Some(i.modeled),
                    offload_ratio: Some(i.stats.total_ratio()),
                    offloaded_macs: i.stats.offloaded_macs,
                    total_macs: i.stats.total_macs,
                    kv_swap_bytes: i.kv_swap_bytes,
                    streamed_bytes: i.streamed_bytes,
                    wall_prefill_s: i.wall_prefill,
                    wall_decode_s: i.wall_decode,
                    ..BackendReport::default()
                }
            }
            BackendExec::Placement(p) => {
                let reports: Vec<BackendReport> =
                    p.parts.iter().map(|part| part.exec.report()).collect();
                BackendReport::merged(&reports)
            }
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(_) => BackendReport {
                backend: "pjrt".to_string(),
                ..BackendReport::default()
            },
        }
    }
}

impl MatvecExec for BackendExec {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        match self {
            BackendExec::Native(e) => e.linear(op, w, act, out),
            BackendExec::Imax(e) => e.linear(op, w, act, out),
            BackendExec::Placement(e) => e.linear(op, w, act, out),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.linear(op, w, act, out),
        }
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        match self {
            BackendExec::Native(e) => e.linear_ubatch(op, w, acts, outs),
            BackendExec::Imax(e) => e.linear_ubatch(op, w, acts, outs),
            BackendExec::Placement(e) => e.linear_ubatch(op, w, acts, outs),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.linear_ubatch(op, w, acts, outs),
        }
    }

    fn attn(&mut self, op: &MatvecOp) {
        match self {
            BackendExec::Native(e) => e.attn(op),
            BackendExec::Imax(e) => e.attn(op),
            BackendExec::Placement(e) => e.attn(op),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.attn(op),
        }
    }

    fn kv_transfer(&mut self, phase: Phase, dir: KvSwapDir, bytes: usize) {
        match self {
            BackendExec::Native(e) => e.kv_transfer(phase, dir, bytes),
            BackendExec::Imax(e) => e.kv_transfer(phase, dir, bytes),
            BackendExec::Placement(e) => e.kv_transfer(phase, dir, bytes),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.kv_transfer(phase, dir, bytes),
        }
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        match self {
            BackendExec::Native(e) => e.begin_step(phase, pos),
            BackendExec::Imax(e) => e.begin_step(phase, pos),
            BackendExec::Placement(e) => e.begin_step(phase, pos),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.begin_step(phase, pos),
        }
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        match self {
            BackendExec::Native(e) => e.end_step(phase, pos),
            BackendExec::Imax(e) => e.end_step(phase, pos),
            BackendExec::Placement(e) => e.end_step(phase, pos),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.end_step(phase, pos),
        }
    }
}

impl KernelExec for BackendExec {
    fn submit(&mut self) {
        match self {
            BackendExec::Native(e) => e.submit(),
            BackendExec::Imax(e) => e.submit(),
            BackendExec::Placement(e) => e.submit(),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.submit(),
        }
    }

    fn sync(&mut self) {
        match self {
            BackendExec::Native(e) => e.sync(),
            BackendExec::Imax(e) => e.sync(),
            BackendExec::Placement(e) => e.sync(),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.sync(),
        }
    }

    fn round_boundary(&mut self) {
        match self {
            BackendExec::Native(e) => e.round_boundary(),
            BackendExec::Imax(e) => e.round_boundary(),
            BackendExec::Placement(e) => e.round_boundary(),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.round_boundary(),
        }
    }

    fn last_round_balance(&self) -> Option<RoundBalance> {
        match self {
            BackendExec::Native(e) => e.last_round_balance(),
            BackendExec::Imax(e) => e.last_round_balance(),
            BackendExec::Placement(e) => e.last_round_balance(),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.last_round_balance(),
        }
    }
}

/// Constructs [`BackendExec`]s from [`ExecSpec`]s. Stateless — the
/// registry is the naming + wiring, not a cache.
pub struct BackendRegistry;

impl BackendRegistry {
    /// Base selector names accepted by [`ExecSpec::parse`] (the imax
    /// option grammar and layer-range placements are documented in the
    /// module docs and `imax-llm help`).
    pub fn available() -> Vec<&'static str> {
        let mut names = vec!["native", "imax", "imax:asic"];
        if cfg!(feature = "pjrt") {
            names.push("pjrt");
        }
        names
    }

    /// Cheap validation that `spec` can be built in this binary (used to
    /// fail fast before spawning worker threads).
    pub fn validate(spec: &ExecSpec) -> Result<()> {
        match spec {
            ExecSpec::Native | ExecSpec::Imax(_) => Ok(()),
            ExecSpec::Placement(p) => {
                for r in &p.rules {
                    Self::validate(&r.spec)?;
                }
                Ok(())
            }
            ExecSpec::Pjrt => {
                if cfg!(feature = "pjrt") {
                    Ok(())
                } else {
                    bail!(
                        "backend 'pjrt' requires building with `--features pjrt` \
                         (the xla crate + `make artifacts`)"
                    )
                }
            }
        }
    }

    /// Build an executor for `spec`. Each worker thread builds its own
    /// (executors are stateful and not shared).
    pub fn build(spec: &ExecSpec) -> Result<BackendExec> {
        match spec {
            ExecSpec::Native => Ok(BackendExec::Native(NativeExec)),
            ExecSpec::Imax(i) => {
                // Keep the modeled device consistent with a CLI LMM
                // override (the policy's LmmConfig drives tiling/fit; the
                // device's lmm_kb drives static power).
                let dev = i.device().with_lmm_kb(i.lmm_kb);
                let policy = OffloadPolicy::new(LmmConfig::new(i.lmm_kb));
                Ok(BackendExec::Imax(Box::new(
                    InstrumentedExec::new(NativeExec, dev, policy, i.mode).with_overlap(i.overlap),
                )))
            }
            ExecSpec::Placement(p) => {
                let mut parts = Vec::with_capacity(p.rules.len());
                for r in &p.rules {
                    parts.push(PlacementPart {
                        first: r.first,
                        last: r.last,
                        exec: Self::build(&r.spec)?,
                    });
                }
                Ok(BackendExec::Placement(PlacementExec::new(parts)))
            }
            ExecSpec::Pjrt => {
                Self::validate(spec)?;
                #[cfg(feature = "pjrt")]
                {
                    Ok(BackendExec::Pjrt(PjrtExec::new()?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    unreachable!("validate rejects pjrt without the feature")
                }
            }
        }
    }

    pub fn build_named(name: &str) -> Result<BackendExec> {
        Self::build(&ExecSpec::parse(name)?)
    }
}

/// Split Q8_0 blocks into the (codes, scales) arrays the Pallas kernel
/// takes (the paper's "four distinct input arrays", §III.D). Shared with
/// the PJRT parity tests; no xla dependency.
pub fn split_q8_blocks(blocks: &[crate::quant::q8_0::BlockQ8_0]) -> (Vec<i8>, Vec<f32>) {
    let mut qs = Vec::with_capacity(blocks.len() * crate::quant::q8_0::QK8_0);
    let mut ds = Vec::with_capacity(blocks.len());
    for b in blocks {
        qs.extend_from_slice(&b.qs);
        ds.push(b.d.to_f32());
    }
    (qs, ds)
}

#[cfg(feature = "pjrt")]
pub use pjrt_exec::PjrtExec;

/// PJRT-backed kernel execution: a [`MatvecExec`] that routes the tiny
/// model's Q8_0 linear projections through the AOT-compiled Pallas
/// kernels instead of the native Rust kernels.
///
/// This is the composition proof for the three-layer architecture: the
/// L3 coordinator's engine loop drives L1 Pallas arithmetic (inside the
/// L2-lowered HLO) through PJRT, with identical packed operands to the
/// native path. `rust/tests/integration_runtime.rs` asserts the numerics
/// agree.
#[cfg(feature = "pjrt")]
mod pjrt_exec {
    use std::collections::HashMap;

    use anyhow::Result;

    use super::split_q8_blocks;
    use crate::model::engine::{KernelExec, MatvecExec};
    use crate::model::graph::MatvecOp;
    use crate::quant::{q8_0, GgmlType};
    use crate::runtime::artifacts::ArtifactDir;
    use crate::runtime::pjrt::{lit, PjrtRuntime};
    use crate::tensor::{ActQuant, QTensor, TensorData};

    /// MatvecExec that offloads Q8_0 linears to PJRT artifacts, falling
    /// back to native kernels for formats/shapes without an artifact.
    pub struct PjrtExec {
        pub rt: PjrtRuntime,
        /// Cached unpacked weight arrays keyed by tensor name (the
        /// host-side DMA staging buffer analogue).
        weight_cache: HashMap<String, (Vec<i8>, Vec<f32>)>,
        /// Kernels executed via PJRT vs native fallback (introspection).
        pub pjrt_calls: usize,
        pub native_calls: usize,
    }

    impl PjrtExec {
        pub fn new() -> Result<PjrtExec> {
            Ok(PjrtExec {
                rt: PjrtRuntime::new()?,
                weight_cache: HashMap::new(),
                pjrt_calls: 0,
                native_calls: 0,
            })
        }

        fn try_pjrt(
            &mut self,
            op: &MatvecOp,
            w: &QTensor,
            act: &ActQuant,
            out: &mut [f32],
        ) -> Result<bool> {
            if w.ty != GgmlType::Q8_0 {
                return Ok(false);
            }
            let name = ArtifactDir::q8_dot_name(op.rows, op.cols);
            if !self.rt.artifacts.has(&name) {
                return Ok(false);
            }
            let (TensorData::Q8_0(blocks), ActQuant::Q8_0(ablocks)) = (&w.data, act) else {
                return Ok(false);
            };
            let nb = op.cols / q8_0::QK8_0;
            if !self.weight_cache.contains_key(&w.name) {
                self.weight_cache
                    .insert(w.name.clone(), split_q8_blocks(blocks));
            }
            let (wqv, wdv) = self.weight_cache.get(&w.name).expect("cached");
            let wq = lit::i8(&[op.rows, op.cols], wqv)?;
            let wd = lit::f32(&[op.rows, nb], wdv)?;
            let (aq, ad) = split_q8_blocks(ablocks);
            let aql = lit::i8(&[op.cols], &aq)?;
            let adl = lit::f32(&[nb], &ad)?;
            let result = self.rt.execute_vec1_f32(&name, &[wq, wd, aql, adl])?;
            out.copy_from_slice(&result);
            Ok(true)
        }
    }

    impl MatvecExec for PjrtExec {
        fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
            match self.try_pjrt(op, w, act, out) {
                Ok(true) => {
                    self.pjrt_calls += 1;
                }
                Ok(false) => {
                    self.native_calls += 1;
                    crate::tensor::matvec_into(w, act, out);
                }
                Err(e) => panic!("pjrt backend failed on {}: {e:#}", w.name),
            }
        }
    }

    impl KernelExec for PjrtExec {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::Engine;
    use crate::model::graph::Phase;
    use crate::model::sampler::Sampler;
    use crate::model::weights::ModelWeights;

    #[test]
    fn spec_parsing_roundtrip() {
        assert_eq!(ExecSpec::parse("native").unwrap(), ExecSpec::Native);
        assert_eq!(ExecSpec::parse("pjrt").unwrap(), ExecSpec::Pjrt);
        assert_eq!(
            ExecSpec::parse("imax").unwrap(),
            ExecSpec::Imax(ImaxSpec::default())
        );
        let asic4 = ExecSpec::parse("imax:asic4").unwrap();
        match &asic4 {
            ExecSpec::Imax(i) => {
                assert!(i.asic);
                assert_eq!(i.lanes, 4);
            }
            other => panic!("expected imax spec, got {other:?}"),
        }
        assert_eq!(asic4.name(), "imax:asic4");
        assert!(ExecSpec::parse("tpu").is_err());
        assert!(ExecSpec::parse("imax:gpu2").is_err());
        assert!(ExecSpec::parse("imax:fpga0").is_err(), "0 lanes rejected");
        assert!(ExecSpec::parse("imax:fpga16").is_err(), "beyond the 8-lane carrier");
    }

    #[test]
    fn imax_option_grammar_roundtrips() {
        // Every option settable from the CLI, in any order.
        let spec = ExecSpec::parse("imax:fpga4:lmm128:naive:dbuf").unwrap();
        match &spec {
            ExecSpec::Imax(i) => {
                assert!(!i.asic);
                assert_eq!(i.lanes, 4);
                assert_eq!(i.lmm_kb, 128);
                assert_eq!(i.mode, TransferMode::Naive);
                assert!(i.overlap);
            }
            other => panic!("expected imax spec, got {other:?}"),
        }
        assert_eq!(spec.name(), "imax:fpga4:lmm128:naive:dbuf");
        assert_eq!(ExecSpec::parse(&spec.name()).unwrap(), spec);
        // Options without an explicit variant keep the default device.
        let d = ExecSpec::parse("imax:dbuf").unwrap();
        assert_eq!(d.name(), "imax:fpga2:dbuf");
        assert_eq!(ExecSpec::parse(&d.name()).unwrap(), d);
        let lmm = ExecSpec::parse("imax:lmm256").unwrap();
        assert_eq!(lmm.name(), "imax:fpga2:lmm256");
        // Order-insensitive.
        assert_eq!(
            ExecSpec::parse("imax:naive:fpga4:dbuf:lmm128").unwrap(),
            ExecSpec::parse("imax:fpga4:lmm128:naive:dbuf").unwrap()
        );
        // Defaults elide: coalesced and lmm64 never appear in the name.
        assert_eq!(ExecSpec::parse("imax:coalesced:lmm64").unwrap().name(), "imax:fpga2");
    }

    #[test]
    fn imax_option_grammar_rejects_nonsense() {
        // LmmConfig asserts 16..=512 — the parser must reject out-of-range
        // sizes rather than panic at build time.
        assert!(ExecSpec::parse("imax:lmm0").is_err());
        assert!(ExecSpec::parse("imax:lmm8").is_err());
        assert!(ExecSpec::parse("imax:lmm1024").is_err());
        assert!(ExecSpec::parse("imax:lmmx").is_err());
        assert!(ExecSpec::parse("imax:bogus").is_err());
        assert!(ExecSpec::parse("imax:").is_err(), "empty option segment");
        assert!(ExecSpec::parse("imax:fpga2:asic2").is_err(), "duplicate variant");
        assert!(ExecSpec::parse("imax:naive:coalesced").is_err(), "duplicate mode");
        assert!(ExecSpec::parse("imax:lmm64:lmm128").is_err(), "duplicate lmm");
        assert!(ExecSpec::parse("imax:dbuf:dbuf").is_err(), "duplicate dbuf");
        assert!(ExecSpec::parse("").is_err());
    }

    #[test]
    fn placement_spec_parses_and_roundtrips() {
        let spec = ExecSpec::parse("0-11:imax:fpga2,12-23:native").unwrap();
        let ExecSpec::Placement(p) = &spec else {
            panic!("expected placement, got {spec:?}");
        };
        assert_eq!(p.rules.len(), 2);
        assert_eq!((p.rules[0].first, p.rules[0].last), (0, 11));
        assert_eq!(p.rules[0].spec, ExecSpec::Imax(ImaxSpec::default()));
        assert_eq!((p.rules[1].first, p.rules[1].last), (12, 23));
        assert_eq!(p.rules[1].spec, ExecSpec::Native);
        assert_eq!(spec.name(), "0-11:imax:fpga2,12-23:native");
        assert_eq!(ExecSpec::parse(&spec.name()).unwrap(), spec);
        // Single-layer rules and out-of-order input normalize.
        let s = ExecSpec::parse("3:native,0-2:imax").unwrap();
        assert_eq!(s.name(), "0-2:imax:fpga2,3:native");
        assert_eq!(ExecSpec::parse(&s.name()).unwrap(), s);
    }

    #[test]
    fn placement_spec_rejects_bad_rules() {
        assert!(ExecSpec::parse("0-3:imax,2-5:native").is_err(), "overlap");
        assert!(ExecSpec::parse("5-3:native").is_err(), "inverted range");
        assert!(ExecSpec::parse("0-3:tpu").is_err(), "unknown inner backend");
        assert!(ExecSpec::parse("0-3").is_err(), "missing backend");
        assert!(ExecSpec::parse("0-x:native").is_err(), "bad layer bound");
        // Nested placement cannot be expressed (a comma splits rules
        // first), but a digit-leading inner spec must not recurse.
        assert!(ExecSpec::parse("0-1:2-3:native").is_err());
    }

    #[test]
    fn placement_layer_coverage_validates() {
        let ExecSpec::Placement(p) = ExecSpec::parse("0-1:imax,2-3:native").unwrap() else {
            unreachable!()
        };
        assert!(p.validate_layers(4).is_ok());
        assert!(p.validate_layers(3).is_ok(), "ranges may extend beyond");
        assert!(p.validate_layers(5).is_err(), "layer 4 uncovered");
        let ExecSpec::Placement(gap) = ExecSpec::parse("0-1:imax,3:native").unwrap() else {
            unreachable!()
        };
        assert!(gap.validate_layers(4).is_err(), "layer 2 uncovered");
    }

    #[test]
    fn registry_builds_native_and_imax() {
        let n = BackendRegistry::build(&ExecSpec::Native).unwrap();
        assert_eq!(n.name(), "native");
        assert!(n.report().modeled.is_none());
        let i = BackendRegistry::build_named("imax").unwrap();
        assert_eq!(i.name(), "imax");
        assert!(i.offload_stats().is_some());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_requires_feature() {
        assert!(BackendRegistry::validate(&ExecSpec::Pjrt).is_err());
        assert!(BackendRegistry::build(&ExecSpec::Pjrt).is_err());
        assert!(!BackendRegistry::available().contains(&"pjrt"));
        // …including behind a placement rule.
        let spec = ExecSpec::parse("0-1:pjrt,2-3:native").unwrap();
        assert!(BackendRegistry::validate(&spec).is_err());
    }

    #[test]
    fn imax_backend_accounts_a_real_run() {
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 8));
        let mut native = BackendRegistry::build(&ExecSpec::Native).unwrap();
        let mut imax = BackendRegistry::build_named("imax").unwrap();
        let a = engine.generate(&[1, 2, 3], 4, &mut Sampler::greedy(), &mut native);
        engine.reset();
        let b = engine.generate(&[1, 2, 3], 4, &mut Sampler::greedy(), &mut imax);
        assert_eq!(a.tokens, b.tokens, "backend choice must not change tokens");
        let rep = imax.report();
        let m = rep.modeled.expect("imax models phases");
        assert!(m.prefill.total() > 0.0 && m.decode.total() > 0.0);
        assert!(rep.offload_ratio.unwrap() > 0.0);
    }

    #[test]
    fn dbuf_overlap_lowers_modeled_time_end_to_end() {
        // Acceptance: the instrumented imax model shows strictly lower
        // modeled decode time with double-buffered overlap enabled than
        // disabled on the same run.
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 5);
        let run = |name: &str| {
            let mut engine = Engine::new(weights.clone());
            let mut exec = BackendRegistry::build_named(name).unwrap();
            let res = engine.generate(&[1, 2, 3, 4], 6, &mut Sampler::greedy(), &mut exec);
            (res.tokens, exec.report())
        };
        let (t0, r0) = run("imax");
        let (t1, r1) = run("imax:dbuf");
        assert_eq!(t0, t1, "overlap modeling must not change tokens");
        let (m0, m1) = (r0.modeled.unwrap(), r1.modeled.unwrap());
        assert!(
            m1.decode.total() < m0.decode.total(),
            "dbuf decode {} !< {}",
            m1.decode.total(),
            m0.decode.total()
        );
        assert!(m1.prefill.total() < m0.prefill.total());
        assert_eq!(m1.decode.exec, m0.decode.exec, "overlap hides LOAD, never EXEC");
        assert!(m1.decode.load < m0.decode.load);
    }

    #[test]
    fn placement_routes_layers_and_merges_reports() {
        // tiny has 4 layers: 0-1 on instrumented imax, 2-3 native. The
        // run must match a homogeneous native run token-for-token, and
        // the merged report must label both backends and model only the
        // imax share.
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 21);
        let spec = ExecSpec::parse("0-1:imax,2-3:native").unwrap();
        if let ExecSpec::Placement(p) = &spec {
            p.validate_layers(cfg.n_layers).unwrap();
        }
        let mut hetero = BackendRegistry::build(&spec).unwrap();
        let mut engine = Engine::new(weights.clone());
        let got = engine.generate(&[1, 2, 3], 5, &mut Sampler::greedy(), &mut hetero);
        let mut reference = Engine::new(weights);
        let want = reference.generate(&[1, 2, 3], 5, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(got.tokens, want.tokens, "placement must not change tokens");

        assert_eq!(hetero.name(), "placement");
        assert!(hetero.offload_stats().is_some(), "imax part tracks offload");
        let rep = hetero.report();
        assert_eq!(rep.backend, "imax:fpga2+native", "joined, not last-wins");
        assert_eq!(rep.parts.len(), 2);
        assert_eq!(rep.parts[0].backend, "imax:fpga2");
        assert_eq!(rep.parts[1].backend, "native");
        let m = rep.modeled.expect("imax part models phases");
        assert!(m.prefill.total() > 0.0 && m.decode.total() > 0.0);
        assert!(rep.parts[0].total_macs > 0);
        assert_eq!(rep.parts[1].total_macs, 0, "native part tracks no macs");

        // The imax part saw only layers 0-1 (+ nothing else): its MACs
        // are strictly below a full-model imax run's.
        let mut full = BackendRegistry::build_named("imax").unwrap();
        let mut e2 = Engine::new(reference.weights.clone());
        e2.generate(&[1, 2, 3], 5, &mut Sampler::greedy(), &mut full);
        assert!(rep.parts[0].total_macs < full.report().total_macs);
    }

    #[test]
    fn merged_reports_sum_workers() {
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 8);
        let run = |seed: u32| {
            let mut engine = Engine::new(weights.clone());
            let mut exec = BackendRegistry::build_named("imax").unwrap();
            engine.forward(seed, Phase::Prefill, true, &mut exec);
            exec.report()
        };
        let (r1, r2) = (run(1), run(2));
        let merged = BackendReport::merged(&[r1.clone(), r2.clone()]);
        assert_eq!(merged.backend, "imax:fpga2");
        assert!(merged.parts.is_empty(), "single backend needs no parts");
        assert_eq!(merged.total_macs, r1.total_macs + r2.total_macs);
        let m = merged.modeled.unwrap();
        let want = r1.modeled.unwrap().prefill.total() + r2.modeled.unwrap().prefill.total();
        assert!((m.prefill.total() - want).abs() < 1e-12);
    }

    #[test]
    fn merged_reports_join_distinct_backends() {
        // Satellite fix: heterogeneous merges used to take the *last*
        // report's name, silently mislabeling the sums.
        let imax = BackendReport {
            backend: "imax:fpga2".to_string(),
            modeled: Some(RunBreakdown::default()),
            offloaded_macs: 10,
            total_macs: 20,
            ..BackendReport::default()
        };
        let native = BackendReport {
            backend: "native".to_string(),
            total_macs: 0,
            ..BackendReport::default()
        };
        let merged = BackendReport::merged(&[imax.clone(), native.clone(), imax.clone()]);
        assert_eq!(merged.backend, "imax:fpga2+native");
        assert_eq!(merged.total_macs, 40);
        assert_eq!(merged.parts.len(), 2);
        assert_eq!(merged.parts[0].backend, "imax:fpga2");
        assert_eq!(merged.parts[0].total_macs, 40);
        assert_eq!(merged.parts[1].backend, "native");
        assert_eq!(merged.parts[1].total_macs, 0);
        // Merging pre-merged reports flattens to the same leaves.
        let again = BackendReport::merged(&[merged.clone(), native]);
        assert_eq!(again.backend, "imax:fpga2+native");
        assert_eq!(again.parts.len(), 2);
        assert_eq!(again.parts[0].total_macs, 40);
    }
}
