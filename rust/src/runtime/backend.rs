//! PJRT-backed kernel execution: a [`MatvecExec`] that routes the tiny
//! model's Q8_0 linear projections through the AOT-compiled Pallas
//! kernels instead of the native Rust kernels.
//!
//! This is the composition proof for the three-layer architecture: the
//! L3 coordinator's engine loop drives L1 Pallas arithmetic (inside the
//! L2-lowered HLO) through PJRT, with identical packed operands to the
//! native path. `rust/tests/integration_runtime.rs` asserts the numerics
//! agree.

use std::collections::HashMap;

use anyhow::Result;

use crate::model::engine::MatvecExec;
use crate::model::graph::MatvecOp;
use crate::quant::{q8_0, GgmlType};
use crate::runtime::artifacts::ArtifactDir;
use crate::runtime::pjrt::{lit, PjrtRuntime};
use crate::tensor::{ActQuant, QTensor, TensorData};

/// Split Q8_0 blocks into the (codes, scales) arrays the Pallas kernel
/// takes (the paper's "four distinct input arrays", §III.D).
pub fn split_q8_blocks(blocks: &[q8_0::BlockQ8_0]) -> (Vec<i8>, Vec<f32>) {
    let mut qs = Vec::with_capacity(blocks.len() * q8_0::QK8_0);
    let mut ds = Vec::with_capacity(blocks.len());
    for b in blocks {
        qs.extend_from_slice(&b.qs);
        ds.push(b.d.to_f32());
    }
    (qs, ds)
}

/// MatvecExec that offloads Q8_0 linears to PJRT artifacts, falling back
/// to native kernels for formats/shapes without an artifact.
pub struct PjrtExec {
    pub rt: PjrtRuntime,
    /// Cached unpacked weight arrays keyed by tensor name (the host-side
    /// DMA staging buffer analogue).
    weight_cache: HashMap<String, (Vec<i8>, Vec<f32>)>,
    /// Kernels executed via PJRT vs native fallback (introspection).
    pub pjrt_calls: usize,
    pub native_calls: usize,
}

impl PjrtExec {
    pub fn new() -> Result<PjrtExec> {
        Ok(PjrtExec {
            rt: PjrtRuntime::new()?,
            weight_cache: HashMap::new(),
            pjrt_calls: 0,
            native_calls: 0,
        })
    }

    fn try_pjrt(
        &mut self,
        op: &MatvecOp,
        w: &QTensor,
        act: &ActQuant,
        out: &mut [f32],
    ) -> Result<bool> {
        if w.ty != GgmlType::Q8_0 {
            return Ok(false);
        }
        let name = ArtifactDir::q8_dot_name(op.rows, op.cols);
        if !self.rt.artifacts.has(&name) {
            return Ok(false);
        }
        let (TensorData::Q8_0(blocks), ActQuant::Q8_0(ablocks)) = (&w.data, act) else {
            return Ok(false);
        };
        let nb = op.cols / q8_0::QK8_0;
        if !self.weight_cache.contains_key(&w.name) {
            self.weight_cache
                .insert(w.name.clone(), split_q8_blocks(blocks));
        }
        let (wqv, wdv) = self.weight_cache.get(&w.name).expect("cached");
        let wq = lit::i8(&[op.rows, op.cols], wqv)?;
        let wd = lit::f32(&[op.rows, nb], wdv)?;
        let (aq, ad) = split_q8_blocks(ablocks);
        let aql = lit::i8(&[op.cols], &aq)?;
        let adl = lit::f32(&[nb], &ad)?;
        let result = self.rt.execute_vec1_f32(&name, &[wq, wd, aql, adl])?;
        out.copy_from_slice(&result);
        Ok(true)
    }
}

impl MatvecExec for PjrtExec {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        match self.try_pjrt(op, w, act, out) {
            Ok(true) => {
                self.pjrt_calls += 1;
            }
            Ok(false) => {
                self.native_calls += 1;
                crate::tensor::matvec_into(w, act, out);
            }
            Err(e) => panic!("pjrt backend failed on {}: {e:#}", w.name),
        }
    }
}
