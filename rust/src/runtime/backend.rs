//! The backend registry: one place that turns a declarative [`ExecSpec`]
//! into the right [`MatvecExec`] implementation — native Rust kernels,
//! the instrumented-IMAX cost model, or (feature `pjrt`) the
//! AOT-compiled Pallas kernels via PJRT.
//!
//! Before the registry, every call site hand-wired `&mut NativeExec` or
//! assembled an `InstrumentedExec` by hand; now `serve`, the CLI, and
//! the examples all construct backends from one spec (`--backend
//! native|imax|pjrt`), which is what lets instrumented-IMAX timing run
//! under the serving loop.

use anyhow::{bail, Result};

use crate::coordinator::offload::OffloadPolicy;
use crate::coordinator::phases::InstrumentedExec;
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;
use crate::imax::timing::RunBreakdown;
use crate::model::engine::{MatvecExec, NativeExec};
use crate::model::graph::{MatvecOp, Phase};
use crate::tensor::{ActQuant, QTensor};

/// IMAX instrumentation parameters (which modeled device shadows the
/// functional run).
#[derive(Clone, Debug, PartialEq)]
pub struct ImaxSpec {
    /// 28 nm ASIC projection instead of the FPGA prototype.
    pub asic: bool,
    pub lanes: usize,
    pub lmm_kb: usize,
    pub mode: TransferMode,
}

impl Default for ImaxSpec {
    fn default() -> ImaxSpec {
        // The paper's chosen configuration: FPGA prototype, 2 lanes,
        // 64 KB LMM, coalesced DMA.
        ImaxSpec {
            asic: false,
            lanes: 2,
            lmm_kb: 64,
            mode: TransferMode::Coalesced,
        }
    }
}

impl ImaxSpec {
    pub fn device(&self) -> ImaxDevice {
        if self.asic {
            ImaxDevice::asic28(self.lanes)
        } else {
            ImaxDevice::fpga(self.lanes)
        }
    }
}

/// Declarative backend selection, parseable from a CLI flag.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecSpec {
    /// Pure-Rust kernels, no instrumentation.
    Native,
    /// Native kernels shadowed by the IMAX cost model (per-phase
    /// EXEC/LOAD/HOST/... accounting and offload stats).
    Imax(ImaxSpec),
    /// AOT-compiled Pallas kernels through PJRT (requires the `pjrt`
    /// cargo feature and `make artifacts`).
    Pjrt,
}

impl ExecSpec {
    /// Parse a `--backend` selector: `native`, `pjrt`, `imax`,
    /// `imax:asic`, `imax:fpga`, optionally with a lane count suffix
    /// (`imax:fpga4`, `imax:asic2`).
    pub fn parse(s: &str) -> Result<ExecSpec> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "native" => return Ok(ExecSpec::Native),
            "pjrt" => return Ok(ExecSpec::Pjrt),
            "imax" => return Ok(ExecSpec::Imax(ImaxSpec::default())),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("imax:") {
            let (asic, lanes_str) = if let Some(l) = rest.strip_prefix("asic") {
                (true, l)
            } else if let Some(l) = rest.strip_prefix("fpga") {
                (false, l)
            } else {
                bail!("unknown imax variant '{rest}' (use imax:fpga[N] or imax:asic[N])");
            };
            let lanes: usize = if lanes_str.is_empty() {
                2
            } else {
                lanes_str
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad lane count '{lanes_str}'"))?
            };
            if !(1..=8).contains(&lanes) {
                bail!("lane count {lanes} out of range (the IMAX carrier has 1..=8 lanes)");
            }
            return Ok(ExecSpec::Imax(ImaxSpec {
                asic,
                lanes,
                ..ImaxSpec::default()
            }));
        }
        bail!("unknown backend '{s}' (available: {})", BackendRegistry::available().join("|"));
    }

    pub fn name(&self) -> String {
        match self {
            ExecSpec::Native => "native".to_string(),
            ExecSpec::Pjrt => "pjrt".to_string(),
            ExecSpec::Imax(i) => format!(
                "imax:{}{}",
                if i.asic { "asic" } else { "fpga" },
                i.lanes
            ),
        }
    }
}

/// Per-backend accounting pulled out after a run; serving aggregates one
/// of these per worker into the `ServeReport`.
#[derive(Clone, Debug, Default)]
pub struct BackendReport {
    pub backend: String,
    /// Modeled IMAX per-phase costs (imax backend only).
    pub modeled: Option<RunBreakdown>,
    /// Offloaded / total dot-product invocations (imax backend only).
    pub offload_ratio: Option<f64>,
    pub offloaded_macs: u64,
    pub total_macs: u64,
    /// Measured engine wall time per phase (imax backend only; the
    /// serving loop measures its own phases for the others).
    pub wall_prefill_s: f64,
    pub wall_decode_s: f64,
}

impl BackendReport {
    /// Merge per-worker reports into one (sums the additive fields).
    pub fn merged(reports: &[BackendReport]) -> BackendReport {
        let mut out = BackendReport::default();
        let mut modeled = RunBreakdown::default();
        let mut any_modeled = false;
        for r in reports {
            out.backend = r.backend.clone();
            if let Some(m) = r.modeled {
                modeled.prefill += m.prefill;
                modeled.decode += m.decode;
                any_modeled = true;
            }
            out.offloaded_macs += r.offloaded_macs;
            out.total_macs += r.total_macs;
            out.wall_prefill_s += r.wall_prefill_s;
            out.wall_decode_s += r.wall_decode_s;
        }
        if any_modeled {
            out.modeled = Some(modeled);
        }
        if out.total_macs > 0 && any_modeled {
            out.offload_ratio = Some(out.offloaded_macs as f64 / out.total_macs as f64);
        }
        out
    }
}

/// A constructed backend executor. Closed enum rather than a trait
/// object so `MatvecExec`'s provided methods (ubatch dispatch) forward
/// without dynamic upcasting.
pub enum BackendExec {
    Native(NativeExec),
    Imax(Box<InstrumentedExec<NativeExec>>),
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtExec),
}

impl BackendExec {
    pub fn name(&self) -> &'static str {
        match self {
            BackendExec::Native(_) => "native",
            BackendExec::Imax(_) => "imax",
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(_) => "pjrt",
        }
    }

    /// Offload statistics table source, when the backend tracks one.
    pub fn offload_stats(&self) -> Option<&crate::coordinator::offload::OffloadStats> {
        match self {
            BackendExec::Imax(i) => Some(&i.stats),
            _ => None,
        }
    }

    pub fn report(&self) -> BackendReport {
        match self {
            BackendExec::Native(_) => BackendReport {
                backend: "native".to_string(),
                ..BackendReport::default()
            },
            BackendExec::Imax(i) => BackendReport {
                backend: "imax".to_string(),
                modeled: Some(i.modeled),
                offload_ratio: Some(i.stats.total_ratio()),
                offloaded_macs: i.stats.offloaded_macs,
                total_macs: i.stats.total_macs,
                wall_prefill_s: i.wall_prefill,
                wall_decode_s: i.wall_decode,
            },
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(_) => BackendReport {
                backend: "pjrt".to_string(),
                ..BackendReport::default()
            },
        }
    }
}

impl MatvecExec for BackendExec {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        match self {
            BackendExec::Native(e) => e.linear(op, w, act, out),
            BackendExec::Imax(e) => e.linear(op, w, act, out),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.linear(op, w, act, out),
        }
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        match self {
            BackendExec::Native(e) => e.linear_ubatch(op, w, acts, outs),
            BackendExec::Imax(e) => e.linear_ubatch(op, w, acts, outs),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.linear_ubatch(op, w, acts, outs),
        }
    }

    fn attn(&mut self, op: &MatvecOp) {
        match self {
            BackendExec::Native(e) => e.attn(op),
            BackendExec::Imax(e) => e.attn(op),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.attn(op),
        }
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        match self {
            BackendExec::Native(e) => e.begin_step(phase, pos),
            BackendExec::Imax(e) => e.begin_step(phase, pos),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.begin_step(phase, pos),
        }
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        match self {
            BackendExec::Native(e) => e.end_step(phase, pos),
            BackendExec::Imax(e) => e.end_step(phase, pos),
            #[cfg(feature = "pjrt")]
            BackendExec::Pjrt(e) => e.end_step(phase, pos),
        }
    }
}

/// Constructs [`BackendExec`]s from [`ExecSpec`]s. Stateless — the
/// registry is the naming + wiring, not a cache.
pub struct BackendRegistry;

impl BackendRegistry {
    /// Selector names accepted by [`ExecSpec::parse`].
    pub fn available() -> Vec<&'static str> {
        let mut names = vec!["native", "imax", "imax:asic"];
        if cfg!(feature = "pjrt") {
            names.push("pjrt");
        }
        names
    }

    /// Cheap validation that `spec` can be built in this binary (used to
    /// fail fast before spawning worker threads).
    pub fn validate(spec: &ExecSpec) -> Result<()> {
        match spec {
            ExecSpec::Native | ExecSpec::Imax(_) => Ok(()),
            ExecSpec::Pjrt => {
                if cfg!(feature = "pjrt") {
                    Ok(())
                } else {
                    bail!(
                        "backend 'pjrt' requires building with `--features pjrt` \
                         (the xla crate + `make artifacts`)"
                    )
                }
            }
        }
    }

    /// Build an executor for `spec`. Each worker thread builds its own
    /// (executors are stateful and not shared).
    pub fn build(spec: &ExecSpec) -> Result<BackendExec> {
        match spec {
            ExecSpec::Native => Ok(BackendExec::Native(NativeExec)),
            ExecSpec::Imax(i) => {
                let dev = i.device();
                let policy = OffloadPolicy::new(LmmConfig::new(i.lmm_kb));
                Ok(BackendExec::Imax(Box::new(InstrumentedExec::new(
                    NativeExec, dev, policy, i.mode,
                ))))
            }
            ExecSpec::Pjrt => {
                Self::validate(spec)?;
                #[cfg(feature = "pjrt")]
                {
                    Ok(BackendExec::Pjrt(PjrtExec::new()?))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    unreachable!("validate rejects pjrt without the feature")
                }
            }
        }
    }

    pub fn build_named(name: &str) -> Result<BackendExec> {
        Self::build(&ExecSpec::parse(name)?)
    }
}

/// Split Q8_0 blocks into the (codes, scales) arrays the Pallas kernel
/// takes (the paper's "four distinct input arrays", §III.D). Shared with
/// the PJRT parity tests; no xla dependency.
pub fn split_q8_blocks(blocks: &[crate::quant::q8_0::BlockQ8_0]) -> (Vec<i8>, Vec<f32>) {
    let mut qs = Vec::with_capacity(blocks.len() * crate::quant::q8_0::QK8_0);
    let mut ds = Vec::with_capacity(blocks.len());
    for b in blocks {
        qs.extend_from_slice(&b.qs);
        ds.push(b.d.to_f32());
    }
    (qs, ds)
}

#[cfg(feature = "pjrt")]
pub use pjrt_exec::PjrtExec;

/// PJRT-backed kernel execution: a [`MatvecExec`] that routes the tiny
/// model's Q8_0 linear projections through the AOT-compiled Pallas
/// kernels instead of the native Rust kernels.
///
/// This is the composition proof for the three-layer architecture: the
/// L3 coordinator's engine loop drives L1 Pallas arithmetic (inside the
/// L2-lowered HLO) through PJRT, with identical packed operands to the
/// native path. `rust/tests/integration_runtime.rs` asserts the numerics
/// agree.
#[cfg(feature = "pjrt")]
mod pjrt_exec {
    use std::collections::HashMap;

    use anyhow::Result;

    use super::split_q8_blocks;
    use crate::model::engine::MatvecExec;
    use crate::model::graph::MatvecOp;
    use crate::quant::{q8_0, GgmlType};
    use crate::runtime::artifacts::ArtifactDir;
    use crate::runtime::pjrt::{lit, PjrtRuntime};
    use crate::tensor::{ActQuant, QTensor, TensorData};

    /// MatvecExec that offloads Q8_0 linears to PJRT artifacts, falling
    /// back to native kernels for formats/shapes without an artifact.
    pub struct PjrtExec {
        pub rt: PjrtRuntime,
        /// Cached unpacked weight arrays keyed by tensor name (the
        /// host-side DMA staging buffer analogue).
        weight_cache: HashMap<String, (Vec<i8>, Vec<f32>)>,
        /// Kernels executed via PJRT vs native fallback (introspection).
        pub pjrt_calls: usize,
        pub native_calls: usize,
    }

    impl PjrtExec {
        pub fn new() -> Result<PjrtExec> {
            Ok(PjrtExec {
                rt: PjrtRuntime::new()?,
                weight_cache: HashMap::new(),
                pjrt_calls: 0,
                native_calls: 0,
            })
        }

        fn try_pjrt(
            &mut self,
            op: &MatvecOp,
            w: &QTensor,
            act: &ActQuant,
            out: &mut [f32],
        ) -> Result<bool> {
            if w.ty != GgmlType::Q8_0 {
                return Ok(false);
            }
            let name = ArtifactDir::q8_dot_name(op.rows, op.cols);
            if !self.rt.artifacts.has(&name) {
                return Ok(false);
            }
            let (TensorData::Q8_0(blocks), ActQuant::Q8_0(ablocks)) = (&w.data, act) else {
                return Ok(false);
            };
            let nb = op.cols / q8_0::QK8_0;
            if !self.weight_cache.contains_key(&w.name) {
                self.weight_cache
                    .insert(w.name.clone(), split_q8_blocks(blocks));
            }
            let (wqv, wdv) = self.weight_cache.get(&w.name).expect("cached");
            let wq = lit::i8(&[op.rows, op.cols], wqv)?;
            let wd = lit::f32(&[op.rows, nb], wdv)?;
            let (aq, ad) = split_q8_blocks(ablocks);
            let aql = lit::i8(&[op.cols], &aq)?;
            let adl = lit::f32(&[nb], &ad)?;
            let result = self.rt.execute_vec1_f32(&name, &[wq, wd, aql, adl])?;
            out.copy_from_slice(&result);
            Ok(true)
        }
    }

    impl MatvecExec for PjrtExec {
        fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
            match self.try_pjrt(op, w, act, out) {
                Ok(true) => {
                    self.pjrt_calls += 1;
                }
                Ok(false) => {
                    self.native_calls += 1;
                    crate::tensor::matvec_into(w, act, out);
                }
                Err(e) => panic!("pjrt backend failed on {}: {e:#}", w.name),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::Engine;
    use crate::model::graph::Phase;
    use crate::model::sampler::Sampler;
    use crate::model::weights::ModelWeights;

    #[test]
    fn spec_parsing_roundtrip() {
        assert_eq!(ExecSpec::parse("native").unwrap(), ExecSpec::Native);
        assert_eq!(ExecSpec::parse("pjrt").unwrap(), ExecSpec::Pjrt);
        assert_eq!(
            ExecSpec::parse("imax").unwrap(),
            ExecSpec::Imax(ImaxSpec::default())
        );
        let asic4 = ExecSpec::parse("imax:asic4").unwrap();
        match &asic4 {
            ExecSpec::Imax(i) => {
                assert!(i.asic);
                assert_eq!(i.lanes, 4);
            }
            other => panic!("expected imax spec, got {other:?}"),
        }
        assert_eq!(asic4.name(), "imax:asic4");
        assert!(ExecSpec::parse("tpu").is_err());
        assert!(ExecSpec::parse("imax:gpu2").is_err());
        assert!(ExecSpec::parse("imax:fpga0").is_err(), "0 lanes rejected");
        assert!(ExecSpec::parse("imax:fpga16").is_err(), "beyond the 8-lane carrier");
    }

    #[test]
    fn registry_builds_native_and_imax() {
        let n = BackendRegistry::build(&ExecSpec::Native).unwrap();
        assert_eq!(n.name(), "native");
        assert!(n.report().modeled.is_none());
        let i = BackendRegistry::build_named("imax").unwrap();
        assert_eq!(i.name(), "imax");
        assert!(i.offload_stats().is_some());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_requires_feature() {
        assert!(BackendRegistry::validate(&ExecSpec::Pjrt).is_err());
        assert!(BackendRegistry::build(&ExecSpec::Pjrt).is_err());
        assert!(!BackendRegistry::available().contains(&"pjrt"));
    }

    #[test]
    fn imax_backend_accounts_a_real_run() {
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 8));
        let mut native = BackendRegistry::build(&ExecSpec::Native).unwrap();
        let mut imax = BackendRegistry::build_named("imax").unwrap();
        let a = engine.generate(&[1, 2, 3], 4, &mut Sampler::greedy(), &mut native);
        engine.reset();
        let b = engine.generate(&[1, 2, 3], 4, &mut Sampler::greedy(), &mut imax);
        assert_eq!(a.tokens, b.tokens, "backend choice must not change tokens");
        let rep = imax.report();
        let m = rep.modeled.expect("imax models phases");
        assert!(m.prefill.total() > 0.0 && m.decode.total() > 0.0);
        assert!(rep.offload_ratio.unwrap() > 0.0);
    }

    #[test]
    fn merged_reports_sum_workers() {
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 8);
        let run = |seed: u32| {
            let mut engine = Engine::new(weights.clone());
            let mut exec = BackendRegistry::build_named("imax").unwrap();
            engine.forward(seed, Phase::Prefill, true, &mut exec);
            exec.report()
        };
        let (r1, r2) = (run(1), run(2));
        let merged = BackendReport::merged(&[r1.clone(), r2.clone()]);
        assert_eq!(merged.backend, "imax");
        assert_eq!(merged.total_macs, r1.total_macs + r2.total_macs);
        let m = merged.modeled.unwrap();
        let want = r1.modeled.unwrap().prefill.total() + r2.modeled.unwrap().prefill.total();
        assert!((m.prefill.total() - want).abs() < 1e-12);
    }
}
