//! Kernel launch queues — the plan half of the plan/submit backend API.
//!
//! The engine no longer assumes a backend consumes kernels eagerly: every
//! dispatch is *recorded* as a data-free [`KernelOp`] descriptor, and the
//! engine marks host dependency boundaries with
//! [`crate::model::engine::KernelExec::submit`] /
//! [`crate::model::engine::KernelExec::sync`]. A queueing backend pushes
//! descriptors into a [`LaunchQueue`] and drains them at submit points,
//! which is what lets it reason about *consecutive* kernels as one
//! submission batch — e.g. the instrumented IMAX model overlapping each
//! queued kernel's DMA LOAD with the previous kernel's EXEC
//! (double-buffered LMM prefetch), something per-kernel eager accounting
//! cannot express.
//!
//! The queue is strictly FIFO: `submit()` drains pending launches in
//! record order, so replaying a drained stream is bit-identical to eager
//! execution. Schedulers built on top may *model* concurrency across a
//! submission batch but must never reorder launches within a dependency
//! chain — `rust/tests/batching_equiv.rs` holds a property test to that
//! contract.

use crate::model::graph::{MatvecOp, Phase};

/// One recorded backend operation: the shape/format metadata of a kernel
/// launch (no operand data — the functional buffers stay owned by the
/// engine) or a step boundary marker.
#[derive(Clone, Debug)]
pub enum KernelOp {
    /// A linear projection processing `batch` activation vectors against
    /// one weight matrix (`batch > 1` for prefill ubatches).
    Linear { op: MatvecOp, batch: usize },
    /// An attention kernel (score or mix) over the KV cache.
    Attn { op: MatvecOp },
    /// Forward-step start marker (one per engine forward call).
    BeginStep { phase: Phase, pos: usize },
    /// Forward-step end marker.
    EndStep { phase: Phase, pos: usize },
}

impl KernelOp {
    /// Whether this descriptor is an actual kernel launch (vs a marker).
    pub fn is_kernel(&self) -> bool {
        matches!(self, KernelOp::Linear { .. } | KernelOp::Attn { .. })
    }

    /// The layer the launch belongs to (`None` for step markers and the
    /// LM head). Launches on one layer form a dependency chain.
    pub fn layer(&self) -> Option<usize> {
        match self {
            KernelOp::Linear { op, .. } | KernelOp::Attn { op } => op.layer,
            _ => None,
        }
    }
}

/// One queued launch: the descriptor, a backend-chosen payload (e.g. the
/// modeled cost), and its position in the queue's launch stream.
#[derive(Clone, Debug)]
pub struct Launch<P> {
    pub op: KernelOp,
    pub payload: P,
    /// Global record order, monotonic per queue.
    pub seq: u64,
    /// Index of the submission batch this launch is flushed in. Stamped
    /// once, at record time — exact, not provisional: the queue is FIFO,
    /// `submit()` drains *everything* pending, and an empty submit
    /// consumes no index, so the batch a pending launch will land in is
    /// always the queue's current submission counter. `submit()` asserts
    /// the contract rather than re-stamping.
    pub submission: u64,
}

/// FIFO launch queue with explicit submission batches.
///
/// `record` appends; `submit` drains everything recorded since the last
/// submit, in record order, stamped with a monotonically increasing
/// submission index. Launches in one submission batch are known to the
/// backend *together* (no host dependency separates them), which is the
/// window cross-kernel optimizations may model over.
pub struct LaunchQueue<P = ()> {
    pending: Vec<Launch<P>>,
    next_seq: u64,
    n_submissions: u64,
    n_launched: u64,
}

impl<P> LaunchQueue<P> {
    pub fn new() -> LaunchQueue<P> {
        LaunchQueue {
            pending: Vec::new(),
            next_seq: 0,
            n_submissions: 0,
            n_launched: 0,
        }
    }

    /// Record one launch; returns its sequence number. The launch's
    /// `submission` index is stamped here and is final — see
    /// [`Launch::submission`] for why the FIFO total-drain discipline
    /// makes the record-time value exact.
    pub fn record(&mut self, op: KernelOp, payload: P) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(Launch { op, payload, seq, submission: self.n_submissions });
        seq
    }

    /// Flush: drain every pending launch in record (FIFO) order as one
    /// submission batch. An empty queue yields an empty batch and does
    /// not consume a submission index.
    pub fn submit(&mut self) -> Vec<Launch<P>> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        let batch = std::mem::take(&mut self.pending);
        debug_assert!(
            batch.iter().all(|l| l.submission == self.n_submissions),
            "record-time submission stamps must match the batch being flushed"
        );
        self.n_submissions += 1;
        self.n_launched += batch.len() as u64;
        batch
    }

    /// Launches recorded but not yet submitted.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Non-empty submission batches flushed so far.
    pub fn submissions(&self) -> u64 {
        self.n_submissions
    }

    /// Total launches flushed so far.
    pub fn launched(&self) -> u64 {
        self.n_launched
    }
}

impl<P> Default for LaunchQueue<P> {
    fn default() -> LaunchQueue<P> {
        LaunchQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::LinearKind;
    use crate::model::graph::OpKind;
    use crate::quant::GgmlType;

    fn lop(layer: usize) -> KernelOp {
        KernelOp::Linear {
            op: MatvecOp {
                kind: OpKind::Linear(LinearKind::QProj),
                layer: Some(layer),
                wty: GgmlType::Q8_0,
                rows: 8,
                cols: 32,
            },
            batch: 1,
        }
    }

    #[test]
    fn submit_drains_in_fifo_order() {
        let mut q: LaunchQueue<usize> = LaunchQueue::new();
        for i in 0..5 {
            q.record(lop(i), i);
        }
        assert_eq!(q.pending_len(), 5);
        let batch = q.submit();
        assert!(q.is_empty());
        let payloads: Vec<usize> = batch.iter().map(|l| l.payload).collect();
        assert_eq!(payloads, vec![0, 1, 2, 3, 4], "FIFO replay order");
        for (i, l) in batch.iter().enumerate() {
            assert_eq!(l.seq, i as u64, "seq is record order");
            assert_eq!(l.submission, 0);
        }
    }

    #[test]
    fn submission_indices_are_monotonic() {
        let mut q: LaunchQueue<()> = LaunchQueue::new();
        q.record(lop(0), ());
        let a = q.submit();
        q.record(lop(1), ());
        q.record(lop(1), ());
        let b = q.submit();
        assert_eq!(a[0].submission, 0);
        assert!(b.iter().all(|l| l.submission == 1));
        assert_eq!(q.submissions(), 2);
        assert_eq!(q.launched(), 3);
    }

    #[test]
    fn empty_submit_is_free() {
        let mut q: LaunchQueue<()> = LaunchQueue::new();
        assert!(q.submit().is_empty());
        assert_eq!(q.submissions(), 0, "no submission index consumed");
        q.record(lop(0), ());
        q.submit();
        assert!(q.submit().is_empty());
        assert_eq!(q.submissions(), 1);
    }

    /// Pins the `Launch::submission` stamping contract: the index is
    /// assigned at record time and `submit()` never changes it — exact
    /// because empty submits consume no index and every flush drains the
    /// whole pending set.
    #[test]
    fn submission_stamp_is_final_at_record_time() {
        let mut q: LaunchQueue<()> = LaunchQueue::new();
        // Empty submits before anything is pending consume no index, so
        // the first recorded launch still lands in batch 0.
        q.submit();
        q.submit();
        q.record(lop(0), ());
        let a = q.submit();
        assert_eq!(a[0].submission, 0, "first non-empty flush is batch 0");
        // Interleave another empty submit, then a two-launch batch: both
        // launches carry the batch index they were recorded under.
        assert!(q.submit().is_empty());
        q.record(lop(1), ());
        q.record(lop(2), ());
        let b = q.submit();
        assert!(b.iter().all(|l| l.submission == 1));
        assert_eq!(q.submissions(), 2);
    }

    #[test]
    fn markers_are_not_kernels() {
        assert!(lop(0).is_kernel());
        assert_eq!(lop(3).layer(), Some(3));
        let b = KernelOp::BeginStep { phase: Phase::Decode, pos: 4 };
        assert!(!b.is_kernel());
        assert_eq!(b.layer(), None);
    }
}
