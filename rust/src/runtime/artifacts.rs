//! Artifact directory handling: locating `artifacts/`, parsing the
//! manifest that `python/compile/aot.py` writes, and checking that the
//! shapes the Rust side expects match what was lowered.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One manifest entry: artifact name → (shape signature, sha16).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub shape_sig: String,
    pub sha16: String,
}

/// A located artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub dir: PathBuf,
    pub entries: HashMap<String, ArtifactEntry>,
}

impl ArtifactDir {
    /// Locate artifacts: `$IMAX_ARTIFACTS`, `./artifacts`, or the crate
    /// root's `artifacts/` (tests run from the workspace root).
    pub fn locate() -> Result<ArtifactDir> {
        let candidates = [
            std::env::var("IMAX_ARTIFACTS").ok().map(PathBuf::from),
            Some(PathBuf::from("artifacts")),
            Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")),
        ];
        for cand in candidates.into_iter().flatten() {
            if cand.join("manifest.txt").exists() {
                return ArtifactDir::open(&cand);
            }
        }
        bail!("artifacts/ not found — run `make artifacts` first")
    }

    pub fn open(dir: &Path) -> Result<ArtifactDir> {
        let manifest = dir.join("manifest.txt");
        let text = fs::read_to_string(&manifest)
            .with_context(|| format!("read {}", manifest.display()))?;
        let mut entries = HashMap::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split('\t').collect();
            if parts.len() != 3 {
                bail!("manifest line {} malformed: {line:?}", i + 1);
            }
            entries.insert(
                parts[0].to_string(),
                ArtifactEntry {
                    name: parts[0].to_string(),
                    shape_sig: parts[1].to_string(),
                    sha16: parts[2].to_string(),
                },
            );
        }
        Ok(ArtifactDir {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Path of an artifact's HLO text file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        if !self.entries.contains_key(name) {
            bail!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()
            );
        }
        let p = self.dir.join(format!("{name}.hlo.txt"));
        if !p.exists() {
            bail!("artifact file missing: {}", p.display());
        }
        Ok(p)
    }

    /// The Q8_0 dot artifact name for a (rows, cols) shape.
    pub fn q8_dot_name(rows: usize, cols: usize) -> String {
        format!("q8_0_dot_{rows}x{cols}")
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_finds_built_artifacts() {
        // Skip silently when artifacts haven't been generated (CI order);
        // `make test` always builds them first.
        let Ok(ad) = ArtifactDir::locate() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(ad.entries.len() >= 10);
        assert!(ad.has("lm_head_q8"));
        assert!(ad.has(&ArtifactDir::q8_dot_name(256, 256)));
        let p = ad.hlo_path("lm_head_q8").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.starts_with("HloModule"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Ok(ad) = ArtifactDir::locate() else {
            return;
        };
        assert!(ad.hlo_path("does_not_exist").is_err());
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("imax_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "bad line without tabs\n").unwrap();
        assert!(ArtifactDir::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
