//! Quantized tensors and the matrix–vector kernels the engine dispatches.
//!
//! Weights are 2-D row-major quantized tensors ([`QTensor`]); activations
//! are f32 vectors that get quantized once per matvec into the format the
//! weight kernel consumes ([`ActQuant`]) — exactly llama.cpp's structure,
//! where `quantize_row_q8_K/q8_0` runs once and the row kernels reuse it.
//! In the paper's system the quantized activation row is one of the "four
//! distinct input arrays" coalesced into a single DMA transfer (§III.D).

use crate::quant::{fp16, q3_k, q6_k, q8_0, q8_k, GgmlType};
use crate::util::f16::F16;

/// Storage for one quantized 2-D tensor.
#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    F16(Vec<F16>),
    Q8_0(Vec<q8_0::BlockQ8_0>),
    Q6K(Vec<q6_k::BlockQ6K>),
    Q3K(Vec<q3_k::BlockQ3K>),
}

/// A row-major 2-D quantized tensor (`rows × cols`). 1-D vectors are
/// represented as `rows = 1`.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub name: String,
    pub ty: GgmlType,
    pub rows: usize,
    pub cols: usize,
    pub data: TensorData,
}

impl QTensor {
    /// Quantize an f32 matrix (row-major, `rows × cols`) into `ty`.
    /// `cols` must be a multiple of the format's block size.
    pub fn quantize(name: &str, ty: GgmlType, rows: usize, cols: usize, x: &[f32]) -> QTensor {
        assert_eq!(x.len(), rows * cols, "{name}: shape mismatch");
        assert_eq!(
            cols % ty.block_size(),
            0,
            "{name}: cols {cols} not aligned to {} block {}",
            ty.name(),
            ty.block_size()
        );
        let data = match ty {
            GgmlType::F32 => TensorData::F32(x.to_vec()),
            GgmlType::F16 => TensorData::F16(fp16::encode_row(x)),
            GgmlType::Q8_0 => TensorData::Q8_0(q8_0::quantize_row(x)),
            GgmlType::Q6K => TensorData::Q6K(q6_k::quantize_row(x)),
            GgmlType::Q3K => TensorData::Q3K(q3_k::quantize_row(x)),
        };
        QTensor {
            name: name.to_string(),
            ty,
            rows,
            cols,
            data,
        }
    }

    /// Total serialized size in bytes — the quantity the paper's DMA/LMM
    /// analysis is driven by.
    pub fn nbytes(&self) -> usize {
        self.rows * self.ty.row_bytes(self.cols)
    }

    /// Bytes of one row (one dot-product operand tile).
    pub fn row_bytes(&self) -> usize {
        self.ty.row_bytes(self.cols)
    }

    pub fn nelems(&self) -> usize {
        self.rows * self.cols
    }

    /// Dequantize row `r` to f32 (test/debug path).
    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        assert!(r < self.rows);
        let bpr = self.cols / self.ty.block_size();
        match &self.data {
            TensorData::F32(v) => v[r * self.cols..(r + 1) * self.cols].to_vec(),
            TensorData::F16(v) => v[r * self.cols..(r + 1) * self.cols]
                .iter()
                .map(|h| h.to_f32())
                .collect(),
            TensorData::Q8_0(b) => q8_0::dequantize_row(&b[r * bpr..(r + 1) * bpr], self.cols),
            TensorData::Q6K(b) => q6_k::dequantize_row(&b[r * bpr..(r + 1) * bpr], self.cols),
            TensorData::Q3K(b) => q3_k::dequantize_row(&b[r * bpr..(r + 1) * bpr], self.cols),
        }
    }

    /// Dequantize the whole tensor (row-major).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.nelems());
        for r in 0..self.rows {
            out.extend(self.dequantize_row(r));
        }
        out
    }
}

/// Activations quantized into the format a weight type's kernel consumes.
#[derive(Clone, Debug)]
pub enum ActQuant {
    /// f32 passthrough (for F32/F16 weight kernels).
    F32(Vec<f32>),
    /// Q8_0 blocks (for Q8_0 weights — ggml q8_0×q8_0 path).
    Q8_0(Vec<q8_0::BlockQ8_0>),
    /// Q8_K super-blocks (for Q6_K / Q3_K weights).
    Q8K(Vec<q8_k::BlockQ8K>),
}

impl ActQuant {
    /// Quantize activation vector `x` for a weight of type `wty`.
    pub fn for_weight(wty: GgmlType, x: &[f32]) -> ActQuant {
        match wty {
            GgmlType::F32 | GgmlType::F16 => ActQuant::F32(x.to_vec()),
            GgmlType::Q8_0 => ActQuant::Q8_0(q8_0::quantize_row(x)),
            GgmlType::Q6K | GgmlType::Q3K => ActQuant::Q8K(q8_k::quantize_row(x)),
        }
    }

    /// Serialized byte size of the quantized activation row (DMA operand).
    pub fn nbytes(&self) -> usize {
        match self {
            ActQuant::F32(v) => 4 * v.len(),
            ActQuant::Q8_0(b) => b.len() * q8_0::BLOCK_BYTES,
            ActQuant::Q8K(b) => b.len() * q8_k::BLOCK_BYTES,
        }
    }
}

/// `y[r] = dot(W[r, :], x)` for one row.
#[inline]
pub fn row_dot(w: &QTensor, r: usize, act: &ActQuant) -> f32 {
    let bpr = w.cols / w.ty.block_size();
    match (&w.data, act) {
        (TensorData::F32(v), ActQuant::F32(x)) => v[r * w.cols..(r + 1) * w.cols]
            .iter()
            .zip(x.iter())
            .map(|(a, b)| a * b)
            .sum(),
        (TensorData::F16(v), ActQuant::F32(x)) => {
            fp16::vec_dot_f16(&v[r * w.cols..(r + 1) * w.cols], x)
        }
        (TensorData::Q8_0(b), ActQuant::Q8_0(a)) => {
            q8_0::vec_dot(&b[r * bpr..(r + 1) * bpr], a)
        }
        (TensorData::Q6K(b), ActQuant::Q8K(a)) => q6_k::vec_dot(&b[r * bpr..(r + 1) * bpr], a),
        (TensorData::Q3K(b), ActQuant::Q8K(a)) => q3_k::vec_dot(&b[r * bpr..(r + 1) * bpr], a),
        _ => panic!(
            "tensor '{}': weight {:?} incompatible with activation format",
            w.name, w.ty
        ),
    }
}

/// Full matvec `y = W x` (`W: rows × cols`, `x: cols`), quantizing the
/// activation once. This is the unit of work the paper offloads to IMAX.
pub fn matvec(w: &QTensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.cols, "{}: matvec dim mismatch", w.name);
    let act = ActQuant::for_weight(w.ty, x);
    matvec_pre(w, &act)
}

/// Matvec with a pre-quantized activation (reused across weight tensors
/// that share an input, e.g. q/k/v projections).
pub fn matvec_pre(w: &QTensor, act: &ActQuant) -> Vec<f32> {
    (0..w.rows).map(|r| row_dot(w, r, act)).collect()
}

/// Matvec into a caller-provided buffer (hot-path variant; avoids the
/// per-call allocation in the decode loop).
pub fn matvec_into(w: &QTensor, act: &ActQuant, out: &mut [f32]) {
    assert_eq!(out.len(), w.rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = row_dot(w, r, act);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dense_matvec(w: &[f32], rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
        (0..rows)
            .map(|r| {
                w[r * cols..(r + 1) * cols]
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }

    #[test]
    fn matvec_all_formats_close_to_dense() {
        let mut rng = Rng::new(14);
        let (rows, cols) = (8, 512);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; cols];
        rng.fill_normal(&mut w, 0.3);
        rng.fill_normal(&mut x, 1.0);
        let want = dense_matvec(&w, rows, cols, &x);
        let scale = (cols as f32).sqrt() * 0.3;

        for (ty, tol_mult) in [
            (GgmlType::F32, 1e-6),
            (GgmlType::F16, 1e-3),
            (GgmlType::Q8_0, 0.02),
            (GgmlType::Q6K, 0.05),
            (GgmlType::Q3K, 0.25),
        ] {
            let q = QTensor::quantize("w", ty, rows, cols, &w);
            let got = matvec(&q, &x);
            for (g, wnt) in got.iter().zip(&want) {
                assert!(
                    (g - wnt).abs() <= tol_mult * scale * 3.0 + 1e-4,
                    "{}: got {g} want {wnt}",
                    ty.name()
                );
            }
        }
    }

    #[test]
    fn nbytes_matches_format_math() {
        let w = vec![0.0f32; 4 * 256];
        let q = QTensor::quantize("w", GgmlType::Q3K, 4, 256, &w);
        assert_eq!(q.nbytes(), 4 * 110);
        assert_eq!(q.row_bytes(), 110);
    }

    #[test]
    fn matvec_into_matches_matvec() {
        let mut rng = Rng::new(15);
        let (rows, cols) = (5, 64);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; cols];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut x, 1.0);
        let q = QTensor::quantize("w", GgmlType::Q8_0, rows, cols, &w);
        let a = matvec(&q, &x);
        let act = ActQuant::for_weight(q.ty, &x);
        let mut b = vec![0.0f32; rows];
        matvec_into(&q, &act, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "not aligned")]
    fn misaligned_cols_rejected() {
        QTensor::quantize("w", GgmlType::Q6K, 1, 100, &vec![0.0; 100]);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn mismatched_activation_rejected() {
        let q = QTensor::quantize("w", GgmlType::Q8_0, 1, 32, &vec![0.0; 32]);
        let act = ActQuant::F32(vec![0.0; 32]);
        row_dot(&q, 0, &act);
    }

    #[test]
    fn shared_activation_reuse_consistent() {
        let mut rng = Rng::new(16);
        let cols = 256;
        let mut w1 = vec![0.0f32; 4 * cols];
        let mut w2 = vec![0.0f32; 2 * cols];
        let mut x = vec![0.0f32; cols];
        rng.fill_normal(&mut w1, 1.0);
        rng.fill_normal(&mut w2, 1.0);
        rng.fill_normal(&mut x, 1.0);
        let q1 = QTensor::quantize("q", GgmlType::Q6K, 4, cols, &w1);
        let q2 = QTensor::quantize("k", GgmlType::Q6K, 2, cols, &w2);
        let act = ActQuant::for_weight(GgmlType::Q6K, &x);
        assert_eq!(matvec_pre(&q1, &act), matvec(&q1, &x));
        assert_eq!(matvec_pre(&q2, &act), matvec(&q2, &x));
    }
}
