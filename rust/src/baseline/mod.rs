//! Comparison platforms and calibration anchors.
//!
//! * [`gpu`] — roofline models of the paper's commercial comparators
//!   (RTX 4090, GTX 1080 Ti, Jetson AGX Orin) with the TDP power model.
//! * [`calibration`] — the paper's published numbers, used to pin the
//!   simulator's shape (asserted by `rust/tests/integration_experiments`).

pub mod calibration;
pub mod gpu;

pub use gpu::GpuDevice;
