//! Calibration anchors: the paper's published measurements (DESIGN.md §6).
//!
//! Our substrate is a simulator, not the authors' testbed, so absolute
//! numbers are not expected to match; these anchors pin the *shape* —
//! who wins, by roughly what factor, where the crossovers fall — and the
//! integration tests assert each one. The constants are quoted verbatim
//! from the paper.

/// §V.B macro breakdown of Qwen3-0.6B Q3_K_S [32:16] on the FPGA.
pub mod anchor_breakdown {
    pub const TOTAL_S: f64 = 16.3;
    pub const EXEC_S: f64 = 4.47;
    pub const HOST_S: f64 = 5.43;
    pub const LOAD_S: f64 = 5.31;
    pub const DRAIN_S: f64 = 0.31;
    pub const CONFIG_S: f64 = 0.78; // CONF + REGV + RANGE lumped
    pub const EXEC_SHARE: f64 = 0.274;
    pub const LOAD_SHARE: f64 = 0.326;
}

/// Fig 12 PDP anchors (J), Qwen3-1.7B Q8_0 [16:4].
pub mod anchor_pdp_17b_q8_16_4 {
    pub const IMAX28: f64 = 15.5;
    pub const RTX4090: f64 = 28.4;
    pub const GTX1080TI: f64 = 35.1;
    pub const JETSON: f64 = 22.1;
}

/// Fig 12 PDP anchors (J), Qwen3-8B Q8_0 [32:16] — the inversion case.
pub mod anchor_pdp_8b_q8_32_16 {
    pub const IMAX28: f64 = 1148.7;
    pub const RTX4090: f64 = 547.9;
    pub const JETSON: f64 = 378.0;
}

/// Fig 13 EDP anchors (J·s), Qwen3-0.6B Q3_K_S [32:16].
pub mod anchor_edp_06b_q3_32_16 {
    pub const IMAX28: f64 = 118.9;
    pub const RTX4090: f64 = 216.8;
    pub const JETSON: f64 = 153.6;
    /// Representative IMAX 28 nm latency quoted in §IV.B.
    pub const IMAX28_LATENCY_S: f64 = 5.63;
}

/// Fig 13 EDP anchors, Qwen3-1.7B Q8_0 [32:16] — Jetson wins EDP.
pub mod anchor_edp_17b_q8_32_16 {
    pub const IMAX28: f64 = 413.6;
    pub const IMAX28_LATENCY_S: f64 = 14.7;
    pub const JETSON: f64 = 216.6;
    pub const JETSON_LATENCY_S: f64 = 1.9;
}

/// §III.D DMA coalescing gains.
pub mod anchor_coalescing {
    pub const LOAD_SPEEDUP: f64 = 1.2;
    pub const DRAIN_SPEEDUP: f64 = 4.8;
}

/// Headline claims (§I / §VI).
pub mod anchor_headline {
    pub const PDP_VS_RTX_MAX: f64 = 44.4;
    pub const PDP_VS_GTX_MAX: f64 = 54.0;
    pub const PDP_VS_JETSON_MAX: f64 = 13.6;
    pub const EDP_VS_RTX_MAX: f64 = 11.5;
    pub const EDP_VS_GTX_MAX: f64 = 15.0;
}

/// Table 2 total offload ratios.
pub mod anchor_offload_totals {
    pub const Q06B_Q3KS: f64 = 0.9994;
    pub const Q06B_Q8: f64 = 0.9113;
    pub const Q17B_Q3KS: f64 = 0.9427;
    pub const Q17B_Q8: f64 = 0.8559;
    pub const Q8B_Q3KS: f64 = 0.8823;
    pub const Q8B_Q8: f64 = 0.1151;
}

/// Relative tolerance used when comparing a simulated value against a
/// paper anchor: factor-of-N agreement (shape preservation, not absolute
/// reproduction).
pub fn within_factor(got: f64, anchor: f64, factor: f64) -> bool {
    if got <= 0.0 || anchor <= 0.0 {
        return false;
    }
    let r = got / anchor;
    r <= factor && r >= 1.0 / factor
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_breakdown_sums() {
        use anchor_breakdown::*;
        assert!((EXEC_S + HOST_S + LOAD_S + DRAIN_S + CONFIG_S - TOTAL_S).abs() < 1e-9);
        assert!((EXEC_S / TOTAL_S - EXEC_SHARE).abs() < 0.01);
        assert!((LOAD_S / TOTAL_S - LOAD_SHARE).abs() < 0.01);
    }

    #[test]
    fn jetson_edp_consistency() {
        // The paper's own numbers: EDP = L² × P → 1.9² × 60 = 216.6 ✓
        use anchor_edp_17b_q8_32_16::*;
        assert!((JETSON_LATENCY_S * JETSON_LATENCY_S * 60.0 - JETSON).abs() < 0.1);
    }

    #[test]
    fn within_factor_basics() {
        assert!(within_factor(10.0, 10.0, 1.5));
        assert!(within_factor(14.0, 10.0, 1.5));
        assert!(!within_factor(20.0, 10.0, 1.5));
        assert!(!within_factor(-1.0, 10.0, 1.5));
    }
}
