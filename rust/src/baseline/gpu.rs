//! Analytic GPU comparators (paper Table 1's commercial platforms).
//!
//! The paper measured llama.cpp on an RTX 4090, a GTX 1080 Ti and a
//! Jetson AGX Orin; we have none of them (DESIGN.md §2), so each is a
//! roofline model: prefill is compute-bound (batched GEMM at an effective
//! fraction of peak), decode is memory-bandwidth-bound (the whole weight
//! set streams per token), plus per-token launch overhead and a fixed
//! framework setup. Parameters are calibrated against the paper's own
//! published per-device numbers (DESIGN.md §6) and then frozen.

use crate::coordinator::hybrid::Workload;
use crate::model::config::{model_bytes, LinearKind, QuantScheme};
use crate::model::graph::ops_for_token;
use crate::power::EnergyReport;

/// One commercial comparison platform.
#[derive(Clone, Debug)]
pub struct GpuDevice {
    pub name: &'static str,
    /// Nominal TDP (W) — the paper's power model input.
    pub tdp_w: f64,
    /// Host CPU TDP applied during host-primary phases (W).
    pub host_tdp_w: f64,
    /// Peak memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Effective fraction of peak bandwidth llama.cpp decode achieves.
    pub bw_eff: f64,
    /// Effective compute throughput for prefill GEMMs (MAC/s).
    pub flops_eff: f64,
    /// Fixed framework/model setup charged to E2E latency (s).
    pub setup_s: f64,
    /// Per-token GPU launch/dispatch overhead (s).
    pub per_token_s: f64,
    /// Per-token host-side overhead (sampling over the 152K vocab,
    /// detokenization, graph rebuild) — zero for the integrated Jetson,
    /// whose budget folds it into per_token_s.
    pub per_token_host_s: f64,
    /// K-quant decode slowdown vs Q8_0 (CUDA K-quant kernels are less
    /// bandwidth-efficient).
    pub kquant_penalty: f64,
    /// Table 1 metadata.
    pub process_nm: u32,
    pub chip_area_mm2: f64,
    pub cores: u32,
    pub freq_mhz: u32,
    pub memory: &'static str,
}

impl GpuDevice {
    pub fn rtx4090() -> GpuDevice {
        GpuDevice {
            name: "NVIDIA RTX 4090",
            tdp_w: 450.0,
            host_tdp_w: 240.0, // Xeon W5-2455X
            mem_bw: 1008e9,
            bw_eff: 0.65,
            flops_eff: 35e12,
            setup_s: 0.45,
            per_token_s: 2.6e-3,
            per_token_host_s: 6.0e-3,
            kquant_penalty: 1.6,
            process_nm: 5,
            chip_area_mm2: 608.0,
            cores: 16384,
            freq_mhz: 2520,
            memory: "24 GB GDDR6X",
        }
    }

    pub fn gtx1080ti() -> GpuDevice {
        GpuDevice {
            name: "NVIDIA GTX 1080 Ti",
            tdp_w: 250.0,
            host_tdp_w: 240.0,
            mem_bw: 484e9,
            bw_eff: 0.60,
            flops_eff: 9e12,
            setup_s: 0.55,
            per_token_s: 4.5e-3,
            per_token_host_s: 12.0e-3,
            kquant_penalty: 1.9,
            process_nm: 16,
            chip_area_mm2: 471.0,
            cores: 3584,
            freq_mhz: 1582,
            memory: "11 GB GDDR5X",
        }
    }

    pub fn jetson_orin() -> GpuDevice {
        GpuDevice {
            name: "Jetson AGX Orin 32GB",
            tdp_w: 60.0, // nominal maximum-performance mode
            host_tdp_w: 0.0, // integrated — the 60 W budget covers the SoC
            mem_bw: 204.8e9,
            bw_eff: 0.60,
            flops_eff: 5e12,
            setup_s: 0.9,
            per_token_s: 20.0e-3,
            per_token_host_s: 0.0,
            kquant_penalty: 1.7,
            process_nm: 8,
            chip_area_mm2: 200.0,
            cores: 1792,
            freq_mhz: 930,
            memory: "32 GB LPDDR5",
        }
    }

    pub fn all() -> Vec<GpuDevice> {
        vec![Self::rtx4090(), Self::gtx1080ti(), Self::jetson_orin()]
    }

    /// Bytes the decode phase must stream per token: every weight tensor
    /// except the embedding lookup.
    fn decode_bytes_per_token(w: &Workload) -> f64 {
        let total = model_bytes(&w.cfg, w.scheme) as f64;
        let embed = w.cfg.vocab_size as f64
            * LinearKind::LmHead.weight_type(w.scheme).row_bytes(w.cfg.d_model) as f64;
        total - embed
    }

    /// Prefill MAC count (batched over the prompt).
    fn prefill_macs(w: &Workload) -> f64 {
        let per_tok: u64 = ops_for_token(&w.cfg, w.scheme, w.n_in - 1, false)
            .iter()
            .map(|o| o.macs())
            .sum();
        per_tok as f64 * w.n_in as f64
    }

    /// GPU-active time: compute + memory streaming + launches.
    pub fn active_seconds(&self, w: &Workload) -> f64 {
        let kq = if w.scheme == QuantScheme::Q3KS {
            self.kquant_penalty
        } else {
            1.0
        };
        let prefill = Self::prefill_macs(w) / self.flops_eff * kq;
        let decode = w.n_out.saturating_sub(1) as f64 * Self::decode_bytes_per_token(w) * kq
            / (self.mem_bw * self.bw_eff);
        // K-quant graphs dispatch more (smaller) kernels per layer, so
        // the per-token overhead scales with the penalty too.
        let launches = (w.n_in + w.n_out) as f64 * (self.per_token_s + self.per_token_host_s) * kq;
        prefill + decode + launches
    }

    /// E2E latency (the Fig 11 / PDP / EDP quantity). The paper's metric
    /// is generation latency under load — framework/model setup
    /// (`setup_s`) is excluded, matching its per-device numbers (the
    /// 28.4 J RTX PDP on 1.7B Q8_0 [16:4] implies a sub-0.1 s latency,
    /// impossible with CUDA context setup included).
    pub fn e2e_seconds(&self, w: &Workload) -> f64 {
        self.active_seconds(w)
    }

    /// Energy per the paper's TDP model: nominal TDP over the active
    /// latency ("performance under peak load conditions").
    pub fn energy(&self, w: &Workload) -> EnergyReport {
        EnergyReport::from_phases(&[(self.active_seconds(w), self.tdp_w)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn wl(cfg: ModelConfig, scheme: QuantScheme, n_in: usize, n_out: usize) -> Workload {
        Workload {
            cfg,
            scheme,
            n_in,
            n_out,
        }
    }

    #[test]
    fn rtx_is_fastest_everywhere() {
        for w in [
            wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16),
            wl(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 16, 4),
            wl(ModelConfig::qwen3_8b(), QuantScheme::Q8_0, 32, 16),
        ] {
            let rtx = GpuDevice::rtx4090().e2e_seconds(&w);
            let gtx = GpuDevice::gtx1080ti().e2e_seconds(&w);
            let jet = GpuDevice::jetson_orin().e2e_seconds(&w);
            assert!(rtx < gtx && rtx < jet, "{}: {rtx} {gtx} {jet}", w.label());
        }
    }

    #[test]
    fn decode_dominates_for_large_models() {
        let d = GpuDevice::jetson_orin();
        let w = wl(ModelConfig::qwen3_8b(), QuantScheme::Q8_0, 8, 16);
        let decode = 15.0 * GpuDevice::decode_bytes_per_token(&w) / (d.mem_bw * d.bw_eff);
        assert!(decode / d.active_seconds(&w) > 0.5);
    }

    #[test]
    fn jetson_energy_competitive_despite_slower() {
        // The 60 W Jetson burns less energy than the 450 W RTX on
        // memory-bound workloads even while being slower.
        let w = wl(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 32, 16);
        let rtx = GpuDevice::rtx4090();
        let jet = GpuDevice::jetson_orin();
        assert!(jet.e2e_seconds(&w) > rtx.e2e_seconds(&w));
        assert!(jet.energy(&w).pdp_j() < rtx.energy(&w).pdp_j());
    }

    #[test]
    fn kquant_penalty_applies() {
        let d = GpuDevice::rtx4090();
        let q8 = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q8_0, 32, 16);
        let q3 = wl(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16);
        // Q3_K_S moves fewer bytes but pays the kernel penalty; per-byte
        // time must be higher.
        let t8 = d.active_seconds(&q8);
        let t3 = d.active_seconds(&q3);
        let b8 = GpuDevice::decode_bytes_per_token(&q8);
        let b3 = GpuDevice::decode_bytes_per_token(&q3);
        assert!(b3 < b8);
        assert!(t3 / b3 > t8 / b8);
    }

    #[test]
    fn table1_metadata_present() {
        for d in GpuDevice::all() {
            assert!(d.process_nm > 0 && d.chip_area_mm2 > 0.0 && d.cores > 0);
        }
    }
}
