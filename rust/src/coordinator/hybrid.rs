//! The hybrid host/IMAX workload simulator — the timing path that prices a
//! full `[n_in : n_out]` inference run at paper scale (Qwen3 0.6B/1.7B/8B)
//! without materializing weights.
//!
//! Uses the same kernel-call enumeration as the functional engine
//! ([`crate::model::graph`]); prefill is costed as one batched ubatch
//! (weights amortized over the prompt — llama.cpp behaviour, and the
//! origin of the paper's prefill-compute-bound / decode-LOAD-bound
//! duality), decode as per-token steps.

use crate::coordinator::offload::{OffloadPolicy, OffloadStats};
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::pio::ConfTracker;
use crate::imax::sim;
use crate::imax::timing::{PhaseCost, RunBreakdown};
use crate::model::config::{ModelConfig, QuantScheme};
use crate::model::graph::{ops_for_token, MatvecOp, OpKind, Phase};

/// A `[n_in : n_out]` workload on a model+scheme (the paper's notation).
#[derive(Clone, Debug)]
pub struct Workload {
    /// Model hyperparameters the workload runs on.
    pub cfg: ModelConfig,
    /// Weight quantization scheme priced by the cost model.
    pub scheme: QuantScheme,
    /// Prompt (prefill) length in tokens.
    pub n_in: usize,
    /// Decode length in tokens.
    pub n_out: usize,
}

impl Workload {
    /// Human-readable `model scheme [n_in:n_out]` tag for tables.
    pub fn label(&self) -> String {
        format!(
            "{} {} [{}:{}]",
            self.cfg.name,
            self.scheme.name(),
            self.n_in,
            self.n_out
        )
    }
}

/// Result of simulating one workload on one IMAX configuration.
#[derive(Clone, Debug)]
pub struct WorkloadRun {
    /// Modeled per-phase LOAD/EXEC/DRAIN cost totals.
    pub breakdown: RunBreakdown,
    /// Offloaded / total MAC accounting behind the Table 2 ratios.
    pub stats: OffloadStats,
    /// Total bytes moved host→IMAX (LOAD traffic).
    pub load_bytes: u64,
    /// Seconds spent in IMAX-active vs host-primary time, per kernel
    /// class — the inputs to the paper's phase-aware power model.
    pub active_time: ActiveTime,
}

/// Time with IMAX lanes active, split per kernel class (for the power
/// model: each kernel has its own synthesized power), plus transfer time
/// (DMA/PIO), light host time (dispatch/staging/sampling) and heavy host
/// time (host-executed kernels, NEON pegged).
#[derive(Clone, Copy, Debug, Default)]
pub struct ActiveTime {
    /// Seconds of IMAX-active time in fp16 kernels.
    pub fp16: f64,
    /// Seconds of IMAX-active time in q8_0 kernels.
    pub q8_0: f64,
    /// Seconds of IMAX-active time in q6_k kernels.
    pub q6_k: f64,
    /// Seconds of IMAX-active time in q3_k kernels.
    pub q3_k: f64,
    /// DMA + PIO activity (LOAD/DRAIN/CONF/REGV/RANGE).
    pub xfer: f64,
    /// Host dispatch, staging, norms, sampling.
    pub host_primary: f64,
    /// Host-executed (non-offloaded) kernels.
    pub host_compute: f64,
}

impl ActiveTime {
    /// Total seconds with IMAX lanes active, summed over kernel classes.
    pub fn imax_active(&self) -> f64 {
        self.fp16 + self.q8_0 + self.q6_k + self.q3_k
    }

    fn add_class(&mut self, class: crate::imax::isa::KernelClass, secs: f64) {
        use crate::imax::isa::KernelClass as K;
        match class {
            K::Fp16 => self.fp16 += secs,
            K::Q8_0 => self.q8_0 += secs,
            K::Q6K => self.q6_k += secs,
            K::Q3K => self.q3_k += secs,
        }
    }
}

/// Cost one kernel instance under the policy; returns (cost, offloaded).
fn cost_op(
    dev: &ImaxDevice,
    policy: &OffloadPolicy,
    tracker: &mut ConfTracker,
    op: &MatvecOp,
    batch: usize,
    mode: TransferMode,
) -> (PhaseCost, bool) {
    if policy.should_offload(dev, op) {
        (
            sim::offloaded_cost(dev, &policy.lmm, tracker, op, batch, mode),
            true,
        )
    } else {
        (sim::host_cost(dev, op, batch), false)
    }
}

/// Simulate with the standard policy for this workload (LMM from the
/// device, DMA-buffer exclusions applied).
pub fn simulate_auto(w: &Workload, dev: &ImaxDevice, mode: TransferMode) -> WorkloadRun {
    let policy = OffloadPolicy::for_workload(
        dev,
        &w.cfg,
        w.scheme,
        crate::imax::lmm::LmmConfig::new(dev.lmm_kb),
    );
    simulate(w, dev, &policy, mode)
}

/// Simulate a full workload run.
pub fn simulate(
    w: &Workload,
    dev: &ImaxDevice,
    policy: &OffloadPolicy,
    mode: TransferMode,
) -> WorkloadRun {
    let mut breakdown = RunBreakdown::default();
    let mut stats = OffloadStats::default();
    let mut load_bytes = 0u64;
    let mut active = ActiveTime::default();
    let mut tracker = ConfTracker::new();

    // ---- prefill: one batched ubatch over the prompt ----
    // Linear kernels run once with batch = n_in (weights amortized);
    // attention kernels run per position (their operand is the growing
    // KV cache, never reusable across positions).
    let last = w.n_in - 1;
    for op in ops_for_token(&w.cfg, w.scheme, last, true) {
        match op.kind {
            OpKind::Linear(_) => {
                let (cost, off) =
                    cost_op(dev, policy, &mut tracker, &op, w.n_in, mode);
                record(
                    &mut breakdown,
                    &mut stats,
                    &mut load_bytes,
                    &mut active,
                    Phase::Prefill,
                    &op,
                    cost,
                    off,
                    w.n_in,
                );
            }
            OpKind::AttnScore | OpKind::AttnMix => {
                // Sum attention over every prompt position.
                for pos in 0..w.n_in {
                    let mut aop = op.clone();
                    match op.kind {
                        OpKind::AttnScore => aop.rows = w.cfg.n_heads * (pos + 1),
                        OpKind::AttnMix => aop.cols = pos + 1,
                        _ => unreachable!(),
                    }
                    let (cost, off) =
                        cost_op(dev, policy, &mut tracker, &aop, 1, mode);
                    record(
                        &mut breakdown,
                        &mut stats,
                        &mut load_bytes,
                        &mut active,
                        Phase::Prefill,
                        &aop,
                        cost,
                        off,
                        1,
                    );
                }
            }
        }
    }
    // Host-side per-token overheads across the prompt.
    let pre_host = sim::host_token_overhead(
        dev,
        w.cfg.d_model,
        w.cfg.n_layers,
        w.cfg.n_heads,
        w.n_in,
        Some(w.cfg.vocab_size),
    )
    .scaled(w.n_in as f64);
    breakdown.add(Phase::Prefill, pre_host);
    active.host_primary += pre_host.host;

    // ---- decode: per-token steps ----
    for step in 0..w.n_out.saturating_sub(1) {
        let pos = w.n_in + step;
        for op in ops_for_token(&w.cfg, w.scheme, pos, true) {
            let (cost, off) = cost_op(dev, policy, &mut tracker, &op, 1, mode);
            record(
                &mut breakdown,
                &mut stats,
                &mut load_bytes,
                &mut active,
                Phase::Decode,
                &op,
                cost,
                off,
                1,
            );
        }
        let host = sim::host_token_overhead(
            dev,
            w.cfg.d_model,
            w.cfg.n_layers,
            w.cfg.n_heads,
            pos + 1,
            Some(w.cfg.vocab_size),
        );
        breakdown.add(Phase::Decode, host);
        active.host_primary += host.host;
    }

    WorkloadRun {
        breakdown,
        stats,
        load_bytes,
        active_time: active,
    }
}

#[allow(clippy::too_many_arguments)]
fn record(
    breakdown: &mut RunBreakdown,
    stats: &mut OffloadStats,
    load_bytes: &mut u64,
    active: &mut ActiveTime,
    phase: Phase,
    op: &MatvecOp,
    cost: PhaseCost,
    offloaded: bool,
    batch: usize,
) {
    breakdown.add(phase, cost);
    // Table 2 counts each dot-product invocation; a batched linear runs
    // rows × batch invocations.
    let mut scaled = op.clone();
    scaled.rows *= batch;
    stats.record(&scaled, offloaded);
    if offloaded {
        *load_bytes += (op.weight_bytes() + op.act_bytes() * batch) as u64;
        // EXEC at the kernel's synthesized power; transfers and PIO at
        // the memory-path power; host dispatch at light host power.
        active.add_class(crate::imax::isa::KernelClass::for_type(op.wty), cost.exec);
        active.xfer += cost.imax_total() - cost.exec;
        active.host_primary += cost.host;
    } else {
        active.host_compute += cost.total();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::lmm::LmmConfig;
    use crate::imax::isa::KernelClass;

    fn run(cfg: ModelConfig, scheme: QuantScheme, n_in: usize, n_out: usize) -> WorkloadRun {
        let w = Workload {
            cfg,
            scheme,
            n_in,
            n_out,
        };
        simulate_auto(&w, &ImaxDevice::fpga(2), TransferMode::Coalesced)
    }

    #[test]
    fn decode_is_load_bound_prefill_compute_bound() {
        // The paper's central Fig 15 finding.
        let r = run(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 32, 16);
        let d = r.breakdown.decode;
        let p = r.breakdown.prefill;
        assert!(d.load > d.exec, "decode LOAD {} > EXEC {}", d.load, d.exec);
        assert!(p.exec > p.load, "prefill EXEC {} > LOAD {}", p.exec, p.load);
    }

    #[test]
    fn e2e_grows_with_model_size() {
        let small = run(ModelConfig::qwen3_0_6b(), QuantScheme::Q8_0, 16, 4);
        let large = run(ModelConfig::qwen3_1_7b(), QuantScheme::Q8_0, 16, 4);
        assert!(large.breakdown.e2e_seconds() > 1.5 * small.breakdown.e2e_seconds());
    }

    #[test]
    fn more_output_tokens_cost_more() {
        let a = run(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 16, 4);
        let b = run(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 16, 16);
        assert!(b.breakdown.e2e_seconds() > a.breakdown.e2e_seconds());
    }

    #[test]
    fn q3ks_offload_ratios_high_q8_8b_low() {
        let r = run(ModelConfig::qwen3_0_6b(), QuantScheme::Q3KS, 32, 16);
        assert!(r.stats.total_ratio() > 0.85, "{}", r.stats.total_ratio());
        assert!(r.stats.ratio(KernelClass::Q3K).unwrap() > 0.9);

        let r8 = run(ModelConfig::qwen3_8b(), QuantScheme::Q8_0, 32, 16);
        assert!(
            r8.stats.total_ratio() < 0.35,
            "8B Q8_0 total offload should collapse: {}",
            r8.stats.total_ratio()
        );
        assert!(r8.stats.ratio(KernelClass::Q8_0).unwrap() < 0.2);
    }

    #[test]
    fn active_time_components_sum_sane() {
        let r = run(ModelConfig::qwen3_1_7b(), QuantScheme::Q3KS, 16, 4);
        let at = r.active_time;
        assert!(at.q3_k > 0.0 && at.q6_k > 0.0 && at.fp16 >= 0.0);
        assert!(at.imax_active() > 0.0);
        assert!(at.host_primary > 0.0);
    }

    #[test]
    fn naive_dma_slower() {
        let w = Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q8_0,
            n_in: 8,
            n_out: 4,
        };
        let dev = ImaxDevice::fpga(2);
        let c = simulate_auto(&w, &dev, TransferMode::Coalesced);
        let n = simulate_auto(&w, &dev, TransferMode::Naive);
        assert!(n.breakdown.e2e_seconds() > c.breakdown.e2e_seconds());
    }
}
