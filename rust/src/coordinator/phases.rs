//! Instrumented execution: wraps the functional engine's kernel dispatch
//! so every real tiny-model inference also produces (a) measured host
//! wall-time per phase and (b) the modeled IMAX phase costs for the same
//! kernel sequence — tying the functional and timing paths together (the
//! quickstart example prints both side by side).
//!
//! Ubatch dispatches ([`MatvecExec::linear_ubatch`]) are accounted with
//! the chunk size as the cost model's batch factor, so a batched prefill
//! amortizes the weight transfer and per-kernel configuration exactly the
//! way `coordinator::hybrid` models it (prefill compute-bound, decode
//! LOAD-bound — paper §V.B).

use std::time::Instant;

use crate::coordinator::offload::{OffloadPolicy, OffloadStats};
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::pio::ConfTracker;
use crate::imax::sim;
use crate::imax::timing::RunBreakdown;
use crate::model::engine::MatvecExec;
use crate::model::graph::{MatvecOp, Phase};
use crate::tensor::{ActQuant, QTensor};

/// A [`MatvecExec`] that runs kernels through an inner executor while
/// accumulating modeled IMAX costs, offload statistics, and measured
/// wall time per phase.
pub struct InstrumentedExec<E: MatvecExec> {
    pub inner: E,
    pub dev: ImaxDevice,
    pub policy: OffloadPolicy,
    pub mode: TransferMode,
    pub modeled: RunBreakdown,
    pub stats: OffloadStats,
    pub wall_prefill: f64,
    pub wall_decode: f64,
    tracker: ConfTracker,
    current_phase: Phase,
    step_start: Option<Instant>,
}

impl<E: MatvecExec> InstrumentedExec<E> {
    pub fn new(inner: E, dev: ImaxDevice, policy: OffloadPolicy, mode: TransferMode) -> Self {
        InstrumentedExec {
            inner,
            dev,
            policy,
            mode,
            modeled: RunBreakdown::default(),
            stats: OffloadStats::default(),
            wall_prefill: 0.0,
            wall_decode: 0.0,
            tracker: ConfTracker::new(),
            current_phase: Phase::Prefill,
            step_start: None,
        }
    }

    /// Account one kernel instance processing `batch` activation vectors
    /// against the same weights (batch > 1 for prefill ubatches).
    fn account(&mut self, op: &MatvecOp, batch: usize) {
        let offloaded = self.policy.should_offload(&self.dev, op);
        let cost = if offloaded {
            sim::offloaded_cost(
                &self.dev,
                &self.policy.lmm,
                &mut self.tracker,
                op,
                batch,
                self.mode,
            )
        } else {
            sim::host_cost(&self.dev, op, batch)
        };
        self.modeled.add(self.current_phase, cost);
        for _ in 0..batch {
            self.stats.record(op, offloaded);
        }
    }
}

impl<E: MatvecExec> MatvecExec for InstrumentedExec<E> {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.account(op, 1);
        self.inner.linear(op, w, act, out);
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        // One modeled launch for the whole chunk: the weight transfer and
        // configuration amortize across `acts.len()` activation vectors.
        // Dispatch through the inner executor's own ubatch hook so a
        // batching backend keeps its amortization under instrumentation.
        self.account(op, acts.len());
        self.inner.linear_ubatch(op, w, acts, outs);
    }

    fn attn(&mut self, op: &MatvecOp) {
        self.account(op, 1);
        self.inner.attn(op);
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        self.current_phase = phase;
        self.step_start = Some(Instant::now());
        self.inner.begin_step(phase, pos);
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        if let Some(t0) = self.step_start.take() {
            let dt = t0.elapsed().as_secs_f64();
            match phase {
                Phase::Prefill => self.wall_prefill += dt,
                Phase::Decode => self.wall_decode += dt,
            }
        }
        self.inner.end_step(phase, pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::lmm::LmmConfig;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::{Engine, NativeExec};
    use crate::model::sampler::Sampler;
    use crate::model::weights::ModelWeights;

    fn fpga_instrumented() -> InstrumentedExec<NativeExec> {
        InstrumentedExec::new(
            NativeExec,
            ImaxDevice::fpga(2),
            OffloadPolicy::new(LmmConfig::new(64)),
            TransferMode::Coalesced,
        )
    }

    #[test]
    fn instrumentation_tracks_real_generation() {
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 3));
        let mut exec = fpga_instrumented();
        let res = engine.generate(&[1, 2, 3, 4], 4, &mut Sampler::greedy(), &mut exec);
        assert_eq!(res.tokens.len(), 4);
        // 4-token prefill ubatch + 3 decode steps, each with linears +
        // attention.
        assert!(exec.modeled.prefill.total() > 0.0);
        assert!(exec.modeled.decode.total() > 0.0);
        assert!(exec.wall_prefill > 0.0);
        assert!(exec.wall_decode > 0.0);
        assert!(exec.stats.total_ratio() > 0.0);
    }

    #[test]
    fn instrumented_results_match_native() {
        let cfg = ModelConfig::tiny();
        let mut e1 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let mut e2 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let mut inst = fpga_instrumented();
        let a = e1.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut NativeExec);
        let b = e2.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut inst);
        assert_eq!(a.tokens, b.tokens, "instrumentation must not alter results");
    }

    #[test]
    fn ubatch_accounting_amortizes_prefill() {
        // The same 8-token prompt, prefilled as one ubatch vs one token
        // at a time: identical compute, but the batched run amortizes
        // weight LOAD and configuration, so its modeled prefill must be
        // strictly cheaper.
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 9);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];

        let mut batched = Engine::new(weights.clone());
        let mut exec_b = fpga_instrumented();
        let sess = batched.open_session(Sampler::greedy()).unwrap();
        batched.prefill_session(&sess, &prompt, prompt.len(), &mut exec_b);

        let mut seq = Engine::new(weights);
        let mut exec_s = fpga_instrumented();
        for (i, &t) in prompt.iter().enumerate() {
            seq.forward(t, Phase::Prefill, i + 1 == prompt.len(), &mut exec_s);
        }

        let b = exec_b.modeled.prefill;
        let s = exec_s.modeled.prefill;
        assert!(
            b.load < s.load,
            "batched LOAD {} must beat sequential {}",
            b.load,
            s.load
        );
        assert!(b.total() < s.total(), "batched prefill cheaper overall");
        // Same kernels were executed either way.
        assert!((exec_b.stats.total_ratio() - exec_s.stats.total_ratio()).abs() < 1e-9);
    }
}
